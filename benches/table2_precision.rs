//! Bench: paper Table II — precision of the analytic `O_s` method.
//!
//! Regenerates the table (exact algorithmic value vs analytic lower
//! bound, error normalised both ways) and measures the cost of each
//! method on the peak-defining op of each model — the motivation for the
//! analytic method (§III-D: "without needing to loop through a large
//! simulated tensor operation, potentially taking millions of
//! iterations").

use dmo::models;
use dmo::overlap::{compute_os, Method};
use dmo::planner::PlannedModel;
use dmo::report::precision_row;
use dmo::util::bench::{report, time};

fn main() {
    println!("=== Table II: estimation error of safe overlap (O_s) ===\n");
    println!(
        "{:28} {:>14} {:>14} {:>9} {:>12}",
        "model", "exact O_s", "analytic O_s", "err/O_s", "err/peak"
    );
    for name in [
        "mobilenet_v1_1.0_224",
        "mobilenet_v2_1.0_224",
        "inception_resnet_v2",
    ] {
        let pm = PlannedModel::new(models::build(name).unwrap()).unwrap();
        let r = precision_row(&pm.graph);
        let row = pm.row();
        println!(
            "{:28} {:>14} {:>14} {:>8.2}% {:>11.2}%",
            name,
            r.exact,
            r.estimate,
            r.error_pct(),
            r.error_vs_peak_pct(row.original)
        );
    }
    println!("\npaper: 1204224 / 1193376 / 0.18% for the §III-E worked op;");
    println!("       0% error rows are peak ops whose bound is tight.\n");

    println!("=== Method cost on the Table-I op (112×112×96 dw s2) ===\n");
    let x = dmo::ir::Shape::hwc(112, 112, 96);
    let k = dmo::ir::OpKind::DepthwiseConv2D(dmo::ir::op::DepthwiseParams {
        kernel: (3, 3),
        stride: (2, 2),
        dilation: (1, 1),
        padding: dmo::ir::Padding::Same,
        depth_multiplier: 1,
        act: dmo::ir::Activation::None,
    });
    let out = dmo::ops::infer_output(&k, &[&x]).unwrap();
    for (m, iters) in [
        (Method::Analytic, 1000),
        (Method::Algorithmic, 10),
        (Method::BottomUp, 3),
    ] {
        let meas = time(&format!("O_s via {:12}", m.name()), iters, || {
            std::hint::black_box(compute_os(m, &k, &[&x], &out, dmo::ir::DType::F32));
        });
        report(&meas);
    }
}
