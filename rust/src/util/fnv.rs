//! Shared 64-bit FNV-1a — the repository's deterministic structural
//! hash, used by plan-artifact fingerprints and the persisted `O_s`
//! cache's content addresses. One implementation so the constants can
//! never drift between users.

/// Incremental FNV-1a hasher.
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    pub fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    /// Fold one machine word (hashed as a little-endian `u64`).
    pub fn word(&mut self, v: usize) {
        self.bytes(&(v as u64).to_le_bytes());
    }

    /// Fold a length-prefixed string.
    pub fn str(&mut self, v: &str) {
        self.word(v.len());
        self.bytes(v.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector_and_order_sensitivity() {
        // FNV-1a of the empty input is the offset basis
        assert_eq!(Fnv::new().finish(), 0xcbf2_9ce4_8422_2325);
        let mut a = Fnv::new();
        a.str("ab");
        let mut b = Fnv::new();
        b.str("ba");
        assert_ne!(a.finish(), b.finish());
        // word() is the little-endian u64 fold str() builds on
        let mut w = Fnv::new();
        w.word(2);
        let mut manual = Fnv::new();
        manual.bytes(&2u64.to_le_bytes());
        assert_eq!(w.finish(), manual.finish());
    }
}
