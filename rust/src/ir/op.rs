//! Operation kinds and their static parameters.
//!
//! The set mirrors the TFLite reference kernels the paper analyses
//! (§III, Fig 3): convolutions, pooling, element-wise ops, fully
//! connected / matmul, plus the re-arrangement ops (concat, pad,
//! reshape) that §II-C's *operation removal* targets.
//!
//! Behaviour (shape inference, memory-access patterns, numerics) lives in
//! [`crate::ops`]; this module is pure data so graphs stay cheap to build,
//! clone and serialise.

use super::shape::Shape;

/// Spatial padding scheme (TFLite semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Padding {
    /// Output = ceil(input / stride); zero padding split per Eqs (5)/(6).
    Same,
    /// No padding; output = ceil((input − (k−1)·d) / stride).
    Valid,
}

/// Activation fused into a producing op (TFLite fuses these, so no
/// intermediate tensor exists between e.g. a conv and its relu).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    None,
    Relu,
    Relu6,
}

/// Parameters shared by 2-D convolution-family ops.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Conv2DParams {
    /// Kernel size (h, w) — the paper's `K_h`, `K_w`.
    pub kernel: (usize, usize),
    /// Stride (h, w) — `S_h`, `S_w`.
    pub stride: (usize, usize),
    /// Dilation (h, w) — `D_h`, `D_w`.
    pub dilation: (usize, usize),
    /// Padding scheme.
    pub padding: Padding,
    /// Output channels (`O_d`).
    pub out_channels: usize,
    /// Fused activation.
    pub act: Activation,
}

/// Parameters for depthwise 2-D convolution.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct DepthwiseParams {
    /// Kernel size (h, w).
    pub kernel: (usize, usize),
    /// Stride (h, w).
    pub stride: (usize, usize),
    /// Dilation (h, w).
    pub dilation: (usize, usize),
    /// Padding scheme.
    pub padding: Padding,
    /// Channel multiplier — the paper's `filterC` / `K_c`.
    pub depth_multiplier: usize,
    /// Fused activation.
    pub act: Activation,
}

/// Pooling flavour.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    Max,
    Avg,
}

/// Parameters for spatial pooling.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PoolParams {
    pub kind: PoolKind,
    /// Window size (h, w).
    pub kernel: (usize, usize),
    /// Stride (h, w).
    pub stride: (usize, usize),
    pub padding: Padding,
}

/// Binary element-wise flavour.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinaryKind {
    Add,
    Mul,
}

/// Unary element-wise flavour (standalone, i.e. not fused).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnaryKind {
    Relu,
    Relu6,
    /// Identity copy (also models quantize/dequantize for planning).
    Copy,
}

/// Static parameters of a §II-A *banded* window op: the underlying op
/// restricted to a horizontal band of its output rows, with its input
/// and output tensors holding only the rows the band touches.
///
/// All padding / clipping geometry is computed against the **full**
/// frame (`full_in_h` / `full_out_h`), so each output element of a band
/// is produced by exactly the arithmetic the unsplit op would use —
/// banded execution is bit-identical to full execution by construction
/// (the invariant `ir::rewrite::split_chain` — and its depth-2 shim
/// `split_pair` — and the interpreter's split-safety proofs rely on).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BandParams {
    /// The full op this band is a slice of. Restricted to the window
    /// family ([`OpKind::bandable`]): conv2d, dwconv2d, pool, unary.
    pub inner: Box<OpKind>,
    /// Height of the full (virtual) input frame — `P_h` and bounds
    /// clipping are derived from this, not the band's tensor height.
    pub full_in_h: usize,
    /// Global row index of the input tensor's row 0 within the full
    /// input frame (`0` when the op reads the whole input tensor).
    pub in_row0: usize,
    /// Height of the full (virtual) output frame.
    pub full_out_h: usize,
    /// First output row this band computes (global).
    pub out_row0: usize,
    /// Number of output rows this band computes.
    pub out_rows: usize,
}

impl BandParams {
    /// `(kernel_h, stride_h, dilation_h)` of the inner op.
    pub fn window_h(&self) -> (usize, usize, usize) {
        match self.inner.as_ref() {
            OpKind::Conv2D(p) => (p.kernel.0, p.stride.0, p.dilation.0),
            OpKind::DepthwiseConv2D(p) => (p.kernel.0, p.stride.0, p.dilation.0),
            OpKind::Pool(p) => (p.kernel.0, p.stride.0, 1),
            _ => (1, 1, 1),
        }
    }

    /// `P_h` of the full-frame geometry (Eq 5).
    pub fn pad_h(&self) -> usize {
        let (kh, sh, dh) = self.window_h();
        pad_before(self.full_in_h, self.full_out_h, kh, sh, dh)
    }

    /// Global input-row range `[lo, hi)` (clipped to the full frame)
    /// this band's receptive field reads. Empty when the band's whole
    /// window falls in padding.
    pub fn in_rows_needed(&self) -> (usize, usize) {
        let (kh, sh, dh) = self.window_h();
        let ph = self.pad_h() as isize;
        let lo = (self.out_row0 as isize * sh as isize - ph).clamp(0, self.full_in_h as isize);
        let hi = ((self.out_row0 + self.out_rows - 1) as isize * sh as isize - ph
            + ((kh - 1) * dh) as isize
            + 1)
            .clamp(0, self.full_in_h as isize);
        (lo as usize, hi.max(lo) as usize)
    }
}

/// An operation kind with its static parameters.
///
/// `Eq`/`Hash` so a kind (with its parameters) can participate in the
/// canonical op signature keying the `O_s` cache
/// ([`crate::overlap::cache::OpSignature`]).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Standard 2-D convolution (one activation input; weights are op
    /// attributes and live in flash, not the tensor arena).
    Conv2D(Conv2DParams),
    /// Depthwise 2-D convolution — the op the paper derives `O_s` for
    /// analytically (§III-D).
    DepthwiseConv2D(DepthwiseParams),
    /// Max / average pooling.
    Pool(PoolParams),
    /// Global average pooling over H×W, output `[1, 1, 1, C]`.
    GlobalAvgPool,
    /// Standalone unary element-wise op (Fig 3a).
    Unary(UnaryKind),
    /// Binary element-wise op over two equal-shaped inputs (residual adds).
    Binary(BinaryKind),
    /// Fully connected layer, TFLite reference loop order
    /// (per-output-element accumulate in a register, single store).
    FullyConnected {
        out_features: usize,
        act: Activation,
    },
    /// Matrix multiply with *accumulate-in-output* loop order — the
    /// worst-case access pattern of Fig 3b where `O_s ≈ 0`.
    MatMulAccum {
        out_features: usize,
    },
    /// Concatenate along the channel axis (NHWC axis 3) — the op that §II-C
    /// operation removal elides.
    Concat,
    /// Spatial zero padding: `(top, bottom, left, right)`.
    Pad {
        pad: (usize, usize, usize, usize),
    },
    /// Row-wise softmax over the last axis.
    Softmax,
    /// Shape change without element movement.
    Reshape {
        to: Shape,
    },
    /// §II-A banded slice of a window op — computes only the output
    /// rows in [`BandParams::out_row0`], reading the input rows the
    /// receptive-field halo requires. Produced by
    /// [`crate::ir::rewrite::split_chain`] (and its `split_pair` shim);
    /// never emitted by the model builders.
    Band(BandParams),
    /// Concatenate along the row (H) axis — reassembles the banded
    /// outputs of a split pair into the full tensor downstream
    /// consumers expect. Row-major NHWC makes this a pure sequential
    /// copy per input.
    ConcatRows,
}

impl OpKind {
    /// Short name for reports and traces.
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Conv2D(_) => "conv2d",
            OpKind::DepthwiseConv2D(_) => "dwconv2d",
            OpKind::Pool(p) => match p.kind {
                PoolKind::Max => "maxpool",
                PoolKind::Avg => "avgpool",
            },
            OpKind::GlobalAvgPool => "gavgpool",
            OpKind::Unary(u) => match u {
                UnaryKind::Relu => "relu",
                UnaryKind::Relu6 => "relu6",
                UnaryKind::Copy => "copy",
            },
            OpKind::Binary(b) => match b {
                BinaryKind::Add => "add",
                BinaryKind::Mul => "mul",
            },
            OpKind::FullyConnected { .. } => "fc",
            OpKind::MatMulAccum { .. } => "matmul",
            OpKind::Concat => "concat",
            OpKind::Pad { .. } => "pad",
            OpKind::Softmax => "softmax",
            OpKind::Reshape { .. } => "reshape",
            OpKind::Band(b) => match b.inner.as_ref() {
                OpKind::Conv2D(_) => "band-conv2d",
                OpKind::DepthwiseConv2D(_) => "band-dwconv2d",
                OpKind::Pool(_) => "band-pool",
                _ => "band",
            },
            OpKind::ConcatRows => "concat-rows",
        }
    }

    /// Number of activation inputs this kind consumes (the concats are
    /// variadic and return `None`).
    pub fn arity(&self) -> Option<usize> {
        match self {
            OpKind::Binary(_) => Some(2),
            OpKind::Concat | OpKind::ConcatRows => None,
            _ => Some(1),
        }
    }

    /// Can this kind be sliced into horizontal bands by
    /// [`crate::ir::rewrite::split_chain`]? The window family: output
    /// row `r` depends only on a contiguous input-row window, so a band
    /// of output rows needs only a band of input rows.
    pub fn bandable(&self) -> bool {
        matches!(
            self,
            OpKind::Conv2D(_) | OpKind::DepthwiseConv2D(_) | OpKind::Pool(_) | OpKind::Unary(_)
        )
    }
}

/// Resolved padding amounts before the start of each spatial axis —
/// the paper's `P_h` / `P_w` (Eqs 5, 6), matching TFLite:
/// `pad_before = max(0, ((O−1)·S + (K−1)·D + 1 − I) / 2)` (floor).
pub fn pad_before(input: usize, output: usize, kernel: usize, stride: usize, dilation: usize) -> usize {
    let total = (output as isize - 1) * stride as isize + ((kernel as isize - 1) * dilation as isize + 1)
        - input as isize;
    (total.max(0) / 2) as usize
}

/// TFLite output size for one spatial axis.
pub fn out_dim(input: usize, kernel: usize, stride: usize, dilation: usize, padding: Padding) -> usize {
    let eff_k = (kernel - 1) * dilation + 1;
    match padding {
        Padding::Same => input.div_ceil(stride),
        Padding::Valid => (input.saturating_sub(eff_k - 1)).div_ceil(stride),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_dims_match_tflite() {
        // 224 -> 112 with k3 s2 SAME
        assert_eq!(out_dim(224, 3, 2, 1, Padding::Same), 112);
        // 112 -> 56 with k3 s2 SAME
        assert_eq!(out_dim(112, 3, 2, 1, Padding::Same), 56);
        // 147 -> 73 with k3 s2 VALID
        assert_eq!(out_dim(147, 3, 2, 1, Padding::Valid), 73);
        // 149 -> 147 with k3 s1 VALID
        assert_eq!(out_dim(149, 3, 1, 1, Padding::Valid), 147);
    }

    #[test]
    fn pad_before_matches_eq5() {
        // Table I op: in 112, out 56, k3, s2 -> P_h = 0
        assert_eq!(pad_before(112, 56, 3, 2, 1), 0);
        // in 224, out 112, k3, s2 -> total = 111*2+3-224 = 1 -> before 0
        assert_eq!(pad_before(224, 112, 3, 2, 1), 0);
        // in 112, out 112, k3, s1 -> total = 2 -> before 1
        assert_eq!(pad_before(112, 112, 3, 1, 1), 1);
    }
}
