//! Leveled stderr logging with a `DMO_LOG` environment filter.
//!
//! Replaces raw `eprintln!` at runtime-event sites (fleet hot-reload,
//! watcher rejections) so serve output is machine-parseable
//! (`dmo[LEVEL] message`) and quiet by default: the filter defaults to
//! `warn`, so info-level chatter never pollutes bench output unless
//! `DMO_LOG=info` (or lower) is set.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn from_u8(v: u8) -> Level {
        match v {
            1 => Level::Error,
            2 => Level::Warn,
            3 => Level::Info,
            4 => Level::Debug,
            _ => Level::Trace,
        }
    }

    fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

fn parse(s: &str) -> Option<Level> {
    match s.trim().to_ascii_lowercase().as_str() {
        "error" | "e" | "1" => Some(Level::Error),
        "warn" | "warning" | "w" | "2" => Some(Level::Warn),
        "info" | "i" | "3" => Some(Level::Info),
        "debug" | "d" | "4" => Some(Level::Debug),
        "trace" | "t" | "5" => Some(Level::Trace),
        _ => None,
    }
}

/// `u8::MAX` = not yet resolved from the environment.
static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

/// The active filter level: `DMO_LOG` if set and valid, else `warn`.
/// Parsed once; [`set_level`] overrides (used by tests and `--quiet`-style
/// callers).
pub fn level() -> Level {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return Level::from_u8(v);
    }
    let resolved = std::env::var("DMO_LOG")
        .ok()
        .and_then(|s| parse(&s))
        .unwrap_or(Level::Warn);
    LEVEL.store(resolved as u8, Ordering::Relaxed);
    resolved
}

/// Override the filter level (takes precedence over `DMO_LOG`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Whether a message at `l` would be emitted.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Emit a message at `l` if the filter allows it. Prefer the per-level
/// helpers with `format_args!`:
/// `obs::log::info(format_args!("reloaded {name}"))`.
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("dmo[{}] {}", l.name(), args);
    }
}

pub fn error(args: std::fmt::Arguments<'_>) {
    log(Level::Error, args);
}

pub fn warn(args: std::fmt::Arguments<'_>) {
    log(Level::Warn, args);
}

pub fn info(args: std::fmt::Arguments<'_>) {
    log(Level::Info, args);
}

pub fn debug(args: std::fmt::Arguments<'_>) {
    log(Level::Debug, args);
}

pub fn trace(args: std::fmt::Arguments<'_>) {
    log(Level::Trace, args);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_names_and_numbers() {
        assert_eq!(parse("info"), Some(Level::Info));
        assert_eq!(parse("WARN"), Some(Level::Warn));
        assert_eq!(parse(" trace "), Some(Level::Trace));
        assert_eq!(parse("4"), Some(Level::Debug));
        assert_eq!(parse("nonsense"), None);
    }

    #[test]
    fn ordering_matches_severity() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }
}
