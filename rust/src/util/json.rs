//! Minimal JSON reader/writer.
//!
//! Used for the artifact metadata sidecar (`artifacts/model.meta.json`,
//! written by `python/compile/aot.py` and consumed by the planner) and for
//! report emission. Supports the full JSON grammar except `\u` surrogate
//! pairs, which the sidecar never contains.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.pos == p.bytes.len(), "trailing bytes at {}", p.pos);
        Ok(v)
    }
}

/// Convenience: build an object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: numeric value.
pub fn num(n: usize) -> Json {
    Json::Num(n as f64)
}

/// Convenience: string value.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(b),
            "expected '{}' at {}",
            b as char,
            self.pos
        );
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "bad literal at {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => anyhow::bail!("expected ',' or '}}' at {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => anyhow::bail!("expected ',' or ']' at {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let v = obj(vec![
            ("name", s("mobilenet")),
            ("peak", num(96 * 1024)),
            ("shapes", Json::Arr(vec![num(1), num(128), num(128), num(3)])),
            ("quantised", Json::Bool(true)),
            ("note", Json::Null),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_nested_and_escapes() {
        let v = Json::parse(r#"{"a": [1, -2.5, {"b": "x\ny\"z"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(-2.5));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny\"z"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }
}
