"""L1 Pallas kernel: depthwise 2-D convolution.

The paper's analytic `O_s` derivation (§III-D) is built on exactly this
op's low-to-high sweep; the kernel keeps that *diagonal* schedule on TPU:
the grid walks output rows in increasing order, each step consuming an
input row-band (the window halo) and producing one output row. That
HBM→VMEM block schedule is the TPU analogue of the MCU loop nest the
paper instruments — reads lead writes by the halo, which is precisely
what makes the buffers overlappable (DESIGN.md §Hardware-Adaptation).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so correctness runs through the interpreter and real-TPU
performance is estimated from the block working set (EXPERIMENTS.md
§Perf-L1).

VMEM working set per grid step (f32):
    input band  K_eff × Wp × C
    weights     Kh × Kw × C
    output row  OW × C
e.g. the tiny serving model's 16×16×8 dw3x3 s1 step holds
3×18×8 + 3×3×8 + 16×8 ≈ 2.7 KB — far under the ~16 MB VMEM budget, so
rows could be aggregated into multi-row blocks on real hardware; the
row-granular schedule is kept because it maximises the overlap window.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import out_dim


def _pad_amounts(i: int, o: int, k: int, s: int):
    """TFLite SAME padding split (Eqs 5/6 of the paper)."""
    total = max(0, (o - 1) * s + k - i)
    before = total // 2
    return before, total - before


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def dwconv2d(x, w, stride=(1, 1), padding="SAME"):
    """Depthwise conv via Pallas: x (H, W, C), w (Kh, Kw, C) → (OH, OW, C)."""
    h, wd, c = x.shape
    kh, kw, wc = w.shape
    assert wc == c, f"filter channels {wc} != input channels {c}"
    sh, sw = stride
    oh = out_dim(h, kh, sh, padding)
    ow = out_dim(wd, kw, sw, padding)

    if padding == "SAME":
        pt, pb = _pad_amounts(h, oh, kh, sh)
        plf, prt = _pad_amounts(wd, ow, kw, sw)
        xp = jnp.pad(x, ((pt, pb), (plf, prt), (0, 0)))
    else:
        xp = x
    hp, wp, _ = xp.shape
    # guarantee the last window fits (defensive for VALID + stride tails)
    need_h = (oh - 1) * sh + kh
    need_w = (ow - 1) * sw + kw
    if need_h > hp or need_w > wp:
        xp = jnp.pad(xp, ((0, max(0, need_h - hp)), (0, max(0, need_w - wp)), (0, 0)))
        hp, wp, _ = xp.shape

    def kernel(x_ref, w_ref, o_ref):
        oy = pl.program_id(0)
        acc = jnp.zeros((ow, c), dtype=x_ref.dtype)
        for ky in range(kh):  # static unroll over the filter window
            # one padded input row: (wp, c)
            row = x_ref[pl.ds(oy * sh + ky, 1), :, :][0]
            for kx in range(kw):
                # strided column gather for every output x at once
                cols = jax.lax.slice(row, (kx, 0), (kx + (ow - 1) * sw + 1, c), (sw, 1))
                acc = acc + cols * w_ref[ky, kx]
        o_ref[pl.ds(oy, 1), :, :] = acc[None]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((oh, ow, c), x.dtype),
        grid=(oh,),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, w)
