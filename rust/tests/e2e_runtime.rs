//! End-to-end tests over the PJRT runtime and the serving coordinator.
//!
//! These need `make artifacts` to have run; they skip (with a note)
//! when the artifacts are absent so `cargo test` stays green in a fresh
//! checkout. CI runs `make test`, which builds artifacts first.

use dmo::coordinator::{serve, BatchPolicy, ServeConfig};
use dmo::runtime::{default_artifacts_dir, Engine};
use std::time::Duration;

fn artifacts_ready() -> bool {
    let ok = default_artifacts_dir().join("model.meta.json").exists();
    if !ok {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
    }
    ok
}

#[test]
fn engine_loads_and_outputs_distributions() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::load(&default_artifacts_dir()).unwrap();
    assert_eq!(engine.platform(), "cpu");
    let per = engine.meta.elements_per_request();
    for &b in &engine.meta.batch_sizes {
        let v = engine.variant_for(b);
        assert_eq!(v.batch, b);
        let mut rng = dmo::util::rng::Rng::new(b as u64);
        let input: Vec<f32> = (0..b * per).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let out = engine.run(v, &input).unwrap();
        assert_eq!(out.len(), b * engine.meta.output_features);
        for row in out.chunks(engine.meta.output_features) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "softmax row sums to {s}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }
}

#[test]
fn engine_is_deterministic_and_batch_consistent() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::load(&default_artifacts_dir()).unwrap();
    let per = engine.meta.elements_per_request();
    let mut rng = dmo::util::rng::Rng::new(5);
    let one: Vec<f32> = (0..per).map(|_| rng.uniform(-1.0, 1.0)).collect();

    // b=1 twice: identical
    let v1 = engine.variant_for(1);
    let a = engine.run(v1, &one).unwrap();
    let b = engine.run(v1, &one).unwrap();
    assert_eq!(a, b);

    // the same example inside a padded b=4 batch: same row
    let v4 = engine.variant_for(3);
    assert_eq!(v4.batch, 4);
    let mut padded = vec![0.0f32; 4 * per];
    padded[..per].copy_from_slice(&one);
    let out = engine.run(v4, &padded).unwrap();
    let of = engine.meta.output_features;
    for (x, y) in a.iter().zip(&out[..of]) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

#[test]
fn variant_selection_rounds_up() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::load(&default_artifacts_dir()).unwrap();
    assert_eq!(engine.variant_for(1).batch, 1);
    assert_eq!(engine.variant_for(3).batch, 4);
    assert_eq!(engine.variant_for(8).batch, 8);
    assert_eq!(engine.variant_for(100).batch, 8); // clamped to largest
}

#[test]
fn serve_completes_all_requests() {
    if !artifacts_ready() {
        return;
    }
    let cfg = ServeConfig {
        requests: 48,
        rate: 2000.0,
        queue_capacity: 64,
        policy: BatchPolicy {
            max_batch: 8,
            window: Duration::from_millis(1),
        },
        seed: 3,
        ..Default::default()
    };
    let r = serve(&cfg).unwrap();
    assert_eq!(r.completed + r.shed, 48);
    assert!(r.completed > 0);
    let l = r.metrics.latency();
    assert!(l.p50_us > 0.0 && l.p99_us >= l.p50_us);
    assert!(r.metrics.batch_efficiency() > 0.1);
    // the DMO arena story is attached to the report
    assert!(r.arena_dmo < r.arena_original);
}
