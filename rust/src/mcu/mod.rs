//! Micro-controller deployment-fit analysis (§IV discussion).
//!
//! The paper's point: intermediate-tensor RAM, not weight storage, gates
//! deployment — MCUs almost universally carry far more flash than SRAM.
//! The catalog includes the paper's two parts (STM32F103xF hosting the
//! smallest MobileNet *only with DMO*, and the AT32UC3C of ESA's ESEO
//! mission) plus common contemporary targets.

use crate::ir::graph::Graph;
use crate::planner::SavingRow;

/// A micro-controller deployment target.
#[derive(Debug, Clone)]
pub struct Mcu {
    pub name: &'static str,
    pub core: &'static str,
    pub flash_bytes: usize,
    pub sram_bytes: usize,
}

/// Catalog of targets. Flash/SRAM from the referenced datasheets.
pub fn catalog() -> Vec<Mcu> {
    vec![
        Mcu {
            // §IV: "768 KB or 1 MB of program storage and 96 KB of SRAM"
            name: "STM32F103xF",
            core: "Cortex-M3",
            flash_bytes: 768 * 1024,
            sram_bytes: 96 * 1024,
        },
        Mcu {
            // §IV: ESA ESEO on-board computer; ≥4× more flash than SRAM
            name: "AT32UC3C0512C",
            core: "AVR32",
            flash_bytes: 512 * 1024,
            sram_bytes: 68 * 1024,
        },
        Mcu {
            name: "STM32F746",
            core: "Cortex-M7",
            flash_bytes: 1024 * 1024,
            sram_bytes: 320 * 1024,
        },
        Mcu {
            name: "STM32H743",
            core: "Cortex-M7",
            flash_bytes: 2 * 1024 * 1024,
            sram_bytes: 1024 * 1024,
        },
        Mcu {
            name: "nRF52840",
            core: "Cortex-M4",
            flash_bytes: 1024 * 1024,
            sram_bytes: 256 * 1024,
        },
        Mcu {
            name: "ESP32-WROOM",
            core: "Xtensa LX6",
            flash_bytes: 4 * 1024 * 1024,
            sram_bytes: 520 * 1024,
        },
        Mcu {
            name: "RP2040 (2MB QSPI)",
            core: "Cortex-M0+",
            flash_bytes: 2 * 1024 * 1024,
            sram_bytes: 264 * 1024,
        },
        Mcu {
            // mid-range M4 with 64 KB SRAM: the class of part the
            // paper's smallest MobileNet *just* misses even with DMO
            // (64 KB + a few bytes of arena) — §II-A splitting is what
            // puts it on this device
            name: "STM32F303RE",
            core: "Cortex-M4",
            flash_bytes: 512 * 1024,
            sram_bytes: 64 * 1024,
        },
    ]
}

/// Can `model` deploy on `mcu` given an arena of `arena_bytes`?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fit {
    /// The flash image (weights, plus code when checked via
    /// [`fit_flash`] with an emitted unit's footprint) fits.
    pub weights_fit: bool,
    pub arena_fits: bool,
    /// flash image bytes / flash capacity, scaled by 1000 (‰)
    pub flash_permille: usize,
}

impl Fit {
    pub fn deployable(&self) -> bool {
        self.weights_fit && self.arena_fits
    }
}

/// Fit check against an explicit flash image size — use
/// [`crate::codegen::flash_footprint`] (weights + code estimate) to
/// check the unit `dmo emit-c` actually produces, not just its weights.
pub fn fit_flash(mcu: &Mcu, arena_bytes: usize, flash_needed: usize) -> Fit {
    Fit {
        weights_fit: flash_needed <= mcu.flash_bytes,
        arena_fits: arena_bytes <= mcu.sram_bytes,
        flash_permille: if mcu.flash_bytes == 0 {
            1000
        } else {
            flash_needed * 1000 / mcu.flash_bytes
        },
    }
}

/// Weights-only fit check for a model on an MCU (the paper's §IV
/// accounting, which ignores code size).
pub fn fit(graph: &Graph, mcu: &Mcu, arena_bytes: usize) -> Fit {
    fit_flash(mcu, arena_bytes, graph.weight_bytes())
}

/// One row of the deployment matrix: does DMO — or §II-A splitting —
/// change deployability?
#[derive(Debug, Clone)]
pub struct DeployRow {
    pub model: String,
    pub mcu: &'static str,
    /// Flash bytes the emitted unit needs (weights + code estimate).
    pub flash_bytes: usize,
    /// The emitted unit's flash image fits this part.
    pub flash_fits: bool,
    pub without_dmo: bool,
    pub with_dmo: bool,
    /// Deployability of the best split plan, when one was computed and
    /// a split rewrite won (`None` = no split plan to compare).
    pub with_split: Option<bool>,
}

impl DeployRow {
    /// A (model, target) pair that becomes deployable *only* through
    /// §II-A splitting — the rescue the paper's future-work section
    /// promises.
    pub fn rescued_by_split(&self) -> bool {
        self.with_split == Some(true) && !self.with_dmo && !self.without_dmo
    }
}

/// Cross every catalog MCU with a planned model. Deployability checks
/// the full emitted-unit flash footprint (weights + code estimate via
/// [`crate::codegen::flash_footprint`]), not just SRAM.
pub fn deploy_matrix(graph: &Graph, row: &SavingRow) -> Vec<DeployRow> {
    deploy_matrix_split(graph, row, None)
}

/// [`deploy_matrix`] with an optional split plan: `split` carries the
/// split plan's peak and the rewritten (banded) graph, whose flash
/// footprint gates the split column — weights are stored once per
/// original op ([`Graph::weight_bytes`] dedupes), but the banded
/// kernels and extra call sites cost code bytes.
pub fn deploy_matrix_split(
    graph: &Graph,
    row: &SavingRow,
    split: Option<(usize, &Graph)>,
) -> Vec<DeployRow> {
    let flash = crate::codegen::flash_footprint(graph).total();
    let split_flash = split.map(|(_, g)| crate::codegen::flash_footprint(g).total());
    catalog()
        .iter()
        .map(|m| DeployRow {
            model: graph.name.clone(),
            mcu: m.name,
            flash_bytes: flash,
            flash_fits: flash <= m.flash_bytes,
            without_dmo: fit_flash(m, row.original, flash).deployable(),
            with_dmo: fit_flash(m, row.optimised, flash).deployable(),
            with_split: split.map(|(peak, _)| {
                fit_flash(m, peak, split_flash.unwrap_or(flash)).deployable()
            }),
        })
        .collect()
}

/// Deployment matrix for a fully planned model, including the split
/// column when [`crate::planner::PlannedModel::new_split`] found a
/// winning rewrite.
pub fn deploy_matrix_planned(pm: &crate::planner::PlannedModel) -> Vec<DeployRow> {
    let split = pm
        .split
        .as_ref()
        .and_then(|p| p.rewrite.as_ref().map(|r| (p.peak(), &r.graph)));
    deploy_matrix_split(&pm.graph, &pm.row(), split)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::planner::PlannedModel;

    /// §IV's headline deployment claim: MobileNet v1 0.25 128 (8-bit)
    /// fits the STM32F103xF's 96 KB SRAM *only* with DMO (96 KB arena
    /// leaves no room for stack/runtime; 64 KB does), and its ~620 KB of
    /// weights take most of the 768 KB flash.
    #[test]
    fn stm32f103_needs_dmo_for_smallest_mobilenet() {
        let pm = PlannedModel::new(models::build("mobilenet_v1_0.25_128_int8").unwrap()).unwrap();
        let row = pm.row();
        let stm = &catalog()[0];
        // without DMO the arena exactly consumes all SRAM — treat the
        // paper's "only possible with DMO" as requiring headroom
        let without = fit(&pm.graph, stm, row.original + 4 * 1024); // +4 KB runtime headroom
        let with = fit(&pm.graph, stm, row.optimised + 4 * 1024);
        assert!(!without.arena_fits, "96 KB arena + runtime must NOT fit");
        assert!(with.arena_fits, "64 KB arena + runtime must fit");
        assert!(with.weights_fit, "weights must fit flash");
        // §IV: weights ≈ 60.8 % of program memory; ours is close
        assert!(
            with.flash_permille > 400 && with.flash_permille < 800,
            "got {}",
            with.flash_permille
        );
    }

    #[test]
    fn big_models_never_fit_mcus() {
        let pm = PlannedModel::new(models::build("mobilenet_v2_1.0_224").unwrap()).unwrap();
        let row = pm.row();
        for m in catalog() {
            assert!(
                !fit(&pm.graph, &m, row.optimised).deployable(),
                "{} should not fit",
                m.name
            );
        }
    }

    #[test]
    fn matrix_shape() {
        let pm = PlannedModel::new(models::build("tiny_int8").unwrap()).unwrap();
        let rows = deploy_matrix(&pm.graph, &pm.row());
        assert_eq!(rows.len(), catalog().len());
        // tiny model fits everything, with or without
        assert!(rows.iter().all(|r| r.with_dmo && r.flash_fits));
        // the matrix accounts for code, not just weights
        assert!(rows.iter().all(|r| r.flash_bytes > pm.graph.weight_bytes()));
    }

    /// The §II-A pay-off the paper leaves as future work: the smallest
    /// MobileNet's DMO arena is 64 KB *plus a few bytes*, so a 64 KB
    /// part refuses it — only the split plan (≈61 KB) deploys there.
    #[test]
    fn split_rescues_mnv1_on_the_64kb_part() {
        let pm = PlannedModel::new_split(
            models::build("mobilenet_v1_0.25_128_int8").unwrap(),
            4,
            0,
            None,
        )
        .unwrap();
        let split = pm.split.as_ref().expect("splitting must win on mnv1");
        assert!(split.peak() < pm.dmo.peak());
        assert!(split.peak() <= 64 * 1024, "split peak {} > 64 KB", split.peak());
        let rows = deploy_matrix_planned(&pm);
        let f303 = rows.iter().find(|r| r.mcu == "STM32F303RE").unwrap();
        assert!(!f303.without_dmo, "96 KB arena cannot fit 64 KB SRAM");
        assert!(!f303.with_dmo, "64 KB + ε arena cannot fit 64 KB SRAM");
        assert_eq!(f303.with_split, Some(true));
        assert!(f303.rescued_by_split());
        assert_eq!(rows.iter().filter(|r| r.rescued_by_split()).count(), 1);
    }

    #[test]
    fn unsplit_matrix_carries_no_split_column() {
        let pm = PlannedModel::new(models::build("tiny_int8").unwrap()).unwrap();
        let rows = deploy_matrix(&pm.graph, &pm.row());
        assert!(rows.iter().all(|r| r.with_split.is_none()));
        assert!(rows.iter().all(|r| !r.rescued_by_split()));
    }

    #[test]
    fn flash_image_gates_deployability() {
        let g = models::build("tiny_int8").unwrap();
        let stm = &catalog()[0];
        // arena fits but an oversized flash image must block deployment
        let f = fit_flash(stm, 16 * 1024, stm.flash_bytes * 2);
        assert!(f.arena_fits && !f.weights_fit && !f.deployable());
        assert_eq!(f.flash_permille, 2000);
        // and the emitted-unit footprint is what deploy_matrix feeds in
        let flash = crate::codegen::flash_footprint(&g).total();
        let ok = fit_flash(stm, 16 * 1024, flash);
        assert!(ok.deployable());
    }
}
