//! Serving metrics: latency distribution, throughput, batch efficiency.
//!
//! `Metrics` is O(1) in the request count: latencies accumulate into a
//! fixed-size log-bucket [`LatencyHistogram`] (exact count/sum/max,
//! bucket-bounded percentiles) instead of an unbounded sample vector, and
//! batch statistics are scalar accumulators. A fleet serving 10^6+
//! requests holds a few hundred counters per model, not a million
//! `Duration`s.

use std::time::Duration;

use crate::obs::hist::LatencyHistogram;

/// Latency percentiles over a completed run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl LatencyStats {
    /// Compute from raw samples (any order), with exact nearest-rank
    /// percentiles: the p-th percentile is the smallest sample such that
    /// at least `p·n` samples are ≤ it (`idx = ceil(p·n) − 1`).
    pub fn from_samples(samples: &[Duration]) -> LatencyStats {
        if samples.is_empty() {
            return LatencyStats {
                count: 0,
                mean_us: 0.0,
                p50_us: 0.0,
                p95_us: 0.0,
                p99_us: 0.0,
                max_us: 0.0,
            };
        }
        let mut us: Vec<f64> = samples.iter().map(|d| d.as_secs_f64() * 1e6).collect();
        us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let rank = ((p * us.len() as f64).ceil() as usize).clamp(1, us.len());
            us[rank - 1]
        };
        LatencyStats {
            count: us.len(),
            mean_us: us.iter().sum::<f64>() / us.len() as f64,
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            max_us: *us.last().unwrap(),
        }
    }
}

/// Accumulated run metrics — constant-size regardless of request count.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    hist: LatencyHistogram,
    batch_count: usize,
    batch_real: usize,
    batch_lanes: usize,
    pub shed: usize,
    /// Sheds caused by the circuit breaker (subset of `shed`).
    pub shed_quarantined: usize,
    /// Requests that settled as failures (panic, exec error, watermark
    /// violation, deadline) with no retry budget left.
    pub failed: usize,
    /// Failed attempts that were handed back for a client retry (not
    /// settled — the retried attempt settles elsewhere).
    pub retries: usize,
    /// Failures whose cause was a blown deadline (subset of
    /// `failed + retries`).
    pub deadline_expired: usize,
    /// Completed requests served by a degraded generation (pinned
    /// previous or safe plan); subset of the completed count.
    pub degraded: usize,
}

impl Metrics {
    pub fn record(&mut self, latency: Duration) {
        self.hist.record(latency.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Count one shed (rejected-at-admission) request. `Metrics` is the
    /// single source of truth for shedding — reports read it from here.
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// Count one breaker-quarantine shed (also counts into `shed`, so
    /// the accounting identity keeps a single shed total).
    pub fn record_shed_quarantined(&mut self) {
        self.shed += 1;
        self.shed_quarantined += 1;
    }

    /// Count one finally-failed request.
    pub fn record_failed(&mut self) {
        self.failed += 1;
    }

    /// Count one failed attempt handed back for retry.
    pub fn record_retry(&mut self) {
        self.retries += 1;
    }

    /// Count one blown deadline (call alongside `record_failed` or
    /// `record_retry`).
    pub fn record_deadline_expired(&mut self) {
        self.deadline_expired += 1;
    }

    /// Count one completed request that a degraded generation served.
    pub fn record_degraded_served(&mut self) {
        self.degraded += 1;
    }

    pub fn record_batch(&mut self, actual: usize, padded: usize) {
        self.batch_count += 1;
        self.batch_real += actual;
        self.batch_lanes += padded;
    }

    /// Number of recorded latency samples (completed requests). Exact.
    pub fn count(&self) -> usize {
        self.hist.count() as usize
    }

    /// The underlying histogram (for Prometheus export).
    pub fn histogram(&self) -> &LatencyHistogram {
        &self.hist
    }

    /// Latency stats from the histogram: `count`/`mean`/`max` exact,
    /// percentiles bounded above by the bucket width (≤ 25%) and clamped
    /// to the exact max, so p50 ≤ p95 ≤ p99 ≤ max always holds.
    pub fn latency(&self) -> LatencyStats {
        LatencyStats {
            count: self.hist.count() as usize,
            mean_us: self.hist.mean_us(),
            p50_us: self.hist.percentile_us(0.50),
            p95_us: self.hist.percentile_us(0.95),
            p99_us: self.hist.percentile_us(0.99),
            max_us: self.hist.max_us() as f64,
        }
    }

    /// Mean requests per executed batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batch_count == 0 {
            return 0.0;
        }
        self.batch_real as f64 / self.batch_count as f64
    }

    /// Fraction of executed lanes that carried real requests.
    pub fn batch_efficiency(&self) -> f64 {
        if self.batch_lanes == 0 {
            return 1.0;
        }
        self.batch_real as f64 / self.batch_lanes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let s = LatencyStats::from_samples(&samples);
        assert_eq!(s.count, 100);
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us && s.p99_us <= s.max_us);
        assert!((s.mean_us - 50.5).abs() < 0.6);
        assert_eq!(s.max_us, 100.0);
    }

    #[test]
    fn nearest_rank_small_sample() {
        // 10 samples 1..=10 µs: nearest-rank gives p50 = 5th sample, and
        // p99 must report the max, not under-report it (the old
        // `((len−1)·p).round()` formula gave p99 = samples[9·0.99 ≈ 9] ✓
        // but p50 = samples[4.5 → 5] = 6 µs and p95 = samples[8.55 → 9]
        // = 10 — rounding half-up from an interpolated index, not a rank)
        let samples: Vec<Duration> = (1..=10).map(Duration::from_micros).collect();
        let s = LatencyStats::from_samples(&samples);
        assert_eq!(s.p50_us, 5.0, "ceil(0.50·10)−1 = index 4 → 5 µs");
        assert_eq!(s.p95_us, 10.0, "ceil(0.95·10)−1 = index 9 → 10 µs");
        assert_eq!(s.p99_us, 10.0, "ceil(0.99·10)−1 = index 9 → 10 µs");
        assert_eq!(s.max_us, 10.0);
    }

    #[test]
    fn empty_is_zero() {
        let s = LatencyStats::from_samples(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.max_us, 0.0);
    }

    #[test]
    fn batch_efficiency() {
        let mut m = Metrics::default();
        m.record_batch(3, 4);
        m.record_batch(4, 4);
        assert!((m.batch_efficiency() - 7.0 / 8.0).abs() < 1e-9);
        assert!((m.mean_batch() - 3.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_metrics_bounded_and_ordered() {
        let mut m = Metrics::default();
        for us in 1..=100_000u64 {
            m.record(Duration::from_micros(us));
        }
        let s = m.latency();
        assert_eq!(s.count, 100_000);
        assert_eq!(m.count(), 100_000);
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us && s.p99_us <= s.max_us);
        assert_eq!(s.max_us, 100_000.0);
        // log-bucket estimate within 25% above the true nearest-rank value
        assert!((50_000.0..=62_500.0).contains(&s.p50_us), "p50 = {}", s.p50_us);
        assert!((99_000.0..=123_750.0).contains(&s.p99_us), "p99 = {}", s.p99_us);
        assert!((s.mean_us - 50_000.5).abs() < 1.0);
    }
}
