//! Admission control: per-model bounded queues behind one fair dispatcher.
//!
//! Each model gets its own bounded queue so one model's burst can never
//! evict another's requests; the shared worker pool drains them
//! **round-robin** — `take` scans from a rotating cursor, so a model
//! with one queued request is served within `N` pops no matter how
//! deep another model's backlog is. Producers choose the overload
//! behaviour per call: [`Admission::try_submit`] sheds (open-loop
//! traffic keeps its arrival clock honest), [`Admission::submit`]
//! blocks (closed-loop backpressure).
//!
//! Two additions for the fault-tolerant fleet:
//!
//! * every pop is stamped with the model's **dispatch sequence number**
//!   ([`Admission::take_seq`]) — assigned under the admission lock, so it
//!   is identical across runs regardless of worker timing; the
//!   deterministic fault injector keys exec faults off it;
//! * a queue can be **stalled** ([`Admission::stall_for`]) — skipped by
//!   the dispatcher for a bounded wall-clock window — so chaos tests can
//!   make a queue back up and prove backpressure/shedding still account
//!   for every request. Stalls are ignored once the admission is closed,
//!   so shutdown always drains.
//!
//! All locking is poison-tolerant ([`crate::util::sync`]): a worker
//! panic must not cascade into every later submit/take.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::sync::{lock, wait, wait_timeout};

struct AdmState<T> {
    queues: Vec<VecDeque<T>>,
    /// Round-robin scan start for the next `take`.
    cursor: usize,
    /// High-water mark per queue (reported by the serve metrics).
    max_depth: Vec<usize>,
    /// Dispatches so far per queue — the next pop's sequence number.
    popped: Vec<u64>,
    /// Queue skipped by the dispatcher until this instant.
    stalled_until: Vec<Option<Instant>>,
    closed: bool,
}

impl<T> AdmState<T> {
    /// True while `i` must be skipped (stall active and not closed).
    fn is_stalled(&self, i: usize) -> bool {
        if self.closed {
            return false;
        }
        match self.stalled_until[i] {
            Some(until) => Instant::now() < until,
            None => false,
        }
    }
}

/// Per-model bounded queues with fair round-robin dispatch.
pub struct Admission<T> {
    inner: Mutex<AdmState<T>>,
    /// Consumers sleep here when every queue is empty (or stalled).
    ready: Condvar,
    /// Blocking producers sleep here when their queue is full.
    space: Condvar,
    capacity: usize,
}

impl<T> Admission<T> {
    /// `models` queues of `capacity` entries each (clamped to ≥ 1).
    pub fn new(models: usize, capacity: usize) -> Admission<T> {
        Admission {
            inner: Mutex::new(AdmState {
                queues: (0..models).map(|_| VecDeque::new()).collect(),
                cursor: 0,
                max_depth: vec![0; models],
                popped: vec![0; models],
                stalled_until: vec![None; models],
                closed: false,
            }),
            ready: Condvar::new(),
            space: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn push(state: &mut AdmState<T>, model: usize, item: T) {
        state.queues[model].push_back(item);
        let d = state.queues[model].len();
        if d > state.max_depth[model] {
            state.max_depth[model] = d;
        }
    }

    /// Non-blocking admit; `Err(item)` when `model`'s queue is full or
    /// the fleet is closed — the caller records the shed.
    pub fn try_submit(&self, model: usize, item: T) -> Result<(), T> {
        let mut g = lock(&self.inner);
        if g.closed || g.queues[model].len() >= self.capacity {
            return Err(item);
        }
        Self::push(&mut g, model, item);
        self.ready.notify_one();
        Ok(())
    }

    /// Blocking admit (backpressure); `Err(item)` only when closed.
    pub fn submit(&self, model: usize, item: T) -> Result<(), T> {
        let mut g = lock(&self.inner);
        while g.queues[model].len() >= self.capacity && !g.closed {
            g = wait(&self.space, g);
        }
        if g.closed {
            return Err(item);
        }
        Self::push(&mut g, model, item);
        self.ready.notify_one();
        Ok(())
    }

    /// Fair pop: scan the queues round-robin from the rotating cursor,
    /// blocking while all are empty. `None` once closed and drained.
    pub fn take(&self) -> Option<(usize, T)> {
        self.take_seq().map(|(m, _, item)| (m, item))
    }

    /// [`Admission::take`] plus the dispatched item's per-model sequence
    /// number (0-based, assigned under the lock — deterministic for a
    /// deterministic submission order).
    pub fn take_seq(&self) -> Option<(usize, u64, T)> {
        let mut g = lock(&self.inner);
        loop {
            let n = g.queues.len();
            let mut stalled_pending = false;
            for k in 0..n {
                let i = (g.cursor + k) % n;
                if !g.queues[i].is_empty() && g.is_stalled(i) {
                    stalled_pending = true;
                    continue;
                }
                if let Some(item) = g.queues[i].pop_front() {
                    g.cursor = (i + 1) % n;
                    let seq = g.popped[i];
                    g.popped[i] += 1;
                    self.space.notify_all();
                    return Some((i, seq, item));
                }
            }
            if g.closed {
                return None;
            }
            // a stalled queue holds work nothing will signal for — poll
            // on a short timeout so its expiry is noticed promptly
            g = if stalled_pending {
                wait_timeout(&self.ready, g, Duration::from_millis(1)).0
            } else {
                wait(&self.ready, g)
            };
        }
    }

    /// Stall `model`'s queue: the dispatcher skips it until `hold`
    /// elapses (or the admission closes). Fault injection only.
    pub fn stall_for(&self, model: usize, hold: Duration) {
        let mut g = lock(&self.inner);
        g.stalled_until[model] = Some(Instant::now() + hold);
        // wake dispatchers so ones sleeping on `ready` re-enter the
        // timeout-polling branch
        self.ready.notify_all();
    }

    /// Close: producers fail from now on, consumers drain then `None`.
    pub fn close(&self) {
        let mut g = lock(&self.inner);
        g.closed = true;
        self.ready.notify_all();
        self.space.notify_all();
    }

    /// Current depth of `model`'s queue.
    pub fn depth(&self, model: usize) -> usize {
        lock(&self.inner).queues[model].len()
    }

    /// High-water queue depth per model since construction.
    pub fn max_depths(&self) -> Vec<usize> {
        lock(&self.inner).max_depth.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn bounded_per_model_and_sheds_independently() {
        let a: Admission<u32> = Admission::new(2, 2);
        assert!(a.try_submit(0, 1).is_ok());
        assert!(a.try_submit(0, 2).is_ok());
        // model 0 full → shed; model 1 unaffected
        assert!(a.try_submit(0, 3).is_err());
        assert!(a.try_submit(1, 9).is_ok());
        assert_eq!(a.depth(0), 2);
        assert_eq!(a.depth(1), 1);
        assert_eq!(a.max_depths(), vec![2, 1]);
    }

    #[test]
    fn round_robin_serves_a_starved_model_within_n_pops() {
        let a: Admission<u32> = Admission::new(2, 1024);
        // model 0 floods; model 1 trickles one request
        for i in 0..100 {
            a.try_submit(0, i).unwrap();
        }
        a.try_submit(1, 999).unwrap();
        let (m1, _) = a.take().unwrap();
        let (m2, v2) = a.take().unwrap();
        // whichever the cursor hits first, the starved model is one of
        // the first two dispatches — fairness under a 100:1 imbalance
        assert!(
            m1 == 1 || (m2 == 1 && v2 == 999),
            "starved model must be served within 2 pops, got models {m1},{m2}"
        );
    }

    #[test]
    fn backpressure_blocks_then_unblocks_on_take() {
        let a: Arc<Admission<u32>> = Arc::new(Admission::new(1, 1));
        a.submit(0, 1).unwrap();
        let a2 = a.clone();
        let h = thread::spawn(move || a2.submit(0, 2));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(a.depth(0), 1, "second submit must be blocked");
        assert_eq!(a.take().unwrap(), (0, 1));
        assert!(h.join().unwrap().is_ok());
        assert_eq!(a.take().unwrap(), (0, 2));
    }

    #[test]
    fn close_wakes_blocked_submitters_and_drains_takers() {
        let a: Arc<Admission<u32>> = Arc::new(Admission::new(1, 1));
        a.submit(0, 1).unwrap();
        let a2 = a.clone();
        let h = thread::spawn(move || a2.submit(0, 2));
        thread::sleep(Duration::from_millis(20));
        a.close();
        // the blocked submitter gets its item back instead of hanging
        assert_eq!(h.join().unwrap(), Err(2));
        // consumers drain what was admitted, then see the close
        assert_eq!(a.take(), Some((0, 1)));
        assert_eq!(a.take(), None);
    }

    #[test]
    fn take_blocks_until_submit() {
        let a: Arc<Admission<u32>> = Arc::new(Admission::new(1, 4));
        let a2 = a.clone();
        let h = thread::spawn(move || a2.take());
        thread::sleep(Duration::from_millis(20));
        a.try_submit(0, 7).unwrap();
        assert_eq!(h.join().unwrap(), Some((0, 7)));
    }

    #[test]
    fn capacity_zero_clamps_to_one() {
        let a: Admission<u32> = Admission::new(1, 0);
        assert_eq!(a.capacity(), 1);
        assert!(a.try_submit(0, 1).is_ok());
        assert!(a.try_submit(0, 2).is_err());
    }

    #[test]
    fn take_seq_numbers_each_model_independently() {
        let a: Admission<u32> = Admission::new(2, 8);
        a.try_submit(0, 10).unwrap();
        a.try_submit(1, 20).unwrap();
        a.try_submit(0, 11).unwrap();
        let mut seqs = vec![Vec::new(), Vec::new()];
        for _ in 0..3 {
            let (m, seq, _) = a.take_seq().unwrap();
            seqs[m].push(seq);
        }
        assert_eq!(seqs[0], vec![0, 1]);
        assert_eq!(seqs[1], vec![0]);
    }

    #[test]
    fn stalled_queue_is_skipped_then_recovers() {
        let a: Admission<u32> = Admission::new(2, 8);
        a.try_submit(0, 1).unwrap();
        a.try_submit(1, 2).unwrap();
        a.stall_for(0, Duration::from_millis(40));
        // while stalled, only model 1 is dispatchable
        assert_eq!(a.take().unwrap(), (1, 2));
        // the stalled item is still there and dispatches after expiry
        let t0 = std::time::Instant::now();
        assert_eq!(a.take().unwrap(), (0, 1));
        assert!(
            t0.elapsed() >= Duration::from_millis(25),
            "dispatch had to wait out the stall"
        );
    }

    #[test]
    fn close_overrides_stall_so_shutdown_drains() {
        let a: Admission<u32> = Admission::new(1, 8);
        a.try_submit(0, 5).unwrap();
        a.stall_for(0, Duration::from_secs(3600));
        a.close();
        assert_eq!(a.take(), Some((0, 5)));
        assert_eq!(a.take(), None);
    }
}
