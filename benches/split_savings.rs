//! Bench: §II-A rewrites as a planning action, per zoo model.
//!
//! For every Table III model (plus the `hourglass` chain witness) this
//! plans three ways with DMO on — the plain searched plan, the
//! searched + single-pair-split plan (`RewriteBudget::pairs`), and the
//! generalised plan (multi-split + depth-3 chains) — and records the
//! peaks plus the recompute/reassembly overhead the winning rewrite
//! pays. Asserts the headline properties: each wider budget is never
//! worse than the narrower one, at least one model's split plan
//! strictly beats its best unsplit layout (the §II-A MobileNet case),
//! and at least one model's chain rewrite strictly beats its best pair
//! split (the hourglass case). Results go to `BENCH_split.json`,
//! uploaded by CI as part of the perf trajectory.

use dmo::models;
use dmo::planner::{Planner, RewriteBudget, DEFAULT_BEAM, DEFAULT_BUDGET};
use dmo::report::fmt_bytes;
use dmo::util::json::{num, obj, s, Json};
use std::time::Instant;

const MAX_PARTS: usize = 4;

fn main() {
    println!("=== §II-A rewrites: searched pair / multi+chain vs no-rewrite (DMO on) ===\n");
    println!(
        "{:32} {:>10} {:>10} {:>10} {:>8} {:>12} {:>12} {:>9}",
        "model", "none", "pair", "general", "Δ", "recomputed", "reassembled", "wall"
    );

    let general_budget = RewriteBudget {
        max_parts: MAX_PARTS,
        max_splits: 2,
        max_chain_depth: 3,
    };

    let mut names = models::table3_names();
    names.push("hourglass");
    let mut entries: Vec<Json> = Vec::new();
    let mut wins = 0usize;
    let mut chain_wins = 0usize;
    for name in names {
        let g = models::build(name).unwrap();
        let unsplit = Planner::for_graph(&g)
            .dmo(true)
            .search(DEFAULT_BEAM, DEFAULT_BUDGET)
            .plan()
            .unwrap();
        let pair = Planner::for_graph(&g)
            .dmo(true)
            .search(DEFAULT_BEAM, DEFAULT_BUDGET)
            .rewrites(RewriteBudget::pairs(MAX_PARTS))
            .plan()
            .unwrap();
        let t0 = Instant::now();
        let general = Planner::for_graph(&g)
            .dmo(true)
            .search(DEFAULT_BEAM, DEFAULT_BUDGET)
            .rewrites(general_budget)
            .plan()
            .unwrap();
        let wall = t0.elapsed();
        assert!(
            pair.peak() <= unsplit.peak(),
            "{name}: pair-split session {} worse than unsplit {}",
            pair.peak(),
            unsplit.peak()
        );
        assert!(
            general.peak() <= pair.peak(),
            "{name}: generalised session {} worse than single-pair best {}",
            general.peak(),
            pair.peak()
        );

        // overhead + shape of the winning generalised rewrite, if one won
        let (recomputed, assembled, spec, has_chain, n_splits) = match &general.rewrite {
            Some(rw) => {
                wins += 1;
                let mut recomputed = 0usize;
                let mut assembled = 0usize;
                for sp in &rw.specs {
                    let ops = sp.op_indices();
                    let rep = dmo::planner::split::analyse_chain(
                        &g,
                        &ops.iter().map(|&i| dmo::ir::OpId(i)).collect::<Vec<_>>(),
                        sp.parts(),
                    )
                    .unwrap();
                    recomputed += rep.recomputed_elems;
                    assembled += rep.assembled_elems;
                }
                let described = rw
                    .specs
                    .iter()
                    .map(|sp| sp.describe())
                    .collect::<Vec<_>>()
                    .join(" + ");
                let has_chain = rw.specs.iter().any(|sp| sp.depth() >= 3);
                (recomputed, assembled, described, has_chain, rw.specs.len())
            }
            None => (0, 0, "-".to_string(), false, 0),
        };
        if has_chain && general.peak() < pair.peak() {
            chain_wins += 1;
        }
        let delta = if general.peak() < unsplit.peak() {
            format!(
                "-{:.1}%",
                100.0 * (unsplit.peak() - general.peak()) as f64 / unsplit.peak() as f64
            )
        } else {
            "=".to_string()
        };
        println!(
            "{:32} {:>10} {:>10} {:>10} {:>8} {:>12} {:>12} {:>8.2}s",
            name,
            fmt_bytes(unsplit.peak()),
            fmt_bytes(pair.peak()),
            fmt_bytes(general.peak()),
            delta,
            recomputed,
            assembled,
            wall.as_secs_f64()
        );

        entries.push(obj(vec![
            ("model", s(name)),
            ("no_split_peak_bytes", num(unsplit.peak())),
            ("split_peak_bytes", num(pair.peak())),
            ("general_peak_bytes", num(general.peak())),
            ("split_won", Json::Bool(general.rewrite.is_some())),
            ("chain_beat_pair", Json::Bool(has_chain && general.peak() < pair.peak())),
            ("rewrite_count", num(n_splits)),
            ("split_spec", s(&spec)),
            ("recomputed_elems", num(recomputed)),
            ("assembled_elems", num(assembled)),
            ("max_parts", num(MAX_PARTS)),
            ("max_splits", num(general_budget.max_splits)),
            ("max_chain_depth", num(general_budget.max_chain_depth)),
            ("split_plan_wall_ms", num(wall.as_millis() as usize)),
        ]));
    }

    assert!(
        wins >= 1,
        "at least one zoo model's searched+rewrite plan must beat its best unsplit order"
    );
    assert!(
        chain_wins >= 1,
        "at least one zoo model's chain rewrite must beat its best pair split"
    );

    let doc = obj(vec![("bench", s("split_savings")), ("models", Json::Arr(entries))]);
    let path = "BENCH_split.json";
    std::fs::write(path, doc.to_string()).unwrap();
    println!("\nwrote {path} ({wins} models improved by rewriting, {chain_wins} by chains over pairs)");
}
