//! Small self-contained utilities.
//!
//! The build environment vendors only the `xla` crate's dependency
//! closure, so `rand`, `serde`/`serde_json` and `criterion` are not
//! available; these modules provide the minimal deterministic
//! replacements the library needs (documented in DESIGN.md).

pub mod args;
pub mod bench;
pub mod fnv;
pub mod json;
pub mod par;
pub mod rng;
pub mod sync;
