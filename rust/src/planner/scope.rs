//! Tensor liveness (scope) analysis.
//!
//! A tensor's *scope* is the closed interval of execution-order positions
//! during which its buffer must hold valid data — from first materialised
//! (graph input: before op 0; intermediate: its producer's slot) to last
//! consumed (graph output: after the final op). This is exactly the
//! y-extent of the buffer rectangles in Figs 1 and 9.

use super::order::ExecOrder;
use crate::ir::graph::{Graph, OpId, TensorId, TensorKind};

/// Closed interval of order positions `[start, end]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scope {
    pub start: usize,
    pub end: usize,
}

impl Scope {
    /// Two scopes conflict if any position is in both.
    pub fn overlaps(&self, other: &Scope) -> bool {
        self.start <= other.end && other.start <= self.end
    }
}

/// Per-tensor scopes under a given execution order.
#[derive(Debug, Clone)]
pub struct Scopes {
    /// Indexed by `TensorId`. `None` for tensors never used under this
    /// order (possible after graph transforms).
    pub scopes: Vec<Option<Scope>>,
    /// position of each op in the order, indexed by `OpId`
    pub pos: Vec<usize>,
}

impl Scopes {
    pub fn get(&self, t: TensorId) -> Option<Scope> {
        self.scopes[t.0]
    }

    /// Position of op in the execution order.
    pub fn op_pos(&self, op: OpId) -> usize {
        self.pos[op.0]
    }

    /// Is `op` the last use of tensor `t`?
    pub fn dies_at(&self, t: TensorId, op: OpId) -> bool {
        self.scopes[t.0]
            .map(|s| s.end == self.pos[op.0])
            .unwrap_or(false)
    }
}

/// Compute scopes for `graph` under `order`.
pub fn analyse(graph: &Graph, order: &ExecOrder) -> Scopes {
    let n_ops = graph.ops.len();
    let mut pos = vec![usize::MAX; n_ops];
    for (p, &op) in order.0.iter().enumerate() {
        pos[op.0] = p;
    }
    let mut scopes: Vec<Option<Scope>> = vec![None; graph.tensors.len()];
    for (tid, info) in graph.tensors.iter().enumerate() {
        let t = TensorId(tid);
        let producer = graph.producer(t);
        let consumers = graph.consumers(t);
        let start = match (&info.kind, producer) {
            (TensorKind::Input, _) => 0,
            (_, Some(p)) => pos[p.0],
            // unused non-input tensor with no producer: skip
            (_, None) => {
                continue;
            }
        };
        let mut end = match info.kind {
            // outputs must survive past the last op
            TensorKind::Output => n_ops, // one past the last slot
            _ => start,
        };
        for c in &consumers {
            end = end.max(pos[c.0]);
        }
        if info.kind != TensorKind::Output && consumers.is_empty() && producer.is_none() {
            continue;
        }
        scopes[tid] = Some(Scope { start, end });
    }
    Scopes { scopes, pos }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Padding};
    use crate::ir::{DType, GraphBuilder, Shape};
    use crate::planner::order::{serialise, Strategy};

    #[test]
    fn sequential_scopes() {
        let mut b = GraphBuilder::new("seq", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 3));
        let c = b.conv2d(x, 8, (3, 3), (2, 2), Padding::Same, Activation::Relu);
        let d = b.dwconv2d(c, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let g = b.finish(&[d]);
        let order = serialise(&g, Strategy::Eager);
        let s = analyse(&g, &order);
        // input: live [0, 0] (consumed by op 0)
        assert_eq!(s.get(x), Some(Scope { start: 0, end: 0 }));
        // conv out: produced op 0, consumed op 1
        assert_eq!(s.get(c), Some(Scope { start: 0, end: 1 }));
        // output: produced op 1, survives to the end (pos 2 = n_ops)
        assert_eq!(s.get(d), Some(Scope { start: 1, end: 2 }));
        assert!(s.dies_at(x, crate::ir::graph::OpId(0)));
        assert!(!s.dies_at(c, crate::ir::graph::OpId(0)));
    }

    #[test]
    fn residual_keeps_tensor_alive() {
        // x -> a; a -> p; (a, p) -> add : a must live until the add
        let mut b = GraphBuilder::new("res", DType::F32);
        let x = b.input(Shape::hwc(4, 4, 2));
        let a = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let p = b.conv2d(a, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let s = b.add(a, p);
        let g = b.finish(&[s]);
        let order = serialise(&g, Strategy::Eager);
        let sc = analyse(&g, &order);
        let a_scope = sc.get(a).unwrap();
        // a produced at pos 0, last used by add at pos 2
        assert_eq!(a_scope, Scope { start: 0, end: 2 });
        // therefore a does NOT die at the conv that reads it (pos 1)
        assert!(!sc.dies_at(a, crate::ir::graph::OpId(1)));
    }

    #[test]
    fn overlap_relation() {
        let a = Scope { start: 0, end: 2 };
        let b = Scope { start: 2, end: 5 };
        let c = Scope { start: 3, end: 4 };
        assert!(a.overlaps(&b));
        assert!(!a.overlaps(&c));
        assert!(b.overlaps(&c));
    }
}
