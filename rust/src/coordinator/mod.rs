//! L3 serving coordinator.
//!
//! The paper's system contribution is the memory *planner*; serving it on
//! a real runtime needs the surrounding coordination: a bounded request
//! queue with backpressure, a dynamic batcher that groups requests into
//! the AOT-compiled batch variants, a worker owning the PJRT engine, and
//! latency/throughput metrics. Rust owns the event loop and process
//! topology; Python exists only in the compile path.
//!
//! Threading: `std::thread` + `Mutex`/`Condvar` (the vendored dependency
//! set has no tokio; the queue provides the same bounded-channel
//! semantics — see DESIGN.md §Substitutions).

pub mod batcher;
pub mod cli;
pub mod metrics;
pub mod queue;
pub mod server;
pub mod workload;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::{LatencyStats, Metrics};
pub use queue::BoundedQueue;
pub use server::{serve, Reply, Request, ServeConfig, ServeReport};
pub use workload::Workload;
