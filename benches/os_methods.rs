//! Bench/ablation: the three `O_s` engines (§III-B/C/D).
//!
//! 1. Cost scaling with op size: analytic is O(1), algorithmic walks the
//!    step stream, bottom-up executes the op with tracing.
//! 2. Paper's Algorithm-2 array form vs the streaming rewrite
//!    (equal results, different memory behaviour).
//! 3. Planning ablation: Table-III peaks when the planner consumes
//!    analytic vs exact `O_s` (the paper claims <2 % penalty; our
//!    allocator shows where the bound's slack breaks a nesting —
//!    EXPERIMENTS.md §Deviations).

use dmo::ir::op::{Activation, DepthwiseParams, Padding};
use dmo::ir::{DType, OpKind, Shape};
use dmo::models;
use dmo::overlap::algorithmic::{os_paper_arrays, os_streaming};
use dmo::overlap::{compute_os, Method};
use dmo::planner::Planner;
use dmo::util::bench::{report, time};

fn dw(stride: usize) -> OpKind {
    OpKind::DepthwiseConv2D(DepthwiseParams {
        kernel: (3, 3),
        stride: (stride, stride),
        dilation: (1, 1),
        padding: Padding::Same,
        depth_multiplier: 1,
        act: Activation::None,
    })
}

fn main() {
    println!("=== O_s engine cost vs op size (dwconv 3x3 s2) ===\n");
    for (hw, c) in [(14usize, 32usize), (28, 64), (56, 96), (112, 96)] {
        let x = Shape::hwc(hw, hw, c);
        let k = dw(2);
        let out = dmo::ops::infer_output(&k, &[&x]).unwrap();
        let steps = dmo::ops::access::step_count(&k, &[&x], &out);
        println!("-- {hw}x{hw}x{c} ({steps} steps)");
        for (m, iters) in [(Method::Analytic, 2000), (Method::Algorithmic, 20), (Method::BottomUp, 3)] {
            let meas = time(&format!("  {}", m.name()), iters, || {
                std::hint::black_box(compute_os(m, &k, &[&x], &out, DType::F32));
            });
            report(&meas);
        }
    }

    println!("\n=== Algorithm 2 (arrays + reverse pass) vs streaming ===\n");
    let x = Shape::hwc(56, 56, 96);
    let k = dw(2);
    let out = dmo::ops::infer_output(&k, &[&x]).unwrap();
    let a = os_paper_arrays(&k, &[&x], &out, DType::F32);
    let b = os_streaming(&k, &[&x], &out, DType::F32);
    assert_eq!(a, b, "both forms must agree");
    report(&time("paper arrays (Algorithm 2)", 20, || {
        std::hint::black_box(os_paper_arrays(&k, &[&x], &out, DType::F32));
    }));
    report(&time("streaming (O(1) memory)", 20, || {
        std::hint::black_box(os_streaming(&k, &[&x], &out, DType::F32));
    }));

    println!("\n=== Planning ablation: analytic vs exact O_s ===\n");
    println!(
        "{:30} {:>10} {:>12} {:>12}",
        "model", "baseline", "DMO(exact)", "DMO(analytic)"
    );
    for name in [
        "mobilenet_v1_1.0_224",
        "mobilenet_v1_0.25_128_int8",
        "mobilenet_v2_1.0_224",
        "inception_resnet_v2",
    ] {
        let g = models::build(name).unwrap();
        let base = Planner::for_graph(&g).plan().unwrap();
        let exact = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let analytic = Planner::for_graph(&g)
            .dmo(true)
            .method(Method::Analytic)
            .plan()
            .unwrap();
        println!(
            "{:30} {:>9}K {:>11}K {:>11}K",
            name,
            base.peak() / 1024,
            exact.peak() / 1024,
            analytic.peak() / 1024
        );
    }
    println!("\n(paper plans with the analytic bound; our allocator needs the");
    println!(" exact value to reproduce the MobileNet nestings — §Deviations)");
}
