//! Content-addressed memoisation of [`compute_os`](super::compute_os).
//!
//! `O_s` depends only on an op's *geometry* — its kind (with all static
//! parameters), input/output shapes, element type — and on the engine
//! used to compute it. It does **not** depend on which graph the op sits
//! in, on tensor identities, or on the execution order. Zoo models
//! repeat the same block shapes dozens of times (every ResNet stage,
//! every MobileNet depthwise/pointwise pair), and a planning sweep
//! re-derives the very same table per session, so memoising on the
//! canonical [`OpSignature`] collapses all of that to one analysis per
//! distinct signature.
//!
//! The pay-off is largest for [`Method::BottomUp`], which *executes*
//! the kernel on dummy data with an event probe attached (§III-B, the
//! paper's Valgrind substitute) — milliseconds to seconds per op —
//! but even the exact algorithmic engine walks `O(Steps)` per call.
//!
//! [`OsCache`] is interior-mutable and thread-safe: wrap it in an
//! [`Arc`] and share one instance across
//! [`Planner`](crate::planner::Planner) sessions, `dmo serve`
//! processes' planning step, and the `dmo orders` report
//! ([`OsCache::process_shared`] hands out the process-wide instance).
//! Parallel sweep workers hit the same cache; the value is computed
//! outside the lock so a slow bottom-up trace never serialises other
//! lookups. Hit/miss counters make the savings observable
//! ([`OsCache::stats`]), not just benchmarkable
//! (`benches/planner_scale.rs`, EXPERIMENTS.md §Perf).

use super::{compute_os, Method, SafeOverlap};
use crate::ir::op::OpKind;
use crate::ir::shape::Shape;
use crate::ir::DType;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Canonical identity of one `compute_os` call: everything the result
/// depends on, and nothing else. Two ops anywhere in any graph with
/// equal signatures have byte-identical `O_s` vectors.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct OpSignature {
    /// Op kind including all static parameters (kernel, stride,
    /// dilation, padding, fused activation, …).
    pub kind: OpKind,
    /// Activation input shapes, in input order.
    pub in_shapes: Vec<Shape>,
    /// Output shape.
    pub out_shape: Shape,
    /// Element type (`O_s` is reported in bytes — multiples of `T_s`).
    pub dtype: DType,
    /// Engine the overlap was computed with; the three engines may
    /// legitimately disagree (the analytic bound under-estimates by
    /// design, §III-E), so they never share entries.
    pub method: Method,
}

impl OpSignature {
    /// Build the signature for one `compute_os` call.
    pub fn of(
        method: Method,
        kind: &OpKind,
        in_shapes: &[&Shape],
        out_shape: &Shape,
        dtype: DType,
    ) -> OpSignature {
        OpSignature {
            kind: kind.clone(),
            in_shapes: in_shapes.iter().map(|s| (*s).clone()).collect(),
            out_shape: out_shape.clone(),
            dtype,
            method,
        }
    }
}

/// Lookup counters of an [`OsCache`] — cheap, lock-free reads.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: usize,
    /// Lookups that had to run the engine (one per distinct signature).
    pub misses: usize,
}

impl CacheStats {
    /// Total lookups served.
    pub fn lookups(&self) -> usize {
        self.hits + self.misses
    }

    /// Fraction of lookups answered without running an engine.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups() as f64
    }
}

/// Thread-safe, content-addressed `compute_os` memo table.
///
/// ```
/// use dmo::ir::op::{OpKind, UnaryKind};
/// use dmo::ir::{DType, Shape};
/// use dmo::overlap::{compute_os, Method, OsCache};
///
/// let cache = OsCache::new();
/// let shape = Shape::hwc(8, 8, 4);
/// let kind = OpKind::Unary(UnaryKind::Relu);
/// let direct = compute_os(Method::Algorithmic, &kind, &[&shape], &shape, DType::F32);
/// let cached = cache.get_or_compute(Method::Algorithmic, &kind, &[&shape], &shape, DType::F32);
/// assert_eq!(direct, cached);
/// let warm = cache.get_or_compute(Method::Algorithmic, &kind, &[&shape], &shape, DType::F32);
/// assert_eq!(direct, warm);
/// assert_eq!((cache.stats().hits, cache.stats().misses), (1, 1));
/// ```
#[derive(Debug, Default)]
pub struct OsCache {
    map: Mutex<HashMap<OpSignature, SafeOverlap>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl OsCache {
    /// An empty cache.
    pub fn new() -> OsCache {
        OsCache::default()
    }

    /// The process-wide shared cache. `dmo orders` rows, `dmo serve`
    /// startup planning and any other in-process consumer that wants
    /// cross-session reuse without threading an [`Arc`] around all use
    /// this one instance.
    pub fn process_shared() -> Arc<OsCache> {
        static SHARED: OnceLock<Arc<OsCache>> = OnceLock::new();
        SHARED.get_or_init(|| Arc::new(OsCache::new())).clone()
    }

    /// `compute_os`, memoised: return the cached overlap for this
    /// signature or run `method`'s engine exactly once and remember the
    /// result.
    ///
    /// The engine runs *outside* the map lock — a multi-second
    /// bottom-up trace must not serialise unrelated lookups from
    /// parallel sweep workers. Two threads racing on the same cold
    /// signature may both compute it (deterministically equal values;
    /// the first insert wins), which trades a rare duplicated analysis
    /// for never blocking readers.
    pub fn get_or_compute(
        &self,
        method: Method,
        kind: &OpKind,
        in_shapes: &[&Shape],
        out_shape: &Shape,
        dtype: DType,
    ) -> SafeOverlap {
        let sig = OpSignature::of(method, kind, in_shapes, out_shape, dtype);
        if let Some(hit) = self.lock().get(&sig).cloned() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit;
        }
        let value = compute_os(method, kind, in_shapes, out_shape, dtype);
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.lock().entry(sig).or_insert_with(|| value.clone());
        value
    }

    /// Current hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct signatures held.
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }

    /// Drop every entry and reset the counters.
    pub fn clear(&self) {
        self.lock().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<OpSignature, SafeOverlap>> {
        // a panic while holding the lock can only happen inside std
        // HashMap ops; treat poisoning as unrecoverable
        self.map.lock().expect("O_s cache lock poisoned")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Conv2DParams, Padding, UnaryKind};

    fn conv(kernel: (usize, usize), stride: (usize, usize)) -> OpKind {
        OpKind::Conv2D(Conv2DParams {
            kernel,
            stride,
            dilation: (1, 1),
            padding: Padding::Same,
            out_channels: 4,
            act: Activation::None,
        })
    }

    #[test]
    fn distinct_signatures_do_not_alias() {
        let cache = OsCache::new();
        let x = Shape::hwc(8, 8, 3);
        let out = crate::ops::infer_output(&conv((3, 3), (1, 1)), &[&x]).unwrap();
        let a = cache.get_or_compute(Method::Algorithmic, &conv((3, 3), (1, 1)), &[&x], &out, DType::F32);
        // same geometry, different stride ⇒ different signature + value
        let out2 = crate::ops::infer_output(&conv((3, 3), (2, 2)), &[&x]).unwrap();
        let b = cache.get_or_compute(Method::Algorithmic, &conv((3, 3), (2, 2)), &[&x], &out2, DType::F32);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().misses, 2);
        assert_eq!(
            a,
            compute_os(Method::Algorithmic, &conv((3, 3), (1, 1)), &[&x], &out, DType::F32)
        );
        assert_eq!(
            b,
            compute_os(Method::Algorithmic, &conv((3, 3), (2, 2)), &[&x], &out2, DType::F32)
        );
    }

    #[test]
    fn methods_never_share_entries() {
        let cache = OsCache::new();
        let x = Shape::hwc(6, 6, 2);
        let k = OpKind::Unary(UnaryKind::Relu);
        let exact = cache.get_or_compute(Method::Algorithmic, &k, &[&x], &x, DType::F32);
        let analytic = cache.get_or_compute(Method::Analytic, &k, &[&x], &x, DType::F32);
        assert_eq!(cache.stats().misses, 2, "same geometry, two engines, two entries");
        assert_eq!(exact, compute_os(Method::Algorithmic, &k, &[&x], &x, DType::F32));
        assert_eq!(analytic, compute_os(Method::Analytic, &k, &[&x], &x, DType::F32));
    }

    #[test]
    fn concurrent_lookups_agree_and_count() {
        let cache = Arc::new(OsCache::new());
        let x = Shape::hwc(10, 10, 3);
        let kind = conv((3, 3), (1, 1));
        let out = crate::ops::infer_output(&kind, &[&x]).unwrap();
        let expect = compute_os(Method::Algorithmic, &kind, &[&x], &out, DType::F32);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let cache = &cache;
                let (kind, x, out, expect) = (&kind, &x, &out, &expect);
                scope.spawn(move || {
                    for _ in 0..8 {
                        let got =
                            cache.get_or_compute(Method::Algorithmic, kind, &[x], out, DType::F32);
                        assert_eq!(&got, expect);
                    }
                });
            }
        });
        let st = cache.stats();
        assert_eq!(st.lookups(), 32);
        assert_eq!(cache.len(), 1, "one signature no matter how many racers");
        assert!(st.hits >= 28, "at most one duplicated compute per racer: {st:?}");
    }

    #[test]
    fn clear_resets_contents_and_counters() {
        let cache = OsCache::new();
        let x = Shape::hwc(4, 4, 2);
        let k = OpKind::Unary(UnaryKind::Relu6);
        cache.get_or_compute(Method::Analytic, &k, &[&x], &x, DType::I8);
        cache.get_or_compute(Method::Analytic, &k, &[&x], &x, DType::I8);
        assert!(!cache.is_empty());
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.stats(), CacheStats::default());
    }

    #[test]
    fn hit_rate_is_well_defined() {
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
        let s = CacheStats { hits: 3, misses: 1 };
        assert_eq!(s.lookups(), 4);
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
    }
}
