//! Integration tests for the memoised/parallel planning pipeline:
//!
//! 1. **Determinism under parallelism** — across the whole Table III
//!    zoo, `.jobs(1)` and `.jobs(4)` produce byte-identical serialized
//!    [`PlanArtifact`]s, for the default eager/lazy sweep *and* for
//!    `Strategy::Search` (the acceptance property of the parallel
//!    planner: worker count is a wall-clock knob, never a result knob).
//! 2. **Cache transparency** — cached `compute_os` results equal
//!    uncached ones across randomized op signatures (no collision or
//!    aliasing in the content-addressed key), for every engine,
//!    including the kernel-executing bottom-up method.
//! 3. **Table equivalence** — `OsTable::build_cached` through a shared,
//!    pre-warmed cache equals a plain `OsTable::build`.

use dmo::ir::graph::Graph;
use dmo::ir::op::{
    Activation, BinaryKind, Conv2DParams, DepthwiseParams, OpKind, Padding, PoolKind, PoolParams,
    UnaryKind,
};
use dmo::ir::{DType, Shape};
use dmo::models;
use dmo::overlap::{compute_os, Method, OsCache};
use dmo::planner::{Heuristic, OsTable, PlanArtifact, Planner, Strategy};
use dmo::util::rng::Rng;
use std::sync::Arc;

/// Analytic `O_s` + a two-heuristic allocator axis: the same
/// configuration `rust/tests/order_search.rs` uses to keep the 11-model
/// debug-mode sweeps fast, applied consistently to both jobs values.
const TEST_HEURISTICS: [Heuristic; 2] = [Heuristic::SizeDesc, Heuristic::PairFrontier];

fn sweep_artifact(g: &Graph, jobs: usize) -> String {
    let plan = Planner::for_graph(g)
        .dmo(true)
        .method(Method::Analytic)
        .heuristics(&TEST_HEURISTICS)
        .jobs(jobs)
        .plan()
        .unwrap();
    PlanArtifact::from_plan(g, &plan).to_json().to_string()
}

fn search_artifact(g: &Graph, jobs: usize) -> String {
    let plan = Planner::for_graph(g)
        .dmo(true)
        .method(Method::Analytic)
        .heuristics(&TEST_HEURISTICS)
        .strategies(&[Strategy::Search {
            beam: 4,
            budget: 2_000,
        }])
        .jobs(jobs)
        .plan()
        .unwrap();
    PlanArtifact::from_plan(g, &plan).to_json().to_string()
}

#[test]
fn zoo_sweep_artifacts_identical_across_job_counts() {
    for name in models::table3_names() {
        let g = models::build(name).unwrap();
        let serial = sweep_artifact(&g, 1);
        let parallel = sweep_artifact(&g, 4);
        assert_eq!(serial, parallel, "{name}: sweep artifact differs between jobs 1 and 4");
    }
}

#[test]
fn zoo_search_artifacts_identical_across_job_counts() {
    for name in models::table3_names() {
        let g = models::build(name).unwrap();
        let serial = search_artifact(&g, 1);
        let parallel = search_artifact(&g, 4);
        assert_eq!(serial, parallel, "{name}: search artifact differs between jobs 1 and 4");
    }
}

/// Random op signature over the kinds all three engines support, with
/// shapes small enough that the bottom-up engine (which executes the
/// kernel) stays cheap in debug mode.
fn random_signature(rng: &mut Rng) -> (OpKind, Vec<Shape>) {
    let h = rng.range(3, 9);
    let w = rng.range(3, 9);
    let c = rng.range(1, 4);
    let x = Shape::hwc(h, w, c);
    let stride = [1usize, 2][rng.below(2)];
    let padding = if rng.chance(0.5) { Padding::Same } else { Padding::Valid };
    match rng.below(5) {
        0 => (
            OpKind::Conv2D(Conv2DParams {
                kernel: (rng.range(1, 3), rng.range(1, 3)),
                stride: (stride, stride),
                dilation: (1, 1),
                padding,
                out_channels: rng.range(1, 6),
                act: [Activation::None, Activation::Relu, Activation::Relu6][rng.below(3)],
            }),
            vec![x],
        ),
        1 => (
            OpKind::DepthwiseConv2D(DepthwiseParams {
                kernel: (3, 3),
                stride: (stride, stride),
                dilation: (1, 1),
                padding: Padding::Same,
                depth_multiplier: rng.range(1, 2),
                act: Activation::None,
            }),
            vec![x],
        ),
        2 => (
            OpKind::Pool(PoolParams {
                kind: if rng.chance(0.5) { PoolKind::Max } else { PoolKind::Avg },
                kernel: (2, 2),
                stride: (2, 2),
                padding: Padding::Valid,
            }),
            vec![x],
        ),
        3 => (
            OpKind::Unary([UnaryKind::Relu, UnaryKind::Relu6, UnaryKind::Copy][rng.below(3)]),
            vec![x],
        ),
        _ => (
            OpKind::Binary(if rng.chance(0.5) { BinaryKind::Add } else { BinaryKind::Mul }),
            vec![x.clone(), x],
        ),
    }
}

#[test]
fn cached_os_equals_uncached_across_random_signatures() {
    let mut rng = Rng::new(0x05CA_C4E0);
    let cache = OsCache::new();
    let mut distinct = 0usize;
    for case in 0..60 {
        let (kind, in_shapes) = random_signature(&mut rng);
        let refs: Vec<&Shape> = in_shapes.iter().collect();
        let out = dmo::ops::infer_output(&kind, &refs).unwrap();
        let dtype = if rng.chance(0.5) { DType::F32 } else { DType::I8 };
        for method in [Method::Algorithmic, Method::Analytic, Method::BottomUp] {
            let before = cache.stats();
            let direct = compute_os(method, &kind, &refs, &out, dtype);
            let cached = cache.get_or_compute(method, &kind, &refs, &out, dtype);
            assert_eq!(direct, cached, "case {case} {method:?}: cold lookup diverged");
            let warm = cache.get_or_compute(method, &kind, &refs, &out, dtype);
            assert_eq!(direct, warm, "case {case} {method:?}: warm lookup diverged");
            let after = cache.stats();
            // the signature may repeat across cases; whichever way, the
            // second lookup of this pair is always a hit
            assert!(after.hits >= before.hits + 1, "case {case} {method:?}: no hit recorded");
            if after.misses > before.misses {
                distinct += 1;
                assert_eq!(after.misses, before.misses + 1);
            }
        }
    }
    assert_eq!(cache.len(), distinct, "one entry per distinct signature, no aliasing");
    assert!(distinct >= 30, "the generator must produce real variety, got {distinct}");
}

#[test]
fn cached_table_build_equals_uncached_for_zoo_models() {
    let cache = Arc::new(OsCache::new());
    for name in ["tiny", "mobilenet_v1_0.25_128_int8"] {
        let g = models::build(name).unwrap();
        let plain = OsTable::build(&g, Method::Algorithmic);
        let cold = OsTable::build_cached(&g, Method::Algorithmic, &cache);
        let warm = OsTable::build_cached(&g, Method::Algorithmic, &cache);
        assert_eq!(plain.per_op, cold.per_op, "{name}: cached build diverged");
        assert_eq!(plain.per_op, warm.per_op, "{name}: warm build diverged");
        assert_eq!(plain.method, warm.method);
    }
    let st = cache.stats();
    assert!(st.hits > 0, "second builds must hit: {st:?}");
    assert!(st.misses > 0);
    assert_eq!(cache.len(), st.misses);
}

/// A plan produced through a shared cache and parallel workers is the
/// very same artifact as the plain serial one — the end-to-end
/// composition of both tentpole features.
#[test]
fn cache_plus_parallelism_never_changes_the_artifact() {
    let g = models::build("mobilenet_v1_0.25_128_int8").unwrap();
    let plain = Planner::for_graph(&g).dmo(true).jobs(1).plan().unwrap();
    let cache = Arc::new(OsCache::new());
    // warm the cache with a throwaway session first
    let _ = Planner::for_graph(&g).dmo(true).os_cache(cache.clone()).plan().unwrap();
    let tuned = Planner::for_graph(&g)
        .dmo(true)
        .jobs(4)
        .os_cache(cache.clone())
        .plan()
        .unwrap();
    assert_eq!(
        PlanArtifact::from_plan(&g, &plain).to_json().to_string(),
        PlanArtifact::from_plan(&g, &tuned).to_json().to_string(),
        "shared cache + jobs must be invisible in the artifact"
    );
    assert!(cache.stats().hits > 0);
}
