//! Bench: paper Table III — memory saving using diagonal optimisation on
//! all eleven catalog models, side by side with the paper's numbers,
//! plus end-to-end planning cost per model.

use dmo::models;
use dmo::planner::Planner;
use dmo::report::paper_table3;
use std::time::Instant;

fn main() {
    println!("=== Table III: memory saving using diagonal optimisation ===\n");
    println!(
        "{:30} {:>9} {:>9} {:>8} | {:>9} {:>9} {:>8} | {:>9}",
        "model", "orig KB", "DMO KB", "saving", "paper", "paper", "paper", "plan time"
    );
    let mut total_orig = 0usize;
    let mut total_opt = 0usize;
    for (name, p_orig, p_opt) in paper_table3() {
        let g = models::build(name).unwrap();
        let t0 = Instant::now();
        let base = Planner::for_graph(&g).plan().unwrap();
        let opt = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let dt = t0.elapsed();
        let orig = base.peak();
        let o = opt.peak().min(orig);
        let saving = 100.0 * (orig - o) as f64 / orig as f64;
        let p_saving = if p_orig == p_opt {
            "None".to_string()
        } else {
            format!("{:.1}%", 100.0 * (p_orig - p_opt) as f64 / p_orig as f64)
        };
        println!(
            "{:30} {:>9} {:>9} {:>7.1}% | {:>9} {:>9} {:>8} | {:>8.2}s",
            name,
            orig / 1024,
            o / 1024,
            saving,
            p_orig,
            p_opt,
            p_saving,
            dt.as_secs_f64()
        );
        total_orig += orig;
        total_opt += o;
    }
    println!(
        "\ntotal: {} KB → {} KB ({:.1}% overall saving across the catalog)",
        total_orig / 1024,
        total_opt / 1024,
        100.0 * (total_orig - total_opt) as f64 / total_orig as f64
    );
    println!("(MobileNet rows should match the paper exactly; the complex");
    println!(" nets match in shape — see EXPERIMENTS.md §Deviations.)");
}
