//! Bench: figure regeneration (Figs 1, 2, 3, 6, 8, 9) — correctness of
//! the instrumentation plus its cost (event rate of the tracing arena,
//! raster throughput).

use dmo::ir::op::{Activation, Conv2DParams, DepthwiseParams, Padding, UnaryKind};
use dmo::ir::{DType, OpKind, Shape};
use dmo::models;
use dmo::planner::Planner;
use dmo::trace::render::{alloc_map_csv, fig6_csv, model_raster, op_raster};
use dmo::trace::threads::sharded_conv_events;
use dmo::util::bench::{report, time};

fn main() {
    println!("=== Fig 3: per-op trace generation ===\n");
    let shape = Shape::hwc(24, 24, 4);
    let ops: Vec<(&str, OpKind, Shape)> = vec![
        ("relu", OpKind::Unary(UnaryKind::Relu), shape.clone()),
        ("matmul", OpKind::MatMulAccum { out_features: 64 }, Shape::new(&[1, 96])),
        (
            "dwconv",
            OpKind::DepthwiseConv2D(DepthwiseParams {
                kernel: (3, 3),
                stride: (1, 1),
                dilation: (1, 1),
                padding: Padding::Same,
                depth_multiplier: 1,
                act: Activation::None,
            }),
            shape.clone(),
        ),
        (
            "conv",
            OpKind::Conv2D(Conv2DParams {
                kernel: (3, 3),
                stride: (1, 1),
                dilation: (1, 1),
                padding: Padding::Same,
                out_channels: 8,
                act: Activation::None,
            }),
            shape,
        ),
    ];
    for (name, kind, s) in &ops {
        let m = time(&format!("fig3 {name}"), 5, || {
            std::hint::black_box(op_raster(kind, &[s], DType::F32, 96, 128).unwrap());
        });
        report(&m);
    }

    println!("\n=== Fig 1/2: whole-model maps & rasters ===\n");
    let g = models::build("mobilenet_v1_0.25_128_int8").unwrap();
    let base = Planner::for_graph(&g).plan().unwrap();
    let opt = Planner::for_graph(&g).dmo(true).plan().unwrap();
    report(&time("fig1 alloc map (csv)", 20, || {
        std::hint::black_box(alloc_map_csv(&g, &base));
    }));
    report(&time("fig2a raster original", 2, || {
        std::hint::black_box(model_raster(&g, &base, 1, 120, 160).unwrap());
    }));
    report(&time("fig2b raster DMO", 2, || {
        std::hint::black_box(model_raster(&g, &opt, 1, 120, 160).unwrap());
    }));
    println!(
        "\n  arena: original {} KB vs DMO {} KB (paper Fig 2: 96 vs 64)",
        base.peak() / 1024,
        opt.peak() / 1024
    );

    println!("\n=== Fig 6: minR(i) bound sampling ===\n");
    let x = Shape::hwc(112, 112, 96);
    let k = OpKind::DepthwiseConv2D(DepthwiseParams {
        kernel: (3, 3),
        stride: (2, 2),
        dilation: (1, 1),
        padding: Padding::Same,
        depth_multiplier: 1,
        act: Activation::None,
    });
    report(&time("fig6 csv (Table-I op, 400 samples)", 3, || {
        std::hint::black_box(fig6_csv(&k, &[&x], 400).unwrap());
    }));

    println!("\n=== Fig 8: 4-thread sharded conv trace ===\n");
    let p = Conv2DParams {
        kernel: (5, 5),
        stride: (1, 1),
        dilation: (1, 1),
        padding: Padding::Same,
        out_channels: 8,
        act: Activation::None,
    };
    let xin = Shape::hwc(32, 32, 4);
    let m = time("fig8 sharded events", 3, || {
        std::hint::black_box(sharded_conv_events(&p, &xin, DType::F32, 4).unwrap());
    });
    report(&m);
    let events = sharded_conv_events(&p, &xin, DType::F32, 4).unwrap();
    println!("  {} interleaved events across 4 shards", events.len());

    println!("\n=== Fig 9: DenseNet allocation, original vs DMO ===\n");
    let g9 = models::build("densenet_121").unwrap();
    let b9 = Planner::for_graph(&g9).plan().unwrap();
    let o9 = Planner::for_graph(&g9).dmo(true).plan().unwrap();
    println!(
        "  densenet peak: original {} KB vs DMO {} KB (paper: 8624 vs 8232,",
        b9.peak() / 1024,
        o9.peak() / 1024
    );
    println!("  an allocation-ordering effect — ours finds more, see §Deviations)");
}
