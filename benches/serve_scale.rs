//! Bench: fleet serving at scale — mixed-model traffic over pooled
//! DMO-planned arenas.
//!
//! Drives 10^4 (default; `--requests` up to 10^6) closed-loop requests
//! across ≥3 models through `dmo::fleet::fleet_serve` and records
//! per-model latency percentiles, throughput and arena-pool counters to
//! `BENCH_serve_scale.json` (uploaded by CI next to the other BENCH_*
//! artifacts; summarised in EXPERIMENTS.md §Serving).
//!
//! The bench *asserts* the subsystem's headline property instead of
//! trusting it: each model's plan fixes its arena size before the first
//! request (§II-D), so with K pooled arenas ≥ the worker count the
//! steady-state serving path allocates **zero** arenas — every model
//! must finish with `pool_allocs == 0` and `pool_hit_rate == 1.0`.
//!
//! Usage: `cargo bench --bench serve_scale -- [--requests N]
//! [--models a,b,c] [--arenas K] [--workers N] [--queue C] [--rate R]
//! [--seed S]`

use dmo::fleet::{fleet_serve, FleetConfig, ModelSpec};
use dmo::util::args::{opt, ArgSpec, Args};
use dmo::util::json::{num, obj, s, Json};

const SPEC: &[ArgSpec] = &[
    opt("--requests", "total requests across the fleet (default 10000)"),
    opt("--models", "comma-separated model list (default tiny,tiny_int8,tiny_wide)"),
    opt("--arenas", "pooled arenas per model (default 4)"),
    opt("--workers", "serving worker threads (default 4)"),
    opt("--queue", "per-model admission queue capacity (default 64)"),
    opt("--rate", "open-loop arrival rate, req/s (default 0 = closed loop)"),
    opt("--seed", "workload seed (default 42)"),
];

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, SPEC).unwrap();
    let requests: u64 = args.parsed("--requests", 10_000u64).unwrap();
    let names: Vec<String> = args
        .value("--models")
        .unwrap_or("tiny,tiny_int8,tiny_wide")
        .split(',')
        .map(|n| n.trim().to_string())
        .filter(|n| !n.is_empty())
        .collect();
    let arenas: usize = args.parsed("--arenas", 4usize).unwrap();
    let workers: usize = args.parsed("--workers", 4usize).unwrap();
    let queue: usize = args.parsed("--queue", 64usize).unwrap();
    let rate: f64 = args.parsed("--rate", 0.0f64).unwrap();
    let seed: u64 = args.parsed("--seed", 42u64).unwrap();

    assert!(
        names.len() >= 3,
        "serve_scale measures mixed-model traffic: need ≥3 models, got {names:?}"
    );
    println!(
        "=== serve scale: {} requests over {} models, {} arenas/model, {} workers ({}) ===\n",
        requests,
        names.len(),
        arenas,
        workers,
        if rate > 0.0 {
            format!("open loop @ {rate} req/s")
        } else {
            "closed loop".to_string()
        }
    );

    let cfg = FleetConfig {
        models: names.iter().map(|n| ModelSpec::planned(n)).collect(),
        arenas,
        workers,
        queue_capacity: queue,
        requests,
        rate,
        mix: Vec::new(),
        seed,
        jobs: 0,
        ..FleetConfig::default()
    };
    let report = fleet_serve(&cfg).unwrap();

    println!(
        "{:<14} {:>9} {:>6} {:>9} {:>9} {:>9} {:>10} {:>8} {:>7} {:>5}",
        "model", "done", "shed", "p50 µs", "p95 µs", "p99 µs", "arena B", "pool", "allocs", "maxq"
    );
    let mut entries: Vec<Json> = Vec::new();
    for m in &report.per_model {
        let l = m.metrics.latency();
        println!(
            "{:<14} {:>9} {:>6} {:>9.0} {:>9.0} {:>9.0} {:>10} {:>7.1}% {:>7} {:>5}",
            m.model,
            m.completed,
            m.shed,
            l.p50_us,
            l.p95_us,
            l.p99_us,
            m.arena_bytes,
            100.0 * m.pool_hit_rate,
            m.pool_allocs,
            m.max_queue_depth
        );
        entries.push(obj(vec![
            ("model", s(&m.model)),
            ("completed", num(m.completed)),
            ("shed", num(m.shed)),
            ("mean_us", Json::Num(l.mean_us)),
            ("p50_us", Json::Num(l.p50_us)),
            ("p95_us", Json::Num(l.p95_us)),
            ("p99_us", Json::Num(l.p99_us)),
            ("max_us", Json::Num(l.max_us)),
            ("arena_bytes", num(m.arena_bytes)),
            ("pool_hits", num(m.pool_hits)),
            ("pool_allocs", num(m.pool_allocs)),
            ("pool_hit_rate", Json::Num(m.pool_hit_rate)),
            ("max_queue_depth", num(m.max_queue_depth)),
            ("queue_capacity", num(m.queue_capacity)),
            ("generation", num(m.generation as usize)),
        ]));
    }
    println!(
        "\ncompleted {} ({} shed) in {:.3} s — {:.0} req/s aggregate",
        report.completed,
        report.shed,
        report.wall.as_secs_f64(),
        report.throughput_rps
    );

    let doc = obj(vec![
        ("bench", s("serve_scale")),
        ("requests", num(requests as usize)),
        ("models", num(names.len())),
        ("arenas", num(arenas)),
        ("workers", num(workers)),
        ("queue_capacity", num(queue)),
        ("rate_rps", Json::Num(rate)),
        ("completed", num(report.completed)),
        ("shed", num(report.shed)),
        ("wall_s", Json::Num(report.wall.as_secs_f64())),
        ("throughput_rps", Json::Num(report.throughput_rps)),
        ("per_model", Json::Arr(entries)),
    ]);
    let path = "BENCH_serve_scale.json";
    std::fs::write(path, doc.to_string()).unwrap();
    println!("wrote {path}");

    // ---- the properties this bench exists to enforce -----------------
    assert_eq!(
        report.completed as u64 + report.shed as u64,
        requests,
        "every request must be either completed or accounted as shed"
    );
    if rate <= 0.0 {
        assert_eq!(report.shed, 0, "closed-loop backpressure never sheds");
    }
    for m in &report.per_model {
        assert!(
            m.completed > 0,
            "mixed traffic must actually reach `{}`",
            m.model
        );
    }
    if arenas >= workers {
        // per-model in-flight concurrency can never exceed the worker
        // count, so a pool of K ≥ workers arenas makes the steady-state
        // path allocation-free — exactly, not approximately
        for m in &report.per_model {
            assert_eq!(
                m.pool_allocs, 0,
                "`{}` allocated an arena after warm-up (pool K={arenas}, {workers} workers)",
                m.model
            );
            assert_eq!(
                m.pool_hit_rate, 1.0,
                "`{}` pool hit rate {} != 1.0",
                m.model, m.pool_hit_rate
            );
        }
        println!(
            "pooled-arena path allocation-free across {} models ✓",
            report.per_model.len()
        );
    } else {
        println!("note: --arenas {arenas} < --workers {workers}; skipping the zero-alloc assertion");
    }
}
