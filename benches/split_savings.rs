//! Bench: §II-A operation splitting as a planning action, per zoo model.
//!
//! For every Table III model this plans twice with DMO on — the plain
//! searched plan and the searched+split plan (`allow_splits`) — and
//! records the best split vs no-split peak plus the recompute/reassembly
//! overhead the winning rewrite pays. Asserts the headline properties:
//! the split session is never worse than the unsplit one, and at least
//! one model's split plan strictly beats its best unsplit layout (the
//! §II-A MobileNet case). Results go to `BENCH_split.json`, uploaded by
//! CI as part of the perf trajectory.

use dmo::ir::graph::OpId;
use dmo::models;
use dmo::planner::split::analyse_pair;
use dmo::planner::{Planner, DEFAULT_BEAM, DEFAULT_BUDGET};
use dmo::report::fmt_bytes;
use dmo::util::json::{num, obj, s, Json};
use std::time::Instant;

const MAX_PARTS: usize = 4;

fn main() {
    println!("=== §II-A operation splitting: searched split vs no-split (DMO on) ===\n");
    println!(
        "{:32} {:>10} {:>10} {:>8} {:>12} {:>12} {:>9}",
        "model", "no-split", "split", "Δ", "recomputed", "reassembled", "wall"
    );

    let mut entries: Vec<Json> = Vec::new();
    let mut wins = 0usize;
    for name in models::table3_names() {
        let g = models::build(name).unwrap();
        let unsplit = Planner::for_graph(&g)
            .dmo(true)
            .search(DEFAULT_BEAM, DEFAULT_BUDGET)
            .plan()
            .unwrap();
        let t0 = Instant::now();
        let split = Planner::for_graph(&g)
            .dmo(true)
            .search(DEFAULT_BEAM, DEFAULT_BUDGET)
            .allow_splits(MAX_PARTS)
            .plan()
            .unwrap();
        let wall = t0.elapsed();
        assert!(
            split.peak() <= unsplit.peak(),
            "{name}: split-enabled session {} worse than unsplit {}",
            split.peak(),
            unsplit.peak()
        );

        // recompute overhead of the winning rewrite, if one won
        let (recomputed, assembled, spec) = match &split.rewrite {
            Some(rw) => {
                let sp = rw.splits[0];
                let rep = analyse_pair(&g, OpId(sp.first), OpId(sp.second), sp.parts).unwrap();
                wins += 1;
                (
                    rep.recomputed_elems,
                    rep.assembled_elems,
                    format!("{}→{}×{}", sp.first, sp.second, sp.parts),
                )
            }
            None => (0, 0, "-".to_string()),
        };
        let delta = if split.peak() < unsplit.peak() {
            format!(
                "-{:.1}%",
                100.0 * (unsplit.peak() - split.peak()) as f64 / unsplit.peak() as f64
            )
        } else {
            "=".to_string()
        };
        println!(
            "{:32} {:>10} {:>10} {:>8} {:>12} {:>12} {:>8.2}s",
            name,
            fmt_bytes(unsplit.peak()),
            fmt_bytes(split.peak()),
            delta,
            recomputed,
            assembled,
            wall.as_secs_f64()
        );

        entries.push(obj(vec![
            ("model", s(name)),
            ("no_split_peak_bytes", num(unsplit.peak())),
            ("split_peak_bytes", num(split.peak())),
            ("split_won", Json::Bool(split.rewrite.is_some())),
            ("split_spec", s(&spec)),
            ("recomputed_elems", num(recomputed)),
            ("assembled_elems", num(assembled)),
            ("max_parts", num(MAX_PARTS)),
            ("split_plan_wall_ms", num(wall.as_millis() as usize)),
        ]));
    }

    assert!(
        wins >= 1,
        "at least one zoo model's searched+split plan must beat its best unsplit order"
    );

    let doc = obj(vec![("bench", s("split_savings")), ("models", Json::Arr(entries))]);
    let path = "BENCH_split.json";
    std::fs::write(path, doc.to_string()).unwrap();
    println!("\nwrote {path} ({wins} models improved by splitting)");
}
