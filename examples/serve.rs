//! End-to-end serving driver — the full three-layer stack on a real
//! workload.
//!
//! Layer 1 (Pallas dwconv/pointwise kernels) and Layer 2 (the JAX tiny
//! model) were AOT-lowered by `make artifacts`; this binary is Layer 3:
//! it loads the HLO artifacts onto the PJRT CPU client, then drives an
//! open-loop Poisson request stream through the bounded queue and dynamic
//! batcher at several arrival rates, reporting latency percentiles,
//! throughput and batch efficiency per rate — plus the DMO arena story
//! for the same model if it were deployed on-device.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve
//! ```

use dmo::coordinator::{serve, BatchPolicy, ServeConfig};
use dmo::report::fmt_bytes;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let rates = [100.0, 300.0, 1000.0, 3000.0];
    let requests = 384u64;

    println!("three-layer serving: Pallas kernels → JAX model → HLO text → rust PJRT");
    println!(
        "{:>9} {:>9} {:>6} {:>9} {:>9} {:>9} {:>9} {:>10} {:>6}",
        "rate", "done", "shed", "thr(rps)", "p50(µs)", "p95(µs)", "p99(µs)", "batch", "eff"
    );

    let mut first_platform = None;
    for rate in rates {
        let cfg = ServeConfig {
            requests,
            rate,
            queue_capacity: 128,
            policy: BatchPolicy {
                max_batch: 8,
                window: Duration::from_millis(2),
            },
            seed: 7,
            ..Default::default()
        };
        let r = serve(&cfg)?;
        let l = r.metrics.latency();
        println!(
            "{:>9.0} {:>9} {:>6} {:>9.1} {:>9.0} {:>9.0} {:>9.0} {:>10.2} {:>5.0}%",
            rate,
            r.completed,
            r.shed,
            r.throughput_rps,
            l.p50_us,
            l.p95_us,
            l.p99_us,
            r.metrics.mean_batch(),
            100.0 * r.metrics.batch_efficiency()
        );
        if first_platform.is_none() {
            first_platform = Some((r.platform.clone(), r.arena_original, r.arena_dmo));
        }
    }

    if let Some((platform, orig, dmo)) = first_platform {
        println!("\nPJRT platform: {platform}");
        println!(
            "served model's on-device arena: {} original → {} with DMO ({:.0}% smaller)",
            fmt_bytes(orig),
            fmt_bytes(dmo),
            100.0 * (orig - dmo) as f64 / orig as f64
        );
    }
    Ok(())
}
