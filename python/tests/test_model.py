"""L2 correctness: the tiny model with Pallas kernels vs the pure-jnp
reference path, plus the batching semantics the serving layer relies on."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import CLASSES, RES, forward_one, init_params, make_batched

jax.config.update("jax_platform_name", "cpu")


def _x(seed, batch=None):
    shape = (batch, RES, RES, 3) if batch else (RES, RES, 3)
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)


def test_forward_shape_and_softmax():
    params = init_params()
    probs = forward_one(params, _x(0))
    assert probs.shape == (CLASSES,)
    np.testing.assert_allclose(float(probs.sum()), 1.0, rtol=1e-5)
    assert (np.asarray(probs) >= 0).all()


def test_pallas_path_matches_reference_path():
    params = init_params()
    x = _x(1)
    got = forward_one(params, x, use_pallas=True)
    want = forward_one(params, x, use_pallas=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_batched_rows_match_single():
    params = init_params()
    fn = make_batched(params)
    xb = _x(2, batch=4)
    (out,) = fn(xb)
    assert out.shape == (4, CLASSES)
    for i in range(4):
        single = forward_one(params, xb[i])
        np.testing.assert_allclose(out[i], single, rtol=1e-5, atol=1e-6)


def test_padding_lanes_are_independent():
    """Zero-padded batch lanes must not change real lanes' results —
    the batcher pads every batch to a compiled variant size."""
    params = init_params()
    fn = make_batched(params)
    x1 = _x(3, batch=1)
    (single,) = fn(x1)
    padded = jnp.concatenate([x1, jnp.zeros((3, RES, RES, 3))], axis=0)
    (out,) = fn(padded)
    np.testing.assert_allclose(out[0], single[0], rtol=1e-5, atol=1e-6)


def test_params_deterministic():
    a = init_params()
    b = init_params()
    for k in a:
        assert (np.asarray(a[k]) == np.asarray(b[k])).all(), k
