//! C99 firmware emission — the deployment backend the paper assumes.
//!
//! §I frames DMO as a *pre-allocation* technique for TFMin-style
//! generated C: the plan only pays off once its fixed buffer offsets are
//! baked into firmware that runs inside a single static arena on the
//! MCU. This module is that last mile. [`emit`] lowers a validated
//! [`Plan`](crate::planner::Plan) (or, via [`emit_artifact`], a loaded
//! [`PlanArtifact`](crate::planner::PlanArtifact)) for a
//! [`Graph`](crate::ir::graph::Graph) into one self-contained,
//! dependency-free C99 translation unit plus a small public header:
//!
//! * `static uint8_t dmo_arena[DMO_ARENA_BYTES]` — the planned arena,
//!   sized to the plan's (overlapped) peak, not the disjoint sum;
//! * one `#define DMO_OFF_T<i>` per tensor, taken verbatim from the
//!   plan — overlapping offsets and all;
//! * one kernel function per [`OpKind`](crate::ir::op::OpKind) used,
//!   whose loop sweep and read-before-write order replicate
//!   [`crate::ops::exec`] exactly (the invariant the `O_s` engines
//!   assume — see [`kernels`]);
//! * weights/biases as `const` arrays destined for flash (or, past
//!   [`EmitOptions::weight_embed_limit`], a SplitMix64 generator that
//!   reproduces the same synthetic stream);
//! * a `dmo_invoke(input, output)` entry point, and a header carrying
//!   arena/flash size macros plus the source graph's fingerprint.
//!
//! [`harness`] is the proof-of-safety layer for the emitted artifact:
//! it compiles the unit with the host `cc` (`-std=c99 -Wall -Werror`),
//! runs it, and asserts the outputs are bit-identical to
//! [`crate::interp::run_reference`] — the same guarantee the arena
//! interpreter gives, now for the code we would actually ship.
//!
//! ```
//! use dmo::codegen::{emit, EmitOptions};
//! use dmo::planner::Planner;
//!
//! # fn main() -> anyhow::Result<()> {
//! let graph = dmo::models::build("tiny")?;
//! let plan = Planner::for_graph(&graph).dmo(true).plan()?;
//! let unit = emit(&graph, &plan, &EmitOptions::new("tiny_model"))?;
//! assert!(unit.header.contains(&format!("#define DMO_ARENA_BYTES {}", plan.peak())));
//! assert!(unit.source.contains("void dmo_invoke(const float *input_0, float *output_0)"));
//! # Ok(())
//! # }
//! ```

pub(crate) mod fmt;
pub mod harness;
pub(crate) mod kernels;
pub mod tune;
mod unit;

pub use harness::{
    cc_available, differential_test, differential_test_unit, differential_test_with,
    generate_main_c, time_unit, DiffReport, TimedRun,
};
pub use tune::{tune, TuneCache, TuneReport, TuneTable, Variant};
pub use unit::{emit, emit_artifact, CUnit, EmitOptions};

use crate::ir::graph::Graph;

/// Rough per-kernel machine-code size on a Cortex-M class target —
/// deliberately generous so [`flash_footprint`] over-estimates rather
/// than green-lighting a part the image will not fit.
const KERNEL_CODE_BYTES: usize = 640;
/// Per-op call-site cost (argument setup + call).
const CALL_CODE_BYTES: usize = 48;
/// Fixed runtime overhead (accessors, entry point, CRT glue).
const RUNTIME_CODE_BYTES: usize = 1024;

/// Flash image of an emitted unit: weights (exact) + code (estimate).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlashFootprint {
    /// Constant weight/bias bytes, exactly as stored by the emitted
    /// arrays (dtype-faithful: `int8_t` weights for quantised models).
    pub weight_bytes: usize,
    /// Estimated machine-code bytes for the kernels + entry point.
    pub code_bytes: usize,
}

impl FlashFootprint {
    /// Total flash bytes the unit needs.
    pub fn total(&self) -> usize {
        self.weight_bytes + self.code_bytes
    }
}

/// Flash footprint the emitted unit for `graph` will need — available
/// without emitting, so [`crate::mcu::deploy_matrix`] can gate on it.
pub fn flash_footprint(graph: &Graph) -> FlashFootprint {
    FlashFootprint {
        weight_bytes: graph.weight_bytes(),
        code_bytes: code_estimate(graph),
    }
}

pub(crate) fn code_estimate(graph: &Graph) -> usize {
    RUNTIME_CODE_BYTES
        + KERNEL_CODE_BYTES * kernels::kernels_used(graph).len()
        + CALL_CODE_BYTES * graph.ops.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn flash_footprint_weights_match_graph() {
        let g = models::build("tiny").unwrap();
        let ff = flash_footprint(&g);
        assert_eq!(ff.weight_bytes, g.weight_bytes());
        assert!(ff.code_bytes >= RUNTIME_CODE_BYTES + KERNEL_CODE_BYTES);
        assert_eq!(ff.total(), ff.weight_bytes + ff.code_bytes);
    }

    #[test]
    fn quantised_weights_are_smaller_in_flash() {
        let f32v = flash_footprint(&models::build("tiny").unwrap());
        let i8v = flash_footprint(&models::build("tiny_int8").unwrap());
        assert!(i8v.weight_bytes < f32v.weight_bytes);
    }
}
