//! `dmo` — command-line driver for the DMO reproduction.
//!
//! Subcommands map one-to-one onto the paper's artefacts:
//! `table2`, `table3`, `figures`, `fit`, `plan`, `split`, `validate`,
//! `trace-op`, `emit-c`, `serve` (see `dmo help`). Plans can be
//! exported as versioned artifacts (`dmo plan <model> --export p.json`)
//! and reused across processes (`dmo validate <model> --import p.json`,
//! `dmo emit-c --import p.json --out model.c`, `dmo serve --plan
//! p.json`) without re-running the planner search.

use anyhow::{bail, Context, Result};
use dmo::codegen::{self, EmitOptions};
use dmo::ir::{DType, Shape};
use dmo::planner::{PlanArtifact, PlanCandidate, PlannedModel, Planner};
use dmo::util::args::{flag, opt, ArgSpec, Args};
use dmo::{interp, mcu, models, report, trace};
use std::fs;
use std::path::{Path, PathBuf};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const OUT_SPEC: ArgSpec = opt("--out", "output directory (default `results`)");

fn out_dir(args: &Args) -> String {
    args.value("--out").unwrap_or("results").to_string()
}

fn write_out(dir: &str, file: &str, content: &str) -> Result<()> {
    fs::create_dir_all(dir)?;
    let path = Path::new(dir).join(file);
    fs::write(&path, content).with_context(|| format!("writing {}", path.display()))?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Stderr progress line for `--verbose` planning sessions.
fn report_candidate(c: &PlanCandidate) {
    let split = match &c.rewrite {
        Some(specs) => format!(
            " + rewrite({})",
            specs.iter().map(|sp| sp.describe()).collect::<Vec<_>>().join(", ")
        ),
        None => String::new(),
    };
    eprintln!(
        "  [{}/{}] {} + {}{split} → peak {} (best {})",
        c.index + 1,
        c.total,
        c.strategy.name(),
        c.heuristic.name(),
        report::fmt_bytes(c.peak),
        report::fmt_bytes(c.best_peak)
    );
}

/// Resolve the §II-A rewrite budget from `--rewrites=pairs:N[,chains:D]
/// [,multi:K]`. The legacy `--splits=N` spelling is still accepted,
/// mapped onto `pairs:N`, and warned about via `obs::log`.
fn rewrite_budget(args: &Args) -> Result<Option<dmo::planner::RewriteBudget>> {
    use dmo::planner::RewriteBudget;
    match (args.value("--rewrites"), args.value("--splits")) {
        (Some(_), Some(_)) => {
            bail!("--rewrites and --splits are the same knob — pass only --rewrites")
        }
        (Some(spec), None) => {
            let b = RewriteBudget::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
            Ok(Some(b))
        }
        (None, Some(_)) => {
            let n: usize = args.parsed("--splits", 0usize)?;
            dmo::obs::log::warn(format_args!(
                "--splits={n} is deprecated; use --rewrites=pairs:{n}"
            ));
            Ok(if n > 0 { Some(RewriteBudget::pairs(n)) } else { None })
        }
        (None, None) => Ok(None),
    }
}

/// Load a persisted `O_s` cache if the flagged file exists; a corrupt or
/// stale file degrades to a cold start with a warning, never a failure.
fn load_os_cache(cache: &dmo::overlap::OsCache, path: &str) {
    if !Path::new(path).exists() {
        return;
    }
    match cache.load(Path::new(path)) {
        Ok(n) => eprintln!("  O_s cache: loaded {n} entries from {path}"),
        Err(e) => eprintln!("  O_s cache: ignoring {path} ({e:#}); starting cold"),
    }
}

/// Persist the `O_s` cache after a run (best-effort).
fn save_os_cache(cache: &dmo::overlap::OsCache, path: &str) {
    match cache.save(Path::new(path)) {
        Ok(n) => eprintln!("  O_s cache: saved {n} entries to {path}"),
        Err(e) => eprintln!("  O_s cache: could not save to {path}: {e:#}"),
    }
}

/// Load a persisted kernel-tuning cache; corruption degrades to a cold
/// start with a warning, mirroring [`load_os_cache`].
fn load_tune_cache(cache: &codegen::TuneCache, path: &str) {
    if !Path::new(path).exists() {
        return;
    }
    match cache.load(Path::new(path)) {
        Ok(n) => eprintln!("  tune cache: loaded {n} entries from {path}"),
        Err(e) => eprintln!("  tune cache: ignoring {path} ({e:#}); starting cold"),
    }
}

/// Persist the kernel-tuning cache after a run (best-effort).
fn save_tune_cache(cache: &codegen::TuneCache, path: &str) {
    match cache.save(Path::new(path)) {
        Ok(n) => eprintln!("  tune cache: saved {n} entries to {path}"),
        Err(e) => eprintln!("  tune cache: could not save to {path}: {e:#}"),
    }
}

fn run(argv: &[String]) -> Result<()> {
    let (cmd, rest) = match argv.split_first() {
        None => {
            print_help();
            return Ok(());
        }
        Some((c, rest)) => (c.as_str(), rest),
    };
    match cmd {
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        "models" => {
            Args::parse(rest, &[])?;
            for n in models::all_names() {
                let g = models::build(n)?;
                println!(
                    "{n:32} {:4} ops  {:5} tensors  weights {}",
                    g.ops.len(),
                    g.tensors.len(),
                    report::fmt_bytes(g.weight_bytes())
                );
            }
            Ok(())
        }
        "plan" => {
            let args = Args::parse(
                rest,
                &[
                    flag("--baseline", "plan without DMO"),
                    flag("--map", "print the allocation map"),
                    flag("--verbose", "print every search candidate"),
                    opt("--strategy", "serialisation: sweep (default) | eager | lazy | search"),
                    opt("--beam", "beam width for --strategy=search (default 8)"),
                    opt("--budget", "expansion budget for --strategy=search (default 50000)"),
                    opt("--jobs", "planner worker threads (default: all cores; plans are identical at any count)"),
                    opt("--rewrites", "sweep §II-A rewrites: pairs:N[,chains:D][,multi:K]"),
                    opt("--splits", "deprecated alias: --splits=N maps to --rewrites=pairs:N"),
                    opt("--os-cache", "persisted O_s cache file (loaded if present, saved after planning)"),
                    opt("--export", "write the plan as a reusable artifact"),
                    opt("--import", "load a plan artifact instead of planning"),
                    flag("--profile", "execute the plan under the watermark profiler; print observed vs planned per op"),
                    opt("--trace-out", "Chrome trace-event JSON of the session (planner spans + --profile execution)"),
                ],
            )?;
            let name = args
                .pos(0)
                .context("usage: dmo plan <model> [--baseline] [--map] [--strategy=search] [--splits N] [--profile] [--trace-out PATH] [--export PATH] [--import PATH]")?
                .to_string();
            let trace_out = args.value("--trace-out").map(PathBuf::from);
            if trace_out.is_some() {
                dmo::obs::trace::enable();
            }
            let g = models::build(&name)?;
            let os_cache = std::sync::Arc::new(dmo::overlap::OsCache::new());
            let os_cache_path = args.value("--os-cache").map(str::to_string);
            let plan = match args.value("--import") {
                Some(path) => {
                    let planning_only = args.flag("--baseline")
                        || args.flag("--verbose")
                        || args.value("--strategy").is_some()
                        || args.value("--beam").is_some()
                        || args.value("--budget").is_some()
                        || args.value("--jobs").is_some()
                        || args.value("--splits").is_some()
                        || args.value("--rewrites").is_some()
                        || args.value("--os-cache").is_some();
                    if planning_only {
                        bail!(
                            "--import loads a finished plan; --baseline/--verbose/--strategy/\
                             --beam/--budget/--jobs/--rewrites/--os-cache only apply when \
                             planning from scratch"
                        );
                    }
                    let artifact = PlanArtifact::load(Path::new(path))?;
                    let plan = artifact.to_plan(&g)?;
                    println!("loaded plan artifact {path} (revalidated against `{name}`)");
                    plan
                }
                None => {
                    if let Some(p) = &os_cache_path {
                        load_os_cache(&os_cache, p);
                    }
                    let mut session = Planner::for_graph(&g)
                        .dmo(!args.flag("--baseline"))
                        .jobs(args.parsed("--jobs", 0usize)?)
                        .os_cache(os_cache.clone());
                    let strategy = args.value("--strategy");
                    if (args.value("--beam").is_some() || args.value("--budget").is_some())
                        && strategy != Some("search")
                    {
                        bail!("--beam/--budget only apply with --strategy=search");
                    }
                    let beam: usize = args.parsed("--beam", dmo::planner::DEFAULT_BEAM)?;
                    let budget: usize = args.parsed("--budget", dmo::planner::DEFAULT_BUDGET)?;
                    session = match strategy {
                        None | Some("sweep") => session,
                        Some("eager") => session.strategies(&[dmo::planner::Strategy::Eager]),
                        Some("lazy") => session.strategies(&[dmo::planner::Strategy::Lazy]),
                        Some("search") => session.search(beam, budget),
                        Some(other) => bail!(
                            "unknown strategy `{other}` (sweep | eager | lazy | search)"
                        ),
                    };
                    if let Some(rb) = rewrite_budget(&args)? {
                        session = session.rewrites(rb);
                    }
                    if args.flag("--verbose") {
                        session = session.on_candidate(report_candidate);
                    }
                    let plan = session.plan()?;
                    if let Some(p) = &os_cache_path {
                        save_os_cache(&os_cache, p);
                    }
                    plan
                }
            };
            println!(
                "{name}: peak {} ({} strategy, {} heuristic, {} overlaps applied)",
                report::fmt_bytes(plan.peak()),
                plan.strategy.name(),
                plan.heuristic.name(),
                plan.alloc.applied.len()
            );
            if let Some(st) = plan.search {
                println!(
                    "  order search: beam {}, budget {}, {} states expanded, {} pruned, \
                     {} orders scored (surrogate peak {})",
                    st.beam,
                    st.budget,
                    st.expanded,
                    st.pruned,
                    st.orders_scored,
                    report::fmt_bytes(st.surrogate_peak)
                );
            }
            let cache_stats = os_cache.stats();
            if cache_stats.lookups() > 0 {
                println!(
                    "  O_s cache: {} hits / {} misses ({} distinct op signatures, {:.0}% hit rate)",
                    cache_stats.hits,
                    cache_stats.misses,
                    os_cache.len(),
                    100.0 * cache_stats.hit_rate()
                );
            }
            if let Some(rw) = &plan.rewrite {
                for sp in &rw.specs {
                    println!(
                        "  rewrite: {} ({} ops → {}; §II-A rewrite carried in the plan)",
                        sp.describe(),
                        g.ops.len(),
                        rw.graph.ops.len()
                    );
                }
            }
            // split plans index the rewritten graph — resolve for names
            let pg = plan.graph_for(&g);
            for a in &plan.alloc.applied {
                println!(
                    "  overlap {} ⇢ {}: {}",
                    pg.tensor(a.input).name,
                    pg.tensor(a.output).name,
                    report::fmt_bytes(a.bytes)
                );
            }
            if let Some(path) = args.value("--export") {
                PlanArtifact::from_plan(&g, &plan).save(Path::new(path))?;
                println!("exported plan artifact to {path}");
            }
            if args.flag("--map") {
                println!("{}", trace::render::alloc_map_ascii(&g, &plan, 100));
            }
            let profile = if args.flag("--profile") {
                let prof = profile_plan(&name, &g, &plan, 42)?;
                print_profile(&prof);
                Some(prof)
            } else {
                None
            };
            // the trace file is written even on a watermark violation —
            // it is exactly the evidence needed to debug one
            if let Some(p) = &trace_out {
                write_trace(p)?;
            }
            if let Some(prof) = profile {
                prof.verify()?;
            }
            Ok(())
        }
        "orders" => {
            let args = Args::parse(
                rest,
                &[
                    OUT_SPEC,
                    opt("--beam", "search beam width (default 8)"),
                    opt("--budget", "search expansion budget (default 50000)"),
                    opt("--jobs", "planner worker threads (default: all cores)"),
                    opt("--rewrites", "add a searched+rewritten session per row: pairs:N[,chains:D][,multi:K]"),
                    opt("--splits", "deprecated alias: --splits=N maps to --rewrites=pairs:N"),
                    opt("--os-cache", "persisted O_s cache file (loaded if present, saved after the report)"),
                ],
            )?;
            let beam: usize = args.parsed("--beam", dmo::planner::DEFAULT_BEAM)?;
            let budget: usize = args.parsed("--budget", dmo::planner::DEFAULT_BUDGET)?;
            let jobs: usize = args.parsed("--jobs", 0usize)?;
            let rb = rewrite_budget(&args)?.unwrap_or_default();
            let names: Vec<&str> = match args.pos(0) {
                Some(n) => vec![n],
                None => models::table3_names(),
            };
            // one cache for the whole report: every row's sessions share
            // it, and repeated shapes across models collapse too
            let cache = dmo::overlap::OsCache::process_shared();
            if let Some(p) = args.value("--os-cache") {
                load_os_cache(&cache, p);
            }
            let mut rows = Vec::new();
            for name in names {
                let row =
                    report::order_search_row_rewrites(name, beam, budget, jobs, &cache, &rb)?;
                eprintln!(
                    "  {name}: eager {}, lazy {}, search {}{} (O_s cache {} hits / {} misses)",
                    report::fmt_bytes(row.eager),
                    report::fmt_bytes(row.lazy),
                    report::fmt_bytes(row.search),
                    match row.split {
                        Some(p) => format!(", rewritten {}", report::fmt_bytes(p)),
                        None => String::new(),
                    },
                    row.cache_hits,
                    row.cache_misses
                );
                rows.push(row);
            }
            let md = report::order_search_markdown(&rows);
            println!("{md}");
            if let Some(p) = args.value("--os-cache") {
                save_os_cache(&cache, p);
            }
            write_out(&out_dir(&args), "orders.md", &md)
        }
        "table2" => {
            let args = Args::parse(rest, &[OUT_SPEC])?;
            let planned = report::plan_models(&report::table2_models())?;
            let md = report::table2_markdown(&planned)?;
            println!("{md}");
            write_out(&out_dir(&args), "table2.md", &md)
        }
        "table3" => {
            let args = Args::parse(rest, &[OUT_SPEC])?;
            let planned = report::plan_models(&models::table3_names())?;
            let (md, rows) = report::table3_markdown(&planned)?;
            println!("{md}");
            let dir = out_dir(&args);
            write_out(&dir, "table3.md", &md)?;
            write_out(&dir, "table3.csv", &report::table3_csv(&rows))
        }
        "figures" => {
            let args = Args::parse(
                rest,
                &[OUT_SPEC, opt("--fig", "regenerate one figure (1|2|3|6|8|9)")],
            )?;
            figures(&args)
        }
        "fit" => {
            let args = Args::parse(
                rest,
                &[
                    opt(
                        "--rewrites",
                        "also plan with §II-A rewrites (pairs:N[,chains:D][,multi:K]) and add a deploy(split) column",
                    ),
                    opt("--splits", "deprecated alias: --splits=N maps to --rewrites=pairs:N"),
                    opt(
                        "--budget-ms",
                        "also gate deployability on estimated latency (milliseconds)",
                    ),
                ],
            )?;
            let rb = rewrite_budget(&args)?.unwrap_or_default();
            let budget_ms: Option<f64> = match args.value("--budget-ms") {
                Some(v) => {
                    let b: f64 = v
                        .parse()
                        .with_context(|| format!("--budget-ms: `{v}` is not a number"))?;
                    if b.is_nan() || b <= 0.0 {
                        bail!("--budget-ms must be positive, got {b}");
                    }
                    Some(b)
                }
                None => None,
            };
            let names: Vec<&str> = match args.pos(0) {
                Some(n) => vec![n],
                None => models::table3_names(),
            };
            println!(
                "{:32} {:20} {:>9} {:>9} {:>9} {:>11}  deploy(orig) deploy(DMO) deploy(split)",
                "model", "mcu", "arena0", "arenaD", "flash", "latency"
            );
            for name in names {
                let pm = if rb.enabled() {
                    PlannedModel::new_rewrites(models::build(name)?, rb, 0, None)?
                } else {
                    PlannedModel::new(models::build(name)?)?
                };
                // deployability gates on the emitted unit's full flash
                // image (weights + code estimate), not weights alone;
                // the split column gates on the *rewritten* unit's image.
                // with --budget-ms a part that fits SRAM and flash can
                // still be rejected for missing the latency budget.
                let row = pm.row();
                for r in mcu::deploy_matrix_planned(&pm) {
                    let in_budget = budget_ms.map_or(true, |b| r.latency_ms <= b);
                    let verdict = |fits: bool| match (fits, in_budget) {
                        (true, true) => "yes",
                        (true, false) => "no (latency)",
                        (false, _) => "no",
                    };
                    println!(
                        "{:32} {:20} {:>9} {:>9} {:>9} {:>8.2} ms  {:12} {:11} {}",
                        name,
                        r.mcu,
                        report::fmt_bytes(row.original),
                        report::fmt_bytes(row.optimised),
                        report::fmt_bytes(r.flash_bytes),
                        r.latency_ms,
                        verdict(r.without_dmo),
                        verdict(r.with_dmo),
                        match r.with_split {
                            Some(true) if r.rescued_by_split() && in_budget => "yes (rescued)",
                            Some(true) => verdict(true),
                            Some(false) => "no",
                            None => "-",
                        },
                    );
                }
            }
            Ok(())
        }
        "emit-c" => {
            let args = Args::parse(
                rest,
                &[
                    opt("--import", "plan artifact to emit (model taken from it)"),
                    opt("--out", "output C file (default results/<model>_model.c)"),
                    opt("--seed", "synthetic weight/input seed (default 42)"),
                    opt("--embed-limit", "max weight elements embedded as const arrays"),
                    flag("--check", "compile + run the unit, diff against the interpreter"),
                    flag("--tune", "autotune kernel variants (compile+time, bit-exact gated)"),
                    opt("--tune-cache", "tuning-cache file to load/persist across runs"),
                    opt("--tune-iters", "timing iterations per tuning probe (default 50)"),
                ],
            )?;
            emit_c(&args)
        }
        "split" => {
            let args = Args::parse(
                rest,
                &[
                    opt("--parts", "max bands to consider (default 8)"),
                    opt(
                        "--rewrites",
                        "candidate budget pairs:N[,chains:D][,multi:K] (default pairs:8,chains:4)",
                    ),
                ],
            )?;
            let rb = match args.value("--rewrites") {
                Some(spec) => {
                    dmo::planner::RewriteBudget::parse(spec).map_err(|e| anyhow::anyhow!(e))?
                }
                None => dmo::planner::RewriteBudget {
                    max_parts: args.parsed("--parts", 8usize)?,
                    max_splits: 2,
                    max_chain_depth: 4,
                },
            };
            let name = args
                .pos(0)
                .context("usage: dmo split <model> [--parts N] [--rewrites pairs:N,chains:D]")?;
            let g = models::build(name)?;
            let mut any = false;
            if let Some(r) = dmo::planner::split::best_split(&g, rb.max_parts) {
                any = true;
                println!(
                    "{name}: split ops {}→{} into {} bands: {} → {} pair peak, \
                     {} elems recomputed + {} copied by reassembly",
                    r.first.0,
                    r.second.0,
                    r.parts,
                    report::fmt_bytes(r.peak_before),
                    report::fmt_bytes(r.peak_after),
                    r.recomputed_elems,
                    r.assembled_elems
                );
            }
            let chains = dmo::planner::split::chain_candidates(
                &g,
                rb.max_parts,
                rb.max_chain_depth,
                8,
            );
            for c in &chains {
                any = true;
                let ops = c
                    .ops
                    .iter()
                    .map(|o| o.0.to_string())
                    .collect::<Vec<_>>()
                    .join("→");
                println!(
                    "{name}: chain ops {ops} banded ×{}: {} → {} chain peak, \
                     {} elems recomputed + {} copied by reassembly",
                    c.parts,
                    report::fmt_bytes(c.peak_before),
                    report::fmt_bytes(c.peak_after),
                    c.recomputed_elems,
                    c.assembled_elems
                );
            }
            if any {
                println!(
                    "  plan them end-to-end with `dmo plan {name} --rewrites=pairs:{}{}` — the \
                     winning plan carries the rewrite through artifact/interp/emit-c",
                    rb.max_parts,
                    if rb.max_chain_depth >= 3 {
                        format!(",chains:{}", rb.max_chain_depth)
                    } else {
                        String::new()
                    }
                );
            } else {
                println!("{name}: no profitable rewrite found");
            }
            Ok(())
        }
        "validate" => {
            let args = Args::parse(
                rest,
                &[opt("--import", "plan artifact to revalidate and execute")],
            )?;
            let name = args
                .pos(0)
                .context("usage: dmo validate <model> [--import PATH]")?
                .to_string();
            let g = models::build(&name)?;
            match args.value("--import") {
                Some(path) => {
                    let artifact = PlanArtifact::load(Path::new(path))?;
                    interp::run_planned_artifact(&g, &artifact, 42)?;
                    println!(
                        "{name}: artifact {path} ({}, {} overlaps) revalidated and executed \
                         bit-identically to the reference — safe",
                        report::fmt_bytes(artifact.peak),
                        artifact.applied.len()
                    );
                }
                None => {
                    let plan = Planner::for_graph(&g).dmo(true).plan()?;
                    interp::validate_plan(&g, &plan, 42)?;
                    println!(
                        "{name}: DMO plan ({} with {} overlaps) executes bit-identically to the \
                         reference — safe",
                        report::fmt_bytes(plan.peak()),
                        plan.alloc.applied.len()
                    );
                }
            }
            Ok(())
        }
        "trace-op" => {
            let args = Args::parse(rest, &[])?;
            let which = args.pos(0).unwrap_or("dwconv");
            let (kind, shape) = trace_op_spec(which)?;
            let r = trace::render::op_raster(&kind, &[&shape], DType::F32, 48, 96)?;
            println!("{}", r.to_ascii());
            Ok(())
        }
        "trace-run" => {
            let args = Args::parse(
                rest,
                &[
                    opt("--trace-out", "trace file (default results/<model>_trace.json)"),
                    opt("--seed", "synthetic input seed (default 42)"),
                    flag("--baseline", "plan without DMO"),
                ],
            )?;
            let name = args
                .pos(0)
                .context("usage: dmo trace-run <model> [--trace-out PATH] [--seed N]")?
                .to_string();
            let seed: u64 = args.parsed("--seed", 42u64)?;
            let trace_path: PathBuf = match args.value("--trace-out") {
                Some(p) => PathBuf::from(p),
                None => PathBuf::from("results").join(format!("{name}_trace.json")),
            };
            // enable before planning so the planner's sweep/beam spans land
            // in the same timeline as the per-op execution spans
            dmo::obs::trace::enable();
            let g = models::build(&name)?;
            let plan = Planner::for_graph(&g).dmo(!args.flag("--baseline")).plan()?;
            println!(
                "{name}: peak {} ({} strategy, {} overlaps applied)",
                report::fmt_bytes(plan.peak()),
                plan.strategy.name(),
                plan.alloc.applied.len()
            );
            let prof = profile_plan(&name, &g, &plan, seed)?;
            print_profile(&prof);
            write_trace(&trace_path)?;
            prof.verify()?;
            Ok(())
        }
        "serve" => {
            let args = Args::parse(rest, dmo::coordinator::cli::SERVE_SPEC)?;
            dmo::coordinator::cli::serve_main(&args)
        }
        other => bail!("unknown command `{other}` — try `dmo help`"),
    }
}

/// `dmo emit-c`: lower a plan (fresh or `--import`ed artifact) to a
/// standalone C99 unit + header, report its flash/RAM fit across the
/// MCU catalog, and optionally (`--check`) compile and run it against
/// the interpreter's reference outputs.
fn emit_c(args: &Args) -> Result<()> {
    let seed: u64 = args.parsed("--seed", 42u64)?;
    let embed_limit: usize = args.parsed("--embed-limit", 1_000_000usize)?;

    let (graph, plan) = match args.value("--import") {
        Some(path) => {
            let artifact = PlanArtifact::load(Path::new(path))?;
            // a positional model name must agree with the artifact —
            // silently emitting a different model than the user named
            // would be firmware for the wrong network
            if let Some(named) = args.pos(0) {
                if named != artifact.model {
                    bail!(
                        "model `{named}` does not match the artifact's model \
                         `{}` — drop the positional argument or re-plan",
                        artifact.model
                    );
                }
            }
            let g = models::build(&artifact.model)?;
            let plan = artifact.to_plan(&g)?;
            println!(
                "loaded plan artifact {path} (revalidated against `{}`)",
                artifact.model
            );
            (g, plan)
        }
        None => {
            let name = args.pos(0).context(
                "usage: dmo emit-c <model> [--out PATH] [--seed N] [--check] [--tune]\n\
                 \x20      dmo emit-c --import plan.json [--out PATH]",
            )?;
            let g = models::build(name)?;
            let plan = Planner::for_graph(&g).dmo(true).plan()?;
            (g, plan)
        }
    };

    let out: PathBuf = match args.value("--out") {
        Some(p) => PathBuf::from(p),
        None => {
            // EmitOptions sanitises the stem to a C identifier; reuse it
            // for the default file name so the two always agree
            let stem = EmitOptions::new(&format!("{}_model", graph.name)).stem;
            PathBuf::from("results").join(format!("{stem}.c"))
        }
    };
    let stem = out
        .file_stem()
        .and_then(|s| s.to_str())
        .context("--out path has no usable file stem")?
        .to_string();
    let mut opts = EmitOptions::new(&stem).seed(seed).weight_embed_limit(embed_limit);

    if args.flag("--tune") {
        let iters: usize = args.parsed("--tune-iters", 50usize)?;
        if codegen::cc_available().is_none() {
            eprintln!("  tune: no C compiler on PATH — emitting untuned defaults");
        } else {
            let cache = codegen::TuneCache::new();
            if let Some(path) = args.value("--tune-cache") {
                load_tune_cache(&cache, path);
            }
            let tr = codegen::tune(&graph, &plan, seed, iters, &cache)?;
            // `probes: 0` on a warm cache is what the CI determinism
            // smoke greps for — keep this line machine-readable
            println!(
                "tuned {} classes (probes: {}, cache hits: {})",
                tr.rows.len(),
                tr.probes,
                tr.cache_hits
            );
            for r in &tr.rows {
                let timings = if r.from_cache {
                    "cached".to_string()
                } else {
                    r.timings
                        .iter()
                        .map(|(v, ns)| match ns {
                            Some(ns) => format!("{} {:.0}ns", v.name(), ns),
                            None => format!("{} disqualified", v.name()),
                        })
                        .collect::<Vec<_>>()
                        .join(", ")
                };
                println!("  {}: {} ({timings})", r.class, r.chosen.name());
            }
            if let Some(path) = args.value("--tune-cache") {
                save_tune_cache(&cache, path);
            }
            opts = opts.tuning(tr.table);
        }
    }

    let unit = codegen::emit(&graph, &plan, &opts)?;
    let header_path = unit.write_to(&out)?;
    println!("wrote {} and {}", out.display(), header_path.display());
    println!(
        "weights: {} ({})",
        report::fmt_bytes(unit.flash.weight_bytes),
        if unit.weights_embedded {
            "embedded const arrays"
        } else {
            "SplitMix64 generator (over --embed-limit)"
        }
    );
    println!("{}", report::emitted_unit_markdown(&unit));

    if args.flag("--check") {
        let r = codegen::harness::differential_test_unit(&unit, &graph, opts.seed)?;
        println!(
            "differential check passed: {} output elems bit-identical to the \
             interpreter reference (compiled with `{}`)",
            r.elems, r.cc
        );
    }
    Ok(())
}

/// Execute `plan` under the watermark profiler on deterministic synthetic
/// inputs, returning the observed-vs-planned [`ExecProfile`].
fn profile_plan(
    name: &str,
    g: &dmo::ir::graph::Graph,
    plan: &dmo::planner::Plan,
    seed: u64,
) -> Result<dmo::obs::watermark::ExecProfile> {
    let inputs: Vec<Vec<f32>> = g
        .inputs
        .iter()
        .map(|&t| interp::gen_input(g, t, seed))
        .collect();
    let (_outputs, prof) = interp::run_plan_profiled(name, g, plan, &inputs, seed)?;
    Ok(prof)
}

/// Per-op observed-vs-planned table for `dmo plan --profile` / `trace-run`.
fn print_profile(p: &dmo::obs::watermark::ExecProfile) {
    println!(
        "profile: observed peak {} (planned {}) — {} of {} arena bytes touched",
        report::fmt_bytes(p.observed_peak),
        report::fmt_bytes(p.planned_peak),
        report::fmt_bytes(p.touched_bytes),
        report::fmt_bytes(p.arena_bytes)
    );
    println!(
        "  {:>4} {:>4}  {:<28} {:>8} {:>10} {:>10} {:>10} {:>10}",
        "step", "op", "name", "µs", "read", "written", "observed", "planned≤"
    );
    for op in &p.ops {
        println!(
            "  {:>4} {:>4}  {:<28} {:>8} {:>10} {:>10} {:>10} {:>10}",
            op.step,
            op.op,
            op.name,
            op.wall_us,
            report::fmt_bytes(op.bytes_read as usize),
            report::fmt_bytes(op.bytes_written as usize),
            report::fmt_bytes(op.high_water),
            report::fmt_bytes(op.planned_extent)
        );
    }
}

/// Drain the process tracer and write a Chrome trace-event JSON file.
fn write_trace(path: &Path) -> Result<()> {
    dmo::obs::trace::disable();
    let events = dmo::obs::trace::drain();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            fs::create_dir_all(dir)?;
        }
    }
    fs::write(path, dmo::obs::trace::export_chrome(&events).to_string())
        .with_context(|| format!("writing trace to {}", path.display()))?;
    println!(
        "trace: {} events → {} (load in Perfetto / chrome://tracing)",
        events.len(),
        path.display()
    );
    Ok(())
}

fn trace_op_spec(which: &str) -> Result<(dmo::ir::OpKind, Shape)> {
    use dmo::ir::op::*;
    Ok(match which {
        "relu" => (OpKind::Unary(UnaryKind::Relu), Shape::hwc(24, 24, 4)),
        "matmul" => (OpKind::MatMulAccum { out_features: 64 }, Shape::new(&[1, 96])),
        "dwconv" => (
            OpKind::DepthwiseConv2D(DepthwiseParams {
                kernel: (3, 3),
                stride: (1, 1),
                dilation: (1, 1),
                padding: Padding::Same,
                depth_multiplier: 1,
                act: Activation::None,
            }),
            Shape::hwc(24, 24, 4),
        ),
        "conv" => (
            OpKind::Conv2D(Conv2DParams {
                kernel: (3, 3),
                stride: (1, 1),
                dilation: (1, 1),
                padding: Padding::Same,
                out_channels: 8,
                act: Activation::None,
            }),
            Shape::hwc(24, 24, 4),
        ),
        other => bail!("unknown op `{other}` (relu|matmul|dwconv|conv)"),
    })
}

fn figures(args: &Args) -> Result<()> {
    let dir = out_dir(args);
    let which: Option<usize> = args.value("--fig").map(|v| v.parse()).transpose()?;
    let all = which.is_none();
    let fig = |n: usize| all || which == Some(n);

    // Figs 1 & 2 use the paper's example model: MobileNet v1 0.25 128 8-bit
    let pm = PlannedModel::new(models::build("mobilenet_v1_0.25_128_int8")?)?;
    let (g, base, opt) = (&pm.graph, &pm.baseline, &pm.dmo);

    if fig(1) {
        write_out(&dir, "fig1_alloc_original.txt", &trace::render::alloc_map_ascii(g, base, 100))?;
        write_out(&dir, "fig1_alloc_original.csv", &trace::render::alloc_map_csv(g, base))?;
    }
    if fig(2) {
        let ra = trace::render::model_raster(g, base, 1, 120, 160)?;
        write_out(&dir, "fig2a_trace_original.pgm", &ra.to_pgm())?;
        let rb = trace::render::model_raster(g, opt, 1, 120, 160)?;
        write_out(&dir, "fig2b_trace_dmo.pgm", &rb.to_pgm())?;
        println!(
            "fig2: arena original {} vs DMO {}",
            report::fmt_bytes(base.peak()),
            report::fmt_bytes(opt.peak())
        );
    }
    if fig(3) {
        for op in ["relu", "matmul", "dwconv", "conv"] {
            let (kind, shape) = trace_op_spec(op)?;
            let r = trace::render::op_raster(&kind, &[&shape], DType::F32, 96, 128)?;
            write_out(&dir, &format!("fig3_{op}.pgm"), &r.to_pgm())?;
        }
    }
    if fig(6) {
        let x = Shape::hwc(112, 112, 96);
        let k = dmo::ir::OpKind::DepthwiseConv2D(dmo::ir::op::DepthwiseParams {
            kernel: (3, 3),
            stride: (2, 2),
            dilation: (1, 1),
            padding: dmo::ir::Padding::Same,
            depth_multiplier: 1,
            act: dmo::ir::Activation::None,
        });
        write_out(&dir, "fig6_minr_bound.csv", &trace::render::fig6_csv(&k, &[&x], 400)?)?;
    }
    if fig(8) {
        let p = dmo::ir::op::Conv2DParams {
            kernel: (5, 5),
            stride: (1, 1),
            dilation: (1, 1),
            padding: dmo::ir::Padding::Same,
            out_channels: 8,
            act: dmo::ir::Activation::None,
        };
        let x = Shape::hwc(32, 32, 4);
        let events = trace::threads::sharded_conv_events(&p, &x, DType::F32, 4)?;
        let arena = (x.num_elements() + 32 * 32 * 8) * 4;
        let r = trace::threads::raster_events(&events, arena, 96, 128);
        write_out(&dir, "fig8_multithreaded_conv.pgm", &r.to_pgm())?;
    }
    if fig(9) {
        let pm9 = PlannedModel::new(models::build("densenet_121")?)?;
        write_out(&dir, "fig9a_densenet_original.csv", &trace::render::alloc_map_csv(&pm9.graph, &pm9.baseline))?;
        write_out(&dir, "fig9b_densenet_dmo.csv", &trace::render::alloc_map_csv(&pm9.graph, &pm9.dmo))?;
        println!(
            "fig9: densenet original {} vs DMO {}",
            report::fmt_bytes(pm9.baseline.peak()),
            report::fmt_bytes(pm9.dmo.peak())
        );
    }
    Ok(())
}

fn print_help() {
    println!(
        "dmo — Diagonal Memory Optimisation (paper reproduction)

USAGE: dmo <command> [args]   (flags accept both `--key value` and `--key=value`)

COMMANDS:
  models                      list the model zoo
  plan <model> [--baseline] [--map] [--verbose]
       [--strategy=sweep|eager|lazy|search] [--beam N] [--budget N]
       [--jobs N] [--rewrites pairs:N[,chains:D][,multi:K]]
       [--os-cache PATH] [--profile] [--trace-out PATH]
       [--export PATH] [--import PATH]
                              plan a model's arena (or reload an exported
                              plan artifact); print overlaps and O_s
                              cache hit/miss counters.
                              --strategy=search runs the memory-aware
                              execution-order search (never worse than
                              the eager/lazy sweep); --jobs parallelises
                              the sweep + search without changing the plan.
                              --rewrites additionally sweeps §II-A
                              rewrites: pairs:N bands single pair splits,
                              multi:K composes up to K independent pair
                              splits, chains:D bands whole chains of depth
                              ≤ D end-to-end — a rewritten plan wins only
                              when it strictly beats every unrewritten
                              layout, and then flows through --export /
                              validate / emit-c unchanged. (--splits=N is
                              a deprecated alias for --rewrites=pairs:N.)
                              --os-cache persists the O_s cache across
                              processes (cold runs start warm).
                              --profile executes the plan under the runtime
                              watermark verifier and prints observed vs
                              planned arena use per op; --trace-out writes
                              the session as Chrome trace-event JSON
  orders [<model>] [--beam N] [--budget N] [--jobs N]
         [--rewrites pairs:N[,chains:D][,multi:K]]
         [--os-cache PATH] [--out DIR]
                              eager vs lazy vs searched execution order:
                              DMO-overlapped peaks across the zoo, with
                              per-row O_s cache savings; --rewrites adds
                              a searched+rewritten session and columns
  validate <model> [--import PATH]
                              execute the DMO plan (or a loaded artifact),
                              prove bit-exact safety
  table2 [--out DIR]          O_s exact vs analytic (paper Table II)
  table3 [--out DIR]          memory savings, 11 models (paper Table III)
  figures [--fig N] [--out DIR]
                              regenerate paper figures 1,2,3,6,8,9
  fit [<model>] [--rewrites pairs:N[,chains:D][,multi:K]] [--budget-ms MS]
                              MCU deployment matrix (§IV), incl. emitted
                              flash image (weights + code estimate) and a
                              per-target latency estimate; --rewrites adds
                              a deploy(split) column showing targets
                              rescued by §II-A rewriting; --budget-ms also
                              rejects parts whose estimated latency misses
                              the budget ("no (latency)")
  emit-c <model> [--out PATH] [--seed N] [--embed-limit N] [--check]
         [--tune] [--tune-cache PATH] [--tune-iters N]
  emit-c --import plan.json [--out PATH] [--check]
                              emit a standalone C99 firmware unit from a
                              plan: static arena at the planned peak,
                              offsets verbatim, flash-resident weights,
                              overlap-aware fast kernels (CMSIS-NN-style
                              requantising int8 loops on i8 models);
                              --check compiles + runs it and diffs
                              against the interpreter bit-for-bit;
                              --tune times each kernel variant through the
                              same bit-exact harness and pins the winners
                              (cached across runs via --tune-cache)
  split <model> [--parts N] [--rewrites pairs:N,chains:D]
                              best pair-split and chain-banding report
                              (§II-A generalised); `dmo plan
                              --rewrites=pairs:N,chains:D` applies them
  trace-op <relu|matmul|dwconv|conv>
                              ASCII access-pattern trace (Fig 3)
  trace-run <model> [--trace-out PATH] [--seed N] [--baseline]
                              plan + execute under the observatory: planner
                              spans, per-op execution spans, and runtime
                              watermark verification (asserts observed peak
                              ≤ planned peak); writes Chrome trace-event
                              JSON loadable in Perfetto / chrome://tracing
  serve [--requests N] [--rate R] [--batch B] [--plan PATH] [--model M]
        [--jobs N] [--os-cache PATH]
                              end-to-end serving on the AOT'd model,
                              optionally starting from a plan artifact;
                              startup planning shares the process-wide
                              O_s cache (persisted via --os-cache so cold
                              replicas start warm) and runs on --jobs
                              workers
  serve --models a,b,c [--arenas K] [--workers N] [--queue C] [--mix W]
        [--rate R] [--requests N] [--reload-watch DIR]
                              fleet serving: N DMO-planned models in one
                              process, K pooled arenas per model (zero
                              per-request allocation at steady state),
                              per-model bounded queues drained fairly;
                              --rate>0 sheds on overload (open loop),
                              default blocks (closed loop);
                              --reload-watch hot-swaps <model>.plan.json
                              artifacts without dropping requests.
  serve --faults SPEC [--seed N] [--retries R] [--deadline-us D]
        [--breaker-k K] [--breaker-cooldown C]
                              chaos mode (implies fleet serving): inject a
                              deterministic seeded fault schedule — SPEC is
                              kind:count[@model],… with kinds panic,
                              corrupt-arena, corrupt-reload, stall, delay.
                              Panics are isolated per request, K consecutive
                              failures quarantine a model (circuit breaker),
                              watermark violations degrade the slot to a
                              safe plan, and the report proves
                              completed + shed + failed == requests.
                              Both serve modes take --metrics-out FILE
                              (Prometheus text snapshot; the fleet rewrites
                              it every 500 ms) and --trace-out FILE
                              (Chrome trace of the request lifecycle);
                              DMO_LOG=error|warn|info|debug|trace filters
                              runtime logging (default warn)"
    );
}
