//! Observability integration tests: structural validity of the Chrome
//! trace export (golden-free — asserts shape, not timings) and zoo-wide
//! runtime watermark verification (`observed peak ≤ planned peak`).

use dmo::interp;
use dmo::models;
use dmo::obs::trace;
use dmo::obs::watermark::ExecProfile;
use dmo::planner::Planner;
use dmo::util::json::Json;
use std::sync::Mutex;

/// The tracer is process-global; any test that executes a profiled run
/// while another has it enabled would leak spans into that test's drain.
/// Every test that runs `run_plan_profiled` holds this gate.
static TRACER_GATE: Mutex<()> = Mutex::new(());

fn profiled_run(name: &str, seed: u64) -> ExecProfile {
    let g = models::build(name).unwrap();
    let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
    let inputs: Vec<Vec<f32>> = g
        .inputs
        .iter()
        .map(|&t| interp::gen_input(&g, t, seed))
        .collect();
    let (_out, prof) = interp::run_plan_profiled(name, &g, &plan, &inputs, seed).unwrap();
    prof
}

fn assert_within(prof: &ExecProfile) {
    assert!(
        prof.within_plan(),
        "{}: observed peak {} exceeds planned {}",
        prof.model,
        prof.observed_peak,
        prof.planned_peak
    );
    assert!(prof.observed_peak > 0, "{}: nothing was traced", prof.model);
    assert!(
        prof.touched_bytes <= prof.arena_bytes,
        "{}: touched {} > arena {}",
        prof.model,
        prof.touched_bytes,
        prof.arena_bytes
    );
    assert!(!prof.ops.is_empty());
    for op in &prof.ops {
        assert!(
            op.high_water <= prof.planned_peak,
            "{} op {}: high water {} > planned peak {}",
            prof.model,
            op.name,
            op.high_water,
            prof.planned_peak
        );
    }
}

/// The `dmo trace-run tiny` pipeline, in-process: plan + profiled
/// execution under the tracer must export Chrome trace-event JSON that
/// re-parses, covers the planner and every plan op exactly once, and
/// nests execution spans inside the run span.
#[test]
fn trace_of_tiny_is_valid_chrome_trace_json() {
    let _gate = TRACER_GATE.lock().unwrap_or_else(|e| e.into_inner());
    trace::enable();
    let g = models::build("tiny").unwrap();
    let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
    let inputs: Vec<Vec<f32>> = g
        .inputs
        .iter()
        .map(|&t| interp::gen_input(&g, t, 42))
        .collect();
    let (_out, prof) = interp::run_plan_profiled("tiny", &g, &plan, &inputs, 42).unwrap();
    trace::disable();
    let events = trace::drain();
    assert!(trace::drain().is_empty(), "drain must empty the buffers");
    assert_within(&prof);

    // the export must survive a round-trip through the JSON parser
    let text = trace::export_chrome(&events).to_string();
    let doc = Json::parse(&text).unwrap();
    let rows = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("top-level traceEvents array");
    assert!(!rows.is_empty());

    // every event carries the Chrome trace-event required fields
    for r in rows {
        assert!(r.get("name").and_then(|v| v.as_str()).is_some());
        assert!(r.get("cat").and_then(|v| v.as_str()).is_some());
        assert!(r.get("ts").and_then(|v| v.as_f64()).is_some());
        assert!(r.get("pid").and_then(|v| v.as_f64()).is_some());
        assert!(r.get("tid").and_then(|v| v.as_f64()).is_some());
        match r.get("ph").and_then(|v| v.as_str()) {
            Some("X") => assert!(r.get("dur").and_then(|v| v.as_f64()).is_some()),
            Some("i") => assert_eq!(r.get("s").and_then(|v| v.as_str()), Some("t")),
            other => panic!("unexpected event phase {other:?}"),
        }
    }

    let spans_named = |name: &str| -> Vec<(u64, u64)> {
        rows.iter()
            .filter(|r| r.get("name").and_then(|v| v.as_str()) == Some(name))
            .map(|r| {
                let ts = r.get("ts").unwrap().as_f64().unwrap() as u64;
                let dur = r.get("dur").unwrap().as_f64().unwrap() as u64;
                (ts, ts + dur)
            })
            .collect()
    };

    // planner and run spans appear exactly once
    assert_eq!(spans_named("plan:tiny").len(), 1, "one planner span");
    let runs = spans_named("run:tiny");
    assert_eq!(runs.len(), 1, "one run span");
    let (run_start, run_end) = runs[0];

    // every plan op's exec span appears exactly once, inside the run span
    let pg = plan.graph_for(&g);
    assert!(!plan.order.0.is_empty());
    for &opid in &plan.order.0 {
        let name = format!("exec:{}", pg.op(opid).name);
        let execs = spans_named(&name);
        assert_eq!(execs.len(), 1, "span {name} must appear exactly once");
        let (s, e) = execs[0];
        assert!(
            run_start <= s && e <= run_end,
            "{name} [{s},{e}] outside run [{run_start},{run_end}]"
        );
    }
}

/// Runtime watermark verification over a zoo sample, including the
/// paper's deployable MobileNet at full size. The full zoo runs under
/// `--ignored` (and in CI's release-mode pass).
#[test]
fn observed_peak_within_plan_zoo_sample() {
    let _gate = TRACER_GATE.lock().unwrap_or_else(|e| e.into_inner());
    for name in ["tiny", "tiny_int8", "tiny_wide", "mobilenet_v1_0.25_128_int8"] {
        assert_within(&profiled_run(name, 7));
    }
}

#[test]
#[ignore = "slow: profiled execution of every zoo model (run with --ignored)"]
fn observed_peak_within_plan_full_zoo() {
    let _gate = TRACER_GATE.lock().unwrap_or_else(|e| e.into_inner());
    for name in models::all_names() {
        assert_within(&profiled_run(name, 11));
    }
}
