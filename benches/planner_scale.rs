//! Bench: planner scaling — memoised `O_s` cache + parallel sweep.
//!
//! Two axes, recorded to `BENCH_planner_scale.json` (uploaded by CI
//! next to `BENCH_order_search.json` as the repo's perf trajectory;
//! summarised in EXPERIMENTS.md §Perf):
//!
//! 1. **Cold vs warm `OsTable` builds.** Every zoo model is measured
//!    with the exact algorithmic engine; a subset is also measured with
//!    the bottom-up engine, which *executes* each kernel on dummy data
//!    (§III-B, the paper's Valgrind substitute) and is therefore the
//!    engine the cache amortises hardest. "Cold" is a fresh build
//!    (which already dedupes repeated signatures within the model);
//!    "warm" rebuilds the same table through a primed shared
//!    [`OsCache`]. The bench asserts the headline property: warm
//!    bottom-up builds are ≥ 5× faster than cold on at least one zoo
//!    model.
//! 2. **Serial vs parallel candidate sweep.** The default multi-
//!    candidate sweep (eager + lazy × four heuristics) at `.jobs(1)` vs
//!    `.jobs(all cores)`; plans are asserted byte-identical peaks and
//!    at least one model must show a parallel wall-clock win.

use dmo::models;
use dmo::overlap::{Method, OsCache};
use dmo::planner::{OsTable, Planner};
use dmo::util::bench::{fmt_dur, time};
use dmo::util::json::{num, obj, s, Json};
use std::sync::Arc;
use std::time::Instant;

/// Zoo models the bottom-up cold/warm comparison runs on — moderate
/// graphs, so the bench stays minutes not hours (the engine executes
/// every distinct kernel signature once per cold build).
const BOTTOM_UP_MODELS: [&str; 3] = [
    "mobilenet_v1_0.25_128_int8",
    "mobilenet_v1_0.25_224",
    "mobilenet_v2_0.35_224",
];

fn ms(d: std::time::Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// Cold build, then a warm rebuild through a primed cache. Returns
/// (cold, warm, speedup, hits, misses) and asserts table equality.
fn cold_vs_warm(
    g: &dmo::ir::graph::Graph,
    method: Method,
) -> (std::time::Duration, std::time::Duration, f64, usize, usize) {
    let t0 = Instant::now();
    let cold_table = OsTable::build(g, method);
    let cold = t0.elapsed();

    let cache = Arc::new(OsCache::new());
    let primed = OsTable::build_cached(g, method, &cache);
    let t0 = Instant::now();
    let warm_table = OsTable::build_cached(g, method, &cache);
    let warm = t0.elapsed();

    assert_eq!(cold_table.per_op, primed.per_op, "{}: cache changed O_s", g.name);
    assert_eq!(cold_table.per_op, warm_table.per_op, "{}: warm build diverged", g.name);
    let st = cache.stats();
    let speedup = cold.as_secs_f64() / warm.as_secs_f64().max(1e-9);
    (cold, warm, speedup, st.hits, st.misses)
}

fn main() {
    let jobs = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("=== planner scale: memoised O_s cache + parallel sweep (jobs = {jobs}) ===\n");

    println!(
        "{:32} {:>12} {:>12} {:>8} {:>12} {:>12} {:>8}",
        "model", "alg cold", "alg warm", "hit/miss", "sweep j=1", "sweep j=N", "speedup"
    );

    let mut entries: Vec<Json> = Vec::new();
    let mut parallel_wins = 0usize;
    for name in models::table3_names() {
        let g = models::build(name).unwrap();

        let (alg_cold, alg_warm, _alg_speedup, hits, misses) =
            cold_vs_warm(&g, Method::Algorithmic);

        let m_serial = time("sweep jobs=1", 2, || {
            std::hint::black_box(Planner::for_graph(&g).dmo(true).jobs(1).plan().unwrap());
        });
        let m_parallel = time("sweep jobs=N", 2, || {
            std::hint::black_box(Planner::for_graph(&g).dmo(true).jobs(jobs).plan().unwrap());
        });
        // the knob must never change the result…
        let p1 = Planner::for_graph(&g).dmo(true).jobs(1).plan().unwrap();
        let pn = Planner::for_graph(&g).dmo(true).jobs(jobs).plan().unwrap();
        assert_eq!(p1.peak(), pn.peak(), "{name}: jobs changed the plan");
        // …only the wall clock
        if m_parallel.median < m_serial.median {
            parallel_wins += 1;
        }
        let sweep_speedup =
            m_serial.median.as_secs_f64() / m_parallel.median.as_secs_f64().max(1e-9);

        println!(
            "{:32} {:>12} {:>12} {:>8} {:>12} {:>12} {:>7.2}x",
            name,
            fmt_dur(alg_cold),
            fmt_dur(alg_warm),
            format!("{hits}/{misses}"),
            fmt_dur(m_serial.median),
            fmt_dur(m_parallel.median),
            sweep_speedup
        );

        entries.push(obj(vec![
            ("model", s(name)),
            ("ops", num(g.ops.len())),
            ("alg_cold_ms", Json::Num(ms(alg_cold))),
            ("alg_warm_ms", Json::Num(ms(alg_warm))),
            ("cache_hits", num(hits)),
            ("cache_misses", num(misses)),
            ("sweep_serial_ms", Json::Num(ms(m_serial.median))),
            ("sweep_parallel_ms", Json::Num(ms(m_parallel.median))),
            ("sweep_speedup", Json::Num(sweep_speedup)),
        ]));
    }

    println!("\n--- bottom-up engine (executes kernels; the cache's best case) ---\n");
    println!(
        "{:32} {:>12} {:>12} {:>10}",
        "model", "cold", "warm", "speedup"
    );
    let mut bottom_up: Vec<Json> = Vec::new();
    let mut best_warm_speedup = 0.0f64;
    for name in BOTTOM_UP_MODELS {
        let g = models::build(name).unwrap();
        let (cold, warm, speedup, _, _) = cold_vs_warm(&g, Method::BottomUp);
        best_warm_speedup = best_warm_speedup.max(speedup);
        println!(
            "{:32} {:>12} {:>12} {:>9.1}x",
            name,
            fmt_dur(cold),
            fmt_dur(warm),
            speedup
        );
        bottom_up.push(obj(vec![
            ("model", s(name)),
            ("cold_ms", Json::Num(ms(cold))),
            ("warm_ms", Json::Num(ms(warm))),
            ("warm_speedup", Json::Num(speedup)),
        ]));
    }

    let doc = obj(vec![
        ("bench", s("planner_scale")),
        ("jobs", num(jobs)),
        ("models", Json::Arr(entries)),
        ("bottom_up", Json::Arr(bottom_up)),
    ]);
    let path = "BENCH_planner_scale.json";
    std::fs::write(path, doc.to_string()).unwrap();
    println!("\nwrote {path}");

    assert!(
        best_warm_speedup >= 5.0,
        "warm bottom-up OsTable builds must be ≥5× faster than cold on at \
         least one zoo model, best was {best_warm_speedup:.1}×"
    );
    assert!(
        jobs < 2 || parallel_wins > 0,
        "with {jobs} cores the parallel sweep must beat serial on at least one model"
    );
    println!(
        "warm bottom-up speedup {best_warm_speedup:.1}×; parallel sweep won on \
         {parallel_wins}/11 models"
    );
}
