//! Memory planning: serialisation → scopes → allocation (→ validation).
//!
//! [`plan_graph`] reproduces the paper's §IV methodology: serialise the
//! graph with both eager and lazy strategies, allocate forwards and
//! backwards with the modified heap allocator, and keep the lowest-peak
//! layout. With DMO enabled the allocator may additionally overlap each
//! op's dying input with its output by up to `O_s`.

pub mod alloc;
pub mod order;
pub mod removal;
pub mod scope;
pub mod split;

pub use alloc::{allocate, check, Allocation, AppliedOverlap, Direction, Heuristic, OsTable, DIRECTIONS, HEURISTICS};
pub use order::{serialise, ExecOrder, Strategy, STRATEGIES};
pub use scope::{analyse, Scope, Scopes};

use crate::ir::graph::Graph;
use crate::overlap::Method;

/// Planning configuration.
#[derive(Debug, Clone, Copy)]
pub struct PlanOptions {
    /// Apply diagonal memory optimisation (overlap relaxation).
    pub dmo: bool,
    /// Engine used for `O_s` when `dmo`.
    ///
    /// Default: the exact algorithmic method. The paper planned with the
    /// analytic lower bound (§II-D) and reports a <2 % penalty (§III-E);
    /// under our allocator the penalty can be structural — e.g. the
    /// stride-2 depthwise output of MobileNet nests inside its input only
    /// when `O_s` equals the exact output size, and the analytic bound's
    /// few-hundred-byte shortfall then costs a whole buffer of packing.
    /// `benches/os_methods.rs` quantifies this as an ablation; see
    /// EXPERIMENTS.md §Deviations.
    pub method: Method,
}

impl PlanOptions {
    pub fn baseline() -> Self {
        PlanOptions {
            dmo: false,
            method: Method::Algorithmic,
        }
    }

    pub fn dmo() -> Self {
        PlanOptions {
            dmo: true,
            method: Method::Algorithmic,
        }
    }

    /// DMO planning with the paper's analytic `O_s` (ablation).
    pub fn dmo_analytic() -> Self {
        PlanOptions {
            dmo: true,
            method: Method::Analytic,
        }
    }
}

/// A complete, validated memory plan.
#[derive(Debug, Clone)]
pub struct Plan {
    pub order: ExecOrder,
    pub scopes: Scopes,
    pub alloc: Allocation,
    pub strategy: Strategy,
    pub heuristic: Heuristic,
    /// The `O_s` table the layout was checked against.
    pub os: OsTable,
}

impl Plan {
    /// Arena bytes required.
    pub fn peak(&self) -> usize {
        self.alloc.peak
    }
}

/// Plan `graph`: sweep strategy × direction, return the lowest-peak valid
/// layout (§IV: "serialised using both an eager and lazy execution
/// strategy with the lowest peak memory figure being taken").
pub fn plan_graph(graph: &Graph, opts: PlanOptions) -> Plan {
    // O_s depends only on op geometry, never on serialisation order —
    // build the table once for the whole sweep (perf pass, §Perf).
    let os = if opts.dmo {
        OsTable::build(graph, opts.method)
    } else {
        OsTable::disabled(graph)
    };
    let mut best: Option<Plan> = None;
    for strat in STRATEGIES {
        let ord = serialise(graph, strat);
        let scopes = analyse(graph, &ord);
        for h in HEURISTICS {
            let a = allocate(graph, &scopes, &os, h);
            debug_assert!(check(graph, &scopes, &os, &a).is_ok());
            if best.as_ref().map_or(true, |b| a.peak < b.alloc.peak) {
                best = Some(Plan {
                    order: ord.clone(),
                    scopes: scopes.clone(),
                    alloc: a,
                    strategy: strat,
                    heuristic: h,
                    os: os.clone(),
                });
            }
        }
    }
    best.expect("graph has no tensors to plan")
}

/// Original-vs-DMO comparison for one graph — one row of Table III.
#[derive(Debug, Clone)]
pub struct SavingRow {
    pub model: String,
    pub original: usize,
    pub optimised: usize,
}

impl SavingRow {
    pub fn saving_pct(&self) -> f64 {
        if self.original == 0 {
            return 0.0;
        }
        100.0 * (self.original - self.optimised) as f64 / self.original as f64
    }
}

/// Compute both plans and the Table-III row for `graph`.
pub fn saving_row(graph: &Graph) -> (Plan, Plan, SavingRow) {
    let base = plan_graph(graph, PlanOptions::baseline());
    let dmo = plan_graph(graph, PlanOptions::dmo());
    let row = SavingRow {
        model: graph.name.clone(),
        original: base.peak(),
        optimised: dmo.peak().min(base.peak()),
    };
    (base, dmo, row)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Padding};
    use crate::ir::{DType, GraphBuilder, Shape};

    /// The motivating example from §I: MobileNet v1 0.25 128 (8-bit)
    /// head — conv s2 to 8ch, dw s1, 1x1 conv to 16ch. Peak pair is
    /// dw_out (32 KB) + pw_out (64 KB) = 96 KB; DMO overlaps them to
    /// ~64 KB.
    fn mobilenet_head_i8() -> Graph {
        let mut b = GraphBuilder::new("mnv1-head", DType::I8);
        let x = b.input(Shape::hwc(128, 128, 3));
        let c1 = b.conv2d(x, 8, (3, 3), (2, 2), Padding::Same, Activation::Relu6);
        let d1 = b.dwconv2d(c1, (3, 3), (1, 1), Padding::Same, Activation::Relu6);
        let p1 = b.conv2d(d1, 16, (1, 1), (1, 1), Padding::Same, Activation::Relu6);
        b.finish(&[p1])
    }

    #[test]
    fn paper_intro_example_96kb_to_64kb() {
        let g = mobilenet_head_i8();
        let (_base, _dmo, row) = saving_row(&g);
        assert_eq!(row.original, 96 * 1024, "original peak must be 96 KB");
        // optimised: 64 KB + a few bytes (O_s is IB minus (D_in−1) elems)
        assert!(row.optimised >= 64 * 1024);
        assert!(row.optimised < 64 * 1024 + 64, "got {}", row.optimised);
        // paper reports 33.1 % for the full model; the head alone matches
        assert!((row.saving_pct() - 33.3).abs() < 0.5, "saving {}", row.saving_pct());
    }

    #[test]
    fn dmo_never_worse_than_baseline() {
        let g = mobilenet_head_i8();
        let base = plan_graph(&g, PlanOptions::baseline());
        let dmo = plan_graph(&g, PlanOptions::dmo());
        assert!(dmo.peak() <= base.peak());
    }

    #[test]
    fn plans_are_checkable() {
        let g = mobilenet_head_i8();
        for opts in [PlanOptions::baseline(), PlanOptions::dmo()] {
            let p = plan_graph(&g, opts);
            check(&g, &p.scopes, &p.os, &p.alloc).unwrap();
        }
    }
}
