//! Serving metrics: latency distribution, throughput, batch efficiency.

use std::time::Duration;

/// Latency percentiles over a completed run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl LatencyStats {
    /// Compute from raw samples (any order).
    pub fn from_samples(samples: &[Duration]) -> LatencyStats {
        if samples.is_empty() {
            return LatencyStats {
                count: 0,
                mean_us: 0.0,
                p50_us: 0.0,
                p95_us: 0.0,
                p99_us: 0.0,
                max_us: 0.0,
            };
        }
        let mut us: Vec<f64> = samples.iter().map(|d| d.as_secs_f64() * 1e6).collect();
        us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let idx = ((us.len() as f64 - 1.0) * p).round() as usize;
            us[idx]
        };
        LatencyStats {
            count: us.len(),
            mean_us: us.iter().sum::<f64>() / us.len() as f64,
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            max_us: *us.last().unwrap(),
        }
    }
}

/// Accumulated run metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub latencies: Vec<Duration>,
    pub batches: Vec<usize>,
    pub padded: Vec<usize>,
    pub shed: usize,
}

impl Metrics {
    pub fn record(&mut self, latency: Duration) {
        self.latencies.push(latency);
    }

    /// Count one shed (rejected-at-admission) request. `Metrics` is the
    /// single source of truth for shedding — reports read it from here.
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    pub fn record_batch(&mut self, actual: usize, padded: usize) {
        self.batches.push(actual);
        self.padded.push(padded);
    }

    pub fn latency(&self) -> LatencyStats {
        LatencyStats::from_samples(&self.latencies)
    }

    /// Mean requests per executed batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        self.batches.iter().sum::<usize>() as f64 / self.batches.len() as f64
    }

    /// Fraction of executed lanes that carried real requests.
    pub fn batch_efficiency(&self) -> f64 {
        let real: usize = self.batches.iter().sum();
        let lanes: usize = self.padded.iter().sum();
        if lanes == 0 {
            return 1.0;
        }
        real as f64 / lanes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let s = LatencyStats::from_samples(&samples);
        assert_eq!(s.count, 100);
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us && s.p99_us <= s.max_us);
        assert!((s.mean_us - 50.5).abs() < 0.6);
        assert_eq!(s.max_us, 100.0);
    }

    #[test]
    fn empty_is_zero() {
        let s = LatencyStats::from_samples(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.max_us, 0.0);
    }

    #[test]
    fn batch_efficiency() {
        let mut m = Metrics::default();
        m.record_batch(3, 4);
        m.record_batch(4, 4);
        assert!((m.batch_efficiency() - 7.0 / 8.0).abs() < 1e-9);
        assert!((m.mean_batch() - 3.5).abs() < 1e-9);
    }
}
