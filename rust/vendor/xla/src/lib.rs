//! Stub of the PJRT/XLA binding surface consumed by `dmo::runtime`.
//!
//! The offline build environment does not ship the real `xla` crate (a
//! native binding with a large dependency closure), so this stub keeps
//! the runtime layer compiling everywhere. Every entry point that would
//! touch a device returns [`Error::Unavailable`] at run time; the serving
//! stack surfaces that as a clean "backend unavailable" failure instead
//! of a link error. Integration tests gate on the AOT artifacts existing
//! and skip before reaching these calls.
//!
//! To serve real traffic, point the `xla` path dependency in the root
//! `Cargo.toml` at an actual PJRT binding with the same API:
//! `PjRtClient::cpu`, `HloModuleProto::from_text_file`,
//! `XlaComputation::from_proto`, `PjRtClient::compile`,
//! `PjRtLoadedExecutable::execute`, and the `Literal` conversions.

use std::fmt;

/// Errors surfaced by the stub: always [`Error::Unavailable`].
#[derive(Debug, Clone)]
pub enum Error {
    /// The build carries no real PJRT backend.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "XLA/PJRT backend unavailable in this build (stubbed `{what}`); \
                 link a real `xla` binding to execute compiled models"
            ),
        }
    }
}

impl std::error::Error for Error {}

/// Host-side tensor literal.
pub struct Literal;

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::Unavailable("Literal::reshape"))
    }

    /// Unwrap a 1-tuple result literal.
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(Error::Unavailable("Literal::to_tuple1"))
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Synchronously copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; returns per-device, per-output
    /// buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A PJRT client bound to one platform.
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    /// Platform name for diagnostics.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}
