//! Content-addressed memoisation of [`compute_os`](super::compute_os).
//!
//! `O_s` depends only on an op's *geometry* — its kind (with all static
//! parameters), input/output shapes, element type — and on the engine
//! used to compute it. It does **not** depend on which graph the op sits
//! in, on tensor identities, or on the execution order. Zoo models
//! repeat the same block shapes dozens of times (every ResNet stage,
//! every MobileNet depthwise/pointwise pair), and a planning sweep
//! re-derives the very same table per session, so memoising on the
//! canonical [`OpSignature`] collapses all of that to one analysis per
//! distinct signature.
//!
//! The pay-off is largest for [`Method::BottomUp`], which *executes*
//! the kernel on dummy data with an event probe attached (§III-B, the
//! paper's Valgrind substitute) — milliseconds to seconds per op —
//! but even the exact algorithmic engine walks `O(Steps)` per call.
//!
//! [`OsCache`] is interior-mutable and thread-safe: wrap it in an
//! [`Arc`] and share one instance across
//! [`Planner`](crate::planner::Planner) sessions, `dmo serve`
//! processes' planning step, and the `dmo orders` report
//! ([`OsCache::process_shared`] hands out the process-wide instance).
//! Parallel sweep workers hit the same cache; the value is computed
//! outside the lock so a slow bottom-up trace never serialises other
//! lookups. Hit/miss counters make the savings observable
//! ([`OsCache::stats`]), not just benchmarkable
//! (`benches/planner_scale.rs`, EXPERIMENTS.md §Perf).

use super::{compute_os, Method, SafeOverlap};
use crate::ir::op::OpKind;
use crate::ir::shape::Shape;
use crate::ir::DType;
use crate::util::json::{num, obj, s, Json};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Canonical identity of one `compute_os` call: everything the result
/// depends on, and nothing else. Two ops anywhere in any graph with
/// equal signatures have byte-identical `O_s` vectors.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct OpSignature {
    /// Op kind including all static parameters (kernel, stride,
    /// dilation, padding, fused activation, …).
    pub kind: OpKind,
    /// Activation input shapes, in input order.
    pub in_shapes: Vec<Shape>,
    /// Output shape.
    pub out_shape: Shape,
    /// Element type (`O_s` is reported in bytes — multiples of `T_s`).
    pub dtype: DType,
    /// Engine the overlap was computed with; the three engines may
    /// legitimately disagree (the analytic bound under-estimates by
    /// design, §III-E), so they never share entries.
    pub method: Method,
}

impl OpSignature {
    /// Build the signature for one `compute_os` call.
    pub fn of(
        method: Method,
        kind: &OpKind,
        in_shapes: &[&Shape],
        out_shape: &Shape,
        dtype: DType,
    ) -> OpSignature {
        OpSignature {
            kind: kind.clone(),
            in_shapes: in_shapes.iter().map(|s| (*s).clone()).collect(),
            out_shape: out_shape.clone(),
            dtype,
            method,
        }
    }
}

/// Lookup counters of an [`OsCache`] — cheap, lock-free reads.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: usize,
    /// Lookups that had to run the engine (one per distinct signature).
    pub misses: usize,
}

impl CacheStats {
    /// Total lookups served.
    pub fn lookups(&self) -> usize {
        self.hits + self.misses
    }

    /// Fraction of lookups answered without running an engine.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups() as f64
    }
}

/// Thread-safe, content-addressed `compute_os` memo table.
///
/// ```
/// use dmo::ir::op::{OpKind, UnaryKind};
/// use dmo::ir::{DType, Shape};
/// use dmo::overlap::{compute_os, Method, OsCache};
///
/// let cache = OsCache::new();
/// let shape = Shape::hwc(8, 8, 4);
/// let kind = OpKind::Unary(UnaryKind::Relu);
/// let direct = compute_os(Method::Algorithmic, &kind, &[&shape], &shape, DType::F32);
/// let cached = cache.get_or_compute(Method::Algorithmic, &kind, &[&shape], &shape, DType::F32);
/// assert_eq!(direct, cached);
/// let warm = cache.get_or_compute(Method::Algorithmic, &kind, &[&shape], &shape, DType::F32);
/// assert_eq!(direct, warm);
/// assert_eq!((cache.stats().hits, cache.stats().misses), (1, 1));
/// ```
#[derive(Debug, Default)]
pub struct OsCache {
    map: Mutex<HashMap<OpSignature, SafeOverlap>>,
    /// Entries loaded from a persisted cache file, keyed by signature
    /// hash (the file cannot reconstruct full signatures, and does not
    /// need to: lookups hash the query). Promoted into `map` on first
    /// hit so subsequent lookups skip the second probe.
    disk: Mutex<HashMap<u64, SafeOverlap>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

/// 64-bit FNV-1a over a signature's canonical debug form — the content
/// address persisted cache files use. Stable within one build of this
/// crate; [`OsCache::DISK_VERSION`] is bumped whenever the signature
/// types change shape, so a stale file degrades to a cold start rather
/// than wrong lookups.
fn sig_hash(sig: &OpSignature) -> u64 {
    let mut h = crate::util::fnv::Fnv::new();
    h.bytes(format!("{sig:?}").as_bytes());
    h.finish()
}

impl OsCache {
    /// An empty cache.
    pub fn new() -> OsCache {
        OsCache::default()
    }

    /// The process-wide shared cache. `dmo orders` rows, `dmo serve`
    /// startup planning and any other in-process consumer that wants
    /// cross-session reuse without threading an [`Arc`] around all use
    /// this one instance.
    pub fn process_shared() -> Arc<OsCache> {
        static SHARED: OnceLock<Arc<OsCache>> = OnceLock::new();
        SHARED.get_or_init(|| Arc::new(OsCache::new())).clone()
    }

    /// `compute_os`, memoised: return the cached overlap for this
    /// signature or run `method`'s engine exactly once and remember the
    /// result.
    ///
    /// The engine runs *outside* the map lock — a multi-second
    /// bottom-up trace must not serialise unrelated lookups from
    /// parallel sweep workers. Two threads racing on the same cold
    /// signature may both compute it (deterministically equal values;
    /// the first insert wins), which trades a rare duplicated analysis
    /// for never blocking readers.
    pub fn get_or_compute(
        &self,
        method: Method,
        kind: &OpKind,
        in_shapes: &[&Shape],
        out_shape: &Shape,
        dtype: DType,
    ) -> SafeOverlap {
        let sig = OpSignature::of(method, kind, in_shapes, out_shape, dtype);
        if let Some(hit) = self.lock().get(&sig).cloned() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit;
        }
        // a persisted entry counts as a hit — the engine never runs.
        // The disk map keys a 64-bit content hash, not the full
        // signature; reject hits whose arity cannot belong to this op
        // (the residual same-arity collision risk is documented on
        // `sig_hash` and accepted as astronomically unlikely).
        let from_disk = self
            .disk_lock()
            .get(&sig_hash(&sig))
            .filter(|hit| hit.per_input.len() == in_shapes.len())
            .cloned();
        if let Some(hit) = from_disk {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.lock().entry(sig).or_insert_with(|| hit.clone());
            return hit;
        }
        let value = compute_os(method, kind, in_shapes, out_shape, dtype);
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.lock().entry(sig).or_insert_with(|| value.clone());
        value
    }

    /// Current hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct signatures held.
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }

    /// Drop every entry (including disk-loaded ones) and reset the
    /// counters.
    pub fn clear(&self) {
        self.lock().clear();
        self.disk_lock().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    /// File-format marker of a persisted cache.
    pub const DISK_KIND: &'static str = "dmo-os-cache";
    /// File-format version. Bump when [`OpSignature`]'s debug form (the
    /// content address) changes shape — old files then load as empty
    /// rather than aliasing wrong entries.
    pub const DISK_VERSION: u64 = 1;
    /// Revision of the `O_s` engines themselves, recorded in every
    /// persisted cache and checked on load. A persisted entry bypasses
    /// the engine *and* the planner's safety checker validates against
    /// the same cached table, so serving values computed by an older,
    /// since-changed engine would be silently unsafe across a build
    /// boundary. **Bump this whenever any change can alter a
    /// [`compute_os`] result** (engine math, access streams, kernel
    /// sweep orders) — stale files then degrade to a cold start.
    pub const ENGINE_REV: u64 = 1;

    /// Load a cache persisted by [`OsCache::save`] and merge its
    /// entries (existing in-memory entries win). Returns the number of
    /// entries loaded. The file is versioned and content-hashed like a
    /// [`crate::planner::PlanArtifact`]: a wrong kind, version or hash
    /// is an error — callers typically warn and start cold.
    pub fn load(&self, path: &Path) -> anyhow::Result<usize> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let v = Json::parse(&text)?;
        anyhow::ensure!(
            v.get("kind").and_then(|k| k.as_str()) == Some(Self::DISK_KIND),
            "{} is not an O_s cache file",
            path.display()
        );
        let version = v.get("version").and_then(|x| x.as_usize()).unwrap_or(0);
        anyhow::ensure!(
            version as u64 == Self::DISK_VERSION,
            "unsupported O_s cache version {version} (this build reads {})",
            Self::DISK_VERSION
        );
        let engine = v.get("engine").and_then(|x| x.as_usize()).unwrap_or(0);
        anyhow::ensure!(
            engine as u64 == Self::ENGINE_REV,
            "O_s cache was computed by engine revision {engine}; this build is revision {} — \
             refusing stale overlap values",
            Self::ENGINE_REV
        );
        let entries = v
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow::anyhow!("O_s cache file has no entries array"))?;
        let mut parsed: Vec<(u64, Vec<usize>)> = Vec::with_capacity(entries.len());
        for e in entries {
            let sig = e
                .get("sig")
                .and_then(|x| x.as_str())
                .and_then(|x| u64::from_str_radix(x, 16).ok())
                .ok_or_else(|| anyhow::anyhow!("bad `sig` in O_s cache entry"))?;
            let os = e
                .get("os")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow::anyhow!("bad `os` in O_s cache entry"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow::anyhow!("non-numeric O_s")))
                .collect::<anyhow::Result<Vec<usize>>>()?;
            parsed.push((sig, os));
        }
        let recorded = v
            .get("hash")
            .and_then(|x| x.as_str())
            .and_then(|x| u64::from_str_radix(x, 16).ok())
            .ok_or_else(|| anyhow::anyhow!("O_s cache file has no content hash"))?;
        anyhow::ensure!(
            entries_hash(&parsed) == recorded,
            "O_s cache content does not match its recorded hash"
        );
        let n = parsed.len();
        let mut disk = self.disk_lock();
        for (sig, os) in parsed {
            disk.entry(sig).or_insert(SafeOverlap { per_input: os });
        }
        Ok(n)
    }

    /// Persist every entry (computed and previously loaded) to `path`,
    /// atomically (tmp + rename, like `PlanArtifact::save`). Returns
    /// the number of entries written. Warm caches accumulate: saving
    /// after a run writes the union of what was loaded and what this
    /// process computed.
    pub fn save(&self, path: &Path) -> anyhow::Result<usize> {
        let mut union: HashMap<u64, Vec<usize>> = HashMap::new();
        for (sig, os) in self.disk_lock().iter() {
            union.insert(*sig, os.per_input.clone());
        }
        for (sig, os) in self.lock().iter() {
            union.insert(sig_hash(sig), os.per_input.clone());
        }
        let mut entries: Vec<(u64, Vec<usize>)> = union.into_iter().collect();
        entries.sort();
        let hash = entries_hash(&entries);
        let doc = obj(vec![
            ("kind", s(Self::DISK_KIND)),
            ("version", num(Self::DISK_VERSION as usize)),
            ("engine", num(Self::ENGINE_REV as usize)),
            ("hash", s(&format!("{hash:016x}"))),
            (
                "entries",
                Json::Arr(
                    entries
                        .iter()
                        .map(|(sig, os)| {
                            obj(vec![
                                ("sig", s(&format!("{sig:016x}"))),
                                ("os", Json::Arr(os.iter().map(|&v| num(v)).collect())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)
                .map_err(|e| anyhow::anyhow!("creating {}: {e}", parent.display()))?;
        }
        let file_name = path
            .file_name()
            .ok_or_else(|| anyhow::anyhow!("{} has no file name", path.display()))?;
        // pid + per-process counter, as PlanArtifact::save: concurrent
        // savers never rename each other's half-written document
        static SAVE_COUNTER: AtomicUsize = AtomicUsize::new(0);
        let tmp = path.with_file_name(format!(
            "{}.tmp.{}.{}",
            file_name.to_string_lossy(),
            std::process::id(),
            SAVE_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, doc.to_string())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path).map_err(|e| {
            let _ = std::fs::remove_file(&tmp);
            anyhow::anyhow!("renaming {} into place: {e}", path.display())
        })?;
        Ok(entries.len())
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<OpSignature, SafeOverlap>> {
        // a panic while holding the lock can only happen inside std
        // HashMap ops; treat poisoning as unrecoverable
        self.map.lock().expect("O_s cache lock poisoned")
    }

    fn disk_lock(&self) -> std::sync::MutexGuard<'_, HashMap<u64, SafeOverlap>> {
        self.disk.lock().expect("O_s disk cache lock poisoned")
    }
}

/// Content hash of a persisted cache's entry list (order-sensitive —
/// the writer sorts by signature hash).
fn entries_hash(entries: &[(u64, Vec<usize>)]) -> u64 {
    let mut h = crate::util::fnv::Fnv::new();
    h.word(entries.len());
    for (sig, os) in entries {
        h.word(*sig as usize);
        h.word(os.len());
        for &v in os {
            h.word(v);
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Conv2DParams, Padding, UnaryKind};

    fn conv(kernel: (usize, usize), stride: (usize, usize)) -> OpKind {
        OpKind::Conv2D(Conv2DParams {
            kernel,
            stride,
            dilation: (1, 1),
            padding: Padding::Same,
            out_channels: 4,
            act: Activation::None,
        })
    }

    #[test]
    fn distinct_signatures_do_not_alias() {
        let cache = OsCache::new();
        let x = Shape::hwc(8, 8, 3);
        let out = crate::ops::infer_output(&conv((3, 3), (1, 1)), &[&x]).unwrap();
        let a = cache.get_or_compute(Method::Algorithmic, &conv((3, 3), (1, 1)), &[&x], &out, DType::F32);
        // same geometry, different stride ⇒ different signature + value
        let out2 = crate::ops::infer_output(&conv((3, 3), (2, 2)), &[&x]).unwrap();
        let b = cache.get_or_compute(Method::Algorithmic, &conv((3, 3), (2, 2)), &[&x], &out2, DType::F32);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().misses, 2);
        assert_eq!(
            a,
            compute_os(Method::Algorithmic, &conv((3, 3), (1, 1)), &[&x], &out, DType::F32)
        );
        assert_eq!(
            b,
            compute_os(Method::Algorithmic, &conv((3, 3), (2, 2)), &[&x], &out2, DType::F32)
        );
    }

    #[test]
    fn methods_never_share_entries() {
        let cache = OsCache::new();
        let x = Shape::hwc(6, 6, 2);
        let k = OpKind::Unary(UnaryKind::Relu);
        let exact = cache.get_or_compute(Method::Algorithmic, &k, &[&x], &x, DType::F32);
        let analytic = cache.get_or_compute(Method::Analytic, &k, &[&x], &x, DType::F32);
        assert_eq!(cache.stats().misses, 2, "same geometry, two engines, two entries");
        assert_eq!(exact, compute_os(Method::Algorithmic, &k, &[&x], &x, DType::F32));
        assert_eq!(analytic, compute_os(Method::Analytic, &k, &[&x], &x, DType::F32));
    }

    #[test]
    fn concurrent_lookups_agree_and_count() {
        let cache = Arc::new(OsCache::new());
        let x = Shape::hwc(10, 10, 3);
        let kind = conv((3, 3), (1, 1));
        let out = crate::ops::infer_output(&kind, &[&x]).unwrap();
        let expect = compute_os(Method::Algorithmic, &kind, &[&x], &out, DType::F32);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let cache = &cache;
                let (kind, x, out, expect) = (&kind, &x, &out, &expect);
                scope.spawn(move || {
                    for _ in 0..8 {
                        let got =
                            cache.get_or_compute(Method::Algorithmic, kind, &[x], out, DType::F32);
                        assert_eq!(&got, expect);
                    }
                });
            }
        });
        let st = cache.stats();
        assert_eq!(st.lookups(), 32);
        assert_eq!(cache.len(), 1, "one signature no matter how many racers");
        assert!(st.hits >= 28, "at most one duplicated compute per racer: {st:?}");
    }

    #[test]
    fn clear_resets_contents_and_counters() {
        let cache = OsCache::new();
        let x = Shape::hwc(4, 4, 2);
        let k = OpKind::Unary(UnaryKind::Relu6);
        cache.get_or_compute(Method::Analytic, &k, &[&x], &x, DType::I8);
        cache.get_or_compute(Method::Analytic, &k, &[&x], &x, DType::I8);
        assert!(!cache.is_empty());
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.stats(), CacheStats::default());
    }

    #[test]
    fn disk_round_trip_warms_a_cold_process() {
        let dir = std::env::temp_dir().join(format!("dmo-oscache-{}", std::process::id()));
        let path = dir.join("os_cache.json");
        let warm = OsCache::new();
        let x = Shape::hwc(12, 12, 3);
        let kind = conv((3, 3), (2, 2));
        let out = crate::ops::infer_output(&kind, &[&x]).unwrap();
        let expect = warm.get_or_compute(Method::Algorithmic, &kind, &[&x], &out, DType::F32);
        assert_eq!(warm.save(&path).unwrap(), 1);

        // a cold instance (≈ a fresh process) answers from the file —
        // the lookup counts as a hit because no engine ran
        let cold = OsCache::new();
        assert_eq!(cold.load(&path).unwrap(), 1);
        let got = cold.get_or_compute(Method::Algorithmic, &kind, &[&x], &out, DType::F32);
        assert_eq!(got, expect);
        assert_eq!(cold.stats(), CacheStats { hits: 1, misses: 0 });
        // promoted entries keep answering without re-probing the file map
        let again = cold.get_or_compute(Method::Algorithmic, &kind, &[&x], &out, DType::F32);
        assert_eq!(again, expect);
        assert_eq!(cold.stats().hits, 2);

        // saving after more work persists the union
        let y = Shape::hwc(6, 6, 2);
        let k2 = OpKind::Unary(UnaryKind::Relu);
        cold.get_or_compute(Method::Analytic, &k2, &[&y], &y, DType::I8);
        assert_eq!(cold.save(&path).unwrap(), 2);
        assert_eq!(OsCache::new().load(&path).unwrap(), 2);

        // a different engine revision is refused outright (stale math)
        let good = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, good.replace("\"engine\":1", "\"engine\":999")).unwrap();
        assert!(OsCache::new().load(&path).is_err());

        // tampered content fails the recorded hash
        std::fs::write(&path, good.replace("\"os\":[", "\"os\":[9999,")).unwrap();
        assert!(OsCache::new().load(&path).is_err());
        // and a wrong kind is refused outright
        std::fs::write(&path, "{\"kind\":\"something-else\",\"version\":1}").unwrap();
        assert!(OsCache::new().load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hit_rate_is_well_defined() {
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
        let s = CacheStats { hits: 3, misses: 1 };
        assert_eq!(s.lookups(), 4);
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
    }
}
