//! `dmo serve` — CLI front-end for the serving loop.

use super::server::{serve, ServeConfig};
use super::BatchPolicy;
use crate::util::args::{opt, ArgSpec, Args};
use anyhow::Result;
use std::path::PathBuf;
use std::time::Duration;

/// Flags accepted by `dmo serve`.
pub const SERVE_SPEC: &[ArgSpec] = &[
    opt("--requests", "number of requests to generate (default 256)"),
    opt("--rate", "open-loop arrival rate, req/s (default 500)"),
    opt("--queue", "bounded queue capacity (default 64)"),
    opt("--batch", "max dynamic batch size (default 8)"),
    opt("--window-us", "batching window in µs (default 2000)"),
    opt("--seed", "workload RNG seed (default 42)"),
    opt("--plan", "pre-computed plan artifact to start from (skips the planner search)"),
    opt("--model", "model the memory plan is for (default `tiny`)"),
    opt("--jobs", "planner worker threads for startup planning (default: all cores)"),
    opt("--os-cache", "persisted O_s cache file: loaded before startup planning, saved after — cold replicas start warm"),
];

/// Entry point used by `main.rs`.
pub fn serve_main(args: &Args) -> Result<()> {
    let cfg = ServeConfig {
        requests: args.parsed("--requests", 256u64)?,
        rate: args.parsed("--rate", 500.0f64)?,
        queue_capacity: args.parsed("--queue", 64usize)?,
        policy: BatchPolicy {
            max_batch: args.parsed("--batch", 8usize)?,
            window: Duration::from_micros(args.parsed("--window-us", 2000u64)?),
        },
        seed: args.parsed("--seed", 42u64)?,
        plan_artifact: args.value("--plan").map(PathBuf::from),
        plan_model: args.value("--model").unwrap_or("tiny").to_string(),
        jobs: args.parsed("--jobs", 0usize)?,
        os_cache_path: args.value("--os-cache").map(PathBuf::from),
        ..Default::default()
    };
    println!(
        "serving {} requests at {} req/s (queue {}, batch ≤{}, window {:?})",
        cfg.requests, cfg.rate, cfg.queue_capacity, cfg.policy.max_batch, cfg.policy.window
    );
    if let Some(p) = &cfg.plan_artifact {
        println!("memory plan     : loaded from artifact {}", p.display());
    }
    let report = serve(&cfg)?;
    let l = report.metrics.latency();
    println!("platform        : {}", report.platform);
    println!("completed       : {} ({} shed)", report.completed, report.shed);
    println!("wall time       : {:.3} s", report.wall.as_secs_f64());
    println!("throughput      : {:.1} req/s", report.throughput_rps);
    println!(
        "latency         : mean {:.0} µs  p50 {:.0}  p95 {:.0}  p99 {:.0}  max {:.0}",
        l.mean_us, l.p50_us, l.p95_us, l.p99_us, l.max_us
    );
    println!(
        "batching        : mean {:.2} req/batch, lane efficiency {:.0}%",
        report.metrics.mean_batch(),
        100.0 * report.metrics.batch_efficiency()
    );
    println!(
        "on-device arena : {} original → {} with DMO",
        crate::report::fmt_bytes(report.arena_original),
        crate::report::fmt_bytes(report.arena_dmo)
    );
    Ok(())
}
