//! Deterministic PRNG (SplitMix64) for weight generation, synthetic
//! inputs, property-test case generation and workload arrival processes.
//!
//! SplitMix64 is the seeding generator of `rand`'s `SmallRng`; it passes
//! BigCrush and is fully reproducible across platforms, which matters
//! because tests assert bit-exact numerics between runs.

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator. Same seed ⇒ same stream, forever.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform usize in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponentially distributed inter-arrival time with rate `lambda`
    /// (events per second) — used by the serving workload generator.
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = self.next_f64().max(1e-12);
        -u.ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
            let n = r.range(3, 9);
            assert!((3..=9).contains(&n));
        }
    }

    #[test]
    fn exp_positive_and_mean_reasonable() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(100.0)).sum::<f64>() / n as f64;
        assert!(mean > 0.008 && mean < 0.012, "mean {mean}");
    }
}
