//! Tensor-op graph and builder.

use super::dtype::DType;
use super::op::{Activation, Conv2DParams, DepthwiseParams, OpKind, Padding, PoolKind, PoolParams};
use super::shape::Shape;
use crate::ops::infer_output;

/// Index of a tensor in [`Graph::tensors`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub usize);

/// Index of an op in [`Graph::ops`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub usize);

/// Whether a tensor lives in the tensor arena and how it is produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorKind {
    /// Model input — materialised in the arena before the first op runs.
    Input,
    /// Produced and consumed inside the graph; lives in the arena.
    Intermediate,
    /// Graph output; lives in the arena until inference completes.
    Output,
}

/// Static description of one tensor.
#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Shape,
    pub dtype: DType,
    pub kind: TensorKind,
}

impl TensorInfo {
    /// Buffer size in bytes.
    pub fn size_bytes(&self) -> usize {
        self.shape.num_elements() * self.dtype.size_bytes()
    }
}

/// Weight / bias attribute of an op (stored in flash, not the arena).
#[derive(Debug, Clone)]
pub struct WeightInfo {
    pub shape: Shape,
    pub dtype: DType,
}

impl WeightInfo {
    pub fn size_bytes(&self) -> usize {
        self.shape.num_elements() * self.dtype.size_bytes()
    }
}

/// One operation node.
#[derive(Debug, Clone)]
pub struct OpNode {
    pub name: String,
    pub kind: OpKind,
    /// Activation inputs, in op-defined order.
    pub inputs: Vec<TensorId>,
    /// Single activation output (TFLite reference kernels are all SISO on
    /// the activation path).
    pub output: TensorId,
    /// Flash-resident weights/biases.
    pub weights: Vec<WeightInfo>,
    /// Identity of this op's synthetic weight stream; `None` means "my
    /// own op index". Graph rewrites (§II-A operation splitting) point
    /// every band of a split op at the *original* op's index, so all
    /// bands draw the one weight tensor the unsplit op would — the
    /// prerequisite for banded execution being bit-identical to the
    /// unsplit reference. Ops sharing a `weight_seed` share one flash
    /// weight array (see [`Graph::weight_bytes`] and the C emitter).
    pub weight_seed: Option<usize>,
}

impl OpNode {
    /// The weight-stream key of op `own_index`: the rewrite-provenance
    /// index when set, the op's own index otherwise.
    pub fn weight_key(&self, own_index: usize) -> usize {
        self.weight_seed.unwrap_or(own_index)
    }
}

/// A tensor-op graph. `ops` is stored in a valid execution order
/// (the order the builder emitted), which [`crate::planner::order`]
/// may re-serialise.
#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub tensors: Vec<TensorInfo>,
    pub ops: Vec<OpNode>,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
}

impl Graph {
    pub fn tensor(&self, id: TensorId) -> &TensorInfo {
        &self.tensors[id.0]
    }

    pub fn op(&self, id: OpId) -> &OpNode {
        &self.ops[id.0]
    }

    /// Ops that consume tensor `t`.
    pub fn consumers(&self, t: TensorId) -> Vec<OpId> {
        self.ops
            .iter()
            .enumerate()
            .filter(|(_, op)| op.inputs.contains(&t))
            .map(|(i, _)| OpId(i))
            .collect()
    }

    /// Op producing tensor `t`, if any (inputs have no producer).
    pub fn producer(&self, t: TensorId) -> Option<OpId> {
        self.ops
            .iter()
            .enumerate()
            .find(|(_, op)| op.output == t)
            .map(|(i, _)| OpId(i))
    }

    /// The ops owning a distinct weight group, in op order: the first
    /// op carrying each weight key. Bands of a §II-A split share their
    /// source op's key ([`OpNode::weight_seed`]), so flash accounting
    /// ([`Graph::weight_bytes`]) and the C emitter's array emission
    /// iterate this one definition in lockstep.
    pub fn unique_weight_ops(&self) -> impl Iterator<Item = (usize, &OpNode)> {
        let mut seen = std::collections::HashSet::new();
        self.ops
            .iter()
            .enumerate()
            .filter(move |(i, op)| !op.weights.is_empty() && seen.insert(op.weight_key(*i)))
    }

    /// Total weight bytes — the flash footprint discussed in §IV.
    ///
    /// Ops sharing a weight stream (the bands of a §II-A split all
    /// carry the original op's [`OpNode::weight_seed`]) store their
    /// weights in flash **once**, so each distinct weight key is
    /// counted once.
    pub fn weight_bytes(&self) -> usize {
        self.unique_weight_ops()
            .flat_map(|(_, op)| op.weights.iter())
            .map(|w| w.size_bytes())
            .sum()
    }

    /// Sum of all arena tensor sizes (upper bound on any allocation).
    pub fn total_tensor_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }

    /// Sanity-check structural invariants; used by tests and the builders.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, op) in self.ops.iter().enumerate() {
            if let Some(n) = op.kind.arity() {
                anyhow::ensure!(
                    op.inputs.len() == n,
                    "op {i} `{}` expects {n} inputs, has {}",
                    op.name,
                    op.inputs.len()
                );
            }
            for &t in &op.inputs {
                anyhow::ensure!(t.0 < self.tensors.len(), "op {i} input out of range");
                // producer must come before consumer in builder order
                if let Some(p) = self.producer(t) {
                    anyhow::ensure!(p.0 < i, "op {i} `{}` consumes tensor produced later", op.name);
                }
            }
            anyhow::ensure!(op.output.0 < self.tensors.len(), "op {i} output out of range");
            let inferred = infer_output(&op.kind, &op.inputs.iter().map(|&t| &self.tensor(t).shape).collect::<Vec<_>>())?;
            anyhow::ensure!(
                inferred == self.tensor(op.output).shape,
                "op {i} `{}`: inferred shape {} != stored {}",
                op.name,
                inferred,
                self.tensor(op.output).shape
            );
        }
        Ok(())
    }
}

/// Convenience builder used by the model zoo.
pub struct GraphBuilder {
    graph: Graph,
    dtype: DType,
    counter: usize,
}

impl GraphBuilder {
    pub fn new(name: &str, dtype: DType) -> Self {
        GraphBuilder {
            graph: Graph {
                name: name.to_string(),
                tensors: Vec::new(),
                ops: Vec::new(),
                inputs: Vec::new(),
                outputs: Vec::new(),
            },
            dtype,
            counter: 0,
        }
    }

    /// Element dtype this builder emits.
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Read access to the graph under construction.
    pub fn graph_ref(&self) -> &Graph {
        &self.graph
    }

    /// Shape of a tensor already added to the graph.
    pub fn shape_of(&self, t: TensorId) -> Shape {
        self.graph.tensor(t).shape.clone()
    }

    fn fresh_name(&mut self, base: &str) -> String {
        let n = self.counter;
        self.counter += 1;
        format!("{base}_{n}")
    }

    fn add_tensor(&mut self, name: String, shape: Shape, kind: TensorKind) -> TensorId {
        let id = TensorId(self.graph.tensors.len());
        self.graph.tensors.push(TensorInfo {
            name,
            shape,
            dtype: self.dtype,
            kind,
        });
        id
    }

    /// Declare a model input.
    pub fn input(&mut self, shape: Shape) -> TensorId {
        let name = self.fresh_name("input");
        let id = self.add_tensor(name, shape, TensorKind::Input);
        self.graph.inputs.push(id);
        id
    }

    /// Append an op with explicit kind; returns its output tensor.
    pub fn add_op(&mut self, kind: OpKind, inputs: &[TensorId], weights: Vec<WeightInfo>) -> TensorId {
        let name = self.fresh_name(kind.name());
        let in_shapes: Vec<&Shape> = inputs.iter().map(|&t| &self.graph.tensor(t).shape).collect();
        let out_shape = infer_output(&kind, &in_shapes).expect("shape inference failed");
        let out = self.add_tensor(format!("{name}_out"), out_shape, TensorKind::Intermediate);
        self.graph.ops.push(OpNode {
            name,
            kind,
            inputs: inputs.to_vec(),
            output: out,
            weights,
            weight_seed: None,
        });
        out
    }

    /// 2-D convolution with fused activation. Weights `[Kh, Kw, Cin, Cout]`
    /// plus bias `[Cout]`.
    pub fn conv2d(
        &mut self,
        x: TensorId,
        out_channels: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
        act: Activation,
    ) -> TensorId {
        let cin = self.graph.tensor(x).shape.c();
        let weights = vec![
            WeightInfo {
                shape: Shape::new(&[kernel.0, kernel.1, cin, out_channels]),
                dtype: self.dtype,
            },
            WeightInfo {
                shape: Shape::vec1(out_channels),
                dtype: if self.dtype == DType::I8 { DType::I32 } else { self.dtype },
            },
        ];
        self.add_op(
            OpKind::Conv2D(Conv2DParams {
                kernel,
                stride,
                dilation: (1, 1),
                padding,
                out_channels,
                act,
            }),
            &[x],
            weights,
        )
    }

    /// Depthwise convolution with fused activation.
    pub fn dwconv2d(
        &mut self,
        x: TensorId,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
        act: Activation,
    ) -> TensorId {
        let cin = self.graph.tensor(x).shape.c();
        let weights = vec![
            WeightInfo {
                shape: Shape::new(&[kernel.0, kernel.1, cin, 1]),
                dtype: self.dtype,
            },
            WeightInfo {
                shape: Shape::vec1(cin),
                dtype: if self.dtype == DType::I8 { DType::I32 } else { self.dtype },
            },
        ];
        self.add_op(
            OpKind::DepthwiseConv2D(DepthwiseParams {
                kernel,
                stride,
                dilation: (1, 1),
                padding,
                depth_multiplier: 1,
                act,
            }),
            &[x],
            weights,
        )
    }

    /// Max pooling.
    pub fn maxpool(&mut self, x: TensorId, kernel: (usize, usize), stride: (usize, usize), padding: Padding) -> TensorId {
        self.add_op(
            OpKind::Pool(PoolParams {
                kind: PoolKind::Max,
                kernel,
                stride,
                padding,
            }),
            &[x],
            vec![],
        )
    }

    /// Average pooling.
    pub fn avgpool(&mut self, x: TensorId, kernel: (usize, usize), stride: (usize, usize), padding: Padding) -> TensorId {
        self.add_op(
            OpKind::Pool(PoolParams {
                kind: PoolKind::Avg,
                kernel,
                stride,
                padding,
            }),
            &[x],
            vec![],
        )
    }

    /// Global average pooling.
    pub fn global_avg_pool(&mut self, x: TensorId) -> TensorId {
        self.add_op(OpKind::GlobalAvgPool, &[x], vec![])
    }

    /// Residual / element-wise add.
    pub fn add(&mut self, a: TensorId, b: TensorId) -> TensorId {
        self.add_op(OpKind::Binary(crate::ir::op::BinaryKind::Add), &[a, b], vec![])
    }

    /// Standalone relu (models without fused activations).
    pub fn relu(&mut self, x: TensorId) -> TensorId {
        self.add_op(OpKind::Unary(crate::ir::op::UnaryKind::Relu), &[x], vec![])
    }

    /// Channel-axis concatenation.
    pub fn concat(&mut self, xs: &[TensorId]) -> TensorId {
        self.add_op(OpKind::Concat, xs, vec![])
    }

    /// Fully connected layer.
    pub fn fully_connected(&mut self, x: TensorId, out_features: usize, act: Activation) -> TensorId {
        let cin = self.graph.tensor(x).shape.num_elements();
        let weights = vec![
            WeightInfo {
                shape: Shape::new(&[cin, out_features]),
                dtype: self.dtype,
            },
            WeightInfo {
                shape: Shape::vec1(out_features),
                dtype: if self.dtype == DType::I8 { DType::I32 } else { self.dtype },
            },
        ];
        self.add_op(OpKind::FullyConnected { out_features, act }, &[x], weights)
    }

    /// Row-wise softmax.
    pub fn softmax(&mut self, x: TensorId) -> TensorId {
        self.add_op(OpKind::Softmax, &[x], vec![])
    }

    /// Spatial zero-pad `(top, bottom, left, right)`.
    pub fn pad(&mut self, x: TensorId, pad: (usize, usize, usize, usize)) -> TensorId {
        self.add_op(OpKind::Pad { pad }, &[x], vec![])
    }

    /// Reshape (element order preserved).
    pub fn reshape(&mut self, x: TensorId, to: Shape) -> TensorId {
        self.add_op(OpKind::Reshape { to }, &[x], vec![])
    }

    /// Finish: mark `outputs`, fix tensor kinds, validate.
    pub fn finish(mut self, outputs: &[TensorId]) -> Graph {
        for &t in outputs {
            self.graph.tensors[t.0].kind = TensorKind::Output;
            self.graph.outputs.push(t);
        }
        self.graph.validate().expect("graph invalid");
        self.graph
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_tiny_graph() {
        let mut b = GraphBuilder::new("tiny", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 3));
        let c = b.conv2d(x, 4, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let p = b.maxpool(c, (2, 2), (2, 2), Padding::Valid);
        let f = b.fully_connected(p, 10, Activation::None);
        let s = b.softmax(f);
        let g = b.finish(&[s]);
        assert_eq!(g.ops.len(), 4);
        assert_eq!(g.tensor(c).shape, Shape::hwc(8, 8, 4));
        assert_eq!(g.tensor(p).shape, Shape::hwc(4, 4, 4));
        assert_eq!(g.tensor(f).shape, Shape::new(&[1, 10]));
        assert_eq!(g.consumers(c), vec![OpId(1)]);
        assert_eq!(g.producer(x), None);
        assert!(g.weight_bytes() > 0);
    }

    #[test]
    fn validate_rejects_bad_shape() {
        let mut b = GraphBuilder::new("bad", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 3));
        let c = b.conv2d(x, 4, (3, 3), (1, 1), Padding::Same, Activation::None);
        let mut g = b.finish(&[c]);
        // corrupt the stored output shape
        g.tensors[c.0].shape = Shape::hwc(5, 5, 4);
        assert!(g.validate().is_err());
    }
}
