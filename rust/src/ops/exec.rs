//! Numeric reference kernels over a flat tensor arena.
//!
//! These are re-implementations of the TFLite reference kernels the paper
//! instruments (§III, Fig 3): every op reads and writes through an
//! [`Arena`] that can record each load/store/update event — the substitute
//! for the authors' patched Valgrind (DESIGN.md, substitution table).
//!
//! Loop orders are byte-for-byte the same sweeps as
//! [`super::access::for_each_step`]; the tests in `rust/tests/` replay
//! both against each other.
//!
//! Quantised (`i8`) semantics are simplified to saturating round-to-
//! nearest with unit scale: DMO only depends on element *sizes* and access
//! *order*, and unit-scale integer math keeps runs bit-exactly
//! reproducible, which the overlap-safety validator requires.

use crate::ir::op::{pad_before, Activation, OpKind, PoolKind};
use crate::ir::shape::Shape;
use crate::ir::DType;
use anyhow::{ensure, Result};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Kind of a recorded memory event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Read.
    Load,
    /// Write of a fresh value.
    Store,
    /// Read-modify-write (accumulation into the output buffer).
    Update,
}

/// Sink receiving memory events in execution order.
///
/// Implementations: [`EventLog`] (raw storage, small ops),
/// [`crate::overlap::trace::OverlapProbe`] (streaming bottom-up `O_s`),
/// [`crate::trace::RasterSink`] (down-sampled figure rendering).
///
/// `Send` is required because an [`Arena`] (which owns its sink) travels
/// between threads via the fleet's arena pool, and the fleet installs a
/// watermark sink on worker threads.
pub trait EventSink: Send {
    /// `addr`/`len` are arena byte offsets.
    fn event(&mut self, kind: EventKind, addr: usize, len: usize);
}

/// A raw in-memory event with a sequence number.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub t: u64,
    pub kind: EventKind,
    pub addr: u32,
    pub len: u8,
}

/// Stores every event — only for small ops and figure generation.
#[derive(Debug, Default)]
pub struct EventLog {
    pub events: Vec<Event>,
}

impl EventSink for EventLog {
    fn event(&mut self, kind: EventKind, addr: usize, len: usize) {
        let t = self.events.len() as u64;
        self.events.push(Event {
            t,
            kind,
            addr: addr as u32,
            len: len as u8,
        });
    }
}

/// Shared handle to an [`EventLog`], so callers can install it as the
/// arena's sink and still read the events afterwards.
#[derive(Debug, Clone, Default)]
pub struct SharedLog(pub std::sync::Arc<std::sync::Mutex<EventLog>>);

impl SharedLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn take_events(&self) -> Vec<Event> {
        std::mem::take(&mut crate::util::sync::lock(&self.0).events)
    }
}

impl EventSink for SharedLog {
    fn event(&mut self, kind: EventKind, addr: usize, len: usize) {
        crate::util::sync::lock(&self.0).event(kind, addr, len);
    }
}

/// A byte region inside the arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub base: usize,
    pub len: usize,
}

impl Region {
    pub fn new(base: usize, len: usize) -> Self {
        Region { base, len }
    }

    pub fn end(&self) -> usize {
        self.base + self.len
    }

    pub fn contains(&self, addr: usize) -> bool {
        addr >= self.base && addr < self.end()
    }
}

/// Flat byte arena with optional event tracing.
///
/// All activation loads/stores go through [`Arena::load`]/[`Arena::store`]/
/// [`Arena::update`], which emit events; weight accesses do not touch the
/// arena (the paper's traces omit filter/weight buffers, which live in
/// flash on the target).
pub struct Arena {
    bytes: Vec<u8>,
    pub sink: Option<Box<dyn EventSink>>,
}

impl Arena {
    pub fn new(size: usize) -> Self {
        Arena {
            bytes: vec![0; size],
            sink: None,
        }
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// Install an event sink; returns the previous one.
    pub fn set_sink(&mut self, sink: Option<Box<dyn EventSink>>) -> Option<Box<dyn EventSink>> {
        std::mem::replace(&mut self.sink, sink)
    }

    /// Traced element load.
    #[inline]
    pub fn load(&mut self, dtype: DType, byte_off: usize) -> f32 {
        let w = dtype.size_bytes();
        if let Some(s) = self.sink.as_mut() {
            s.event(EventKind::Load, byte_off, w);
        }
        self.peek(dtype, byte_off)
    }

    /// Traced element store.
    #[inline]
    pub fn store(&mut self, dtype: DType, byte_off: usize, v: f32) {
        let w = dtype.size_bytes();
        if let Some(s) = self.sink.as_mut() {
            s.event(EventKind::Store, byte_off, w);
        }
        self.poke(dtype, byte_off, v);
    }

    /// Traced read-modify-write: `mem[off] += v`.
    #[inline]
    pub fn update_add(&mut self, dtype: DType, byte_off: usize, v: f32) {
        let w = dtype.size_bytes();
        if let Some(s) = self.sink.as_mut() {
            s.event(EventKind::Update, byte_off, w);
        }
        let cur = self.peek(dtype, byte_off);
        self.poke(dtype, byte_off, cur + v);
    }

    /// Untraced element read (initialisation / inspection).
    #[inline]
    pub fn peek(&self, dtype: DType, byte_off: usize) -> f32 {
        match dtype {
            DType::F32 => f32::from_le_bytes(self.bytes[byte_off..byte_off + 4].try_into().unwrap()),
            DType::I8 => self.bytes[byte_off] as i8 as f32,
            DType::I32 => {
                i32::from_le_bytes(self.bytes[byte_off..byte_off + 4].try_into().unwrap()) as f32
            }
        }
    }

    /// Untraced element write.
    #[inline]
    pub fn poke(&mut self, dtype: DType, byte_off: usize, v: f32) {
        match dtype {
            DType::F32 => self.bytes[byte_off..byte_off + 4].copy_from_slice(&v.to_le_bytes()),
            DType::I8 => {
                self.bytes[byte_off] = (v.round().clamp(-128.0, 127.0) as i8) as u8;
            }
            DType::I32 => {
                let q = v.round().clamp(i32::MIN as f32, i32::MAX as f32) as i32;
                self.bytes[byte_off..byte_off + 4].copy_from_slice(&q.to_le_bytes());
            }
        }
    }

    /// Copy a typed tensor into the arena without tracing.
    pub fn write_tensor(&mut self, dtype: DType, region: Region, values: &[f32]) {
        let w = dtype.size_bytes();
        assert!(values.len() * w <= region.len, "tensor larger than region");
        for (i, &v) in values.iter().enumerate() {
            self.poke(dtype, region.base + i * w, v);
        }
    }

    /// Copy a typed tensor out of the arena without tracing.
    pub fn read_tensor(&self, dtype: DType, region: Region, count: usize) -> Vec<f32> {
        let w = dtype.size_bytes();
        assert!(count * w <= region.len);
        (0..count).map(|i| self.peek(dtype, region.base + i * w)).collect()
    }
}

/// Everything an op execution needs to know about where its data lives.
pub struct OpIo<'a> {
    pub in_shapes: &'a [&'a Shape],
    pub in_regions: &'a [Region],
    pub out_shape: &'a Shape,
    pub out_region: Region,
    pub dtype: DType,
    /// Weight tensors as f32 (conv: HWIO; fc: `[in, out]` row-major),
    /// then bias. Empty for weight-less ops.
    pub weights: &'a [Vec<f32>],
}

/// Fused activation. Written as explicit comparisons (not `f32::max` /
/// `clamp`) so the result is fully specified for `-0.0` ties — the C
/// emitter (`crate::codegen`) replicates these exact expressions and the
/// differential harness demands bit-identical outputs.
#[inline]
fn act(v: f32, a: Activation) -> f32 {
    match a {
        Activation::None => v,
        Activation::Relu => {
            if v < 0.0 {
                0.0
            } else {
                v
            }
        }
        Activation::Relu6 => {
            if v < 0.0 {
                0.0
            } else if v > 6.0 {
                6.0
            } else {
                v
            }
        }
    }
}

/// Fast-i8 kill switch (on by default). The fleet interpreter is the
/// real serving engine while `xla` is a stub, so the CMSIS-NN-style
/// integer path matters for throughput; benches flip this to measure
/// the reference loops.
static FAST_I8: AtomicBool = AtomicBool::new(true);
/// Ops actually executed through the fast i8 path (not just eligible).
static FAST_I8_HITS: AtomicUsize = AtomicUsize::new(0);

/// Enable/disable the fast i8 interpreter path (process-wide).
pub fn set_fast_i8(on: bool) {
    FAST_I8.store(on, Ordering::Relaxed);
}

/// Is the fast i8 interpreter path enabled?
pub fn fast_i8_enabled() -> bool {
    FAST_I8.load(Ordering::Relaxed)
}

/// Count of ops executed through the fast i8 path so far.
pub fn fast_i8_hits() -> usize {
    FAST_I8_HITS.load(Ordering::Relaxed)
}

/// Integer fused activation — identical to [`act`] on integral values
/// (no `-0.0` subtleties exist in the integer domain).
#[inline]
fn i8_act(v: i32, a: Activation) -> i32 {
    match a {
        Activation::None => v,
        Activation::Relu => v.max(0),
        Activation::Relu6 => v.clamp(0, 6),
    }
}

/// Is the int32 accumulator provably bit-identical to the reference f32
/// accumulation? Requires integral weights and
/// `|bias| + macs·127·|w|max < 2^24` — below that bound every partial
/// f32 sum of integers is exact, so both paths compute the same value
/// at every step (same gate the C emitter applies per site).
fn fast_i8_bound_ok(macs_per_out: usize, weights: &[Vec<f32>]) -> bool {
    if weights.len() != 2 {
        return false;
    }
    if weights.iter().flatten().any(|v| v.fract() != 0.0) {
        return false;
    }
    let absmax = |tv: &[f32]| tv.iter().fold(0f32, |m, &v| m.max(v.abs())) as i64;
    absmax(&weights[1]) + macs_per_out as i64 * 127 * absmax(&weights[0]) < 1 << 24
}

/// CMSIS-NN-idiom execution for i8 conv/dwconv/fc: accumulate in `i32`
/// over the raw arena bytes, saturate at store. Element order is
/// byte-for-byte the reference sweep, so planned in-place overlaps stay
/// safe. Only taken when no event sink is installed — tracing callers
/// (watermark verification, O_s probes) always see the reference path.
/// Returns `false` when ineligible; the caller then runs the reference.
fn exec_fast_i8(kind: &OpKind, io: &OpIo<'_>, arena: &mut Arena) -> bool {
    if io.dtype != DType::I8 || arena.sink.is_some() || !fast_i8_enabled() {
        return false;
    }
    match kind {
        OpKind::Conv2D(p) => {
            let (xs, os) = (io.in_shapes[0], io.out_shape);
            let (ih, iw, id) = (xs.h(), xs.w(), xs.c());
            let (oh, ow, od) = (os.h(), os.w(), os.c());
            if !fast_i8_bound_ok(p.kernel.0 * p.kernel.1 * id, io.weights) {
                return false;
            }
            let (wts, bias) = (&io.weights[0], &io.weights[1]);
            if wts.len() != p.kernel.0 * p.kernel.1 * id * od {
                return false;
            }
            let ph = pad_before(ih, oh, p.kernel.0, p.stride.0, p.dilation.0) as isize;
            let pw = pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1) as isize;
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for oy in 0..oh {
                for ox in 0..ow {
                    let y0 = oy as isize * p.stride.0 as isize - ph;
                    let x0 = ox as isize * p.stride.1 as isize - pw;
                    for oc in 0..od {
                        let mut acc = bias[oc] as i32;
                        for ky in 0..p.kernel.0 {
                            let iy = y0 + (ky * p.dilation.0) as isize;
                            if iy < 0 || iy as usize >= ih {
                                continue;
                            }
                            for kx in 0..p.kernel.1 {
                                let ix = x0 + (kx * p.dilation.1) as isize;
                                if ix < 0 || ix as usize >= iw {
                                    continue;
                                }
                                for ic in 0..id {
                                    let v = arena.bytes
                                        [ib + (iy as usize * iw + ix as usize) * id + ic]
                                        as i8 as i32;
                                    acc += v
                                        * wts[((ky * p.kernel.1 + kx) * id + ic) * od + oc] as i32;
                                }
                            }
                        }
                        let r = i8_act(acc, p.act).clamp(-128, 127);
                        arena.bytes[ob + (oy * ow + ox) * od + oc] = r as i8 as u8;
                    }
                }
            }
        }
        OpKind::DepthwiseConv2D(p) => {
            let (xs, os) = (io.in_shapes[0], io.out_shape);
            let (ih, iw, id) = (xs.h(), xs.w(), xs.c());
            let (oh, ow, od) = (os.h(), os.w(), os.c());
            let mult = p.depth_multiplier;
            if !fast_i8_bound_ok(p.kernel.0 * p.kernel.1, io.weights) {
                return false;
            }
            let (wts, bias) = (&io.weights[0], &io.weights[1]);
            if wts.len() != p.kernel.0 * p.kernel.1 * id * mult {
                return false;
            }
            let ph = pad_before(ih, oh, p.kernel.0, p.stride.0, p.dilation.0) as isize;
            let pw = pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1) as isize;
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for oy in 0..oh {
                for ox in 0..ow {
                    let y0 = oy as isize * p.stride.0 as isize - ph;
                    let x0 = ox as isize * p.stride.1 as isize - pw;
                    for ic in 0..id {
                        for m in 0..mult {
                            let oc = ic * mult + m;
                            let mut acc = bias[oc.min(bias.len() - 1)] as i32;
                            for ky in 0..p.kernel.0 {
                                let iy = y0 + (ky * p.dilation.0) as isize;
                                if iy < 0 || iy as usize >= ih {
                                    continue;
                                }
                                for kx in 0..p.kernel.1 {
                                    let ix = x0 + (kx * p.dilation.1) as isize;
                                    if ix < 0 || ix as usize >= iw {
                                        continue;
                                    }
                                    let v = arena.bytes
                                        [ib + (iy as usize * iw + ix as usize) * id + ic]
                                        as i8 as i32;
                                    acc += v
                                        * wts[((ky * p.kernel.1 + kx) * id + ic) * mult + m]
                                            as i32;
                                }
                            }
                            let r = i8_act(acc, p.act).clamp(-128, 127);
                            arena.bytes[ob + (oy * ow + ox) * od + oc] = r as i8 as u8;
                        }
                    }
                }
            }
        }
        OpKind::FullyConnected { out_features, act: a } => {
            let k_dim = io.in_shapes[0].num_elements();
            if !fast_i8_bound_ok(k_dim, io.weights) {
                return false;
            }
            let (wts, bias) = (&io.weights[0], &io.weights[1]);
            if wts.len() != k_dim * out_features {
                return false;
            }
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for o in 0..*out_features {
                let mut acc = bias[o] as i32;
                for k in 0..k_dim {
                    acc += (arena.bytes[ib + k] as i8 as i32) * wts[k * out_features + o] as i32;
                }
                let r = i8_act(acc, *a).clamp(-128, 127);
                arena.bytes[ob + o] = r as i8 as u8;
            }
        }
        _ => return false,
    }
    FAST_I8_HITS.fetch_add(1, Ordering::Relaxed);
    true
}

/// Execute one op. Loop order mirrors [`super::access::for_each_step`].
pub fn execute_op(kind: &OpKind, io: &OpIo<'_>, arena: &mut Arena) -> Result<()> {
    if exec_fast_i8(kind, io, arena) {
        return Ok(());
    }
    let t = io.dtype.size_bytes();
    match kind {
        OpKind::Conv2D(p) => {
            let (xs, os) = (io.in_shapes[0], io.out_shape);
            let (ih, iw, id) = (xs.h(), xs.w(), xs.c());
            let (oh, ow, od) = (os.h(), os.w(), os.c());
            let ph = pad_before(ih, oh, p.kernel.0, p.stride.0, p.dilation.0) as isize;
            let pw = pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1) as isize;
            let (wts, bias) = (&io.weights[0], &io.weights[1]);
            ensure!(wts.len() == p.kernel.0 * p.kernel.1 * id * od, "conv weight size");
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for oy in 0..oh {
                for ox in 0..ow {
                    let y0 = oy as isize * p.stride.0 as isize - ph;
                    let x0 = ox as isize * p.stride.1 as isize - pw;
                    for oc in 0..od {
                        let mut total = bias[oc];
                        for ky in 0..p.kernel.0 {
                            let iy = y0 + (ky * p.dilation.0) as isize;
                            if iy < 0 || iy as usize >= ih {
                                continue;
                            }
                            for kx in 0..p.kernel.1 {
                                let ix = x0 + (kx * p.dilation.1) as isize;
                                if ix < 0 || ix as usize >= iw {
                                    continue;
                                }
                                for ic in 0..id {
                                    let ioff = ((iy as usize * iw + ix as usize) * id + ic) * t;
                                    let v = arena.load(io.dtype, ib + ioff);
                                    let wv = wts[((ky * p.kernel.1 + kx) * id + ic) * od + oc];
                                    total += v * wv;
                                }
                            }
                        }
                        let ooff = ((oy * ow + ox) * od + oc) * t;
                        arena.store(io.dtype, ob + ooff, act(total, p.act));
                    }
                }
            }
        }
        OpKind::DepthwiseConv2D(p) => {
            let (xs, os) = (io.in_shapes[0], io.out_shape);
            let (ih, iw, id) = (xs.h(), xs.w(), xs.c());
            let (oh, ow, od) = (os.h(), os.w(), os.c());
            let mult = p.depth_multiplier;
            let ph = pad_before(ih, oh, p.kernel.0, p.stride.0, p.dilation.0) as isize;
            let pw = pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1) as isize;
            let (wts, bias) = (&io.weights[0], &io.weights[1]);
            ensure!(wts.len() == p.kernel.0 * p.kernel.1 * id * mult, "dw weight size");
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for oy in 0..oh {
                for ox in 0..ow {
                    let y0 = oy as isize * p.stride.0 as isize - ph;
                    let x0 = ox as isize * p.stride.1 as isize - pw;
                    for ic in 0..id {
                        for m in 0..mult {
                            let oc = ic * mult + m;
                            let mut total = bias[oc.min(bias.len() - 1)];
                            for ky in 0..p.kernel.0 {
                                let iy = y0 + (ky * p.dilation.0) as isize;
                                if iy < 0 || iy as usize >= ih {
                                    continue;
                                }
                                for kx in 0..p.kernel.1 {
                                    let ix = x0 + (kx * p.dilation.1) as isize;
                                    if ix < 0 || ix as usize >= iw {
                                        continue;
                                    }
                                    let ioff = ((iy as usize * iw + ix as usize) * id + ic) * t;
                                    let v = arena.load(io.dtype, ib + ioff);
                                    let wv = wts[((ky * p.kernel.1 + kx) * id + ic) * mult + m];
                                    total += v * wv;
                                }
                            }
                            let ooff = ((oy * ow + ox) * od + oc) * t;
                            arena.store(io.dtype, ob + ooff, act(total, p.act));
                        }
                    }
                }
            }
        }
        OpKind::Pool(p) => {
            let (xs, os) = (io.in_shapes[0], io.out_shape);
            let (ih, iw, id) = (xs.h(), xs.w(), xs.c());
            let (oh, ow, od) = (os.h(), os.w(), os.c());
            let ph = pad_before(ih, oh, p.kernel.0, p.stride.0, 1) as isize;
            let pw = pad_before(iw, ow, p.kernel.1, p.stride.1, 1) as isize;
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for oy in 0..oh {
                for ox in 0..ow {
                    let y0 = oy as isize * p.stride.0 as isize - ph;
                    let x0 = ox as isize * p.stride.1 as isize - pw;
                    for c in 0..od {
                        let mut acc = match p.kind {
                            PoolKind::Max => f32::NEG_INFINITY,
                            PoolKind::Avg => 0.0,
                        };
                        let mut n = 0usize;
                        for ky in 0..p.kernel.0 {
                            let iy = y0 + ky as isize;
                            if iy < 0 || iy as usize >= ih {
                                continue;
                            }
                            for kx in 0..p.kernel.1 {
                                let ix = x0 + kx as isize;
                                if ix < 0 || ix as usize >= iw {
                                    continue;
                                }
                                let ioff = ((iy as usize * iw + ix as usize) * id + c) * t;
                                let v = arena.load(io.dtype, ib + ioff);
                                match p.kind {
                                    // explicit compare (not f32::max): pins
                                    // -0.0 ties for the C emitter
                                    PoolKind::Max => {
                                        if v > acc {
                                            acc = v;
                                        }
                                    }
                                    PoolKind::Avg => acc += v,
                                }
                                n += 1;
                            }
                        }
                        let v = match p.kind {
                            PoolKind::Max => acc,
                            PoolKind::Avg => acc / n.max(1) as f32,
                        };
                        arena.store(io.dtype, io.out_region.base + ((oy * ow + ox) * od + c) * t, v);
                        let _ = ob;
                    }
                }
            }
        }
        OpKind::GlobalAvgPool => {
            let xs = io.in_shapes[0];
            let (ih, iw, id) = (xs.h(), xs.w(), xs.c());
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for c in 0..id {
                let mut acc = 0.0;
                for p in 0..ih * iw {
                    acc += arena.load(io.dtype, ib + (p * id + c) * t);
                }
                arena.store(io.dtype, ob + c * t, acc / (ih * iw) as f32);
            }
        }
        OpKind::Unary(u) => {
            let n = io.out_shape.num_elements();
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for i in 0..n {
                let v = arena.load(io.dtype, ib + i * t);
                let r = match u {
                    crate::ir::op::UnaryKind::Relu => act(v, Activation::Relu),
                    crate::ir::op::UnaryKind::Relu6 => act(v, Activation::Relu6),
                    crate::ir::op::UnaryKind::Copy => v,
                };
                arena.store(io.dtype, ob + i * t, r);
            }
        }
        OpKind::Binary(bk) => {
            let n = io.out_shape.num_elements();
            let (ab, bb) = (io.in_regions[0].base, io.in_regions[1].base);
            let ob = io.out_region.base;
            for i in 0..n {
                let x = arena.load(io.dtype, ab + i * t);
                let y = arena.load(io.dtype, bb + i * t);
                let r = match bk {
                    crate::ir::op::BinaryKind::Add => x + y,
                    crate::ir::op::BinaryKind::Mul => x * y,
                };
                arena.store(io.dtype, ob + i * t, r);
            }
        }
        OpKind::FullyConnected { out_features, act: a } => {
            let k_dim = io.in_shapes[0].num_elements();
            let (wts, bias) = (&io.weights[0], &io.weights[1]);
            ensure!(wts.len() == k_dim * out_features, "fc weight size");
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for o in 0..*out_features {
                let mut total = bias[o];
                for k in 0..k_dim {
                    total += arena.load(io.dtype, ib + k * t) * wts[k * out_features + o];
                }
                arena.store(io.dtype, ob + o * t, act(total, *a));
            }
        }
        OpKind::MatMulAccum { out_features } => {
            let k_dim = io.in_shapes[0].num_elements();
            let (wts, bias) = (&io.weights[0], &io.weights[1]);
            ensure!(wts.len() == k_dim * out_features, "matmul weight size");
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            // zero-init sweep (bias), then accumulate in the OUTPUT buffer —
            // the Fig 3b worst case.
            for o in 0..*out_features {
                arena.store(io.dtype, ob + o * t, bias[o]);
            }
            for k in 0..k_dim {
                let v = arena.load(io.dtype, ib + k * t);
                for o in 0..*out_features {
                    arena.update_add(io.dtype, ob + o * t, v * wts[k * out_features + o]);
                }
            }
        }
        OpKind::Concat => {
            let os = io.out_shape;
            let (oh, ow, od) = (os.h(), os.w(), os.c());
            let ob = io.out_region.base;
            for p in 0..oh * ow {
                let mut coff = 0usize;
                for (j, xs) in io.in_shapes.iter().enumerate() {
                    let cj = xs.c();
                    let ib = io.in_regions[j].base;
                    for c in 0..cj {
                        let v = arena.load(io.dtype, ib + (p * cj + c) * t);
                        arena.store(io.dtype, ob + (p * od + coff + c) * t, v);
                    }
                    coff += cj;
                }
            }
        }
        OpKind::Pad { pad } => {
            let (xs, os) = (io.in_shapes[0], io.out_shape);
            let (ih, iw, id) = (xs.h(), xs.w(), xs.c());
            let (oh, ow, od) = (os.h(), os.w(), os.c());
            let (top, _bot, left, _right) = *pad;
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for oy in 0..oh {
                for ox in 0..ow {
                    let inside = oy >= top && oy < top + ih && ox >= left && ox < left + iw;
                    for c in 0..od {
                        let v = if inside {
                            arena.load(io.dtype, ib + (((oy - top) * iw + (ox - left)) * id + c) * t)
                        } else {
                            0.0
                        };
                        arena.store(io.dtype, ob + ((oy * ow + ox) * od + c) * t, v);
                    }
                }
            }
        }
        OpKind::Softmax => {
            let s = io.out_shape;
            let d = s.dim(s.rank() - 1);
            let rows = s.num_elements() / d;
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for r in 0..rows {
                // pass 1: max (explicit compare — see `act` on -0.0 ties)
                let mut m = f32::NEG_INFINITY;
                for c in 0..d {
                    let x = arena.load(io.dtype, ib + (r * d + c) * t);
                    if x > m {
                        m = x;
                    }
                }
                // pass 2: sum of exp
                let mut sum = 0.0;
                for c in 0..d {
                    sum += (arena.load(io.dtype, ib + (r * d + c) * t) - m).exp();
                }
                // pass 3: re-read, write normalised
                for c in 0..d {
                    let v = (arena.load(io.dtype, ib + (r * d + c) * t) - m).exp() / sum;
                    arena.store(io.dtype, ob + (r * d + c) * t, v);
                }
            }
        }
        OpKind::Reshape { .. } => {
            let n = io.out_shape.num_elements();
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            for i in 0..n {
                let v = arena.load(io.dtype, ib + i * t);
                arena.store(io.dtype, ob + i * t, v);
            }
        }
        // §II-A banded window op: every output element is produced by the
        // exact arithmetic of the inner (full) op — padding and clipping
        // use the full-frame geometry, only the loop bounds and the
        // band-local addressing differ. Bit-identity with the unsplit op
        // follows element-wise.
        OpKind::Band(b) => {
            let (xs, os) = (io.in_shapes[0], io.out_shape);
            let (iw, id) = (xs.w(), xs.c());
            let (obh, ow, od) = (os.h(), os.w(), os.c());
            let ph = b.pad_h() as isize;
            let (ib, ob) = (io.in_regions[0].base, io.out_region.base);
            match b.inner.as_ref() {
                OpKind::Conv2D(p) => {
                    let pw = pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1) as isize;
                    let (wts, bias) = (&io.weights[0], &io.weights[1]);
                    ensure!(wts.len() == p.kernel.0 * p.kernel.1 * id * od, "band conv weight size");
                    for oyl in 0..obh {
                        let oy = b.out_row0 + oyl;
                        for ox in 0..ow {
                            let y0 = oy as isize * p.stride.0 as isize - ph;
                            let x0 = ox as isize * p.stride.1 as isize - pw;
                            for oc in 0..od {
                                let mut total = bias[oc];
                                for ky in 0..p.kernel.0 {
                                    let iy = y0 + (ky * p.dilation.0) as isize;
                                    if iy < 0 || iy as usize >= b.full_in_h {
                                        continue;
                                    }
                                    let iyl = iy as usize - b.in_row0;
                                    for kx in 0..p.kernel.1 {
                                        let ix = x0 + (kx * p.dilation.1) as isize;
                                        if ix < 0 || ix as usize >= iw {
                                            continue;
                                        }
                                        for ic in 0..id {
                                            let ioff = ((iyl * iw + ix as usize) * id + ic) * t;
                                            let v = arena.load(io.dtype, ib + ioff);
                                            let wv = wts[((ky * p.kernel.1 + kx) * id + ic) * od + oc];
                                            total += v * wv;
                                        }
                                    }
                                }
                                let ooff = ((oyl * ow + ox) * od + oc) * t;
                                arena.store(io.dtype, ob + ooff, act(total, p.act));
                            }
                        }
                    }
                }
                OpKind::DepthwiseConv2D(p) => {
                    let mult = p.depth_multiplier;
                    let pw = pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1) as isize;
                    let (wts, bias) = (&io.weights[0], &io.weights[1]);
                    ensure!(wts.len() == p.kernel.0 * p.kernel.1 * id * mult, "band dw weight size");
                    for oyl in 0..obh {
                        let oy = b.out_row0 + oyl;
                        for ox in 0..ow {
                            let y0 = oy as isize * p.stride.0 as isize - ph;
                            let x0 = ox as isize * p.stride.1 as isize - pw;
                            for ic in 0..id {
                                for m in 0..mult {
                                    let oc = ic * mult + m;
                                    let mut total = bias[oc.min(bias.len() - 1)];
                                    for ky in 0..p.kernel.0 {
                                        let iy = y0 + (ky * p.dilation.0) as isize;
                                        if iy < 0 || iy as usize >= b.full_in_h {
                                            continue;
                                        }
                                        let iyl = iy as usize - b.in_row0;
                                        for kx in 0..p.kernel.1 {
                                            let ix = x0 + (kx * p.dilation.1) as isize;
                                            if ix < 0 || ix as usize >= iw {
                                                continue;
                                            }
                                            let ioff = ((iyl * iw + ix as usize) * id + ic) * t;
                                            let v = arena.load(io.dtype, ib + ioff);
                                            let wv = wts[((ky * p.kernel.1 + kx) * id + ic) * mult + m];
                                            total += v * wv;
                                        }
                                    }
                                    let ooff = ((oyl * ow + ox) * od + oc) * t;
                                    arena.store(io.dtype, ob + ooff, act(total, p.act));
                                }
                            }
                        }
                    }
                }
                OpKind::Pool(p) => {
                    let pw = pad_before(iw, ow, p.kernel.1, p.stride.1, 1) as isize;
                    for oyl in 0..obh {
                        let oy = b.out_row0 + oyl;
                        for ox in 0..ow {
                            let y0 = oy as isize * p.stride.0 as isize - ph;
                            let x0 = ox as isize * p.stride.1 as isize - pw;
                            for c in 0..od {
                                let mut acc = match p.kind {
                                    PoolKind::Max => f32::NEG_INFINITY,
                                    PoolKind::Avg => 0.0,
                                };
                                let mut n = 0usize;
                                for ky in 0..p.kernel.0 {
                                    let iy = y0 + ky as isize;
                                    if iy < 0 || iy as usize >= b.full_in_h {
                                        continue;
                                    }
                                    let iyl = iy as usize - b.in_row0;
                                    for kx in 0..p.kernel.1 {
                                        let ix = x0 + kx as isize;
                                        if ix < 0 || ix as usize >= iw {
                                            continue;
                                        }
                                        let v = arena.load(io.dtype, ib + ((iyl * iw + ix as usize) * id + c) * t);
                                        match p.kind {
                                            PoolKind::Max => {
                                                if v > acc {
                                                    acc = v;
                                                }
                                            }
                                            PoolKind::Avg => acc += v,
                                        }
                                        n += 1;
                                    }
                                }
                                let v = match p.kind {
                                    PoolKind::Max => acc,
                                    PoolKind::Avg => acc / n.max(1) as f32,
                                };
                                arena.store(io.dtype, ob + ((oyl * ow + ox) * od + c) * t, v);
                            }
                        }
                    }
                }
                OpKind::Unary(u) => {
                    // rows map 1:1: the band is a contiguous input sub-range
                    let delta = (b.out_row0 - b.in_row0) * iw * id;
                    let n = os.num_elements();
                    for i in 0..n {
                        let v = arena.load(io.dtype, ib + (delta + i) * t);
                        let r = match u {
                            crate::ir::op::UnaryKind::Relu => act(v, Activation::Relu),
                            crate::ir::op::UnaryKind::Relu6 => act(v, Activation::Relu6),
                            crate::ir::op::UnaryKind::Copy => v,
                        };
                        arena.store(io.dtype, ob + i * t, r);
                    }
                }
                other => anyhow::bail!("op kind `{}` cannot execute as a band", other.name()),
            }
        }
        OpKind::ConcatRows => {
            // row-major NHWC: row-axis concat is a sequential copy per input
            let ob = io.out_region.base;
            let mut base = 0usize;
            for (j, xs) in io.in_shapes.iter().enumerate() {
                let n = xs.num_elements();
                let ibj = io.in_regions[j].base;
                for i in 0..n {
                    let v = arena.load(io.dtype, ibj + i * t);
                    arena.store(io.dtype, ob + (base + i) * t, v);
                }
                base += n;
            }
        }
    }
    Ok(())
}

/// Generate deterministic pseudo-random weights for an op (used by the
/// interpreter and validation — the paper's technique is weight-agnostic,
/// but execution needs concrete values).
pub fn gen_weights(op: &crate::ir::graph::OpNode, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = crate::util::rng::Rng::new(seed ^ 0xD0D0_0000_0000_0000);
    op.weights
        .iter()
        .map(|w| {
            let n = w.shape.num_elements();
            // small integer-ish weights keep i8 paths well-conditioned
            (0..n).map(|_| (rng.range(0, 4) as f32) - 2.0).collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{BinaryKind, UnaryKind};

    fn f32_arena(vals: &[f32]) -> Arena {
        let mut a = Arena::new(vals.len() * 4 + 64);
        for (i, &v) in vals.iter().enumerate() {
            a.poke(DType::F32, i * 4, v);
        }
        a
    }

    #[test]
    fn relu_numerics_and_events() {
        let mut a = f32_arena(&[-1.0, 2.0, -3.0, 4.0]);
        let log = SharedLog::new();
        a.set_sink(Some(Box::new(log.clone())));
        let s = Shape::new(&[4]);
        let io = OpIo {
            in_shapes: &[&s],
            in_regions: &[Region::new(0, 16)],
            out_shape: &s,
            out_region: Region::new(16, 16),
            dtype: DType::F32,
            weights: &[],
        };
        execute_op(&OpKind::Unary(UnaryKind::Relu), &io, &mut a).unwrap();
        assert_eq!(a.read_tensor(DType::F32, Region::new(16, 16), 4), vec![0.0, 2.0, 0.0, 4.0]);
        let events = log.take_events();
        // 4 loads interleaved with 4 stores, perfectly diagonal (Fig 3a)
        assert_eq!(events.len(), 8);
        assert_eq!(events[0].kind, EventKind::Load);
        assert_eq!(events[1].kind, EventKind::Store);
        assert_eq!(events[0].addr, 0);
        assert_eq!(events[1].addr, 16);
        assert_eq!(events[7].addr as usize, 16 + 3 * 4);
    }

    #[test]
    fn binary_add() {
        let mut a = f32_arena(&[1.0, 2.0, 10.0, 20.0]);
        let s = Shape::new(&[2]);
        let io = OpIo {
            in_shapes: &[&s, &s],
            in_regions: &[Region::new(0, 8), Region::new(8, 8)],
            out_shape: &s,
            out_region: Region::new(16, 8),
            dtype: DType::F32,
            weights: &[],
        };
        execute_op(&OpKind::Binary(BinaryKind::Add), &io, &mut a).unwrap();
        assert_eq!(a.read_tensor(DType::F32, Region::new(16, 8), 2), vec![11.0, 22.0]);
    }

    #[test]
    fn i8_saturates() {
        let mut a = Arena::new(8);
        a.poke(DType::I8, 0, 200.0);
        assert_eq!(a.peek(DType::I8, 0), 127.0);
        a.poke(DType::I8, 1, -300.0);
        assert_eq!(a.peek(DType::I8, 1), -128.0);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut a = f32_arena(&[1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let s = Shape::new(&[2, 3]);
        let io = OpIo {
            in_shapes: &[&s],
            in_regions: &[Region::new(0, 24)],
            out_shape: &s,
            out_region: Region::new(24, 24),
            dtype: DType::F32,
            weights: &[],
        };
        execute_op(&OpKind::Softmax, &io, &mut a).unwrap();
        let out = a.read_tensor(DType::F32, Region::new(24, 24), 6);
        let r0: f32 = out[..3].iter().sum();
        let r1: f32 = out[3..].iter().sum();
        assert!((r0 - 1.0).abs() < 1e-5 && (r1 - 1.0).abs() < 1e-5);
        assert!(out[2] > out[1] && out[1] > out[0]);
    }
}
