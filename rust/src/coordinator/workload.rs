//! Workload generation: Poisson (open-loop) request arrivals with
//! deterministic synthetic payloads.

use crate::util::rng::Rng;
use std::time::Duration;

/// An open-loop arrival process.
#[derive(Debug, Clone)]
pub struct Workload {
    rng: Rng,
    /// mean arrival rate, requests/second
    pub rate: f64,
    /// elements per request payload
    pub elems: usize,
}

impl Workload {
    pub fn new(seed: u64, rate: f64, elems: usize) -> Self {
        Workload {
            rng: Rng::new(seed),
            rate,
            elems,
        }
    }

    /// Next inter-arrival gap (exponential with mean `1/rate`).
    pub fn next_gap(&mut self) -> Duration {
        Duration::from_secs_f64(self.rng.exp(self.rate))
    }

    /// Deterministic payload for request `id`.
    pub fn payload(&mut self, id: u64) -> Vec<f32> {
        let mut r = Rng::new(0x9A71_0AD ^ id);
        (0..self.elems).map(|_| r.uniform(-1.0, 1.0)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaps_average_to_rate() {
        let mut w = Workload::new(1, 1000.0, 4);
        let n = 5000;
        let total: f64 = (0..n).map(|_| w.next_gap().as_secs_f64()).sum();
        let mean = total / n as f64;
        assert!((mean - 0.001).abs() < 0.0002, "mean gap {mean}");
    }

    #[test]
    fn payload_deterministic_per_id() {
        let mut w = Workload::new(1, 10.0, 8);
        assert_eq!(w.payload(7), w.payload(7));
        assert_ne!(w.payload(7), w.payload(8));
    }
}
