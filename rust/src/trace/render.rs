//! Figure generators (Fig 1, 2, 3, 6, 9).

use super::raster::{EventCounter, RasterSink, Shared};
use crate::ir::graph::{Graph, TensorId};
use crate::ir::op::OpKind;
use crate::ir::{DType, Shape};
use crate::ops::access::for_each_step;
use crate::ops::exec::{execute_op, Arena, OpIo, Region};
use crate::overlap::analytic::linear_bound;
use crate::overlap::trace::dummy_weights;
use crate::planner::Plan;
use anyhow::Result;

/// Fig 1 / Fig 9: buffer allocation map. Rows = execution slots (scope
/// axis), columns = arena memory buckets; each tensor's rectangle is
/// drawn with a rotating letter, `#` marking peak-defining buffers.
pub fn alloc_map_ascii(graph: &Graph, plan: &Plan, width: usize) -> String {
    let graph = plan.graph_for(graph); // split plans index the rewritten graph
    let peak = plan.peak().max(1);
    let n_slots = plan.order.0.len() + 1;
    let mut rows = vec![vec!['.'; width]; n_slots];
    let letters: Vec<char> = ('a'..='z').collect();
    for t in 0..graph.tensors.len() {
        let (Some(off), Some(scope)) = (plan.alloc.offsets[t], plan.scopes.scopes[t]) else {
            continue;
        };
        let size = graph.tensor(TensorId(t)).size_bytes();
        let c0 = off * width / peak;
        let c1 = (((off + size) * width).div_ceil(peak)).min(width);
        let peak_defining = off + size == peak;
        let ch = if peak_defining { '#' } else { letters[t % letters.len()] };
        for row in rows.iter_mut().take(scope.end.min(n_slots - 1) + 1).skip(scope.start) {
            for cell in row.iter_mut().take(c1).skip(c0) {
                *cell = ch;
            }
        }
    }
    let mut s = format!(
        "# {} — peak {} KB ({} slots x {} B/col)\n",
        graph.name,
        peak / 1024,
        n_slots,
        peak / width
    );
    for row in rows {
        s.push_str(&row.iter().collect::<String>());
        s.push('\n');
    }
    s
}

/// Fig 1 / Fig 9 data: CSV `tensor,offset,size,scope_start,scope_end`.
pub fn alloc_map_csv(graph: &Graph, plan: &Plan) -> String {
    let graph = plan.graph_for(graph); // split plans index the rewritten graph
    let mut s = String::from("tensor,offset,size,scope_start,scope_end\n");
    for t in 0..graph.tensors.len() {
        let (Some(off), Some(scope)) = (plan.alloc.offsets[t], plan.scopes.scopes[t]) else {
            continue;
        };
        s.push_str(&format!(
            "{},{},{},{},{}\n",
            graph.tensor(TensorId(t)).name,
            off,
            graph.tensor(TensorId(t)).size_bytes(),
            scope.start,
            scope.end
        ));
    }
    s
}

/// Fig 2: full-model memory access raster under `plan`'s layout.
/// Two passes: count events, then rasterise.
pub fn model_raster(
    graph: &Graph,
    plan: &Plan,
    seed: u64,
    t_buckets: usize,
    m_buckets: usize,
) -> Result<RasterSink> {
    let inputs: Vec<Vec<f32>> = graph
        .inputs
        .iter()
        .map(|&t| crate::interp::gen_input(graph, t, seed))
        .collect();
    // pass 1: count
    let counter = Shared::new(EventCounter::default());
    run_traced(graph, plan, &inputs, seed, Box::new(counter.clone()))?;
    let total = crate::util::sync::lock(&counter.0).count;
    // pass 2: raster
    let raster = Shared::new(RasterSink::new(plan.peak(), total, t_buckets, m_buckets));
    run_traced(graph, plan, &inputs, seed, Box::new(raster.clone()))?;
    let inner = std::sync::Arc::try_unwrap(raster.0)
        .map_err(|_| anyhow::anyhow!("raster still shared"))?
        .into_inner()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    Ok(inner)
}

fn run_traced(
    graph: &Graph,
    plan: &Plan,
    inputs: &[Vec<f32>],
    seed: u64,
    sink: Box<dyn crate::ops::exec::EventSink>,
) -> Result<()> {
    use crate::ops::exec::gen_weights;
    let graph = plan.graph_for(graph); // split plans index the rewritten graph
    let regions: Vec<Option<Region>> = (0..graph.tensors.len())
        .map(|t| {
            plan.alloc.offsets[t].map(|off| Region::new(off, graph.tensor(TensorId(t)).size_bytes()))
        })
        .collect();
    let mut arena = Arena::new(plan.peak());
    for (&t, data) in graph.inputs.iter().zip(inputs) {
        arena.write_tensor(graph.tensor(t).dtype, regions[t.0].unwrap(), data);
    }
    arena.set_sink(Some(sink));
    for &opid in &plan.order.0 {
        let op = graph.op(opid);
        let in_shapes: Vec<&Shape> = op.inputs.iter().map(|&t| &graph.tensor(t).shape).collect();
        let in_regions: Vec<Region> = op.inputs.iter().map(|&t| regions[t.0].unwrap()).collect();
        let weights = gen_weights(op, seed ^ op.weight_key(opid.0) as u64);
        let io = OpIo {
            in_shapes: &in_shapes,
            in_regions: &in_regions,
            out_shape: &graph.tensor(op.output).shape,
            out_region: regions[op.output.0].unwrap(),
            dtype: graph.tensor(op.output).dtype,
            weights: &weights,
        };
        execute_op(&op.kind, &io, &mut arena)?;
    }
    arena.set_sink(None);
    Ok(())
}

/// Fig 3: single-op access-pattern raster. Buffers are laid out
/// input(s)-then-output, disjoint, like the paper's per-op traces.
pub fn op_raster(
    kind: &OpKind,
    in_shapes: &[&Shape],
    dtype: DType,
    t_buckets: usize,
    m_buckets: usize,
) -> Result<RasterSink> {
    let out_shape = crate::ops::infer_output(kind, in_shapes)?;
    let t = dtype.size_bytes();
    let mut base = 0usize;
    let in_regions: Vec<Region> = in_shapes
        .iter()
        .map(|s| {
            let r = Region::new(base, s.num_elements() * t);
            base += r.len;
            r
        })
        .collect();
    let out_region = Region::new(base, out_shape.num_elements() * t);
    let arena_size = out_region.end();

    let run = |sink: Box<dyn crate::ops::exec::EventSink>| -> Result<()> {
        let mut arena = Arena::new(arena_size);
        let mut rng = crate::util::rng::Rng::new(0xF16_3);
        for (s, r) in in_shapes.iter().zip(&in_regions) {
            let data: Vec<f32> = (0..s.num_elements()).map(|_| rng.uniform(-2.0, 2.0)).collect();
            arena.write_tensor(dtype, *r, &data);
        }
        let weights = dummy_weights(kind, in_shapes, dtype);
        arena.set_sink(Some(sink));
        let io = OpIo {
            in_shapes,
            in_regions: &in_regions,
            out_shape: &out_shape,
            out_region,
            dtype,
            weights: &weights,
        };
        execute_op(kind, &io, &mut arena)?;
        arena.set_sink(None);
        Ok(())
    };

    let counter = Shared::new(EventCounter::default());
    run(Box::new(counter.clone()))?;
    let total = crate::util::sync::lock(&counter.0).count;
    let raster = Shared::new(RasterSink::new(arena_size, total, t_buckets, m_buckets));
    run(Box::new(raster.clone()))?;
    Ok(std::sync::Arc::try_unwrap(raster.0)
        .map_err(|_| anyhow::anyhow!("raster still shared"))?
        .into_inner()
        .unwrap_or_else(|poisoned| poisoned.into_inner()))
}

/// Fig 6 data: sampled `(step, min_read_offset)` pairs of a window op,
/// plus the analytic bound `minR(i) = max(0, a·i + b)` — CSV columns
/// `i,min_read,bound`.
pub fn fig6_csv(kind: &OpKind, in_shapes: &[&Shape], samples: usize) -> Result<String> {
    let out_shape = crate::ops::infer_output(kind, in_shapes)?;
    let lb = linear_bound(kind, in_shapes, &out_shape)
        .ok_or_else(|| anyhow::anyhow!("op outside the analytic family"))?;
    let steps = crate::ops::access::step_count(kind, in_shapes, &out_shape);
    let stride = (steps / samples.max(1)).max(1);
    let mut s = String::from("i,min_read,bound\n");
    let mut i = 0usize;
    for_each_step(kind, in_shapes, &out_shape, &mut |_w, reads| {
        if i % stride == 0 {
            if let Some(r) = reads[0] {
                let bound = (lb.a * i as f64 + lb.b).max(0.0);
                s.push_str(&format!("{i},{r},{bound:.1}\n"));
            }
        }
        i += 1;
    });
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, DepthwiseParams, Padding, UnaryKind};
    use crate::models;
    use crate::planner::Planner;

    #[test]
    fn alloc_map_renders() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let map = alloc_map_ascii(&g, &plan, 60);
        assert!(map.contains('#'), "peak-defining buffer marked");
        let csv = alloc_map_csv(&g, &plan);
        assert!(csv.lines().count() > 5);
    }

    #[test]
    fn model_raster_runs() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let r = model_raster(&g, &plan, 1, 40, 60).unwrap();
        let nonempty: u32 = r.grid.iter().flatten().map(|c| c.total()).sum();
        assert!(nonempty > 1000);
    }

    #[test]
    fn fig3_relu_is_diagonal() {
        let s = Shape::hwc(16, 16, 4);
        let r = op_raster(
            &OpKind::Unary(UnaryKind::Relu),
            &[&s],
            DType::F32,
            16,
            32,
        )
        .unwrap();
        // first time-bucket activity must be in low memory, last in high
        let first_active: Vec<usize> = (0..32).filter(|&m| r.grid[0][m].total() > 0).collect();
        let last_active: Vec<usize> = (0..32).filter(|&m| r.grid[15][m].total() > 0).collect();
        assert!(first_active.iter().min() < last_active.iter().min());
    }

    #[test]
    fn fig6_bound_below_reads() {
        let x = Shape::hwc(24, 24, 8);
        let k = OpKind::DepthwiseConv2D(DepthwiseParams {
            kernel: (3, 3),
            stride: (2, 2),
            dilation: (1, 1),
            padding: Padding::Same,
            depth_multiplier: 1,
            act: Activation::None,
        });
        let csv = fig6_csv(&k, &[&x], 50).unwrap();
        for line in csv.lines().skip(1) {
            let f: Vec<f64> = line.split(',').map(|v| v.parse().unwrap()).collect();
            assert!(f[2] <= f[1] + 1e-9, "bound above an actual read: {line}");
        }
    }
}
