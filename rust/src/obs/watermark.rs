//! Runtime arena watermark verification.
//!
//! The paper proved overlap safety by watching every load/store under a
//! modified Valgrind. This module is the in-process analogue: a
//! [`WatermarkSink`] installed on the execution [`crate::ops::exec::Arena`]
//! observes every traced memory event and tracks the *actual* high-water
//! mark (max `addr + len` touched) and the touched-byte extent, per op and
//! for the whole run. `interp::run_plan_profiled` packages the result as an
//! [`ExecProfile`] so callers can assert `observed_peak ≤ plan.peak()` —
//! the plan's promise, checked against reality instead of trusted.
//!
//! Observed can be legitimately *below* planned: input tensors are written
//! through the untraced `write_tensor` fast path, and a plan's peak also
//! covers scopes whose extents a particular input may not exercise.

use std::sync::{Arc, Mutex};

use crate::ops::exec::{EventKind, EventSink};
use crate::util::sync::lock;

/// Mutable watermark state shared between the sink (owned by the arena)
/// and the profiler that reads it between ops.
#[derive(Debug, Default)]
pub struct WmState {
    /// Max `addr + len` over every traced event in the run.
    pub high_water: usize,
    /// Total bytes read (loads + the read half of updates).
    pub bytes_read: u64,
    /// Total bytes written (stores + the write half of updates).
    pub bytes_written: u64,
    /// Per-op accumulators, reset by [`WmState::begin_op`].
    pub op_high_water: usize,
    pub op_bytes_read: u64,
    pub op_bytes_written: u64,
    /// Bitmap over arena bytes: which were touched by any traced event.
    touched: Vec<u64>,
}

impl WmState {
    pub fn new(arena_len: usize) -> WmState {
        WmState {
            touched: vec![0u64; arena_len.div_ceil(64)],
            ..WmState::default()
        }
    }

    /// Reset the per-op accumulators (call before each op executes).
    pub fn begin_op(&mut self) {
        self.op_high_water = 0;
        self.op_bytes_read = 0;
        self.op_bytes_written = 0;
    }

    fn on_event(&mut self, kind: EventKind, addr: usize, len: usize) {
        let end = addr + len;
        self.high_water = self.high_water.max(end);
        self.op_high_water = self.op_high_water.max(end);
        match kind {
            EventKind::Load => {
                self.bytes_read += len as u64;
                self.op_bytes_read += len as u64;
            }
            EventKind::Store => {
                self.bytes_written += len as u64;
                self.op_bytes_written += len as u64;
            }
            EventKind::Update => {
                // read-modify-write touches the range twice
                self.bytes_read += len as u64;
                self.bytes_written += len as u64;
                self.op_bytes_read += len as u64;
                self.op_bytes_written += len as u64;
            }
        }
        for b in addr..end.min(self.touched.len() * 64) {
            self.touched[b / 64] |= 1u64 << (b % 64);
        }
    }

    /// Number of distinct arena bytes touched by any traced event.
    pub fn touched_bytes(&self) -> usize {
        self.touched.iter().map(|w| w.count_ones() as usize).sum()
    }
}

/// [`EventSink`] forwarding into a shared [`WmState`]. Clone one handle
/// into the arena via `set_sink`, keep the other to read results. The
/// state is behind `Arc<Mutex>` so the sink can ride a pooled arena
/// across fleet worker threads.
#[derive(Clone)]
pub struct WatermarkSink(pub Arc<Mutex<WmState>>);

impl WatermarkSink {
    pub fn new(arena_len: usize) -> WatermarkSink {
        WatermarkSink(Arc::new(Mutex::new(WmState::new(arena_len))))
    }

    /// Snapshot the run-wide high-water mark.
    pub fn high_water(&self) -> usize {
        lock(&self.0).high_water
    }
}

impl EventSink for WatermarkSink {
    fn event(&mut self, kind: EventKind, addr: usize, len: usize) {
        lock(&self.0).on_event(kind, addr, len);
    }
}

/// Typed watermark-invariant violation: a traced access went past the
/// peak the plan promised. In a DMO arena that means a store may have
/// clobbered a live buffer, so the result cannot be trusted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WatermarkViolation {
    pub model: String,
    /// Max traced `addr + len` over the run.
    pub observed_peak: usize,
    /// `plan.peak()` — the planner's promise.
    pub planned_peak: usize,
}

impl std::fmt::Display for WatermarkViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "watermark violation in model '{}': observed peak {} B exceeds planned peak {} B",
            self.model, self.observed_peak, self.planned_peak
        )
    }
}

impl std::error::Error for WatermarkViolation {}

/// Observed execution profile of one op under a planned arena.
#[derive(Debug, Clone)]
pub struct OpProfile {
    /// Position in the plan's execution order.
    pub step: usize,
    /// Graph op id.
    pub op: usize,
    /// Op display name from the graph.
    pub name: String,
    /// Wall-clock execution time.
    pub wall_us: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Max `addr + len` this op touched.
    pub high_water: usize,
    /// The planned extent available to this op: end of its output region
    /// (the allocator's placement promise for the step).
    pub planned_extent: usize,
}

/// Observed execution profile of a full planned run.
#[derive(Debug, Clone)]
pub struct ExecProfile {
    pub model: String,
    /// `plan.peak()` — what the planner promised.
    pub planned_peak: usize,
    /// Max traced `addr + len` over the run — what actually happened.
    pub observed_peak: usize,
    /// Distinct arena bytes touched by traced events.
    pub touched_bytes: usize,
    /// Size of the arena the run executed in.
    pub arena_bytes: usize,
    pub ops: Vec<OpProfile>,
}

impl ExecProfile {
    /// The watermark invariant: every traced access stayed within the
    /// planned peak. (`observed ≤ planned` — observed may be lower because
    /// inputs are written untraced and not every extent is exercised.)
    pub fn within_plan(&self) -> bool {
        self.verify().is_ok()
    }

    /// Typed form of the invariant check: `Err(WatermarkViolation)` when
    /// a traced access exceeded the planned peak.
    pub fn verify(&self) -> Result<(), WatermarkViolation> {
        if self.observed_peak <= self.planned_peak {
            Ok(())
        } else {
            Err(WatermarkViolation {
                model: self.model.clone(),
                observed_peak: self.observed_peak,
                planned_peak: self.planned_peak,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_high_water_and_touched() {
        let mut sink = WatermarkSink::new(128);
        sink.event(EventKind::Store, 0, 16);
        sink.event(EventKind::Load, 8, 16);
        sink.event(EventKind::Update, 100, 4);
        let st = lock(&sink.0);
        assert_eq!(st.high_water, 104);
        assert_eq!(st.bytes_read, 16 + 4);
        assert_eq!(st.bytes_written, 16 + 4);
        // [0,24) plus [100,104) touched
        assert_eq!(st.touched_bytes(), 24 + 4);
    }

    #[test]
    fn per_op_resets() {
        let mut sink = WatermarkSink::new(64);
        sink.event(EventKind::Store, 0, 32);
        lock(&sink.0).begin_op();
        sink.event(EventKind::Load, 4, 8);
        let st = lock(&sink.0);
        assert_eq!(st.op_high_water, 12);
        assert_eq!(st.op_bytes_read, 8);
        assert_eq!(st.op_bytes_written, 0);
        assert_eq!(st.high_water, 32, "global watermark survives the reset");
    }
}
