//! Fig 8: multi-threaded layer execution trace (§III-F).
//!
//! Multi-threaded kernels usually give each thread a contiguous slice of
//! the output; the interleaved writes destroy the diagonal access pattern
//! and make it non-deterministic, which is why the paper excludes
//! multi-threaded implementations from DMO. We reproduce the *shape* of
//! that trace deterministically: the op is executed once per thread-shard
//! (each shard owning a contiguous band of output rows) and the per-shard
//! event streams are interleaved round-robin — the same single-core
//! interleaving the paper's Valgrind tool produced ("interleaves threads
//! on a single core so does not precisely reproduce true multi-threaded
//! behaviour").
//!
//! §III-F's constructive note is also modelled: [`interleaved_os`] shows
//! that if threads take *interleaved* rows and synchronise within a
//! bounded skew, a safe overlap still exists (smaller by the skew).

use super::raster::RasterSink;
use crate::ir::op::{Conv2DParams, OpKind};
use crate::ir::{DType, Shape};
use crate::ops::exec::{execute_op, Arena, Event, EventKind, EventSink, OpIo, Region, SharedLog};
use crate::overlap::trace::dummy_weights;
use anyhow::Result;

/// Execute `conv` sharded across `threads` contiguous output bands and
/// return the interleaved event stream.
pub fn sharded_conv_events(
    p: &Conv2DParams,
    in_shape: &Shape,
    dtype: DType,
    threads: usize,
) -> Result<Vec<Event>> {
    let kind = OpKind::Conv2D(p.clone());
    let out_shape = crate::ops::infer_output(&kind, &[in_shape])?;
    let t = dtype.size_bytes();
    let in_region = Region::new(0, in_shape.num_elements() * t);
    let out_region = Region::new(in_region.len, out_shape.num_elements() * t);
    let oh = out_shape.h();
    let band = oh.div_ceil(threads);

    let mut streams: Vec<Vec<Event>> = Vec::new();
    for th in 0..threads {
        let y0 = th * band;
        let y1 = ((th + 1) * band).min(oh);
        if y0 >= y1 {
            continue;
        }
        // run the full op but keep only this band's events: each thread's
        // loop nest is the reference kernel restricted to its rows, so we
        // re-run with a sub-op whose output rows are [y0, y1) by offsetting
        // the output region and clipping input rows via padding arithmetic.
        let log = SharedLog::new();
        let mut arena = Arena::new(out_region.end());
        let mut rng = crate::util::rng::Rng::new(0xF18 + th as u64);
        let data: Vec<f32> = (0..in_shape.num_elements()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        arena.write_tensor(dtype, in_region, &data);
        let weights = dummy_weights(&kind, &[in_shape], dtype);
        arena.set_sink(Some(Box::new(log.clone())));
        let io = OpIo {
            in_shapes: &[in_shape],
            in_regions: &[in_region],
            out_shape: &out_shape,
            out_region,
            dtype,
            weights: &weights,
        };
        execute_op(&kind, &io, &mut arena)?;
        arena.set_sink(None);
        // keep events whose output row falls in [y0, y1); input loads keep
        // company with their step's writes by position in the stream
        let row_bytes = out_shape.w() * out_shape.c() * t;
        let events = log.take_events();
        let mut band_events = Vec::new();
        let mut keep = false;
        for e in events {
            if matches!(e.kind, EventKind::Store | EventKind::Update)
                && out_region.contains(e.addr as usize)
            {
                let row = (e.addr as usize - out_region.base) / row_bytes;
                keep = row >= y0 && row < y1;
                if keep {
                    band_events.push(e);
                }
            } else if keep {
                band_events.push(e);
            }
        }
        streams.push(band_events);
    }

    // round-robin interleave (the paper's single-core thread interleaving)
    let mut out = Vec::new();
    let mut idx = vec![0usize; streams.len()];
    let chunk = 64usize; // events per scheduling quantum
    loop {
        let mut progressed = false;
        for (s, stream) in streams.iter().enumerate() {
            let i = idx[s];
            if i < stream.len() {
                let j = (i + chunk).min(stream.len());
                out.extend_from_slice(&stream[i..j]);
                idx[s] = j;
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }
    Ok(out)
}

/// Raster a pre-recorded event stream (Fig 8 rendering).
pub fn raster_events(events: &[Event], arena_bytes: usize, t_buckets: usize, m_buckets: usize) -> RasterSink {
    let mut r = RasterSink::new(arena_bytes, events.len() as u64, t_buckets, m_buckets);
    for e in events {
        r.event(e.kind, e.addr as usize, e.len as usize);
    }
    r
}

/// §III-F: safe overlap for an interleaved-row multi-threaded
/// implementation with a bounded skew of `skew_rows` output rows —
/// the single-threaded `O_s` shrunk by the skew's write lead.
pub fn interleaved_os(
    kind: &OpKind,
    in_shapes: &[&Shape],
    out_shape: &Shape,
    dtype: DType,
    skew_rows: usize,
) -> usize {
    let single = crate::overlap::algorithmic::os_streaming(kind, in_shapes, out_shape, dtype);
    let row_bytes = out_shape.w() * out_shape.c() * dtype.size_bytes();
    single.single().saturating_sub(skew_rows * row_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Padding};

    fn conv5() -> (Conv2DParams, Shape) {
        (
            Conv2DParams {
                kernel: (5, 5),
                stride: (1, 1),
                dilation: (1, 1),
                padding: Padding::Same,
                out_channels: 4,
                act: Activation::None,
            },
            Shape::hwc(24, 24, 3),
        )
    }

    #[test]
    fn four_threads_write_four_regions_early() {
        let (p, x) = conv5();
        let events = sharded_conv_events(&p, &x, DType::F32, 4).unwrap();
        assert!(!events.is_empty());
        // within the first 2% of events, stores must hit ≥3 distinct
        // quarters of the output buffer (Fig 8's key feature)
        let out_base = x.num_elements() * 4;
        let out_len = 24 * 24 * 4 * 4;
        let head = &events[..events.len() / 50];
        let mut quarters = std::collections::BTreeSet::new();
        for e in head {
            if matches!(e.kind, EventKind::Store) {
                let off = e.addr as usize - out_base;
                quarters.insert(off * 4 / out_len);
            }
        }
        assert!(quarters.len() >= 3, "only {quarters:?}");
    }

    #[test]
    fn interleaved_os_shrinks_with_skew() {
        let (p, x) = conv5();
        let kind = OpKind::Conv2D(p);
        let out = crate::ops::infer_output(&kind, &[&x]).unwrap();
        let o0 = interleaved_os(&kind, &[&x], &out, DType::F32, 0);
        let o2 = interleaved_os(&kind, &[&x], &out, DType::F32, 2);
        assert!(o0 > o2);
        assert_eq!(o0 - o2, 2 * 24 * 4 * 4);
    }
}
