//! Serializable plan artifacts — cross-process reuse of a computed plan.
//!
//! §II-D makes DMO pre-allocation an *offline* step: the overlap
//! geometry is computed once and reused for every inference. A
//! [`PlanArtifact`] is the durable form of that step — a versioned JSON
//! snapshot of a validated [`Plan`](super::Plan) (execution order, byte
//! offsets, applied overlaps, the `O_s` table with its method and hash,
//! and a structural fingerprint of the graph it was planned against).
//!
//! Loading is defensive: [`PlanArtifact::to_plan`] refuses artifacts
//! whose version, graph fingerprint, or `O_s` table hash do not match,
//! and re-runs the pairwise overlap-safety checker on the reconstructed
//! layout before handing it out. The checker trusts the stored `O_s`
//! budgets (recomputing them would erase the point of caching); for the
//! full bit-exactness proof, run the layout through
//! [`crate::interp::run_planned_artifact`], which executes it against a
//! disjoint reference.

use super::alloc::{Allocation, AppliedOverlap, Heuristic, OsTable};
use super::error::PlanError;
use super::order::{self, ExecOrder, Strategy};
use super::scope::analyse;
use super::search::SearchStats;
use super::{Plan, PlanRewrite};
use crate::ir::graph::{Graph, OpId, TensorId};
use crate::ir::rewrite::{self, RewriteSpec, SplitSpec};
use crate::overlap::Method;
use crate::util::fnv::Fnv;
use crate::util::json::{num, obj, s, Json};
use std::path::Path;

/// Structural fingerprint of a graph: name, tensors (shape, dtype,
/// kind), ops (kind incl. parameters, input/output wiring) and the
/// input/output lists. Two graphs plan identically iff these match, so
/// the fingerprint is what gates artifact reuse.
pub fn graph_fingerprint(graph: &Graph) -> u64 {
    let mut h = Fnv::new();
    h.str(&graph.name);
    h.word(graph.tensors.len());
    for t in &graph.tensors {
        h.word(t.shape.0.len());
        for &d in &t.shape.0 {
            h.word(d);
        }
        h.str(t.dtype.name());
        h.str(&format!("{:?}", t.kind));
    }
    h.word(graph.ops.len());
    for op in &graph.ops {
        h.str(&format!("{:?}", op.kind));
        h.word(op.inputs.len());
        for &t in &op.inputs {
            h.word(t.0);
        }
        h.word(op.output.0);
        // weight provenance changes execution (which stream an op
        // draws), so rewritten graphs hash it; base graphs (all `None`)
        // keep their pre-split fingerprints
        if let Some(ws) = op.weight_seed {
            h.str("ws");
            h.word(ws);
        }
    }
    h.word(graph.inputs.len());
    for &t in &graph.inputs {
        h.word(t.0);
    }
    h.word(graph.outputs.len());
    for &t in &graph.outputs {
        h.word(t.0);
    }
    h.finish()
}

/// Content hash of an `O_s` table (method + every per-input budget).
fn os_table_hash(method: Method, per_op: &[Vec<usize>]) -> u64 {
    let mut h = Fnv::new();
    h.str(method.name());
    h.word(per_op.len());
    for row in per_op {
        h.word(row.len());
        for &v in row {
            h.word(v);
        }
    }
    h.finish()
}

fn hex(v: u64) -> String {
    format!("{v:016x}")
}

fn parse_hex(text: &str) -> Result<u64, PlanError> {
    u64::from_str_radix(text, 16)
        .map_err(|_| PlanError::Malformed(format!("bad hex hash `{text}`")))
}

/// A versioned, serializable snapshot of a validated [`Plan`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanArtifact {
    /// Format version ([`PlanArtifact::VERSION`] when written by this
    /// build).
    pub version: u64,
    /// Name of the graph the plan was computed for.
    pub model: String,
    /// [`graph_fingerprint`] of that graph.
    pub fingerprint: u64,
    /// Winning serialisation strategy.
    pub strategy: Strategy,
    /// Winning allocation heuristic.
    pub heuristic: Heuristic,
    /// `O_s` engine the table was computed with.
    pub method: Method,
    /// Execution order (op indices).
    pub order: Vec<usize>,
    /// Byte offset per tensor (`None` = tensor has no arena buffer).
    pub offsets: Vec<Option<usize>>,
    /// Arena bytes required.
    pub peak: usize,
    /// Applied overlaps as `(op, input, output, bytes)`.
    pub applied: Vec<(usize, usize, usize, usize)>,
    /// Per-(op, input) `O_s` budgets in bytes.
    pub os_per_op: Vec<Vec<usize>>,
    /// Content hash of `method` + `os_per_op`.
    pub os_hash: u64,
    /// Search provenance, present iff `strategy` is the order search
    /// (format v2; absent from v1 artifacts, which predate search).
    pub search: Option<SearchStats>,
    /// §II-A rewrite passes the plan was computed on, in application
    /// order (format v4; empty for unrewritten plans and for v1/v2
    /// artifacts; v3 files stored pair splits under a `splits` key,
    /// which loads into the same field). When non-empty,
    /// `order`/`offsets`/`os` index the re-derived rewritten graph, and
    /// `fingerprint` still names the *base* graph the consumer passes
    /// to [`PlanArtifact::to_plan`].
    pub rewrites: Vec<RewriteSpec>,
    /// Fingerprint of the rewritten graph (present iff `rewrites` is
    /// non-empty) — re-verified after re-deriving the rewrite on load.
    pub rewrite_fingerprint: Option<u64>,
}

/// Serialise one rewrite spec in the v4 `rewrites` array shape.
fn rewrite_spec_json(spec: &RewriteSpec) -> Json {
    match spec {
        RewriteSpec::PairSplit(sp) => obj(vec![
            ("kind", s("pair")),
            ("first", num(sp.first)),
            ("second", num(sp.second)),
            ("parts", num(sp.parts)),
        ]),
        RewriteSpec::ChainSplit { ops, parts } => obj(vec![
            ("kind", s("chain")),
            ("ops", Json::Arr(ops.iter().map(|o| num(o.0)).collect())),
            ("parts", num(*parts)),
        ]),
    }
}

impl PlanArtifact {
    /// Artifact format version this build reads and writes. Version 1
    /// (pre order-search, no `search` field), version 2 (no split
    /// rewrites) and version 3 (pair splits only, stored under a
    /// `splits` key) are still accepted by [`PlanArtifact::load`] /
    /// [`PlanArtifact::to_plan`].
    pub const VERSION: u64 = 4;

    /// Marker stored in the `kind` field of every artifact file.
    pub const KIND: &'static str = "dmo-plan-artifact";

    /// Snapshot a validated plan for `graph` — the *base* graph the
    /// planning session ran on. When the plan carries a split rewrite,
    /// the artifact records the specs (and the rewritten graph's
    /// fingerprint) so the rewrite is re-derived, not trusted, on load.
    pub fn from_plan(graph: &Graph, plan: &Plan) -> PlanArtifact {
        PlanArtifact {
            version: Self::VERSION,
            model: graph.name.clone(),
            fingerprint: graph_fingerprint(graph),
            strategy: plan.strategy,
            heuristic: plan.heuristic,
            method: plan.os.method,
            order: plan.order.0.iter().map(|op| op.0).collect(),
            offsets: plan.alloc.offsets.clone(),
            peak: plan.alloc.peak,
            applied: plan
                .alloc
                .applied
                .iter()
                .map(|a| (a.op.0, a.input.0, a.output.0, a.bytes))
                .collect(),
            os_per_op: plan.os.per_op.clone(),
            os_hash: os_table_hash(plan.os.method, &plan.os.per_op),
            search: plan.search,
            rewrites: plan
                .rewrite
                .as_ref()
                .map(|r| r.specs.clone())
                .unwrap_or_default(),
            rewrite_fingerprint: plan.rewrite.as_ref().map(|r| graph_fingerprint(&r.graph)),
        }
    }

    /// Serialise to the artifact JSON document.
    pub fn to_json(&self) -> Json {
        let offsets = Json::Arr(
            self.offsets
                .iter()
                .map(|o| match o {
                    Some(v) => num(*v),
                    None => Json::Null,
                })
                .collect(),
        );
        let applied = Json::Arr(
            self.applied
                .iter()
                .map(|&(op, input, output, bytes)| {
                    obj(vec![
                        ("op", num(op)),
                        ("input", num(input)),
                        ("output", num(output)),
                        ("bytes", num(bytes)),
                    ])
                })
                .collect(),
        );
        let os = Json::Arr(
            self.os_per_op
                .iter()
                .map(|row| Json::Arr(row.iter().map(|&v| num(v)).collect()))
                .collect(),
        );
        let mut fields = vec![
            ("kind", s(Self::KIND)),
            ("version", num(self.version as usize)),
            ("model", s(&self.model)),
            ("fingerprint", s(&hex(self.fingerprint))),
            ("strategy", s(self.strategy.name())),
            ("heuristic", s(self.heuristic.name())),
            ("method", s(self.method.name())),
            ("order", Json::Arr(self.order.iter().map(|&i| num(i)).collect())),
            ("offsets", offsets),
            ("peak", num(self.peak)),
            ("applied", applied),
            ("os", os),
            ("os_hash", s(&hex(self.os_hash))),
        ];
        if let Some(st) = &self.search {
            fields.push((
                "search",
                obj(vec![
                    ("beam", num(st.beam)),
                    ("budget", num(st.budget)),
                    ("expanded", num(st.expanded)),
                    ("pruned", num(st.pruned)),
                    ("orders_scored", num(st.orders_scored)),
                    ("surrogate_peak", num(st.surrogate_peak)),
                ]),
            ));
        }
        if !self.rewrites.is_empty() {
            // a v3 (or older) artifact can only describe pair splits,
            // and wrote them under the legacy `splits` key — keep that
            // byte shape so downgraded files stay readable by v3 tools
            let legacy = self.version <= 3
                && self
                    .rewrites
                    .iter()
                    .all(|r| matches!(r, RewriteSpec::PairSplit(_)));
            if legacy {
                fields.push((
                    "splits",
                    Json::Arr(
                        self.rewrites
                            .iter()
                            .map(|r| match r {
                                RewriteSpec::PairSplit(sp) => obj(vec![
                                    ("first", num(sp.first)),
                                    ("second", num(sp.second)),
                                    ("parts", num(sp.parts)),
                                ]),
                                RewriteSpec::ChainSplit { .. } => unreachable!(),
                            })
                            .collect(),
                    ),
                ));
                if let Some(fp) = self.rewrite_fingerprint {
                    fields.push(("split_fingerprint", s(&hex(fp))));
                }
            } else {
                fields.push((
                    "rewrites",
                    Json::Arr(self.rewrites.iter().map(rewrite_spec_json).collect()),
                ));
                if let Some(fp) = self.rewrite_fingerprint {
                    fields.push(("rewrite_fingerprint", s(&hex(fp))));
                }
            }
        }
        obj(fields)
    }

    /// Parse an artifact JSON document.
    pub fn from_json(v: &Json) -> Result<PlanArtifact, PlanError> {
        let field = |key: &str| {
            v.get(key)
                .ok_or_else(|| PlanError::Malformed(format!("missing field `{key}`")))
        };
        let str_field = |key: &str| {
            field(key)?
                .as_str()
                .map(|x| x.to_string())
                .ok_or_else(|| PlanError::Malformed(format!("field `{key}` must be a string")))
        };
        let usize_field = |key: &str| {
            field(key)?
                .as_usize()
                .ok_or_else(|| PlanError::Malformed(format!("field `{key}` must be a number")))
        };

        let kind = str_field("kind")?;
        if kind != Self::KIND {
            return Err(PlanError::Malformed(format!(
                "not a plan artifact (kind `{kind}`)"
            )));
        }
        let version = usize_field("version")? as u64;
        if version == 0 || version > Self::VERSION {
            return Err(PlanError::UnsupportedVersion {
                found: version,
                supported: Self::VERSION,
            });
        }

        // v4: general rewrite specs; v3 stored pair splits under the
        // legacy `splits` key — both load into `rewrites`.
        let mut rewrites = match v.get("rewrites") {
            None | Some(Json::Null) => Vec::new(),
            Some(arr) => arr
                .as_arr()
                .ok_or_else(|| PlanError::Malformed("field `rewrites` must be an array".into()))?
                .iter()
                .map(|entry| {
                    let part = |key: &str| {
                        entry
                            .get(key)
                            .and_then(|x| x.as_usize())
                            .ok_or_else(|| PlanError::Malformed(format!("bad `rewrites.{key}`")))
                    };
                    let kind = entry
                        .get("kind")
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| PlanError::Malformed("bad `rewrites.kind`".into()))?;
                    match kind {
                        "pair" => Ok(RewriteSpec::PairSplit(SplitSpec {
                            first: part("first")?,
                            second: part("second")?,
                            parts: part("parts")?,
                        })),
                        "chain" => {
                            let ops = entry
                                .get("ops")
                                .and_then(|x| x.as_arr())
                                .ok_or_else(|| {
                                    PlanError::Malformed("bad `rewrites.ops`".into())
                                })?
                                .iter()
                                .map(|x| {
                                    x.as_usize().map(OpId).ok_or_else(|| {
                                        PlanError::Malformed("bad `rewrites.ops` entry".into())
                                    })
                                })
                                .collect::<Result<Vec<_>, PlanError>>()?;
                            Ok(RewriteSpec::ChainSplit {
                                ops,
                                parts: part("parts")?,
                            })
                        }
                        other => Err(PlanError::Malformed(format!(
                            "unknown rewrite kind `{other}`"
                        ))),
                    }
                })
                .collect::<Result<Vec<_>, PlanError>>()?,
        };
        let legacy_splits = match v.get("splits") {
            None | Some(Json::Null) => Vec::new(),
            Some(arr) => arr
                .as_arr()
                .ok_or_else(|| PlanError::Malformed("field `splits` must be an array".into()))?
                .iter()
                .map(|entry| {
                    let part = |key: &str| {
                        entry
                            .get(key)
                            .and_then(|x| x.as_usize())
                            .ok_or_else(|| PlanError::Malformed(format!("bad `splits.{key}`")))
                    };
                    Ok(RewriteSpec::PairSplit(SplitSpec {
                        first: part("first")?,
                        second: part("second")?,
                        parts: part("parts")?,
                    }))
                })
                .collect::<Result<Vec<_>, PlanError>>()?,
        };
        if !rewrites.is_empty() && !legacy_splits.is_empty() {
            return Err(PlanError::Malformed(
                "artifact carries both `rewrites` and legacy `splits`".into(),
            ));
        }
        rewrites.extend(legacy_splits);
        let fp_field = |key: &str| match v.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(x) => x
                .as_str()
                .ok_or_else(|| PlanError::Malformed(format!("field `{key}` must be a string")))
                .and_then(parse_hex)
                .map(Some),
        };
        let rewrite_fingerprint = match fp_field("rewrite_fingerprint")? {
            Some(fp) => Some(fp),
            None => fp_field("split_fingerprint")?,
        };
        if !rewrites.is_empty() && rewrite_fingerprint.is_none() {
            return Err(PlanError::Malformed(
                "rewritten-plan artifact is missing `rewrite_fingerprint`".into(),
            ));
        }

        // v2: search provenance (absent from v1 and from eager/lazy wins)
        let search = match v.get("search") {
            None | Some(Json::Null) => None,
            Some(st) => {
                let part = |key: &str| {
                    st.get(key)
                        .and_then(|x| x.as_usize())
                        .ok_or_else(|| PlanError::Malformed(format!("bad `search.{key}`")))
                };
                Some(SearchStats {
                    beam: part("beam")?,
                    budget: part("budget")?,
                    expanded: part("expanded")?,
                    pruned: part("pruned")?,
                    orders_scored: part("orders_scored")?,
                    surrogate_peak: part("surrogate_peak")?,
                })
            }
        };

        let strategy_name = str_field("strategy")?;
        let mut strategy = Strategy::from_name(&strategy_name)
            .ok_or_else(|| PlanError::Malformed(format!("unknown strategy `{strategy_name}`")))?;
        // restore the exact beam/budget the winning search ran with
        if let (Strategy::Search { .. }, Some(st)) = (strategy, &search) {
            strategy = Strategy::Search {
                beam: st.beam,
                budget: st.budget,
            };
        }
        let heuristic_name = str_field("heuristic")?;
        let heuristic = Heuristic::from_name(&heuristic_name)
            .ok_or_else(|| PlanError::Malformed(format!("unknown heuristic `{heuristic_name}`")))?;
        let method_name = str_field("method")?;
        let method = Method::from_name(&method_name)
            .ok_or_else(|| PlanError::Malformed(format!("unknown O_s method `{method_name}`")))?;

        let usize_arr = |key: &str| -> Result<Vec<usize>, PlanError> {
            field(key)?
                .as_arr()
                .ok_or_else(|| PlanError::Malformed(format!("field `{key}` must be an array")))?
                .iter()
                .map(|x| {
                    x.as_usize()
                        .ok_or_else(|| PlanError::Malformed(format!("non-numeric entry in `{key}`")))
                })
                .collect()
        };

        let offsets = field("offsets")?
            .as_arr()
            .ok_or_else(|| PlanError::Malformed("field `offsets` must be an array".into()))?
            .iter()
            .map(|x| match x {
                Json::Null => Ok(None),
                other => other
                    .as_usize()
                    .map(Some)
                    .ok_or_else(|| PlanError::Malformed("bad entry in `offsets`".into())),
            })
            .collect::<Result<Vec<_>, _>>()?;

        let applied = field("applied")?
            .as_arr()
            .ok_or_else(|| PlanError::Malformed("field `applied` must be an array".into()))?
            .iter()
            .map(|entry| {
                let part = |key: &str| {
                    entry
                        .get(key)
                        .and_then(|x| x.as_usize())
                        .ok_or_else(|| PlanError::Malformed(format!("bad `applied.{key}`")))
                };
                Ok((part("op")?, part("input")?, part("output")?, part("bytes")?))
            })
            .collect::<Result<Vec<_>, PlanError>>()?;

        let os_per_op = field("os")?
            .as_arr()
            .ok_or_else(|| PlanError::Malformed("field `os` must be an array".into()))?
            .iter()
            .map(|row| {
                row.as_arr()
                    .ok_or_else(|| PlanError::Malformed("bad row in `os`".into()))?
                    .iter()
                    .map(|x| {
                        x.as_usize()
                            .ok_or_else(|| PlanError::Malformed("non-numeric entry in `os`".into()))
                    })
                    .collect::<Result<Vec<usize>, _>>()
            })
            .collect::<Result<Vec<_>, PlanError>>()?;

        Ok(PlanArtifact {
            version,
            model: str_field("model")?,
            fingerprint: parse_hex(&str_field("fingerprint")?)?,
            strategy,
            heuristic,
            method,
            order: usize_arr("order")?,
            offsets,
            peak: usize_field("peak")?,
            applied,
            os_per_op,
            os_hash: parse_hex(&str_field("os_hash")?)?,
            search,
            rewrites,
            rewrite_fingerprint,
        })
    }

    /// Write the artifact to `path` as JSON, creating parent
    /// directories as needed (matching the CLI's other outputs).
    ///
    /// The write is atomic: the document goes to a sibling temporary
    /// file first and is renamed into place, so a crash mid-save can
    /// never leave a torn artifact that [`PlanArtifact::load`]
    /// half-parses — deploy processes watching the path see either the
    /// old complete file or the new complete file.
    pub fn save(&self, path: &Path) -> Result<(), PlanError> {
        if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)
                .map_err(|e| PlanError::Io(format!("creating {}: {e}", parent.display())))?;
        }
        let file_name = path
            .file_name()
            .ok_or_else(|| PlanError::Io(format!("{} has no file name", path.display())))?;
        // pid + per-process counter: concurrent saves (threads or
        // processes) each write their own temp file, so no writer can
        // rename another's half-written document into place
        use std::sync::atomic::{AtomicUsize, Ordering};
        static SAVE_COUNTER: AtomicUsize = AtomicUsize::new(0);
        let tmp = path.with_file_name(format!(
            "{}.tmp.{}.{}",
            file_name.to_string_lossy(),
            std::process::id(),
            SAVE_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, self.to_json().to_string())
            .map_err(|e| PlanError::Io(format!("writing {}: {e}", tmp.display())))?;
        std::fs::rename(&tmp, path).map_err(|e| {
            let _ = std::fs::remove_file(&tmp);
            PlanError::Io(format!("renaming {} into place: {e}", path.display()))
        })
    }

    /// Read an artifact file. Parsing only — call
    /// [`PlanArtifact::to_plan`] to revalidate against a graph.
    pub fn load(path: &Path) -> Result<PlanArtifact, PlanError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| PlanError::Io(format!("reading {}: {e}", path.display())))?;
        let v = Json::parse(&text)
            .map_err(|e| PlanError::Malformed(format!("{}: {e:#}", path.display())))?;
        Self::from_json(&v)
    }

    /// `model@fingerprint` label used in mismatch errors.
    fn identity(&self) -> String {
        format!("{}@{}", self.model, hex(self.fingerprint))
    }

    /// Reconstruct and revalidate the plan against `graph`.
    ///
    /// Verifies, in order: the graph fingerprint (a mismatching graph
    /// yields [`PlanError::GraphMismatch`] — §II-D overlap geometry is
    /// only valid for the exact graph), the `O_s` table hash, structural
    /// consistency (table shapes, order validity), and finally the full
    /// pairwise overlap-safety check of the reconstructed layout.
    pub fn to_plan(&self, graph: &Graph) -> Result<Plan, PlanError> {
        if self.version == 0 || self.version > Self::VERSION {
            return Err(PlanError::UnsupportedVersion {
                found: self.version,
                supported: Self::VERSION,
            });
        }
        let found_fp = graph_fingerprint(graph);
        if self.model != graph.name || self.fingerprint != found_fp {
            return Err(PlanError::GraphMismatch {
                expected: self.identity(),
                found: format!("{}@{}", graph.name, hex(found_fp)),
            });
        }
        if self.os_hash != os_table_hash(self.method, &self.os_per_op) {
            return Err(PlanError::Malformed(
                "O_s table does not match its recorded hash".into(),
            ));
        }

        // Rewritten plans: re-derive the rewrite from the (verified)
        // base graph — the banded graph is never trusted from the file,
        // only its fingerprint is, so a tampered spec cannot smuggle in
        // a different computation.
        let rewrite_info = if self.rewrites.is_empty() {
            None
        } else {
            let (rw_graph, provenance) = rewrite::apply(graph, &self.rewrites)
                .map_err(|e| PlanError::Malformed(format!("re-deriving rewrite: {e:#}")))?;
            let fp = graph_fingerprint(&rw_graph);
            if Some(fp) != self.rewrite_fingerprint {
                return Err(PlanError::Malformed(
                    "re-derived rewritten graph does not match its recorded fingerprint".into(),
                ));
            }
            Some(PlanRewrite {
                specs: self.rewrites.clone(),
                graph: rw_graph,
                provenance,
            })
        };
        // every structural check below runs against the graph the plan
        // actually indexes — the rewrite when present, the base otherwise
        let planned: &Graph = rewrite_info.as_ref().map(|r| &r.graph).unwrap_or(graph);

        if self.offsets.len() != planned.tensors.len() {
            return Err(PlanError::Malformed(format!(
                "offset table covers {} tensors, graph has {}",
                self.offsets.len(),
                planned.tensors.len()
            )));
        }
        if self.os_per_op.len() != planned.ops.len()
            || self
                .os_per_op
                .iter()
                .zip(&planned.ops)
                .any(|(row, op)| row.len() != op.inputs.len())
        {
            return Err(PlanError::Malformed(
                "O_s table shape does not match the graph's ops".into(),
            ));
        }
        if self.order.iter().any(|&i| i >= planned.ops.len())
            || self
                .applied
                .iter()
                .any(|&(op, i, o, _)| {
                    op >= planned.ops.len()
                        || i >= planned.tensors.len()
                        || o >= planned.tensors.len()
                })
        {
            return Err(PlanError::Malformed(
                "order or overlap entry out of range".into(),
            ));
        }

        let order = ExecOrder(self.order.iter().map(|&i| OpId(i)).collect());
        if !order::is_valid(planned, &order) {
            return Err(PlanError::InvalidLayout(
                "stored execution order is not a valid topological order".into(),
            ));
        }
        let scopes = analyse(planned, &order);
        let os = OsTable {
            per_op: self.os_per_op.clone(),
            method: self.method,
        };
        let alloc = Allocation {
            offsets: self.offsets.clone(),
            peak: self.peak,
            applied: self
                .applied
                .iter()
                .map(|&(op, input, output, bytes)| AppliedOverlap {
                    op: OpId(op),
                    input: TensorId(input),
                    output: TensorId(output),
                    bytes,
                })
                .collect(),
        };
        super::check(planned, &scopes, &os, &alloc)
            .map_err(|e| PlanError::InvalidLayout(format!("{e:#}")))?;
        Ok(Plan {
            order,
            scopes,
            alloc,
            strategy: self.strategy,
            heuristic: self.heuristic,
            os,
            search: self.search,
            rewrite: rewrite_info,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::planner::Planner;

    #[test]
    fn fingerprint_is_stable_and_structure_sensitive() {
        let a = models::build("tiny").unwrap();
        let b = models::build("tiny").unwrap();
        assert_eq!(graph_fingerprint(&a), graph_fingerprint(&b));
        let c = models::build("tiny_int8").unwrap();
        assert_ne!(graph_fingerprint(&a), graph_fingerprint(&c));
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let art = PlanArtifact::from_plan(&g, &plan);
        let text = art.to_json().to_string();
        let back = PlanArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(art, back);
    }

    #[test]
    fn reloaded_plan_matches_original() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let art = PlanArtifact::from_plan(&g, &plan);
        let re = art.to_plan(&g).unwrap();
        assert_eq!(re.peak(), plan.peak());
        assert_eq!(re.order, plan.order);
        assert_eq!(re.alloc.offsets, plan.alloc.offsets);
        assert_eq!(re.strategy, plan.strategy);
        assert_eq!(re.heuristic, plan.heuristic);
    }

    #[test]
    fn wrong_graph_is_rejected() {
        let g = models::build("tiny").unwrap();
        let other = models::build("tiny_int8").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let art = PlanArtifact::from_plan(&g, &plan);
        assert!(matches!(
            art.to_plan(&other),
            Err(PlanError::GraphMismatch { .. })
        ));
    }

    #[test]
    fn tampered_peak_fails_the_safety_check() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let mut art = PlanArtifact::from_plan(&g, &plan);
        // a peak that disagrees with the offsets is an invalid layout
        art.peak += 1;
        assert!(matches!(art.to_plan(&g), Err(PlanError::InvalidLayout(_))));
    }

    #[test]
    fn tampered_os_table_is_rejected_by_hash() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let mut art = PlanArtifact::from_plan(&g, &plan);
        if let Some(first) = art.os_per_op.iter_mut().flat_map(|r| r.iter_mut()).next() {
            *first = first.wrapping_add(4096);
        }
        assert!(matches!(art.to_plan(&g), Err(PlanError::Malformed(_))));
    }

    #[test]
    fn save_is_atomic_and_roundtrips() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let art = PlanArtifact::from_plan(&g, &plan);
        let dir = std::env::temp_dir().join(format!("dmo-artifact-save-{}", std::process::id()));
        let path = dir.join("nested").join("plan.json");
        art.save(&path).unwrap();
        // the temp sibling must not linger after a successful save
        let siblings: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(siblings, vec!["plan.json".to_string()], "{siblings:?}");
        let back = PlanArtifact::load(&path).unwrap();
        assert_eq!(back, art);
        // overwriting an existing artifact is also atomic + lossless
        art.save(&path).unwrap();
        assert_eq!(PlanArtifact::load(&path).unwrap(), art);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn searched_plan_round_trips_with_stats_and_exact_strategy() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).search(3, 500).plan().unwrap();
        let art = PlanArtifact::from_plan(&g, &plan);
        assert!(art.search.is_some(), "search win must record stats");
        let text = art.to_json().to_string();
        let back = PlanArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(art, back, "v2 search fields must round-trip");
        // the exact (non-default) beam/budget come back through the stats
        assert_eq!(
            back.strategy,
            crate::planner::Strategy::Search { beam: 3, budget: 500 }
        );
        let re = back.to_plan(&g).unwrap();
        assert_eq!(re.peak(), plan.peak());
        assert_eq!(re.order, plan.order);
        assert_eq!(re.search, plan.search);
    }

    #[test]
    fn v1_artifacts_still_load() {
        // a pre-search artifact: version 1, no `search` field
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let mut art = PlanArtifact::from_plan(&g, &plan);
        art.version = 1;
        assert!(art.search.is_none(), "eager/lazy wins carry no stats");
        let text = art.to_json().to_string();
        assert!(!text.contains("\"search\""));
        let back = PlanArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.version, 1);
        let re = back.to_plan(&g).unwrap();
        assert_eq!(re.peak(), plan.peak());
        assert!(re.search.is_none());
    }

    #[test]
    fn split_plan_round_trips_through_v4() {
        use crate::ir::op::{Activation, Padding};
        use crate::ir::{DType, GraphBuilder, Shape};
        // the §II-A pair: splitting strictly beats every unsplit layout
        let mut b = GraphBuilder::new("v4pair", DType::I8);
        let x = b.input(Shape::hwc(64, 64, 8));
        let c = b.conv2d(x, 16, (1, 1), (1, 1), Padding::Same, Activation::None);
        let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None);
        let g = b.finish(&[d]);
        let plan = Planner::for_graph(&g).dmo(true).allow_splits(4).plan().unwrap();
        assert!(plan.rewrite.is_some(), "split must win the §II-A pair");
        let art = PlanArtifact::from_plan(&g, &plan);
        assert_eq!(art.version, 4);
        assert!(!art.rewrites.is_empty());
        assert!(art.rewrite_fingerprint.is_some());
        // fingerprint names the *base* graph the consumer holds
        assert_eq!(art.fingerprint, graph_fingerprint(&g));
        let text = art.to_json().to_string();
        assert!(text.contains("\"rewrites\""));
        let back = PlanArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(art, back);
        let re = back.to_plan(&g).unwrap();
        assert_eq!(re.peak(), plan.peak());
        assert_eq!(re.order, plan.order);
        assert_eq!(re.alloc.offsets, plan.alloc.offsets);
        let rw = re.rewrite.expect("rewrite must be re-derived on load");
        assert_eq!(rw.specs, plan.rewrite.as_ref().unwrap().specs);
        // a tampered spec re-derives a different graph and is refused
        let mut bad = art.clone();
        match &mut bad.rewrites[0] {
            RewriteSpec::PairSplit(sp) => sp.parts = 2,
            RewriteSpec::ChainSplit { parts, .. } => *parts = 2,
        }
        assert!(matches!(bad.to_plan(&g), Err(PlanError::Malformed(_))));
        // a rewritten-plan artifact without its fingerprint is malformed
        let mut no_fp = art.clone();
        no_fp.rewrite_fingerprint = None;
        let bad_text = no_fp.to_json().to_string();
        assert!(PlanArtifact::from_json(&Json::parse(&bad_text).unwrap()).is_err());
    }

    #[test]
    fn v3_legacy_split_artifacts_still_load() {
        use crate::ir::op::{Activation, Padding};
        use crate::ir::{DType, GraphBuilder, Shape};
        let mut b = GraphBuilder::new("v3pair", DType::I8);
        let x = b.input(Shape::hwc(64, 64, 8));
        let c = b.conv2d(x, 16, (1, 1), (1, 1), Padding::Same, Activation::None);
        let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None);
        let g = b.finish(&[d]);
        let plan = Planner::for_graph(&g).dmo(true).allow_splits(4).plan().unwrap();
        assert!(plan.rewrite.is_some());
        // downgrade to the v3 writer: pair splits go under `splits`
        let mut art = PlanArtifact::from_plan(&g, &plan);
        art.version = 3;
        let text = art.to_json().to_string();
        assert!(text.contains("\"splits\"") && text.contains("\"split_fingerprint\""));
        assert!(!text.contains("\"rewrites\""));
        let back = PlanArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.version, 3);
        assert_eq!(back.rewrites, art.rewrites, "legacy splits map onto PairSplit");
        assert_eq!(back.rewrite_fingerprint, art.rewrite_fingerprint);
        let re = back.to_plan(&g).unwrap();
        assert_eq!(re.peak(), plan.peak());
        assert_eq!(re.order, plan.order);
    }

    #[test]
    fn chain_plan_round_trips_through_v4() {
        use crate::ir::op::{Activation, Padding};
        use crate::ir::{DType, GraphBuilder, Shape};
        use crate::planner::RewriteBudget;
        // hourglass: a fat 16 KB intermediate only a depth-3 chain avoids
        let mut b = GraphBuilder::new("v4chain", DType::I8);
        let x = b.input(Shape::hwc(32, 32, 2));
        let c = b.conv2d(x, 16, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let d = b.dwconv2d(c, (3, 3), (1, 1), Padding::Same, Activation::None);
        let p = b.maxpool(d, (4, 4), (4, 4), Padding::Valid);
        let g = b.finish(&[p]);
        let plan = Planner::for_graph(&g)
            .dmo(true)
            .rewrites(RewriteBudget { max_parts: 4, max_splits: 1, max_chain_depth: 3 })
            .plan()
            .unwrap();
        let rw = plan.rewrite.as_ref().expect("chain must win the hourglass");
        assert!(
            rw.specs.iter().any(|r| r.depth() >= 3),
            "expected a depth-3 chain, got {:?}",
            rw.specs
        );
        let art = PlanArtifact::from_plan(&g, &plan);
        let text = art.to_json().to_string();
        assert!(text.contains("\"rewrites\"") && text.contains("\"chain\""));
        let back = PlanArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(art, back, "chain specs must round-trip");
        let re = back.to_plan(&g).unwrap();
        assert_eq!(re.peak(), plan.peak());
        assert_eq!(re.order, plan.order);
        assert_eq!(re.rewrite.unwrap().specs, rw.specs);
    }

    #[test]
    fn unrewritten_v4_artifacts_match_v2_shape() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let art = PlanArtifact::from_plan(&g, &plan);
        assert!(art.rewrites.is_empty() && art.rewrite_fingerprint.is_none());
        let text = art.to_json().to_string();
        assert!(
            !text.contains("\"splits\"") && !text.contains("\"rewrites\""),
            "unrewritten plans carry no rewrite fields"
        );
        // a v2 reader field-set still loads (we parse our own v2 files)
        let mut v2 = art.clone();
        v2.version = 2;
        let back = PlanArtifact::from_json(&Json::parse(&v2.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.version, 2);
        assert_eq!(back.to_plan(&g).unwrap().peak(), plan.peak());
    }

    #[test]
    fn future_version_is_refused() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let mut art = PlanArtifact::from_plan(&g, &plan);
        art.version = PlanArtifact::VERSION + 1;
        assert_eq!(
            art.to_plan(&g).unwrap_err(),
            PlanError::UnsupportedVersion {
                found: PlanArtifact::VERSION + 1,
                supported: PlanArtifact::VERSION,
            }
        );
    }
}
