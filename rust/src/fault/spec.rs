//! The `--faults=SPEC` grammar.
//!
//! A spec is a comma-separated list of clauses, each
//! `kind:count[@model]`:
//!
//! ```text
//! panic:2              panic a worker on 2 consecutive requests (any model)
//! corrupt-arena:1@0    corrupt arena bytes mid-exec for model 0, once
//! corrupt-reload:1     garble an artifact and hot-reload it mid-run
//! stall:20@1           stall model 1's admission queue around 20 requests
//! delay:5              slow-walk 5 requests through exec (blows deadlines)
//! ```
//!
//! `count` must be ≥ 1; `@model` pins the clause to one model index,
//! otherwise the [`super::FaultPlan`] seed picks a model.

use std::fmt;

/// The classes of fault the injector knows how to cause.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// Poke garbage bytes into the arena mid-exec and emit a synthetic
    /// out-of-bounds store event — a rogue kernel write past the planned
    /// peak, the exact defect the watermark check exists to catch.
    ArenaCorrupt,
    /// Panic the worker thread at a chosen request.
    WorkerPanic,
    /// Garble a model's artifact and hot-reload it mid-run (load-time
    /// corruption is covered by the artifact-corpus tests).
    CorruptReload,
    /// Stall a model's admission queue so it backs up and sheds/blocks.
    QueueStall,
    /// Sleep mid-exec so queued requests blow their deadlines.
    ExecDelay,
}

impl FaultKind {
    pub const ALL: [FaultKind; 5] = [
        FaultKind::ArenaCorrupt,
        FaultKind::WorkerPanic,
        FaultKind::CorruptReload,
        FaultKind::QueueStall,
        FaultKind::ExecDelay,
    ];

    /// Stable spec/metrics label.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::ArenaCorrupt => "corrupt-arena",
            FaultKind::WorkerPanic => "panic",
            FaultKind::CorruptReload => "corrupt-reload",
            FaultKind::QueueStall => "stall",
            FaultKind::ExecDelay => "delay",
        }
    }

    /// Index into per-kind counter arrays.
    pub fn index(&self) -> usize {
        match self {
            FaultKind::ArenaCorrupt => 0,
            FaultKind::WorkerPanic => 1,
            FaultKind::CorruptReload => 2,
            FaultKind::QueueStall => 3,
            FaultKind::ExecDelay => 4,
        }
    }

    fn parse(s: &str) -> Option<FaultKind> {
        FaultKind::ALL.into_iter().find(|k| k.name() == s)
    }
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One `kind:count[@model]` clause.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultClause {
    pub kind: FaultKind,
    /// How many requests the clause hits (window length / stall span).
    pub count: u64,
    /// Pin to a model index; `None` lets the plan seed choose.
    pub model: Option<usize>,
}

/// A parsed `--faults` specification.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultSpec {
    pub clauses: Vec<FaultClause>,
}

impl FaultSpec {
    pub fn is_empty(&self) -> bool {
        self.clauses.is_empty()
    }

    /// Parse `kind:count[@model],...`; empty input parses to an empty spec.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let mut clauses = Vec::new();
        for raw in s.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (head, model) = match raw.split_once('@') {
                Some((head, m)) => {
                    let idx = m
                        .parse::<usize>()
                        .map_err(|_| format!("bad model index in fault clause `{raw}`"))?;
                    (head, Some(idx))
                }
                None => (raw, None),
            };
            let (kind_s, count_s) = head
                .split_once(':')
                .ok_or_else(|| format!("fault clause `{raw}` is not kind:count[@model]"))?;
            let kind = FaultKind::parse(kind_s).ok_or_else(|| {
                let known: Vec<&str> = FaultKind::ALL.iter().map(|k| k.name()).collect();
                format!(
                    "unknown fault kind `{kind_s}` (known: {})",
                    known.join(", ")
                )
            })?;
            let count = count_s
                .parse::<u64>()
                .map_err(|_| format!("bad count in fault clause `{raw}`"))?;
            if count == 0 {
                return Err(format!("fault clause `{raw}` has count 0"));
            }
            clauses.push(FaultClause { kind, count, model });
        }
        Ok(FaultSpec { clauses })
    }
}

impl fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, c) in self.clauses.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            write!(f, "{}:{}", c.kind, c.count)?;
            if let Some(m) = c.model {
                write!(f, "@{m}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let spec = FaultSpec::parse("panic:2@0, corrupt-reload:1,stall:20@1").unwrap();
        assert_eq!(
            spec.clauses,
            vec![
                FaultClause {
                    kind: FaultKind::WorkerPanic,
                    count: 2,
                    model: Some(0)
                },
                FaultClause {
                    kind: FaultKind::CorruptReload,
                    count: 1,
                    model: None
                },
                FaultClause {
                    kind: FaultKind::QueueStall,
                    count: 20,
                    model: Some(1)
                },
            ]
        );
        // round-trips through Display (modulo whitespace)
        assert_eq!(spec.to_string(), "panic:2@0,corrupt-reload:1,stall:20@1");
    }

    #[test]
    fn empty_spec_is_empty() {
        assert!(FaultSpec::parse("").unwrap().is_empty());
        assert!(FaultSpec::parse(" , ").unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed_clauses() {
        assert!(FaultSpec::parse("panic").is_err());
        assert!(FaultSpec::parse("panic:zero").is_err());
        assert!(FaultSpec::parse("panic:0").is_err());
        assert!(FaultSpec::parse("frobnicate:1").is_err());
        assert!(FaultSpec::parse("panic:1@x").is_err());
    }
}
