//! Graph serialisation (§II-B).
//!
//! Connected graphs admit many valid execution orders; the order changes
//! which tensors are simultaneously live and therefore the peak memory.
//! The paper evaluates each model under an *eager* and a *lazy* strategy
//! and keeps the better result (§IV); both are implemented here as Kahn
//! topological sorts with different ready-queue policies.

use crate::ir::graph::{Graph, OpId, TensorId};
use std::collections::BTreeSet;

/// A valid execution order over the graph's ops.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecOrder(pub Vec<OpId>);

/// Serialisation strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Run ops as soon as their inputs exist, in emission order — breadth
    /// first across branches.
    Eager,
    /// Run each op as late as possible — depth first along branches, so
    /// side branches complete just before their results are consumed.
    Lazy,
    /// Memory-aware beam search over all topological orders, scored by
    /// the DMO-overlapped incremental footprint ([`super::search`]).
    /// `beam` states survive each level; `budget` caps total state
    /// expansions before the search degrades to greedy completion. The
    /// eager and lazy orders are always scored as seed candidates, so
    /// this strategy is never worse than the paper's best-of-two.
    Search { beam: usize, budget: usize },
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Eager => "eager",
            Strategy::Lazy => "lazy",
            Strategy::Search { .. } => "search",
        }
    }

    /// The search strategy at its default beam width and budget.
    pub const fn search_default() -> Strategy {
        Strategy::Search {
            beam: super::search::DEFAULT_BEAM,
            budget: super::search::DEFAULT_BUDGET,
        }
    }

    /// Parse from the name produced by [`Strategy::name`] — used when
    /// deserialising plan artifacts. `"search"` parses at the default
    /// beam/budget; artifact loading restores the recorded values from
    /// the stored search stats.
    pub fn from_name(name: &str) -> Option<Strategy> {
        match name {
            "eager" => Some(Strategy::Eager),
            "lazy" => Some(Strategy::Lazy),
            "search" => Some(Strategy::search_default()),
            _ => None,
        }
    }
}

/// The paper's §IV sweep strategies. [`Strategy::Search`] is opt-in
/// (it costs orders of magnitude more than a single Kahn pass), so it
/// is not part of the default best-of sweep.
pub const STRATEGIES: [Strategy; 2] = [Strategy::Eager, Strategy::Lazy];

/// Serialise `graph` with the given strategy.
///
/// For [`Strategy::Search`] this returns the search's preferred order
/// under the *baseline* (no-overlap) cost model; planning through
/// [`super::Planner`] instead searches with the session's real `O_s`
/// budgets and scores every candidate with the full allocator.
pub fn serialise(graph: &Graph, strategy: Strategy) -> ExecOrder {
    match strategy {
        Strategy::Eager => eager(graph),
        Strategy::Lazy => lazy(graph),
        Strategy::Search { beam, budget } => {
            let os = super::alloc::OsTable::disabled(graph);
            super::search::search(graph, &os, beam, budget)
                .orders
                .into_iter()
                .next()
                .expect("search always yields at least the seed orders")
        }
    }
}

fn ready_inputs(graph: &Graph, op: OpId, produced: &[bool]) -> bool {
    graph.op(op).inputs.iter().all(|&t| {
        graph.producer(t).map(|p| produced[p.0]).unwrap_or(true) // graph inputs always ready
    })
}

/// Kahn's algorithm, ready set ordered by op index (FIFO w.r.t. emission).
fn eager(graph: &Graph) -> ExecOrder {
    let n = graph.ops.len();
    let mut produced = vec![false; n];
    let mut done = vec![false; n];
    let mut order = Vec::with_capacity(n);
    let mut ready: BTreeSet<usize> = (0..n)
        .filter(|&i| ready_inputs(graph, OpId(i), &produced))
        .collect();
    while let Some(&i) = ready.iter().next() {
        ready.remove(&i);
        if done[i] {
            continue;
        }
        done[i] = true;
        produced[i] = true;
        order.push(OpId(i));
        // newly ready consumers
        let out: TensorId = graph.ops[i].output;
        for c in graph.consumers(out) {
            if !done[c.0] && ready_inputs(graph, c, &produced) {
                ready.insert(c.0);
            }
        }
    }
    assert_eq!(order.len(), n, "graph has a cycle");
    ExecOrder(order)
}

/// As-late-as-possible: schedule the *reverse* graph eagerly from the
/// outputs, preferring the highest op index, then reverse. Each op lands
/// just before its first consumer.
fn lazy(graph: &Graph) -> ExecOrder {
    let n = graph.ops.len();
    // consumers_done[i]: all ops consuming i's output already scheduled
    // (in reverse construction).
    let consumer_count: Vec<usize> = (0..n)
        .map(|i| graph.consumers(graph.ops[i].output).len())
        .collect();
    let mut remaining = consumer_count;
    let mut done = vec![false; n];
    let mut rev = Vec::with_capacity(n);
    let mut ready: BTreeSet<usize> = (0..n).filter(|&i| remaining[i] == 0).collect();
    while let Some(&i) = ready.iter().next_back() {
        ready.remove(&i);
        if done[i] {
            continue;
        }
        done[i] = true;
        rev.push(OpId(i));
        for &t in &graph.ops[i].inputs {
            if let Some(p) = graph.producer(t) {
                remaining[p.0] -= 1;
                if remaining[p.0] == 0 {
                    ready.insert(p.0);
                }
            }
        }
    }
    assert_eq!(rev.len(), n, "graph has a cycle");
    rev.reverse();
    ExecOrder(rev)
}

/// Check that `order` is a valid topological order of `graph`.
pub fn is_valid(graph: &Graph, order: &ExecOrder) -> bool {
    if order.0.len() != graph.ops.len() {
        return false;
    }
    let mut pos = vec![usize::MAX; graph.ops.len()];
    for (p, &op) in order.0.iter().enumerate() {
        if pos[op.0] != usize::MAX {
            return false; // duplicate
        }
        pos[op.0] = p;
    }
    for (i, op) in graph.ops.iter().enumerate() {
        for &t in &op.inputs {
            if let Some(p) = graph.producer(t) {
                if pos[p.0] >= pos[i] {
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Padding};
    use crate::ir::{DType, GraphBuilder, Shape};

    fn branchy() -> Graph {
        // x -> a -> b ┐
        //      └-> c ─┴-> add -> out
        let mut b = GraphBuilder::new("branchy", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 4));
        let a = b.conv2d(x, 4, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let p = b.conv2d(a, 4, (3, 3), (1, 1), Padding::Same, Activation::None);
        let q = b.conv2d(a, 4, (1, 1), (1, 1), Padding::Same, Activation::None);
        let s = b.add(p, q);
        b.finish(&[s])
    }

    #[test]
    fn both_strategies_valid() {
        let g = branchy();
        for strat in STRATEGIES {
            let o = serialise(&g, strat);
            assert!(is_valid(&g, &o), "{strat:?} produced invalid order");
        }
    }

    #[test]
    fn sequential_graph_orders_agree() {
        let mut b = GraphBuilder::new("seq", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 3));
        let c = b.conv2d(x, 8, (3, 3), (2, 2), Padding::Same, Activation::Relu);
        let d = b.dwconv2d(c, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let g = b.finish(&[d]);
        assert_eq!(serialise(&g, Strategy::Eager), serialise(&g, Strategy::Lazy));
    }

    #[test]
    fn search_strategy_serialises_to_a_valid_order() {
        let g = branchy();
        let o = serialise(&g, Strategy::search_default());
        assert!(is_valid(&g, &o));
        assert_eq!(o.0.len(), g.ops.len());
    }

    #[test]
    fn strategy_names_round_trip() {
        for s in [Strategy::Eager, Strategy::Lazy, Strategy::search_default()] {
            assert_eq!(Strategy::from_name(s.name()), Some(s));
        }
        assert_eq!(Strategy::from_name("zigzag"), None);
    }

    #[test]
    fn invalid_order_detected() {
        let g = branchy();
        let mut o = serialise(&g, Strategy::Eager);
        o.0.swap(0, 3);
        assert!(!is_valid(&g, &o));
    }
}
