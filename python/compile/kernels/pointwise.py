"""L1 Pallas kernel: pointwise (1×1) convolution as a row-tiled matmul.

The 1×1 conv is the op behind the paper's 33 % MobileNet saving (§IV):
its reads trail its writes by `D_out/D_in`, so input and output overlap
by almost the whole input buffer. The kernel preserves that order — the
grid walks row-tiles of the flattened (H·W, Cin) activation in increasing
order and feeds the MXU one (TILE×Cin)·(Cin×Cout) matmul per step.

This kernel uses proper `BlockSpec` blocking (unlike the halo'd dwconv):
x is tiled (TILE, Cin), the weight block is whole, the output tile is
(TILE, Cout). VMEM per step at the tiny model's largest instance
(256×16 @ 16→32, TILE=64): 64·16 + 16·32 + 64·32 floats ≈ 14 KB.

`interpret=True` as everywhere (see dwconv.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@functools.partial(jax.jit, static_argnames=("tile",))
def pointwise_conv(x, w, b=None, tile=64):
    """1×1 conv: x (H, W, Cin), w (Cin, Cout), b (Cout,) → (H, W, Cout)."""
    h, wd, cin = x.shape
    cin2, cout = w.shape
    assert cin2 == cin
    n = h * wd
    xf = x.reshape(n, cin)
    t = min(tile, n)
    # pad rows to a tile multiple; the pad tail is dead output
    n_pad = -(-n // t) * t
    if n_pad != n:
        xf = jnp.pad(xf, ((0, n_pad - n), (0, 0)))

    def kernel(x_ref, w_ref, o_ref):
        # one MXU-shaped matmul per tile, fp32 accumulate
        o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n_pad, cout), x.dtype),
        grid=(n_pad // t,),
        in_specs=[
            pl.BlockSpec((t, cin), lambda i: (i, 0)),
            pl.BlockSpec((cin, cout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t, cout), lambda i: (i, 0)),
        interpret=True,
    )(xf, w)
    out = out[:n].reshape(h, wd, cout)
    if b is not None:
        out = out + b
    return out
