//! ResNet 50 v2 (He et al., pre-activation variant) — Table III row 11:
//! the residual topology keeps tensors live across whole blocks, so DMO
//! finds no overlap opportunities ("None").

use crate::ir::graph::{Graph, TensorId};
use crate::ir::op::{Activation, Padding};
use crate::ir::{DType, GraphBuilder, Shape};

/// Pre-activation bottleneck block.
///
/// `conv_shortcut`: first block of a stage projects the shortcut with a
/// 1×1 conv; later blocks use the identity. `stride` is applied in the
/// 3×3 conv (and the shortcut projection/pool), v2-style at the *end* of
/// each stage.
fn block_v2(
    b: &mut GraphBuilder,
    x: TensorId,
    filters: usize,
    stride: usize,
    conv_shortcut: bool,
) -> TensorId {
    // pre-activation (BN folded; the relu is standalone and shared, which
    // is what keeps `x`'s successor live for the whole block)
    let preact = b.relu(x);
    let shortcut = if conv_shortcut {
        b.conv2d(preact, 4 * filters, (1, 1), (stride, stride), Padding::Same, Activation::None)
    } else if stride > 1 {
        b.maxpool(x, (1, 1), (stride, stride), Padding::Same)
    } else {
        x
    };
    let h = b.conv2d(preact, filters, (1, 1), (1, 1), Padding::Same, Activation::Relu);
    let h = b.conv2d(h, filters, (3, 3), (stride, stride), Padding::Same, Activation::Relu);
    let h = b.conv2d(h, 4 * filters, (1, 1), (1, 1), Padding::Same, Activation::None);
    b.add(shortcut, h)
}

/// Stage of `n` blocks; stride 2 in the last block (except the final
/// stage), matching `keras.applications.ResNet50V2`.
fn stack_v2(b: &mut GraphBuilder, mut x: TensorId, filters: usize, n: usize, last_stride: usize) -> TensorId {
    x = block_v2(b, x, filters, 1, true);
    for _ in 0..n.saturating_sub(2) {
        x = block_v2(b, x, filters, 1, false);
    }
    x = block_v2(b, x, filters, last_stride, false);
    x
}

/// Build ResNet 50 v2 at 224×224.
pub fn build_50_v2(dtype: DType) -> Graph {
    let mut b = GraphBuilder::new("resnet_50_v2", dtype);
    let x = b.input(Shape::hwc(224, 224, 3));
    // conv1: 7x7 s2 64
    let h = b.conv2d(x, 64, (7, 7), (2, 2), Padding::Same, Activation::Relu);
    // maxpool 3x3 s2
    let mut h = b.maxpool(h, (3, 3), (2, 2), Padding::Same);
    for (f, n, s) in [(64, 3, 2), (128, 4, 2), (256, 6, 2), (512, 3, 1)] {
        h = stack_v2(&mut b, h, f, n, s);
    }
    let h = b.relu(h); // post-norm activation
    let h = b.global_avg_pool(h);
    let h = b.reshape(h, Shape::new(&[1, 2048]));
    let h = b.fully_connected(h, 1000, Activation::None);
    let out = b.softmax(h);
    b.finish(&[out])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_size() {
        let g = build_50_v2(DType::F32);
        // conv1 out 112x112x64, pool out 56x56x64
        assert_eq!(g.tensor(g.ops[0].output).shape, Shape::hwc(112, 112, 64));
        assert_eq!(g.tensor(g.ops[1].output).shape, Shape::hwc(56, 56, 64));
        // final feature map 7x7x2048
        let gap_in = g
            .ops
            .iter()
            .find(|o| matches!(o.kind, crate::ir::op::OpKind::GlobalAvgPool))
            .map(|o| &g.tensor(o.inputs[0]).shape)
            .unwrap();
        assert_eq!(*gap_in, Shape::hwc(7, 7, 2048));
        // 16 blocks x add
        let adds = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, crate::ir::op::OpKind::Binary(_)))
            .count();
        assert_eq!(adds, 16);
    }

    #[test]
    fn residual_tensors_are_multi_use() {
        // the pre-activation output feeds both shortcut conv and branch
        let g = build_50_v2(DType::F32);
        let first_relu = g.ops.iter().position(|o| matches!(o.kind, crate::ir::op::OpKind::Unary(_))).unwrap();
        let t = g.ops[first_relu].output;
        assert!(g.consumers(t).len() >= 2);
    }
}
