//! Operation-splitting analysis (§II-A) — the planning side of
//! [`crate::ir::rewrite::split_pair`].
//!
//! A pair of chained window ops whose intermediate tensor dominates peak
//! memory can be split into `k` horizontal bands executed sequentially:
//! each band computes a slice of the final output through a slice of the
//! intermediate tensor, so only `≈ 1/k` of the intermediate values are
//! live at once — at the price of recomputing the receptive-field halo
//! rows adjacent bands share, plus one copy of the output during
//! reassembly.
//!
//! The paper demonstrates this manually on MobileNet v1 (§II-A: 96 KB →
//! 66 KB with 6144 elements computed twice) and calls for automatic
//! application as future work. Here the analysis and the transform share
//! one geometry ([`crate::ir::rewrite::band_plan`]): [`analyse_pair`]
//! predicts the banded schedule's exact live-set watermark — the peak
//! the allocator measures on the materialised rewrite (asserted zoo-wide
//! by `rust/tests/split_rewrite.rs`) — and
//! [`candidates`] ranks the graph's peak-defining pairs so
//! [`super::Planner::allow_splits`] can propose splitting as a search
//! action alongside reordering.
//!
//! Note the §II-A caveat is *modelled*, not assumed away: the split
//! tensors' longer scopes (the pair's input spans every band) suppress
//! DMO overlap on the banded region, which the planner sees through the
//! ordinary scope analysis of the rewritten graph.

use crate::ir::graph::{Graph, OpId};
use crate::ir::rewrite::{self, SplitSpec};
use crate::ir::GraphBuilder;

/// Result of splitting a two-op chain into `parts` bands.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SplitReport {
    pub first: OpId,
    pub second: OpId,
    pub parts: usize,
    /// Peak bytes for the fused pair without splitting
    /// (input + intermediate, intermediate + output, whichever is larger).
    pub peak_before: usize,
    /// Exact live-set watermark of the banded schedule (§II-A): the max
    /// over every band step of input + current intermediate band +
    /// already-materialised output bands, and the reassembly step's
    /// 2×output. This is what the baseline allocator measures on the
    /// rewritten pair.
    pub peak_after: usize,
    /// Intermediate elements computed more than once (halo rows shared
    /// by adjacent bands).
    pub recomputed_elems: usize,
    /// Output elements copied once by the concat-rows reassembly.
    pub assembled_elems: usize,
}

impl SplitReport {
    pub fn saving_pct(&self) -> f64 {
        if self.peak_before == 0 {
            return 0.0;
        }
        100.0 * (self.peak_before.saturating_sub(self.peak_after)) as f64 / self.peak_before as f64
    }

    /// The spec that materialises this report via
    /// [`crate::ir::rewrite::split_pair`].
    pub fn spec(&self) -> SplitSpec {
        SplitSpec {
            first: self.first.0,
            second: self.second.0,
            parts: self.parts,
        }
    }
}

/// Analyse splitting the chain `first → second` (second consumes first's
/// output) into `parts` horizontal bands. Errors when the pair is not
/// splittable (see [`crate::ir::rewrite::split_eligible`]).
pub fn analyse_pair(
    graph: &Graph,
    first: OpId,
    second: OpId,
    parts: usize,
) -> anyhow::Result<SplitReport> {
    let plans = rewrite::band_plan(graph, first, second, parts)?;
    let f = graph.op(first);
    let s = graph.op(second);
    let input = graph.tensor(f.inputs[0]);
    let mid = graph.tensor(f.output);
    let out = graph.tensor(s.output);

    let peak_before = (input.size_bytes() + mid.size_bytes()).max(mid.size_bytes() + out.size_bytes());

    let in_bytes = input.size_bytes();
    let mid_row_bytes = mid.shape.w() * mid.shape.c() * mid.dtype.size_bytes();
    let out_row_bytes = out.shape.w() * out.shape.c() * out.dtype.size_bytes();
    let out_bytes = out.size_bytes();

    // Exact live-set watermark of the banded schedule
    // A_0 B_0 A_1 B_1 … A_{k-1} B_{k-1} concat. The pair's input is
    // consumed by every A band, so it dies at A_{k-1}; output bands
    // accumulate until the reassembly copies them into the full tensor.
    let last = plans.len() - 1;
    let mut peak_after = 0usize;
    let mut out_prefix = 0usize; // bytes of output bands already live
    let mut mid_rows_total = 0usize;
    for (p, bp) in plans.iter().enumerate() {
        let band_mid = (bp.mid1 - bp.mid0) * mid_row_bytes;
        let band_out = (bp.out1 - bp.out0) * out_row_bytes;
        mid_rows_total += bp.mid1 - bp.mid0;
        // during A_p: input + this intermediate band + prior output bands
        peak_after = peak_after.max(in_bytes + band_mid + out_prefix);
        // during B_p: input (unless this is the last band — the input
        // died at A_{k-1}) + the band + output bands incl. this one
        let in_live = if p < last { in_bytes } else { 0 };
        peak_after = peak_after.max(in_live + band_mid + out_prefix + band_out);
        out_prefix += band_out;
    }
    // reassembly: every output band + the full output
    peak_after = peak_after.max(out_prefix + out_bytes);

    let recomputed_rows = mid_rows_total.saturating_sub(mid.shape.h());
    Ok(SplitReport {
        first,
        second,
        parts,
        peak_before,
        peak_after,
        recomputed_elems: recomputed_rows * mid.shape.w() * mid.shape.c(),
        assembled_elems: out.shape.num_elements(),
    })
}

/// Extract the pair `first → second` into a standalone three-tensor
/// chain (`Input → first → second → Output`) with the same kinds,
/// shapes, dtype and weights — the subgraph [`analyse_pair`]'s schedule
/// model describes, used by the property tests to compare prediction
/// against the allocator on the materialised rewrite.
pub fn isolate_pair(graph: &Graph, first: OpId, second: OpId) -> anyhow::Result<Graph> {
    rewrite::split_eligible(graph, first, second, 2)?;
    let f = graph.op(first);
    let s = graph.op(second);
    let dtype = graph.tensor(f.inputs[0]).dtype;
    let mut b = GraphBuilder::new(&format!("{}_pair", graph.name), dtype);
    let x = b.input(graph.tensor(f.inputs[0]).shape.clone());
    let m = b.add_op(f.kind.clone(), &[x], f.weights.clone());
    let o = b.add_op(s.kind.clone(), &[m], s.weights.clone());
    anyhow::ensure!(
        b.graph_ref().tensor(m).shape == graph.tensor(f.output).shape
            && b.graph_ref().tensor(o).shape == graph.tensor(s.output).shape,
        "isolated pair re-inferred different shapes"
    );
    Ok(b.finish(&[o]))
}

/// The graph's most promising split candidates: every eligible pair
/// whose banded schedule beats its fused peak, each at its best `parts`
/// in `2..=max_parts`, ranked by the pair's memory pressure
/// (`peak_before`, descending) and truncated to `limit`. The
/// peak-defining pair of the graph — §II-A's target — ranks first.
pub fn candidates(graph: &Graph, max_parts: usize, limit: usize) -> Vec<SplitReport> {
    let mut per_pair: Vec<SplitReport> = Vec::new();
    for (i, f) in graph.ops.iter().enumerate() {
        let consumers = graph.consumers(f.output);
        if consumers.len() != 1 {
            continue;
        }
        let c = consumers[0];
        if rewrite::split_eligible(graph, OpId(i), c, 2).is_err() {
            continue;
        }
        let oh = graph.tensor(graph.op(c).output).shape.h();
        let mut best: Option<SplitReport> = None;
        for parts in 2..=max_parts.min(oh) {
            if let Ok(r) = analyse_pair(graph, OpId(i), c, parts) {
                if r.peak_after < r.peak_before
                    && best.as_ref().map_or(true, |b| r.peak_after < b.peak_after)
                {
                    best = Some(r);
                }
            }
        }
        if let Some(b) = best {
            per_pair.push(b);
        }
    }
    per_pair.sort_by_key(|r| (usize::MAX - r.peak_before, r.first.0));
    per_pair.truncate(limit);
    per_pair
}

/// Scan a graph for its most profitable 2-op split (exhaustive over
/// eligible pairs and `2..=max_parts`) — the `dmo split` report.
pub fn best_split(graph: &Graph, max_parts: usize) -> Option<SplitReport> {
    candidates(graph, max_parts, usize::MAX)
        .into_iter()
        .min_by_key(|r| (r.peak_after, r.first.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Padding};
    use crate::ir::{DType, GraphBuilder, Shape};
    use crate::overlap::Method;
    use crate::planner::alloc::{allocate, OsTable, HEURISTICS};
    use crate::planner::order::{serialise, Strategy};
    use crate::planner::scope::analyse;

    /// §II-A's MobileNet v1 0.25 128 (8-bit) shape: the 1x1 conv
    /// (64 KB intermediate) feeding the next dwconv (16 KB out), with a
    /// 32 KB input. The paper reports 96 KB → 66 KB; the banded
    /// schedule's exact watermark is lower still (61 KB) because output
    /// bands materialise progressively and the input dies before the
    /// last one exists.
    #[test]
    fn paper_mobilenet_split_case() {
        let mut b = GraphBuilder::new("split", DType::I8);
        let x = b.input(Shape::hwc(64, 64, 8)); // 32 KB
        let c = b.conv2d(x, 16, (1, 1), (1, 1), Padding::Same, Activation::None); // 64 KB mid
        let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None); // 16 KB out
        let g = b.finish(&[d]);
        let r = analyse_pair(&g, OpId(0), OpId(1), 4).unwrap();
        assert_eq!(r.peak_before, 96 * 1024);
        // bands of 8 output rows need (8-1)*2+3 = 17 intermediate rows
        // (16 for the last, clipped); watermark peaks during B_2:
        // 32 KB input + 17 KB band + 12 KB of output bands = 61 KB
        assert_eq!(r.peak_after, 61 * 1024);
        assert!(r.saving_pct() > 30.0);
        // halo: 1 recomputed row × 64·16 elems × 3 boundaries
        assert_eq!(r.recomputed_elems, 3 * 64 * 16);
        assert_eq!(r.assembled_elems, 32 * 32 * 16);
    }

    /// The analysis must predict exactly what the baseline allocator
    /// measures on the materialised rewrite.
    #[test]
    fn predicted_peak_matches_allocator_on_rewrite() {
        let mut b = GraphBuilder::new("pm", DType::F32);
        let x = b.input(Shape::hwc(24, 20, 3));
        let c = b.conv2d(x, 12, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let d = b.maxpool(c, (2, 2), (2, 2), Padding::Valid);
        let g = b.finish(&[d]);
        for parts in [2usize, 3, 4] {
            let r = analyse_pair(&g, OpId(0), OpId(1), parts).unwrap();
            let rw = crate::ir::rewrite::split_pair(&g, OpId(0), OpId(1), parts).unwrap();
            let order = serialise(&rw.graph, Strategy::Eager);
            let scopes = analyse(&rw.graph, &order);
            let os = OsTable::disabled(&rw.graph);
            let measured = HEURISTICS
                .iter()
                .map(|&h| allocate(&rw.graph, &scopes, &os, h).peak)
                .min()
                .unwrap();
            assert_eq!(measured, r.peak_after, "parts={parts}");
        }
    }

    #[test]
    fn best_split_finds_something() {
        let mut b = GraphBuilder::new("bs", DType::F32);
        let x = b.input(Shape::hwc(32, 32, 4));
        let c = b.conv2d(x, 16, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let d = b.maxpool(c, (2, 2), (2, 2), Padding::Valid);
        let g = b.finish(&[d]);
        let r = best_split(&g, 8).unwrap();
        assert!(r.peak_after < r.peak_before);
        assert_eq!(r.spec().first, r.first.0);
    }

    #[test]
    fn rejects_non_chain() {
        let mut b = GraphBuilder::new("nc", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 2));
        let c = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let d = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let s = b.add(c, d);
        let g = b.finish(&[s]);
        // ops 0 and 1 are siblings, not a chain
        assert!(analyse_pair(&g, OpId(0), OpId(1), 2).is_err());
    }

    #[test]
    fn candidates_rank_by_pressure_and_keep_the_peak_pair_first() {
        // two eligible pairs with very different pressure
        let mut b = GraphBuilder::new("rank", DType::F32);
        let x = b.input(Shape::hwc(32, 32, 4));
        let big = b.conv2d(x, 16, (3, 3), (1, 1), Padding::Same, Activation::None); // big mid
        let shr = b.maxpool(big, (2, 2), (2, 2), Padding::Valid);
        let small = b.conv2d(shr, 8, (3, 3), (1, 1), Padding::Same, Activation::None);
        let tail = b.maxpool(small, (2, 2), (2, 2), Padding::Valid);
        let g = b.finish(&[tail]);
        let cands = candidates(&g, 4, 8);
        assert!(!cands.is_empty());
        // first candidate must be the highest-pressure pair
        let max_pressure = cands.iter().map(|r| r.peak_before).max().unwrap();
        assert_eq!(cands[0].peak_before, max_pressure);
        // limit is respected
        assert_eq!(candidates(&g, 4, 1).len(), 1);
    }

    #[test]
    fn isolated_pair_matches_in_situ_analysis() {
        let mut b = GraphBuilder::new("iso", DType::F32);
        let x = b.input(Shape::hwc(16, 16, 4));
        let pre = b.relu(x);
        let c = b.conv2d(pre, 8, (3, 3), (1, 1), Padding::Same, Activation::None);
        let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None);
        let post = b.relu(d);
        let g = b.finish(&[post]);
        let iso = isolate_pair(&g, OpId(1), OpId(2)).unwrap();
        assert_eq!(iso.ops.len(), 2);
        let in_situ = analyse_pair(&g, OpId(1), OpId(2), 3).unwrap();
        let isolated = analyse_pair(&iso, OpId(0), OpId(1), 3).unwrap();
        assert_eq!(in_situ.peak_after, isolated.peak_after);
        assert_eq!(in_situ.recomputed_elems, isolated.recomputed_elems);
    }

    #[test]
    fn split_suppresses_dmo_overlap_on_the_banded_region() {
        // the §II-A caveat, modelled: the pair input feeds every band,
        // so it cannot die at the first band — its O_s credit is unusable
        let mut b = GraphBuilder::new("caveat", DType::F32);
        let x = b.input(Shape::hwc(16, 16, 4));
        let c = b.conv2d(x, 8, (1, 1), (1, 1), Padding::Same, Activation::None);
        let d = b.dwconv2d(c, (3, 3), (1, 1), Padding::Same, Activation::None);
        let g = b.finish(&[d]);
        let rw = crate::ir::rewrite::split_pair(&g, OpId(0), OpId(1), 2).unwrap();
        let order = serialise(&rw.graph, Strategy::Eager);
        let scopes = analyse(&rw.graph, &order);
        // input is read by both A bands: it dies only at the last one
        let a0 = OpId(0);
        assert!(!scopes.dies_at(g.inputs[0], a0), "input must outlive band 0");
        let os = OsTable::build(&rw.graph, Method::Algorithmic);
        let alloc = allocate(
            &rw.graph,
            &scopes,
            &os,
            crate::planner::alloc::Heuristic::PairFrontier,
        );
        crate::planner::alloc::check(&rw.graph, &scopes, &os, &alloc).unwrap();
    }
}
