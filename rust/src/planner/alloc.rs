//! Buffer pre-allocation: the paper's *modified heap* allocator (§IV)
//! with the diagonal-memory-optimisation overlap relaxation (§II-D).
//!
//! The baseline places every arena tensor at the lowest offset that is
//! disjoint from all already-placed, scope-overlapping buffers, choosing
//! the next buffer heuristically (frontier member placeable lowest). DMO
//! relaxes exactly one constraint class: the input of an op may share
//! bytes with that op's output, provided the input *dies* at the op and
//! `out_end − in_start ≤ O_s` — i.e. the start of the input overlaps at
//! most `O_s` bytes of the end of the output (Fig 4).
//!
//! Allocation is a pre-inference step (the overlap geometry is only valid
//! for the analysed execution order), matching §II-D: "this approach can
//! only be used as a pre-allocation method".

use super::scope::{Scope, Scopes};
use crate::ir::graph::{Graph, OpId, TensorId, TensorKind};
use crate::overlap::{Method, OsCache};

/// Cached `O_s` values per op per input index, in bytes.
#[derive(Debug, Clone)]
pub struct OsTable {
    pub per_op: Vec<Vec<usize>>,
    pub method: Method,
}

impl OsTable {
    /// Compute `O_s` for every (op, input) in `graph` with `method`.
    ///
    /// Repeated op signatures within the graph (every repeated
    /// conv/dw block of the zoo models) are analysed once via a
    /// build-local [`OsCache`]; pass a longer-lived cache to
    /// [`OsTable::build_cached`] to also share results across builds,
    /// sessions and threads.
    pub fn build(graph: &Graph, method: Method) -> OsTable {
        Self::build_cached(graph, method, &OsCache::new())
    }

    /// [`OsTable::build`] through a caller-supplied memo table: every
    /// (op, input) `O_s` is looked up by canonical op signature and
    /// computed at most once per distinct signature across *all* users
    /// of `cache`.
    pub fn build_cached(graph: &Graph, method: Method, cache: &OsCache) -> OsTable {
        let per_op = graph
            .ops
            .iter()
            .map(|op| {
                let in_shapes: Vec<_> = op.inputs.iter().map(|&t| &graph.tensor(t).shape).collect();
                let out_shape = &graph.tensor(op.output).shape;
                let dtype = graph.tensor(op.output).dtype;
                cache
                    .get_or_compute(method, &op.kind, &in_shapes, out_shape, dtype)
                    .per_input
            })
            .collect();
        OsTable { per_op, method }
    }

    /// A table of zeros — disables all overlapping (baseline allocator).
    pub fn disabled(graph: &Graph) -> OsTable {
        OsTable {
            per_op: graph.ops.iter().map(|op| vec![0; op.inputs.len()]).collect(),
            method: Method::Analytic,
        }
    }

    pub fn get(&self, op: OpId, input_idx: usize) -> usize {
        self.per_op[op.0][input_idx]
    }
}

/// Seed / fallback direction (§IV: forwards seeds the model input at
/// offset zero, backwards seeds the model output).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Forward,
    Backward,
}

pub const DIRECTIONS: [Direction; 2] = [Direction::Forward, Direction::Backward];

impl Direction {
    pub fn name(self) -> &'static str {
        match self {
            Direction::Forward => "forward",
            Direction::Backward => "backward",
        }
    }
}

/// Which order tensors are offered to the heap.
///
/// The paper describes a scope-frontier walk seeded at an input or output
/// buffer (§IV); TFLite Micro's greedy planner instead offers buffers in
/// decreasing size order. Both are heuristics for the same NP-hard
/// problem ("no guarantee of optimality", §IV) and neither dominates;
/// [`super::Planner`] sweeps all and keeps the best, exactly as the
/// paper sweeps serialisation orders.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Heuristic {
    /// §IV frontier walk, seeded per [`Direction`].
    Frontier(Direction),
    /// Decreasing buffer size (TFLite-Micro-style greedy).
    SizeDesc,
    /// Pair-aware frontier: seed the largest tensor, then repeatedly place
    /// the unplaced tensor most constrained by what is already down —
    /// preferring tensors with a DMO pair relation to a placed tensor,
    /// larger first. This follows the overlap chains outward from the
    /// peak-defining op, which is how the diagonal packings of Fig 2b
    /// arise (the dying input nests into its consumer's output *before*
    /// an unrelated tensor can squat on the low addresses).
    PairFrontier,
}

/// Every allocation-order heuristic, for best-of sweeps.
pub const HEURISTICS: [Heuristic; 4] = [
    Heuristic::Frontier(Direction::Forward),
    Heuristic::Frontier(Direction::Backward),
    Heuristic::SizeDesc,
    Heuristic::PairFrontier,
];

impl Heuristic {
    pub fn name(self) -> &'static str {
        match self {
            Heuristic::Frontier(Direction::Forward) => "frontier-fwd",
            Heuristic::Frontier(Direction::Backward) => "frontier-bwd",
            Heuristic::SizeDesc => "size-desc",
            Heuristic::PairFrontier => "pair-frontier",
        }
    }

    /// Parse from the name produced by [`Heuristic::name`] — used when
    /// deserialising plan artifacts.
    pub fn from_name(name: &str) -> Option<Heuristic> {
        match name {
            "frontier-fwd" => Some(Heuristic::Frontier(Direction::Forward)),
            "frontier-bwd" => Some(Heuristic::Frontier(Direction::Backward)),
            "size-desc" => Some(Heuristic::SizeDesc),
            "pair-frontier" => Some(Heuristic::PairFrontier),
            _ => None,
        }
    }
}

/// A DMO overlap actually applied in a layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AppliedOverlap {
    pub op: OpId,
    pub input: TensorId,
    pub output: TensorId,
    /// bytes shared between the two buffers
    pub bytes: usize,
}

/// Result of allocation: byte offsets for every arena tensor.
#[derive(Debug, Clone)]
pub struct Allocation {
    /// Indexed by `TensorId`; `None` for tensors with no scope (unused).
    pub offsets: Vec<Option<usize>>,
    /// Arena size = max(offset + size).
    pub peak: usize,
    /// Overlaps the layout exploits (for reports and Fig 2b/9b).
    pub applied: Vec<AppliedOverlap>,
}

/// Precomputed DMO pair relation: `(input, output) → O_s budget` for
/// every op whose input dies at it. Built once per allocation/check —
/// the placement loop is O(n²) pairs, and resolving producers on the fly
/// made each pair O(ops) (the planner perf pass measured 3.05 s → see
/// EXPERIMENTS.md §Perf).
pub struct PairTable {
    budgets: std::collections::HashMap<(usize, usize), usize>,
}

impl PairTable {
    pub fn build(graph: &Graph, scopes: &Scopes, os: &OsTable) -> PairTable {
        let mut budgets = std::collections::HashMap::new();
        for (k, op) in graph.ops.iter().enumerate() {
            for (idx, &inp) in op.inputs.iter().enumerate() {
                if inp == op.output || !scopes.dies_at(inp, OpId(k)) {
                    continue;
                }
                let b = os.get(OpId(k), idx);
                budgets
                    .entry((inp.0, op.output.0))
                    .and_modify(|cur: &mut usize| *cur = (*cur).min(b))
                    .or_insert(b);
            }
        }
        PairTable { budgets }
    }

    /// Budget for `input` overlapping the tail of `output`, if related.
    #[inline]
    pub fn budget(&self, input: TensorId, output: TensorId) -> Option<usize> {
        self.budgets.get(&(input.0, output.0)).copied()
    }

    /// Does `t` participate in any pair relation (either side)?
    pub fn related(&self, t: TensorId) -> impl Iterator<Item = usize> + '_ {
        let tid = t.0;
        self.budgets
            .keys()
            .filter(move |(a, b)| *a == tid || *b == tid)
            .map(move |(a, b)| if *a == tid { *b } else { *a })
    }
}

/// Cost of executing one more op on top of a schedule prefix — see
/// [`IncrementalCost::step`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepCost {
    /// Arena bytes any layout needs *while* the op executes: the live
    /// set, plus the output, minus the best single DMO overlap credit.
    pub during: usize,
    /// Live bytes once the op has retired (dying inputs freed; the
    /// caller additionally frees an output nobody consumes).
    pub live_after: usize,
}

/// Incremental form of the §IV modified-heap allocator, for costing
/// schedule *prefixes* during execution-order search.
///
/// The full allocator places every buffer of a complete order; re-running
/// it per candidate prefix would make search O(n³) and is unnecessary:
/// at any instant exactly one op executes, so the only overlap the DMO
/// relaxation can have active is between that op's output and one of its
/// dying inputs (two dying inputs sharing the output's tail would have to
/// share bytes with *each other*, which no relaxation permits). The
/// reachable footprint of a prefix is therefore
///
/// ```text
///   max over executed ops of  (live bytes + out − best credit(op))
///   credit(op, input) = min(O_s(op, input), |input|, |out|)
/// ```
///
/// which [`IncrementalCost::step`] evaluates in O(inputs) per op from
/// tables built once per search. It is the same relaxation geometry
/// [`allocate`] exploits (Fig 4: `out_end − in_start ≤ O_s`), minus
/// fragmentation — a lower-ish bound that ranks prefixes, while final
/// candidates are still scored by the real allocator.
#[derive(Debug, Clone)]
pub struct IncrementalCost {
    /// Per op: arena size of its output buffer in bytes.
    out_size: Vec<usize>,
    /// Per op: distinct input tensors as `(tensor, size, credit)`;
    /// `credit` is the most bytes that input may share with the op's
    /// output when it dies at the op.
    inputs: Vec<Vec<(TensorId, usize, usize)>>,
}

impl IncrementalCost {
    /// Build the per-op tables for `graph` under `os` budgets.
    pub fn build(graph: &Graph, os: &OsTable) -> IncrementalCost {
        let out_size: Vec<usize> = graph
            .ops
            .iter()
            .map(|op| graph.tensor(op.output).size_bytes())
            .collect();
        let inputs = graph
            .ops
            .iter()
            .enumerate()
            .map(|(k, op)| {
                let out_bytes = out_size[k];
                let mut v: Vec<(TensorId, usize, usize)> = Vec::new();
                for (idx, &inp) in op.inputs.iter().enumerate() {
                    let size = graph.tensor(inp).size_bytes();
                    let credit = if inp == op.output {
                        0
                    } else {
                        os.get(OpId(k), idx).min(size).min(out_bytes)
                    };
                    // an op reading the same tensor through two inputs is
                    // constrained by the tighter budget, as in PairTable
                    match v.iter_mut().find(|(t, _, _)| *t == inp) {
                        Some(e) => e.2 = e.2.min(credit),
                        None => v.push((inp, size, credit)),
                    }
                }
                v
            })
            .collect();
        IncrementalCost { out_size, inputs }
    }

    /// Output buffer size of `op` in bytes.
    pub fn out_size(&self, op: OpId) -> usize {
        self.out_size[op.0]
    }

    /// Distinct inputs of `op` as `(tensor, size, overlap credit)`.
    pub fn inputs(&self, op: OpId) -> &[(TensorId, usize, usize)] {
        &self.inputs[op.0]
    }

    /// Cost of executing `op` when `live_bytes` are currently live;
    /// `dies` reports whether a given input tensor's last remaining
    /// consumer is this op (graph outputs never die).
    pub fn step(
        &self,
        op: OpId,
        live_bytes: usize,
        mut dies: impl FnMut(TensorId) -> bool,
    ) -> StepCost {
        let out = self.out_size[op.0];
        let mut credit = 0usize;
        let mut freed = 0usize;
        for &(t, size, c) in &self.inputs[op.0] {
            if dies(t) {
                freed += size;
                credit = credit.max(c);
            }
        }
        StepCost {
            during: live_bytes + out - credit,
            live_after: live_bytes + out - freed,
        }
    }
}

/// One pairwise constraint between a tensor being placed and an already
/// placed tensor.
enum Constraint {
    /// Must not share any byte.
    Disjoint,
    /// May overlap; safe iff disjoint OR `out_end − in_start ≤ budget`,
    /// where the placed tensor is the op's output.
    PairPlacedOutput { budget: usize },
    /// May overlap; the placed tensor is the dying input, the candidate is
    /// the output. Safe iff disjoint OR `cand_end − placed_start ≤ budget`.
    PairPlacedInput { budget: usize },
}

/// Lowest feasible offset for tensor `t` of `size` bytes with alignment
/// `align`, against `placed = [(offset, size, constraint)]`.
fn lowest_feasible(placed: &[(usize, usize, Constraint)], size: usize, align: usize) -> usize {
    let align_up = |x: usize| x.div_ceil(align) * align;
    let mut x = 0usize;
    'retry: loop {
        for &(u_off, u_len, ref c) in placed {
            let u_end = u_off + u_len;
            let disjoint = x >= u_end || x + size <= u_off;
            let ok = match c {
                Constraint::Disjoint => disjoint,
                Constraint::PairPlacedOutput { budget } => {
                    // candidate is the input: in_start = x, out_end = u_end
                    disjoint || u_end.saturating_sub(x) <= *budget
                }
                Constraint::PairPlacedInput { budget } => {
                    // candidate is the output: out_end = x + size
                    disjoint || (x + size).saturating_sub(u_off) <= *budget
                }
            };
            if !ok {
                // advance past the violation and rescan
                let next = match c {
                    Constraint::Disjoint => u_end,
                    Constraint::PairPlacedOutput { budget } => u_end.saturating_sub(*budget).max(x + 1),
                    Constraint::PairPlacedInput { .. } => u_end,
                };
                x = align_up(next.max(x + 1));
                continue 'retry;
            }
        }
        return x;
    }
}

/// Collect the placement constraints for unplaced tensor `t` against all
/// placed, scope-overlapping tensors.
fn constraints_for(
    graph: &Graph,
    scopes: &Scopes,
    pairs: &PairTable,
    offsets: &[Option<usize>],
    t: TensorId,
) -> Vec<(usize, usize, Constraint)> {
    let ts = scopes.scopes[t.0].unwrap();
    let mut placed = Vec::new();
    for u0 in 0..graph.tensors.len() {
        let u = TensorId(u0);
        let (Some(u_off), Some(us)) = (offsets[u0], scopes.scopes[u0]) else {
            continue;
        };
        if !ts.overlaps(&us) {
            continue;
        }
        let u_len = graph.tensor(u).size_bytes();
        let c = if let Some(b) = pairs.budget(t, u) {
            Constraint::PairPlacedOutput { budget: b }
        } else if let Some(b) = pairs.budget(u, t) {
            Constraint::PairPlacedInput { budget: b }
        } else {
            Constraint::Disjoint
        };
        placed.push((u_off, u_len, c));
    }
    placed
}

/// Allocate every arena tensor of `graph` under `order`/`scopes`.
///
/// `os` supplies per-(op, input) overlap budgets; pass
/// [`OsTable::disabled`] for the non-DMO baseline.
pub fn allocate(graph: &Graph, scopes: &Scopes, os: &OsTable, heuristic: Heuristic) -> Allocation {
    let pairs = PairTable::build(graph, scopes, os);
    let n = graph.tensors.len();
    let mut offsets: Vec<Option<usize>> = vec![None; n];
    let live: Vec<Option<Scope>> = scopes.scopes.clone();

    let arena_tensors: Vec<TensorId> = (0..n)
        .map(TensorId)
        .filter(|&t| live[t.0].is_some())
        .collect();

    match heuristic {
        Heuristic::SizeDesc => {
            // decreasing size, ties by earlier scope start then id
            let mut order: Vec<TensorId> = arena_tensors.clone();
            order.sort_by_key(|&t| {
                (
                    usize::MAX - graph.tensor(t).size_bytes(),
                    live[t.0].unwrap().start,
                    t.0,
                )
            });
            for t in order {
                let placed = constraints_for(graph, scopes, &pairs, &offsets, t);
                let size = graph.tensor(t).size_bytes();
                let align = graph.tensor(t).dtype.size_bytes();
                offsets[t.0] = Some(lowest_feasible(&placed, size, align));
            }
        }
        Heuristic::PairFrontier => {
            // seed: the largest arena tensor
            let seed = *arena_tensors
                .iter()
                .max_by_key(|t| (graph.tensor(**t).size_bytes(), usize::MAX - t.0))
                .unwrap();
            offsets[seed.0] = Some(0);
            let total = arena_tensors.len();
            let mut done = 1usize;
            // does `t` have a DMO pair relation with any placed tensor?
            let has_pair = |offsets: &[Option<usize>], t: TensorId| -> bool {
                pairs.related(t).any(|u| offsets[u].is_some())
            };
            while done < total {
                // select: pair-related first, then scope-frontier, then
                // anything; larger first within a class
                let mut chosen: Option<(usize, usize, usize, TensorId)> = None;
                for &t in &arena_tensors {
                    if offsets[t.0].is_some() {
                        continue;
                    }
                    let ts = live[t.0].unwrap();
                    let touches = arena_tensors.iter().any(|&u| {
                        offsets[u.0].is_some() && ts.overlaps(&live[u.0].unwrap())
                    });
                    let class = if has_pair(&offsets, t) {
                        0
                    } else if touches {
                        1
                    } else {
                        2
                    };
                    let key = (class, usize::MAX - graph.tensor(t).size_bytes(), t.0, t);
                    if chosen.map_or(true, |c| (key.0, key.1, key.2) < (c.0, c.1, c.2)) {
                        chosen = Some(key);
                    }
                }
                let t = chosen.unwrap().3;
                let placed = constraints_for(graph, scopes, &pairs, &offsets, t);
                let size = graph.tensor(t).size_bytes();
                let align = graph.tensor(t).dtype.size_bytes();
                offsets[t.0] = Some(lowest_feasible(&placed, size, align));
                done += 1;
            }
        }
        Heuristic::Frontier(direction) => {
            let total = arena_tensors.len();
            let mut done = 0usize;
            // seed: first model input (forward) or last output (backward)
            let seed = match direction {
                Direction::Forward => graph
                    .inputs
                    .first()
                    .copied()
                    .filter(|t| live[t.0].is_some())
                    .unwrap_or(arena_tensors[0]),
                Direction::Backward => graph
                    .outputs
                    .last()
                    .copied()
                    .filter(|t| live[t.0].is_some())
                    .unwrap_or(*arena_tensors.last().unwrap()),
            };
            offsets[seed.0] = Some(0);
            done += 1;

            while done < total {
                // frontier: unplaced tensors whose scope overlaps a placed one
                let mut best: Option<(usize, usize, TensorId)> = None;
                for &t in &arena_tensors {
                    if offsets[t.0].is_some() {
                        continue;
                    }
                    let placed = constraints_for(graph, scopes, &pairs, &offsets, t);
                    if placed.is_empty() {
                        continue; // not on the frontier
                    }
                    let size = graph.tensor(t).size_bytes();
                    let align = graph.tensor(t).dtype.size_bytes();
                    let x = lowest_feasible(&placed, size, align);
                    // frontier member placeable lowest; ties: bigger first
                    let key = (x, usize::MAX - size, t.0);
                    if best.map_or(true, |(bx, bk, bt)| key < (bx, bk, bt.0)) {
                        best = Some((x, key.1, t));
                    }
                }
                let (x, _k, t) = match best {
                    Some(b) => b,
                    None => {
                        // disconnected scope group: next unplaced in scope order
                        let t = *arena_tensors
                            .iter()
                            .filter(|t| offsets[t.0].is_none())
                            .min_by_key(|t| match direction {
                                Direction::Forward => live[t.0].unwrap().start,
                                Direction::Backward => usize::MAX - live[t.0].unwrap().end,
                            })
                            .unwrap();
                        (0, 0, t)
                    }
                };
                offsets[t.0] = Some(x);
                done += 1;
            }
        }
    }

    // peak + applied overlaps
    let mut peak = 0usize;
    for &t in &arena_tensors {
        peak = peak.max(offsets[t.0].unwrap() + graph.tensor(t).size_bytes());
    }
    let mut applied = Vec::new();
    for (oi, op) in graph.ops.iter().enumerate() {
        let out = op.output;
        let (Some(out_off), Some(_)) = (offsets[out.0], live[out.0]) else {
            continue;
        };
        let out_end = out_off + graph.tensor(out).size_bytes();
        for &inp in &op.inputs {
            let Some(in_off) = offsets[inp.0] else { continue };
            let in_end = in_off + graph.tensor(inp).size_bytes();
            let shared = out_end.min(in_end).saturating_sub(out_off.max(in_off));
            if shared > 0 && inp != out {
                applied.push(AppliedOverlap {
                    op: OpId(oi),
                    input: inp,
                    output: out,
                    bytes: shared,
                });
            }
        }
    }

    Allocation {
        offsets,
        peak,
        applied,
    }
}

/// Verify that `alloc` satisfies every pairwise constraint — used by the
/// property tests and after every planning run.
pub fn check(graph: &Graph, scopes: &Scopes, os: &OsTable, alloc: &Allocation) -> anyhow::Result<()> {
    let pairs = PairTable::build(graph, scopes, os);
    let n = graph.tensors.len();
    for a in 0..n {
        let (Some(ao), Some(asc)) = (alloc.offsets[a], scopes.scopes[a]) else {
            continue;
        };
        let a_id = TensorId(a);
        let a_len = graph.tensor(a_id).size_bytes();
        for b in (a + 1)..n {
            let (Some(bo), Some(bsc)) = (alloc.offsets[b], scopes.scopes[b]) else {
                continue;
            };
            let b_id = TensorId(b);
            let b_len = graph.tensor(b_id).size_bytes();
            if !asc.overlaps(&bsc) {
                continue;
            }
            let disjoint = ao + a_len <= bo || bo + b_len <= ao;
            if disjoint {
                continue;
            }
            // overlapping bytes: must be a DMO pair within budget
            let ok_ab = pairs
                .budget(a_id, b_id)
                .map(|budget| (bo + b_len).saturating_sub(ao) <= budget)
                .unwrap_or(false);
            let ok_ba = pairs
                .budget(b_id, a_id)
                .map(|budget| (ao + a_len).saturating_sub(bo) <= budget)
                .unwrap_or(false);
            anyhow::ensure!(
                ok_ab || ok_ba,
                "tensors {} and {} overlap illegally ({}..{} vs {}..{})",
                graph.tensor(a_id).name,
                graph.tensor(b_id).name,
                ao,
                ao + a_len,
                bo,
                bo + b_len
            );
        }
    }
    // every live tensor placed, peak correct
    let mut peak = 0;
    for t in 0..n {
        if scopes.scopes[t].is_some() {
            let off = alloc.offsets[t]
                .ok_or_else(|| anyhow::anyhow!("tensor {t} unplaced"))?;
            peak = peak.max(off + graph.tensor(TensorId(t)).size_bytes());
        }
    }
    anyhow::ensure!(peak == alloc.peak, "peak mismatch: {} != {}", peak, alloc.peak);
    // outputs may never be clobbered: an output tensor's buffer must not
    // overlap anything while it is an op input later… covered by pair rule
    let _ = TensorKind::Output;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Padding};
    use crate::ir::{DType, GraphBuilder, Shape};
    use crate::planner::order::{serialise, Strategy};
    use crate::planner::scope::analyse;

    fn two_op_graph() -> Graph {
        // input 8x8x4 -> 1x1 conv to 8 ch (out 2x input) -> dw 3x3 s2
        let mut b = GraphBuilder::new("t", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 4));
        let c = b.conv2d(x, 8, (1, 1), (1, 1), Padding::Same, Activation::None);
        let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None);
        b.finish(&[d])
    }

    #[test]
    fn baseline_no_overlaps() {
        let g = two_op_graph();
        let order = serialise(&g, Strategy::Eager);
        let sc = analyse(&g, &order);
        let os = OsTable::disabled(&g);
        for h in HEURISTICS {
            let a = allocate(&g, &sc, &os, h);
            check(&g, &sc, &os, &a).unwrap();
            assert!(a.applied.is_empty(), "baseline must not overlap");
            // peak >= the largest simultaneous pair (conv in+out)
            let pair = g.tensor(crate::ir::graph::TensorId(0)).size_bytes()
                + g.tensor(crate::ir::graph::TensorId(1)).size_bytes();
            assert!(a.peak >= pair);
        }
    }

    #[test]
    fn dmo_overlaps_and_shrinks_peak() {
        let g = two_op_graph();
        let order = serialise(&g, Strategy::Eager);
        let sc = analyse(&g, &order);
        let base = allocate(&g, &sc, &OsTable::disabled(&g), Heuristic::Frontier(Direction::Backward));
        let os = OsTable::build(&g, Method::Algorithmic);
        let dmo = allocate(&g, &sc, &os, Heuristic::Frontier(Direction::Backward));
        check(&g, &sc, &os, &dmo).unwrap();
        assert!(!dmo.applied.is_empty(), "DMO should apply an overlap");
        assert!(dmo.peak < base.peak, "DMO {} !< base {}", dmo.peak, base.peak);
    }

    #[test]
    fn residual_blocks_overlap_with_live_tensor() {
        // a is used by conv AND add: it must not be overlapped by the conv
        let mut b = GraphBuilder::new("res", DType::F32);
        let x = b.input(Shape::hwc(4, 4, 2));
        let a = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let p = b.conv2d(a, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let s = b.add(a, p);
        let g = b.finish(&[s]);
        let order = serialise(&g, Strategy::Eager);
        let sc = analyse(&g, &order);
        let os = OsTable::build(&g, Method::Algorithmic);
        let alloc = allocate(&g, &sc, &os, Heuristic::Frontier(Direction::Backward));
        check(&g, &sc, &os, &alloc).unwrap();
        // `a` (tensor of the first conv) must not share bytes with p's
        // buffer: dies_at(a, conv_p) is false
        let a_off = alloc.offsets[a.0].unwrap();
        let a_end = a_off + g.tensor(a).size_bytes();
        let p_off = alloc.offsets[p.0].unwrap();
        let p_end = p_off + g.tensor(p).size_bytes();
        assert!(a_end <= p_off || p_end <= a_off, "a and p must be disjoint");
    }

    #[test]
    fn incremental_cost_matches_chain_geometry() {
        // input(1024 B) -> conv(2048 B) -> dw(512 B): credits bounded by
        // min(O_s, in, out) and dying inputs freed after the step
        let g = two_op_graph();
        let os = OsTable::build(&g, Method::Algorithmic);
        let inc = IncrementalCost::build(&g, &os);
        let x = g.inputs[0];
        let conv_out = g.ops[0].output;
        let in_b = g.tensor(x).size_bytes();
        let conv_b = g.tensor(conv_out).size_bytes();

        // op 0: input dies there
        let sc = inc.step(OpId(0), in_b, |t| t == x);
        let credit = inc.inputs(OpId(0))[0].2;
        assert!(credit <= in_b.min(conv_b));
        assert_eq!(sc.during, in_b + conv_b - credit);
        assert_eq!(sc.live_after, conv_b);

        // with nothing dying there is no credit and nothing freed
        let sc = inc.step(OpId(0), in_b, |_| false);
        assert_eq!(sc.during, in_b + conv_b);
        assert_eq!(sc.live_after, in_b + conv_b);

        // a disabled table yields zero credits everywhere
        let inc0 = IncrementalCost::build(&g, &OsTable::disabled(&g));
        for k in 0..g.ops.len() {
            for &(_, _, c) in inc0.inputs(OpId(k)) {
                assert_eq!(c, 0);
            }
        }
    }

    #[test]
    fn lowest_feasible_respects_budget() {
        // one placed output [0, 100); budget 40 ⇒ input may start at 60
        let placed = vec![(0usize, 100usize, Constraint::PairPlacedOutput { budget: 40 })];
        assert_eq!(lowest_feasible(&placed, 50, 1), 60);
        let placed = vec![(0usize, 100usize, Constraint::Disjoint)];
        assert_eq!(lowest_feasible(&placed, 50, 1), 100);
        // alignment rounds up
        let placed = vec![(0usize, 10usize, Constraint::Disjoint)];
        assert_eq!(lowest_feasible(&placed, 8, 4), 12);
    }
}
