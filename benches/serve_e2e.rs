//! Bench: end-to-end serving — the three-layer stack under load.
//!
//! Sweeps arrival rate and batch policy over the AOT'd tiny model,
//! reporting throughput, latency percentiles and batch efficiency.
//! Requires `make artifacts`.

use dmo::coordinator::{serve, BatchPolicy, ServeConfig};
use std::time::Duration;

fn main() {
    if !dmo::runtime::default_artifacts_dir()
        .join("model.meta.json")
        .exists()
    {
        eprintln!("artifacts missing — run `make artifacts` first; skipping serve bench");
        return;
    }

    println!("=== serving rate sweep (batch ≤8, 2 ms window) ===\n");
    println!(
        "{:>9} {:>9} {:>6} {:>10} {:>9} {:>9} {:>9} {:>8} {:>6}",
        "rate", "done", "shed", "thr(rps)", "p50(µs)", "p95(µs)", "p99(µs)", "batch", "eff"
    );
    for rate in [100.0, 300.0, 1000.0, 3000.0] {
        let cfg = ServeConfig {
            requests: 256,
            rate,
            queue_capacity: 128,
            policy: BatchPolicy {
                max_batch: 8,
                window: Duration::from_millis(2),
            },
            seed: 11,
            ..Default::default()
        };
        match serve(&cfg) {
            Ok(r) => {
                let l = r.metrics.latency();
                println!(
                    "{:>9.0} {:>9} {:>6} {:>10.1} {:>9.0} {:>9.0} {:>9.0} {:>8.2} {:>5.0}%",
                    rate,
                    r.completed,
                    r.shed,
                    r.throughput_rps,
                    l.p50_us,
                    l.p95_us,
                    l.p99_us,
                    r.metrics.mean_batch(),
                    100.0 * r.metrics.batch_efficiency()
                );
            }
            Err(e) => {
                eprintln!("serve failed at rate {rate}: {e:#}");
                return;
            }
        }
    }

    println!("\n=== batch policy sweep at 1000 req/s ===\n");
    println!(
        "{:>6} {:>10} {:>10} {:>9} {:>9} {:>8} {:>6}",
        "batch", "window", "thr(rps)", "p50(µs)", "p99(µs)", "avg b", "eff"
    );
    for (max_batch, window_ms) in [(1usize, 0u64), (4, 1), (8, 2), (8, 8)] {
        let cfg = ServeConfig {
            requests: 256,
            rate: 1000.0,
            queue_capacity: 128,
            policy: BatchPolicy {
                max_batch,
                window: Duration::from_millis(window_ms),
            },
            seed: 12,
            ..Default::default()
        };
        match serve(&cfg) {
            Ok(r) => {
                let l = r.metrics.latency();
                println!(
                    "{:>6} {:>9}ms {:>10.1} {:>9.0} {:>9.0} {:>8.2} {:>5.0}%",
                    max_batch,
                    window_ms,
                    r.throughput_rps,
                    l.p50_us,
                    l.p99_us,
                    r.metrics.mean_batch(),
                    100.0 * r.metrics.batch_efficiency()
                );
            }
            Err(e) => {
                eprintln!("serve failed: {e:#}");
                return;
            }
        }
    }
}
