//! Model registry: N DMO-planned models in one process, each behind a
//! generation-counted atomically-swappable state.
//!
//! Every registered model is a [`ModelState`]: the base graph, the
//! revalidated plan (loaded from a [`PlanArtifact`] or planned at
//! registration), the precomputed per-tensor arena regions and per-op
//! weights, and a pooled-arena set sized to the plan's peak. The state
//! is immutable once built; **hot-reload** swaps a freshly validated
//! state in behind an `Arc` while in-flight requests keep executing on
//! the old generation until their clones drop — no request is ever torn
//! between two layouts, and a stale artifact (fingerprint mismatch) is
//! rejected without touching the serving state.

use super::pool::{ArenaPool, PooledArena};
use crate::interp;
use crate::ir::graph::{Graph, TensorId};
use crate::ops::exec::{execute_op, gen_weights, OpIo, Region};
use crate::planner::{Plan, PlanArtifact, Planner};
use crate::util::sync::lock;
use anyhow::{ensure, Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// How a fleet model is sourced at registration.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Zoo name (`dmo models`).
    pub name: String,
    /// Plan artifact to start from; `None` plans at registration.
    pub artifact: Option<PathBuf>,
}

impl ModelSpec {
    pub fn planned(name: &str) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            artifact: None,
        }
    }
}

/// One immutable model generation: everything a request needs, resolved.
pub struct ModelState {
    pub name: String,
    /// Monotonic per-slot generation; bumped by every successful reload.
    pub generation: u64,
    /// The base graph the artifact was validated against.
    pub graph: Graph,
    /// The artifact this generation serves (re-exportable).
    pub artifact: PlanArtifact,
    /// The revalidated plan (owns the split rewrite when present).
    pub plan: Plan,
    /// Arena byte region per tensor of the *planned* graph.
    regions: Vec<Option<Region>>,
    /// Per-op weights of the planned graph, generated once — request
    /// execution never re-derives weights.
    weights: Vec<Vec<Vec<f32>>>,
    /// Seed the weights (and the validation run) were generated with.
    pub weight_seed: u64,
    /// K pre-sized arenas; sized to `plan.peak()` for this generation.
    pub pool: Arc<ArenaPool>,
}

impl ModelState {
    /// Build and *prove* a generation: revalidate the artifact against
    /// the graph (fingerprint + layout checks), execute the planned
    /// layout bit-identically against the disjoint reference
    /// ([`interp::validate_plan`]), then precompute regions and weights.
    pub fn new(
        name: &str,
        graph: Graph,
        artifact: PlanArtifact,
        generation: u64,
        arenas: usize,
        weight_seed: u64,
    ) -> Result<ModelState> {
        let plan = artifact
            .to_plan(&graph)
            .with_context(|| format!("revalidating plan artifact for `{name}`"))?;
        interp::validate_plan(&graph, &plan, weight_seed)
            .with_context(|| format!("proving `{name}` plan safe before serving"))?;
        let pg = plan.graph_for(&graph);
        let regions: Vec<Option<Region>> = (0..pg.tensors.len())
            .map(|t| {
                plan.alloc.offsets[t]
                    .map(|off| Region::new(off, pg.tensor(TensorId(t)).size_bytes()))
            })
            .collect();
        let weights: Vec<Vec<Vec<f32>>> = pg
            .ops
            .iter()
            .enumerate()
            .map(|(i, op)| gen_weights(op, weight_seed ^ op.weight_key(i) as u64))
            .collect();
        let pool = Arc::new(ArenaPool::new(plan.peak(), arenas));
        Ok(ModelState {
            name: name.to_string(),
            generation,
            graph,
            artifact,
            plan,
            regions,
            weights,
            weight_seed,
            pool,
        })
    }

    /// The graph the plan's order/offsets index (the §II-A rewrite when
    /// the plan carries one, the base graph otherwise).
    pub fn planned_graph(&self) -> &Graph {
        self.plan.graph_for(&self.graph)
    }

    /// Elements the single model input expects per request.
    pub fn input_elements(&self) -> usize {
        self.graph
            .tensor(self.graph.inputs[0])
            .shape
            .num_elements()
    }

    /// Acquire a pooled arena sized for this generation.
    pub fn acquire_arena(&self) -> PooledArena {
        self.pool.acquire()
    }

    /// Execute one request in `arena` (acquired from this generation's
    /// pool) and return the model's first output. No allocation beyond
    /// the output vector: regions and weights are precomputed, and the
    /// arena is reused as-is — a validated plan writes every region
    /// before reading it, so stale bytes from the previous request can
    /// never leak into the result.
    pub fn execute(&self, arena: &mut crate::ops::exec::Arena, input: &[f32]) -> Result<Vec<f32>> {
        self.execute_with(arena, input, |_, _| Ok(()))
    }

    /// [`ModelState::execute`] with a per-step hook, called with the step
    /// index before each op executes. The fleet's fault injector uses the
    /// hook to corrupt/delay/panic at a chosen step; everything else goes
    /// through [`ModelState::execute`], whose hook is a no-op.
    pub fn execute_with<F>(
        &self,
        arena: &mut crate::ops::exec::Arena,
        input: &[f32],
        mut hook: F,
    ) -> Result<Vec<f32>>
    where
        F: FnMut(usize, &mut crate::ops::exec::Arena) -> Result<()>,
    {
        let pg = self.planned_graph();
        ensure!(
            pg.inputs.len() == 1 && pg.outputs.len() == 1,
            "fleet serving expects single-input single-output models, `{}` has {}/{}",
            self.name,
            pg.inputs.len(),
            pg.outputs.len()
        );
        ensure!(
            arena.len() == self.plan.peak(),
            "arena size {} does not match plan peak {} — arena from another generation?",
            arena.len(),
            self.plan.peak()
        );
        let in_id = pg.inputs[0];
        let in_info = pg.tensor(in_id);
        ensure!(
            input.len() == in_info.shape.num_elements(),
            "input length {} != expected {}",
            input.len(),
            in_info.shape.num_elements()
        );
        arena.write_tensor(
            in_info.dtype,
            self.regions[in_id.0].context("input tensor unplaced")?,
            input,
        );
        for (step, &opid) in self.plan.order.0.iter().enumerate() {
            hook(step, arena)?;
            let op = pg.op(opid);
            let in_shapes: Vec<&crate::ir::Shape> =
                op.inputs.iter().map(|&t| &pg.tensor(t).shape).collect();
            let in_regions: Vec<Region> = op
                .inputs
                .iter()
                .map(|&t| self.regions[t.0].context("op input unplaced"))
                .collect::<Result<_>>()?;
            let io = OpIo {
                in_shapes: &in_shapes,
                in_regions: &in_regions,
                out_shape: &pg.tensor(op.output).shape,
                out_region: self.regions[op.output.0].context("op output unplaced")?,
                dtype: pg.tensor(op.output).dtype,
                weights: &self.weights[opid.0],
            };
            execute_op(&op.kind, &io, arena)
                .with_context(|| format!("executing {}", op.name))?;
        }
        let out_id = pg.outputs[0];
        let out_info = pg.tensor(out_id);
        Ok(arena.read_tensor(
            out_info.dtype,
            self.regions[out_id.0].context("output tensor unplaced")?,
            out_info.shape.num_elements(),
        ))
    }
}

/// Result of a successful hot-reload.
#[derive(Debug, Clone, Copy)]
pub struct ReloadInfo {
    pub generation: u64,
    pub old_peak: usize,
    pub new_peak: usize,
}

/// How [`Registry::degrade`] recovered the slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DegradeMode {
    /// Pinned the last-known-good generation (survived a prior reload).
    PinnedPrevious,
    /// No previous generation — freshly planned safe plan (no overlap
    /// relaxation, no rewrites).
    SafePlan,
    /// Slot was already degraded; no further action taken.
    AlreadyDegraded,
}

/// Result of a [`Registry::degrade`] call.
#[derive(Debug, Clone, Copy)]
pub struct DegradeInfo {
    pub mode: DegradeMode,
    /// Generation now serving the slot.
    pub generation: u64,
    /// Its arena peak — for a safe plan, the un-overlapped footprint.
    pub peak: usize,
}

struct Slot {
    name: String,
    current: Mutex<Arc<ModelState>>,
    /// Last-known-good generation displaced by the latest successful
    /// reload — the pin target when `current` must be abandoned.
    previous: Mutex<Option<Arc<ModelState>>>,
    /// Slot is serving a degraded generation (pinned previous or safe
    /// plan); cleared by the next successful reload.
    degraded: AtomicBool,
    reloads: AtomicUsize,
    /// Degrade transitions (not per-request; deterministic per fault).
    degrades: AtomicUsize,
    /// Reloads rejected by validation, serving generation untouched.
    reload_rejections: AtomicUsize,
}

/// The fleet's model table: index-addressed slots, each holding the
/// current [`ModelState`] generation behind a swappable `Arc`.
pub struct Registry {
    slots: Vec<Slot>,
}

impl Registry {
    /// Load every spec: build the graph, load (or compute) its plan
    /// artifact, and prove each resulting state safe. Planning shares
    /// the process-wide `O_s` cache, so fleets of related models warm
    /// each other up.
    pub fn load(specs: &[ModelSpec], arenas: usize, jobs: usize, weight_seed: u64) -> Result<Registry> {
        ensure!(!specs.is_empty(), "fleet needs at least one model");
        let cache = crate::overlap::OsCache::process_shared();
        let mut slots = Vec::with_capacity(specs.len());
        for spec in specs {
            let graph = crate::models::build(&spec.name)?;
            let artifact = match &spec.artifact {
                Some(path) => PlanArtifact::load(path)
                    .with_context(|| format!("loading plan artifact {}", path.display()))?,
                None => {
                    let plan = Planner::for_graph(&graph)
                        .dmo(true)
                        .jobs(jobs)
                        .os_cache(cache.clone())
                        .plan()
                        .with_context(|| format!("planning `{}` at registration", spec.name))?;
                    PlanArtifact::from_plan(&graph, &plan)
                }
            };
            let state = ModelState::new(&spec.name, graph, artifact, 0, arenas, weight_seed)?;
            slots.push(Slot {
                name: spec.name.clone(),
                current: Mutex::new(Arc::new(state)),
                previous: Mutex::new(None),
                degraded: AtomicBool::new(false),
                reloads: AtomicUsize::new(0),
                degrades: AtomicUsize::new(0),
                reload_rejections: AtomicUsize::new(0),
            });
        }
        Ok(Registry { slots })
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        self.slots.iter().map(|s| s.name.as_str()).collect()
    }

    /// First slot index serving `name` (models may be registered twice —
    /// two slots, two pools — for A/B traffic splits).
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.slots.iter().position(|s| s.name == name)
    }

    /// The current generation of slot `m`. The clone keeps that
    /// generation alive for the caller even across a concurrent reload.
    pub fn current(&self, m: usize) -> Arc<ModelState> {
        lock(&self.slots[m].current).clone()
    }

    /// Times slot `m` was successfully hot-reloaded.
    pub fn reloads(&self, m: usize) -> usize {
        self.slots[m].reloads.load(Ordering::Relaxed)
    }

    /// True while slot `m` serves a degraded generation.
    pub fn is_degraded(&self, m: usize) -> bool {
        self.slots[m].degraded.load(Ordering::Relaxed)
    }

    /// Degrade transitions slot `m` has performed.
    pub fn degrades(&self, m: usize) -> usize {
        self.slots[m].degrades.load(Ordering::Relaxed)
    }

    /// Reload attempts slot `m` rejected at validation.
    pub fn reload_rejections(&self, m: usize) -> usize {
        self.slots[m].reload_rejections.load(Ordering::Relaxed)
    }

    /// Atomically swap slot `m` to a re-planned artifact.
    ///
    /// The artifact is fully validated (fingerprint, layout safety and a
    /// bit-exact execution proof) against the slot's graph *before* the
    /// swap; any failure leaves the old generation serving untouched.
    /// After the swap, new requests see the new generation (and its
    /// freshly pre-sized arena pool) while in-flight requests drain on
    /// the old `Arc`.
    pub fn reload(&self, m: usize, artifact: PlanArtifact) -> Result<ReloadInfo> {
        let slot = &self.slots[m];
        let (old_generation, old_peak, graph, arenas, weight_seed) = {
            let cur = lock(&slot.current);
            (
                cur.generation,
                cur.plan.peak(),
                cur.graph.clone(),
                cur.pool.capacity(),
                cur.weight_seed,
            )
        };
        // validate OUTSIDE the slot lock: a slow (or failing) artifact
        // must never stall or corrupt the serving path
        let state = match ModelState::new(
            &slot.name,
            graph,
            artifact,
            old_generation + 1,
            arenas,
            weight_seed,
        ) {
            Ok(s) => s,
            Err(e) => {
                slot.reload_rejections.fetch_add(1, Ordering::Relaxed);
                return Err(e.context(format!("hot-reload rejected for `{}`", slot.name)));
            }
        };
        let info = ReloadInfo {
            generation: state.generation,
            old_peak,
            new_peak: state.plan.peak(),
        };
        let old = {
            let mut cur = lock(&slot.current);
            std::mem::replace(&mut *cur, Arc::new(state))
        };
        // the displaced generation becomes the pin target for degrade,
        // and a fresh validated generation clears any degraded flag
        *lock(&slot.previous) = Some(old);
        slot.degraded.store(false, Ordering::Relaxed);
        slot.reloads.fetch_add(1, Ordering::Relaxed);
        Ok(info)
    }

    /// Abandon slot `m`'s current generation — its watermark check
    /// tripped, so its results can no longer be trusted. Pins the
    /// last-known-good generation when one exists; otherwise plans and
    /// proves a fresh *safe plan* (no overlap relaxation, no rewrites —
    /// every buffer disjoint) and installs it. The slot stays flagged
    /// degraded until a successful reload. Idempotent: a second caller
    /// (another worker hitting the same fault) is a no-op.
    pub fn degrade(&self, m: usize) -> Result<DegradeInfo> {
        let slot = &self.slots[m];
        if slot.degraded.swap(true, Ordering::SeqCst) {
            let cur = lock(&slot.current);
            return Ok(DegradeInfo {
                mode: DegradeMode::AlreadyDegraded,
                generation: cur.generation,
                peak: cur.plan.peak(),
            });
        }
        if let Some(prev) = lock(&slot.previous).take() {
            let info = DegradeInfo {
                mode: DegradeMode::PinnedPrevious,
                generation: prev.generation,
                peak: prev.plan.peak(),
            };
            *lock(&slot.current) = prev;
            slot.degrades.fetch_add(1, Ordering::Relaxed);
            return Ok(info);
        }
        let (old_generation, graph, arenas, weight_seed) = {
            let cur = lock(&slot.current);
            (
                cur.generation,
                cur.graph.clone(),
                cur.pool.capacity(),
                cur.weight_seed,
            )
        };
        // plan + prove outside the slot lock, like reload
        let built = Planner::safe_for_graph(&graph)
            .plan()
            .with_context(|| format!("planning safe fallback for `{}`", slot.name))
            .and_then(|plan| {
                let artifact = PlanArtifact::from_plan(&graph, &plan);
                ModelState::new(
                    &slot.name,
                    graph.clone(),
                    artifact,
                    old_generation + 1,
                    arenas,
                    weight_seed,
                )
            });
        match built {
            Ok(state) => {
                let info = DegradeInfo {
                    mode: DegradeMode::SafePlan,
                    generation: state.generation,
                    peak: state.plan.peak(),
                };
                *lock(&slot.current) = Arc::new(state);
                slot.degrades.fetch_add(1, Ordering::Relaxed);
                Ok(info)
            }
            Err(e) => {
                // nothing installed — leave the flag clear so a later
                // attempt (or reload) can still recover the slot
                slot.degraded.store(false, Ordering::SeqCst);
                Err(e.context(format!("degrading `{}` failed", slot.name)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_loads_plans_and_serves_current() {
        let specs = [ModelSpec::planned("tiny"), ModelSpec::planned("tiny_int8")];
        let reg = Registry::load(&specs, 2, 1, 42).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.names(), vec!["tiny", "tiny_int8"]);
        assert_eq!(reg.index_of("tiny_int8"), Some(1));
        let s = reg.current(0);
        assert_eq!(s.generation, 0);
        assert_eq!(s.pool.arena_bytes(), s.plan.peak());
        assert_eq!(s.input_elements(), 32 * 32 * 3);
    }

    #[test]
    fn reload_with_matching_fingerprint_bumps_generation() {
        let reg = Registry::load(&[ModelSpec::planned("tiny")], 2, 1, 42).unwrap();
        let g = crate::models::build("tiny").unwrap();
        // a different planning session over the same graph: same
        // fingerprint, possibly different layout — a valid re-plan
        let plan = Planner::for_graph(&g)
            .dmo(true)
            .strategies(&[crate::planner::Strategy::Eager])
            .plan()
            .unwrap();
        let old = reg.current(0);
        let info = reg.reload(0, PlanArtifact::from_plan(&g, &plan)).unwrap();
        assert_eq!(info.generation, 1);
        let new = reg.current(0);
        assert_eq!(new.generation, 1);
        assert_eq!(new.plan.peak(), info.new_peak);
        assert_eq!(reg.reloads(0), 1);
        // the old generation is still alive and executable for holders
        let mut arena = old.acquire_arena();
        let input = vec![0.5f32; old.input_elements()];
        old.execute(&mut arena, &input).unwrap();
    }

    #[test]
    fn degrade_without_previous_installs_a_safe_plan() {
        let reg = Registry::load(&[ModelSpec::planned("tiny")], 2, 1, 42).unwrap();
        let dmo_peak = reg.current(0).plan.peak();
        let info = reg.degrade(0).unwrap();
        assert_eq!(info.mode, DegradeMode::SafePlan);
        assert!(reg.is_degraded(0));
        assert_eq!(reg.degrades(0), 1);
        let cur = reg.current(0);
        assert_eq!(cur.generation, 1);
        assert!(
            cur.plan.peak() >= dmo_peak,
            "safe plan gives every buffer disjoint placement — never below the DMO peak"
        );
        assert!(cur.plan.alloc.applied.is_empty(), "no overlaps in a safe plan");
        // degraded but still serving, bit-identically provable
        let mut arena = cur.acquire_arena();
        let input = vec![0.5f32; cur.input_elements()];
        cur.execute(&mut arena, &input).unwrap();
        // second degrade is a no-op
        let again = reg.degrade(0).unwrap();
        assert_eq!(again.mode, DegradeMode::AlreadyDegraded);
        assert_eq!(reg.degrades(0), 1);
    }

    #[test]
    fn degrade_pins_previous_generation_and_reload_clears_it() {
        let reg = Registry::load(&[ModelSpec::planned("tiny")], 2, 1, 42).unwrap();
        let g = crate::models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g)
            .dmo(true)
            .strategies(&[crate::planner::Strategy::Eager])
            .plan()
            .unwrap();
        reg.reload(0, PlanArtifact::from_plan(&g, &plan)).unwrap();
        assert_eq!(reg.current(0).generation, 1);
        let info = reg.degrade(0).unwrap();
        assert_eq!(info.mode, DegradeMode::PinnedPrevious);
        assert_eq!(reg.current(0).generation, 0, "pinned last-known-good");
        assert!(reg.is_degraded(0));
        // a fresh validated reload recovers the slot
        reg.reload(0, PlanArtifact::from_plan(&g, &plan)).unwrap();
        assert!(!reg.is_degraded(0), "successful reload clears degraded");
    }

    #[test]
    fn rejected_reload_counts_and_leaves_generation_untouched() {
        let reg = Registry::load(&[ModelSpec::planned("tiny")], 2, 1, 42).unwrap();
        let bad = crate::fault::FaultPlan::garble(
            &reg.current(0).artifact,
            crate::fault::GarbleMode::FingerprintFlip,
        );
        assert!(reg.reload(0, bad).is_err());
        assert_eq!(reg.reload_rejections(0), 1);
        assert_eq!(reg.current(0).generation, 0);
        assert_eq!(reg.reloads(0), 0);
    }

    #[test]
    fn reload_with_stale_fingerprint_is_rejected_and_old_keeps_serving() {
        let reg = Registry::load(&[ModelSpec::planned("tiny")], 2, 1, 42).unwrap();
        // an artifact planned for a *different* graph
        let other = crate::models::build("tiny_int8").unwrap();
        let plan = Planner::for_graph(&other).dmo(true).plan().unwrap();
        let err = reg.reload(0, PlanArtifact::from_plan(&other, &plan));
        assert!(err.is_err(), "fingerprint mismatch must be rejected");
        let cur = reg.current(0);
        assert_eq!(cur.generation, 0, "old generation must keep serving");
        assert_eq!(reg.reloads(0), 0);
        let mut arena = cur.acquire_arena();
        let input = vec![0.25f32; cur.input_elements()];
        cur.execute(&mut arena, &input).unwrap();
    }
}
