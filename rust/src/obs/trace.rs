//! Low-overhead structured tracing: spans and instants recorded into
//! per-thread buffers, merged at drain, exportable as Chrome trace-event
//! JSON (loadable in Perfetto or `chrome://tracing`).
//!
//! Disabled (the default) the cost per probe is one relaxed atomic load and
//! no allocation. Enabled, each span costs two `Instant` reads and one
//! push into a thread-local buffer behind an uncontended mutex (the mutex
//! is only contended at [`drain`], which merges all buffers).
//!
//! ```
//! use dmo::obs::trace;
//! trace::enable();
//! {
//!     let mut sp = trace::span("exec:conv1", "interp");
//!     if sp.is_active() {
//!         sp.arg("bytes", dmo::util::json::num(4096));
//!     }
//! } // recorded on drop
//! let events = trace::drain();
//! assert_eq!(events.len(), 1);
//! let json = trace::export_chrome(&events).to_string();
//! assert!(json.contains("traceEvents"));
//! trace::disable();
//! ```

use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{self, Json};

/// One recorded event: a complete span (`ph == 'X'`) or an instant
/// (`ph == 'i'`). Timestamps are microseconds since the tracer epoch.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    pub cat: &'static str,
    pub ph: char,
    pub ts_us: u64,
    pub dur_us: u64,
    pub tid: u64,
    pub args: Vec<(&'static str, Json)>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

type Buffer = Arc<Mutex<Vec<TraceEvent>>>;

/// Global registry of per-thread buffers. Holding an `Arc` here keeps
/// events from threads that have since exited alive until [`drain`].
fn registry() -> &'static Mutex<Vec<Buffer>> {
    static REGISTRY: OnceLock<Mutex<Vec<Buffer>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

fn next_tid() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

thread_local! {
    static LOCAL: (u64, Buffer) = {
        let buf: Buffer = Arc::new(Mutex::new(Vec::new()));
        registry().lock().unwrap().push(buf.clone());
        (next_tid(), buf)
    };
}

fn record(mut ev: TraceEvent) {
    LOCAL.with(|(tid, buf)| {
        ev.tid = *tid;
        buf.lock().unwrap().push(ev);
    });
}

/// Turn recording on (process-wide). Sets the timestamp epoch on first use.
pub fn enable() {
    epoch();
    ENABLED.store(true, std::sync::atomic::Ordering::Relaxed);
}

/// Turn recording off. Already-buffered events stay until [`drain`].
pub fn disable() {
    ENABLED.store(false, std::sync::atomic::Ordering::Relaxed);
}

/// Whether the tracer is currently recording.
pub fn is_enabled() -> bool {
    ENABLED.load(std::sync::atomic::Ordering::Relaxed)
}

/// RAII span guard: records a complete (`ph: "X"`) event on drop. Inactive
/// (when tracing is disabled at creation) guards cost nothing on drop.
pub struct Span {
    active: bool,
    name: String,
    cat: &'static str,
    start_us: u64,
    args: Vec<(&'static str, Json)>,
}

impl Span {
    /// Whether this span will record — guard expensive argument
    /// construction behind this on hot paths.
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Attach a key/value argument (shown in the trace viewer).
    pub fn arg(&mut self, key: &'static str, value: Json) {
        if self.active {
            self.args.push((key, value));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = now_us();
        record(TraceEvent {
            name: std::mem::take(&mut self.name),
            cat: self.cat,
            ph: 'X',
            ts_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            tid: 0,
            args: std::mem::take(&mut self.args),
        });
    }
}

/// Open a span. `cat` groups rows in the trace viewer (`planner`,
/// `interp`, `fleet`). Records on drop; a no-op when tracing is disabled.
pub fn span(name: &str, cat: &'static str) -> Span {
    if !is_enabled() {
        return Span {
            active: false,
            name: String::new(),
            cat,
            start_us: 0,
            args: Vec::new(),
        };
    }
    Span {
        active: true,
        name: name.to_string(),
        cat,
        start_us: now_us(),
        args: Vec::new(),
    }
}

/// Record a zero-duration instant event (`ph: "i"`).
pub fn instant(name: &str, cat: &'static str, args: Vec<(&'static str, Json)>) {
    if !is_enabled() {
        return;
    }
    record(TraceEvent {
        name: name.to_string(),
        cat,
        ph: 'i',
        ts_us: now_us(),
        dur_us: 0,
        tid: 0,
        args,
    });
}

/// Take every buffered event from every thread, sorted by timestamp.
/// Buffers are left empty; recording state is unchanged.
pub fn drain() -> Vec<TraceEvent> {
    let mut all = Vec::new();
    for buf in registry().lock().unwrap().iter() {
        all.append(&mut buf.lock().unwrap());
    }
    all.sort_by_key(|e| (e.ts_us, e.tid));
    all
}

/// Render events as Chrome trace-event JSON:
/// `{"traceEvents": [{"name", "cat", "ph", "ts", "dur", "pid", "tid",
/// "args"}, …]}` — the format Perfetto and `chrome://tracing` load
/// directly. `ts`/`dur` are microseconds.
pub fn export_chrome(events: &[TraceEvent]) -> Json {
    let rows = events
        .iter()
        .map(|e| {
            let mut fields = vec![
                ("name", json::s(&e.name)),
                ("cat", json::s(e.cat)),
                ("ph", json::s(&e.ph.to_string())),
                ("ts", json::num(e.ts_us as usize)),
                ("pid", json::num(1)),
                ("tid", json::num(e.tid as usize)),
            ];
            if e.ph == 'X' {
                fields.push(("dur", json::num(e.dur_us as usize)));
            } else {
                // instant scope: thread
                fields.push(("s", json::s("t")));
            }
            if !e.args.is_empty() {
                let args = e.args.iter().map(|(k, v)| (*k, v.clone())).collect();
                fields.push(("args", json::obj(args)));
            }
            json::obj(fields)
        })
        .collect();
    json::obj(vec![("traceEvents", Json::Arr(rows))])
}
