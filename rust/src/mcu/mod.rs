//! Micro-controller deployment-fit analysis (§IV discussion).
//!
//! The paper's point: intermediate-tensor RAM, not weight storage, gates
//! deployment — MCUs almost universally carry far more flash than SRAM.
//! The catalog includes the paper's two parts (STM32F103xF hosting the
//! smallest MobileNet *only with DMO*, and the AT32UC3C of ESA's ESEO
//! mission) plus common contemporary targets.

use crate::ir::graph::Graph;
use crate::ir::op::OpKind;
use crate::ir::DType;
use crate::planner::SavingRow;

/// A micro-controller deployment target.
///
/// Beyond the memory capacities that gate *fit*, each entry carries a
/// coarse first-order performance model: a clock and per-operation
/// cycle factors that [`latency_ms`] combines with a model's
/// [`CostBreakdown`]. The factors are calibration-class numbers (an
/// M7 retires one MAC per cycle from its FPU pipeline; an M0+ without
/// hardware FP multiplies that by an order of magnitude via soft-float)
/// — good enough to rank targets and reject hopeless pairings, not a
/// cycle-accurate simulator.
#[derive(Debug, Clone)]
pub struct Mcu {
    pub name: &'static str,
    pub core: &'static str,
    pub flash_bytes: usize,
    pub sram_bytes: usize,
    /// Core clock in MHz (datasheet maximum).
    pub mhz: u32,
    /// Cycles per f32 multiply-accumulate (soft-float cores pay dearly).
    pub cycles_per_mac_f32: f64,
    /// Cycles per int8 multiply-accumulate (i32 accumulator).
    pub cycles_per_mac_i8: f64,
    /// Cycles per byte of SRAM traffic (load + store amortised).
    pub cycles_per_byte: f64,
}

/// Catalog of targets. Flash/SRAM from the referenced datasheets.
pub fn catalog() -> Vec<Mcu> {
    vec![
        Mcu {
            // §IV: "768 KB or 1 MB of program storage and 96 KB of SRAM"
            name: "STM32F103xF",
            core: "Cortex-M3",
            flash_bytes: 768 * 1024,
            sram_bytes: 96 * 1024,
            mhz: 72,
            cycles_per_mac_f32: 18.0, // no FPU: soft-float f32 MAC
            cycles_per_mac_i8: 6.0,
            cycles_per_byte: 2.0,
        },
        Mcu {
            // §IV: ESA ESEO on-board computer; ≥4× more flash than SRAM
            name: "AT32UC3C0512C",
            core: "AVR32",
            flash_bytes: 512 * 1024,
            sram_bytes: 68 * 1024,
            mhz: 66,
            cycles_per_mac_f32: 20.0,
            cycles_per_mac_i8: 8.0,
            cycles_per_byte: 2.0,
        },
        Mcu {
            name: "STM32F746",
            core: "Cortex-M7",
            flash_bytes: 1024 * 1024,
            sram_bytes: 320 * 1024,
            mhz: 216,
            cycles_per_mac_f32: 2.0, // dual-issue FPU pipeline
            cycles_per_mac_i8: 1.0,  // SMLAD-class dual MAC
            cycles_per_byte: 0.5,
        },
        Mcu {
            name: "STM32H743",
            core: "Cortex-M7",
            flash_bytes: 2 * 1024 * 1024,
            sram_bytes: 1024 * 1024,
            mhz: 480,
            cycles_per_mac_f32: 2.0,
            cycles_per_mac_i8: 1.0,
            cycles_per_byte: 0.5,
        },
        Mcu {
            name: "nRF52840",
            core: "Cortex-M4",
            flash_bytes: 1024 * 1024,
            sram_bytes: 256 * 1024,
            mhz: 64,
            cycles_per_mac_f32: 4.0, // single-precision FPU
            cycles_per_mac_i8: 2.0,
            cycles_per_byte: 1.0,
        },
        Mcu {
            name: "ESP32-WROOM",
            core: "Xtensa LX6",
            flash_bytes: 4 * 1024 * 1024,
            sram_bytes: 520 * 1024,
            mhz: 240,
            cycles_per_mac_f32: 6.0,
            cycles_per_mac_i8: 4.0,
            cycles_per_byte: 1.0,
        },
        Mcu {
            name: "RP2040 (2MB QSPI)",
            core: "Cortex-M0+",
            flash_bytes: 2 * 1024 * 1024,
            sram_bytes: 264 * 1024,
            mhz: 133,
            cycles_per_mac_f32: 30.0, // M0+: soft-float, 32-cycle MUL path
            cycles_per_mac_i8: 8.0,
            cycles_per_byte: 2.0,
        },
        Mcu {
            // mid-range M4 with 64 KB SRAM: the class of part the
            // paper's smallest MobileNet *just* misses even with DMO
            // (64 KB + a few bytes of arena) — §II-A splitting is what
            // puts it on this device
            name: "STM32F303RE",
            core: "Cortex-M4",
            flash_bytes: 512 * 1024,
            sram_bytes: 64 * 1024,
            mhz: 72,
            cycles_per_mac_f32: 4.0,
            cycles_per_mac_i8: 2.0,
            cycles_per_byte: 1.0,
        },
    ]
}

/// Arithmetic + memory-traffic cost of running a graph once, counted
/// from the reference kernels' loop structure. `macs` is multiply-
/// accumulates (window comparisons/adds for pools count as one each);
/// `bytes` is unique tensor bytes read and written per op — a coarse
/// SRAM-traffic proxy that deliberately ignores window re-reads, which
/// the MAC term already prices in.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CostBreakdown {
    pub macs: u64,
    pub bytes: u64,
}

/// Per-op cost accounting for one inference over `graph`. Banded ops
/// scale naturally: a band's tensors hold only the rows it touches, so
/// the inner-op formulas applied to the band's own shapes give the
/// band's share of the work.
pub fn graph_cost(graph: &Graph) -> CostBreakdown {
    let mut cost = CostBreakdown::default();
    for op in &graph.ops {
        let out = graph.tensor(op.output);
        let out_elems = out.shape.num_elements() as u64;
        let kind = match &op.kind {
            OpKind::Band(b) => b.inner.as_ref(),
            k => k,
        };
        cost.macs += match kind {
            OpKind::Conv2D(p) => {
                let in_c = graph.tensor(op.inputs[0]).shape.c() as u64;
                out_elems * (p.kernel.0 * p.kernel.1) as u64 * in_c
            }
            OpKind::DepthwiseConv2D(p) => out_elems * (p.kernel.0 * p.kernel.1) as u64,
            OpKind::Pool(p) => out_elems * (p.kernel.0 * p.kernel.1) as u64,
            OpKind::GlobalAvgPool => graph.tensor(op.inputs[0]).shape.num_elements() as u64,
            OpKind::FullyConnected { .. } | OpKind::MatMulAccum { .. } => {
                graph.tensor(op.inputs[0]).shape.num_elements() as u64 * out.shape.num_elements() as u64
            }
            // exp + normalise ≈ a handful of MAC-equivalents per element
            OpKind::Softmax => 8 * out_elems,
            OpKind::Binary(_) => out_elems,
            OpKind::Unary(_)
            | OpKind::Reshape { .. }
            | OpKind::Concat
            | OpKind::ConcatRows
            | OpKind::Pad { .. }
            | OpKind::Band(_) => 0,
        };
        let in_bytes: u64 = op
            .inputs
            .iter()
            .map(|&t| graph.tensor(t).size_bytes() as u64)
            .sum();
        cost.bytes += in_bytes + out.size_bytes() as u64;
    }
    cost
}

/// First-order single-inference latency of `cost` on `mcu`, in
/// milliseconds: `(macs·cycles_per_mac + bytes·cycles_per_byte) / clock`.
/// `dtype` selects the MAC cost (the arena dtype decides which
/// arithmetic the kernels run in).
pub fn latency_ms(mcu: &Mcu, cost: &CostBreakdown, dtype: DType) -> f64 {
    let per_mac = match dtype {
        DType::I8 => mcu.cycles_per_mac_i8,
        _ => mcu.cycles_per_mac_f32,
    };
    let cycles = cost.macs as f64 * per_mac + cost.bytes as f64 * mcu.cycles_per_byte;
    cycles / (mcu.mhz as f64 * 1e3)
}

/// [`latency_ms`] for a graph: cost from [`graph_cost`], dtype from the
/// graph's first tensor (the arena dtype).
pub fn estimate_latency_ms(graph: &Graph, mcu: &Mcu) -> f64 {
    let dtype = graph
        .tensors
        .first()
        .map(|t| t.dtype)
        .unwrap_or(DType::F32);
    latency_ms(mcu, &graph_cost(graph), dtype)
}

/// Can `model` deploy on `mcu` given an arena of `arena_bytes`?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fit {
    /// The flash image (weights, plus code when checked via
    /// [`fit_flash`] with an emitted unit's footprint) fits.
    pub weights_fit: bool,
    pub arena_fits: bool,
    /// flash image bytes / flash capacity, scaled by 1000 (‰)
    pub flash_permille: usize,
}

impl Fit {
    pub fn deployable(&self) -> bool {
        self.weights_fit && self.arena_fits
    }
}

/// Fit check against an explicit flash image size — use
/// [`crate::codegen::flash_footprint`] (weights + code estimate) to
/// check the unit `dmo emit-c` actually produces, not just its weights.
pub fn fit_flash(mcu: &Mcu, arena_bytes: usize, flash_needed: usize) -> Fit {
    Fit {
        weights_fit: flash_needed <= mcu.flash_bytes,
        arena_fits: arena_bytes <= mcu.sram_bytes,
        flash_permille: if mcu.flash_bytes == 0 {
            1000
        } else {
            flash_needed * 1000 / mcu.flash_bytes
        },
    }
}

/// Weights-only fit check for a model on an MCU (the paper's §IV
/// accounting, which ignores code size).
pub fn fit(graph: &Graph, mcu: &Mcu, arena_bytes: usize) -> Fit {
    fit_flash(mcu, arena_bytes, graph.weight_bytes())
}

/// One row of the deployment matrix: does DMO — or §II-A splitting —
/// change deployability?
#[derive(Debug, Clone)]
pub struct DeployRow {
    pub model: String,
    pub mcu: &'static str,
    /// Flash bytes the emitted unit needs (weights + code estimate).
    pub flash_bytes: usize,
    /// The emitted unit's flash image fits this part.
    pub flash_fits: bool,
    pub without_dmo: bool,
    pub with_dmo: bool,
    /// Deployability of the best split plan, when one was computed and
    /// a split rewrite won (`None` = no split plan to compare).
    pub with_split: Option<bool>,
    /// Estimated single-inference latency on this part
    /// ([`estimate_latency_ms`]).
    pub latency_ms: f64,
}

impl DeployRow {
    /// A (model, target) pair that becomes deployable *only* through
    /// §II-A splitting — the rescue the paper's future-work section
    /// promises.
    pub fn rescued_by_split(&self) -> bool {
        self.with_split == Some(true) && !self.with_dmo && !self.without_dmo
    }
}

/// Cross every catalog MCU with a planned model. Deployability checks
/// the full emitted-unit flash footprint (weights + code estimate via
/// [`crate::codegen::flash_footprint`]), not just SRAM.
pub fn deploy_matrix(graph: &Graph, row: &SavingRow) -> Vec<DeployRow> {
    deploy_matrix_split(graph, row, None)
}

/// [`deploy_matrix`] with an optional split plan: `split` carries the
/// split plan's peak and the rewritten (banded) graph, whose flash
/// footprint gates the split column — weights are stored once per
/// original op ([`Graph::weight_bytes`] dedupes), but the banded
/// kernels and extra call sites cost code bytes.
pub fn deploy_matrix_split(
    graph: &Graph,
    row: &SavingRow,
    split: Option<(usize, &Graph)>,
) -> Vec<DeployRow> {
    let flash = crate::codegen::flash_footprint(graph).total();
    let split_flash = split.map(|(_, g)| crate::codegen::flash_footprint(g).total());
    let cost = graph_cost(graph);
    let dtype = graph
        .tensors
        .first()
        .map(|t| t.dtype)
        .unwrap_or(DType::F32);
    catalog()
        .iter()
        .map(|m| DeployRow {
            model: graph.name.clone(),
            mcu: m.name,
            flash_bytes: flash,
            flash_fits: flash <= m.flash_bytes,
            without_dmo: fit_flash(m, row.original, flash).deployable(),
            with_dmo: fit_flash(m, row.optimised, flash).deployable(),
            with_split: split.map(|(peak, _)| {
                fit_flash(m, peak, split_flash.unwrap_or(flash)).deployable()
            }),
            latency_ms: latency_ms(m, &cost, dtype),
        })
        .collect()
}

/// Deployment matrix for a fully planned model, including the split
/// column when [`crate::planner::PlannedModel::new_split`] found a
/// winning rewrite.
pub fn deploy_matrix_planned(pm: &crate::planner::PlannedModel) -> Vec<DeployRow> {
    let split = pm
        .split
        .as_ref()
        .and_then(|p| p.rewrite.as_ref().map(|r| (p.peak(), &r.graph)));
    deploy_matrix_split(&pm.graph, &pm.row(), split)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::planner::PlannedModel;

    /// §IV's headline deployment claim: MobileNet v1 0.25 128 (8-bit)
    /// fits the STM32F103xF's 96 KB SRAM *only* with DMO (96 KB arena
    /// leaves no room for stack/runtime; 64 KB does), and its ~620 KB of
    /// weights take most of the 768 KB flash.
    #[test]
    fn stm32f103_needs_dmo_for_smallest_mobilenet() {
        let pm = PlannedModel::new(models::build("mobilenet_v1_0.25_128_int8").unwrap()).unwrap();
        let row = pm.row();
        let stm = &catalog()[0];
        // without DMO the arena exactly consumes all SRAM — treat the
        // paper's "only possible with DMO" as requiring headroom
        let without = fit(&pm.graph, stm, row.original + 4 * 1024); // +4 KB runtime headroom
        let with = fit(&pm.graph, stm, row.optimised + 4 * 1024);
        assert!(!without.arena_fits, "96 KB arena + runtime must NOT fit");
        assert!(with.arena_fits, "64 KB arena + runtime must fit");
        assert!(with.weights_fit, "weights must fit flash");
        // §IV: weights ≈ 60.8 % of program memory; ours is close
        assert!(
            with.flash_permille > 400 && with.flash_permille < 800,
            "got {}",
            with.flash_permille
        );
    }

    #[test]
    fn big_models_never_fit_mcus() {
        let pm = PlannedModel::new(models::build("mobilenet_v2_1.0_224").unwrap()).unwrap();
        let row = pm.row();
        for m in catalog() {
            assert!(
                !fit(&pm.graph, &m, row.optimised).deployable(),
                "{} should not fit",
                m.name
            );
        }
    }

    #[test]
    fn matrix_shape() {
        let pm = PlannedModel::new(models::build("tiny_int8").unwrap()).unwrap();
        let rows = deploy_matrix(&pm.graph, &pm.row());
        assert_eq!(rows.len(), catalog().len());
        // tiny model fits everything, with or without
        assert!(rows.iter().all(|r| r.with_dmo && r.flash_fits));
        // the matrix accounts for code, not just weights
        assert!(rows.iter().all(|r| r.flash_bytes > pm.graph.weight_bytes()));
    }

    /// The §II-A pay-off the paper leaves as future work: the smallest
    /// MobileNet's DMO arena is 64 KB *plus a few bytes*, so a 64 KB
    /// part refuses it — only the split plan (≈61 KB) deploys there.
    #[test]
    fn split_rescues_mnv1_on_the_64kb_part() {
        let pm = PlannedModel::new_split(
            models::build("mobilenet_v1_0.25_128_int8").unwrap(),
            4,
            0,
            None,
        )
        .unwrap();
        let split = pm.split.as_ref().expect("splitting must win on mnv1");
        assert!(split.peak() < pm.dmo.peak());
        assert!(split.peak() <= 64 * 1024, "split peak {} > 64 KB", split.peak());
        let rows = deploy_matrix_planned(&pm);
        let f303 = rows.iter().find(|r| r.mcu == "STM32F303RE").unwrap();
        assert!(!f303.without_dmo, "96 KB arena cannot fit 64 KB SRAM");
        assert!(!f303.with_dmo, "64 KB + ε arena cannot fit 64 KB SRAM");
        assert_eq!(f303.with_split, Some(true));
        assert!(f303.rescued_by_split());
        assert_eq!(rows.iter().filter(|r| r.rescued_by_split()).count(), 1);
    }

    #[test]
    fn unsplit_matrix_carries_no_split_column() {
        let pm = PlannedModel::new(models::build("tiny_int8").unwrap()).unwrap();
        let rows = deploy_matrix(&pm.graph, &pm.row());
        assert!(rows.iter().all(|r| r.with_split.is_none()));
        assert!(rows.iter().all(|r| !r.rescued_by_split()));
    }

    #[test]
    fn cost_model_counts_macs_and_bytes() {
        let g = models::build("tiny").unwrap();
        let c = graph_cost(&g);
        assert!(c.macs > 0, "tiny has convolutions");
        assert!(c.bytes > 0);
        // int8 variant moves fewer bytes (1-byte elements), same macs shape
        let gq = models::build("tiny_int8").unwrap();
        let cq = graph_cost(&gq);
        assert!(cq.bytes < c.bytes);
    }

    /// A slow part can fit a model's SRAM and flash yet miss a latency
    /// budget a fast part makes easily — the gate `dmo fit --budget-ms`
    /// applies. Pinned relatively: the soft-float 72 MHz STM32F103xF is
    /// orders of magnitude slower than the 480 MHz M7.
    #[test]
    fn latency_budget_rejects_slow_part_that_fits_sram() {
        let pm = PlannedModel::new(models::build("tiny").unwrap()).unwrap();
        let rows = deploy_matrix(&pm.graph, &pm.row());
        let f103 = rows.iter().find(|r| r.mcu == "STM32F103xF").unwrap();
        let h743 = rows.iter().find(|r| r.mcu == "STM32H743").unwrap();
        assert!(f103.with_dmo, "tiny fits the F103's SRAM and flash");
        assert!(h743.with_dmo);
        assert!(
            f103.latency_ms > 10.0 * h743.latency_ms,
            "soft-float 72 MHz vs FPU 480 MHz: got {} vs {}",
            f103.latency_ms,
            h743.latency_ms
        );
        // a budget between the two rejects the F103 on latency alone
        let budget = (f103.latency_ms * h743.latency_ms).sqrt();
        assert!(h743.latency_ms <= budget && f103.latency_ms > budget);
    }

    #[test]
    fn int8_latency_beats_f32_on_every_part() {
        let f = models::build("tiny").unwrap();
        let q = models::build("tiny_int8").unwrap();
        for m in catalog() {
            assert!(
                estimate_latency_ms(&q, &m) < estimate_latency_ms(&f, &m),
                "{}: int8 must be faster",
                m.name
            );
        }
    }

    #[test]
    fn flash_image_gates_deployability() {
        let g = models::build("tiny_int8").unwrap();
        let stm = &catalog()[0];
        // arena fits but an oversized flash image must block deployment
        let f = fit_flash(stm, 16 * 1024, stm.flash_bytes * 2);
        assert!(f.arena_fits && !f.weights_fit && !f.deployable());
        assert_eq!(f.flash_permille, 2000);
        // and the emitted-unit footprint is what deploy_matrix feeds in
        let flash = crate::codegen::flash_footprint(&g).total();
        let ok = fit_flash(stm, 16 * 1024, flash);
        assert!(ok.deployable());
    }
}
