//! Pooled arenas: pre-sized execution buffers reused across requests.
//!
//! A DMO plan fixes the model's arena size at planning time (§II-D), so
//! the serving layer can allocate the K arenas a model will ever need
//! *once*, at registration, and hand them out per request. At steady
//! state no request allocates: an inference acquires a pooled arena,
//! executes the planned layout in place, and returns the buffer on drop.
//! The pool keeps an allocation counter so benches and tests can assert
//! that property (`allocs == 0` / `hit_rate() == 1.0`) instead of
//! trusting it.
//!
//! Reuse is safe without zeroing because a validated plan writes every
//! region before reading it (inputs are stored up front; every op fully
//! stores — or bias-initialises, for the accumulating matmul — its
//! output before consumers load it). `rust/tests/fleet_serving.rs`
//! proves it by executing on a deliberately dirtied arena and demanding
//! bit-identical outputs.

use crate::ops::exec::Arena;
use crate::util::sync::lock;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A fixed-size pool of same-sized [`Arena`]s for one model generation.
pub struct ArenaPool {
    /// Arena size in bytes — the plan's peak.
    size: usize,
    /// Target resident count (K); returns beyond K are dropped.
    capacity: usize,
    free: Mutex<Vec<Arena>>,
    /// Acquires served by a pooled arena.
    hits: AtomicUsize,
    /// Acquires that had to allocate because the pool ran dry — the
    /// counter that must stay 0 at steady state.
    allocs: AtomicUsize,
}

impl ArenaPool {
    /// Pre-size `capacity` arenas of `size` bytes. This is the only
    /// allocation a well-provisioned model ever performs.
    pub fn new(size: usize, capacity: usize) -> ArenaPool {
        let capacity = capacity.max(1);
        ArenaPool {
            size,
            capacity,
            free: Mutex::new((0..capacity).map(|_| Arena::new(size)).collect()),
            hits: AtomicUsize::new(0),
            allocs: AtomicUsize::new(0),
        }
    }

    /// Arena size in bytes every pooled buffer has.
    pub fn arena_bytes(&self) -> usize {
        self.size
    }

    /// Target resident arena count (K).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Take an arena, preferring a pooled one; allocates (and counts it)
    /// only when more than `capacity` acquisitions are in flight.
    pub fn acquire(self: &Arc<Self>) -> PooledArena {
        let pooled = lock(&self.free).pop();
        let arena = match pooled {
            Some(a) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                a
            }
            None => {
                self.allocs.fetch_add(1, Ordering::Relaxed);
                Arena::new(self.size)
            }
        };
        PooledArena {
            arena: Some(arena),
            pool: Arc::clone(self),
        }
    }

    /// Acquires served from the pool.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Arenas allocated after construction (pool misses).
    pub fn allocs(&self) -> usize {
        self.allocs.load(Ordering::Relaxed)
    }

    /// Fraction of acquisitions served without allocating (1.0 when the
    /// pool has seen no traffic yet).
    pub fn hit_rate(&self) -> f64 {
        let (h, a) = (self.hits(), self.allocs());
        if h + a == 0 {
            return 1.0;
        }
        h as f64 / (h + a) as f64
    }

    /// Arenas currently resident and idle.
    pub fn idle(&self) -> usize {
        lock(&self.free).len()
    }

    fn release(&self, mut arena: Arena) {
        // a panicking request can unwind with its profiling sink still
        // installed — a returned arena must never carry one request's
        // sink into the next
        arena.set_sink(None);
        let mut free = lock(&self.free);
        // never retain beyond K, and never retain a foreign-sized arena
        // (the pool is per model-generation, so sizes only mismatch if a
        // caller moved a guard across pools — drop, don't poison)
        if free.len() < self.capacity && arena.len() == self.size {
            free.push(arena);
        }
    }
}

/// RAII guard over a pooled [`Arena`]; returns the buffer on drop.
pub struct PooledArena {
    arena: Option<Arena>,
    pool: Arc<ArenaPool>,
}

impl Deref for PooledArena {
    type Target = Arena;
    fn deref(&self) -> &Arena {
        self.arena.as_ref().expect("arena taken")
    }
}

impl DerefMut for PooledArena {
    fn deref_mut(&mut self) -> &mut Arena {
        self.arena.as_mut().expect("arena taken")
    }
}

impl Drop for PooledArena {
    fn drop(&mut self) {
        if let Some(a) = self.arena.take() {
            self.pool.release(a);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_never_allocates() {
        let pool = Arc::new(ArenaPool::new(128, 2));
        for _ in 0..100 {
            let a = pool.acquire();
            assert_eq!(a.len(), 128);
        }
        assert_eq!(pool.allocs(), 0);
        assert_eq!(pool.hits(), 100);
        assert_eq!(pool.hit_rate(), 1.0);
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn oversubscription_allocates_then_trims_back_to_capacity() {
        let pool = Arc::new(ArenaPool::new(64, 2));
        let g1 = pool.acquire();
        let g2 = pool.acquire();
        let g3 = pool.acquire(); // pool dry → counted allocation
        assert_eq!(pool.allocs(), 1);
        assert_eq!(pool.hits(), 2);
        assert!(pool.hit_rate() < 1.0);
        drop(g1);
        drop(g2);
        drop(g3); // third return exceeds capacity and is dropped
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn empty_pool_reports_perfect_rate() {
        let pool = ArenaPool::new(16, 1);
        assert_eq!(pool.hit_rate(), 1.0);
    }

    #[test]
    fn capacity_zero_clamps_to_one() {
        let pool = Arc::new(ArenaPool::new(16, 0));
        assert_eq!(pool.capacity(), 1);
        let _g = pool.acquire();
        assert_eq!(pool.allocs(), 0);
    }
}
