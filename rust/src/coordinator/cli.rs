//! `dmo serve` — CLI front-end for the serving loop.
//!
//! Two modes share the subcommand: the single-model PJRT loop
//! (default), and — when `--models` is given — the multi-model fleet
//! (`crate::fleet`): pooled arenas, per-model fair admission, and
//! artifact hot-reload via `--reload-watch`.

use super::server::{serve, ServeConfig};
use super::BatchPolicy;
use crate::fault::FaultSpec;
use crate::fleet::{fleet_serve, BreakerConfig, FleetConfig, ModelSpec};
use crate::util::args::{opt, ArgSpec, Args};
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::Duration;

/// Flags accepted by `dmo serve`.
pub const SERVE_SPEC: &[ArgSpec] = &[
    opt("--requests", "number of requests to generate (default 256; fleet 1024)"),
    opt("--rate", "open-loop arrival rate, req/s (default 500; fleet 0 = closed loop)"),
    opt("--queue", "bounded queue capacity, per model in fleet mode (default 64)"),
    opt("--batch", "max dynamic batch size (default 8)"),
    opt("--window-us", "batching window in µs (default 2000)"),
    opt("--seed", "workload RNG seed (default 42)"),
    opt("--plan", "pre-computed plan artifact to start from (skips the planner search)"),
    opt("--model", "model the memory plan is for (default `tiny`)"),
    opt("--jobs", "planner worker threads for startup planning (default: all cores)"),
    opt("--os-cache", "persisted O_s cache file: loaded before startup planning, saved after — cold replicas start warm"),
    opt("--models", "comma-separated model list — switches to multi-model fleet serving"),
    opt("--arenas", "fleet: pooled arenas per model (default 4)"),
    opt("--workers", "fleet: serving worker threads (default: all cores)"),
    opt("--mix", "fleet: comma-separated traffic weights, one per model (default uniform)"),
    opt("--reload-watch", "fleet: directory watched for `<model>.plan.json` hot-reload drops"),
    opt("--metrics-out", "Prometheus text snapshot file (fleet: rewritten every 500 ms + at shutdown)"),
    opt("--trace-out", "Chrome trace-event JSON of the run (load in Perfetto / chrome://tracing)"),
    opt("--faults", "fleet: deterministic fault spec `kind:count[@model],…` (kinds: panic, corrupt-arena, corrupt-reload, stall, delay); implies fleet mode"),
    opt("--deadline-us", "fleet: per-request deadline in µs (0 = none; expiry is a retryable failure)"),
    opt("--retries", "fleet: client retries per failed request, exponential backoff (default 0)"),
    opt("--breaker-k", "fleet: consecutive failures that quarantine a model (default 3)"),
    opt("--breaker-cooldown", "fleet: quarantine sheds before a half-open probe (default 8)"),
];

/// Entry point used by `main.rs`.
pub fn serve_main(args: &Args) -> Result<()> {
    let trace_out = args.value("--trace-out").map(PathBuf::from);
    if trace_out.is_some() {
        crate::obs::trace::enable();
    }
    let result = serve_dispatch(args);
    if let Some(path) = trace_out {
        crate::obs::trace::disable();
        let events = crate::obs::trace::drain();
        let json = crate::obs::trace::export_chrome(&events).to_string();
        std::fs::write(&path, json)
            .with_context(|| format!("writing trace to {}", path.display()))?;
        println!(
            "trace           : {} events → {} (load in Perfetto)",
            events.len(),
            path.display()
        );
    }
    result
}

fn serve_dispatch(args: &Args) -> Result<()> {
    // fault injection only exists in the fleet path, so --faults alone
    // (CI chaos smoke) selects fleet mode over the default single model
    if args.value("--models").is_some() || args.value("--faults").is_some() {
        return fleet_main(args);
    }
    let cfg = ServeConfig {
        requests: args.parsed("--requests", 256u64)?,
        rate: args.parsed("--rate", 500.0f64)?,
        queue_capacity: args.parsed("--queue", 64usize)?,
        policy: BatchPolicy {
            max_batch: args.parsed("--batch", 8usize)?,
            window: Duration::from_micros(args.parsed("--window-us", 2000u64)?),
        },
        seed: args.parsed("--seed", 42u64)?,
        plan_artifact: args.value("--plan").map(PathBuf::from),
        plan_model: args.value("--model").unwrap_or("tiny").to_string(),
        jobs: args.parsed("--jobs", 0usize)?,
        os_cache_path: args.value("--os-cache").map(PathBuf::from),
        metrics_out: args.value("--metrics-out").map(PathBuf::from),
        ..Default::default()
    };
    println!(
        "serving {} requests at {} req/s (queue {}, batch ≤{}, window {:?})",
        cfg.requests, cfg.rate, cfg.queue_capacity, cfg.policy.max_batch, cfg.policy.window
    );
    if let Some(p) = &cfg.plan_artifact {
        println!("memory plan     : loaded from artifact {}", p.display());
    }
    let report = serve(&cfg)?;
    let l = report.metrics.latency();
    println!("platform        : {}", report.platform);
    println!("completed       : {} ({} shed)", report.completed, report.shed);
    println!("wall time       : {:.3} s", report.wall.as_secs_f64());
    println!("throughput      : {:.1} req/s", report.throughput_rps);
    println!(
        "latency         : mean {:.0} µs  p50 {:.0}  p95 {:.0}  p99 {:.0}  max {:.0}",
        l.mean_us, l.p50_us, l.p95_us, l.p99_us, l.max_us
    );
    println!(
        "batching        : mean {:.2} req/batch, lane efficiency {:.0}%",
        report.metrics.mean_batch(),
        100.0 * report.metrics.batch_efficiency()
    );
    println!(
        "queue           : max depth {} of {}",
        report.queue_max_depth, cfg.queue_capacity
    );
    println!(
        "on-device arena : {} original → {} with DMO",
        crate::report::fmt_bytes(report.arena_original),
        crate::report::fmt_bytes(report.arena_dmo)
    );
    if let Some(p) = &cfg.metrics_out {
        println!("metrics         : snapshot written to {}", p.display());
    }
    Ok(())
}

/// `dmo serve --models a,b,c` — the multi-model fleet loop.
fn fleet_main(args: &Args) -> Result<()> {
    let names: Vec<String> = args
        .value("--models")
        .unwrap_or("tiny")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!names.is_empty(), "--models needs at least one model name");
    let reload_watch = args.value("--reload-watch").map(PathBuf::from);
    let models: Vec<ModelSpec> = names
        .iter()
        .map(|n| ModelSpec {
            name: n.clone(),
            // a watched directory that already holds an artifact for the
            // model seeds the initial generation from it
            artifact: reload_watch
                .as_ref()
                .map(|d| d.join(format!("{n}.plan.json")))
                .filter(|p| p.exists()),
        })
        .collect();
    let mix: Vec<f64> = match args.value("--mix") {
        None => Vec::new(),
        Some(s) => s
            .split(',')
            .map(|w| {
                w.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("--mix: cannot parse weight `{w}`"))
            })
            .collect::<Result<_>>()?,
    };
    let faults = match args.value("--faults") {
        None => None,
        Some(s) => {
            let spec = FaultSpec::parse(s).map_err(|e| anyhow::anyhow!("--faults: {e}"))?;
            if spec.is_empty() { None } else { Some(spec) }
        }
    };
    let deadline_us = args.parsed("--deadline-us", 0u64)?;
    let cfg = FleetConfig {
        models,
        arenas: args.parsed("--arenas", 4usize)?,
        workers: args.parsed("--workers", 0usize)?,
        queue_capacity: args.parsed("--queue", 64usize)?,
        requests: args.parsed("--requests", 1024u64)?,
        rate: args.parsed("--rate", 0.0f64)?,
        mix,
        seed: args.parsed("--seed", 42u64)?,
        jobs: args.parsed("--jobs", 0usize)?,
        reload_watch,
        metrics_out: args.value("--metrics-out").map(PathBuf::from),
        faults,
        deadline: (deadline_us > 0).then(|| Duration::from_micros(deadline_us)),
        retries: args.parsed("--retries", 0u32)?,
        breaker: BreakerConfig {
            threshold: args.parsed("--breaker-k", BreakerConfig::default().threshold)?,
            cooldown: args.parsed("--breaker-cooldown", BreakerConfig::default().cooldown)?,
        },
        ..FleetConfig::default()
    };
    println!(
        "fleet: {} models × {} arenas, {} workers, queue {}/model, {} requests ({})",
        names.len(),
        cfg.arenas,
        if cfg.workers == 0 { "all-core".to_string() } else { cfg.workers.to_string() },
        cfg.queue_capacity,
        cfg.requests,
        if cfg.rate > 0.0 {
            format!("open loop @ {} req/s, shedding", cfg.rate)
        } else {
            "closed loop".to_string()
        },
    );
    if let Some(d) = &cfg.reload_watch {
        println!("hot-reload      : watching {} for <model>.plan.json", d.display());
    }
    if let Some(spec) = &cfg.faults {
        println!(
            "fault injection : {spec} (seed {}, breaker K={} cooldown={}, {} retries{})",
            cfg.seed,
            cfg.breaker.threshold,
            cfg.breaker.cooldown,
            cfg.retries,
            match cfg.deadline {
                Some(d) => format!(", deadline {d:?}"),
                None => String::new(),
            }
        );
    }
    let report = fleet_serve(&cfg)?;
    println!(
        "completed       : {} ({} shed, {} failed) in {:.3} s — {:.0} req/s",
        report.completed,
        report.shed,
        report.failed,
        report.wall.as_secs_f64(),
        report.throughput_rps
    );
    if cfg.faults.is_some() || report.failed + report.retried + report.quarantine_shed > 0 {
        println!(
            "resilience      : {} faults injected | {} retried | {} quarantine-shed | {} served degraded",
            report.faults_injected, report.retried, report.quarantine_shed, report.degraded_served
        );
    }
    for e in &report.worker_errors {
        println!("worker error    : {e}");
    }
    for m in &report.per_model {
        let l = m.metrics.latency();
        let status = if m.quarantined {
            " [quarantined]"
        } else if m.degraded {
            " [degraded]"
        } else {
            ""
        };
        println!(
            "  {:<14} gen {} ({} reloads): {} done, {} shed, {} failed | p50 {:.0} p95 {:.0} \
             p99 {:.0} µs | arena {} | pool hit {:.1}% ({} allocs) | max queue {}/{}{status}",
            m.model,
            m.generation,
            m.reloads,
            m.completed,
            m.shed,
            m.failed,
            l.p50_us,
            l.p95_us,
            l.p99_us,
            crate::report::fmt_bytes(m.arena_bytes),
            100.0 * m.pool_hit_rate,
            m.pool_allocs,
            m.max_queue_depth,
            m.queue_capacity
        );
    }
    if let Some(p) = &cfg.metrics_out {
        println!("metrics         : snapshot written to {}", p.display());
    }
    Ok(())
}
