"""AOT: lower the L2 model (with its L1 Pallas kernels) to HLO text.

HLO *text* is the interchange format — NOT `lowered.compile()` output or
serialized HloModuleProto: jax ≥ 0.5 emits protos with 64-bit instruction
ids which the Rust side's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids cleanly. See
/opt/xla-example/README.md.

Emits, per compiled batch size B:
    artifacts/model_b{B}.hlo.txt
plus a metadata sidecar the Rust runtime/planner reads:
    artifacts/model.meta.json

Run via `make artifacts` (no-op when inputs are unchanged).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import CLASSES, RES, init_params, make_batched

BATCH_SIZES = [1, 2, 4, 8]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple convention)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="primary artifact path; siblings are derived from it")
    ap.add_argument("--batches", default=",".join(map(str, BATCH_SIZES)))
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)
    batches = [int(b) for b in args.batches.split(",")]

    params = init_params()
    fn = make_batched(params, use_pallas=True)

    for b in batches:
        spec = jax.ShapeDtypeStruct((b, RES, RES, 3), jnp.float32)
        lowered = jax.jit(fn).lower(spec)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"model_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    # primary artifact = batch-1 copy at the requested path (Makefile stamp)
    with open(os.path.join(out_dir, "model_b1.hlo.txt")) as f:
        primary = f.read()
    with open(args.out, "w") as f:
        f.write(primary)

    meta = {
        "input_shape": [RES, RES, 3],
        "output_features": CLASSES,
        "batch_sizes": batches,
        "model": "tiny",
        "kernels": ["pallas dwconv2d (interpret)", "pallas pointwise_conv (interpret)"],
    }
    meta_path = os.path.join(out_dir, "model.meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
