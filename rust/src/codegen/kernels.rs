//! The C99 kernel bodies the emitter pastes into a translation unit.
//!
//! Every kernel is a line-for-line port of the corresponding arm of
//! [`crate::ops::exec::execute_op`]: same loop nests, same accumulation
//! order, same read-before-write interleaving. That fidelity is the
//! whole point — the `O_s` overlap budgets were computed against the
//! reference sweep order, so the emitted code must touch the arena in
//! exactly that order or the planned overlaps stop being safe. Do not
//! "optimise" these loops without re-deriving the overlap analysis.
//!
//! Floating-point notes (the differential harness asserts bit-exactness
//! against the Rust interpreter):
//! * comparisons are written out (`if (v > acc)`) rather than calling
//!   `fmaxf`, matching the interpreter and fixing `-0.0`/`+0.0` ties;
//! * `expf`/`roundf` come from libm — the same routines Rust's
//!   `f32::exp`/`f32::round` lower to on a glibc host;
//! * the harness compiles with `-ffp-contract=off` so the compiler
//!   cannot fuse `a * b + c` into an FMA the interpreter did not do.
//!
//! Alongside the generic byte-addressed kernels, [`fast_source`]
//! generates *fast variants* per [`super::tune::Variant`]: typed-pointer
//! loops (the compiler addresses elements directly instead of calling
//! `dmo_load`/`dmo_store` per element) whose `Reference` order keeps the
//! exact element order of the generic kernel — same loads, same stores,
//! same f32 accumulation sequence — so they stay both bit-identical
//! *and* safe over planned in-place overlaps. The `ChannelOuter` order
//! reorders stores and is only emitted where the plan proves the
//! buffers disjoint. The `i8` (`_q`) variants follow the CMSIS-NN
//! idiom: accumulate in `int32_t`, requantise at store
//! ([`REQUANT_HELPER`]); the emitter proves at emit time (from the
//! actual generated weights) that every accumulator stays below 2^24,
//! where f32 accumulation of integers is exact — so the integer path is
//! bit-identical to the float reference, not just close.

use crate::ir::graph::Graph;
use crate::ir::op::{OpKind, PoolKind, UnaryKind};
use crate::ir::DType;

use super::tune::{LoopOrder, Variant};

/// One emitted kernel function. Several [`OpKind`]s can share a kernel
/// (both pool flavours, unary/reshape copies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Kernel {
    Conv2D,
    DwConv2D,
    Pool,
    GlobalAvgPool,
    Unary,
    Binary,
    Fc,
    MatMul,
    Concat,
    Pad,
    Softmax,
    /// §II-A banded variants: full-frame padding/clipping geometry,
    /// band-local addressing. Banded unary ops and concat-rows
    /// reassembly reuse [`Kernel::Unary`] (they are offset copies).
    BandConv2D,
    BandDwConv2D,
    BandPool,
}

impl Kernel {
    /// Kernel implementing `kind`.
    pub(crate) fn for_op(kind: &OpKind) -> Kernel {
        match kind {
            OpKind::Conv2D(_) => Kernel::Conv2D,
            OpKind::DepthwiseConv2D(_) => Kernel::DwConv2D,
            OpKind::Pool(_) => Kernel::Pool,
            OpKind::GlobalAvgPool => Kernel::GlobalAvgPool,
            OpKind::Unary(_) | OpKind::Reshape { .. } => Kernel::Unary,
            OpKind::Binary(_) => Kernel::Binary,
            OpKind::FullyConnected { .. } => Kernel::Fc,
            OpKind::MatMulAccum { .. } => Kernel::MatMul,
            OpKind::Concat => Kernel::Concat,
            OpKind::Pad { .. } => Kernel::Pad,
            OpKind::Softmax => Kernel::Softmax,
            OpKind::Band(b) => match b.inner.as_ref() {
                OpKind::Conv2D(_) => Kernel::BandConv2D,
                OpKind::DepthwiseConv2D(_) => Kernel::BandDwConv2D,
                OpKind::Pool(_) => Kernel::BandPool,
                // elementwise bands are plain offset copies
                _ => Kernel::Unary,
            },
            OpKind::ConcatRows => Kernel::Unary,
        }
    }

    /// Does this kernel call the shared `dmo_act` helper?
    pub(crate) fn uses_act(self) -> bool {
        matches!(
            self,
            Kernel::Conv2D | Kernel::DwConv2D | Kernel::Fc | Kernel::BandConv2D | Kernel::BandDwConv2D
        )
    }

    /// Emitted function name — what the emitter greps call sites for
    /// to decide whether this kernel body is actually referenced.
    pub(crate) fn fn_name(self) -> &'static str {
        match self {
            Kernel::Conv2D => "dmo_conv2d",
            Kernel::DwConv2D => "dmo_dwconv2d",
            Kernel::Pool => "dmo_pool",
            Kernel::GlobalAvgPool => "dmo_gavgpool",
            Kernel::Unary => "dmo_unary",
            Kernel::Binary => "dmo_binary",
            Kernel::Fc => "dmo_fc",
            Kernel::MatMul => "dmo_matmul",
            Kernel::Concat => "dmo_concat",
            Kernel::Pad => "dmo_pad",
            Kernel::Softmax => "dmo_softmax",
            Kernel::BandConv2D => "dmo_band_conv2d",
            Kernel::BandDwConv2D => "dmo_band_dwconv2d",
            Kernel::BandPool => "dmo_band_pool",
        }
    }

    /// C source of the kernel function.
    pub(crate) fn source(self) -> &'static str {
        match self {
            Kernel::Conv2D => CONV2D,
            Kernel::DwConv2D => DWCONV2D,
            Kernel::Pool => POOL,
            Kernel::GlobalAvgPool => GAVGPOOL,
            Kernel::Unary => UNARY,
            Kernel::Binary => BINARY,
            Kernel::Fc => FC,
            Kernel::MatMul => MATMUL,
            Kernel::Concat => CONCAT,
            Kernel::Pad => PAD,
            Kernel::Softmax => SOFTMAX,
            Kernel::BandConv2D => BAND_CONV2D,
            Kernel::BandDwConv2D => BAND_DWCONV2D,
            Kernel::BandPool => BAND_POOL,
        }
    }
}

/// The kernels needed by `graph`, in first-use order, deduplicated.
pub(crate) fn kernels_used(graph: &Graph) -> Vec<Kernel> {
    let mut used = Vec::new();
    for op in &graph.ops {
        let k = Kernel::for_op(&op.kind);
        if !used.contains(&k) {
            used.push(k);
        }
    }
    used
}

/// Unary-kernel selector constants (`kind` parameter of `dmo_unary`).
pub(crate) fn unary_kind_id(u: UnaryKind) -> usize {
    match u {
        UnaryKind::Relu => 0,
        UnaryKind::Relu6 => 1,
        UnaryKind::Copy => 2,
    }
}

/// Pool-kernel selector constants (`kind` parameter of `dmo_pool`).
pub(crate) fn pool_kind_id(k: PoolKind) -> usize {
    match k {
        PoolKind::Max => 0,
        PoolKind::Avg => 1,
    }
}

/// Fused-activation selector (`a` parameter of `dmo_act`).
pub(crate) fn act_id(a: crate::ir::op::Activation) -> usize {
    match a {
        crate::ir::op::Activation::None => 0,
        crate::ir::op::Activation::Relu => 1,
        crate::ir::op::Activation::Relu6 => 2,
    }
}

/// Shared fused-activation helper (relu / relu6), `-0.0`-preserving like
/// the interpreter's `act`.
pub(crate) const ACT_HELPER: &str = "\
static float dmo_act(float v, int a) {
    if (a >= 1 && v < 0.0f) {
        v = 0.0f;
    }
    if (a == 2 && v > 6.0f) {
        v = 6.0f;
    }
    return v;
}
";

const CONV2D: &str = "\
static void dmo_conv2d(size_t ib, size_t ob, int ih, int iw, int id, int oh, int ow, int od,
                       int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw, int a,
                       const dmo_wt *w, const dmo_bt *bias) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int oc = 0; oc < od; oc++) {
                float total = (float)bias[oc];
                for (int ky = 0; ky < kh; ky++) {
                    int iy = y0 + ky * dh;
                    if (iy < 0 || iy >= ih) {
                        continue;
                    }
                    for (int kx = 0; kx < kw; kx++) {
                        int ix = x0 + kx * dw;
                        if (ix < 0 || ix >= iw) {
                            continue;
                        }
                        for (int ic = 0; ic < id; ic++) {
                            float v = dmo_load(ib + (size_t)((iy * iw + ix) * id + ic) * DMO_ELEM_BYTES);
                            total += v * (float)w[((ky * kw + kx) * id + ic) * od + oc];
                        }
                    }
                }
                dmo_store(ob + (size_t)((oy * ow + ox) * od + oc) * DMO_ELEM_BYTES, dmo_act(total, a));
            }
        }
    }
}
";

const DWCONV2D: &str = "\
static void dmo_dwconv2d(size_t ib, size_t ob, int ih, int iw, int id, int oh, int ow, int od,
                         int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw,
                         int mult, int bias_n, int a, const dmo_wt *w, const dmo_bt *bias) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int ic = 0; ic < id; ic++) {
                for (int m = 0; m < mult; m++) {
                    int oc = ic * mult + m;
                    float total = (float)bias[oc < bias_n ? oc : bias_n - 1];
                    for (int ky = 0; ky < kh; ky++) {
                        int iy = y0 + ky * dh;
                        if (iy < 0 || iy >= ih) {
                            continue;
                        }
                        for (int kx = 0; kx < kw; kx++) {
                            int ix = x0 + kx * dw;
                            if (ix < 0 || ix >= iw) {
                                continue;
                            }
                            float v = dmo_load(ib + (size_t)((iy * iw + ix) * id + ic) * DMO_ELEM_BYTES);
                            total += v * (float)w[((ky * kw + kx) * id + ic) * mult + m];
                        }
                    }
                    dmo_store(ob + (size_t)((oy * ow + ox) * od + oc) * DMO_ELEM_BYTES, dmo_act(total, a));
                }
            }
        }
    }
}
";

const POOL: &str = "\
static void dmo_pool(size_t ib, size_t ob, int ih, int iw, int id, int oh, int ow, int od,
                     int kh, int kw, int sh, int sw, int ph, int pw, int kind) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int c = 0; c < od; c++) {
                float acc = kind == 0 ? -INFINITY : 0.0f;
                int n = 0;
                for (int ky = 0; ky < kh; ky++) {
                    int iy = y0 + ky;
                    if (iy < 0 || iy >= ih) {
                        continue;
                    }
                    for (int kx = 0; kx < kw; kx++) {
                        int ix = x0 + kx;
                        if (ix < 0 || ix >= iw) {
                            continue;
                        }
                        float v = dmo_load(ib + (size_t)((iy * iw + ix) * id + c) * DMO_ELEM_BYTES);
                        if (kind == 0) {
                            if (v > acc) {
                                acc = v;
                            }
                        } else {
                            acc += v;
                        }
                        n++;
                    }
                }
                float r = kind == 0 ? acc : acc / (float)(n > 0 ? n : 1);
                dmo_store(ob + (size_t)((oy * ow + ox) * od + c) * DMO_ELEM_BYTES, r);
            }
        }
    }
}
";

const GAVGPOOL: &str = "\
static void dmo_gavgpool(size_t ib, size_t ob, int ih, int iw, int id) {
    for (int c = 0; c < id; c++) {
        float acc = 0.0f;
        for (int p = 0; p < ih * iw; p++) {
            acc += dmo_load(ib + (size_t)(p * id + c) * DMO_ELEM_BYTES);
        }
        dmo_store(ob + (size_t)c * DMO_ELEM_BYTES, acc / (float)(ih * iw));
    }
}
";

const UNARY: &str = "\
static void dmo_unary(size_t ib, size_t ob, size_t n, int kind) {
    for (size_t i = 0; i < n; i++) {
        float v = dmo_load(ib + i * DMO_ELEM_BYTES);
        if (kind == 0 && v < 0.0f) {
            v = 0.0f;
        }
        if (kind == 1) {
            if (v < 0.0f) {
                v = 0.0f;
            }
            if (v > 6.0f) {
                v = 6.0f;
            }
        }
        dmo_store(ob + i * DMO_ELEM_BYTES, v);
    }
}
";

const BINARY: &str = "\
static void dmo_binary(size_t ab, size_t bb, size_t ob, size_t n, int kind) {
    for (size_t i = 0; i < n; i++) {
        float x = dmo_load(ab + i * DMO_ELEM_BYTES);
        float y = dmo_load(bb + i * DMO_ELEM_BYTES);
        dmo_store(ob + i * DMO_ELEM_BYTES, kind == 0 ? x + y : x * y);
    }
}
";

const FC: &str = "\
static void dmo_fc(size_t ib, size_t ob, int k_dim, int nf, int a,
                   const dmo_wt *w, const dmo_bt *bias) {
    for (int o = 0; o < nf; o++) {
        float total = (float)bias[o];
        for (int k = 0; k < k_dim; k++) {
            total += dmo_load(ib + (size_t)k * DMO_ELEM_BYTES) * (float)w[k * nf + o];
        }
        dmo_store(ob + (size_t)o * DMO_ELEM_BYTES, dmo_act(total, a));
    }
}
";

const MATMUL: &str = "\
static void dmo_matmul(size_t ib, size_t ob, int k_dim, int nf,
                       const dmo_wt *w, const dmo_bt *bias) {
    for (int o = 0; o < nf; o++) {
        dmo_store(ob + (size_t)o * DMO_ELEM_BYTES, (float)bias[o]);
    }
    for (int k = 0; k < k_dim; k++) {
        float v = dmo_load(ib + (size_t)k * DMO_ELEM_BYTES);
        for (int o = 0; o < nf; o++) {
            size_t off = ob + (size_t)o * DMO_ELEM_BYTES;
            dmo_store(off, dmo_load(off) + v * (float)w[k * nf + o]);
        }
    }
}
";

const CONCAT: &str = "\
static void dmo_concat(size_t ob, int hw, int od, int n, const size_t *ibs, const int *cs) {
    for (int p = 0; p < hw; p++) {
        int coff = 0;
        for (int j = 0; j < n; j++) {
            int cj = cs[j];
            for (int c = 0; c < cj; c++) {
                float v = dmo_load(ibs[j] + (size_t)(p * cj + c) * DMO_ELEM_BYTES);
                dmo_store(ob + (size_t)(p * od + coff + c) * DMO_ELEM_BYTES, v);
            }
            coff += cj;
        }
    }
}
";

const PAD: &str = "\
static void dmo_pad(size_t ib, size_t ob, int ih, int iw, int id, int oh, int ow, int od,
                    int top, int left) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int inside = oy >= top && oy < top + ih && ox >= left && ox < left + iw;
            for (int c = 0; c < od; c++) {
                float v = 0.0f;
                if (inside) {
                    v = dmo_load(ib + (size_t)(((oy - top) * iw + (ox - left)) * id + c) * DMO_ELEM_BYTES);
                }
                dmo_store(ob + (size_t)((oy * ow + ox) * od + c) * DMO_ELEM_BYTES, v);
            }
        }
    }
}
";

const SOFTMAX: &str = "\
static void dmo_softmax(size_t ib, size_t ob, int rows, int d) {
    for (int r = 0; r < rows; r++) {
        float m = -INFINITY;
        for (int c = 0; c < d; c++) {
            float x = dmo_load(ib + (size_t)(r * d + c) * DMO_ELEM_BYTES);
            if (x > m) {
                m = x;
            }
        }
        float sum = 0.0f;
        for (int c = 0; c < d; c++) {
            sum += expf(dmo_load(ib + (size_t)(r * d + c) * DMO_ELEM_BYTES) - m);
        }
        for (int c = 0; c < d; c++) {
            float v = expf(dmo_load(ib + (size_t)(r * d + c) * DMO_ELEM_BYTES) - m) / sum;
            dmo_store(ob + (size_t)(r * d + c) * DMO_ELEM_BYTES, v);
        }
    }
}
";

const BAND_CONV2D: &str = "\
static void dmo_band_conv2d(size_t ib, size_t ob, int fih, int iw, int id, int ir0,
                            int oy0, int orows, int ow, int od,
                            int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw, int a,
                            const dmo_wt *w, const dmo_bt *bias) {
    for (int oyl = 0; oyl < orows; oyl++) {
        int oy = oy0 + oyl;
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int oc = 0; oc < od; oc++) {
                float total = (float)bias[oc];
                for (int ky = 0; ky < kh; ky++) {
                    int iy = y0 + ky * dh;
                    if (iy < 0 || iy >= fih) {
                        continue;
                    }
                    for (int kx = 0; kx < kw; kx++) {
                        int ix = x0 + kx * dw;
                        if (ix < 0 || ix >= iw) {
                            continue;
                        }
                        for (int ic = 0; ic < id; ic++) {
                            float v = dmo_load(ib + (size_t)(((iy - ir0) * iw + ix) * id + ic) * DMO_ELEM_BYTES);
                            total += v * (float)w[((ky * kw + kx) * id + ic) * od + oc];
                        }
                    }
                }
                dmo_store(ob + (size_t)((oyl * ow + ox) * od + oc) * DMO_ELEM_BYTES, dmo_act(total, a));
            }
        }
    }
}
";

const BAND_DWCONV2D: &str = "\
static void dmo_band_dwconv2d(size_t ib, size_t ob, int fih, int iw, int id, int ir0,
                              int oy0, int orows, int ow, int od,
                              int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw,
                              int mult, int bias_n, int a, const dmo_wt *w, const dmo_bt *bias) {
    for (int oyl = 0; oyl < orows; oyl++) {
        int oy = oy0 + oyl;
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int ic = 0; ic < id; ic++) {
                for (int m = 0; m < mult; m++) {
                    int oc = ic * mult + m;
                    float total = (float)bias[oc < bias_n ? oc : bias_n - 1];
                    for (int ky = 0; ky < kh; ky++) {
                        int iy = y0 + ky * dh;
                        if (iy < 0 || iy >= fih) {
                            continue;
                        }
                        for (int kx = 0; kx < kw; kx++) {
                            int ix = x0 + kx * dw;
                            if (ix < 0 || ix >= iw) {
                                continue;
                            }
                            float v = dmo_load(ib + (size_t)(((iy - ir0) * iw + ix) * id + ic) * DMO_ELEM_BYTES);
                            total += v * (float)w[((ky * kw + kx) * id + ic) * mult + m];
                        }
                    }
                    dmo_store(ob + (size_t)((oyl * ow + ox) * od + oc) * DMO_ELEM_BYTES, dmo_act(total, a));
                }
            }
        }
    }
}
";

const BAND_POOL: &str = "\
static void dmo_band_pool(size_t ib, size_t ob, int fih, int iw, int id, int ir0,
                          int oy0, int orows, int ow, int od,
                          int kh, int kw, int sh, int sw, int ph, int pw, int kind) {
    for (int oyl = 0; oyl < orows; oyl++) {
        int oy = oy0 + oyl;
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int c = 0; c < od; c++) {
                float acc = kind == 0 ? -INFINITY : 0.0f;
                int n = 0;
                for (int ky = 0; ky < kh; ky++) {
                    int iy = y0 + ky;
                    if (iy < 0 || iy >= fih) {
                        continue;
                    }
                    for (int kx = 0; kx < kw; kx++) {
                        int ix = x0 + kx;
                        if (ix < 0 || ix >= iw) {
                            continue;
                        }
                        float v = dmo_load(ib + (size_t)(((iy - ir0) * iw + ix) * id + c) * DMO_ELEM_BYTES);
                        if (kind == 0) {
                            if (v > acc) {
                                acc = v;
                            }
                        } else {
                            acc += v;
                        }
                        n++;
                    }
                }
                float r = kind == 0 ? acc : acc / (float)(n > 0 ? n : 1);
                dmo_store(ob + (size_t)((oyl * ow + ox) * od + c) * DMO_ELEM_BYTES, r);
            }
        }
    }
}
";

/// Arena element accessors, specialised per activation dtype. The `i8`
/// store replicates the interpreter's quantisation exactly: libm
/// `roundf` (round half away from zero, what Rust's `f32::round` is),
/// then saturate to `[-128, 127]`.
pub(crate) fn load_store_source(dtype: crate::ir::DType) -> &'static str {
    match dtype {
        crate::ir::DType::F32 | crate::ir::DType::I32 => LOAD_STORE_F32,
        crate::ir::DType::I8 => LOAD_STORE_I8,
    }
}

const LOAD_STORE_F32: &str = "\
static float dmo_load(size_t off) {
    float v;
    memcpy(&v, dmo_arena + off, sizeof v);
    return v;
}

static void dmo_store(size_t off, float v) {
    memcpy(dmo_arena + off, &v, sizeof v);
}
";

const LOAD_STORE_I8: &str = "\
static float dmo_load(size_t off) {
    return (float)(int8_t)dmo_arena[off];
}

static void dmo_store(size_t off, float v) {
    float r = roundf(v);
    if (r < -128.0f) {
        r = -128.0f;
    }
    if (r > 127.0f) {
        r = 127.0f;
    }
    dmo_arena[off] = (uint8_t)(int8_t)r;
}
";

/// SplitMix64 weight generator (emitted only when the model's weights
/// are too large to embed as initialisers): the same stream
/// [`crate::ops::exec::gen_weights`] draws from, so generated and
/// embedded weights are interchangeable bit for bit.
pub(crate) const SPLITMIX: &str = "\
static uint64_t dmo_sm_next(uint64_t *s) {
    *s += 0x9E3779B97F4A7C15ULL;
    uint64_t z = *s;
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
    return z ^ (z >> 31);
}

static void dmo_fill_wt(dmo_wt *dst, size_t n, uint64_t *s) {
    for (size_t i = 0; i < n; i++) {
        dst[i] = (dmo_wt)((int)(dmo_sm_next(s) % 5u) - 2);
    }
}

static void dmo_fill_bt(dmo_bt *dst, size_t n, uint64_t *s) {
    for (size_t i = 0; i < n; i++) {
        dst[i] = (dmo_bt)((int)(dmo_sm_next(s) % 5u) - 2);
    }
}
";

/// CMSIS-NN-style requantisation: widen to 64 bit, multiply by the
/// precomputed fixed-point multiplier, rounding-right-shift, saturate
/// to the int8 range. The synthetic weight scheme is unit-scale
/// (multiplier 1, shift 0), where this reduces to pure saturation —
/// exactly what the reference `roundf`+clamp store does to an integer
/// accumulator.
pub(crate) const REQUANT_HELPER: &str = "\
static int8_t dmo_requant(int32_t acc, int32_t mult, int shift) {
    int64_t v = (int64_t)acc * mult;
    if (shift > 0) {
        v = (v + ((int64_t)1 << (shift - 1))) >> shift;
    }
    if (v < -128) {
        v = -128;
    }
    if (v > 127) {
        v = 127;
    }
    return (int8_t)v;
}
";

/// Function name of the fast variant for `class` at `dtype`, or `None`
/// when the generator does not support the combination (the emitter
/// then downgrades the call site to the generic kernel).
pub(crate) fn fast_fn_name(class: &str, dtype: DType, variant: Variant) -> Option<String> {
    let (order, unroll) = match variant {
        Variant::Generic => return None,
        Variant::Fast { order, unroll } => (order, unroll),
    };
    // ×4 unroll only where there is a long innermost accumulation loop;
    // channel-outer only for f32 conv2d (i8 keeps reference order so
    // requantised stores stay in-place safe)
    if unroll == 4 && !matches!(class, "conv2d" | "fc") {
        return None;
    }
    if order == LoopOrder::ChannelOuter && !(class == "conv2d" && dtype == DType::F32) {
        return None;
    }
    let suffix = match (dtype, order, unroll) {
        (DType::F32, LoopOrder::Reference, 1) => "_f",
        (DType::F32, LoopOrder::Reference, 4) => "_f_u4",
        (DType::F32, LoopOrder::ChannelOuter, 1) => "_f_co",
        (DType::F32, LoopOrder::ChannelOuter, 4) => "_f_co_u4",
        (DType::I8, LoopOrder::Reference, 1) => "_q",
        (DType::I8, LoopOrder::Reference, 4) => "_q_u4",
        _ => return None,
    };
    if !matches!(class, "conv2d" | "dwconv2d" | "pool" | "unary" | "binary" | "fc") {
        return None;
    }
    Some(format!("dmo_{class}{suffix}"))
}

/// C source of the fast variant for `class` at `dtype`, or `None` when
/// unsupported (see [`fast_fn_name`]).
pub(crate) fn fast_source(class: &str, dtype: DType, variant: Variant) -> Option<String> {
    let name = fast_fn_name(class, dtype, variant)?;
    let (order, unroll) = match variant {
        Variant::Fast { order, unroll } => (order, unroll),
        Variant::Generic => return None,
    };
    Some(match (class, dtype) {
        ("conv2d", DType::I8) => conv2d_q(&name, unroll),
        ("conv2d", _) => conv2d_f(&name, order, unroll),
        ("fc", DType::I8) => fc_q(&name, unroll),
        ("fc", _) => fc_f(&name, unroll),
        ("dwconv2d", DType::I8) => DWCONV2D_Q.to_string(),
        ("dwconv2d", _) => DWCONV2D_F.to_string(),
        ("pool", DType::I8) => POOL_Q.to_string(),
        ("pool", _) => POOL_F.to_string(),
        ("unary", DType::I8) => UNARY_Q.to_string(),
        ("unary", _) => UNARY_F.to_string(),
        ("binary", DType::I8) => BINARY_Q.to_string(),
        ("binary", _) => BINARY_F.to_string(),
        _ => return None,
    })
}

fn conv2d_f(name: &str, order: LoopOrder, unroll: u8) -> String {
    // the reference order is the store order the O_s analysis derives
    // overlap distances for — safe fully in place; channel-outer is
    // emitted only for call sites the plan proves disjoint
    let outer = match order {
        LoopOrder::Reference => "\
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            for (int oc = 0; oc < od; oc++) {",
        LoopOrder::ChannelOuter => "\
    for (int oc = 0; oc < od; oc++) {
        for (int oy = 0; oy < oh; oy++) {
            for (int ox = 0; ox < ow; ox++) {",
    };
    // unrolled adds stay in sequence into the one accumulator, so the
    // f32 accumulation order — and therefore every bit — is unchanged
    let acc = if unroll == 4 {
        "\
                        int ic = 0;
                        for (; ic + 4 <= id; ic += 4) {
                            total += ip[ic] * (float)wp[ic * od];
                            total += ip[ic + 1] * (float)wp[(ic + 1) * od];
                            total += ip[ic + 2] * (float)wp[(ic + 2) * od];
                            total += ip[ic + 3] * (float)wp[(ic + 3) * od];
                        }
                        for (; ic < id; ic++) {
                            total += ip[ic] * (float)wp[ic * od];
                        }"
    } else {
        "\
                        for (int ic = 0; ic < id; ic++) {
                            total += ip[ic] * (float)wp[ic * od];
                        }"
    };
    format!(
        "static void {name}(const float *in, float *out, int ih, int iw, int id, int oh, int ow, int od,
                       int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw, int a,
                       const dmo_wt *w, const dmo_bt *bias) {{
{outer}
                int y0 = oy * sh - ph;
                int x0 = ox * sw - pw;
                float total = (float)bias[oc];
                for (int ky = 0; ky < kh; ky++) {{
                    int iy = y0 + ky * dh;
                    if (iy < 0 || iy >= ih) {{
                        continue;
                    }}
                    for (int kx = 0; kx < kw; kx++) {{
                        int ix = x0 + kx * dw;
                        if (ix < 0 || ix >= iw) {{
                            continue;
                        }}
                        const float *ip = in + (iy * iw + ix) * id;
                        const dmo_wt *wp = w + ((ky * kw + kx) * id) * od + oc;
{acc}
                    }}
                }}
                out[(oy * ow + ox) * od + oc] = dmo_act(total, a);
            }}
        }}
    }}
}}
"
    )
}

fn conv2d_q(name: &str, unroll: u8) -> String {
    let acc = if unroll == 4 {
        "\
                        int ic = 0;
                        for (; ic + 4 <= id; ic += 4) {
                            acc += (int32_t)ip[ic] * wp[ic * od];
                            acc += (int32_t)ip[ic + 1] * wp[(ic + 1) * od];
                            acc += (int32_t)ip[ic + 2] * wp[(ic + 2) * od];
                            acc += (int32_t)ip[ic + 3] * wp[(ic + 3) * od];
                        }
                        for (; ic < id; ic++) {
                            acc += (int32_t)ip[ic] * wp[ic * od];
                        }"
    } else {
        "\
                        for (int ic = 0; ic < id; ic++) {
                            acc += (int32_t)ip[ic] * wp[ic * od];
                        }"
    };
    format!(
        "static void {name}(const int8_t *in, int8_t *out, int ih, int iw, int id, int oh, int ow, int od,
                       int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw, int a,
                       int32_t rm, int rs, const dmo_wt *w, const dmo_bt *bias) {{
    for (int oy = 0; oy < oh; oy++) {{
        for (int ox = 0; ox < ow; ox++) {{
            for (int oc = 0; oc < od; oc++) {{
                int y0 = oy * sh - ph;
                int x0 = ox * sw - pw;
                int32_t acc = bias[oc];
                for (int ky = 0; ky < kh; ky++) {{
                    int iy = y0 + ky * dh;
                    if (iy < 0 || iy >= ih) {{
                        continue;
                    }}
                    for (int kx = 0; kx < kw; kx++) {{
                        int ix = x0 + kx * dw;
                        if (ix < 0 || ix >= iw) {{
                            continue;
                        }}
                        const int8_t *ip = in + (iy * iw + ix) * id;
                        const dmo_wt *wp = w + ((ky * kw + kx) * id) * od + oc;
{acc}
                    }}
                }}
                if (a >= 1 && acc < 0) {{
                    acc = 0;
                }}
                if (a == 2 && acc > 6) {{
                    acc = 6;
                }}
                out[(oy * ow + ox) * od + oc] = dmo_requant(acc, rm, rs);
            }}
        }}
    }}
}}
"
    )
}

fn fc_f(name: &str, unroll: u8) -> String {
    let acc = if unroll == 4 {
        "\
        int k = 0;
        for (; k + 4 <= k_dim; k += 4) {
            total += in[k] * (float)w[k * nf + o];
            total += in[k + 1] * (float)w[(k + 1) * nf + o];
            total += in[k + 2] * (float)w[(k + 2) * nf + o];
            total += in[k + 3] * (float)w[(k + 3) * nf + o];
        }
        for (; k < k_dim; k++) {
            total += in[k] * (float)w[k * nf + o];
        }"
    } else {
        "\
        for (int k = 0; k < k_dim; k++) {
            total += in[k] * (float)w[k * nf + o];
        }"
    };
    format!(
        "static void {name}(const float *in, float *out, int k_dim, int nf, int a,
                   const dmo_wt *w, const dmo_bt *bias) {{
    for (int o = 0; o < nf; o++) {{
        float total = (float)bias[o];
{acc}
        out[o] = dmo_act(total, a);
    }}
}}
"
    )
}

fn fc_q(name: &str, unroll: u8) -> String {
    let acc = if unroll == 4 {
        "\
        int k = 0;
        for (; k + 4 <= k_dim; k += 4) {
            acc += (int32_t)in[k] * w[k * nf + o];
            acc += (int32_t)in[k + 1] * w[(k + 1) * nf + o];
            acc += (int32_t)in[k + 2] * w[(k + 2) * nf + o];
            acc += (int32_t)in[k + 3] * w[(k + 3) * nf + o];
        }
        for (; k < k_dim; k++) {
            acc += (int32_t)in[k] * w[k * nf + o];
        }"
    } else {
        "\
        for (int k = 0; k < k_dim; k++) {
            acc += (int32_t)in[k] * w[k * nf + o];
        }"
    };
    format!(
        "static void {name}(const int8_t *in, int8_t *out, int k_dim, int nf, int a,
                   int32_t rm, int rs, const dmo_wt *w, const dmo_bt *bias) {{
    for (int o = 0; o < nf; o++) {{
        int32_t acc = bias[o];
{acc}
        if (a >= 1 && acc < 0) {{
            acc = 0;
        }}
        if (a == 2 && acc > 6) {{
            acc = 6;
        }}
        out[o] = dmo_requant(acc, rm, rs);
    }}
}}
"
    )
}

const DWCONV2D_F: &str = "\
static void dmo_dwconv2d_f(const float *in, float *out, int ih, int iw, int id, int oh, int ow, int od,
                           int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw,
                           int mult, int bias_n, int a, const dmo_wt *w, const dmo_bt *bias) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int ic = 0; ic < id; ic++) {
                for (int m = 0; m < mult; m++) {
                    int oc = ic * mult + m;
                    float total = (float)bias[oc < bias_n ? oc : bias_n - 1];
                    for (int ky = 0; ky < kh; ky++) {
                        int iy = y0 + ky * dh;
                        if (iy < 0 || iy >= ih) {
                            continue;
                        }
                        for (int kx = 0; kx < kw; kx++) {
                            int ix = x0 + kx * dw;
                            if (ix < 0 || ix >= iw) {
                                continue;
                            }
                            total += in[(iy * iw + ix) * id + ic] * (float)w[((ky * kw + kx) * id + ic) * mult + m];
                        }
                    }
                    out[(oy * ow + ox) * od + oc] = dmo_act(total, a);
                }
            }
        }
    }
}
";

const DWCONV2D_Q: &str = "\
static void dmo_dwconv2d_q(const int8_t *in, int8_t *out, int ih, int iw, int id, int oh, int ow, int od,
                           int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw,
                           int mult, int bias_n, int a, int32_t rm, int rs,
                           const dmo_wt *w, const dmo_bt *bias) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int ic = 0; ic < id; ic++) {
                for (int m = 0; m < mult; m++) {
                    int oc = ic * mult + m;
                    int32_t acc = bias[oc < bias_n ? oc : bias_n - 1];
                    for (int ky = 0; ky < kh; ky++) {
                        int iy = y0 + ky * dh;
                        if (iy < 0 || iy >= ih) {
                            continue;
                        }
                        for (int kx = 0; kx < kw; kx++) {
                            int ix = x0 + kx * dw;
                            if (ix < 0 || ix >= iw) {
                                continue;
                            }
                            acc += (int32_t)in[(iy * iw + ix) * id + ic] * w[((ky * kw + kx) * id + ic) * mult + m];
                        }
                    }
                    if (a >= 1 && acc < 0) {
                        acc = 0;
                    }
                    if (a == 2 && acc > 6) {
                        acc = 6;
                    }
                    out[(oy * ow + ox) * od + oc] = dmo_requant(acc, rm, rs);
                }
            }
        }
    }
}
";

const POOL_F: &str = "\
static void dmo_pool_f(const float *in, float *out, int ih, int iw, int id, int oh, int ow, int od,
                       int kh, int kw, int sh, int sw, int ph, int pw, int kind) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int c = 0; c < od; c++) {
                float acc = kind == 0 ? -INFINITY : 0.0f;
                int n = 0;
                for (int ky = 0; ky < kh; ky++) {
                    int iy = y0 + ky;
                    if (iy < 0 || iy >= ih) {
                        continue;
                    }
                    for (int kx = 0; kx < kw; kx++) {
                        int ix = x0 + kx;
                        if (ix < 0 || ix >= iw) {
                            continue;
                        }
                        float v = in[(iy * iw + ix) * id + c];
                        if (kind == 0) {
                            if (v > acc) {
                                acc = v;
                            }
                        } else {
                            acc += v;
                        }
                        n++;
                    }
                }
                out[(oy * ow + ox) * od + c] = kind == 0 ? acc : acc / (float)(n > 0 ? n : 1);
            }
        }
    }
}
";

/* int8 pooling: max needs no arithmetic at all (values already int8;
 * an empty all-padding window yields -128, exactly what the reference's
 * -INFINITY -> roundf -> clamp produces); avg reproduces the reference
 * float division bit for bit because the integer sum is exact in f32
 * below 2^24 (guarded at emit time). */
const POOL_Q: &str = "\
static void dmo_pool_q(const int8_t *in, int8_t *out, int ih, int iw, int id, int oh, int ow, int od,
                       int kh, int kw, int sh, int sw, int ph, int pw, int kind) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int c = 0; c < od; c++) {
                int32_t best = -128;
                int32_t sum = 0;
                int n = 0;
                for (int ky = 0; ky < kh; ky++) {
                    int iy = y0 + ky;
                    if (iy < 0 || iy >= ih) {
                        continue;
                    }
                    for (int kx = 0; kx < kw; kx++) {
                        int ix = x0 + kx;
                        if (ix < 0 || ix >= iw) {
                            continue;
                        }
                        int32_t v = in[(iy * iw + ix) * id + c];
                        if (v > best) {
                            best = v;
                        }
                        sum += v;
                        n++;
                    }
                }
                int32_t r = best;
                if (kind != 0) {
                    r = (int32_t)roundf((float)sum / (float)(n > 0 ? n : 1));
                    if (r < -128) {
                        r = -128;
                    }
                    if (r > 127) {
                        r = 127;
                    }
                }
                out[(oy * ow + ox) * od + c] = (int8_t)r;
            }
        }
    }
}
";

const UNARY_F: &str = "\
static void dmo_unary_f(const float *in, float *out, size_t n, int kind) {
    for (size_t i = 0; i < n; i++) {
        float v = in[i];
        if (kind == 0 && v < 0.0f) {
            v = 0.0f;
        }
        if (kind == 1) {
            if (v < 0.0f) {
                v = 0.0f;
            }
            if (v > 6.0f) {
                v = 6.0f;
            }
        }
        out[i] = v;
    }
}
";

const UNARY_Q: &str = "\
static void dmo_unary_q(const int8_t *in, int8_t *out, size_t n, int kind) {
    for (size_t i = 0; i < n; i++) {
        int32_t v = in[i];
        if (kind == 0 && v < 0) {
            v = 0;
        }
        if (kind == 1) {
            if (v < 0) {
                v = 0;
            }
            if (v > 6) {
                v = 6;
            }
        }
        out[i] = (int8_t)v;
    }
}
";

const BINARY_F: &str = "\
static void dmo_binary_f(const float *a, const float *b, float *out, size_t n, int kind) {
    for (size_t i = 0; i < n; i++) {
        out[i] = kind == 0 ? a[i] + b[i] : a[i] * b[i];
    }
}
";

/* int8 add/mul: |a op b| <= 127*127 — exact in f32, so saturating in
 * the integer domain matches the reference roundf+clamp store. */
const BINARY_Q: &str = "\
static void dmo_binary_q(const int8_t *a, const int8_t *b, int8_t *out, size_t n, int kind) {
    for (size_t i = 0; i < n; i++) {
        int32_t v = kind == 0 ? (int32_t)a[i] + b[i] : (int32_t)a[i] * b[i];
        if (v < -128) {
            v = -128;
        }
        if (v > 127) {
            v = 127;
        }
        out[i] = (int8_t)v;
    }
}
";

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn tiny_uses_expected_kernels() {
        let g = models::build("tiny").unwrap();
        let used = kernels_used(&g);
        assert_eq!(
            used,
            vec![
                Kernel::Conv2D,
                Kernel::DwConv2D,
                Kernel::GlobalAvgPool,
                Kernel::Unary,
                Kernel::Fc,
                Kernel::Softmax,
            ]
        );
        assert!(used.iter().any(|k| k.uses_act()));
    }

    #[test]
    fn kernel_sources_reference_only_emitted_names() {
        // every kernel body must be self-contained modulo the shared
        // helpers the emitter always provides alongside it
        for k in [
            Kernel::Conv2D,
            Kernel::DwConv2D,
            Kernel::Pool,
            Kernel::GlobalAvgPool,
            Kernel::Unary,
            Kernel::Binary,
            Kernel::Fc,
            Kernel::MatMul,
            Kernel::Concat,
            Kernel::Pad,
            Kernel::Softmax,
            Kernel::BandConv2D,
            Kernel::BandDwConv2D,
            Kernel::BandPool,
        ] {
            let src = k.source();
            assert!(src.starts_with("static void dmo_"), "{src}");
            assert!(src.contains("dmo_store("), "every kernel writes: {src}");
            assert_eq!(k.uses_act(), src.contains("dmo_act("), "{src}");
        }
    }

    #[test]
    fn fast_sources_cover_the_variant_space() {
        use super::super::tune::variants_for;
        for class in ["conv2d", "dwconv2d", "pool", "unary", "binary", "fc"] {
            for dt in [DType::F32, DType::I8] {
                for v in variants_for(class, dt) {
                    if v == Variant::Generic {
                        assert_eq!(fast_source(class, dt, v), None);
                        continue;
                    }
                    let name = fast_fn_name(class, dt, v)
                        .unwrap_or_else(|| panic!("{class}/{dt}/{}", v.name()));
                    let src = fast_source(class, dt, v).unwrap();
                    assert!(
                        src.starts_with(&format!("static void {name}(")),
                        "{class}/{dt}: {src}"
                    );
                    // typed-pointer loops never go through the byte
                    // accessors — that indirection is what they remove
                    assert!(!src.contains("dmo_load("), "{src}");
                    assert!(!src.contains("dmo_store("), "{src}");
                    // in-place overlap safety forbids restrict
                    assert!(!src.contains("restrict"), "{src}");
                    if dt == DType::I8 && matches!(class, "conv2d" | "dwconv2d" | "fc") {
                        assert!(src.contains("dmo_requant("), "{src}");
                        assert!(src.contains("int32_t acc"), "{src}");
                    }
                }
            }
        }
    }

    #[test]
    fn unsupported_fast_combinations_downgrade() {
        let co = Variant::Fast { order: LoopOrder::ChannelOuter, unroll: 1 };
        // channel-outer reorders stores: f32 conv2d only
        assert!(fast_fn_name("conv2d", DType::F32, co).is_some());
        assert_eq!(fast_fn_name("conv2d", DType::I8, co), None);
        assert_eq!(fast_fn_name("pool", DType::F32, co), None);
        let u4 = Variant::Fast { order: LoopOrder::Reference, unroll: 4 };
        assert_eq!(fast_fn_name("unary", DType::F32, u4), None);
        assert!(fast_fn_name("fc", DType::I8, u4).is_some());
        // no fast path at all for i32 activations or untunable classes
        assert_eq!(
            fast_fn_name("conv2d", DType::I32, Variant::Fast { order: LoopOrder::Reference, unroll: 1 }),
            None
        );
        assert_eq!(
            fast_fn_name("softmax", DType::F32, Variant::Fast { order: LoopOrder::Reference, unroll: 1 }),
            None
        );
        assert_eq!(fast_fn_name("conv2d", DType::F32, Variant::Generic), None);
    }

    #[test]
    fn unrolled_variants_keep_a_remainder_loop() {
        for (class, dt) in [
            ("conv2d", DType::F32),
            ("conv2d", DType::I8),
            ("fc", DType::F32),
            ("fc", DType::I8),
        ] {
            let src = fast_source(
                class,
                dt,
                Variant::Fast { order: LoopOrder::Reference, unroll: 4 },
            )
            .unwrap();
            assert!(src.contains("+ 4 <="), "{class}/{dt}: {src}");
            assert!(src.contains("+ 3]"), "{class}/{dt}: {src}");
        }
    }
}
