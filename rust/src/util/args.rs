//! Tiny declarative command-line parsing for the `dmo` binary.
//!
//! Each subcommand declares the flags it accepts as a slice of
//! [`ArgSpec`]s; [`Args::parse`] then accepts both `--key value` and
//! `--key=value` spellings, collects bare words as positional
//! arguments, and rejects unknown flags with a message listing what the
//! command does accept (the previous hand-rolled scanner silently
//! ignored typos like `--basline`).

use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Declaration of one accepted `--flag`.
#[derive(Debug, Clone, Copy)]
pub struct ArgSpec {
    /// Flag name including the leading dashes, e.g. `"--export"`.
    pub name: &'static str,
    /// Whether the flag consumes a value (`--key value` / `--key=value`).
    pub takes_value: bool,
    /// Short help fragment shown in error messages.
    pub help: &'static str,
}

/// Declare a boolean flag.
pub const fn flag(name: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec {
        name,
        takes_value: false,
        help,
    }
}

/// Declare a value-taking option.
pub const fn opt(name: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec {
        name,
        takes_value: true,
        help,
    }
}

/// Parsed command-line arguments for one subcommand.
#[derive(Debug, Clone, Default)]
pub struct Args {
    positional: Vec<String>,
    values: BTreeMap<&'static str, String>,
    flags: BTreeSet<&'static str>,
}

impl Args {
    /// Parse `raw` against the accepted `known` flags.
    pub fn parse(raw: &[String], known: &[ArgSpec]) -> Result<Args> {
        let mut args = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (format!("--{n}"), Some(v.to_string())),
                    None => (tok.clone(), None),
                };
                let spec = known
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| anyhow!("unknown flag `{name}`\n{}", usage(known)))?;
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow!("flag `{}` expects a value", spec.name))?
                        }
                    };
                    args.values.insert(spec.name, value);
                } else {
                    if inline.is_some() {
                        bail!("flag `{}` does not take a value", spec.name);
                    }
                    args.flags.insert(spec.name);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Was the boolean `--flag` given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains(name)
    }

    /// Value of `--name`, if given.
    pub fn value(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Value of `--name` parsed as `T`, or `default` when absent.
    pub fn parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.value(name) {
            None => Ok(default),
            Some(text) => text
                .parse()
                .map_err(|_| anyhow!("flag `{name}`: cannot parse `{text}`")),
        }
    }

    /// All positional (non-flag) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Positional argument `i`, if present.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }
}

/// One-line-per-flag usage fragment for error messages.
fn usage(known: &[ArgSpec]) -> String {
    if known.is_empty() {
        return "this command takes no flags".to_string();
    }
    let mut s = String::from("accepted flags:");
    for spec in known {
        s.push_str(&format!(
            "\n  {}{}  {}",
            spec.name,
            if spec.takes_value { " <value>" } else { "" },
            spec.help
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    const SPEC: &[ArgSpec] = &[
        flag("--baseline", "plan without DMO"),
        opt("--export", "write the plan artifact"),
        opt("--rate", "arrival rate"),
    ];

    #[test]
    fn space_and_equals_spellings_agree() {
        let a = Args::parse(&raw(&["model", "--export", "p.json"]), SPEC).unwrap();
        let b = Args::parse(&raw(&["model", "--export=p.json"]), SPEC).unwrap();
        assert_eq!(a.value("--export"), Some("p.json"));
        assert_eq!(b.value("--export"), Some("p.json"));
        assert_eq!(a.pos(0), Some("model"));
        assert_eq!(b.pos(0), Some("model"));
    }

    #[test]
    fn unknown_flags_are_rejected_with_help() {
        let e = Args::parse(&raw(&["--basline"]), SPEC).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("--basline"), "{msg}");
        assert!(msg.contains("--baseline"), "help must list accepted flags: {msg}");
    }

    #[test]
    fn missing_value_and_spurious_value_fail() {
        assert!(Args::parse(&raw(&["--export"]), SPEC).is_err());
        assert!(Args::parse(&raw(&["--baseline=yes"]), SPEC).is_err());
    }

    #[test]
    fn typed_values_parse_with_default() {
        let a = Args::parse(&raw(&["--rate=250.5"]), SPEC).unwrap();
        assert_eq!(a.parsed("--rate", 1.0f64).unwrap(), 250.5);
        assert_eq!(a.parsed("--missing", 7usize).unwrap(), 7);
        let b = Args::parse(&raw(&["--rate", "abc"]), SPEC).unwrap();
        assert!(b.parsed("--rate", 1.0f64).is_err());
    }

    #[test]
    fn flags_and_positionals_mix() {
        let a = Args::parse(&raw(&["tiny", "--baseline", "extra"]), SPEC).unwrap();
        assert!(a.flag("--baseline"));
        assert_eq!(a.positional(), &["tiny".to_string(), "extra".to_string()]);
    }

    #[test]
    fn positional_indexing_is_order_preserving_and_bounded() {
        let a = Args::parse(&raw(&["a", "--export", "p.json", "b", "c"]), SPEC).unwrap();
        // the flag's value is consumed, not treated as a positional
        assert_eq!(a.positional(), &["a".to_string(), "b".to_string(), "c".to_string()]);
        assert_eq!(a.pos(0), Some("a"));
        assert_eq!(a.pos(2), Some("c"));
        assert_eq!(a.pos(3), None, "out-of-range positions are None, not a panic");
        let empty = Args::parse(&[], SPEC).unwrap();
        assert_eq!(empty.pos(0), None);
        assert!(empty.positional().is_empty());
    }

    #[test]
    fn single_dash_tokens_are_positional() {
        // only `--` introduces a flag; `-x` and bare `-` pass through as
        // positionals (some model names could plausibly start with `-`)
        let a = Args::parse(&raw(&["-x", "-", "--baseline"]), SPEC).unwrap();
        assert_eq!(a.positional(), &["-x".to_string(), "-".to_string()]);
        assert!(a.flag("--baseline"));
    }

    #[test]
    fn equals_spelling_with_empty_value_is_kept() {
        // `--export=` means "explicitly empty", distinct from absent —
        // the consumer decides whether an empty path is an error
        let a = Args::parse(&raw(&["--export="]), SPEC).unwrap();
        assert_eq!(a.value("--export"), Some(""));
        let b = Args::parse(&raw(&["model"]), SPEC).unwrap();
        assert_eq!(b.value("--export"), None);
    }

    #[test]
    fn equals_value_may_contain_equals_and_dashes() {
        // only the FIRST `=` splits; the value is taken verbatim
        let a = Args::parse(&raw(&["--export=a=b.json"]), SPEC).unwrap();
        assert_eq!(a.value("--export"), Some("a=b.json"));
        // a value starting with `--` is unambiguous in `=` spelling
        let b = Args::parse(&raw(&["--export=--weird--.json"]), SPEC).unwrap();
        assert_eq!(b.value("--export"), Some("--weird--.json"));
    }

    #[test]
    fn space_spelling_consumes_next_token_even_if_flag_like() {
        // `--export --rate` takes `--rate` as the VALUE (declared order
        // of tokens wins); the remaining stream then has no `--rate`
        let a = Args::parse(&raw(&["--export", "--rate", "tiny"]), SPEC).unwrap();
        assert_eq!(a.value("--export"), Some("--rate"));
        assert_eq!(a.value("--rate"), None);
        assert_eq!(a.pos(0), Some("tiny"));
    }

    #[test]
    fn repeated_flags_last_one_wins() {
        let a = Args::parse(&raw(&["--export=a.json", "--export=b.json"]), SPEC).unwrap();
        assert_eq!(a.value("--export"), Some("b.json"));
    }
}
