//! Deterministic indexed parallel map (the vendored dependency set has
//! no rayon).
//!
//! The planner's parallel phases — the candidate × heuristic sweep and
//! the order search's per-level beam expansion — share one shape: run
//! `n` independent, index-addressed tasks on a few worker threads and
//! consume the results **in index order**, so that every downstream
//! reduction (argmin under ties, dominance merging, progress callbacks)
//! is byte-identical to the serial run. This helper is that shape.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Compute `f(0)..f(n-1)` on up to `jobs` scoped worker threads and
/// return the results in index order.
///
/// Tasks are claimed from a shared atomic counter rather than chunked
/// statically — per-index costs vary wildly (beam states have very
/// different frontier sizes), so pre-partitioning would idle early
/// finishers. Small inputs (`n < 4`) and `jobs <= 1` run inline with no
/// threads. A panic in any worker propagates to the caller.
pub fn par_map_indexed<T, F>(n: usize, jobs: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = jobs.min(n);
    if workers <= 1 || n < 4 {
        return (0..n).map(f).collect();
    }
    let claim = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = claim.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for handle in handles {
            for (i, value) in handle.join().expect("parallel worker panicked") {
                slots[i] = Some(value);
            }
        }
    });
    slots
        .into_iter()
        .map(|v| v.expect("every claimed index produced a value"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_index_order_for_any_job_count() {
        let squares: Vec<usize> = (0..100).map(|i| i * i).collect();
        for jobs in [0usize, 1, 2, 4, 16, 200] {
            assert_eq!(par_map_indexed(100, jobs, |i| i * i), squares, "jobs {jobs}");
        }
    }

    #[test]
    fn empty_and_tiny_inputs_run_inline() {
        assert_eq!(par_map_indexed(0, 8, |i| i), Vec::<usize>::new());
        assert_eq!(par_map_indexed(3, 8, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn uneven_task_costs_still_land_in_order() {
        let out = par_map_indexed(32, 4, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(out, (0..32).collect::<Vec<_>>());
    }
}
