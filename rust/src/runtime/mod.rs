//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The compile path is python/JAX (`python/compile/aot.py` lowers the L2
//! model — which calls the L1 Pallas kernels — to **HLO text**; see
//! DESIGN.md and /opt/xla-example/README.md for why text, not serialized
//! protos, is the interchange format). At run time this module is the
//! only thing touching XLA: `PjRtClient::cpu()` → parse HLO → compile →
//! execute. Python never runs on the request path.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Metadata sidecar emitted by `aot.py` alongside the HLO artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// input shape per request, e.g. `[32, 32, 3]`
    pub input_shape: Vec<usize>,
    /// output features per request, e.g. `10`
    pub output_features: usize,
    /// compiled batch sizes, ascending, e.g. `[1, 2, 4, 8]`
    pub batch_sizes: Vec<usize>,
}

impl ArtifactMeta {
    /// Parse `model.meta.json`.
    pub fn load(path: &Path) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text)?;
        let arr = |key: &str| -> Result<Vec<usize>> {
            Ok(v.get(key)
                .and_then(|j| j.as_arr())
                .context(format!("missing {key}"))?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect())
        };
        Ok(ArtifactMeta {
            input_shape: arr("input_shape")?,
            output_features: v
                .get("output_features")
                .and_then(|j| j.as_usize())
                .context("missing output_features")?,
            batch_sizes: arr("batch_sizes")?,
        })
    }

    pub fn elements_per_request(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// A compiled executable for one batch size.
pub struct BatchExecutable {
    pub batch: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The loaded model: one PJRT client, one executable per batch size.
pub struct Engine {
    client: xla::PjRtClient,
    pub meta: ArtifactMeta,
    pub variants: Vec<BatchExecutable>,
}

impl Engine {
    /// Load every `model_b<N>.hlo.txt` listed in the metadata sidecar.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let meta = ArtifactMeta::load(&artifacts_dir.join("model.meta.json"))?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        let mut variants = Vec::new();
        for &b in &meta.batch_sizes {
            let path: PathBuf = artifacts_dir.join(format!("model_b{b}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(wrap)
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(wrap)?;
            variants.push(BatchExecutable { batch: b, exe });
        }
        Ok(Engine {
            client,
            meta,
            variants,
        })
    }

    /// Smallest compiled batch size ≥ `n` (falls back to the largest).
    pub fn variant_for(&self, n: usize) -> &BatchExecutable {
        self.variants
            .iter()
            .find(|v| v.batch >= n)
            .unwrap_or_else(|| self.variants.last().expect("no variants"))
    }

    /// Run a batch: `inputs` is `batch × elements_per_request` f32s,
    /// zero-padded by the caller to the variant's batch size. Returns
    /// `batch × output_features` probabilities.
    pub fn run(&self, variant: &BatchExecutable, inputs: &[f32]) -> Result<Vec<f32>> {
        let per = self.meta.elements_per_request();
        anyhow::ensure!(
            inputs.len() == variant.batch * per,
            "input length {} != batch {} × {}",
            inputs.len(),
            variant.batch,
            per
        );
        let mut dims: Vec<i64> = vec![variant.batch as i64];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let lit = xla::Literal::vec1(inputs).reshape(&dims).map_err(wrap)?;
        let result = variant.exe.execute::<xla::Literal>(&[lit]).map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        // aot.py lowers with return_tuple=True → 1-tuple
        let out = result.to_tuple1().map_err(wrap)?;
        let values = out.to_vec::<f32>().map_err(wrap)?;
        anyhow::ensure!(
            values.len() == variant.batch * self.meta.output_features,
            "unexpected output length {}",
            values.len()
        );
        Ok(values)
    }

    /// Device the client is running on (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}

/// Default artifacts directory (`artifacts/` next to the workspace root,
/// overridable with `DMO_ARTIFACTS`).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("DMO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses() {
        let dir = std::env::temp_dir().join("dmo_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("model.meta.json");
        std::fs::write(
            &p,
            r#"{"input_shape":[32,32,3],"output_features":10,"batch_sizes":[1,2,4,8]}"#,
        )
        .unwrap();
        let m = ArtifactMeta::load(&p).unwrap();
        assert_eq!(m.elements_per_request(), 32 * 32 * 3);
        assert_eq!(m.batch_sizes, vec![1, 2, 4, 8]);
        assert_eq!(m.output_features, 10);
    }
}
