"""L2: the tiny serving model, in JAX, calling the L1 Pallas kernels.

Architecture mirrors `rust/src/models/tiny.rs` exactly (the Rust planner
plans the on-device arena from that definition):

    input 32×32×3
    conv 3×3 s2 → 8   (relu6)          — lax conv (first layer, 3 ch)
    dwconv 3×3 s1     (relu6, Pallas)
    pointwise → 16    (relu6, Pallas)
    dwconv 3×3 s2     (relu6, Pallas)
    pointwise → 32    (relu6, Pallas)
    global avg pool → fc 10 → softmax

Weights are deterministic (PRNGKey(0)) and baked into the traced graph as
constants, so the AOT artifacts are self-contained — the Rust side feeds
activations only.
"""

import jax
import jax.numpy as jnp

from .kernels.dwconv import dwconv2d
from .kernels.pointwise import pointwise_conv
from .kernels.ref import conv2d_ref, relu6

RES = 32
CLASSES = 10


def init_params(key=None):
    """Deterministic parameters for every layer."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 12)
    scale = 0.3

    def mk(k, shape):
        return scale * jax.random.normal(k, shape, dtype=jnp.float32)

    return {
        "conv1_w": mk(ks[0], (3, 3, 3, 8)),
        "conv1_b": mk(ks[1], (8,)),
        "dw1_w": mk(ks[2], (3, 3, 8)),
        "pw1_w": mk(ks[3], (8, 16)),
        "pw1_b": mk(ks[4], (16,)),
        "dw2_w": mk(ks[5], (3, 3, 16)),
        "pw2_w": mk(ks[6], (16, 32)),
        "pw2_b": mk(ks[7], (32,)),
        "fc_w": mk(ks[8], (32, CLASSES)),
        "fc_b": mk(ks[9], (CLASSES,)),
    }


def forward_one(params, x, use_pallas=True):
    """Single-example forward pass: x (32, 32, 3) → (CLASSES,) probs."""
    dw = dwconv2d if use_pallas else _dw_ref
    pw = pointwise_conv if use_pallas else _pw_ref

    h = relu6(conv2d_ref(x, params["conv1_w"], stride=(2, 2), b=params["conv1_b"]))
    h = relu6(dw(h, params["dw1_w"], stride=(1, 1)))
    h = relu6(pw(h, params["pw1_w"], params["pw1_b"]))
    h = relu6(dw(h, params["dw2_w"], stride=(2, 2)))
    h = relu6(pw(h, params["pw2_w"], params["pw2_b"]))
    h = jnp.mean(h, axis=(0, 1))  # global average pool → (32,)
    logits = h @ params["fc_w"] + params["fc_b"]
    return jax.nn.softmax(logits)


def _dw_ref(x, w, stride=(1, 1)):
    from .kernels.ref import dwconv2d_ref

    return dwconv2d_ref(x, w, stride=stride)


def _pw_ref(x, w, b=None):
    from .kernels.ref import pointwise_conv_ref

    return pointwise_conv_ref(x, w, b)


def make_batched(params, use_pallas=True):
    """Batched forward: (B, 32, 32, 3) → (B, CLASSES). Returns a 1-tuple,
    matching the HLO interchange convention (return_tuple=True)."""

    def fn(xb):
        return (jax.vmap(lambda x: forward_one(params, x, use_pallas))(xb),)

    return fn
