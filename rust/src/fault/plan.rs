//! Seeded resolution of a [`FaultSpec`] into concrete trigger points.
//!
//! Determinism contract: two [`FaultPlan`]s built from the same spec,
//! seed, request count and model count trigger at *identical* points.
//! Exec-class faults (panic / corrupt-arena / delay) key off a model's
//! per-model **dispatch sequence number** — assigned under the admission
//! lock, so it is the same across runs regardless of worker count or
//! thread timing. Reload and stall faults key off the load generator's
//! request id, which is likewise a single deterministic sequence.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::spec::{FaultKind, FaultSpec};
use crate::planner::PlanArtifact;
use crate::util::rng::Rng;

/// A contiguous window of per-model dispatch sequence numbers.
#[derive(Debug, Clone, Copy)]
struct Window {
    kind: FaultKind,
    model: usize,
    start: u64,
    len: u64,
}

impl Window {
    fn hits(&self, model: usize, seq: u64) -> bool {
        model == self.model && seq >= self.start && seq < self.start + self.len
    }
}

/// How a reload-injected artifact is garbled. Both garbles are caught by
/// `PlanArtifact::to_plan`'s defensive checks, so the reload is rejected
/// and the serving generation stays untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GarbleMode {
    /// Flip the recorded graph fingerprint → `PlanError::GraphMismatch`.
    FingerprintFlip,
    /// Flip the recorded `O_s` table hash → `PlanError::Malformed`.
    OsHashFlip,
}

/// A scheduled corrupt-reload: at generator request id `at_request`,
/// garble `model`'s current artifact and hot-reload it.
#[derive(Debug, Clone, Copy)]
pub struct ReloadFault {
    pub model: usize,
    pub at_request: u64,
    pub mode: GarbleMode,
}

/// A scheduled admission-queue stall for `model`, entered at generator
/// request id `at_request` and held for `hold`.
#[derive(Debug, Clone, Copy)]
pub struct StallWindow {
    pub model: usize,
    pub at_request: u64,
    pub hold: Duration,
}

/// Arena corruption order: poke `len` seeded garbage bytes at a seeded
/// offset and emit a synthetic store event past the arena end, so the
/// watermark check observes a rogue out-of-bounds write.
#[derive(Debug, Clone, Copy)]
pub struct ArenaCorrupt {
    /// Salt for the in-arena offset/bytes (resolved against arena size
    /// at injection time).
    pub salt: u64,
    pub len: usize,
}

/// Everything to inject into one dispatched request's execution.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecFaults {
    pub panic: bool,
    pub corrupt: Option<ArenaCorrupt>,
    pub delay: Option<Duration>,
}

impl ExecFaults {
    pub fn any(&self) -> bool {
        self.panic || self.corrupt.is_some() || self.delay.is_some()
    }
}

/// A resolved, seeded fault schedule plus injection counters.
#[derive(Debug)]
pub struct FaultPlan {
    windows: Vec<Window>,
    reloads: Vec<ReloadFault>,
    stalls: Vec<StallWindow>,
    /// Exec delay applied per `delay`-window request.
    pub delay: Duration,
    /// How long a `stall` window holds its queue.
    pub stall_hold: Duration,
    injected: [AtomicU64; FaultKind::ALL.len()],
}

impl FaultPlan {
    /// Resolve `spec` against `seed` for a run of `requests` ids over
    /// `models` models.
    pub fn new(spec: &FaultSpec, seed: u64, requests: u64, models: usize) -> FaultPlan {
        let models = models.max(1);
        let mut rng = Rng::new(seed ^ 0xFA_17_5EED);
        let mut windows = Vec::new();
        let mut reloads = Vec::new();
        let mut stalls = Vec::new();
        let mut garble_flip = false;
        for clause in &spec.clauses {
            let model = clause.model.unwrap_or_else(|| rng.below(models)).min(models - 1);
            match clause.kind {
                FaultKind::ArenaCorrupt | FaultKind::WorkerPanic | FaultKind::ExecDelay => {
                    // start low (seq 1..=4) so short runs still hit the
                    // window, but never at seq 0: the first dispatch
                    // always succeeds, which keeps "some traffic served
                    // before the fault" an invariant tests can lean on
                    windows.push(Window {
                        kind: clause.kind,
                        model,
                        start: 1 + rng.below(4) as u64,
                        len: clause.count,
                    });
                }
                FaultKind::CorruptReload => {
                    for i in 0..clause.count {
                        let third = (requests / 3).max(1);
                        let at = third + rng.below(third as usize) as u64 + i;
                        reloads.push(ReloadFault {
                            model,
                            at_request: at.min(requests.saturating_sub(1)),
                            mode: if garble_flip {
                                GarbleMode::OsHashFlip
                            } else {
                                GarbleMode::FingerprintFlip
                            },
                        });
                        garble_flip = !garble_flip;
                    }
                }
                FaultKind::QueueStall => {
                    let quarter = (requests / 4).max(1);
                    let at = quarter + rng.below(quarter as usize) as u64;
                    stalls.push(StallWindow {
                        model,
                        at_request: at.min(requests.saturating_sub(1)),
                        hold: Duration::from_millis(25),
                    });
                }
            }
        }
        FaultPlan {
            windows,
            reloads,
            stalls,
            delay: Duration::from_millis(10),
            stall_hold: Duration::from_millis(25),
            injected: Default::default(),
        }
    }

    /// Faults to inject into the request dispatched as `model`'s
    /// `seq`-th (0-based) — called by the worker with the sequence number
    /// the admission queue assigned.
    pub fn exec_faults(&self, model: usize, seq: u64) -> ExecFaults {
        let mut f = ExecFaults::default();
        for w in &self.windows {
            if !w.hits(model, seq) {
                continue;
            }
            match w.kind {
                FaultKind::WorkerPanic => f.panic = true,
                FaultKind::ArenaCorrupt => {
                    f.corrupt = Some(ArenaCorrupt {
                        salt: (seq << 8) ^ w.start,
                        len: 64,
                    })
                }
                FaultKind::ExecDelay => f.delay = Some(self.delay),
                _ => {}
            }
        }
        f
    }

    /// Reload faults scheduled at generator request `id`.
    pub fn reloads_at(&self, id: u64) -> impl Iterator<Item = &ReloadFault> {
        self.reloads.iter().filter(move |r| r.at_request == id)
    }

    /// Stall windows entered at generator request `id`.
    pub fn stalls_at(&self, id: u64) -> impl Iterator<Item = &StallWindow> {
        self.stalls.iter().filter(move |s| s.at_request == id)
    }

    /// Garble `artifact` per `mode` — the result must be *rejected* by
    /// the registry's revalidating reload.
    pub fn garble(artifact: &PlanArtifact, mode: GarbleMode) -> PlanArtifact {
        let mut bad = artifact.clone();
        match mode {
            GarbleMode::FingerprintFlip => bad.fingerprint ^= 1,
            GarbleMode::OsHashFlip => bad.os_hash ^= 1,
        }
        bad
    }

    /// Record one injected fault of `kind` (feeds
    /// `dmo_faults_injected_total`).
    pub fn note(&self, kind: FaultKind) {
        self.injected[kind.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Injections recorded so far for `kind`.
    pub fn injected(&self, kind: FaultKind) -> u64 {
        self.injected[kind.index()].load(Ordering::Relaxed)
    }

    /// Total injections across all kinds.
    pub fn total_injected(&self) -> u64 {
        FaultKind::ALL.iter().map(|k| self.injected(*k)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(spec: &str, seed: u64) -> FaultPlan {
        FaultPlan::new(&FaultSpec::parse(spec).unwrap(), seed, 100, 2)
    }

    #[test]
    fn same_seed_same_triggers() {
        let a = plan("panic:3@0,corrupt-reload:1,stall:5@1,delay:2", 7);
        let b = plan("panic:3@0,corrupt-reload:1,stall:5@1,delay:2", 7);
        for model in 0..2 {
            for seq in 0..40 {
                let (fa, fb) = (a.exec_faults(model, seq), b.exec_faults(model, seq));
                assert_eq!(fa.panic, fb.panic);
                assert_eq!(fa.corrupt.is_some(), fb.corrupt.is_some());
                assert_eq!(fa.delay, fb.delay);
            }
        }
        for id in 0..100 {
            assert_eq!(a.reloads_at(id).count(), b.reloads_at(id).count());
            assert_eq!(a.stalls_at(id).count(), b.stalls_at(id).count());
        }
    }

    #[test]
    fn panic_window_is_contiguous_and_spares_seq_zero() {
        let p = plan("panic:3@0", 42);
        let hit: Vec<u64> = (0..20).filter(|&s| p.exec_faults(0, s).panic).collect();
        assert_eq!(hit.len(), 3, "window length equals the clause count");
        assert!(hit[0] >= 1, "seq 0 always succeeds");
        assert!(hit.windows(2).all(|w| w[1] == w[0] + 1), "contiguous");
        // pinned to model 0: model 1 is untouched
        assert!((0..20).all(|s| !p.exec_faults(1, s).any()));
    }

    #[test]
    fn injection_counters_accumulate() {
        let p = plan("panic:1", 1);
        p.note(FaultKind::WorkerPanic);
        p.note(FaultKind::WorkerPanic);
        p.note(FaultKind::CorruptReload);
        assert_eq!(p.injected(FaultKind::WorkerPanic), 2);
        assert_eq!(p.total_injected(), 3);
    }
}
