//! The §III-B *bottom-up method*: derive `O_s` from the observed memory
//! events of an actual execution.
//!
//! The authors patched Valgrind to watch a compiled TFLite binary and
//! signalled buffer locations over a FIFO; our substitute observes the
//! same information at the same abstraction level — every load/store/
//! update of the input/output buffers during a real run of the reference
//! kernel (see DESIGN.md substitution table). The probe is an
//! [`EventSink`], so it can watch any execution the [`Arena`] performs,
//! including full-model runs.
//!
//! Folding is streaming (no event storage): every read is paired with the
//! maximum output write up to *and including the next write after it*,
//! which reproduces Algorithm 2's same-step pairing (reads of a step
//! precede its write). The test suite asserts bottom-up == algorithmic on
//! every op family.

use super::{os_from_mind, SafeOverlap};
use crate::ir::op::OpKind;
use crate::ir::shape::Shape;
use crate::ir::DType;
use crate::ops::exec::{execute_op, Arena, EventKind, EventSink, OpIo, Region};

/// Streaming `O_s` probe over memory events.
///
/// Configure with the op's buffer regions (as laid out in the traced run —
/// non-overlapping), then install as the arena's sink.
pub struct OverlapProbe {
    in_regions: Vec<Region>,
    out_region: Region,
    elem: usize,
    /// running max output write (element units), -inf until first write
    max_w: i64,
    /// min pending read per input since the last write
    pending: Vec<i64>,
    /// folded minD per input
    min_d: Vec<i64>,
}

impl OverlapProbe {
    pub fn new(in_regions: Vec<Region>, out_region: Region, dtype: DType) -> Self {
        let n = in_regions.len();
        OverlapProbe {
            in_regions,
            out_region,
            elem: dtype.size_bytes(),
            max_w: i64::MIN,
            pending: vec![i64::MAX; n],
            min_d: vec![i64::MAX; n],
        }
    }

    fn flush_pending(&mut self) {
        if self.max_w == i64::MIN {
            return;
        }
        for j in 0..self.pending.len() {
            if self.pending[j] != i64::MAX {
                self.min_d[j] = self.min_d[j].min(self.pending[j] - self.max_w);
                self.pending[j] = i64::MAX;
            }
        }
    }

    /// Fold trailing reads and produce per-input `O_s` in bytes.
    pub fn finish(mut self, in_shapes: &[&Shape], out_shape: &Shape, dtype: DType) -> SafeOverlap {
        self.flush_pending();
        let per_input = self
            .min_d
            .iter()
            .enumerate()
            .map(|(j, &d)| {
                if d == i64::MAX {
                    super::os_cap(in_shapes[j], out_shape, dtype)
                } else {
                    os_from_mind(d, in_shapes[j], out_shape, dtype)
                }
            })
            .collect();
        SafeOverlap { per_input }
    }
}

impl EventSink for OverlapProbe {
    fn event(&mut self, kind: EventKind, addr: usize, _len: usize) {
        match kind {
            EventKind::Load => {
                for (j, r) in self.in_regions.iter().enumerate() {
                    if r.contains(addr) {
                        let off = ((addr - r.base) / self.elem) as i64;
                        if off < self.pending[j] {
                            self.pending[j] = off;
                        }
                        // input regions may not overlap in the traced run
                        break;
                    }
                }
            }
            EventKind::Store | EventKind::Update => {
                if self.out_region.contains(addr) {
                    let off = ((addr - self.out_region.base) / self.elem) as i64;
                    if off > self.max_w {
                        self.max_w = off;
                    }
                    self.flush_pending();
                }
            }
        }
    }
}

/// Compute bottom-up `O_s` by actually executing `kind` on deterministic
/// dummy data with the probe attached — the whole §III-B pipeline
/// (build test binary → debug → fold) collapsed into one call.
pub fn os_bottom_up(
    kind: &OpKind,
    in_shapes: &[&Shape],
    out_shape: &Shape,
    dtype: DType,
) -> SafeOverlap {
    let t = dtype.size_bytes();
    // lay out input buffers then the output buffer, disjoint
    let mut base = 0usize;
    let in_regions: Vec<Region> = in_shapes
        .iter()
        .map(|s| {
            let r = Region::new(base, s.num_elements() * t);
            base += r.len;
            r
        })
        .collect();
    let out_region = Region::new(base, out_shape.num_elements() * t);
    let mut arena = Arena::new(out_region.end());

    // deterministic input data
    let mut rng = crate::util::rng::Rng::new(0xB077_0409);
    for (s, r) in in_shapes.iter().zip(&in_regions) {
        let data: Vec<f32> = (0..s.num_elements())
            .map(|_| (rng.range(0, 8) as f32) - 4.0)
            .collect();
        arena.write_tensor(dtype, *r, &data);
    }

    // deterministic weights, if the op needs them
    let weights = dummy_weights(kind, in_shapes, dtype);

    let probe = SharedProbe::new(OverlapProbe::new(in_regions.clone(), out_region, dtype));
    arena.set_sink(Some(Box::new(probe.clone())));
    let io = OpIo {
        in_shapes,
        in_regions: &in_regions,
        out_shape,
        out_region,
        dtype,
        weights: &weights,
    };
    execute_op(kind, &io, &mut arena).expect("traced execution failed");
    arena.set_sink(None);
    probe.take().finish(in_shapes, out_shape, dtype)
}

/// Shared handle to an [`OverlapProbe`] so it can serve as the arena's
/// boxed sink and still be recovered afterwards.
#[derive(Clone)]
pub struct SharedProbe(std::sync::Arc<std::sync::Mutex<Option<OverlapProbe>>>);

impl SharedProbe {
    pub fn new(p: OverlapProbe) -> Self {
        SharedProbe(std::sync::Arc::new(std::sync::Mutex::new(Some(p))))
    }

    /// Remove the probe (panics if already taken).
    pub fn take(&self) -> OverlapProbe {
        crate::util::sync::lock(&self.0)
            .take()
            .expect("probe already taken")
    }
}

impl EventSink for SharedProbe {
    fn event(&mut self, kind: EventKind, addr: usize, len: usize) {
        if let Some(p) = crate::util::sync::lock(&self.0).as_mut() {
            p.event(kind, addr, len);
        }
    }
}

/// Deterministic weights sized for `kind` (values irrelevant to `O_s`).
pub fn dummy_weights(kind: &OpKind, in_shapes: &[&Shape], _dtype: DType) -> Vec<Vec<f32>> {
    let mut rng = crate::util::rng::Rng::new(0x5EED);
    let mut mk = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect() };
    match kind {
        OpKind::Conv2D(p) => {
            let id = in_shapes[0].c();
            vec![
                mk(p.kernel.0 * p.kernel.1 * id * p.out_channels),
                mk(p.out_channels),
            ]
        }
        OpKind::DepthwiseConv2D(p) => {
            let id = in_shapes[0].c();
            vec![
                mk(p.kernel.0 * p.kernel.1 * id * p.depth_multiplier),
                mk(id * p.depth_multiplier),
            ]
        }
        OpKind::FullyConnected { out_features, .. } | OpKind::MatMulAccum { out_features } => {
            let k = in_shapes[0].num_elements();
            vec![mk(k * out_features), mk(*out_features)]
        }
        // a band carries the full inner op's weights (every band of a
        // split reads the same filter)
        OpKind::Band(b) => dummy_weights(&b.inner, in_shapes, _dtype),
        _ => vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, BinaryKind, Conv2DParams, DepthwiseParams, Padding, UnaryKind};
    use crate::ops::infer_output;
    use crate::overlap::algorithmic::os_streaming;

    fn check_matches_algorithmic(kind: &OpKind, ins: &[&Shape], dtype: DType) {
        let out = infer_output(kind, ins).unwrap();
        let bu = os_bottom_up(kind, ins, &out, dtype);
        let alg = os_streaming(kind, ins, &out, dtype);
        assert_eq!(bu, alg, "bottom-up != algorithmic for {kind:?}");
    }

    #[test]
    fn bottom_up_matches_algorithmic_elementwise() {
        let s = Shape::hwc(4, 5, 3);
        check_matches_algorithmic(&OpKind::Unary(UnaryKind::Relu), &[&s], DType::F32);
        check_matches_algorithmic(&OpKind::Binary(BinaryKind::Add), &[&s, &s], DType::I8);
    }

    #[test]
    fn bottom_up_matches_algorithmic_convs() {
        let x = Shape::hwc(10, 10, 3);
        check_matches_algorithmic(
            &OpKind::Conv2D(Conv2DParams {
                kernel: (3, 3),
                stride: (2, 2),
                dilation: (1, 1),
                padding: Padding::Same,
                out_channels: 8,
                act: Activation::Relu,
            }),
            &[&x],
            DType::F32,
        );
        check_matches_algorithmic(
            &OpKind::DepthwiseConv2D(DepthwiseParams {
                kernel: (3, 3),
                stride: (1, 1),
                dilation: (1, 1),
                padding: Padding::Same,
                depth_multiplier: 2,
                act: Activation::None,
            }),
            &[&x],
            DType::I8,
        );
    }

    #[test]
    fn bottom_up_matches_algorithmic_matmul_and_softmax() {
        let x = Shape::new(&[1, 12]);
        check_matches_algorithmic(&OpKind::MatMulAccum { out_features: 7 }, &[&x], DType::F32);
        check_matches_algorithmic(
            &OpKind::FullyConnected {
                out_features: 5,
                act: Activation::None,
            },
            &[&x],
            DType::F32,
        );
        let r = Shape::new(&[3, 9]);
        check_matches_algorithmic(&OpKind::Softmax, &[&r], DType::F32);
    }
}
