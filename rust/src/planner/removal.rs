//! Operation removal (§II-C): elide concat ops by letting producers write
//! directly into the aggregated tensor.
//!
//! Concat stores two copies of the same elements (differently shaped); if
//! each upstream op writes its output *into its channel slice of the
//! concatenated tensor*, the copy — and the duplicated memory — vanish.
//! TFLite Micro cannot express this (its element-offset function assumes
//! dense tensors); the paper notes it needs "a small change to the memory
//! offset function". We model that change as an *alias plan*: removed
//! concat inputs have no allocation of their own, only a base offset and
//! a channel stride inside the concat output's buffer.
//!
//! §II-C also notes that writing strided output alters the producer's
//! `O_s`; we conservatively disable DMO overlap for aliased producers
//! (their writes land further ahead in the aggregate than in a dense
//! buffer, so the dense `O_s` would be unsafe).

use crate::ir::graph::{Graph, OpId, TensorId};
use crate::ir::op::OpKind;
use crate::planner::alloc::OsTable;

/// One aliased concat input: lives inside the concat output buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Alias {
    /// the elided input tensor
    pub tensor: TensorId,
    /// the concat output it aliases into
    pub target: TensorId,
    /// element offset of this input's channel slice within a target row
    pub channel_offset: usize,
    /// channels of the target (the stride between this input's rows)
    pub target_channels: usize,
}

/// Result of the removal pass.
#[derive(Debug, Clone, Default)]
pub struct RemovalPlan {
    /// concat ops removed
    pub removed: Vec<OpId>,
    /// alias records for the planner
    pub aliases: Vec<Alias>,
}

impl RemovalPlan {
    pub fn is_aliased(&self, t: TensorId) -> bool {
        self.aliases.iter().any(|a| a.tensor == t)
    }
}

/// Find concat ops whose inputs can alias into the output: every input
/// must be produced by exactly one op (not a graph input), consumed only
/// by the concat, and the producer must be able to write strided output
/// (window/elementwise ops can; re-arrangement ops cannot).
pub fn find_removals(graph: &Graph) -> RemovalPlan {
    let mut plan = RemovalPlan::default();
    for (i, op) in graph.ops.iter().enumerate() {
        if !matches!(op.kind, OpKind::Concat) {
            continue;
        }
        let out_c = graph.tensor(op.output).shape.c();
        let ok = op.inputs.iter().all(|&t| {
            let single_use = graph.consumers(t).len() == 1;
            let produced = graph.producer(t).is_some();
            let strided_ok = graph
                .producer(t)
                .map(|p| {
                    matches!(
                        graph.op(p).kind,
                        OpKind::Conv2D(_)
                            | OpKind::DepthwiseConv2D(_)
                            | OpKind::Pool(_)
                            | OpKind::Unary(_)
                            | OpKind::Binary(_)
                    )
                })
                .unwrap_or(false);
            single_use && produced && strided_ok
        });
        if !ok {
            continue;
        }
        plan.removed.push(OpId(i));
        let mut coff = 0usize;
        for &t in &op.inputs {
            let c = graph.tensor(t).shape.c();
            plan.aliases.push(Alias {
                tensor: t,
                target: op.output,
                channel_offset: coff,
                target_channels: out_c,
            });
            coff += c;
        }
    }
    plan
}

/// Apply a removal plan: concat ops become `Reshape`-like no-ops on the
/// planning graph — we rebuild the graph with the concat's inputs replaced
/// by zero-sized scopes. Practically the planner needs two effects:
/// (1) aliased tensors take no arena space of their own, and
/// (2) producers of aliased tensors lose their DMO budget.
/// We express both by returning a transformed copy of the `O_s` table and
/// the list of tensors to pin to the concat output's allocation.
pub fn apply_to_os(graph: &Graph, plan: &RemovalPlan, os: &OsTable) -> OsTable {
    let mut out = os.clone();
    for alias in &plan.aliases {
        if let Some(p) = graph.producer(alias.tensor) {
            for b in out.per_op[p.0].iter_mut() {
                *b = 0; // strided writes invalidate the dense O_s (§II-C)
            }
        }
    }
    out
}

/// Peak-memory estimate with concat removal applied on top of a plan:
/// every aliased tensor's bytes are saved whenever it was live alongside
/// its target. This is the §II-C headline effect (Squeezenet-style
/// models); exact layout comes from re-planning with the aliased tensors
/// removed from the arena set.
pub fn removable_bytes(graph: &Graph, plan: &RemovalPlan) -> usize {
    plan.aliases
        .iter()
        .map(|a| graph.tensor(a.tensor).size_bytes())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Padding};
    use crate::ir::{DType, GraphBuilder, Shape};
    use crate::overlap::Method;

    fn concat_graph() -> Graph {
        // inception-style: x -> (1x1 conv, 3x3 conv) -> concat -> conv
        let mut b = GraphBuilder::new("cat", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 4));
        let a = b.conv2d(x, 4, (1, 1), (1, 1), Padding::Same, Activation::Relu);
        let c = b.conv2d(x, 8, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let cat = b.concat(&[a, c]);
        let out = b.conv2d(cat, 4, (1, 1), (1, 1), Padding::Same, Activation::None);
        b.finish(&[out])
    }

    #[test]
    fn finds_removable_concat() {
        let g = concat_graph();
        let plan = find_removals(&g);
        assert_eq!(plan.removed.len(), 1);
        assert_eq!(plan.aliases.len(), 2);
        assert_eq!(plan.aliases[0].channel_offset, 0);
        assert_eq!(plan.aliases[1].channel_offset, 4);
        assert_eq!(plan.aliases[1].target_channels, 12);
        let saved = removable_bytes(&g, &plan);
        assert_eq!(saved, (8 * 8 * 4 + 8 * 8 * 8) * 4);
    }

    #[test]
    fn multi_use_input_blocks_removal() {
        let mut b = GraphBuilder::new("cat2", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 4));
        let a = b.conv2d(x, 4, (1, 1), (1, 1), Padding::Same, Activation::Relu);
        let c = b.conv2d(x, 8, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let cat = b.concat(&[a, c]);
        let merged = b.conv2d(cat, 4, (1, 1), (1, 1), Padding::Same, Activation::None);
        // `a` also feeds a residual add — concat can't claim its buffer
        let extra = b.add(merged, a);
        let g = b.finish(&[extra]);
        let plan = find_removals(&g);
        assert!(plan.removed.is_empty());
    }

    #[test]
    fn aliased_producers_lose_dmo_budget() {
        let g = concat_graph();
        let plan = find_removals(&g);
        let os = OsTable::build(&g, Method::Analytic);
        let adjusted = apply_to_os(&g, &plan, &os);
        // producers of the two concat inputs are ops 0 and 1
        assert_eq!(adjusted.per_op[0], vec![0]);
        assert_eq!(adjusted.per_op[1], vec![0]);
        // the consumer conv's budget is untouched
        assert_eq!(adjusted.per_op[3], os.per_op[3]);
    }
}
