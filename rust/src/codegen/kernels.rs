//! The C99 kernel bodies the emitter pastes into a translation unit.
//!
//! Every kernel is a line-for-line port of the corresponding arm of
//! [`crate::ops::exec::execute_op`]: same loop nests, same accumulation
//! order, same read-before-write interleaving. That fidelity is the
//! whole point — the `O_s` overlap budgets were computed against the
//! reference sweep order, so the emitted code must touch the arena in
//! exactly that order or the planned overlaps stop being safe. Do not
//! "optimise" these loops without re-deriving the overlap analysis.
//!
//! Floating-point notes (the differential harness asserts bit-exactness
//! against the Rust interpreter):
//! * comparisons are written out (`if (v > acc)`) rather than calling
//!   `fmaxf`, matching the interpreter and fixing `-0.0`/`+0.0` ties;
//! * `expf`/`roundf` come from libm — the same routines Rust's
//!   `f32::exp`/`f32::round` lower to on a glibc host;
//! * the harness compiles with `-ffp-contract=off` so the compiler
//!   cannot fuse `a * b + c` into an FMA the interpreter did not do.

use crate::ir::graph::Graph;
use crate::ir::op::{OpKind, PoolKind, UnaryKind};

/// One emitted kernel function. Several [`OpKind`]s can share a kernel
/// (both pool flavours, unary/reshape copies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Kernel {
    Conv2D,
    DwConv2D,
    Pool,
    GlobalAvgPool,
    Unary,
    Binary,
    Fc,
    MatMul,
    Concat,
    Pad,
    Softmax,
    /// §II-A banded variants: full-frame padding/clipping geometry,
    /// band-local addressing. Banded unary ops and concat-rows
    /// reassembly reuse [`Kernel::Unary`] (they are offset copies).
    BandConv2D,
    BandDwConv2D,
    BandPool,
}

impl Kernel {
    /// Kernel implementing `kind`.
    pub(crate) fn for_op(kind: &OpKind) -> Kernel {
        match kind {
            OpKind::Conv2D(_) => Kernel::Conv2D,
            OpKind::DepthwiseConv2D(_) => Kernel::DwConv2D,
            OpKind::Pool(_) => Kernel::Pool,
            OpKind::GlobalAvgPool => Kernel::GlobalAvgPool,
            OpKind::Unary(_) | OpKind::Reshape { .. } => Kernel::Unary,
            OpKind::Binary(_) => Kernel::Binary,
            OpKind::FullyConnected { .. } => Kernel::Fc,
            OpKind::MatMulAccum { .. } => Kernel::MatMul,
            OpKind::Concat => Kernel::Concat,
            OpKind::Pad { .. } => Kernel::Pad,
            OpKind::Softmax => Kernel::Softmax,
            OpKind::Band(b) => match b.inner.as_ref() {
                OpKind::Conv2D(_) => Kernel::BandConv2D,
                OpKind::DepthwiseConv2D(_) => Kernel::BandDwConv2D,
                OpKind::Pool(_) => Kernel::BandPool,
                // elementwise bands are plain offset copies
                _ => Kernel::Unary,
            },
            OpKind::ConcatRows => Kernel::Unary,
        }
    }

    /// Does this kernel call the shared `dmo_act` helper?
    pub(crate) fn uses_act(self) -> bool {
        matches!(
            self,
            Kernel::Conv2D | Kernel::DwConv2D | Kernel::Fc | Kernel::BandConv2D | Kernel::BandDwConv2D
        )
    }

    /// C source of the kernel function.
    pub(crate) fn source(self) -> &'static str {
        match self {
            Kernel::Conv2D => CONV2D,
            Kernel::DwConv2D => DWCONV2D,
            Kernel::Pool => POOL,
            Kernel::GlobalAvgPool => GAVGPOOL,
            Kernel::Unary => UNARY,
            Kernel::Binary => BINARY,
            Kernel::Fc => FC,
            Kernel::MatMul => MATMUL,
            Kernel::Concat => CONCAT,
            Kernel::Pad => PAD,
            Kernel::Softmax => SOFTMAX,
            Kernel::BandConv2D => BAND_CONV2D,
            Kernel::BandDwConv2D => BAND_DWCONV2D,
            Kernel::BandPool => BAND_POOL,
        }
    }
}

/// The kernels needed by `graph`, in first-use order, deduplicated.
pub(crate) fn kernels_used(graph: &Graph) -> Vec<Kernel> {
    let mut used = Vec::new();
    for op in &graph.ops {
        let k = Kernel::for_op(&op.kind);
        if !used.contains(&k) {
            used.push(k);
        }
    }
    used
}

/// Unary-kernel selector constants (`kind` parameter of `dmo_unary`).
pub(crate) fn unary_kind_id(u: UnaryKind) -> usize {
    match u {
        UnaryKind::Relu => 0,
        UnaryKind::Relu6 => 1,
        UnaryKind::Copy => 2,
    }
}

/// Pool-kernel selector constants (`kind` parameter of `dmo_pool`).
pub(crate) fn pool_kind_id(k: PoolKind) -> usize {
    match k {
        PoolKind::Max => 0,
        PoolKind::Avg => 1,
    }
}

/// Fused-activation selector (`a` parameter of `dmo_act`).
pub(crate) fn act_id(a: crate::ir::op::Activation) -> usize {
    match a {
        crate::ir::op::Activation::None => 0,
        crate::ir::op::Activation::Relu => 1,
        crate::ir::op::Activation::Relu6 => 2,
    }
}

/// Shared fused-activation helper (relu / relu6), `-0.0`-preserving like
/// the interpreter's `act`.
pub(crate) const ACT_HELPER: &str = "\
static float dmo_act(float v, int a) {
    if (a >= 1 && v < 0.0f) {
        v = 0.0f;
    }
    if (a == 2 && v > 6.0f) {
        v = 6.0f;
    }
    return v;
}
";

const CONV2D: &str = "\
static void dmo_conv2d(size_t ib, size_t ob, int ih, int iw, int id, int oh, int ow, int od,
                       int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw, int a,
                       const dmo_wt *w, const dmo_bt *bias) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int oc = 0; oc < od; oc++) {
                float total = (float)bias[oc];
                for (int ky = 0; ky < kh; ky++) {
                    int iy = y0 + ky * dh;
                    if (iy < 0 || iy >= ih) {
                        continue;
                    }
                    for (int kx = 0; kx < kw; kx++) {
                        int ix = x0 + kx * dw;
                        if (ix < 0 || ix >= iw) {
                            continue;
                        }
                        for (int ic = 0; ic < id; ic++) {
                            float v = dmo_load(ib + (size_t)((iy * iw + ix) * id + ic) * DMO_ELEM_BYTES);
                            total += v * (float)w[((ky * kw + kx) * id + ic) * od + oc];
                        }
                    }
                }
                dmo_store(ob + (size_t)((oy * ow + ox) * od + oc) * DMO_ELEM_BYTES, dmo_act(total, a));
            }
        }
    }
}
";

const DWCONV2D: &str = "\
static void dmo_dwconv2d(size_t ib, size_t ob, int ih, int iw, int id, int oh, int ow, int od,
                         int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw,
                         int mult, int bias_n, int a, const dmo_wt *w, const dmo_bt *bias) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int ic = 0; ic < id; ic++) {
                for (int m = 0; m < mult; m++) {
                    int oc = ic * mult + m;
                    float total = (float)bias[oc < bias_n ? oc : bias_n - 1];
                    for (int ky = 0; ky < kh; ky++) {
                        int iy = y0 + ky * dh;
                        if (iy < 0 || iy >= ih) {
                            continue;
                        }
                        for (int kx = 0; kx < kw; kx++) {
                            int ix = x0 + kx * dw;
                            if (ix < 0 || ix >= iw) {
                                continue;
                            }
                            float v = dmo_load(ib + (size_t)((iy * iw + ix) * id + ic) * DMO_ELEM_BYTES);
                            total += v * (float)w[((ky * kw + kx) * id + ic) * mult + m];
                        }
                    }
                    dmo_store(ob + (size_t)((oy * ow + ox) * od + oc) * DMO_ELEM_BYTES, dmo_act(total, a));
                }
            }
        }
    }
}
";

const POOL: &str = "\
static void dmo_pool(size_t ib, size_t ob, int ih, int iw, int id, int oh, int ow, int od,
                     int kh, int kw, int sh, int sw, int ph, int pw, int kind) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int c = 0; c < od; c++) {
                float acc = kind == 0 ? -INFINITY : 0.0f;
                int n = 0;
                for (int ky = 0; ky < kh; ky++) {
                    int iy = y0 + ky;
                    if (iy < 0 || iy >= ih) {
                        continue;
                    }
                    for (int kx = 0; kx < kw; kx++) {
                        int ix = x0 + kx;
                        if (ix < 0 || ix >= iw) {
                            continue;
                        }
                        float v = dmo_load(ib + (size_t)((iy * iw + ix) * id + c) * DMO_ELEM_BYTES);
                        if (kind == 0) {
                            if (v > acc) {
                                acc = v;
                            }
                        } else {
                            acc += v;
                        }
                        n++;
                    }
                }
                float r = kind == 0 ? acc : acc / (float)(n > 0 ? n : 1);
                dmo_store(ob + (size_t)((oy * ow + ox) * od + c) * DMO_ELEM_BYTES, r);
            }
        }
    }
}
";

const GAVGPOOL: &str = "\
static void dmo_gavgpool(size_t ib, size_t ob, int ih, int iw, int id) {
    for (int c = 0; c < id; c++) {
        float acc = 0.0f;
        for (int p = 0; p < ih * iw; p++) {
            acc += dmo_load(ib + (size_t)(p * id + c) * DMO_ELEM_BYTES);
        }
        dmo_store(ob + (size_t)c * DMO_ELEM_BYTES, acc / (float)(ih * iw));
    }
}
";

const UNARY: &str = "\
static void dmo_unary(size_t ib, size_t ob, size_t n, int kind) {
    for (size_t i = 0; i < n; i++) {
        float v = dmo_load(ib + i * DMO_ELEM_BYTES);
        if (kind == 0 && v < 0.0f) {
            v = 0.0f;
        }
        if (kind == 1) {
            if (v < 0.0f) {
                v = 0.0f;
            }
            if (v > 6.0f) {
                v = 6.0f;
            }
        }
        dmo_store(ob + i * DMO_ELEM_BYTES, v);
    }
}
";

const BINARY: &str = "\
static void dmo_binary(size_t ab, size_t bb, size_t ob, size_t n, int kind) {
    for (size_t i = 0; i < n; i++) {
        float x = dmo_load(ab + i * DMO_ELEM_BYTES);
        float y = dmo_load(bb + i * DMO_ELEM_BYTES);
        dmo_store(ob + i * DMO_ELEM_BYTES, kind == 0 ? x + y : x * y);
    }
}
";

const FC: &str = "\
static void dmo_fc(size_t ib, size_t ob, int k_dim, int nf, int a,
                   const dmo_wt *w, const dmo_bt *bias) {
    for (int o = 0; o < nf; o++) {
        float total = (float)bias[o];
        for (int k = 0; k < k_dim; k++) {
            total += dmo_load(ib + (size_t)k * DMO_ELEM_BYTES) * (float)w[k * nf + o];
        }
        dmo_store(ob + (size_t)o * DMO_ELEM_BYTES, dmo_act(total, a));
    }
}
";

const MATMUL: &str = "\
static void dmo_matmul(size_t ib, size_t ob, int k_dim, int nf,
                       const dmo_wt *w, const dmo_bt *bias) {
    for (int o = 0; o < nf; o++) {
        dmo_store(ob + (size_t)o * DMO_ELEM_BYTES, (float)bias[o]);
    }
    for (int k = 0; k < k_dim; k++) {
        float v = dmo_load(ib + (size_t)k * DMO_ELEM_BYTES);
        for (int o = 0; o < nf; o++) {
            size_t off = ob + (size_t)o * DMO_ELEM_BYTES;
            dmo_store(off, dmo_load(off) + v * (float)w[k * nf + o]);
        }
    }
}
";

const CONCAT: &str = "\
static void dmo_concat(size_t ob, int hw, int od, int n, const size_t *ibs, const int *cs) {
    for (int p = 0; p < hw; p++) {
        int coff = 0;
        for (int j = 0; j < n; j++) {
            int cj = cs[j];
            for (int c = 0; c < cj; c++) {
                float v = dmo_load(ibs[j] + (size_t)(p * cj + c) * DMO_ELEM_BYTES);
                dmo_store(ob + (size_t)(p * od + coff + c) * DMO_ELEM_BYTES, v);
            }
            coff += cj;
        }
    }
}
";

const PAD: &str = "\
static void dmo_pad(size_t ib, size_t ob, int ih, int iw, int id, int oh, int ow, int od,
                    int top, int left) {
    for (int oy = 0; oy < oh; oy++) {
        for (int ox = 0; ox < ow; ox++) {
            int inside = oy >= top && oy < top + ih && ox >= left && ox < left + iw;
            for (int c = 0; c < od; c++) {
                float v = 0.0f;
                if (inside) {
                    v = dmo_load(ib + (size_t)(((oy - top) * iw + (ox - left)) * id + c) * DMO_ELEM_BYTES);
                }
                dmo_store(ob + (size_t)((oy * ow + ox) * od + c) * DMO_ELEM_BYTES, v);
            }
        }
    }
}
";

const SOFTMAX: &str = "\
static void dmo_softmax(size_t ib, size_t ob, int rows, int d) {
    for (int r = 0; r < rows; r++) {
        float m = -INFINITY;
        for (int c = 0; c < d; c++) {
            float x = dmo_load(ib + (size_t)(r * d + c) * DMO_ELEM_BYTES);
            if (x > m) {
                m = x;
            }
        }
        float sum = 0.0f;
        for (int c = 0; c < d; c++) {
            sum += expf(dmo_load(ib + (size_t)(r * d + c) * DMO_ELEM_BYTES) - m);
        }
        for (int c = 0; c < d; c++) {
            float v = expf(dmo_load(ib + (size_t)(r * d + c) * DMO_ELEM_BYTES) - m) / sum;
            dmo_store(ob + (size_t)(r * d + c) * DMO_ELEM_BYTES, v);
        }
    }
}
";

const BAND_CONV2D: &str = "\
static void dmo_band_conv2d(size_t ib, size_t ob, int fih, int iw, int id, int ir0,
                            int oy0, int orows, int ow, int od,
                            int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw, int a,
                            const dmo_wt *w, const dmo_bt *bias) {
    for (int oyl = 0; oyl < orows; oyl++) {
        int oy = oy0 + oyl;
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int oc = 0; oc < od; oc++) {
                float total = (float)bias[oc];
                for (int ky = 0; ky < kh; ky++) {
                    int iy = y0 + ky * dh;
                    if (iy < 0 || iy >= fih) {
                        continue;
                    }
                    for (int kx = 0; kx < kw; kx++) {
                        int ix = x0 + kx * dw;
                        if (ix < 0 || ix >= iw) {
                            continue;
                        }
                        for (int ic = 0; ic < id; ic++) {
                            float v = dmo_load(ib + (size_t)(((iy - ir0) * iw + ix) * id + ic) * DMO_ELEM_BYTES);
                            total += v * (float)w[((ky * kw + kx) * id + ic) * od + oc];
                        }
                    }
                }
                dmo_store(ob + (size_t)((oyl * ow + ox) * od + oc) * DMO_ELEM_BYTES, dmo_act(total, a));
            }
        }
    }
}
";

const BAND_DWCONV2D: &str = "\
static void dmo_band_dwconv2d(size_t ib, size_t ob, int fih, int iw, int id, int ir0,
                              int oy0, int orows, int ow, int od,
                              int kh, int kw, int sh, int sw, int dh, int dw, int ph, int pw,
                              int mult, int bias_n, int a, const dmo_wt *w, const dmo_bt *bias) {
    for (int oyl = 0; oyl < orows; oyl++) {
        int oy = oy0 + oyl;
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int ic = 0; ic < id; ic++) {
                for (int m = 0; m < mult; m++) {
                    int oc = ic * mult + m;
                    float total = (float)bias[oc < bias_n ? oc : bias_n - 1];
                    for (int ky = 0; ky < kh; ky++) {
                        int iy = y0 + ky * dh;
                        if (iy < 0 || iy >= fih) {
                            continue;
                        }
                        for (int kx = 0; kx < kw; kx++) {
                            int ix = x0 + kx * dw;
                            if (ix < 0 || ix >= iw) {
                                continue;
                            }
                            float v = dmo_load(ib + (size_t)(((iy - ir0) * iw + ix) * id + ic) * DMO_ELEM_BYTES);
                            total += v * (float)w[((ky * kw + kx) * id + ic) * mult + m];
                        }
                    }
                    dmo_store(ob + (size_t)((oyl * ow + ox) * od + oc) * DMO_ELEM_BYTES, dmo_act(total, a));
                }
            }
        }
    }
}
";

const BAND_POOL: &str = "\
static void dmo_band_pool(size_t ib, size_t ob, int fih, int iw, int id, int ir0,
                          int oy0, int orows, int ow, int od,
                          int kh, int kw, int sh, int sw, int ph, int pw, int kind) {
    for (int oyl = 0; oyl < orows; oyl++) {
        int oy = oy0 + oyl;
        for (int ox = 0; ox < ow; ox++) {
            int y0 = oy * sh - ph;
            int x0 = ox * sw - pw;
            for (int c = 0; c < od; c++) {
                float acc = kind == 0 ? -INFINITY : 0.0f;
                int n = 0;
                for (int ky = 0; ky < kh; ky++) {
                    int iy = y0 + ky;
                    if (iy < 0 || iy >= fih) {
                        continue;
                    }
                    for (int kx = 0; kx < kw; kx++) {
                        int ix = x0 + kx;
                        if (ix < 0 || ix >= iw) {
                            continue;
                        }
                        float v = dmo_load(ib + (size_t)(((iy - ir0) * iw + ix) * id + c) * DMO_ELEM_BYTES);
                        if (kind == 0) {
                            if (v > acc) {
                                acc = v;
                            }
                        } else {
                            acc += v;
                        }
                        n++;
                    }
                }
                float r = kind == 0 ? acc : acc / (float)(n > 0 ? n : 1);
                dmo_store(ob + (size_t)((oyl * ow + ox) * od + c) * DMO_ELEM_BYTES, r);
            }
        }
    }
}
";

/// Arena element accessors, specialised per activation dtype. The `i8`
/// store replicates the interpreter's quantisation exactly: libm
/// `roundf` (round half away from zero, what Rust's `f32::round` is),
/// then saturate to `[-128, 127]`.
pub(crate) fn load_store_source(dtype: crate::ir::DType) -> &'static str {
    match dtype {
        crate::ir::DType::F32 | crate::ir::DType::I32 => LOAD_STORE_F32,
        crate::ir::DType::I8 => LOAD_STORE_I8,
    }
}

const LOAD_STORE_F32: &str = "\
static float dmo_load(size_t off) {
    float v;
    memcpy(&v, dmo_arena + off, sizeof v);
    return v;
}

static void dmo_store(size_t off, float v) {
    memcpy(dmo_arena + off, &v, sizeof v);
}
";

const LOAD_STORE_I8: &str = "\
static float dmo_load(size_t off) {
    return (float)(int8_t)dmo_arena[off];
}

static void dmo_store(size_t off, float v) {
    float r = roundf(v);
    if (r < -128.0f) {
        r = -128.0f;
    }
    if (r > 127.0f) {
        r = 127.0f;
    }
    dmo_arena[off] = (uint8_t)(int8_t)r;
}
";

/// SplitMix64 weight generator (emitted only when the model's weights
/// are too large to embed as initialisers): the same stream
/// [`crate::ops::exec::gen_weights`] draws from, so generated and
/// embedded weights are interchangeable bit for bit.
pub(crate) const SPLITMIX: &str = "\
static uint64_t dmo_sm_next(uint64_t *s) {
    *s += 0x9E3779B97F4A7C15ULL;
    uint64_t z = *s;
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
    return z ^ (z >> 31);
}

static void dmo_fill_wt(dmo_wt *dst, size_t n, uint64_t *s) {
    for (size_t i = 0; i < n; i++) {
        dst[i] = (dmo_wt)((int)(dmo_sm_next(s) % 5u) - 2);
    }
}

static void dmo_fill_bt(dmo_bt *dst, size_t n, uint64_t *s) {
    for (size_t i = 0; i < n; i++) {
        dst[i] = (dmo_bt)((int)(dmo_sm_next(s) % 5u) - 2);
    }
}
";

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn tiny_uses_expected_kernels() {
        let g = models::build("tiny").unwrap();
        let used = kernels_used(&g);
        assert_eq!(
            used,
            vec![
                Kernel::Conv2D,
                Kernel::DwConv2D,
                Kernel::GlobalAvgPool,
                Kernel::Unary,
                Kernel::Fc,
                Kernel::Softmax,
            ]
        );
        assert!(used.iter().any(|k| k.uses_act()));
    }

    #[test]
    fn kernel_sources_reference_only_emitted_names() {
        // every kernel body must be self-contained modulo the shared
        // helpers the emitter always provides alongside it
        for k in [
            Kernel::Conv2D,
            Kernel::DwConv2D,
            Kernel::Pool,
            Kernel::GlobalAvgPool,
            Kernel::Unary,
            Kernel::Binary,
            Kernel::Fc,
            Kernel::MatMul,
            Kernel::Concat,
            Kernel::Pad,
            Kernel::Softmax,
            Kernel::BandConv2D,
            Kernel::BandDwConv2D,
            Kernel::BandPool,
        ] {
            let src = k.source();
            assert!(src.starts_with("static void dmo_"), "{src}");
            assert!(src.contains("dmo_store("), "every kernel writes: {src}");
            assert_eq!(k.uses_act(), src.contains("dmo_act("), "{src}");
        }
    }
}
