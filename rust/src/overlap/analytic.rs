//! The §III-D *analytical method*: closed-form lower bounds of `O_s`.
//!
//! For window ops (conv2d, dwconv2d, pooling) the read pattern is bounded
//! below by the truncated linear function `minR(i) = max(0, a·i + b)`
//! (Fig 6); with `maxW(i) = i` (one output element per step, ascending),
//! `O_s = OB_s + minD·T_s` where `minD = min_{0≤i≤i_c} (max(0, a·i+b) − i)`.
//!
//! The paper's Eq (11) evaluates that envelope at two candidate points
//! (Fig 7); we additionally evaluate the kink and both endpoints, which is
//! the exact minimum of the *bound* (still a lower bound of the true
//! `O_s`, but never looser than Eq 11).
//!
//! The `(a, b)` coefficient pairs are the paper's Eqs (7)/(8) for
//! depthwise conv, (12)/(13) for 2-D conv and (14)/(15) for pooling, with
//! `P_h`/`P_w` from Eqs (5)/(6). Element-wise, softmax, global-pool,
//! reshape get their trivially exact values; matmul/FC, concat and pad are
//! conservatively 0 (the paper's analytic family covers only the window
//! ops — §III-D notes elementwise reductions "had no effect" on precision,
//! Table II).

use super::{os_from_mind, SafeOverlap};
use crate::ir::op::{pad_before, OpKind};
use crate::ir::shape::Shape;
use crate::ir::DType;

/// Coefficients of the truncated-linear read bound, element units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearBound {
    pub a: f64,
    pub b: f64,
    /// Total step count `i_c`.
    pub i_c: u64,
}

impl LinearBound {
    /// `minD = min over i in [0, i_c] of (max(0, a·i + b) − i)`, evaluated
    /// at both endpoints and the truncation kink `i* = −b/a`.
    pub fn min_d(&self) -> i64 {
        let f = |i: f64| -> f64 { (self.a * i + self.b).max(0.0) - i };
        let ic = self.i_c as f64;
        let mut m = f(0.0).min(f(ic));
        if self.a > 0.0 {
            let kink = -self.b / self.a;
            if kink > 0.0 && kink < ic {
                m = m.min(f(kink.floor())).min(f(kink.ceil()));
            }
        }
        m.floor() as i64
    }

    /// The paper's Eq (11) two-candidate form: `min{b/a, a·i_c + b − i_c}`.
    pub fn min_d_eq11(&self) -> i64 {
        let ic = self.i_c as f64;
        let c1 = self.b / self.a;
        let c2 = self.a * ic + self.b - ic;
        c1.min(c2).floor() as i64
    }
}

/// Provably-safe intercept: every step of output row `N` reads at offset
/// ≥ `(N·S_h − P_h)·I_w·I_d`, and `N ≥ (i+1)/R_steps − 1`, giving
/// `b_safe = a − (S_h + P_h)·I_w·I_d` independent of kernel/stride
/// interplay. The paper's Eqs (8)/(13)/(15) are tighter but anchor on
/// row-end reads that do not exist when the stride exceeds the effective
/// kernel (windows skip columns/rows entirely) — the property tests found
/// the overshoot, so those configurations fall back to this intercept.
/// Real networks never stride past their kernels; on all Table-III ops
/// the paper's coefficients are used verbatim.
fn b_safe(a: f64, sh: f64, ph: f64, iw: f64, id: f64) -> f64 {
    a - (sh + ph) * iw * id
}

/// Does the paper's row-end anchoring hold for this geometry?
fn paper_b_applicable(kernel: (usize, usize), stride: (usize, usize), dilation: (usize, usize)) -> bool {
    stride.0 <= kernel.0 * dilation.0 && stride.1 <= kernel.1 * dilation.1
}

/// `(a, b)` for a window op per the paper's equations. Returns `None` for
/// kinds outside the analytic family.
pub fn linear_bound(kind: &OpKind, in_shapes: &[&Shape], out_shape: &Shape) -> Option<LinearBound> {
    let xs = in_shapes.first()?;
    match kind {
        OpKind::DepthwiseConv2D(p) => {
            let (ih, iw, id) = (xs.h() as f64, xs.w() as f64, xs.c() as f64);
            let (oh, ow) = (out_shape.h() as f64, out_shape.w() as f64);
            let (sh, sw) = (p.stride.0 as f64, p.stride.1 as f64);
            let kc = p.depth_multiplier as f64;
            let ph = pad_before(xs.h(), out_shape.h(), p.kernel.0, p.stride.0, p.dilation.0) as f64;
            let pw = pad_before(xs.w(), out_shape.w(), p.kernel.1, p.stride.1, p.dilation.1) as f64;
            // Eq (7): a = S_h·I_w / (O_w·K_c)
            let a = sh * iw / (ow * kc);
            // Eq (8): b = (O_w·S_w − P_h·I_w − S_h·I_w − S_w − P_w + 1)·I_d
            let b = if paper_b_applicable(p.kernel, p.stride, p.dilation) {
                (ow * sw - ph * iw - sh * iw - sw - pw + 1.0) * id
            } else {
                b_safe(a, sh, ph, iw, id)
            };
            let _ = ih;
            Some(LinearBound {
                a,
                b,
                i_c: (oh * ow * id * kc) as u64,
            })
        }
        OpKind::Conv2D(p) => {
            let (iw, id) = (xs.w() as f64, xs.c() as f64);
            let (oh, ow, od) = (out_shape.h() as f64, out_shape.w() as f64, out_shape.c() as f64);
            let (sh, sw) = (p.stride.0 as f64, p.stride.1 as f64);
            let ph = pad_before(xs.h(), out_shape.h(), p.kernel.0, p.stride.0, p.dilation.0) as f64;
            let pw = pad_before(xs.w(), out_shape.w(), p.kernel.1, p.stride.1, p.dilation.1) as f64;
            // Eq (12): a = S_h·I_w·I_d / (O_w·O_d)
            let a = sh * iw * id / (ow * od);
            // Eq (13): b = (O_w·S_w − P_h·I_w − S_h·I_w − S_w − P_w)·I_d + 1
            let b = if paper_b_applicable(p.kernel, p.stride, p.dilation) {
                (ow * sw - ph * iw - sh * iw - sw - pw) * id + 1.0
            } else {
                b_safe(a, sh, ph, iw, id)
            };
            Some(LinearBound {
                a,
                b,
                i_c: (oh * ow * od) as u64,
            })
        }
        OpKind::Pool(p) => {
            let (iw, id) = (xs.w() as f64, xs.c() as f64);
            let (oh, ow) = (out_shape.h() as f64, out_shape.w() as f64);
            let (sh, sw) = (p.stride.0 as f64, p.stride.1 as f64);
            let ph = pad_before(xs.h(), out_shape.h(), p.kernel.0, p.stride.0, 1) as f64;
            let pw = pad_before(xs.w(), out_shape.w(), p.kernel.1, p.stride.1, 1) as f64;
            // Eq (14): a = S_h·I_w / O_w
            let a = sh * iw / ow;
            // Eq (15): b = (O_w·S_w − P_h·I_w − S_h·I_w − S_w − P_w)·I_d + 1
            let b = if paper_b_applicable(p.kernel, p.stride, (1, 1)) {
                (ow * sw - ph * iw - sh * iw - sw - pw) * id + 1.0
            } else {
                b_safe(a, sh, ph, iw, id)
            };
            Some(LinearBound {
                a,
                b,
                i_c: (oh * ow * id) as u64,
            })
        }
        _ => None,
    }
}

/// Exact `minD` for a 2-D convolution at *position* granularity.
///
/// Within one spatial position the reference kernel's reads are identical
/// across the `oc` sweep while writes ascend, so `minR(i) − maxW(i)` is
/// minimal at the position's last step — a suffix-min over positions in
/// reverse order reproduces the element-granular algorithmic result in
/// `O(O_h·O_w)` (the paper notes this collapse in §III-C: "the code could
/// be simplified to a single set of nested loops").
pub fn conv_exact_min_d(
    p: &crate::ir::op::Conv2DParams,
    in_shape: &Shape,
    out_shape: &Shape,
) -> i64 {
    let (ih, iw, id) = (in_shape.h(), in_shape.w(), in_shape.c());
    let (oh, ow, od) = (out_shape.h(), out_shape.w(), out_shape.c());
    let ph = pad_before(ih, oh, p.kernel.0, p.stride.0, p.dilation.0) as isize;
    let pw = pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1) as isize;
    let min_cell = |o: usize, stride: usize, pad: isize, k: usize, d: usize, lim: usize| -> Option<usize> {
        let base = o as isize * stride as isize - pad;
        (0..k)
            .map(|t| base + (t * d) as isize)
            .find(|&v| v >= 0 && (v as usize) < lim)
            .map(|v| v as usize)
    };
    let mut suffix = i64::MAX;
    let mut min_d = i64::MAX;
    for pos in (0..oh * ow).rev() {
        let (oy, ox) = (pos / ow, pos % ow);
        let m = match (
            min_cell(oy, p.stride.0, ph, p.kernel.0, p.dilation.0, ih),
            min_cell(ox, p.stride.1, pw, p.kernel.1, p.dilation.1, iw),
        ) {
            (Some(y), Some(x)) => Some(((y * iw + x) * id) as i64),
            _ => None,
        };
        if let Some(m) = m {
            suffix = suffix.min(m);
        }
        if suffix != i64::MAX {
            let i_end = ((pos + 1) * od - 1) as i64;
            min_d = min_d.min(suffix - i_end);
        }
    }
    min_d
}

/// Analytic `O_s` lower bound for every input of `kind`.
pub fn os_analytic(
    kind: &OpKind,
    in_shapes: &[&Shape],
    out_shape: &Shape,
    dtype: DType,
) -> SafeOverlap {
    let t = dtype.size_bytes();
    let ob = out_shape.num_elements() * t;
    let per_input = match kind {
        // perfectly diagonal: O_s = OB_s (in-place is a special case, §III-A)
        OpKind::Unary(_) | OpKind::Reshape { .. } | OpKind::Binary(_) => {
            in_shapes.iter().map(|_| ob).collect()
        }
        // per-row reads precede per-row writes, rows ascend
        OpKind::Softmax => vec![ob],
        // accumulate per channel in a register, channels ascend
        OpKind::GlobalAvgPool => vec![ob],
        // the analytic family does not cover these; conservative zero.
        // Banded ops (§II-A splits) stay zero too: the split pair's
        // longer tensor scopes suppress DMO overlap on the banded
        // region (§II-A caveat) — the exact algorithmic engine still
        // measures whatever overlap genuinely survives.
        OpKind::FullyConnected { .. }
        | OpKind::MatMulAccum { .. }
        | OpKind::Concat
        | OpKind::Pad { .. }
        | OpKind::Band(_)
        | OpKind::ConcatRows => in_shapes.iter().map(|_| 0).collect(),
        OpKind::DepthwiseConv2D(_) | OpKind::Pool(_) => {
            let lb = linear_bound(kind, in_shapes, out_shape).expect("window op");
            vec![os_from_mind(lb.min_d(), in_shapes[0], out_shape, dtype)]
        }
        OpKind::Conv2D(p) => {
            // Our property-based audit found that Eq (13)'s intercept can
            // exceed the true envelope by up to O_d−1 elements on narrow
            // SAME-padded geometries (0.75 % of a 110k-config sweep; never
            // on dwconv/pool, never on any Table-III op). Cap with the
            // exact position-granular minD — O(O_h·O_w), still ~10³×
            // cheaper than the bottom-up method. See EXPERIMENTS.md
            // §Deviations.
            let lb = linear_bound(kind, in_shapes, out_shape).expect("window op");
            let exact_pos = conv_exact_min_d(p, in_shapes[0], out_shape);
            vec![os_from_mind(
                lb.min_d().min(exact_pos),
                in_shapes[0],
                out_shape,
                dtype,
            )]
        }
    };
    SafeOverlap { per_input }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Conv2DParams, DepthwiseParams, Padding};
    use crate::ops::infer_output;

    fn table1_op() -> (OpKind, Shape) {
        (
            OpKind::DepthwiseConv2D(DepthwiseParams {
                kernel: (3, 3),
                stride: (2, 2),
                dilation: (1, 1),
                padding: Padding::Same,
                depth_multiplier: 1,
                act: Activation::None,
            }),
            Shape::hwc(112, 112, 96),
        )
    }

    #[test]
    fn table1_coefficients_match_paper() {
        // §III-D works the Table-I op: a = 4, b = −10848.
        let (k, x) = table1_op();
        let out = infer_output(&k, &[&x]).unwrap();
        let lb = linear_bound(&k, &[&x], &out).unwrap();
        assert_eq!(lb.a, 4.0);
        assert_eq!(lb.b, -10848.0);
        assert_eq!(lb.i_c, 56 * 56 * 96);
    }

    #[test]
    fn table2_estimate_matches_paper() {
        // Analytic O_s of the Table-I op = 1,193,376 B (Table II),
        // 10,848 B (0.18 %) below the exact 1,204,224 B.
        let (k, x) = table1_op();
        let out = infer_output(&k, &[&x]).unwrap();
        let os = os_analytic(&k, &[&x], &out, DType::F32);
        assert_eq!(os.single(), 1_193_376);
    }

    #[test]
    fn eq11_never_exceeds_envelope_min() {
        let (k, x) = table1_op();
        let out = infer_output(&k, &[&x]).unwrap();
        let lb = linear_bound(&k, &[&x], &out).unwrap();
        assert!(lb.min_d_eq11() <= lb.min_d());
        // here they coincide (kink is the binding candidate)
        assert_eq!(lb.min_d_eq11(), lb.min_d());
    }

    #[test]
    fn conv_1x1_bound_matches_hand_derivation() {
        // §IV MobileNet case: 1x1 conv doubling channels, b = −(D_in − 1).
        let x = Shape::hwc(112, 112, 32);
        let k = OpKind::Conv2D(Conv2DParams {
            kernel: (1, 1),
            stride: (1, 1),
            dilation: (1, 1),
            padding: Padding::Same,
            out_channels: 64,
            act: Activation::None,
        });
        let out = infer_output(&k, &[&x]).unwrap();
        let lb = linear_bound(&k, &[&x], &out).unwrap();
        assert_eq!(lb.a, 0.5);
        assert_eq!(lb.b, -31.0);
    }

    #[test]
    fn elementwise_analytic_is_exact() {
        let s = Shape::hwc(5, 5, 4);
        let os = os_analytic(
            &OpKind::Unary(crate::ir::op::UnaryKind::Relu),
            &[&s],
            &s,
            DType::I8,
        );
        assert_eq!(os.single(), s.num_elements());
    }
}
