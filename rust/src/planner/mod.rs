//! Memory planning: serialisation → scopes → allocation (→ validation).
//!
//! Planning is a *pre-inference* step (§II-D: "this approach can only be
//! used as a pre-allocation method"): the overlap geometry is computed
//! once, offline, and then reused for every inference. The API mirrors
//! that lifecycle:
//!
//! * [`Planner`] — a builder-style session that configures the §IV
//!   search (strategy × direction × heuristic, with or without DMO) and
//!   produces a validated [`Plan`]. Long searches are observable through
//!   [`Planner::on_candidate`]. Beyond the paper's fixed eager/lazy
//!   serialisations, [`Strategy::Search`] (see [`search`]) enumerates
//!   the order axis itself with a memory-aware beam search.
//! * [`PlanArtifact`] — a versioned, JSON-serializable snapshot of a
//!   [`Plan`] that can be persisted with [`PlanArtifact::save`], shipped
//!   across processes, and revalidated against the target graph with
//!   [`PlanArtifact::to_plan`]. Deploy-time consumers (the CLI, the
//!   serving coordinator, benches) load artifacts instead of re-running
//!   the search.
//!
//! ```
//! use dmo::planner::Planner;
//!
//! # fn main() -> anyhow::Result<()> {
//! let graph = dmo::models::build("tiny")?;
//! let plan = Planner::for_graph(&graph).dmo(true).plan()?;
//! assert!(plan.peak() > 0);
//! # Ok(())
//! # }
//! ```

pub mod alloc;
pub mod artifact;
pub mod error;
pub mod order;
pub mod removal;
pub mod scope;
pub mod search;
pub mod split;

pub use alloc::{
    allocate, check, Allocation, AppliedOverlap, Direction, Heuristic, IncrementalCost, OsTable,
    DIRECTIONS, HEURISTICS,
};
pub use artifact::{graph_fingerprint, PlanArtifact};
pub use error::PlanError;
pub use order::{serialise, ExecOrder, Strategy, STRATEGIES};
pub use scope::{analyse, Scope, Scopes};
pub use search::{SearchStats, DEFAULT_BEAM, DEFAULT_BUDGET};

use crate::ir::graph::Graph;
use crate::ir::rewrite;
use crate::overlap::{Method, OsCache};
pub use crate::ir::rewrite::{Provenance, RewriteSpec, SplitSpec};
use std::sync::Arc;

/// How much graph rewriting a planning session may propose — the
/// budget [`Planner::rewrites`] sweeps through [`split::proposals`].
///
/// `max_parts` is the §II-A knob (how many row bands a split may use;
/// `0` disables rewriting entirely, `>= 2` enables it). `max_splits`
/// caps how many *independent* pair splits may compose in one plan
/// (`1` = the classic single split). `max_chain_depth` caps end-to-end
/// chain banding (`2` = pairs only; `>= 3` lets Pex-style chains
/// compete, amortising halo recompute across the whole chain).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RewriteBudget {
    /// Maximum row bands per split (`0` disables rewriting).
    pub max_parts: usize,
    /// Maximum independent pair splits composed in one plan.
    pub max_splits: usize,
    /// Maximum chain depth banded end-to-end (`2` = pairs only).
    pub max_chain_depth: usize,
}

impl RewriteBudget {
    /// No rewriting at all — the default session budget.
    pub const fn disabled() -> RewriteBudget {
        RewriteBudget {
            max_parts: 0,
            max_splits: 0,
            max_chain_depth: 0,
        }
    }

    /// The classic §II-A budget: single pair splits of up to
    /// `max_parts` bands, no multi-split, no chains — exactly what the
    /// old `allow_splits(max_parts)` knob meant.
    pub const fn pairs(max_parts: usize) -> RewriteBudget {
        RewriteBudget {
            max_parts,
            max_splits: 1,
            max_chain_depth: 2,
        }
    }

    /// Whether this budget proposes any rewrite at all.
    pub fn enabled(&self) -> bool {
        self.max_parts >= 2
    }

    /// Parse the CLI surface `pairs:N[,chains:D][,multi:K]` —
    /// e.g. `pairs:4`, `pairs:8,chains:3`, `pairs:4,chains:4,multi:3`.
    /// `pairs:N` is required; `chains` defaults to 2 (pairs only) and
    /// `multi` to 2 (one extra composed variant is cheap).
    pub fn parse(s: &str) -> Result<RewriteBudget, String> {
        let usage = "rewrites syntax: pairs:N[,chains:D][,multi:K]";
        let mut budget = RewriteBudget {
            max_parts: 0,
            max_splits: 2,
            max_chain_depth: 2,
        };
        let mut saw_pairs = false;
        for item in s.split(',') {
            let (key, val) = item.split_once(':').ok_or_else(|| usage.to_string())?;
            let n: usize = val
                .trim()
                .parse()
                .map_err(|_| format!("bad number `{val}` in --rewrites ({usage})"))?;
            match key.trim() {
                "pairs" => {
                    budget.max_parts = n;
                    saw_pairs = true;
                }
                "chains" => budget.max_chain_depth = n,
                "multi" => budget.max_splits = n,
                other => return Err(format!("unknown --rewrites key `{other}` ({usage})")),
            }
        }
        if !saw_pairs {
            return Err(usage.to_string());
        }
        Ok(budget)
    }
}

impl Default for RewriteBudget {
    fn default() -> RewriteBudget {
        RewriteBudget::disabled()
    }
}

/// The rewrite sequence a plan was computed on: a plan is no longer
/// just "an order + offsets over the input graph" — it may be "a
/// rewritten graph + order + offsets". Consumers resolve the graph the
/// plan's indices refer to with [`Plan::graph_for`].
#[derive(Debug, Clone)]
pub struct PlanRewrite {
    /// Applied rewrite specs, in application order (each indexes into
    /// the graph produced by the previous application). Recorded in
    /// [`PlanArtifact`] v4 so the rewrite can be re-derived elsewhere;
    /// v3 artifacts' single pair splits load into the same field.
    pub specs: Vec<RewriteSpec>,
    /// The rewritten (banded) graph the plan's order, offsets and `O_s`
    /// table refer to. Input/output tensor ids match the base graph.
    pub graph: Graph,
    /// Map from rewritten ops back to the base graph's ops.
    pub provenance: Provenance,
}

/// A complete, validated memory plan.
#[derive(Debug, Clone)]
pub struct Plan {
    pub order: ExecOrder,
    pub scopes: Scopes,
    pub alloc: Allocation,
    pub strategy: Strategy,
    pub heuristic: Heuristic,
    /// The `O_s` table the layout was checked against.
    pub os: OsTable,
    /// Present iff the winning order came from [`Strategy::Search`] —
    /// the run's counters, recorded in the artifact as provenance.
    pub search: Option<SearchStats>,
    /// Present iff the winning candidate planned a rewritten graph
    /// ([`Planner::rewrites`]); the plan's order/offsets then index
    /// [`PlanRewrite::graph`], not the session's input graph.
    pub rewrite: Option<PlanRewrite>,
}

impl Plan {
    /// Arena bytes required.
    pub fn peak(&self) -> usize {
        self.alloc.peak
    }

    /// The graph this plan's order/offsets actually describe: the split
    /// rewrite when one won, otherwise `base` (the graph the session
    /// planned).
    pub fn graph_for<'a>(&'a self, base: &'a Graph) -> &'a Graph {
        self.rewrite.as_ref().map(|r| &r.graph).unwrap_or(base)
    }
}

/// One evaluated point of the planner's search, reported to
/// [`Planner::on_candidate`] observers as the sweep runs.
#[derive(Debug, Clone)]
pub struct PlanCandidate {
    /// Serialisation strategy of this candidate.
    pub strategy: Strategy,
    /// Allocation heuristic of this candidate.
    pub heuristic: Heuristic,
    /// The rewrite sequence this candidate planned, if any
    /// (`None` = the unrewritten input graph).
    pub rewrite: Option<Vec<RewriteSpec>>,
    /// Arena peak this candidate achieved.
    pub peak: usize,
    /// Best (lowest) peak seen so far, including this candidate.
    pub best_peak: usize,
    /// 0-based index of this candidate in the sweep.
    pub index: usize,
    /// Total number of candidates the sweep will evaluate.
    pub total: usize,
}

/// Builder-style planning session.
///
/// Defaults reproduce the paper's baseline search: DMO off, exact
/// algorithmic `O_s` when DMO is enabled, and the full
/// strategy × direction × heuristic sweep of §IV. Every axis can be
/// narrowed:
///
/// ```
/// use dmo::overlap::Method;
/// use dmo::planner::{Direction, Heuristic, Planner, Strategy};
///
/// # fn main() -> anyhow::Result<()> {
/// let graph = dmo::models::build("tiny")?;
/// let plan = Planner::for_graph(&graph)
///     .dmo(true)
///     .method(Method::Analytic)
///     .strategies(&[Strategy::Lazy])
///     .directions(&[Direction::Backward])
///     .heuristics(&[Heuristic::Frontier(Direction::Backward), Heuristic::SizeDesc])
///     .plan()?;
/// assert_eq!(plan.strategy, Strategy::Lazy);
/// # Ok(())
/// # }
/// ```
pub struct Planner<'a> {
    graph: &'a Graph,
    dmo: bool,
    method: Method,
    strategies: Vec<Strategy>,
    heuristics: Vec<Heuristic>,
    directions: Vec<Direction>,
    jobs: usize,
    budget: RewriteBudget,
    variant_limit: usize,
    os_cache: Option<Arc<OsCache>>,
    on_candidate: Option<Box<dyn FnMut(&PlanCandidate) + 'a>>,
}

impl<'a> Planner<'a> {
    /// Start a planning session for `graph` with the default (baseline,
    /// full-sweep) configuration.
    pub fn for_graph(graph: &'a Graph) -> Planner<'a> {
        Planner {
            graph,
            dmo: false,
            method: Method::Algorithmic,
            strategies: STRATEGIES.to_vec(),
            heuristics: HEURISTICS.to_vec(),
            directions: DIRECTIONS.to_vec(),
            jobs: 0,
            budget: RewriteBudget::disabled(),
            variant_limit: 3,
            os_cache: None,
            on_candidate: None,
        }
    }

    /// Start a *safe-plan* session for `graph`: no overlap relaxation,
    /// no graph rewrites, plain eager/lazy ordering only. Every buffer
    /// gets disjoint placement, so a rogue store inside one op's planned
    /// extent cannot clobber another live tensor — the degradation
    /// target when a served model's watermark check trips and no
    /// last-known-good generation exists. Costs the full (un-overlapped)
    /// arena peak; the fleet flags requests served from it as degraded.
    pub fn safe_for_graph(graph: &'a Graph) -> Planner<'a> {
        Planner::for_graph(graph)
            .dmo(false)
            .strategies(&[Strategy::Eager, Strategy::Lazy])
            .rewrites(RewriteBudget::disabled())
    }

    /// Enable or disable diagonal memory optimisation (overlap
    /// relaxation, §II-D).
    pub fn dmo(mut self, enabled: bool) -> Self {
        self.dmo = enabled;
        self
    }

    /// Engine used for `O_s` when DMO is enabled.
    ///
    /// Default: the exact algorithmic method. The paper planned with the
    /// analytic lower bound (§II-D) and reports a <2 % penalty (§III-E);
    /// under our allocator the penalty can be structural — e.g. the
    /// stride-2 depthwise output of MobileNet nests inside its input only
    /// when `O_s` equals the exact output size, and the analytic bound's
    /// few-hundred-byte shortfall then costs a whole buffer of packing.
    /// `benches/os_methods.rs` quantifies this as an ablation; see
    /// EXPERIMENTS.md §Deviations.
    pub fn method(mut self, method: Method) -> Self {
        self.method = method;
        self
    }

    /// Restrict the serialisation strategies swept (§II-B).
    pub fn strategies(mut self, strategies: &[Strategy]) -> Self {
        self.strategies = strategies.to_vec();
        self
    }

    /// Plan with the memory-aware execution-order search alone —
    /// shorthand for `.strategies(&[Strategy::Search { beam, budget }])`.
    /// The search always scores the eager and lazy orders as seeds, so
    /// the result is never worse than the default two-strategy sweep.
    pub fn search(self, beam: usize, budget: usize) -> Self {
        self.strategies(&[Strategy::Search { beam, budget }])
    }

    /// Restrict the allocation heuristics swept (§IV).
    pub fn heuristics(mut self, heuristics: &[Heuristic]) -> Self {
        self.heuristics = heuristics.to_vec();
        self
    }

    /// Restrict the frontier seed directions swept (§IV). Non-frontier
    /// heuristics are unaffected; `Heuristic::Frontier(d)` candidates are
    /// kept only when `d` is listed here.
    pub fn directions(mut self, directions: &[Direction]) -> Self {
        self.directions = directions.to_vec();
        self
    }

    /// Allow graph rewriting as a planning action: the sweep
    /// additionally plans every spec sequence [`split::proposals`]
    /// derives from `budget` — single §II-A pair splits, multiple
    /// independent pair splits composed in one plan, and depth-≥3
    /// chains banded end-to-end via [`crate::ir::rewrite::apply`] —
    /// through the very same strategy × heuristic grid, including
    /// [`Strategy::Search`], so reordering and rewriting are searched
    /// jointly. A rewrite candidate wins only when its allocator-scored
    /// peak is *strictly* lower than every unrewritten candidate (and
    /// multi/chain variants only when they beat the single-pair ones
    /// swept before them); the winning plan then carries the spec
    /// sequence in [`Plan::rewrite`]. The default budget
    /// ([`RewriteBudget::disabled`]) proposes nothing.
    pub fn rewrites(mut self, budget: RewriteBudget) -> Self {
        self.budget = budget;
        self
    }

    /// Deprecated shim: the old §II-A knob. Use
    /// [`Planner::rewrites`]`(RewriteBudget::pairs(max_parts))` — or a
    /// wider [`RewriteBudget`] to let multi-splits and chains compete.
    pub fn allow_splits(self, max_parts: usize) -> Self {
        self.rewrites(RewriteBudget::pairs(max_parts))
    }

    /// Cap how many candidates *per proposal family* the sweep plans
    /// (default 3 — each rewrite variant re-runs the full strategy
    /// sweep on its rewritten graph, so this bounds planning time).
    /// Formerly named for pairs only; it now also caps the chain list.
    pub fn split_limit(mut self, limit: usize) -> Self {
        self.variant_limit = limit;
        self
    }

    /// Worker threads for the candidate sweep and the order search's
    /// per-level expansion. `0` (the default) means "all available
    /// cores". Every `jobs` value produces a byte-identical plan: work
    /// is distributed by index and reduced in index order, so
    /// parallelism changes wall time only — the winning candidate, the
    /// [`Planner::on_candidate`] sequence (always invoked on the
    /// calling thread, in sweep order) and the serialized
    /// [`PlanArtifact`] are all invariant.
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs;
        self
    }

    /// Memoise `O_s` computation through a shared [`OsCache`].
    ///
    /// Without a cache the session still dedupes repeated op signatures
    /// *within* its own table build; attaching one extends the reuse
    /// across sessions, threads and processes-lifetime consumers (the
    /// serving coordinator, the `dmo orders` report). See
    /// [`OsCache::process_shared`] for the easy process-wide instance.
    pub fn os_cache(mut self, cache: Arc<OsCache>) -> Self {
        self.os_cache = Some(cache);
        self
    }

    /// Observe every candidate the sweep evaluates — progress reporting
    /// for long searches (NasNet's ~600-op graph takes seconds per
    /// candidate).
    pub fn on_candidate<F: FnMut(&PlanCandidate) + 'a>(mut self, f: F) -> Self {
        self.on_candidate = Some(Box::new(f));
        self
    }

    /// Resolved worker count: the configured `.jobs(n)` or, at the
    /// default `0`, whatever parallelism the host offers.
    fn effective_jobs(&self) -> usize {
        if self.jobs > 0 {
            self.jobs
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }

    /// The heuristics that survive direction filtering, in sweep order.
    fn filtered_heuristics(&self) -> Result<Vec<Heuristic>, PlanError> {
        if self.strategies.is_empty() {
            return Err(PlanError::EmptySearchSpace { axis: "strategies" });
        }
        let heuristics: Vec<Heuristic> = self
            .heuristics
            .iter()
            .copied()
            .filter(|h| match h {
                Heuristic::Frontier(d) => self.directions.contains(d),
                _ => true,
            })
            .collect();
        if heuristics.is_empty() {
            return Err(PlanError::EmptySearchSpace { axis: "heuristics" });
        }
        Ok(heuristics)
    }

    /// Run the sweep and return the lowest-peak valid layout (§IV:
    /// "serialised using both an eager and lazy execution strategy with
    /// the lowest peak memory figure being taken"). With
    /// [`Strategy::Search`] in the strategy list, the §II-B order axis
    /// itself is searched: beam-enumerated candidate orders (plus the
    /// eager/lazy seeds) are each scored by the full allocator. With
    /// [`Planner::rewrites`], the graph's peak-defining rewrites (pair
    /// splits, multi-split compositions, chain bandings) are swept
    /// through the same grid — rewriting competes with reordering on
    /// equal (allocator-scored) terms.
    pub fn plan(mut self) -> Result<Plan, PlanError> {
        let graph = self.graph;
        if graph.tensors.is_empty() || graph.ops.is_empty() {
            return Err(PlanError::EmptyGraph {
                model: graph.name.clone(),
            });
        }
        let heuristics = self.filtered_heuristics()?;
        for s in &self.strategies {
            if let Strategy::Search { beam, .. } = s {
                if *beam == 0 {
                    return Err(PlanError::BadSearchConfig {
                        what: "beam width must be at least 1",
                    });
                }
            }
        }
        if self.budget.max_parts == 1 {
            return Err(PlanError::BadSearchConfig {
                what: "rewrite budget needs at least 2 parts (0 disables rewrites)",
            });
        }
        if self.budget.enabled() && (self.budget.max_splits < 1 || self.budget.max_chain_depth < 2)
        {
            return Err(PlanError::BadSearchConfig {
                what: "rewrite budget needs max_splits >= 1 and max_chain_depth >= 2",
            });
        }

        let jobs = self.effective_jobs();

        let mut plan_span = crate::obs::trace::span(&format!("plan:{}", graph.name), "planner");
        if plan_span.is_active() {
            plan_span.arg("ops", crate::util::json::num(graph.ops.len()));
            plan_span.arg("jobs", crate::util::json::num(jobs));
        }

        // O_s depends only on op geometry, never on serialisation order —
        // build each variant's table once for the whole sweep (perf
        // pass, §Perf), always through a cache: the attached one when
        // the session has it, else a session-local one, so split
        // variants (which share almost every signature with the base
        // graph) collapse to analysing the banded ops only.
        let session_cache;
        let cache_ref: &OsCache = match &self.os_cache {
            Some(cache) => cache,
            None => {
                session_cache = OsCache::new();
                &session_cache
            }
        };
        let build_os = |g: &Graph| -> OsTable {
            let mut sp = crate::obs::trace::span("os_table", "planner");
            if sp.is_active() {
                sp.arg("ops", crate::util::json::num(g.ops.len()));
            }
            if self.dmo {
                OsTable::build_cached(g, self.method, cache_ref)
            } else {
                OsTable::disabled(g)
            }
        };

        // Candidate orders per strategy: one Kahn pass for eager/lazy,
        // a beam-search batch (seeds included) for search.
        struct Cand {
            strategy: Strategy,
            order: ExecOrder,
            scopes: Scopes,
            stats: Option<SearchStats>,
        }
        let make_cands = |g: &Graph, os: &OsTable| -> Vec<Cand> {
            let mut cands: Vec<Cand> = Vec::new();
            for &strat in &self.strategies {
                match strat {
                    Strategy::Eager | Strategy::Lazy => {
                        let order = serialise(g, strat);
                        let scopes = analyse(g, &order);
                        cands.push(Cand {
                            strategy: strat,
                            order,
                            scopes,
                            stats: None,
                        });
                    }
                    Strategy::Search { beam, budget } => {
                        let outcome = search::search_with(g, os, beam, budget, jobs);
                        for order in outcome.orders {
                            let scopes = analyse(g, &order);
                            cands.push(Cand {
                                strategy: strat,
                                order,
                                scopes,
                                stats: Some(outcome.stats),
                            });
                        }
                    }
                }
            }
            cands
        };

        // One sweep *variant* per planned graph: the input graph first
        // (so an unrewritten candidate wins all ties), then each
        // proposed rewrite — single pairs before multi-splits before
        // chains, so under the strict-< argmin a wider rewrite must
        // *beat* every narrower one. Each variant re-runs the full
        // strategy sweep — a rewrite changes the graph, so its best
        // order must be searched anew rather than inherited.
        struct Variant {
            rewrite: Option<(Vec<RewriteSpec>, Graph, Provenance)>,
            os: OsTable,
            cands: Vec<Cand>,
        }
        let mut variants: Vec<Variant> = Vec::new();
        {
            let os = build_os(graph);
            let cands = make_cands(graph, &os);
            variants.push(Variant {
                rewrite: None,
                os,
                cands,
            });
        }
        if self.budget.enabled() {
            for specs in split::proposals(graph, &self.budget, self.variant_limit) {
                let Ok((rg, prov)) = rewrite::apply(graph, &specs) else {
                    continue; // proposals() pre-checked; stay defensive
                };
                let os = build_os(&rg);
                let cands = make_cands(&rg, &os);
                variants.push(Variant {
                    rewrite: Some((specs, rg, prov)),
                    os,
                    cands,
                });
            }
        }

        // The sweep grid, flattened in sweep order. Each cell's
        // allocation is independent, so on big graphs cells are
        // precomputed on `jobs` workers; the winner selection and the
        // `on_candidate` stream below then reduce strictly in index
        // order, which makes parallel and serial sweeps byte-identical
        // (same argmin under ties, same callback sequence, on the
        // calling thread). Small graphs allocate lazily inside the
        // reduction instead — no thread spawns for microsecond sweeps,
        // and `--verbose` progress streams per candidate as it always
        // did. The gate depends only on the graph, never on `jobs`.
        let mut cells: Vec<(usize, usize, Heuristic)> = Vec::new();
        for (vi, v) in variants.iter().enumerate() {
            for ci in 0..v.cands.len() {
                for &h in &heuristics {
                    cells.push((vi, ci, h));
                }
            }
        }
        fn vgraph<'a>(variants: &'a [Variant], base: &'a Graph, vi: usize) -> &'a Graph {
            variants[vi]
                .rewrite
                .as_ref()
                .map(|(_, g, _)| g)
                .unwrap_or(base)
        }
        let parallel = jobs > 1 && cells.len() >= 2 && graph.ops.len() >= 16;
        let mut precomputed: Vec<Option<Allocation>> = Vec::new();
        if parallel {
            precomputed = crate::util::par::par_map_indexed(cells.len(), jobs, |i| {
                let (vi, ci, h) = cells[i];
                let mut sp = crate::obs::trace::span("cell", "planner");
                if sp.is_active() {
                    sp.arg("index", crate::util::json::num(i));
                    sp.arg("variant", crate::util::json::num(vi));
                    sp.arg("candidate", crate::util::json::num(ci));
                }
                allocate(
                    vgraph(&variants, graph, vi),
                    &variants[vi].cands[ci].scopes,
                    &variants[vi].os,
                    h,
                )
            })
            .into_iter()
            .map(Some)
            .collect();
        }

        // track the winner by cell index and keep only its Allocation;
        // the Plan (graph/scope/table clones) is built once after the
        // sweep instead of per improvement
        let mut best: Option<(usize, usize, Heuristic, Allocation)> = None;
        let total = cells.len();
        for (index, &(vi, ci, h)) in cells.iter().enumerate() {
            let v = &variants[vi];
            let cand = &v.cands[ci];
            let a = match precomputed.get_mut(index) {
                Some(slot) => slot.take().expect("every sweep cell allocated"),
                None => {
                    let mut sp = crate::obs::trace::span("cell", "planner");
                    if sp.is_active() {
                        sp.arg("index", crate::util::json::num(index));
                        sp.arg("variant", crate::util::json::num(vi));
                        sp.arg("candidate", crate::util::json::num(ci));
                    }
                    allocate(vgraph(&variants, graph, vi), &cand.scopes, &v.os, h)
                }
            };
            let peak = a.peak;
            // strict `<`: a rewrite must *beat* the best unrewritten
            // layout to win (base cells come first in sweep order)
            let improved = best.as_ref().map_or(true, |(_, _, _, ba)| peak < ba.peak);
            if improved {
                best = Some((vi, ci, h, a));
            }
            if let Some(cb) = self.on_candidate.as_mut() {
                cb(&PlanCandidate {
                    strategy: cand.strategy,
                    heuristic: h,
                    rewrite: v.rewrite.as_ref().map(|(specs, _, _)| specs.clone()),
                    peak,
                    best_peak: best.as_ref().map(|(_, _, _, ba)| ba.peak).unwrap_or(peak),
                    index,
                    total,
                });
            }
        }

        let (vi, ci, heuristic, alloc) = best.ok_or_else(|| PlanError::EmptyGraph {
            model: graph.name.clone(),
        })?;
        let v = &variants[vi];
        let cand = &v.cands[ci];
        let plan = Plan {
            order: cand.order.clone(),
            scopes: cand.scopes.clone(),
            alloc,
            strategy: cand.strategy,
            heuristic,
            os: v.os.clone(),
            search: cand.stats,
            rewrite: v.rewrite.as_ref().map(|(specs, g, prov)| PlanRewrite {
                specs: specs.clone(),
                graph: g.clone(),
                provenance: prov.clone(),
            }),
        };
        check(plan.graph_for(graph), &plan.scopes, &plan.os, &plan.alloc)
            .map_err(|e| PlanError::InvalidLayout(format!("{e:#}")))?;
        if plan_span.is_active() {
            let cs = cache_ref.stats();
            plan_span.arg("cells", crate::util::json::num(total));
            plan_span.arg("peak", crate::util::json::num(plan.peak()));
            plan_span.arg("os_cache_hits", crate::util::json::num(cs.hits));
            plan_span.arg("os_cache_misses", crate::util::json::num(cs.misses));
        }
        drop(plan_span);
        Ok(plan)
    }
}

/// Original-vs-DMO comparison for one graph — one row of Table III.
#[derive(Debug, Clone)]
pub struct SavingRow {
    pub model: String,
    pub original: usize,
    pub optimised: usize,
}

impl SavingRow {
    pub fn saving_pct(&self) -> f64 {
        if self.original == 0 {
            return 0.0;
        }
        100.0 * (self.original - self.optimised) as f64 / self.original as f64
    }
}

/// A graph planned both ways (baseline and DMO) with the full sweep —
/// the unit the reports, the MCU fit catalog and the serving stack
/// consume, so each of them works from precomputed [`Plan`]s instead of
/// re-running the search.
#[derive(Debug)]
pub struct PlannedModel {
    pub graph: Graph,
    pub baseline: Plan,
    pub dmo: Plan,
    /// Best rewrite-enabled plan (DMO on, [`Planner::rewrites`]),
    /// recorded by [`PlannedModel::new_rewrites`] only when a rewrite
    /// (pair split, multi-split or chain) strictly beat the unsplit
    /// DMO plan.
    pub split: Option<Plan>,
}

impl PlannedModel {
    /// Plan `graph` with and without DMO (full §IV sweep each).
    pub fn new(graph: Graph) -> Result<PlannedModel, PlanError> {
        Self::new_with(graph, 0, None)
    }

    /// [`PlannedModel::new`] with an explicit worker count (`0` = all
    /// cores) and an optional shared `O_s` cache — the serving
    /// coordinator passes [`OsCache::process_shared`] here so repeated
    /// startups in one process never re-derive a table.
    pub fn new_with(
        graph: Graph,
        jobs: usize,
        cache: Option<Arc<OsCache>>,
    ) -> Result<PlannedModel, PlanError> {
        let baseline = Planner::for_graph(&graph).jobs(jobs).plan()?;
        let mut session = Planner::for_graph(&graph).dmo(true).jobs(jobs);
        if let Some(cache) = cache {
            session = session.os_cache(cache);
        }
        let dmo = session.plan()?;
        Ok(PlannedModel {
            graph,
            baseline,
            dmo,
            split: None,
        })
    }

    /// [`PlannedModel::new_with`] plus a third, rewrite-enabled DMO
    /// session (`rewrites(budget)`); `split` is populated iff a rewrite
    /// won it — i.e. some spec sequence beat every unrewritten layout.
    pub fn new_rewrites(
        graph: Graph,
        budget: RewriteBudget,
        jobs: usize,
        cache: Option<Arc<OsCache>>,
    ) -> Result<PlannedModel, PlanError> {
        let mut pm = Self::new_with(graph, jobs, cache.clone())?;
        // rewriting disabled, or nothing to propose ⇒ the rewrite
        // session would rebuild the exact unrewritten sweep only to
        // discard it (or, for max_parts == 1, error out) — skip it
        if !budget.enabled() || split::proposals(&pm.graph, &budget, 1).is_empty() {
            return Ok(pm);
        }
        let mut session = Planner::for_graph(&pm.graph)
            .dmo(true)
            .jobs(jobs)
            .rewrites(budget);
        if let Some(cache) = cache {
            session = session.os_cache(cache);
        }
        let split = session.plan()?;
        if split.rewrite.is_some() && split.peak() < pm.dmo.peak() {
            pm.split = Some(split);
        }
        Ok(pm)
    }

    /// Deprecated shim: [`PlannedModel::new_rewrites`] with the classic
    /// single-pair budget ([`RewriteBudget::pairs`]).
    pub fn new_split(
        graph: Graph,
        max_parts: usize,
        jobs: usize,
        cache: Option<Arc<OsCache>>,
    ) -> Result<PlannedModel, PlanError> {
        Self::new_rewrites(graph, RewriteBudget::pairs(max_parts), jobs, cache)
    }

    /// The Table-III row for this model.
    pub fn row(&self) -> SavingRow {
        SavingRow {
            model: self.graph.name.clone(),
            original: self.baseline.peak(),
            optimised: self.dmo.peak().min(self.baseline.peak()),
        }
    }

    /// Peak of the best split plan, when splitting won.
    pub fn split_peak(&self) -> Option<usize> {
        self.split.as_ref().map(|p| p.peak())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Padding};
    use crate::ir::{DType, GraphBuilder, Shape};

    /// The motivating example from §I: MobileNet v1 0.25 128 (8-bit)
    /// head — conv s2 to 8ch, dw s1, 1x1 conv to 16ch. Peak pair is
    /// dw_out (32 KB) + pw_out (64 KB) = 96 KB; DMO overlaps them to
    /// ~64 KB.
    fn mobilenet_head_i8() -> Graph {
        let mut b = GraphBuilder::new("mnv1-head", DType::I8);
        let x = b.input(Shape::hwc(128, 128, 3));
        let c1 = b.conv2d(x, 8, (3, 3), (2, 2), Padding::Same, Activation::Relu6);
        let d1 = b.dwconv2d(c1, (3, 3), (1, 1), Padding::Same, Activation::Relu6);
        let p1 = b.conv2d(d1, 16, (1, 1), (1, 1), Padding::Same, Activation::Relu6);
        b.finish(&[p1])
    }

    #[test]
    fn paper_intro_example_96kb_to_64kb() {
        let pm = PlannedModel::new(mobilenet_head_i8()).unwrap();
        let row = pm.row();
        assert_eq!(row.original, 96 * 1024, "original peak must be 96 KB");
        // optimised: 64 KB + a few bytes (O_s is IB minus (D_in−1) elems)
        assert!(row.optimised >= 64 * 1024);
        assert!(row.optimised < 64 * 1024 + 64, "got {}", row.optimised);
        // paper reports 33.1 % for the full model; the head alone matches
        assert!((row.saving_pct() - 33.3).abs() < 0.5, "saving {}", row.saving_pct());
    }

    #[test]
    fn dmo_never_worse_than_baseline() {
        let g = mobilenet_head_i8();
        let base = Planner::for_graph(&g).plan().unwrap();
        let dmo = Planner::for_graph(&g).dmo(true).plan().unwrap();
        assert!(dmo.peak() <= base.peak());
    }

    #[test]
    fn plans_are_checkable() {
        let g = mobilenet_head_i8();
        for dmo in [false, true] {
            let p = Planner::for_graph(&g).dmo(dmo).plan().unwrap();
            check(&g, &p.scopes, &p.os, &p.alloc).unwrap();
        }
    }

    #[test]
    fn narrowed_search_space_is_respected() {
        let g = mobilenet_head_i8();
        let p = Planner::for_graph(&g)
            .dmo(true)
            .strategies(&[Strategy::Lazy])
            .heuristics(&[Heuristic::SizeDesc])
            .plan()
            .unwrap();
        assert_eq!(p.strategy, Strategy::Lazy);
        assert_eq!(p.heuristic, Heuristic::SizeDesc);
    }

    #[test]
    fn direction_filter_applies_to_frontier_heuristics() {
        let g = mobilenet_head_i8();
        let mut seen = Vec::new();
        let p = Planner::for_graph(&g)
            .heuristics(&[
                Heuristic::Frontier(Direction::Forward),
                Heuristic::Frontier(Direction::Backward),
            ])
            .directions(&[Direction::Backward])
            .on_candidate(|c| seen.push(c.heuristic))
            .plan()
            .unwrap();
        assert_eq!(p.heuristic, Heuristic::Frontier(Direction::Backward));
        assert!(seen
            .iter()
            .all(|h| *h == Heuristic::Frontier(Direction::Backward)));
    }

    #[test]
    fn empty_search_space_is_an_error() {
        let g = mobilenet_head_i8();
        assert_eq!(
            Planner::for_graph(&g).strategies(&[]).plan().unwrap_err(),
            PlanError::EmptySearchSpace { axis: "strategies" }
        );
        assert_eq!(
            Planner::for_graph(&g).heuristics(&[]).plan().unwrap_err(),
            PlanError::EmptySearchSpace { axis: "heuristics" }
        );
        // all-frontier heuristics + no directions leaves nothing either
        assert_eq!(
            Planner::for_graph(&g)
                .heuristics(&[Heuristic::Frontier(Direction::Forward)])
                .directions(&[])
                .plan()
                .unwrap_err(),
            PlanError::EmptySearchSpace { axis: "heuristics" }
        );
    }

    #[test]
    fn candidate_callback_sees_whole_sweep() {
        let g = mobilenet_head_i8();
        let mut count = 0usize;
        let mut best = usize::MAX;
        let plan = Planner::for_graph(&g)
            .dmo(true)
            .on_candidate(|c| {
                count += 1;
                assert_eq!(c.total, STRATEGIES.len() * HEURISTICS.len());
                assert!(c.best_peak <= c.peak);
                best = c.best_peak;
            })
            .plan()
            .unwrap();
        assert_eq!(count, STRATEGIES.len() * HEURISTICS.len());
        assert_eq!(best, plan.peak(), "final best_peak must equal the plan's");
    }

    #[test]
    fn search_strategy_never_worse_and_records_stats() {
        let g = mobilenet_head_i8();
        let sweep = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let searched = Planner::for_graph(&g)
            .dmo(true)
            .search(DEFAULT_BEAM, DEFAULT_BUDGET)
            .plan()
            .unwrap();
        assert!(searched.peak() <= sweep.peak());
        assert_eq!(searched.strategy.name(), "search");
        let stats = searched.search.expect("search wins must carry stats");
        assert_eq!(stats.beam, DEFAULT_BEAM);
        assert!(stats.expanded > 0);
        // the head is a chain: every candidate dedupes to the one order
        assert!(stats.orders_scored >= 1);
        // eager/lazy wins never carry search stats
        assert!(sweep.search.is_none());
    }

    #[test]
    fn search_callback_covers_every_scored_order() {
        let g = mobilenet_head_i8();
        let mut count = 0usize;
        let mut total = 0usize;
        let plan = Planner::for_graph(&g)
            .dmo(true)
            .search(2, 1_000)
            .heuristics(&[Heuristic::SizeDesc])
            .on_candidate(|c| {
                count += 1;
                total = c.total;
            })
            .plan()
            .unwrap();
        assert_eq!(count, total);
        assert_eq!(count, plan.search.unwrap().orders_scored);
    }

    #[test]
    fn job_count_never_changes_the_plan() {
        let g = mobilenet_head_i8();
        let artifact = |jobs: usize| {
            let plan = Planner::for_graph(&g).dmo(true).jobs(jobs).plan().unwrap();
            PlanArtifact::from_plan(&g, &plan).to_json().to_string()
        };
        let serial = artifact(1);
        for jobs in [2usize, 4, 8] {
            assert_eq!(serial, artifact(jobs), "jobs {jobs} diverged from serial");
        }
    }

    #[test]
    fn callback_order_is_identical_across_job_counts() {
        let g = mobilenet_head_i8();
        let seen = |jobs: usize| {
            let mut events: Vec<(usize, usize, usize)> = Vec::new();
            Planner::for_graph(&g)
                .dmo(true)
                .jobs(jobs)
                .on_candidate(|c| events.push((c.index, c.peak, c.best_peak)))
                .plan()
                .unwrap();
            events
        };
        assert_eq!(seen(1), seen(4), "candidate stream must not depend on jobs");
    }

    #[test]
    fn shared_cache_is_reused_across_sessions() {
        let g = mobilenet_head_i8();
        let cache = std::sync::Arc::new(crate::overlap::OsCache::new());
        let p1 = Planner::for_graph(&g)
            .dmo(true)
            .os_cache(cache.clone())
            .plan()
            .unwrap();
        let first = cache.stats();
        assert!(first.misses > 0, "first session must populate the cache");
        let p2 = Planner::for_graph(&g)
            .dmo(true)
            .os_cache(cache.clone())
            .plan()
            .unwrap();
        let second = cache.stats();
        assert_eq!(second.misses, first.misses, "second session must be all hits");
        assert!(second.hits > first.hits);
        assert_eq!(p1.peak(), p2.peak());
        assert_eq!(p1.os.per_op, p2.os.per_op, "cached table must equal the recomputed one");
        // and a cached build equals an uncached build outright
        let uncached = OsTable::build(&g, crate::overlap::Method::Algorithmic);
        assert_eq!(p1.os.per_op, uncached.per_op);
    }

    /// The §II-A pair: conv 1x1 doubling bytes into a stride-2 dwconv —
    /// the intermediate dominates and splitting must win.
    fn split_pair_i8() -> Graph {
        let mut b = GraphBuilder::new("splitwin", DType::I8);
        let x = b.input(Shape::hwc(64, 64, 8));
        let c = b.conv2d(x, 16, (1, 1), (1, 1), Padding::Same, Activation::None);
        let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None);
        b.finish(&[d])
    }

    #[test]
    fn split_rewrite_wins_the_paper_pair_and_executes_bit_identically() {
        let g = split_pair_i8();
        let unsplit = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let split = Planner::for_graph(&g).dmo(true).allow_splits(4).plan().unwrap();
        assert!(
            split.peak() < unsplit.peak(),
            "split {} must beat unsplit {}",
            split.peak(),
            unsplit.peak()
        );
        let rw = split.rewrite.as_ref().expect("split rewrite must be recorded");
        assert_eq!(rw.specs.len(), 1);
        assert!(matches!(rw.specs[0], RewriteSpec::PairSplit(_)));
        assert_eq!(split.order.0.len(), rw.graph.ops.len());
        assert_eq!(split.alloc.offsets.len(), rw.graph.tensors.len());
        // the correctness anchor: banded execution in the planned
        // (overlapping) arena is bit-identical to the unsplit reference
        crate::interp::validate_plan(&g, &split, 11).unwrap();
    }

    #[test]
    fn splits_never_hurt_and_lose_ties_to_unsplit_plans() {
        // on the DMO-friendly mobilenet head, splitting cannot beat the
        // overlapped plan — the session must return the unsplit winner
        let g = mobilenet_head_i8();
        let plain = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let with = Planner::for_graph(&g).dmo(true).allow_splits(4).plan().unwrap();
        assert!(with.peak() <= plain.peak());
        if with.peak() == plain.peak() {
            assert!(with.rewrite.is_none(), "ties must keep the unsplit plan");
        }
    }

    #[test]
    fn split_sessions_report_split_candidates() {
        let g = split_pair_i8();
        let mut split_cells = 0usize;
        let mut plain_cells = 0usize;
        let mut total = 0usize;
        let plan = Planner::for_graph(&g)
            .dmo(true)
            .allow_splits(4)
            .on_candidate(|c| {
                if c.rewrite.is_some() {
                    split_cells += 1;
                } else {
                    plain_cells += 1;
                }
                total = c.total;
            })
            .plan()
            .unwrap();
        assert!(plain_cells > 0 && split_cells > 0);
        assert_eq!(total, plain_cells + split_cells, "total is fixed up front");
        assert!(plan.rewrite.is_some());
    }

    #[test]
    fn one_part_split_config_is_an_error() {
        let g = split_pair_i8();
        assert_eq!(
            Planner::for_graph(&g).allow_splits(1).plan().unwrap_err(),
            PlanError::BadSearchConfig {
                what: "rewrite budget needs at least 2 parts (0 disables rewrites)",
            }
        );
        // an enabled budget must have a sane multi/chain range too
        assert_eq!(
            Planner::for_graph(&g)
                .rewrites(RewriteBudget {
                    max_parts: 4,
                    max_splits: 0,
                    max_chain_depth: 2,
                })
                .plan()
                .unwrap_err(),
            PlanError::BadSearchConfig {
                what: "rewrite budget needs max_splits >= 1 and max_chain_depth >= 2",
            }
        );
    }

    #[test]
    fn rewrite_budget_parses_the_cli_syntax() {
        assert_eq!(
            RewriteBudget::parse("pairs:4").unwrap(),
            RewriteBudget {
                max_parts: 4,
                max_splits: 2,
                max_chain_depth: 2,
            }
        );
        assert_eq!(
            RewriteBudget::parse("pairs:8,chains:3").unwrap(),
            RewriteBudget {
                max_parts: 8,
                max_splits: 2,
                max_chain_depth: 3,
            }
        );
        assert_eq!(
            RewriteBudget::parse("pairs:4,chains:4,multi:3").unwrap(),
            RewriteBudget {
                max_parts: 4,
                max_splits: 3,
                max_chain_depth: 4,
            }
        );
        assert!(RewriteBudget::parse("chains:3").is_err(), "pairs is required");
        assert!(RewriteBudget::parse("pairs:x").is_err());
        assert!(RewriteBudget::parse("bogus:1").is_err());
        assert!(RewriteBudget::parse("").is_err());
        assert!(!RewriteBudget::parse("pairs:0").unwrap().enabled());
        assert!(RewriteBudget::pairs(4).enabled());
        assert!(!RewriteBudget::disabled().enabled());
    }

    /// Hourglass shape: tiny input (2 KB), two fat 16 KB intermediates,
    /// tiny output. Any unsplit or single-pair-split plan must
    /// materialise at least one fat intermediate in full (a hard
    /// ≥ 16 KB floor — a tensor's buffer exists in the arena at the
    /// step that produces it), while the depth-3 chain keeps only row
    /// bands of each level live. This is the shape where chains
    /// strictly beat every pair split.
    fn hourglass_i8() -> Graph {
        let mut b = GraphBuilder::new("hourglass", DType::I8);
        let x = b.input(Shape::hwc(32, 32, 2));
        let c = b.conv2d(x, 16, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let d = b.dwconv2d(c, (3, 3), (1, 1), Padding::Same, Activation::None);
        let p = b.maxpool(d, (4, 4), (4, 4), Padding::Valid);
        b.finish(&[p])
    }

    #[test]
    fn chain_budget_strictly_beats_every_pair_split_on_hourglass() {
        let g = hourglass_i8();
        let pairs_only = Planner::for_graph(&g)
            .dmo(true)
            .rewrites(RewriteBudget::pairs(4))
            .plan()
            .unwrap();
        let with_chains = Planner::for_graph(&g)
            .dmo(true)
            .rewrites(RewriteBudget {
                max_parts: 4,
                max_splits: 1,
                max_chain_depth: 3,
            })
            .plan()
            .unwrap();
        // the chain sweep is a superset of the pair sweep, so ≤ holds
        // by construction; on this shape the win must be strict
        assert!(
            with_chains.peak() < pairs_only.peak(),
            "chain {} must strictly beat pair best {}",
            with_chains.peak(),
            pairs_only.peak()
        );
        // no pair plan can get below the fat-intermediate floor
        assert!(pairs_only.peak() >= 16 * 1024);
        assert!(with_chains.peak() < 16 * 1024);
        let rw = with_chains.rewrite.as_ref().expect("chain must be recorded");
        assert_eq!(rw.specs.len(), 1);
        assert!(matches!(rw.specs[0], RewriteSpec::ChainSplit { .. }));
        assert!(rw.specs[0].depth() >= 3);
        // correctness anchor: chain-banded execution in the planned
        // arena is bit-identical to the unsplit reference
        crate::interp::validate_plan(&g, &with_chains, 17).unwrap();
    }

    /// Two §II-A regions separated by a bottleneck: one split rescues
    /// one region but leaves the other's fused peak standing; only the
    /// composed multi-split lowers both.
    fn double_hump_i8() -> Graph {
        let mut b = GraphBuilder::new("double-hump", DType::I8);
        let x = b.input(Shape::hwc(64, 64, 4)); // 16 KB
        let c1 = b.conv2d(x, 16, (1, 1), (1, 1), Padding::Same, Activation::None); // 64 KB
        let d1 = b.dwconv2d(c1, (3, 3), (2, 2), Padding::Same, Activation::None); // 16 KB
        let sq = b.conv2d(d1, 4, (1, 1), (1, 1), Padding::Same, Activation::Relu); // 4 KB
        let c2 = b.conv2d(sq, 64, (1, 1), (1, 1), Padding::Same, Activation::None); // 64 KB
        let d2 = b.dwconv2d(c2, (3, 3), (2, 2), Padding::Same, Activation::None); // 16 KB
        b.finish(&[d2])
    }

    #[test]
    fn multi_split_budget_beats_any_single_pair() {
        let g = double_hump_i8();
        let single = Planner::for_graph(&g)
            .dmo(true)
            .rewrites(RewriteBudget::pairs(4))
            .plan()
            .unwrap();
        let multi = Planner::for_graph(&g)
            .dmo(true)
            .rewrites(RewriteBudget {
                max_parts: 4,
                max_splits: 2,
                max_chain_depth: 2,
            })
            .plan()
            .unwrap();
        assert!(
            multi.peak() < single.peak(),
            "multi {} must strictly beat single best {}",
            multi.peak(),
            single.peak()
        );
        let rw = multi.rewrite.as_ref().expect("multi-split must be recorded");
        assert_eq!(rw.specs.len(), 2, "two independent pair splits compose");
        // recorded in application order: descending op indices
        assert!(rw.specs[0].op_indices()[0] > rw.specs[1].op_indices()[0]);
        crate::interp::validate_plan(&g, &multi, 23).unwrap();
    }

    #[test]
    fn search_and_splits_compose() {
        let g = split_pair_i8();
        let plan = Planner::for_graph(&g)
            .dmo(true)
            .search(4, 2_000)
            .allow_splits(4)
            .plan()
            .unwrap();
        // joint search: the winner is a searched order over a split graph
        assert!(plan.rewrite.is_some());
        assert_eq!(plan.strategy.name(), "search");
        assert!(plan.search.is_some());
        crate::interp::validate_plan(&g, &plan, 5).unwrap();
    }

    #[test]
    fn planned_model_records_split_only_when_it_wins() {
        let pm = PlannedModel::new_split(split_pair_i8(), 4, 0, None).unwrap();
        let split = pm.split.as_ref().expect("split must win here");
        assert!(split.peak() < pm.dmo.peak());
        assert_eq!(pm.split_peak(), Some(split.peak()));
        let pm2 = PlannedModel::new_split(mobilenet_head_i8(), 4, 0, None).unwrap();
        if let Some(s) = &pm2.split {
            assert!(s.peak() < pm2.dmo.peak());
        }
    }

    #[test]
    fn zero_beam_is_a_config_error() {
        let g = mobilenet_head_i8();
        assert_eq!(
            Planner::for_graph(&g).search(0, 100).plan().unwrap_err(),
            PlanError::BadSearchConfig {
                what: "beam width must be at least 1",
            }
        );
    }

    #[test]
    fn empty_graph_is_an_error() {
        let g = Graph {
            name: "empty".into(),
            tensors: Vec::new(),
            ops: Vec::new(),
            inputs: Vec::new(),
            outputs: Vec::new(),
        };
        assert!(matches!(
            Planner::for_graph(&g).plan(),
            Err(PlanError::EmptyGraph { .. })
        ));
    }
}
