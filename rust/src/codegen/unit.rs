//! Translation-unit assembly: lower a validated plan to `.c` + `.h`.
//!
//! The emitted unit is the shape TFMin produced (§I): every tensor at a
//! fixed pre-computed arena offset, weights in flash-resident `const`
//! arrays, one entry point. Emission is byte-deterministic for a given
//! (graph, plan, options) triple — the golden-file tests rely on it.

use super::fmt::{f32_literal, sanitize_ident, wrap_values};
use super::kernels::{
    act_id, fast_fn_name, fast_source, kernels_used, load_store_source, pool_kind_id,
    unary_kind_id, ACT_HELPER, REQUANT_HELPER, SPLITMIX,
};
use super::tune::{class_of, LoopOrder, TuneTable, Variant};
use super::FlashFootprint;
use crate::ir::graph::{Graph, OpNode, TensorId};
use crate::ir::op::{pad_before, OpKind};
use crate::ir::DType;
use crate::ops::exec::gen_weights;
use crate::planner::{graph_fingerprint, Plan, PlanArtifact};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Configuration for one emission.
#[derive(Debug, Clone)]
pub struct EmitOptions {
    /// File stem: the unit becomes `<stem>.c` / `<stem>.h` and the
    /// header guard is derived from it. Sanitised to a C identifier.
    pub stem: String,
    /// Seed for the synthetic weight stream (and the harness inputs) —
    /// must match the seed later passed to the interpreter when
    /// comparing outputs.
    pub seed: u64,
    /// Models whose total weight element count exceeds this are emitted
    /// with a SplitMix64 weight generator instead of literal `const`
    /// arrays (a 50 M-element initialiser list is not a reviewable or
    /// compilable artifact). The stream is identical either way.
    pub weight_embed_limit: usize,
    /// Emit fast typed-pointer kernel variants where the per-site
    /// legality gates allow it (`true` by default). `false` forces the
    /// byte-addressed generic kernels everywhere — the autotuner's
    /// baseline and a debugging escape hatch.
    pub fast: bool,
    /// Per-op-class variant choices from the autotuner
    /// ([`super::tune::tune`]). `None` uses the safe default: the
    /// reference-order fast loop wherever legal.
    pub tuning: Option<TuneTable>,
}

impl EmitOptions {
    /// Defaults: seed 42, embed weights up to one million elements,
    /// fast kernels on, no tuning table.
    pub fn new(stem: &str) -> EmitOptions {
        EmitOptions {
            stem: sanitize_ident(stem),
            seed: 42,
            weight_embed_limit: 1_000_000,
            fast: true,
            tuning: None,
        }
    }

    /// Override the synthetic-weight seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Override the embed-vs-generate threshold (elements).
    pub fn weight_embed_limit(mut self, elems: usize) -> Self {
        self.weight_embed_limit = elems;
        self
    }

    /// Enable/disable fast kernel variants.
    pub fn fast(mut self, fast: bool) -> Self {
        self.fast = fast;
        self
    }

    /// Use autotuned per-class variant choices.
    pub fn tuning(mut self, table: TuneTable) -> Self {
        self.tuning = Some(table);
        self
    }
}

/// An emitted C unit plus the numbers reports care about.
#[derive(Debug, Clone)]
pub struct CUnit {
    /// File stem (`<stem>.c` / `<stem>.h`).
    pub stem: String,
    /// Source model name.
    pub model: String,
    /// [`graph_fingerprint`] of the source graph.
    pub fingerprint: u64,
    /// The translation unit.
    pub source: String,
    /// The public header.
    pub header: String,
    /// `DMO_ARENA_BYTES` — the plan's overlapped peak, verbatim.
    pub arena_bytes: usize,
    /// Flash image (exact weights + code estimate).
    pub flash: FlashFootprint,
    /// Whether weights were embedded as `const` initialisers (`true`)
    /// or left to the emitted SplitMix64 generator (`false`).
    pub weights_embedded: bool,
    /// Element count per model input, in `dmo_invoke` parameter order.
    pub input_elems: Vec<usize>,
    /// Element count per model output, in `dmo_invoke` parameter order.
    pub output_elems: Vec<usize>,
    /// Activation dtype of the unit.
    pub dtype: DType,
    /// Call sites emitted as fast typed-pointer variants (counting
    /// elided concat-rows reassemblies).
    pub fast_sites: usize,
    /// Per-inference work estimate (MACs + arena bytes moved) — what
    /// [`crate::mcu::latency_ms`] scales per deployment target.
    pub cost: crate::mcu::CostBreakdown,
}

impl CUnit {
    /// Header file name the source `#include`s.
    pub fn header_file_name(&self) -> String {
        format!("{}.h", self.stem)
    }

    /// Write `<c_path>` and its sibling header; returns the header path.
    /// `c_path`'s file name should be `<stem>.c` so the `#include`
    /// inside the source resolves.
    pub fn write_to(&self, c_path: &Path) -> Result<PathBuf> {
        if let Some(parent) = c_path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        let header_path = c_path.with_file_name(self.header_file_name());
        std::fs::write(c_path, &self.source)
            .with_context(|| format!("writing {}", c_path.display()))?;
        std::fs::write(&header_path, &self.header)
            .with_context(|| format!("writing {}", header_path.display()))?;
        Ok(header_path)
    }
}

/// Lower `plan` for `graph` into a C unit.
///
/// `graph` is the graph the caller planned; when the plan carries a
/// §II-A split rewrite the banded graph it actually indexes is resolved
/// via [`Plan::graph_for`] — the emitted firmware then contains the
/// banded kernels and the concat-rows reassembly, with each split op's
/// weights stored in flash once and shared by its bands.
pub fn emit(graph: &Graph, plan: &Plan, opts: &EmitOptions) -> Result<CUnit> {
    let graph = plan.graph_for(graph);
    ensure!(!graph.ops.is_empty(), "cannot emit an empty graph");
    ensure!(
        plan.alloc.offsets.len() == graph.tensors.len(),
        "plan places {} tensors but the graph has {} — plan/graph mismatch",
        plan.alloc.offsets.len(),
        graph.tensors.len()
    );
    let dtype = uniform_activation_dtype(graph)?;
    for op in &graph.ops {
        check_weight_scheme(op, dtype)?;
        for &t in op.inputs.iter().chain([&op.output]) {
            ensure!(
                plan.alloc.offsets[t.0].is_some(),
                "tensor `{}` is unplaced in the plan — cannot emit",
                graph.tensor(t).name
            );
        }
    }
    for &t in graph.inputs.iter().chain(&graph.outputs) {
        ensure!(
            plan.alloc.offsets[t.0].is_some(),
            "model i/o tensor `{}` is unplaced in the plan — cannot emit",
            graph.tensor(t).name
        );
    }

    // count each weight group once — split bands share their source
    // op's arrays, both here and in the emitted unit
    let total_weight_elems: usize = graph
        .unique_weight_ops()
        .flat_map(|(_, op)| op.weights.iter())
        .map(|w| w.shape.num_elements())
        .sum();
    let embed = total_weight_elems <= opts.weight_embed_limit;

    let flash = FlashFootprint {
        weight_bytes: graph.weight_bytes(),
        code_bytes: super::code_estimate(graph),
    };
    let fingerprint = graph_fingerprint(graph);
    let input_elems: Vec<usize> = graph
        .inputs
        .iter()
        .map(|&t| graph.tensor(t).shape.num_elements())
        .collect();
    let output_elems: Vec<usize> = graph
        .outputs
        .iter()
        .map(|&t| graph.tensor(t).shape.num_elements())
        .collect();

    let choices = site_choices(graph, plan, opts, dtype);
    let fast_sites = choices
        .iter()
        .filter(|c| !matches!(c, SiteChoice::Generic))
        .count();
    let e = Emitter {
        graph,
        plan,
        opts,
        dtype,
        embed,
        flash,
        fingerprint,
        choices,
    };
    Ok(CUnit {
        stem: opts.stem.clone(),
        model: graph.name.clone(),
        fingerprint,
        source: e.source(),
        header: e.header(&input_elems, &output_elems),
        arena_bytes: plan.alloc.peak,
        flash,
        weights_embedded: embed,
        input_elems,
        output_elems,
        dtype,
        fast_sites,
        cost: crate::mcu::graph_cost(graph),
    })
}

/// Revalidate `artifact` against `graph` (fingerprint, layout safety)
/// and emit the reconstructed plan — the deploy path: plan in one
/// process, `dmo emit-c --import` in another.
pub fn emit_artifact(graph: &Graph, artifact: &PlanArtifact, opts: &EmitOptions) -> Result<CUnit> {
    let plan = artifact
        .to_plan(graph)
        .context("revalidating plan artifact for emission")?;
    emit(graph, &plan, opts)
}

fn uniform_activation_dtype(graph: &Graph) -> Result<DType> {
    let dtype = graph.tensors[0].dtype;
    ensure!(
        graph.tensors.iter().all(|t| t.dtype == dtype),
        "mixed activation dtypes are not supported by the C emitter"
    );
    match dtype {
        DType::F32 | DType::I8 => Ok(dtype),
        DType::I32 => bail!("i32 activation tensors are not supported by the C emitter"),
    }
}

/// Weight storage C types for an activation dtype: quantised models
/// keep `int8_t` weights with `int32_t` biases (the TFLite layout the
/// builders produce), float models use `float` throughout.
fn weight_ctypes(dtype: DType) -> (&'static str, &'static str) {
    match dtype {
        DType::I8 => ("int8_t", "int32_t"),
        _ => ("float", "float"),
    }
}

fn check_weight_scheme(op: &OpNode, dtype: DType) -> Result<()> {
    if op.weights.is_empty() {
        return Ok(());
    }
    ensure!(
        op.weights.len() == 2,
        "op `{}`: expected [weights, bias] attributes, found {}",
        op.name,
        op.weights.len()
    );
    let bias_dtype = if dtype == DType::I8 { DType::I32 } else { dtype };
    ensure!(
        op.weights[0].dtype == dtype && op.weights[1].dtype == bias_dtype,
        "op `{}`: weight dtypes {}/{} do not match the {}/{} storage scheme",
        op.name,
        op.weights[0].dtype,
        op.weights[1].dtype,
        dtype,
        bias_dtype
    );
    Ok(())
}

/// How one call site is lowered. Computed up front so kernel emission
/// knows which function bodies the unit actually references.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SiteChoice {
    /// Byte-addressed generic kernel (the reference loops).
    Generic,
    /// Fast typed-pointer variant of a tunable op class.
    Fast {
        class: &'static str,
        variant: Variant,
    },
    /// Concat-rows reassembly whose bands the plan already placed
    /// contiguously at the output's own offsets — the copy is a no-op
    /// and is dropped entirely.
    ElideConcatRows,
}

/// Per-site legality gates. A fast variant is only chosen where it is
/// *provably* bit-identical and overlap-safe:
///
/// * `Reference`-order variants keep the generic kernel's exact element
///   order (same loads, same stores, same f32 accumulation sequence),
///   so the plan's O_s overlap budgets — derived against that order —
///   still hold in place;
/// * `ChannelOuter` reorders stores, so it is downgraded to `Reference`
///   unless the plan placed this op's buffers disjointly;
/// * f32 typed pointers require 4-byte-aligned arena offsets at every
///   operand (the backing store is float-aligned; offsets usually are
///   too, but the plan is allowed to produce odd ones);
/// * i8 variants accumulate in `int32_t`; they are only exact while the
///   reference's f32 accumulator stays below 2^24, proven here from the
///   actual generated weights of this op.
fn site_choices(graph: &Graph, plan: &Plan, opts: &EmitOptions, dtype: DType) -> Vec<SiteChoice> {
    let elem = dtype.size_bytes();
    graph
        .ops
        .iter()
        .enumerate()
        .map(|(oi, op)| {
            if !opts.fast {
                return SiteChoice::Generic;
            }
            if matches!(op.kind, OpKind::ConcatRows) {
                return if concat_rows_contiguous(graph, plan, op, elem) {
                    SiteChoice::ElideConcatRows
                } else {
                    SiteChoice::Generic
                };
            }
            let Some(class) = class_of(&op.kind) else {
                return SiteChoice::Generic;
            };
            let default = Variant::Fast {
                order: LoopOrder::Reference,
                unroll: 1,
            };
            let mut variant = match opts.tuning.as_ref().and_then(|t| t.choice(class)) {
                Some(Variant::Generic) => return SiteChoice::Generic,
                Some(v) => v,
                None => default,
            };
            if let Variant::Fast {
                order: LoopOrder::ChannelOuter,
                unroll,
            } = variant
            {
                if !buffers_disjoint(graph, plan, op, elem) {
                    variant = Variant::Fast {
                        order: LoopOrder::Reference,
                        unroll,
                    };
                }
            }
            if fast_fn_name(class, dtype, variant).is_none() {
                // a stale/foreign tuning choice the generator cannot
                // honour at this dtype: fall back to the plain fast loop
                variant = default;
            }
            if fast_fn_name(class, dtype, variant).is_none() {
                return SiteChoice::Generic;
            }
            if dtype == DType::F32 {
                let aligned = op.inputs.iter().chain([&op.output]).all(|&t| {
                    plan.alloc.offsets[t.0].is_some_and(|o| o % 4 == 0)
                });
                if !aligned {
                    return SiteChoice::Generic;
                }
            }
            if dtype == DType::I8 && !i8_accumulation_exact(graph, op, oi, opts.seed) {
                return SiteChoice::Generic;
            }
            SiteChoice::Fast { class, variant }
        })
        .collect()
}

/// Are this op's input buffers disjoint from its output buffer in the
/// planned arena? (The gate for store-reordering loop orders.)
fn buffers_disjoint(graph: &Graph, plan: &Plan, op: &OpNode, elem: usize) -> bool {
    let Some(o0) = plan.alloc.offsets[op.output.0] else {
        return false;
    };
    let on = graph.tensor(op.output).shape.num_elements() * elem;
    op.inputs.iter().all(|&t| {
        let Some(i0) = plan.alloc.offsets[t.0] else {
            return false;
        };
        let inb = graph.tensor(t).shape.num_elements() * elem;
        i0 + inb <= o0 || o0 + on <= i0
    })
}

/// Did the plan place every concat-rows band exactly where the output
/// tensor expects it? Then each copy is `memmove(p, p, n)` and the
/// whole reassembly can be elided.
fn concat_rows_contiguous(graph: &Graph, plan: &Plan, op: &OpNode, elem: usize) -> bool {
    let Some(out0) = plan.alloc.offsets[op.output.0] else {
        return false;
    };
    let mut base = 0usize;
    for &t in &op.inputs {
        if plan.alloc.offsets[t.0] != Some(out0 + base * elem) {
            return false;
        }
        base += graph.tensor(t).shape.num_elements();
    }
    true
}

/// Does the i8 fast variant's `int32_t` accumulator provably match the
/// reference f32 accumulation bit for bit? True iff every generated
/// weight is integral and `|bias| + macs·127·|w|max < 2^24` — below
/// that bound f32 addition of integers is exact, so the integer and
/// float paths compute the identical value at every step.
fn i8_accumulation_exact(graph: &Graph, op: &OpNode, oi: usize, seed: u64) -> bool {
    let macs_per_out: i64 = match &op.kind {
        OpKind::Conv2D(p) => {
            (p.kernel.0 * p.kernel.1 * graph.tensor(op.inputs[0]).shape.c()) as i64
        }
        OpKind::DepthwiseConv2D(p) => (p.kernel.0 * p.kernel.1) as i64,
        OpKind::FullyConnected { .. } => {
            graph.tensor(op.inputs[0]).shape.num_elements() as i64
        }
        // avg-pool sums at most kh·kw int8 values
        OpKind::Pool(p) => return (p.kernel.0 * p.kernel.1) as i64 * 127 < 1 << 24,
        // unary/binary never leave the |x| ≤ 127·127 range
        _ => return true,
    };
    let vals = gen_weights(op, seed ^ op.weight_key(oi) as u64);
    if vals.iter().flatten().any(|v| v.fract() != 0.0) {
        return false;
    }
    let absmax = |tv: &[f32]| tv.iter().fold(0f32, |m, &v| m.max(v.abs())) as i64;
    let wmax = vals.first().map(|w| absmax(w)).unwrap_or(0);
    let bmax = vals.get(1).map(|b| absmax(b)).unwrap_or(0);
    bmax + macs_per_out * 127 * wmax < 1 << 24
}

struct Emitter<'a> {
    graph: &'a Graph,
    plan: &'a Plan,
    opts: &'a EmitOptions,
    dtype: DType,
    embed: bool,
    flash: FlashFootprint,
    fingerprint: u64,
    choices: Vec<SiteChoice>,
}

impl Emitter<'_> {
    fn banner(&self) -> String {
        format!(
            "/* Generated by `dmo emit-c` - do not edit.\n \
             * model: {} (fingerprint {:016x})\n \
             * plan: strategy={} heuristic={} os={}\n \
             * arena: {} bytes, weights: {} bytes (seed {}, {})\n \
             */\n",
            self.graph.name,
            self.fingerprint,
            self.plan.strategy.name(),
            self.plan.heuristic.name(),
            self.plan.os.method.name(),
            self.plan.alloc.peak,
            self.flash.weight_bytes,
            self.opts.seed,
            if self.embed { "embedded" } else { "generated" },
        )
    }

    fn invoke_params(&self) -> String {
        let mut params: Vec<String> = (0..self.graph.inputs.len())
            .map(|i| format!("const float *input_{i}"))
            .collect();
        params.extend((0..self.graph.outputs.len()).map(|i| format!("float *output_{i}")));
        params.join(", ")
    }

    fn header(&self, input_elems: &[usize], output_elems: &[usize]) -> String {
        let guard = format!("DMO_{}_H", self.opts.stem.to_uppercase());
        let mut h = self.banner();
        let _ = writeln!(h, "#ifndef {guard}");
        let _ = writeln!(h, "#define {guard}");
        h.push('\n');
        h.push_str("#include <stddef.h>\n\n");
        let _ = writeln!(h, "#define DMO_MODEL_NAME \"{}\"", self.graph.name);
        let _ = writeln!(h, "#define DMO_MODEL_FINGERPRINT \"{:016x}\"", self.fingerprint);
        let _ = writeln!(h, "#define DMO_ARENA_BYTES {}", self.plan.alloc.peak);
        let _ = writeln!(h, "#define DMO_ELEM_BYTES {}", self.dtype.size_bytes());
        let _ = writeln!(h, "#define DMO_WEIGHT_BYTES {}", self.flash.weight_bytes);
        let _ = writeln!(h, "#define DMO_CODE_BYTES_EST {}", self.flash.code_bytes);
        let _ = writeln!(h, "#define DMO_FLASH_BYTES {}", self.flash.total());
        let _ = writeln!(h, "#define DMO_WEIGHT_SEED {}", self.opts.seed);
        let _ = writeln!(h, "#define DMO_WEIGHTS_EMBEDDED {}", i32::from(self.embed));
        let _ = writeln!(h, "#define DMO_INPUT_COUNT {}", input_elems.len());
        let _ = writeln!(h, "#define DMO_OUTPUT_COUNT {}", output_elems.len());
        for (i, n) in input_elems.iter().enumerate() {
            let _ = writeln!(h, "#define DMO_INPUT_{i}_ELEMS {n}");
        }
        for (i, n) in output_elems.iter().enumerate() {
            let _ = writeln!(h, "#define DMO_OUTPUT_{i}_ELEMS {n}");
        }
        h.push('\n');
        h.push_str(
            "/* I/O buffers are caller-provided float arrays (dequantised for\n \
             * quantised models) and are NOT counted in DMO_ARENA_BYTES -\n \
             * stream or stage them according to your data source. */\n",
        );
        let _ = writeln!(h, "void dmo_invoke({});", self.invoke_params());
        h.push('\n');
        let _ = writeln!(h, "#endif /* {guard} */");
        h
    }

    fn source(&self) -> String {
        let (wt, bt) = weight_ctypes(self.dtype);
        let mut c = self.banner();
        let _ = writeln!(c, "#include \"{}.h\"", self.opts.stem);
        c.push('\n');
        c.push_str("#include <math.h>\n#include <stdint.h>\n#include <string.h>\n\n");
        let _ = writeln!(c, "typedef {wt} dmo_wt;");
        let _ = writeln!(c, "typedef {bt} dmo_bt;");
        c.push('\n');
        c.push_str(
            "/* float-aligned backing store: fast kernel variants address the\n \
             * arena through typed float/int8_t pointers */\n",
        );
        c.push_str("static float dmo_arena_store[(DMO_ARENA_BYTES + 3) / 4];\n");
        c.push_str("#define dmo_arena ((uint8_t *)dmo_arena_store)\n\n");

        c.push_str("/* Tensor arena offsets in bytes, verbatim from the plan. */\n");
        for (i, info) in self.graph.tensors.iter().enumerate() {
            if let Some(off) = self.plan.alloc.offsets[i] {
                let _ = writeln!(
                    c,
                    "#define DMO_OFF_T{i} {off} /* {}: {} elems */",
                    info.name,
                    info.shape.num_elements()
                );
            }
        }
        c.push('\n');
        c.push_str(load_store_source(self.dtype));
        c.push('\n');

        self.emit_weights(&mut c);

        // call sites first: which kernels (generic or fast) the body
        // actually references decides which function bodies get
        // emitted — under -Werror an unused static function is a
        // build break
        let mut body = String::new();
        for &opid in &self.plan.order.0 {
            let op = self.graph.op(opid);
            let _ = writeln!(body, "    /* op {}: {} */", opid.0, op.name);
            let _ = writeln!(body, "    {}", self.call_site(opid.0, op));
        }

        let mut kblock = String::new();
        for k in kernels_used(self.graph) {
            if body.contains(&format!("{}(", k.fn_name())) {
                kblock.push_str(k.source());
                kblock.push('\n');
            }
        }
        let mut fast: BTreeMap<String, String> = BTreeMap::new();
        for choice in &self.choices {
            if let SiteChoice::Fast { class, variant } = *choice {
                let name = fast_fn_name(class, self.dtype, variant).expect("gated");
                fast.entry(name).or_insert_with(|| {
                    fast_source(class, self.dtype, variant).expect("gated")
                });
            }
        }
        for src in fast.values() {
            kblock.push_str(src);
            kblock.push('\n');
        }

        c.push_str("/* Kernels: loop sweeps and read/write order match the\n");
        c.push_str(" * crate::ops reference kernels - the invariant the overlap\n");
        c.push_str(" * engines assume. Fast (typed-pointer) variants keep the\n");
        c.push_str(" * same element order unless the plan proves the buffers\n");
        c.push_str(" * disjoint. */\n");
        if kblock.contains("dmo_act(") {
            c.push_str(ACT_HELPER);
            c.push('\n');
        }
        if kblock.contains("dmo_requant(") {
            c.push_str(REQUANT_HELPER);
            c.push('\n');
        }
        c.push_str(&kblock);

        let _ = writeln!(c, "void dmo_invoke({}) {{", self.invoke_params());
        if !self.embed {
            c.push_str("    static int dmo_ready = 0;\n");
            c.push_str("    if (!dmo_ready) {\n");
            c.push_str("        dmo_weights_init();\n");
            c.push_str("        dmo_ready = 1;\n");
            c.push_str("    }\n\n");
        }
        for (i, &t) in self.graph.inputs.iter().enumerate() {
            let _ = writeln!(c, "    for (size_t i = 0; i < DMO_INPUT_{i}_ELEMS; i++) {{");
            let _ = writeln!(
                c,
                "        dmo_store(DMO_OFF_T{} + i * DMO_ELEM_BYTES, input_{i}[i]);",
                t.0
            );
            c.push_str("    }\n");
        }
        c.push('\n');
        c.push_str(&body);
        c.push('\n');
        for (i, &t) in self.graph.outputs.iter().enumerate() {
            let _ = writeln!(c, "    for (size_t i = 0; i < DMO_OUTPUT_{i}_ELEMS; i++) {{");
            let _ = writeln!(
                c,
                "        output_{i}[i] = dmo_load(DMO_OFF_T{} + i * DMO_ELEM_BYTES);",
                t.0
            );
            c.push_str("    }\n");
        }
        c.push_str("}\n");
        c
    }

    fn emit_weights(&self, c: &mut String) {
        c.push_str(
            "/* Weights (synthetic SplitMix64 stream, seed DMO_WEIGHT_SEED).\n \
             * One array set per weight key: the bands of a split op share\n \
             * the original op's arrays. */\n",
        );
        for (oi, op) in self.graph.unique_weight_ops() {
            let key = op.weight_key(oi);
            if self.embed {
                let vals = gen_weights(op, self.opts.seed ^ key as u64);
                for (j, (w, tv)) in op.weights.iter().zip(&vals).enumerate() {
                    let ctype = if j == 0 { "dmo_wt" } else { "dmo_bt" };
                    let lits: Vec<String> = if self.dtype == DType::I8 {
                        tv.iter().map(|&v| (v as i64).to_string()).collect()
                    } else {
                        tv.iter().map(|&v| f32_literal(v)).collect()
                    };
                    let _ = writeln!(
                        c,
                        "static const {ctype} dmo_w{key}_{j}[{}] = {{",
                        w.shape.num_elements()
                    );
                    c.push_str(&wrap_values(&lits, 10));
                    c.push_str("};\n");
                }
            } else {
                for (j, w) in op.weights.iter().enumerate() {
                    let ctype = if j == 0 { "dmo_wt" } else { "dmo_bt" };
                    let _ = writeln!(
                        c,
                        "static {ctype} dmo_w{key}_{j}[{}];",
                        w.shape.num_elements()
                    );
                }
            }
        }
        c.push('\n');
        if !self.embed {
            c.push_str(SPLITMIX);
            c.push('\n');
            c.push_str("static void dmo_weights_init(void) {\n    uint64_t s;\n");
            for (oi, op) in self.graph.unique_weight_ops() {
                let key = op.weight_key(oi);
                let opseed = (self.opts.seed ^ key as u64) ^ 0xD0D0_0000_0000_0000;
                let _ = writeln!(c, "    s = {opseed:#x}ULL; /* weight key {key} */");
                for (j, w) in op.weights.iter().enumerate() {
                    let fill = if j == 0 { "dmo_fill_wt" } else { "dmo_fill_bt" };
                    let _ = writeln!(
                        c,
                        "    {fill}(dmo_w{key}_{j}, {}, &s);",
                        w.shape.num_elements()
                    );
                }
            }
            c.push_str("}\n\n");
        }
    }

    fn call_site(&self, oi: usize, op: &OpNode) -> String {
        match self.choices[oi] {
            SiteChoice::Generic => self.generic_call_site(oi, op),
            SiteChoice::ElideConcatRows => {
                "/* concat-rows reassembly elided: bands are contiguous in the arena */;"
                    .to_string()
            }
            SiteChoice::Fast { class, variant } => self.fast_call_site(oi, op, class, variant),
        }
    }

    fn fast_call_site(
        &self,
        oi: usize,
        op: &OpNode,
        class: &'static str,
        variant: Variant,
    ) -> String {
        let name = fast_fn_name(class, self.dtype, variant).expect("gated in site_choices");
        let ct = if self.dtype == DType::I8 { "int8_t" } else { "float" };
        let src = |t: TensorId| format!("(const {ct} *)(dmo_arena + DMO_OFF_T{})", t.0);
        let dst = |t: TensorId| format!("({ct} *)(dmo_arena + DMO_OFF_T{})", t.0);
        // unit-scale synthetic quantisation: multiplier 1, shift 0
        let requant = if self.dtype == DType::I8 { ", 1, 0" } else { "" };
        let in0 = self.graph.tensor(op.inputs[0]);
        let out = self.graph.tensor(op.output);
        let wk = op.weight_key(oi);
        match &op.kind {
            OpKind::Conv2D(p) => {
                let (ih, iw, id) = (in0.shape.h(), in0.shape.w(), in0.shape.c());
                let (oh, ow, od) = (out.shape.h(), out.shape.w(), out.shape.c());
                format!(
                    "{name}({}, {}, {ih}, {iw}, {id}, {oh}, {ow}, {od}, {}, {}, {}, {}, {}, {}, {}, {}, {}{requant}, dmo_w{wk}_0, dmo_w{wk}_1);",
                    src(op.inputs[0]),
                    dst(op.output),
                    p.kernel.0,
                    p.kernel.1,
                    p.stride.0,
                    p.stride.1,
                    p.dilation.0,
                    p.dilation.1,
                    pad_before(ih, oh, p.kernel.0, p.stride.0, p.dilation.0),
                    pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1),
                    act_id(p.act),
                )
            }
            OpKind::DepthwiseConv2D(p) => {
                let (ih, iw, id) = (in0.shape.h(), in0.shape.w(), in0.shape.c());
                let (oh, ow, od) = (out.shape.h(), out.shape.w(), out.shape.c());
                format!(
                    "{name}({}, {}, {ih}, {iw}, {id}, {oh}, {ow}, {od}, {}, {}, {}, {}, {}, {}, {}, {}, {}, {}, {}{requant}, dmo_w{wk}_0, dmo_w{wk}_1);",
                    src(op.inputs[0]),
                    dst(op.output),
                    p.kernel.0,
                    p.kernel.1,
                    p.stride.0,
                    p.stride.1,
                    p.dilation.0,
                    p.dilation.1,
                    pad_before(ih, oh, p.kernel.0, p.stride.0, p.dilation.0),
                    pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1),
                    p.depth_multiplier,
                    op.weights[1].shape.num_elements(),
                    act_id(p.act),
                )
            }
            OpKind::Pool(p) => {
                let (ih, iw, id) = (in0.shape.h(), in0.shape.w(), in0.shape.c());
                let (oh, ow, od) = (out.shape.h(), out.shape.w(), out.shape.c());
                format!(
                    "{name}({}, {}, {ih}, {iw}, {id}, {oh}, {ow}, {od}, {}, {}, {}, {}, {}, {}, {});",
                    src(op.inputs[0]),
                    dst(op.output),
                    p.kernel.0,
                    p.kernel.1,
                    p.stride.0,
                    p.stride.1,
                    pad_before(ih, oh, p.kernel.0, p.stride.0, 1),
                    pad_before(iw, ow, p.kernel.1, p.stride.1, 1),
                    pool_kind_id(p.kind),
                )
            }
            OpKind::Unary(u) => format!(
                "{name}({}, {}, {}, {});",
                src(op.inputs[0]),
                dst(op.output),
                out.shape.num_elements(),
                unary_kind_id(*u),
            ),
            OpKind::Reshape { .. } => format!(
                "{name}({}, {}, {}, 2);",
                src(op.inputs[0]),
                dst(op.output),
                out.shape.num_elements(),
            ),
            OpKind::Binary(bk) => format!(
                "{name}({}, {}, {}, {}, {});",
                src(op.inputs[0]),
                src(op.inputs[1]),
                dst(op.output),
                out.shape.num_elements(),
                match bk {
                    crate::ir::op::BinaryKind::Add => 0,
                    crate::ir::op::BinaryKind::Mul => 1,
                },
            ),
            OpKind::FullyConnected { out_features, act } => format!(
                "{name}({}, {}, {}, {out_features}, {}{requant}, dmo_w{wk}_0, dmo_w{wk}_1);",
                src(op.inputs[0]),
                dst(op.output),
                in0.shape.num_elements(),
                act_id(*act),
            ),
            other => unreachable!("op kind `{}` has no fast variant", other.name()),
        }
    }

    fn generic_call_site(&self, oi: usize, op: &OpNode) -> String {
        let off = |t: TensorId| format!("DMO_OFF_T{}", t.0);
        let in0 = self.graph.tensor(op.inputs[0]);
        let out = self.graph.tensor(op.output);
        let wk = op.weight_key(oi);
        match &op.kind {
            OpKind::Conv2D(p) => {
                let (ih, iw, id) = (in0.shape.h(), in0.shape.w(), in0.shape.c());
                let (oh, ow, od) = (out.shape.h(), out.shape.w(), out.shape.c());
                format!(
                    "dmo_conv2d({}, {}, {ih}, {iw}, {id}, {oh}, {ow}, {od}, {}, {}, {}, {}, {}, {}, {}, {}, {}, dmo_w{wk}_0, dmo_w{wk}_1);",
                    off(op.inputs[0]),
                    off(op.output),
                    p.kernel.0,
                    p.kernel.1,
                    p.stride.0,
                    p.stride.1,
                    p.dilation.0,
                    p.dilation.1,
                    pad_before(ih, oh, p.kernel.0, p.stride.0, p.dilation.0),
                    pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1),
                    act_id(p.act),
                )
            }
            OpKind::DepthwiseConv2D(p) => {
                let (ih, iw, id) = (in0.shape.h(), in0.shape.w(), in0.shape.c());
                let (oh, ow, od) = (out.shape.h(), out.shape.w(), out.shape.c());
                format!(
                    "dmo_dwconv2d({}, {}, {ih}, {iw}, {id}, {oh}, {ow}, {od}, {}, {}, {}, {}, {}, {}, {}, {}, {}, {}, {}, dmo_w{wk}_0, dmo_w{wk}_1);",
                    off(op.inputs[0]),
                    off(op.output),
                    p.kernel.0,
                    p.kernel.1,
                    p.stride.0,
                    p.stride.1,
                    p.dilation.0,
                    p.dilation.1,
                    pad_before(ih, oh, p.kernel.0, p.stride.0, p.dilation.0),
                    pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1),
                    p.depth_multiplier,
                    op.weights[1].shape.num_elements(),
                    act_id(p.act),
                )
            }
            OpKind::Pool(p) => {
                let (ih, iw, id) = (in0.shape.h(), in0.shape.w(), in0.shape.c());
                let (oh, ow, od) = (out.shape.h(), out.shape.w(), out.shape.c());
                format!(
                    "dmo_pool({}, {}, {ih}, {iw}, {id}, {oh}, {ow}, {od}, {}, {}, {}, {}, {}, {}, {});",
                    off(op.inputs[0]),
                    off(op.output),
                    p.kernel.0,
                    p.kernel.1,
                    p.stride.0,
                    p.stride.1,
                    pad_before(ih, oh, p.kernel.0, p.stride.0, 1),
                    pad_before(iw, ow, p.kernel.1, p.stride.1, 1),
                    pool_kind_id(p.kind),
                )
            }
            OpKind::GlobalAvgPool => format!(
                "dmo_gavgpool({}, {}, {}, {}, {});",
                off(op.inputs[0]),
                off(op.output),
                in0.shape.h(),
                in0.shape.w(),
                in0.shape.c(),
            ),
            OpKind::Unary(u) => format!(
                "dmo_unary({}, {}, {}, {});",
                off(op.inputs[0]),
                off(op.output),
                out.shape.num_elements(),
                unary_kind_id(*u),
            ),
            OpKind::Reshape { .. } => format!(
                "dmo_unary({}, {}, {}, 2);",
                off(op.inputs[0]),
                off(op.output),
                out.shape.num_elements(),
            ),
            OpKind::Binary(bk) => format!(
                "dmo_binary({}, {}, {}, {}, {});",
                off(op.inputs[0]),
                off(op.inputs[1]),
                off(op.output),
                out.shape.num_elements(),
                match bk {
                    crate::ir::op::BinaryKind::Add => 0,
                    crate::ir::op::BinaryKind::Mul => 1,
                },
            ),
            OpKind::FullyConnected { out_features, act } => format!(
                "dmo_fc({}, {}, {}, {out_features}, {}, dmo_w{wk}_0, dmo_w{wk}_1);",
                off(op.inputs[0]),
                off(op.output),
                in0.shape.num_elements(),
                act_id(*act),
            ),
            OpKind::MatMulAccum { out_features } => format!(
                "dmo_matmul({}, {}, {}, {out_features}, dmo_w{wk}_0, dmo_w{wk}_1);",
                off(op.inputs[0]),
                off(op.output),
                in0.shape.num_elements(),
            ),
            OpKind::Concat => {
                let n = op.inputs.len();
                let ibs: Vec<String> = op.inputs.iter().map(|&t| off(t)).collect();
                let cs: Vec<String> = op
                    .inputs
                    .iter()
                    .map(|&t| self.graph.tensor(t).shape.c().to_string())
                    .collect();
                format!(
                    "{{\n        static const size_t ibs[{n}] = {{{}}};\n        static const int cs[{n}] = {{{}}};\n        dmo_concat({}, {}, {}, {n}, ibs, cs);\n    }}",
                    ibs.join(", "),
                    cs.join(", "),
                    off(op.output),
                    out.shape.h() * out.shape.w(),
                    out.shape.c(),
                )
            }
            OpKind::Pad { pad } => {
                let (ih, iw, id) = (in0.shape.h(), in0.shape.w(), in0.shape.c());
                let (oh, ow, od) = (out.shape.h(), out.shape.w(), out.shape.c());
                format!(
                    "dmo_pad({}, {}, {ih}, {iw}, {id}, {oh}, {ow}, {od}, {}, {});",
                    off(op.inputs[0]),
                    off(op.output),
                    pad.0,
                    pad.2,
                )
            }
            OpKind::Softmax => {
                let d = out.shape.dim(out.shape.rank() - 1);
                format!(
                    "dmo_softmax({}, {}, {}, {d});",
                    off(op.inputs[0]),
                    off(op.output),
                    out.shape.num_elements() / d,
                )
            }
            OpKind::Band(b) => {
                let (iw, id) = (in0.shape.w(), in0.shape.c());
                let (orows, ow, od) = (out.shape.h(), out.shape.w(), out.shape.c());
                let ph = b.pad_h();
                match b.inner.as_ref() {
                    OpKind::Conv2D(p) => format!(
                        "dmo_band_conv2d({}, {}, {}, {iw}, {id}, {}, {}, {orows}, {ow}, {od}, {}, {}, {}, {}, {}, {}, {ph}, {}, {}, dmo_w{wk}_0, dmo_w{wk}_1);",
                        off(op.inputs[0]),
                        off(op.output),
                        b.full_in_h,
                        b.in_row0,
                        b.out_row0,
                        p.kernel.0,
                        p.kernel.1,
                        p.stride.0,
                        p.stride.1,
                        p.dilation.0,
                        p.dilation.1,
                        pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1),
                        act_id(p.act),
                    ),
                    OpKind::DepthwiseConv2D(p) => format!(
                        "dmo_band_dwconv2d({}, {}, {}, {iw}, {id}, {}, {}, {orows}, {ow}, {od}, {}, {}, {}, {}, {}, {}, {ph}, {}, {}, {}, {}, dmo_w{wk}_0, dmo_w{wk}_1);",
                        off(op.inputs[0]),
                        off(op.output),
                        b.full_in_h,
                        b.in_row0,
                        b.out_row0,
                        p.kernel.0,
                        p.kernel.1,
                        p.stride.0,
                        p.stride.1,
                        p.dilation.0,
                        p.dilation.1,
                        pad_before(iw, ow, p.kernel.1, p.stride.1, p.dilation.1),
                        p.depth_multiplier,
                        op.weights[1].shape.num_elements(),
                        act_id(p.act),
                    ),
                    OpKind::Pool(p) => format!(
                        "dmo_band_pool({}, {}, {}, {iw}, {id}, {}, {}, {orows}, {ow}, {od}, {}, {}, {}, {}, {ph}, {}, {});",
                        off(op.inputs[0]),
                        off(op.output),
                        b.full_in_h,
                        b.in_row0,
                        b.out_row0,
                        p.kernel.0,
                        p.kernel.1,
                        p.stride.0,
                        p.stride.1,
                        pad_before(iw, ow, p.kernel.1, p.stride.1, 1),
                        pool_kind_id(p.kind),
                    ),
                    OpKind::Unary(u) => {
                        // elementwise band: an offset copy of the mapped rows
                        let delta =
                            (b.out_row0 - b.in_row0) * iw * id * self.dtype.size_bytes();
                        format!(
                            "dmo_unary({} + {delta}, {}, {}, {});",
                            off(op.inputs[0]),
                            off(op.output),
                            out.shape.num_elements(),
                            unary_kind_id(*u),
                        )
                    }
                    other => unreachable!("band inner `{}` is not emittable", other.name()),
                }
            }
            OpKind::ConcatRows => {
                // reassembly: sequential copies into the output at
                // ascending row offsets — same sweep as the interpreter
                let mut stmts = Vec::new();
                let mut base = 0usize;
                for &t in &op.inputs {
                    let n = self.graph.tensor(t).shape.num_elements();
                    stmts.push(format!(
                        "dmo_unary({}, {} + {}, {n}, {});",
                        off(t),
                        off(op.output),
                        base * self.dtype.size_bytes(),
                        unary_kind_id(crate::ir::op::UnaryKind::Copy),
                    ));
                    base += n;
                }
                format!("{{\n        {}\n    }}", stmts.join("\n        "))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::planner::Planner;

    fn tiny_plan() -> (Graph, Plan) {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        (g, plan)
    }

    #[test]
    fn header_carries_plan_and_fingerprint() {
        let (g, plan) = tiny_plan();
        let unit = emit(&g, &plan, &EmitOptions::new("tiny_model")).unwrap();
        assert_eq!(unit.arena_bytes, plan.peak());
        assert!(unit
            .header
            .contains(&format!("#define DMO_ARENA_BYTES {}", plan.peak())));
        assert!(unit
            .header
            .contains(&format!("\"{:016x}\"", graph_fingerprint(&g))));
        assert!(unit.header.contains("#define DMO_INPUT_0_ELEMS 3072"));
        assert!(unit.header.contains("#define DMO_OUTPUT_0_ELEMS 10"));
        assert!(unit
            .header
            .contains("void dmo_invoke(const float *input_0, float *output_0);"));
    }

    #[test]
    fn offsets_are_verbatim_from_the_plan() {
        let (g, plan) = tiny_plan();
        let unit = emit(&g, &plan, &EmitOptions::new("tiny_model")).unwrap();
        for (i, off) in plan.alloc.offsets.iter().enumerate() {
            if let Some(off) = off {
                assert!(
                    unit.source.contains(&format!("#define DMO_OFF_T{i} {off} ")),
                    "missing offset define for tensor {i}"
                );
            }
        }
    }

    #[test]
    fn embedded_and_generated_weight_modes() {
        let (g, plan) = tiny_plan();
        let emb = emit(&g, &plan, &EmitOptions::new("t")).unwrap();
        assert!(emb.weights_embedded);
        assert!(emb.source.contains("static const dmo_wt dmo_w0_0[216] = {"));
        assert!(!emb.source.contains("dmo_weights_init"));

        let gen = emit(&g, &plan, &EmitOptions::new("t").weight_embed_limit(0)).unwrap();
        assert!(!gen.weights_embedded);
        assert!(gen.source.contains("static dmo_wt dmo_w0_0[216];"));
        assert!(gen.source.contains("static void dmo_weights_init(void)"));
        assert!(gen.source.contains("dmo_sm_next"));
    }

    #[test]
    fn i8_models_get_quantised_storage() {
        let g = models::build("tiny_int8").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let unit = emit(&g, &plan, &EmitOptions::new("tiny_int8_model")).unwrap();
        assert!(unit.source.contains("typedef int8_t dmo_wt;"));
        assert!(unit.source.contains("typedef int32_t dmo_bt;"));
        assert!(unit.source.contains("roundf("), "i8 store must quantise");
        assert!(unit.header.contains("#define DMO_ELEM_BYTES 1"));
    }

    #[test]
    fn unplaced_tensor_is_rejected() {
        let (g, mut plan) = tiny_plan();
        plan.alloc.offsets[1] = None;
        let err = emit(&g, &plan, &EmitOptions::new("t")).unwrap_err();
        assert!(format!("{err:#}").contains("unplaced"), "{err:#}");
    }

    #[test]
    fn emission_is_deterministic() {
        let (g, plan) = tiny_plan();
        let a = emit(&g, &plan, &EmitOptions::new("tiny_model")).unwrap();
        let b = emit(&g, &plan, &EmitOptions::new("tiny_model")).unwrap();
        assert_eq!(a.source, b.source);
        assert_eq!(a.header, b.header);
    }

    #[test]
    fn fast_variants_are_on_by_default_and_can_be_disabled() {
        let (g, plan) = tiny_plan();
        let fast = emit(&g, &plan, &EmitOptions::new("tiny_model")).unwrap();
        assert!(fast.fast_sites > 0);
        assert!(fast.source.contains("static float dmo_arena_store["));
        assert!(fast.source.contains("dmo_conv2d_f("), "f32 conv goes fast");
        // the generic conv body is dead code once every site is fast —
        // it must not be emitted (-Werror would reject it)
        assert!(!fast.source.contains("static void dmo_conv2d("));

        let slow = emit(&g, &plan, &EmitOptions::new("tiny_model").fast(false)).unwrap();
        assert_eq!(slow.fast_sites, 0);
        assert!(!slow.source.contains("dmo_conv2d_f("));
        assert!(slow.source.contains("static void dmo_conv2d("));
    }

    #[test]
    fn i8_models_get_requantising_fast_kernels() {
        let g = models::build("tiny_int8").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let unit = emit(&g, &plan, &EmitOptions::new("tiny_int8_model")).unwrap();
        assert!(unit.fast_sites > 0, "i8 zoo model must take the fast path");
        assert!(unit.source.contains("dmo_conv2d_q("));
        assert!(unit.source.contains("static int8_t dmo_requant("));
        assert_eq!(unit.dtype, DType::I8);
    }

    #[test]
    fn tuning_table_pins_variants_per_class() {
        use crate::codegen::tune::TuneTable;
        let (g, plan) = tiny_plan();
        let mut t = TuneTable::new();
        t.set(
            "conv2d",
            Variant::Fast {
                order: LoopOrder::Reference,
                unroll: 4,
            },
        );
        let u4 = emit(&g, &plan, &EmitOptions::new("tiny_model").tuning(t)).unwrap();
        assert!(u4.source.contains("dmo_conv2d_f_u4("));

        let mut t = TuneTable::new();
        t.set("conv2d", Variant::Generic);
        let gen = emit(&g, &plan, &EmitOptions::new("tiny_model").tuning(t)).unwrap();
        assert!(gen.source.contains("static void dmo_conv2d("));
        assert!(!gen.source.contains("dmo_conv2d_f("));
        // untuned classes still default to the fast reference loop
        assert!(gen.source.contains("dmo_fc_f("));
    }

    #[test]
    fn cost_estimate_is_populated() {
        let (g, plan) = tiny_plan();
        let unit = emit(&g, &plan, &EmitOptions::new("tiny_model")).unwrap();
        assert!(unit.cost.macs > 0);
        assert!(unit.cost.bytes > 0);
        assert_eq!(unit.cost, crate::mcu::graph_cost(&g));
    }

    #[test]
    fn artifact_emission_revalidates() {
        let (g, plan) = tiny_plan();
        let art = PlanArtifact::from_plan(&g, &plan);
        let unit = emit_artifact(&g, &art, &EmitOptions::new("tiny_model")).unwrap();
        assert_eq!(unit.arena_bytes, art.peak);
        // a tampered artifact must be refused before emission
        let mut bad = PlanArtifact::from_plan(&g, &plan);
        bad.peak += 1;
        assert!(emit_artifact(&g, &bad, &EmitOptions::new("tiny_model")).is_err());
    }
}
