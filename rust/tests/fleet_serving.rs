//! Fleet serving end-to-end: pooled-arena execution correctness,
//! closed- and open-loop accounting, and artifact hot-reload under
//! in-flight traffic.

use dmo::fleet::{
    fleet_serve, AdmissionPolicy, Fleet, FleetConfig, FleetReply, FleetRequest, ModelSpec,
    Registry,
};
use dmo::interp;
use dmo::ir::DType;
use dmo::planner::{PlanArtifact, Planner, Strategy};
use std::sync::mpsc;
use std::time::{Duration, Instant};

const SEED: u64 = 42;

fn deterministic_input(elems: usize, salt: u64) -> Vec<f32> {
    let mut rng = dmo::util::rng::Rng::new(SEED ^ salt);
    (0..elems).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

fn assert_bit_identical(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} differs ({x} vs {y})"
        );
    }
}

/// The pooled path must be bit-identical to the disjoint reference —
/// including on an arena deliberately filled with garbage from a
/// "previous request", because a validated plan writes every region
/// before reading it.
#[test]
fn pooled_execution_is_bit_identical_even_on_a_dirtied_arena() {
    let reg = Registry::load(&[ModelSpec::planned("tiny")], 1, 1, SEED).unwrap();
    let state = reg.current(0);
    let input = deterministic_input(state.input_elements(), 0xD1);
    let reference =
        interp::run_reference(&state.graph, &[input.clone()], SEED).unwrap().remove(0);

    let mut arena = state.acquire_arena();
    let clean = state.execute(&mut arena, &input).unwrap();
    assert_bit_identical(&clean, &reference, "clean arena vs reference");

    // poison every byte, as if a hostile previous request left residue
    for off in 0..arena.len() {
        arena.poke(DType::I8, off, -77.0);
    }
    let dirty = state.execute(&mut arena, &input).unwrap();
    assert_bit_identical(&dirty, &reference, "dirtied arena vs reference");
}

/// Closed loop over three models: everything completes, nothing sheds,
/// and the pooled-arena path never allocates after registration.
#[test]
fn closed_loop_fleet_completes_everything_without_allocating() {
    let report = fleet_serve(&FleetConfig {
        models: vec![
            ModelSpec::planned("tiny"),
            ModelSpec::planned("tiny_int8"),
            ModelSpec::planned("tiny_wide"),
        ],
        arenas: 2,
        workers: 2,
        queue_capacity: 16,
        requests: 300,
        rate: 0.0,
        seed: 7,
        jobs: 1,
        ..FleetConfig::default()
    })
    .unwrap();
    assert_eq!(report.completed, 300);
    assert_eq!(report.shed, 0, "backpressure admission never sheds");
    assert_eq!(report.per_model.len(), 3);
    let mut total = 0;
    for m in &report.per_model {
        assert!(m.completed > 0, "uniform mix must reach `{}`", m.model);
        assert_eq!(m.shed, m.metrics.shed, "report shed must come from Metrics");
        assert_eq!(m.pool_allocs, 0, "`{}` allocated at steady state", m.model);
        assert_eq!(m.pool_hit_rate, 1.0);
        assert_eq!(m.metrics.latency().count, m.completed);
        total += m.completed;
    }
    assert_eq!(total, 300);
}

/// Open loop with a deliberately overwhelmed single worker: sheds are
/// recorded in per-model `Metrics` (the single source of truth) and
/// `completed + shed == requests` still balances exactly.
#[test]
fn open_loop_sheds_into_metrics_and_accounting_balances() {
    let requests = 400u64;
    let report = fleet_serve(&FleetConfig {
        models: vec![ModelSpec::planned("tiny")],
        arenas: 1,
        workers: 1,
        queue_capacity: 1,
        requests,
        rate: 1e6, // ~1 µs arrival gaps into a 1-deep queue
        seed: 11,
        jobs: 1,
        ..FleetConfig::default()
    })
    .unwrap();
    assert_eq!(
        report.completed as u64 + report.shed as u64,
        requests,
        "every request is either served or counted shed"
    );
    assert!(
        report.shed > 0,
        "a 1-deep queue under µs arrivals must shed (completed {})",
        report.completed
    );
    let m = &report.per_model[0];
    assert_eq!(m.shed, report.shed);
    assert_eq!(m.shed, m.metrics.shed, "ModelReport.shed reads Metrics.shed");
    assert_eq!(m.completed, report.completed);
}

fn submit_blocking(fleet: &Fleet, id: u64, data: Vec<f32>, tx: &mpsc::Sender<FleetReply>) {
    let ok = fleet.submit(
        0,
        FleetRequest {
            id,
            data,
            enqueued: Instant::now(),
            attempts_left: 0,
            reply: tx.clone(),
        },
        AdmissionPolicy::Block,
    );
    assert!(ok, "blocking submit on an open fleet cannot fail");
}

/// A valid re-plan swapped in mid-stream: zero replies lost across the
/// swap, requests executed after it see the new generation, and the
/// registry immediately reports the new arena size.
#[test]
fn hot_reload_mid_stream_drops_nothing_and_swaps_generation() {
    let reg = Registry::load(&[ModelSpec::planned("tiny")], 2, 1, SEED).unwrap();
    let fleet = Fleet::start(reg, 2, 64);
    let elems = fleet.registry.current(0).input_elements();
    let (tx, rx) = mpsc::channel::<FleetReply>();

    for id in 0..100u64 {
        submit_blocking(&fleet, id, deterministic_input(elems, id), &tx);
    }
    let before: Vec<FleetReply> = (0..100).map(|_| rx.recv().unwrap()).collect();
    assert!(
        before.iter().all(|r| r.generation == 0),
        "pre-reload replies all come from generation 0"
    );

    // a different planning session over the same graph — same
    // fingerprint, a valid hot-reload
    let g = dmo::models::build("tiny").unwrap();
    let replan = Planner::for_graph(&g)
        .dmo(true)
        .strategies(&[Strategy::Eager])
        .plan()
        .unwrap();
    let info = fleet.reload(0, PlanArtifact::from_plan(&g, &replan)).unwrap();
    assert_eq!(info.generation, 1);
    assert_eq!(
        fleet.registry.current(0).plan.peak(),
        info.new_peak,
        "new requests see the new generation's arena size immediately"
    );

    for id in 100..200u64 {
        submit_blocking(&fleet, id, deterministic_input(elems, id), &tx);
    }
    drop(tx);
    let after: Vec<FleetReply> = rx.iter().collect();
    assert_eq!(after.len(), 100, "zero replies lost across the swap");
    assert!(
        after.iter().all(|r| r.generation == 1),
        "post-reload submissions execute on generation 1"
    );

    let down = fleet.shutdown().unwrap();
    assert!(down.worker_errors.is_empty());
    let reports = down.per_model;
    assert_eq!(reports[0].completed, 200, "completed == requests - shed");
    assert_eq!(reports[0].shed, 0);
    assert_eq!(reports[0].generation, 1);
    assert_eq!(reports[0].reloads, 1);
}

/// A stale-fingerprint artifact (planned for a different graph) is
/// rejected without killing the server: the old generation keeps
/// serving and the slot records no reload.
#[test]
fn stale_fingerprint_artifact_is_rejected_and_serving_continues() {
    let reg = Registry::load(&[ModelSpec::planned("tiny")], 1, 1, SEED).unwrap();
    let fleet = Fleet::start(reg, 1, 8);
    let elems = fleet.registry.current(0).input_elements();
    let (tx, rx) = mpsc::channel::<FleetReply>();
    submit_blocking(&fleet, 0, deterministic_input(elems, 0), &tx);
    assert_eq!(rx.recv().unwrap().generation, 0);

    let other = dmo::models::build("tiny_wide").unwrap();
    let plan = Planner::for_graph(&other).dmo(true).plan().unwrap();
    let err = fleet.reload(0, PlanArtifact::from_plan(&other, &plan));
    assert!(err.is_err(), "cross-model artifact must be rejected");

    // the server is alive and still on generation 0
    submit_blocking(&fleet, 1, deterministic_input(elems, 1), &tx);
    drop(tx);
    let reply = rx.recv().unwrap();
    assert_eq!(reply.generation, 0, "old generation keeps serving");

    let reports = fleet.shutdown().unwrap().per_model;
    assert_eq!(reports[0].completed, 2);
    assert_eq!(reports[0].generation, 0);
    assert_eq!(reports[0].reloads, 0);
}

/// `--reload-watch` end to end: dropping a re-planned artifact into the
/// watched directory hot-swaps the generation; dropping a mismatched
/// one afterwards is rejected while the server keeps serving.
#[test]
fn reload_watch_picks_up_artifact_drops() {
    let dir = std::env::temp_dir().join(format!("dmo_fleet_watch_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let artifact_path = dir.join("tiny.plan.json");
    let _ = std::fs::remove_file(&artifact_path);

    let reg = Registry::load(&[ModelSpec::planned("tiny")], 1, 1, SEED).unwrap();
    let mut fleet = Fleet::start(reg, 1, 8);
    fleet.watch(dir.clone(), Duration::from_millis(10));

    let g = dmo::models::build("tiny").unwrap();
    let replan = Planner::for_graph(&g)
        .dmo(true)
        .strategies(&[Strategy::Lazy])
        .plan()
        .unwrap();
    PlanArtifact::from_plan(&g, &replan).save(&artifact_path).unwrap();

    // the watcher validates off the serving path; poll for the swap
    let deadline = Instant::now() + Duration::from_secs(30);
    while fleet.registry.current(0).generation != 1 {
        assert!(
            Instant::now() < deadline,
            "watcher did not pick up the artifact drop in time"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // now a stale artifact lands in the same file: rejected, server fine
    let other = dmo::models::build("tiny_int8").unwrap();
    let bad = Planner::for_graph(&other).dmo(true).plan().unwrap();
    PlanArtifact::from_plan(&other, &bad).save(&artifact_path).unwrap();
    std::thread::sleep(Duration::from_millis(200));
    assert_eq!(
        fleet.registry.current(0).generation,
        1,
        "rejected artifact must not change the serving generation"
    );

    let elems = fleet.registry.current(0).input_elements();
    let (tx, rx) = mpsc::channel::<FleetReply>();
    submit_blocking(&fleet, 0, deterministic_input(elems, 9), &tx);
    drop(tx);
    assert_eq!(rx.recv().unwrap().generation, 1, "server still serving post-rejection");

    let reports = fleet.shutdown().unwrap().per_model;
    assert_eq!(reports[0].reloads, 1);
    let _ = std::fs::remove_dir_all(&dir);
}
