//! Tensor shapes.
//!
//! Shapes follow the TFLite convention used throughout the paper:
//! 4-D activation tensors are NHWC (`[batch, height, width, channels]`)
//! and all models here run with `batch == 1`. Lower-rank tensors (FC
//! activations, softmax rows) are stored as-is.

use std::fmt;

/// A tensor shape (row-major / last-axis-fastest, as in TFLite).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    /// New shape from dims.
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    /// NHWC activation shape with batch 1.
    pub fn hwc(h: usize, w: usize, c: usize) -> Self {
        Shape(vec![1, h, w, c])
    }

    /// Rank-1 vector.
    pub fn vec1(n: usize) -> Self {
        Shape(vec![n])
    }

    /// Number of axes.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Total element count.
    pub fn num_elements(&self) -> usize {
        self.0.iter().product()
    }

    /// Dim at axis `i`.
    #[inline]
    pub fn dim(&self, i: usize) -> usize {
        self.0[i]
    }

    /// Height of an NHWC activation.
    #[inline]
    pub fn h(&self) -> usize {
        debug_assert_eq!(self.rank(), 4, "h() needs NHWC");
        self.0[1]
    }

    /// Width of an NHWC activation.
    #[inline]
    pub fn w(&self) -> usize {
        debug_assert_eq!(self.rank(), 4, "w() needs NHWC");
        self.0[2]
    }

    /// Channels of an NHWC activation.
    #[inline]
    pub fn c(&self) -> usize {
        debug_assert_eq!(self.rank(), 4, "c() needs NHWC");
        self.0[3]
    }

    /// Row-major element offset of NHWC coordinate `(y, x, c)` (batch 0).
    ///
    /// This is the paper's `Offset(r, c, d) = (r·I_w + c)·I_d + d` (Eq 4).
    #[inline]
    pub fn offset_hwc(&self, y: usize, x: usize, c: usize) -> usize {
        (y * self.w() + x) * self.c() + c
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elements_and_accessors() {
        let s = Shape::hwc(112, 96, 32);
        assert_eq!(s.rank(), 4);
        assert_eq!(s.num_elements(), 112 * 96 * 32);
        assert_eq!((s.h(), s.w(), s.c()), (112, 96, 32));
    }

    #[test]
    fn offset_matches_eq4() {
        let s = Shape::hwc(8, 5, 3);
        // Offset(r, c, d) = (r*I_w + c)*I_d + d
        assert_eq!(s.offset_hwc(2, 3, 1), (2 * 5 + 3) * 3 + 1);
        assert_eq!(s.offset_hwc(0, 0, 0), 0);
        assert_eq!(s.offset_hwc(7, 4, 2), s.num_elements() - 1);
    }

    #[test]
    fn display() {
        assert_eq!(Shape::new(&[2, 3]).to_string(), "[2, 3]");
    }
}
