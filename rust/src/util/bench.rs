//! Minimal benchmarking helper (criterion is not in the vendored set).
//!
//! `cargo bench` runs each `benches/*.rs` as a plain binary; this module
//! gives them consistent measurement (median-of-N wall times with spread)
//! and table formatting.

use std::time::{Duration, Instant};

/// One measured result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: usize,
}

impl Measurement {
    pub fn per_iter_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }
}

/// Time `f` `iters` times (after one warmup), reporting the median.
pub fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> Measurement {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    Measurement {
        name: name.to_string(),
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
        iters: iters.max(1),
    }
}

/// Pretty duration.
pub fn fmt_dur(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1.0 {
        format!("{:.0} ns", us * 1000.0)
    } else if us < 1000.0 {
        format!("{us:.1} µs")
    } else if us < 1_000_000.0 {
        format!("{:.2} ms", us / 1000.0)
    } else {
        format!("{:.2} s", us / 1_000_000.0)
    }
}

/// Print a measurement row.
pub fn report(m: &Measurement) {
    println!(
        "{:48} {:>12} (min {:>10}, max {:>10}, n={})",
        m.name,
        fmt_dur(m.median),
        fmt_dur(m.min),
        fmt_dur(m.max),
        m.iters
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let m = time("spin", 3, || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert!(m.median > Duration::ZERO);
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_dur(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
    }
}
