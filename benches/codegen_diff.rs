//! Release-mode acceptance sweep for the C backend: every zoo model is
//! planned (full §IV sweep, DMO on), emitted as a standalone C99 unit,
//! compiled with the strict flag set, executed, and diffed bit-for-bit
//! against `interp::run_reference`. This is the `differential_full_zoo`
//! test from `rust/tests/codegen_c.rs` at a speed where the big CNNs
//! (Inception v4 runs ~6 GMACs per inference) are tractable.
//!
//! Skips — never fails — when the machine has no C toolchain.

use dmo::codegen::{cc_available, differential_test};
use dmo::models;
use dmo::planner::Planner;
use std::time::Instant;

fn main() {
    let Some(cc) = cc_available() else {
        println!("SKIP: no C compiler on PATH (install gcc or set $CC)");
        return;
    };
    println!("=== emitted-C differential sweep (compiler: {cc}) ===\n");
    let mut names = models::table3_names();
    names.extend(["tiny", "tiny_int8"]);
    let mut failures = 0;
    for name in names {
        let t0 = Instant::now();
        let g = models::build(name).unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        match differential_test(&g, &plan, 42) {
            Ok(r) => println!(
                "{name:32} PASS  {:>7} elems  arena {:>9} B  weights {}  ({:.1?})",
                r.elems,
                r.arena_bytes,
                if r.weights_embedded { "embedded " } else { "generated" },
                t0.elapsed()
            ),
            Err(e) => {
                failures += 1;
                println!("{name:32} FAIL  {e:#}");
            }
        }
    }
    assert_eq!(failures, 0, "{failures} models diverged from the reference");
    println!("\nall zoo models: emitted C is bit-identical to the interpreter");
}
