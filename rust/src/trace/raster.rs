//! Down-sampling event raster — records a full execution's memory events
//! into a fixed time×address grid so whole-model traces (tens of millions
//! of events) stay bounded.

use crate::ops::exec::{EventKind, EventSink};

/// Per-cell event counts.
#[derive(Debug, Clone, Copy, Default)]
pub struct Cell {
    pub loads: u32,
    pub stores: u32,
    pub updates: u32,
}

impl Cell {
    pub fn total(&self) -> u32 {
        self.loads + self.stores + self.updates
    }

    /// Dominant event class for colouring (paper: load=red, store=blue,
    /// update=green).
    pub fn dominant(&self) -> Option<EventKind> {
        if self.total() == 0 {
            return None;
        }
        if self.updates >= self.loads && self.updates >= self.stores {
            Some(EventKind::Update)
        } else if self.loads >= self.stores {
            Some(EventKind::Load)
        } else {
            Some(EventKind::Store)
        }
    }
}

/// A time × memory grid of event counts.
///
/// Time advances by one tick per event (the paper's x-axis is
/// instructions; event count is the deterministic analogue our
/// instrumentation exposes). Two passes are typical: one to count events
/// (`total_events`), one to rasterise with the right scale.
pub struct RasterSink {
    /// grid[t][m]
    pub grid: Vec<Vec<Cell>>,
    pub t_buckets: usize,
    pub m_buckets: usize,
    /// arena bytes represented per memory bucket
    pub bytes_per_bucket: f64,
    /// events represented per time bucket
    pub events_per_bucket: f64,
    tick: u64,
}

impl RasterSink {
    /// `arena_bytes` across `m_buckets` columns; `expected_events` across
    /// `t_buckets` rows.
    pub fn new(arena_bytes: usize, expected_events: u64, t_buckets: usize, m_buckets: usize) -> Self {
        RasterSink {
            grid: vec![vec![Cell::default(); m_buckets]; t_buckets],
            t_buckets,
            m_buckets,
            bytes_per_bucket: (arena_bytes.max(1) as f64) / m_buckets as f64,
            events_per_bucket: (expected_events.max(1) as f64) / t_buckets as f64,
            tick: 0,
        }
    }

    fn bucket(&self, addr: usize) -> usize {
        ((addr as f64 / self.bytes_per_bucket) as usize).min(self.m_buckets - 1)
    }

    /// Render as a portable graymap (P2) with class-coded intensities:
    /// 0 = empty, loads dark, stores mid, updates bright.
    pub fn to_pgm(&self) -> String {
        let mut s = format!("P2\n{} {}\n255\n", self.m_buckets, self.t_buckets);
        for row in &self.grid {
            let mut line = String::new();
            for c in row {
                let v = match c.dominant() {
                    None => 0,
                    Some(EventKind::Load) => 90,
                    Some(EventKind::Store) => 170,
                    Some(EventKind::Update) => 255,
                };
                line.push_str(&format!("{v} "));
            }
            line.push('\n');
            s.push_str(&line);
        }
        s
    }

    /// Compact ASCII view (`.` empty, `L` load, `S` store, `U` update).
    pub fn to_ascii(&self) -> String {
        let mut s = String::new();
        for row in &self.grid {
            for c in row {
                s.push(match c.dominant() {
                    None => '.',
                    Some(EventKind::Load) => 'L',
                    Some(EventKind::Store) => 'S',
                    Some(EventKind::Update) => 'U',
                });
            }
            s.push('\n');
        }
        s
    }

    /// CSV rows `t_bucket,m_bucket,loads,stores,updates`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("t,m,loads,stores,updates\n");
        for (t, row) in self.grid.iter().enumerate() {
            for (m, c) in row.iter().enumerate() {
                if c.total() > 0 {
                    s.push_str(&format!("{t},{m},{},{},{}\n", c.loads, c.stores, c.updates));
                }
            }
        }
        s
    }
}

impl EventSink for RasterSink {
    fn event(&mut self, kind: EventKind, addr: usize, _len: usize) {
        let t = ((self.tick as f64 / self.events_per_bucket) as usize).min(self.t_buckets - 1);
        let m = self.bucket(addr);
        let cell = &mut self.grid[t][m];
        match kind {
            EventKind::Load => cell.loads += 1,
            EventKind::Store => cell.stores += 1,
            EventKind::Update => cell.updates += 1,
        }
        self.tick += 1;
    }
}

/// Count the events an execution will produce (first pass).
#[derive(Debug, Default)]
pub struct EventCounter {
    pub count: u64,
}

impl EventSink for EventCounter {
    fn event(&mut self, _kind: EventKind, _addr: usize, _len: usize) {
        self.count += 1;
    }
}

/// Shared handle so counters/rasters can be recovered after execution.
#[derive(Default)]
pub struct Shared<T>(pub std::sync::Arc<std::sync::Mutex<T>>);

// manual impl: Arc handles are clonable regardless of T
impl<T> Clone for Shared<T> {
    fn clone(&self) -> Self {
        Shared(self.0.clone())
    }
}

impl<T> Shared<T> {
    pub fn new(v: T) -> Self {
        Shared(std::sync::Arc::new(std::sync::Mutex::new(v)))
    }
}

impl<T: EventSink> EventSink for Shared<T> {
    fn event(&mut self, kind: EventKind, addr: usize, len: usize) {
        crate::util::sync::lock(&self.0).event(kind, addr, len);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raster_buckets_events() {
        let mut r = RasterSink::new(100, 10, 5, 10);
        for i in 0..10u64 {
            r.event(EventKind::Load, (i * 10) as usize, 1);
        }
        // diagonal: event i lands in t=i/2, m=i
        assert_eq!(r.grid[0][0].loads, 1);
        assert_eq!(r.grid[4][9].loads, 1);
        let ascii = r.to_ascii();
        assert!(ascii.contains('L'));
        assert_eq!(ascii.lines().count(), 5);
    }

    #[test]
    fn pgm_header() {
        let r = RasterSink::new(10, 10, 3, 4);
        let pgm = r.to_pgm();
        assert!(pgm.starts_with("P2\n4 3\n255\n"));
    }

    #[test]
    fn dominant_class() {
        let mut c = Cell::default();
        assert_eq!(c.dominant(), None);
        c.loads = 2;
        c.stores = 1;
        assert_eq!(c.dominant(), Some(EventKind::Load));
        c.updates = 5;
        assert_eq!(c.dominant(), Some(EventKind::Update));
    }
}
