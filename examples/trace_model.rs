//! Memory-trace tour: regenerate the paper's instrumentation artefacts
//! for one model — the Fig 1 allocation map, the Fig 2 access-pattern
//! rasters (original vs DMO), and the Fig 3 per-op traces — then print a
//! compact ASCII version of each.
//!
//! ```sh
//! cargo run --release --example trace_model [model]
//! ```

use dmo::ir::op::{Activation, DepthwiseParams, OpKind, Padding, UnaryKind};
use dmo::ir::{DType, Shape};
use dmo::models;
use dmo::planner::Planner;
use dmo::report::fmt_bytes;
use dmo::trace::render::{alloc_map_ascii, model_raster, op_raster};

fn main() -> anyhow::Result<()> {
    let name = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "mobilenet_v1_0.25_128_int8".to_string());
    let g = models::build(&name)?;

    let base = Planner::for_graph(&g).plan()?;
    let opt = Planner::for_graph(&g).dmo(true).plan()?;

    println!("== Fig 1: heap allocation map ({name}) ==");
    println!("{}", alloc_map_ascii(&g, &base, 96));

    println!("== Fig 2a: access pattern, original layout ({}) ==", fmt_bytes(base.peak()));
    let ra = model_raster(&g, &base, 7, 36, 96)?;
    println!("{}", ra.to_ascii());

    println!("== Fig 2b: access pattern, DMO layout ({}) ==", fmt_bytes(opt.peak()));
    let rb = model_raster(&g, &opt, 7, 36, 96)?;
    println!("{}", rb.to_ascii());

    println!("== Fig 3a: relu (perfectly diagonal) ==");
    let relu = op_raster(
        &OpKind::Unary(UnaryKind::Relu),
        &[&Shape::hwc(16, 16, 4)],
        DType::F32,
        24,
        72,
    )?;
    println!("{}", relu.to_ascii());

    println!("== Fig 3c: depthwise conv (diagonal with halo) ==");
    let dw = op_raster(
        &OpKind::DepthwiseConv2D(DepthwiseParams {
            kernel: (3, 3),
            stride: (1, 1),
            dilation: (1, 1),
            padding: Padding::Same,
            depth_multiplier: 1,
            act: Activation::None,
        }),
        &[&Shape::hwc(16, 16, 4)],
        DType::F32,
        24,
        72,
    )?;
    println!("{}", dw.to_ascii());

    println!("== Fig 3b: accumulating matmul (no overlap possible) ==");
    let mm = op_raster(
        &OpKind::MatMulAccum { out_features: 48 },
        &[&Shape::new(&[1, 64])],
        DType::F32,
        24,
        72,
    )?;
    println!("{}", mm.to_ascii());

    println!("legend: L load, S store, U update, . untouched (time ↓, memory →)");
    Ok(())
}
