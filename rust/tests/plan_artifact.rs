//! Plan-artifact round-trip tests: save → load → revalidate must
//! reproduce the plan byte-for-byte for every model in the Table III
//! zoo, and a corrupted graph fingerprint must be refused with
//! [`PlanError::GraphMismatch`].
//!
//! The full strategy × heuristic sweep is exercised elsewhere
//! (`table_reproduction.rs`); here the planner session is narrowed to a
//! single candidate per model so the whole catalog stays fast — the
//! artifact layer is what is under test, not the search.

use dmo::models;
use dmo::planner::{
    graph_fingerprint, Heuristic, PlanArtifact, PlanError, Planner, Strategy,
};
use dmo::util::json::Json;
use std::path::PathBuf;

/// Narrow, fast planning session used across the zoo.
fn quick_plan(g: &dmo::ir::Graph) -> dmo::planner::Plan {
    Planner::for_graph(g)
        .dmo(true)
        .method(dmo::overlap::Method::Analytic) // O(1) per op, exactness irrelevant here
        .strategies(&[Strategy::Eager])
        .heuristics(&[Heuristic::SizeDesc])
        .plan()
        .unwrap()
}

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dmo_plan_artifact_test_{name}.json"))
}

#[test]
fn roundtrip_all_zoo_models() {
    for name in models::table3_names() {
        let g = models::build(name).unwrap();
        let plan = quick_plan(&g);
        let art = PlanArtifact::from_plan(&g, &plan);

        let path = tmp_path(name);
        art.save(&path).unwrap_or_else(|e| panic!("{name}: save: {e}"));
        let loaded = PlanArtifact::load(&path).unwrap_or_else(|e| panic!("{name}: load: {e}"));
        std::fs::remove_file(&path).ok();
        assert_eq!(art, loaded, "{name}: artifact must round-trip losslessly");

        let re = loaded
            .to_plan(&g)
            .unwrap_or_else(|e| panic!("{name}: revalidate: {e}"));
        assert_eq!(re.peak(), plan.peak(), "{name}: peak");
        assert_eq!(re.order, plan.order, "{name}: exec order");
        assert_eq!(re.alloc.offsets, plan.alloc.offsets, "{name}: offsets");
        assert_eq!(re.alloc.applied, plan.alloc.applied, "{name}: overlaps");
        assert_eq!(re.strategy, plan.strategy, "{name}: strategy");
        assert_eq!(re.heuristic, plan.heuristic, "{name}: heuristic");
        assert_eq!(re.os.per_op, plan.os.per_op, "{name}: O_s table");
    }
}

#[test]
fn corrupted_fingerprint_is_a_graph_mismatch() {
    let g = models::build("tiny").unwrap();
    let plan = quick_plan(&g);
    let mut art = PlanArtifact::from_plan(&g, &plan);
    art.fingerprint ^= 0xDEAD_BEEF;
    match art.to_plan(&g) {
        Err(PlanError::GraphMismatch { expected, found }) => {
            assert_ne!(expected, found);
        }
        other => panic!("expected GraphMismatch, got {other:?}"),
    }
}

#[test]
fn corrupted_fingerprint_in_the_file_is_caught_too() {
    // end-to-end through JSON: flip the stored fingerprint on disk
    let g = models::build("tiny").unwrap();
    let plan = quick_plan(&g);
    let art = PlanArtifact::from_plan(&g, &plan);
    let text = art
        .to_json()
        .to_string()
        .replace(&format!("{:016x}", art.fingerprint), &format!("{:016x}", !art.fingerprint));
    let tampered = PlanArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert!(matches!(
        tampered.to_plan(&g),
        Err(PlanError::GraphMismatch { .. })
    ));
}

#[test]
fn artifact_is_graph_specific_not_name_specific() {
    // same model name, different structure (dtype) ⇒ different
    // fingerprint ⇒ mismatch
    let f32_graph = models::build("tiny").unwrap();
    let mut i8_graph = models::build("tiny_int8").unwrap();
    i8_graph.name = f32_graph.name.clone();
    assert_ne!(graph_fingerprint(&f32_graph), graph_fingerprint(&i8_graph));
    let art = PlanArtifact::from_plan(&f32_graph, &quick_plan(&f32_graph));
    assert!(matches!(
        art.to_plan(&i8_graph),
        Err(PlanError::GraphMismatch { .. })
    ));
}

#[test]
fn garbage_files_are_malformed_not_panics() {
    let path = tmp_path("garbage");
    std::fs::write(&path, "{\"kind\":\"something-else\"}").unwrap();
    assert!(matches!(
        PlanArtifact::load(&path),
        Err(PlanError::Malformed(_))
    ));
    std::fs::write(&path, "not json at all").unwrap();
    assert!(matches!(
        PlanArtifact::load(&path),
        Err(PlanError::Malformed(_))
    ));
    std::fs::remove_file(&path).ok();
    assert!(matches!(PlanArtifact::load(&path), Err(PlanError::Io(_))));
}

#[test]
fn loaded_artifact_survives_the_interpreter_proof() {
    // the acceptance path: export, import, execute-and-prove
    let g = models::build("mobilenet_v1_0.25_128_int8").unwrap();
    let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
    let path = tmp_path("acceptance");
    PlanArtifact::from_plan(&g, &plan).save(&path).unwrap();
    let loaded = PlanArtifact::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let out = dmo::interp::run_planned_artifact(&g, &loaded, 42).unwrap();
    assert_eq!(out.len(), g.outputs.len());
}
