//! Bench: plan-artifact reuse vs re-planning.
//!
//! The point of [`dmo::planner::PlanArtifact`] is §II-D made concrete:
//! the strategy × direction × heuristic search (plus the exact `O_s`
//! table build, which walks every window op's step stream) runs once,
//! offline; every serving worker then loads the artifact and only pays
//! fingerprint + overlap-safety revalidation. This bench measures both
//! sides of that trade on a mid-size and a large model and asserts the
//! reuse path is at least 10× faster.

use dmo::models;
use dmo::planner::{PlanArtifact, Planner};
use dmo::util::bench::{fmt_dur, report, time};

fn main() {
    println!("=== plan reuse: full search vs artifact load + revalidate ===\n");
    let mut worst_speedup = f64::INFINITY;
    for name in ["mobilenet_v1_1.0_224", "densenet_121"] {
        let g = models::build(name).unwrap();
        println!("-- {name} ({} ops, {} tensors)", g.ops.len(), g.tensors.len());

        let m_search = time("full planner search (DMO)", 3, || {
            std::hint::black_box(Planner::for_graph(&g).dmo(true).plan().unwrap());
        });
        report(&m_search);

        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let path = std::env::temp_dir().join(format!("dmo_artifact_bench_{name}.json"));
        PlanArtifact::from_plan(&g, &plan).save(&path).unwrap();

        let m_reuse = time("artifact load + revalidate", 10, || {
            let art = PlanArtifact::load(&path).unwrap();
            let re = art.to_plan(&g).unwrap();
            std::hint::black_box(re);
        });
        report(&m_reuse);

        let speedup = m_search.median.as_secs_f64() / m_reuse.median.as_secs_f64().max(1e-9);
        worst_speedup = worst_speedup.min(speedup);
        println!(
            "  reuse speedup: {speedup:.1}× ({} vs {})\n",
            fmt_dur(m_search.median),
            fmt_dur(m_reuse.median)
        );
        let _ = std::fs::remove_file(&path);
    }
    println!("worst-case speedup across models: {worst_speedup:.1}×");
    assert!(
        worst_speedup >= 10.0,
        "plan reuse must be ≥10× faster than re-planning, got {worst_speedup:.1}×"
    );
}
