//! Quickstart: plan a model with and without DMO, inspect the overlaps,
//! and *prove* the optimised layout safe by executing it.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dmo::interp::validate_plan;
use dmo::models;
use dmo::planner::{plan_graph, PlanOptions};
use dmo::report::fmt_bytes;
use dmo::trace::render::alloc_map_ascii;

fn main() -> anyhow::Result<()> {
    // The paper's running example: the smallest deployable MobileNet.
    let graph = models::build("mobilenet_v1_0.25_128_int8")?;
    println!(
        "model: {} ({} ops, {} weights)\n",
        graph.name,
        graph.ops.len(),
        fmt_bytes(graph.weight_bytes())
    );

    // 1. baseline pre-allocation (modified heap, §IV)
    let base = plan_graph(&graph, PlanOptions::baseline());
    println!("baseline arena : {}", fmt_bytes(base.peak()));

    // 2. diagonal memory optimisation (§II-D)
    let opt = plan_graph(&graph, PlanOptions::dmo());
    println!("DMO arena      : {}", fmt_bytes(opt.peak()));
    println!(
        "saving         : {:.1}%  ({} overlapped buffer pairs)\n",
        100.0 * (base.peak() - opt.peak()) as f64 / base.peak() as f64,
        opt.alloc.applied.len()
    );

    for a in opt.alloc.applied.iter().take(5) {
        println!(
            "  {:>22} starts inside the tail of {:<22} sharing {}",
            graph.tensor(a.input).name,
            graph.tensor(a.output).name,
            fmt_bytes(a.bytes)
        );
    }

    // 3. safety proof: run the model inside the overlapped arena and
    //    compare bit-for-bit with a disjoint-buffer execution.
    validate_plan(&graph, &opt, 2024)?;
    println!("\nvalidated: planned execution is bit-identical to the reference ✓");

    // 4. the allocation map (Fig 1/2b style)
    println!("\n{}", alloc_map_ascii(&graph, &opt, 96));
    Ok(())
}
