//! Intermediate representation of tensor-op graphs.
//!
//! A [`Graph`] is a flat list of [`OpNode`]s in *builder* order (a valid
//! execution order), plus a table of [`TensorInfo`] values they produce and
//! consume. The planner may re-serialise ops into other valid orders
//! (see [`crate::planner::order`]); everything downstream (scope analysis,
//! allocation, execution, tracing) works from an explicit
//! [`ExecOrder`](crate::planner::order::ExecOrder).

pub mod dtype;
pub mod graph;
pub mod op;
pub mod rewrite;
pub mod shape;

pub use dtype::DType;
pub use graph::{Graph, GraphBuilder, OpId, OpNode, TensorId, TensorInfo, TensorKind, WeightInfo};
pub use op::{Activation, BandParams, OpKind, Padding};
pub use rewrite::{apply, split_chain, split_pair, Provenance, RewriteSpec, SplitSpec};
pub use shape::Shape;
