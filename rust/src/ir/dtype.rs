//! Element data types.
//!
//! The paper evaluates both float32 models and 8-bit quantised variants
//! (Table III); the only property the planner needs is the element size,
//! while the interpreter needs real arithmetic for both.

use std::fmt;

/// Tensor element type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// IEEE-754 single precision.
    F32,
    /// 8-bit signed quantised (TFLite-style, symmetric per-tensor scale).
    I8,
    /// 32-bit signed integer (bias / accumulator tensors).
    I32,
}

impl DType {
    /// Size of one element in bytes — the paper's `T_s`.
    #[inline]
    pub const fn size_bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::I8 => 1,
            DType::I32 => 4,
        }
    }

    /// Short lowercase name used in reports and JSON sidecars.
    pub const fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I8 => "i8",
            DType::I32 => "i32",
        }
    }

    /// Parse from the name produced by [`DType::name`].
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" | "float32" => Some(DType::F32),
            "i8" | "int8" => Some(DType::I8),
            "i32" | "int32" => Some(DType::I32),
            _ => None,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::I8.size_bytes(), 1);
        assert_eq!(DType::I32.size_bytes(), 4);
    }

    #[test]
    fn parse_roundtrip() {
        for d in [DType::F32, DType::I8, DType::I32] {
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::parse("f16"), None);
    }
}
