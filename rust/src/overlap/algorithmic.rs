//! The §III-C *algorithmic method*: the op's loop nest with value
//! computation removed, folding read/write offsets into `O_s`.
//!
//! Two implementations:
//!
//! * [`os_paper_arrays`] — the faithful transcription of the paper's
//!   Algorithm 2: materialise `minR` / `maxW` arrays of length `Steps`,
//!   reverse-pass to enforce "minimum of all future iterations", then
//!   fold `minD`.
//! * [`os_streaming`] — an `O(1)`-memory equivalent. Because `maxW[i]` is
//!   a running maximum (monotone non-decreasing),
//!   `min_i (minR[i] − maxW[i]) = min_i (r_i − maxW[i])` where `r_i` is
//!   the *raw* minimum read of step `i` alone — so a single forward pass
//!   suffices. The test suite proves the two agree on every op family;
//!   the equivalence is also an ablation entry in `benches/os_methods.rs`.

use super::{os_from_mind, SafeOverlap};
use crate::ir::op::OpKind;
use crate::ir::shape::Shape;
use crate::ir::DType;
use crate::ops::access::{for_each_step, step_count};

/// Streaming algorithmic `O_s` (exact, one forward pass, no arrays).
///
/// Window ops with position-constant read sets (conv2d, dwconv with
/// depth multiplier 1, pooling) collapse to one fold step per *spatial
/// position* instead of per element — within a position the reads' lower
/// envelope is constant while writes ascend, so `minR − maxW` is minimal
/// at the position's last step (§III-C notes the same simplification).
/// `os_paper_arrays` keeps element granularity; the test suite proves the
/// two agree on randomized sweeps. This fast path took the full-catalog
/// `OsTable` build from ~10 ms to µs per model (EXPERIMENTS.md §Perf).
pub fn os_streaming(
    kind: &OpKind,
    in_shapes: &[&Shape],
    out_shape: &Shape,
    dtype: DType,
) -> SafeOverlap {
    if let Some(min_d) = positional_min_d(kind, in_shapes, out_shape) {
        return finish(vec![min_d], in_shapes, out_shape, dtype);
    }
    let n_in = in_shapes.len();
    let mut max_w: i64 = i64::MIN;
    let mut min_d = vec![i64::MAX; n_in];
    for_each_step(kind, in_shapes, out_shape, &mut |w, reads| {
        // reads of a step precede its write, but the paper's Algorithm 2
        // pairs minR[i] against maxW[i] *including* step i's write; we
        // reproduce that (conservative by design, see §III-A).
        max_w = max_w.max(w as i64);
        for (j, r) in reads.iter().enumerate() {
            if let Some(r) = r {
                min_d[j] = min_d[j].min(*r as i64 - max_w);
            }
        }
    });
    finish(min_d, in_shapes, out_shape, dtype)
}

/// Algorithm 2 exactly as printed: arrays `minR`/`maxW` of length `Steps`,
/// reverse pass, fold. Use [`os_streaming`] for large ops.
pub fn os_paper_arrays(
    kind: &OpKind,
    in_shapes: &[&Shape],
    out_shape: &Shape,
    dtype: DType,
) -> SafeOverlap {
    let n_in = in_shapes.len();
    let steps = step_count(kind, in_shapes, out_shape);
    // minR[j][i], maxW[i]
    let mut min_r = vec![vec![i64::MAX; steps]; n_in];
    let mut max_w = vec![0i64; steps]; // filled below
    let mut max_f: i64 = i64::MIN;
    let mut it = 0usize;
    for_each_step(kind, in_shapes, out_shape, &mut |w, reads| {
        for (j, r) in reads.iter().enumerate() {
            min_r[j][it] = r.map(|r| r as i64).unwrap_or(i64::MAX);
        }
        max_f = max_f.max(w as i64);
        max_w[it] = max_f;
        it += 1;
    });
    debug_assert_eq!(it, steps);
    // reverse pass: minR[i] = min(minR[i], minR[i+1..])
    let mut min_d = vec![i64::MAX; n_in];
    for (j, col) in min_r.iter_mut().enumerate() {
        let mut run = i64::MAX;
        for i in (0..steps).rev() {
            run = run.min(col[i]);
            col[i] = run;
            if run != i64::MAX {
                min_d[j] = min_d[j].min(run - max_w[i]);
            }
        }
    }
    finish(min_d, in_shapes, out_shape, dtype)
}

/// Position-granular exact `minD` for window ops whose per-step minimum
/// read is constant across the channel sweep of a spatial position.
/// Returns `None` for kinds that need the generic element stream.
fn positional_min_d(kind: &OpKind, in_shapes: &[&Shape], out_shape: &Shape) -> Option<i64> {
    use crate::ir::op::pad_before;
    // (kernel, stride, dilation, steps-per-position, read offset of the
    //  position's min cell for the *lowest* channel step)
    let (kernel, stride, dilation, per_pos, dw_like) = match kind {
        OpKind::Conv2D(p) => (p.kernel, p.stride, p.dilation, out_shape.c(), false),
        OpKind::DepthwiseConv2D(p) if p.depth_multiplier == 1 => {
            (p.kernel, p.stride, p.dilation, out_shape.c(), true)
        }
        OpKind::Pool(p) => (p.kernel, p.stride, (1, 1), out_shape.c(), true),
        _ => return None,
    };
    let xs = in_shapes[0];
    let (ih, iw, id) = (xs.h(), xs.w(), xs.c());
    let (oh, ow) = (out_shape.h(), out_shape.w());
    let ph = pad_before(ih, oh, kernel.0, stride.0, dilation.0) as isize;
    let pw = pad_before(iw, ow, kernel.1, stride.1, dilation.1) as isize;
    let min_cell = |o: usize, s: usize, pad: isize, k: usize, d: usize, lim: usize| -> Option<usize> {
        let base = o as isize * s as isize - pad;
        (0..k)
            .map(|t| base + (t * d) as isize)
            .find(|&v| v >= 0 && (v as usize) < lim)
            .map(|v| v as usize)
    };
    // per-row min cells are reusable across the row sweep
    let y_min: Vec<Option<usize>> = (0..oh)
        .map(|oy| min_cell(oy, stride.0, ph, kernel.0, dilation.0, ih))
        .collect();
    let x_min: Vec<Option<usize>> = (0..ow)
        .map(|ox| min_cell(ox, stride.1, pw, kernel.1, dilation.1, iw))
        .collect();
    let c = per_pos as i64;
    let mut suffix = i64::MAX; // min read over future positions (lowest channel)
    let mut min_d = i64::MAX;
    for pos in (0..oh * ow).rev() {
        let (oy, ox) = (pos / ow, pos % ow);
        let own = match (y_min[oy], x_min[ox]) {
            (Some(y), Some(x)) => Some(((y * iw + x) * id) as i64),
            _ => None,
        };
        let i_last = pos as i64 * c + (c - 1);
        // constraint from this position's own reads: for dw/pool the read
        // tracks the channel (diff constant); for conv reads stay at
        // channel 0 (diff minimal at the last step)
        if let Some(o) = own {
            let own_d = if dw_like { o - pos as i64 * c } else { o - i_last };
            min_d = min_d.min(own_d);
        }
        // constraint from future positions' lowest reads vs this
        // position's last write
        if suffix != i64::MAX {
            min_d = min_d.min(suffix - i_last);
        }
        if let Some(o) = own {
            suffix = suffix.min(o);
        }
    }
    Some(min_d)
}

fn finish(min_d: Vec<i64>, in_shapes: &[&Shape], out_shape: &Shape, dtype: DType) -> SafeOverlap {
    let per_input = min_d
        .into_iter()
        .enumerate()
        .map(|(j, d)| {
            if d == i64::MAX {
                // input never read: any overlap is safe up to the cap
                super::os_cap(in_shapes[j], out_shape, dtype)
            } else {
                os_from_mind(d, in_shapes[j], out_shape, dtype)
            }
        })
        .collect();
    SafeOverlap { per_input }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{
        Activation, BinaryKind, Conv2DParams, DepthwiseParams, Padding, PoolKind, PoolParams,
        UnaryKind,
    };
    use crate::ops::infer_output;

    fn both(kind: &OpKind, ins: &[&Shape], dtype: DType) -> (SafeOverlap, SafeOverlap) {
        let out = infer_output(kind, ins).unwrap();
        (
            os_streaming(kind, ins, &out, dtype),
            os_paper_arrays(kind, ins, &out, dtype),
        )
    }

    #[test]
    fn relu_os_is_output_buffer_size() {
        // §III-A: in-place reuse is the special case O_s = OB_s.
        let s = Shape::hwc(7, 5, 3);
        let (a, b) = both(&OpKind::Unary(UnaryKind::Relu), &[&s], DType::F32);
        assert_eq!(a, b);
        assert_eq!(a.single(), s.num_elements() * 4);
    }

    #[test]
    fn binary_os_is_output_buffer_size_per_input() {
        let s = Shape::hwc(3, 4, 2);
        let (a, b) = both(&OpKind::Binary(BinaryKind::Add), &[&s, &s], DType::F32);
        assert_eq!(a, b);
        assert_eq!(a.per_input, vec![s.num_elements() * 4; 2]);
    }

    #[test]
    fn matmul_os_is_one_element() {
        // Fig 3b: accumulating matmul — effectively no usable overlap.
        let x = Shape::new(&[1, 8]);
        let k = OpKind::MatMulAccum { out_features: 6 };
        let (a, b) = both(&k, &[&x], DType::F32);
        assert_eq!(a, b);
        // the zero-init sweep writes out[N-1] before any input read, so
        // minD = 0 - (N-1) and O_s = one element.
        assert_eq!(a.single(), 4);
    }

    #[test]
    fn table1_dwconv_exact_matches_paper() {
        // §III-E: exact algorithmic O_s of the Table-I op = 1,204,224 B.
        let x = Shape::hwc(112, 112, 96);
        let k = OpKind::DepthwiseConv2D(DepthwiseParams {
            kernel: (3, 3),
            stride: (2, 2),
            dilation: (1, 1),
            padding: Padding::Same,
            depth_multiplier: 1,
            act: Activation::None,
        });
        let out = infer_output(&k, &[&x]).unwrap();
        assert_eq!(out, Shape::hwc(56, 56, 96));
        let os = os_streaming(&k, &[&x], &out, DType::F32);
        assert_eq!(os.single(), 1_204_224);
    }

    #[test]
    fn conv_1x1_channel_doubling_os() {
        // §IV: 1x1 conv doubling channels overlaps by a few elements less
        // than the input buffer size: O_s = IB - (D_in - 1) elements.
        let x = Shape::hwc(4, 4, 8);
        let k = OpKind::Conv2D(Conv2DParams {
            kernel: (1, 1),
            stride: (1, 1),
            dilation: (1, 1),
            padding: Padding::Same,
            out_channels: 16,
            act: Activation::None,
        });
        let out = infer_output(&k, &[&x]).unwrap();
        let os = os_streaming(&k, &[&x], &out, DType::F32);
        let ib = x.num_elements() * 4;
        assert_eq!(os.single(), ib - (8 - 1) * 4);
    }

    #[test]
    fn streaming_equals_paper_arrays_on_sweep() {
        let mut rng = crate::util::rng::Rng::new(0xA11C);
        for _ in 0..40 {
            let h = rng.range(3, 12);
            let w = rng.range(3, 12);
            let c = rng.range(1, 6);
            let x = Shape::hwc(h, w, c);
            let kinds: Vec<OpKind> = vec![
                OpKind::Conv2D(Conv2DParams {
                    kernel: (rng.range(1, 3), rng.range(1, 3)),
                    stride: (rng.range(1, 2), rng.range(1, 2)),
                    dilation: (1, 1),
                    padding: if rng.chance(0.5) { Padding::Same } else { Padding::Valid },
                    out_channels: rng.range(1, 8),
                    act: Activation::None,
                }),
                OpKind::DepthwiseConv2D(DepthwiseParams {
                    kernel: (rng.range(1, 3), rng.range(1, 3)),
                    stride: (rng.range(1, 2), rng.range(1, 2)),
                    dilation: (1, 1),
                    padding: Padding::Same,
                    depth_multiplier: rng.range(1, 2),
                    act: Activation::None,
                }),
                OpKind::Pool(PoolParams {
                    kind: if rng.chance(0.5) { PoolKind::Max } else { PoolKind::Avg },
                    kernel: (2, 2),
                    stride: (2, 2),
                    padding: Padding::Valid,
                }),
                OpKind::Softmax,
                OpKind::Pad { pad: (1, 1, 1, 1) },
            ];
            for k in &kinds {
                let (a, b) = both(k, &[&x], DType::F32);
                assert_eq!(a, b, "mismatch for {k:?} on {x}");
            }
        }
    }
}
