//! Integration tests for the C firmware backend (`dmo::codegen`).
//!
//! Three layers of guarantee:
//! 1. **Golden files** — the emitted `tiny` unit is byte-stable
//!    (`rust/tests/golden/`, re-bless with `DMO_BLESS_GOLDEN=1`), so
//!    any change to emission shows up in review as a C diff.
//! 2. **Structural** — for the whole 11-model zoo the emitted arena is
//!    exactly the plan's overlapped peak, and every placed tensor's
//!    offset appears verbatim.
//! 3. **Differential** — compile-and-run against the interpreter
//!    (bit-identical outputs), gated on a C toolchain being present:
//!    machines without one skip with a visible message, never fail.

use dmo::codegen::{self, cc_available, differential_test, emit, EmitOptions};
use dmo::ir::graph::{Graph, WeightInfo};
use dmo::ir::op::{BinaryKind, OpKind};
use dmo::ir::{DType, GraphBuilder, Padding, Shape};
use dmo::models;
use dmo::planner::{Plan, PlanArtifact, Planner, Strategy};
use std::path::Path;
use std::process::Command;

fn cc_or_skip() -> bool {
    if cc_available().is_none() {
        eprintln!("skipping compile-and-run check: no C compiler on PATH (install gcc or set $CC)");
        return false;
    }
    true
}

fn full_plan(g: &Graph) -> Plan {
    Planner::for_graph(g).dmo(true).plan().unwrap()
}

/// A cheap single-candidate plan — emission does not need the best
/// layout, any valid one exercises the backend.
fn quick_plan(g: &Graph) -> Plan {
    Planner::for_graph(g)
        .dmo(true)
        .strategies(&[Strategy::Lazy])
        .heuristics(&[dmo::planner::Heuristic::SizeDesc])
        .plan()
        .unwrap()
}

/// Synthetic graph covering the op kinds the zoo models miss on the
/// activation path: both pool flavours, binary add *and* mul,
/// standalone relu, pad, concat, reshape and the accumulate-in-output
/// matmul — plus two model outputs (multi-output `dmo_invoke`).
fn kitchen_graph() -> Graph {
    let mut b = GraphBuilder::new("kitchen", DType::F32);
    let x = b.input(Shape::hwc(8, 8, 4));
    let a = b.maxpool(x, (2, 2), (2, 2), Padding::Valid);
    let v = b.avgpool(x, (2, 2), (2, 2), Padding::Valid);
    let s = b.add(a, v);
    let mu = b.add_op(OpKind::Binary(BinaryKind::Mul), &[a, v], vec![]);
    let r = b.relu(s);
    let p = b.pad(r, (1, 1, 1, 1));
    let c = b.concat(&[mu, v]);
    let rp = b.reshape(p, Shape::new(&[1, 144]));
    let rc = b.reshape(c, Shape::new(&[1, 128]));
    let mm = |b: &mut GraphBuilder, x, k: usize| {
        b.add_op(
            OpKind::MatMulAccum { out_features: 5 },
            &[x],
            vec![
                WeightInfo {
                    shape: Shape::new(&[k, 5]),
                    dtype: DType::F32,
                },
                WeightInfo {
                    shape: Shape::vec1(5),
                    dtype: DType::F32,
                },
            ],
        )
    };
    let m1 = mm(&mut b, rp, 144);
    let m2 = mm(&mut b, rc, 128);
    b.finish(&[m1, m2])
}

fn golden_check(file_name: &str, actual: &str) {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden");
    let path = dir.join(file_name);
    if std::env::var("DMO_BLESS_GOLDEN").is_ok() {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, actual).unwrap();
        eprintln!("blessed golden file {}", path.display());
        return;
    }
    if !path.exists() {
        // CI must never self-bless: a missing golden there means the
        // blessed files were not committed, and "compare against what we
        // just emitted" would vacuously pass. Local first runs still
        // bless (loudly) so development works from a fresh clone.
        if std::env::var("CI").is_ok() {
            panic!(
                "golden file {} is missing from the checkout — CI never self-blesses. \
                 Generate it locally with `DMO_BLESS_GOLDEN=1 cargo test --test codegen_c` \
                 and commit rust/tests/golden/.",
                path.display()
            );
        }
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, actual).unwrap();
        eprintln!(
            "WARNING: blessed missing golden file {} — commit it so CI can compare \
             against a reviewed reference.",
            path.display()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    if want != actual {
        let actual_path = path.with_file_name(format!("{file_name}.actual"));
        std::fs::write(&actual_path, actual).unwrap();
        panic!(
            "emitted C for `tiny` no longer matches {} — wrote {} for diffing. \
             If the change is intentional, re-bless with `DMO_BLESS_GOLDEN=1 cargo test`.",
            path.display(),
            actual_path.display()
        );
    }
}

#[test]
fn golden_tiny_emission_is_byte_stable() {
    let g = models::build("tiny").unwrap();
    let unit = emit(&g, &full_plan(&g), &EmitOptions::new("tiny_model")).unwrap();
    golden_check("tiny_model.c", &unit.source);
    golden_check("tiny_model.h", &unit.header);
}

#[test]
fn zoo_emits_with_arena_equal_to_planned_peak() {
    let mut names = models::table3_names();
    names.push("tiny_int8");
    let mut saw_generator_mode = false;
    for name in names {
        let g = models::build(name).unwrap();
        let plan = quick_plan(&g);
        let unit = emit(&g, &plan, &EmitOptions::new(&format!("{name}_model"))).unwrap();
        assert_eq!(unit.arena_bytes, plan.peak(), "{name}");
        assert!(
            unit.header
                .contains(&format!("#define DMO_ARENA_BYTES {}\n", plan.peak())),
            "{name}: arena macro must be the planned (overlapped) peak"
        );
        for (i, off) in plan.alloc.offsets.iter().enumerate() {
            if let Some(off) = off {
                assert!(
                    unit.source.contains(&format!("#define DMO_OFF_T{i} {off} ")),
                    "{name}: offset of tensor {i} not verbatim"
                );
            }
        }
        assert_eq!(unit.flash.weight_bytes, g.weight_bytes(), "{name}");
        saw_generator_mode |= !unit.weights_embedded;
    }
    assert!(
        saw_generator_mode,
        "large zoo models must fall back to the SplitMix64 weight generator"
    );
}

#[test]
fn kitchen_sink_ops_compile_and_match_bitwise() {
    if !cc_or_skip() {
        return;
    }
    let g = kitchen_graph();
    let plan = full_plan(&g);
    let r = differential_test(&g, &plan, 42).unwrap();
    assert_eq!(r.outputs, 2, "multi-output invoke");
    assert_eq!(r.elems, 10);
}

#[test]
fn small_zoo_models_compile_and_match_bitwise() {
    if !cc_or_skip() {
        return;
    }
    for name in ["tiny", "tiny_int8"] {
        let g = models::build(name).unwrap();
        let plan = full_plan(&g);
        let r = differential_test(&g, &plan, 42).unwrap();
        assert_eq!(r.arena_bytes, plan.peak(), "{name}");
    }
}

/// The full acceptance sweep: every zoo model emitted, compiled with
/// `-std=c99 -Wall -Werror`, run, and diffed bit-for-bit against
/// `interp::run_reference`. The big CNNs take minutes under a debug
/// interpreter, so this runs ignored by default; CI covers tiny + a
/// MobileNet variant via `dmo emit-c --check`, and
/// `benches/codegen_diff.rs` runs this sweep in release mode.
#[test]
#[ignore = "slow: run with --ignored (or `cargo bench --bench codegen_diff`) on a release build"]
fn differential_full_zoo() {
    if !cc_or_skip() {
        return;
    }
    let mut names = models::table3_names();
    names.extend(["tiny", "tiny_int8"]);
    for name in names {
        let g = models::build(name).unwrap();
        let plan = full_plan(&g);
        let r = differential_test(&g, &plan, 42).unwrap();
        eprintln!(
            "{name}: {} elems bit-identical (arena {} B, weights {})",
            r.elems,
            r.arena_bytes,
            if r.weights_embedded { "embedded" } else { "generated" }
        );
    }
}

#[test]
fn cli_emit_c_round_trips_through_an_artifact() {
    let bin = env!("CARGO_BIN_EXE_dmo");
    let dir = std::env::temp_dir().join(format!("dmo-cli-emitc-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let plan_path = dir.join("tiny.plan.json");
    let out_c = dir.join("tiny_model.c");

    let out = Command::new(bin)
        .args(["plan", "tiny", "--export", plan_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // exercise the `--key=value` spelling through the real CLI
    let out_flag = format!("--out={}", out_c.display());
    let out = Command::new(bin)
        .args(["emit-c", "--import", plan_path.to_str().unwrap(), out_flag.as_str()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let artifact = PlanArtifact::load(&plan_path).unwrap();
    let src = std::fs::read_to_string(&out_c).unwrap();
    let hdr = std::fs::read_to_string(dir.join("tiny_model.h")).unwrap();
    assert!(src.contains("#include \"tiny_model.h\""));
    assert!(hdr.contains(&format!("#define DMO_ARENA_BYTES {}\n", artifact.peak)));
    assert!(hdr.contains("void dmo_invoke(const float *input_0, float *output_0);"));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("revalidated"), "{stdout}");
    assert!(stdout.contains("STM32F103xF"), "fit table missing: {stdout}");

    // a positional model that contradicts the artifact is rejected —
    // never silently emit firmware for a different network
    let bad = Command::new(bin)
        .args([
            "emit-c",
            "mobilenet_v1_1.0_224",
            "--import",
            plan_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    let stderr = String::from_utf8_lossy(&bad.stderr);
    assert!(stderr.contains("does not match"), "{stderr}");

    // unknown flags are rejected with the accepted-flag list
    let bad = Command::new(bin)
        .args(["emit-c", "tiny", "--ot", "x.c"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    let stderr = String::from_utf8_lossy(&bad.stderr);
    assert!(stderr.contains("unknown flag"), "{stderr}");
    assert!(stderr.contains("--out"), "{stderr}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cli_emit_c_check_runs_the_differential_harness() {
    if !cc_or_skip() {
        return;
    }
    let bin = env!("CARGO_BIN_EXE_dmo");
    let dir = std::env::temp_dir().join(format!("dmo-cli-emitc-check-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out_c = dir.join("tiny_model.c");
    let out = Command::new(bin)
        .args(["emit-c", "tiny", "--out", out_c.to_str().unwrap(), "--check"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("differential check passed"), "{stdout}");
    assert!(stdout.contains("bit-identical"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn emitted_arena_is_smaller_than_disjoint_sum() {
    // the point of the whole exercise: the emitted firmware's arena is
    // the DMO-overlapped peak, not the sum of live tensors
    let g = models::build("mobilenet_v1_0.25_128_int8").unwrap();
    let plan = full_plan(&g);
    let unit = emit(&g, &plan, &EmitOptions::new("mnv1_model")).unwrap();
    assert_eq!(unit.arena_bytes / 1024, 64, "the paper's 64 KB headline");
    assert!(unit.arena_bytes < g.total_tensor_bytes());
    // flash accounting agrees with the emit-free estimate and is
    // dominated by weights, not the code term
    let ff = codegen::flash_footprint(&g);
    assert_eq!(unit.flash, ff);
    assert!(ff.weight_bytes > ff.code_bytes);
}
