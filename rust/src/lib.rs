//! # DMO — Diagonal Memory Optimisation
//!
//! A full reproduction of *“Diagonal Memory Optimisation for Machine
//! Learning on Micro-controllers”* (Blacker, Bridges, Hadfield, 2020):
//! a tensor-graph IR with TFLite-reference op semantics, the three safe
//! buffer-overlap (`O_s`) engines (§III), the reverse-order DMO
//! pre-allocator and the baseline modified-heap allocator (§II/§IV), an
//! arena interpreter that *executes* planned (overlapping) layouts to
//! prove them safe, memory-trace instrumentation and figure rendering,
//! the 11-network model zoo of Table III, an MCU deployment-fit catalog,
//! and a serving stack (PJRT runtime + request coordinator) that runs
//! AOT-compiled JAX/Pallas models with DMO-planned host arenas.
//!
//! ## Entry points
//!
//! Planning follows the paper's lifecycle (§II-D): it is a
//! *pre-inference* step whose result is reused for every inference.
//!
//! * [`models`] — the paper's networks by name.
//! * [`planner::Planner`] — a builder-style planning session: configure
//!   the §IV search (DMO on/off, `O_s` method, strategies, directions,
//!   heuristics, a progress callback) and produce a validated
//!   [`planner::Plan`].
//! * [`planner::PlanArtifact`] — a versioned JSON snapshot of a plan;
//!   save it once, then load and revalidate it in other processes (the
//!   CLI, the serving coordinator, benches) without re-running the
//!   search.
//! * [`overlap::compute_os`] — `O_s` via any of the three methods.
//! * [`interp`] — execute a planned graph and validate overlap safety;
//!   [`interp::run_planned_artifact`] does so straight from a loaded
//!   artifact.
//! * [`codegen`] — lower a plan (or loaded artifact) to a standalone
//!   C99 firmware unit: static arena at the overlapped peak, `#define`d
//!   tensor offsets verbatim from the plan, flash-resident weights, a
//!   `dmo_invoke` entry point. [`codegen::harness`] compiles and runs
//!   the unit and proves it bit-identical to the interpreter.
//! * [`mcu`] — deployment-fit checks, including the emitted unit's
//!   flash image (weights + code) via [`codegen::flash_footprint`].
//!
//! The full pipeline is **plan → artifact → emit → compile**: plan
//! once, persist, then either interpret the artifact or bake it into
//! firmware.
//!
//! ## Choosing an execution order
//!
//! Connected graphs admit many valid execution orders, and the order
//! decides which tensors are simultaneously live — and therefore the
//! peak. The paper serialises each graph twice (eager and lazy, §II-B)
//! and keeps the better result; [`planner::Strategy::Search`] goes
//! further and *searches* the order space with a beam over topological
//! prefixes, scored by the DMO-overlapped incremental footprint
//! ([`planner::IncrementalCost`]), with dominance pruning on the
//! (live-set, frontier) state. The eager and lazy orders are always
//! scored as seeds, so the searched plan is never worse than the
//! paper's best-of-two — on branchy graphs (inception cells, dense
//! blocks) it can be strictly better:
//!
//! ```
//! use dmo::planner::Planner;
//!
//! # fn main() -> anyhow::Result<()> {
//! let graph = dmo::models::build("tiny")?;
//! let sweep = Planner::for_graph(&graph).dmo(true).plan()?;
//! let searched = Planner::for_graph(&graph)
//!     .dmo(true)
//!     .search(4, 2_000) // beam width, expansion budget
//!     .plan()?;
//! assert!(searched.peak() <= sweep.peak());
//! assert_eq!(searched.strategy.name(), "search");
//! assert!(searched.search.expect("search stats recorded").expanded > 0);
//! # Ok(())
//! # }
//! ```
//!
//! `dmo orders` prints the eager/lazy/search comparison across the
//! model zoo, and `cargo bench --bench order_search` records it (plus
//! search wall time) to `BENCH_order_search.json`.
//!
//! ## When to rewrite (§II-A, generalised)
//!
//! Reordering only rearranges which tensors are live together. When
//! fat intermediates dominate the peak, §II-A *operation splitting*
//! bands producer/consumer regions into `k` horizontal slices so only
//! `≈ 1/k` of each intermediate is live at once, recomputing the halo
//! rows adjacent bands share. The rewrite surface is a composable pass
//! API: [`ir::rewrite::RewriteSpec`] names one rewrite — a
//! `PairSplit` of a single producer/consumer pair, or a `ChainSplit`
//! banding a whole chain of depth ≥ 3 end-to-end — and
//! [`ir::rewrite::apply`] composes any sequence of them into real
//! [`ir::op::OpKind::Band`] / [`ir::op::OpKind::ConcatRows`] ops.
//! [`planner::Planner::rewrites`] folds the whole family into the plan
//! search under a [`planner::RewriteBudget`]: `max_parts` bounds the
//! bands per split, `max_splits` lets several independent pair splits
//! compose in one plan, and `max_chain_depth ≥ 3` adds chain
//! candidates. Rewritten candidates compete with every unrewritten
//! order and win only on a strictly lower allocator-scored peak, so a
//! bigger budget is never worse — pick pairs when one pair dominates
//! (recompute stays local), chains when an hourglass of fat
//! intermediates must never be materialised in full, and prefer the
//! fewest parts that clear the SRAM target, since recompute grows
//! with `k`:
//!
//! ```
//! use dmo::ir::op::{Activation, Padding};
//! use dmo::ir::{DType, GraphBuilder, Shape};
//! use dmo::planner::{Planner, RewriteBudget};
//!
//! # fn main() -> anyhow::Result<()> {
//! // the §II-A shape: 32 KB input → 64 KB intermediate → 16 KB output
//! let mut b = GraphBuilder::new("pair", DType::I8);
//! let x = b.input(Shape::hwc(64, 64, 8));
//! let c = b.conv2d(x, 16, (1, 1), (1, 1), Padding::Same, Activation::None);
//! let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None);
//! let graph = b.finish(&[d]);
//!
//! let unsplit = Planner::for_graph(&graph).dmo(true).plan()?;
//! let split = Planner::for_graph(&graph)
//!     .dmo(true)
//!     .rewrites(RewriteBudget::pairs(4)) // pairs:4 — up to 4 bands
//!     .plan()?;
//! assert!(split.peak() < unsplit.peak(), "banding beats every unsplit order here");
//! let rewrite = split.rewrite.as_ref().expect("the winning plan carries the rewrite");
//! assert_eq!(rewrite.specs.len(), 1);
//!
//! // chains band whole subgraphs: on the zoo's hourglass model a
//! // depth-3 chain strictly beats the best single pair split
//! let hourglass = dmo::models::build("hourglass")?;
//! let pairs = Planner::for_graph(&hourglass)
//!     .dmo(true)
//!     .rewrites(RewriteBudget::pairs(4))
//!     .plan()?;
//! let chains = Planner::for_graph(&hourglass)
//!     .dmo(true)
//!     .rewrites(RewriteBudget { max_parts: 4, max_splits: 1, max_chain_depth: 3 })
//!     .plan()?;
//! assert!(chains.peak() < pairs.peak(), "the chain avoids both fat intermediates");
//!
//! // banded plans execute bit-identically to the *unrewritten* reference
//! dmo::interp::validate_plan(&graph, &split, 42)?;
//! dmo::interp::validate_plan(&hourglass, &chains, 42)?;
//! # Ok(())
//! # }
//! ```
//!
//! The winning plan, rewritten or not, flows unchanged through
//! [`planner::PlanArtifact`] (format v4 records the rewrite specs and
//! re-derives the rewrite on load; v3 pair-split artifacts still
//! load), [`interp`], [`codegen`] (banded kernels; each split op's
//! weights stored in flash once) and [`mcu::deploy_matrix_planned`] —
//! where §II-A is what puts the smallest MobileNet on a 64 KB-SRAM
//! part that DMO alone just misses.
//!
//! ## Planning at scale
//!
//! `O_s` depends only on op geometry, so the planner memoises it
//! content-addressed ([`overlap::OsCache`]): repeated block shapes are
//! analysed once per table build, and a shared cache makes later
//! sessions pure lookups — the pattern `dmo serve` uses at startup via
//! [`overlap::OsCache::process_shared`]. Independently,
//! [`planner::Planner::jobs`] spreads the candidate sweep and the
//! order search's beam expansion over worker threads; results are
//! reduced in a fixed order, so the worker count changes wall time
//! only — never the plan:
//!
//! ```
//! use dmo::overlap::OsCache;
//! use dmo::planner::{PlanArtifact, Planner};
//! use std::sync::Arc;
//!
//! # fn main() -> anyhow::Result<()> {
//! let graph = dmo::models::build("tiny")?;
//! let cache = Arc::new(OsCache::new());
//!
//! // first session populates the cache; the parallel second session
//! // re-uses every O_s entry and still produces the identical artifact
//! let serial = Planner::for_graph(&graph)
//!     .dmo(true)
//!     .jobs(1)
//!     .os_cache(cache.clone())
//!     .plan()?;
//! let parallel = Planner::for_graph(&graph)
//!     .dmo(true)
//!     .jobs(4)
//!     .os_cache(cache.clone())
//!     .plan()?;
//!
//! let a = PlanArtifact::from_plan(&graph, &serial).to_json().to_string();
//! let b = PlanArtifact::from_plan(&graph, &parallel).to_json().to_string();
//! assert_eq!(a, b, "worker count is a wall-clock knob, not a result knob");
//! assert!(cache.stats().hits > 0, "second session was served from the cache");
//! # Ok(())
//! # }
//! ```
//!
//! `cargo bench --bench planner_scale` records cold-vs-warm cache and
//! serial-vs-parallel sweep times to `BENCH_planner_scale.json`; see
//! EXPERIMENTS.md §Perf.
//!
//! ## Serving a fleet
//!
//! Planning fixes each model's arena size before the first request
//! (§II-D), so the [`fleet`] layer pre-sizes K pooled arenas per model
//! and serves N models from one process with **zero per-request arena
//! allocation at steady state** — a property the pool counts and the
//! report asserts rather than assumes. Per-model bounded queues are
//! drained round-robin (one model's burst never starves another), and
//! artifacts hot-reload behind a generation-counted `Arc` while
//! in-flight requests drain on the old layout:
//!
//! ```
//! use dmo::fleet::{fleet_serve, FleetConfig, ModelSpec};
//!
//! # fn main() -> anyhow::Result<()> {
//! let report = fleet_serve(&FleetConfig {
//!     models: vec![ModelSpec::planned("tiny"), ModelSpec::planned("tiny_int8")],
//!     arenas: 2,
//!     workers: 2,
//!     requests: 64,
//!     ..FleetConfig::default()
//! })?;
//! assert_eq!(report.completed, 64); // closed loop: nothing shed
//! assert_eq!(report.shed, 0);
//! for m in &report.per_model {
//!     assert_eq!(m.pool_allocs, 0, "steady state never allocates an arena");
//!     assert_eq!(m.pool_hit_rate, 1.0);
//! }
//! # Ok(())
//! # }
//! ```
//!
//! `dmo serve --models tiny,tiny_int8,tiny_wide` runs the same loop from
//! the CLI, and `cargo bench --bench serve_scale` records mixed-traffic
//! latency/throughput to `BENCH_serve_scale.json`; see EXPERIMENTS.md
//! §Serving.
//!
//! ```
//! use dmo::codegen::{emit_artifact, EmitOptions};
//! use dmo::planner::{PlanArtifact, Planner};
//!
//! # fn main() -> anyhow::Result<()> {
//! let graph = dmo::models::build("tiny")?;
//!
//! // One planning session, full §IV sweep, DMO on.
//! let plan = Planner::for_graph(&graph).dmo(true).plan()?;
//!
//! // Snapshot → JSON → (another process) → revalidate → execute.
//! let artifact = PlanArtifact::from_plan(&graph, &plan);
//! let json = artifact.to_json().to_string();
//! let reloaded = PlanArtifact::from_json(&dmo::util::json::Json::parse(&json)?)?;
//! let restored = reloaded.to_plan(&graph)?; // checks fingerprint + layout
//! assert_eq!(restored.peak(), plan.peak());
//!
//! // The interpreter proves the loaded layout safe by executing it.
//! let outputs = dmo::interp::run_planned_artifact(&graph, &reloaded, 42)?;
//! assert!(!outputs.is_empty());
//!
//! // And the codegen backend bakes the same layout into firmware C:
//! // `static uint8_t dmo_arena[<peak>]` + fixed offsets + kernels.
//! let unit = emit_artifact(&graph, &reloaded, &EmitOptions::new("tiny_model"))?;
//! assert!(unit.header.contains(&format!("#define DMO_ARENA_BYTES {}", plan.peak())));
//! // (write `tiny_model.c`/`.h` with `unit.write_to`, then:
//! //  cc -std=c99 -Wall -Werror tiny_model.c main.c -lm)
//! # Ok(())
//! # }
//! ```

pub mod codegen;
pub mod coordinator;
pub mod fault;
pub mod fleet;
pub mod interp;
pub mod ir;
pub mod mcu;
pub mod models;
pub mod obs;
pub mod ops;
pub mod overlap;
pub mod planner;
pub mod report;
pub mod runtime;
pub mod trace;
pub mod util;
