//! NasNet-A Mobile (Zoph et al. 2018) — Table III row 9. Every cell
//! consumes the outputs of the *two* preceding cells, so cell outputs are
//! always multi-use and DMO finds nothing to overlap ("None").
//!
//! The cell structure follows the published NASNet-A Mobile
//! (penultimate filters 1056 ⇒ per-cell filters 44/88/176, N=4): five
//! pairwise combinations of separable convs / poolings / identities,
//! concatenated. Separable convs are modelled as one dw+pw pair (the
//! published cells apply the pair twice; the repetition changes FLOPs but
//! not liveness structure, which is what Table III measures).

use crate::ir::graph::{Graph, TensorId};
use crate::ir::op::{Activation, Padding};
use crate::ir::{DType, GraphBuilder, Shape};

/// Separable conv: depthwise k×k (stride s) then pointwise to `f`.
fn sep(b: &mut GraphBuilder, x: TensorId, f: usize, k: usize, s: usize) -> TensorId {
    let h = b.dwconv2d(x, (k, k), (s, s), Padding::Same, Activation::Relu);
    b.conv2d(h, f, (1, 1), (1, 1), Padding::Same, Activation::None)
}

/// Match `prev`'s spatial/channel shape to (`h_dim`, `f`): 1×1 conv plus
/// stride-2 pooling when the resolution halved (factorised reduction).
fn adjust(b: &mut GraphBuilder, prev: TensorId, h_dim: usize, f: usize) -> TensorId {
    let shape = b.shape_of(prev);
    let mut t = prev;
    if shape.h() != h_dim {
        t = b.avgpool(t, (1, 1), (2, 2), Padding::Valid);
    }
    b.conv2d(t, f, (1, 1), (1, 1), Padding::Same, Activation::None)
}

/// NASNet-A normal cell: returns the concat of five pairwise sums.
fn normal_cell(b: &mut GraphBuilder, prev: TensorId, cur: TensorId, f: usize) -> TensorId {
    let h_dim = b.shape_of(cur).h();
    let p = adjust(b, prev, h_dim, f);
    let h = adjust(b, cur, h_dim, f);
    let s1a = sep(b, h, f, 5, 1);
    let s1b = sep(b, p, f, 3, 1);
    let y1 = b.add(s1a, s1b);
    let s2a = sep(b, p, f, 5, 1);
    let s2b = sep(b, p, f, 3, 1);
    let y2 = b.add(s2a, s2b);
    let a3 = b.avgpool(h, (3, 3), (1, 1), Padding::Same);
    let y3 = b.add(a3, p);
    let a4a = b.avgpool(p, (3, 3), (1, 1), Padding::Same);
    let a4b = b.avgpool(p, (3, 3), (1, 1), Padding::Same);
    let y4 = b.add(a4a, a4b);
    let s5 = sep(b, h, f, 3, 1);
    let y5 = b.add(s5, h);
    b.concat(&[p, y1, y2, y3, y4, y5])
}

/// NASNet-A reduction cell (halves resolution, concat of four combines).
fn reduction_cell(b: &mut GraphBuilder, prev: TensorId, cur: TensorId, f: usize) -> TensorId {
    let h_dim = b.shape_of(cur).h();
    let p = adjust(b, prev, h_dim, f);
    let h = adjust(b, cur, h_dim, f);
    let s1a = sep(b, h, f, 5, 2);
    let s1b = sep(b, p, f, 7, 2);
    let y1 = b.add(s1a, s1b);
    let m2 = b.maxpool(h, (3, 3), (2, 2), Padding::Same);
    let s2 = sep(b, p, f, 7, 2);
    let y2 = b.add(m2, s2);
    let a3 = b.avgpool(h, (3, 3), (2, 2), Padding::Same);
    let s3 = sep(b, p, f, 5, 2);
    let y3 = b.add(a3, s3);
    let m4 = b.maxpool(h, (3, 3), (2, 2), Padding::Same);
    let s4 = sep(b, y1, f, 3, 1);
    let y4 = b.add(m4, s4);
    b.concat(&[y1, y2, y3, y4])
}

/// Build NasNet-A Mobile (N=4, penultimate filters 1056) at 224×224.
pub fn build(dtype: DType) -> Graph {
    let mut bld = GraphBuilder::new("nasnet_mobile", dtype);
    let x = bld.input(Shape::hwc(224, 224, 3));
    // stem conv 3x3 s2 valid, 32 channels
    let stem = bld.conv2d(x, 32, (3, 3), (2, 2), Padding::Valid, Activation::None);
    // two stem reduction cells (f = 11, 22)
    let r1 = reduction_cell(&mut bld, x, stem, 11);
    let mut prev = stem;
    let mut cur = r1;
    let r2 = reduction_cell(&mut bld, prev, cur, 22);
    prev = cur;
    cur = r2;
    let n = 4usize;
    for (stage, f) in [(0usize, 44usize), (1, 88), (2, 176)] {
        if stage > 0 {
            let r = reduction_cell(&mut bld, prev, cur, f);
            prev = cur;
            cur = r;
        }
        for _ in 0..n {
            let nxt = normal_cell(&mut bld, prev, cur, f);
            prev = cur;
            cur = nxt;
        }
    }
    let h = bld.relu(cur);
    let h = bld.global_avg_pool(h);
    let c = bld.shape_of(h).c();
    let h = bld.reshape(h, Shape::new(&[1, c]));
    let h = bld.fully_connected(h, 1000, Activation::None);
    let out = bld.softmax(h);
    bld.finish(&[out])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn penultimate_channels_1056() {
        let g = build(DType::F32);
        let gap_in = g
            .ops
            .iter()
            .find(|o| matches!(o.kind, crate::ir::op::OpKind::GlobalAvgPool))
            .map(|o| g.tensor(o.inputs[0]).shape.clone())
            .unwrap();
        assert_eq!(gap_in.c(), 6 * 176, "normal cell concat = 6f = 1056");
        assert_eq!(gap_in.h(), 7);
    }

    #[test]
    fn cell_outputs_are_multi_use() {
        let g = build(DType::F32);
        // most concat outputs feed two later cells
        let multi = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, crate::ir::op::OpKind::Concat))
            .filter(|o| g.consumers(o.output).len() >= 2)
            .count();
        assert!(multi >= 10, "only {multi} multi-use cell outputs");
    }
}
