//! Fleet serving loop: mixed-model traffic → per-model admission →
//! shared worker pool → pooled-arena planned execution → replies.
//!
//! [`Fleet`] is the long-lived handle: start it on a [`Registry`],
//! submit requests (blocking or shedding), hot-reload artifacts while
//! requests are in flight, and shut down to collect per-model reports.
//! [`fleet_serve`] wraps it in a deterministic load generator — the
//! `dmo serve --models …` entry point and the `serve_scale` bench both
//! drive that function.

use super::admission::Admission;
use super::registry::{ModelSpec, Registry, ReloadInfo};
use crate::coordinator::Metrics;
use crate::obs::prom::PromText;
use crate::obs::trace as otrace;
use crate::obs::log as obs_log;
use crate::planner::PlanArtifact;
use crate::util::json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant, SystemTime};

/// One in-flight fleet request.
pub struct FleetRequest {
    pub id: u64,
    pub data: Vec<f32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<FleetReply>,
}

/// One completed fleet inference.
pub struct FleetReply {
    pub id: u64,
    pub model: usize,
    /// Generation of the [`super::ModelState`] that served the request —
    /// hot-reload tests read this to see the swap happen mid-stream.
    pub generation: u64,
    pub output: Vec<f32>,
    pub latency: Duration,
}

/// Overload behaviour at the admission edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Block the producer while the model's queue is full (closed loop).
    Block,
    /// Reject immediately and count a shed (open loop keeps its clock).
    Shed,
}

/// A running fleet: registry + admission + worker pool (+ watcher).
pub struct Fleet {
    pub registry: Arc<Registry>,
    admission: Arc<Admission<FleetRequest>>,
    metrics: Arc<Vec<Mutex<Metrics>>>,
    workers: Vec<thread::JoinHandle<Result<()>>>,
    watcher: Option<(Arc<AtomicBool>, thread::JoinHandle<()>)>,
    metrics_writer: Option<(Arc<AtomicBool>, thread::JoinHandle<()>, PathBuf)>,
}

impl Fleet {
    /// Spawn `workers` threads draining the fair admission queues.
    /// `queue_capacity` bounds each model's queue.
    pub fn start(registry: Registry, workers: usize, queue_capacity: usize) -> Fleet {
        let registry = Arc::new(registry);
        let admission = Arc::new(Admission::new(registry.len(), queue_capacity));
        let metrics: Arc<Vec<Mutex<Metrics>>> =
            Arc::new((0..registry.len()).map(|_| Mutex::new(Metrics::default())).collect());
        let n = if workers == 0 {
            thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
        } else {
            workers
        };
        let handles = (0..n)
            .map(|w| {
                let reg = registry.clone();
                let adm = admission.clone();
                let met = metrics.clone();
                thread::Builder::new()
                    .name(format!("fleet-worker-{w}"))
                    .spawn(move || -> Result<()> {
                        while let Some((m, req)) = adm.take() {
                            // time spent queued before a worker picked it up
                            let queue_us = req.enqueued.elapsed().as_micros() as u64;
                            let mut sp = otrace::span("request", "fleet");
                            // the Arc pins this request to one generation;
                            // a concurrent reload drains behind it
                            let state = reg.current(m);
                            let mut arena = {
                                let _acquire = otrace::span("arena_acquire", "fleet");
                                state.acquire_arena()
                            };
                            let output = {
                                let _exec = otrace::span("exec", "fleet");
                                state
                                    .execute(&mut arena, &req.data)
                                    .with_context(|| format!("serving `{}`", state.name))?
                            };
                            drop(arena); // back to the pool before bookkeeping
                            let latency = req.enqueued.elapsed();
                            if sp.is_active() {
                                sp.arg("model", json::s(&state.name));
                                sp.arg("id", json::num(req.id as usize));
                                sp.arg("generation", json::num(state.generation as usize));
                                sp.arg("queue_us", json::num(queue_us as usize));
                            }
                            drop(sp); // the reply send is outside the span
                            met[m].lock().unwrap().record(latency);
                            let _ = req.reply.send(FleetReply {
                                id: req.id,
                                model: m,
                                generation: state.generation,
                                output,
                                latency,
                            });
                        }
                        Ok(())
                    })
                    .expect("spawning fleet worker")
            })
            .collect();
        Fleet {
            registry,
            admission,
            metrics,
            workers: handles,
            watcher: None,
            metrics_writer: None,
        }
    }

    /// Admit a request for model `m` under `policy`. Returns `false`
    /// when the request was shed (recorded in that model's [`Metrics`] —
    /// the single source of truth the reports read) or the fleet is
    /// closed.
    pub fn submit(&self, m: usize, req: FleetRequest, policy: AdmissionPolicy) -> bool {
        let outcome = match policy {
            AdmissionPolicy::Block => self.admission.submit(m, req),
            AdmissionPolicy::Shed => self.admission.try_submit(m, req),
        };
        match outcome {
            Ok(()) => true,
            Err(_rejected) => {
                self.metrics[m].lock().unwrap().record_shed();
                false
            }
        }
    }

    /// Hot-reload slot `m` from a re-planned artifact (see
    /// [`Registry::reload`] for the validation and drain semantics).
    pub fn reload(&self, m: usize, artifact: PlanArtifact) -> Result<ReloadInfo> {
        self.registry.reload(m, artifact)
    }

    /// Watch `dir` for `<model>.plan.json` artifact drops and hot-reload
    /// the matching slot on every change. Files already present when the
    /// watch starts are treated as seen (the registry loaded them — or
    /// chose not to — at startup). A bad artifact is logged and skipped;
    /// the old generation keeps serving.
    pub fn watch(&mut self, dir: PathBuf, poll: Duration) {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let registry = self.registry.clone();
        let handle = thread::Builder::new()
            .name("fleet-reload-watch".into())
            .spawn(move || {
                let paths: Vec<PathBuf> = registry
                    .names()
                    .iter()
                    .map(|n| dir.join(format!("{n}.plan.json")))
                    .collect();
                let mtime = |p: &PathBuf| -> Option<SystemTime> {
                    std::fs::metadata(p).and_then(|m| m.modified()).ok()
                };
                let mut seen: Vec<Option<SystemTime>> = paths.iter().map(&mtime).collect();
                while !flag.load(Ordering::Relaxed) {
                    for (m, path) in paths.iter().enumerate() {
                        let now = mtime(path);
                        if now.is_some() && now != seen[m] {
                            seen[m] = now; // one attempt per change, even if it fails
                            match PlanArtifact::load(path).map_err(anyhow::Error::from)
                                .and_then(|a| registry.reload(m, a))
                            {
                                Ok(info) => obs_log::info(format_args!(
                                    "fleet: hot-reloaded `{}` → generation {} (arena {} → {})",
                                    registry.names()[m],
                                    info.generation,
                                    info.old_peak,
                                    info.new_peak
                                )),
                                Err(e) => obs_log::warn(format_args!(
                                    "fleet: reload of `{}` from {} rejected ({e:#}); old \
                                     generation keeps serving",
                                    registry.names()[m],
                                    path.display()
                                )),
                            }
                        }
                    }
                    thread::sleep(poll);
                }
            })
            .expect("spawning reload watcher");
        self.watcher = Some((stop, handle));
    }

    /// Current queue depth for model `m` (live admission telemetry).
    pub fn queue_depth(&self, m: usize) -> usize {
        self.admission.depth(m)
    }

    /// Render a Prometheus text-exposition snapshot of the fleet's
    /// current state: per-model request counters, latency histograms,
    /// queue-depth and arena-pool gauges, generation/reload counters.
    pub fn prometheus_snapshot(&self) -> String {
        render_prometheus(&self.registry, &self.admission, &self.metrics)
    }

    /// Write the current snapshot to `path` atomically (tmp + rename, so
    /// a concurrent scraper never reads a torn file).
    pub fn write_metrics(&self, path: &Path) -> std::io::Result<()> {
        write_atomic(path, &self.prometheus_snapshot())
    }

    /// Rewrite `path` with a fresh snapshot every `period` until
    /// shutdown, which writes one final snapshot after the last request
    /// drains (`dmo serve --metrics-out=FILE`).
    pub fn metrics_writer(&mut self, path: PathBuf, period: Duration) {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let registry = self.registry.clone();
        let admission = self.admission.clone();
        let metrics = self.metrics.clone();
        let out = path.clone();
        let handle = thread::Builder::new()
            .name("fleet-metrics-writer".into())
            .spawn(move || {
                while !flag.load(Ordering::Relaxed) {
                    let text = render_prometheus(&registry, &admission, &metrics);
                    if let Err(e) = write_atomic(&out, &text) {
                        obs_log::warn(format_args!(
                            "fleet: writing metrics snapshot to {} failed: {e}",
                            out.display()
                        ));
                    }
                    thread::sleep(period);
                }
            })
            .expect("spawning metrics writer");
        self.metrics_writer = Some((stop, handle, path));
    }

    /// Stop admitting, drain the queues, join every worker and the
    /// watcher, and assemble the per-model reports.
    pub fn shutdown(mut self) -> Result<Vec<ModelReport>> {
        self.admission.close();
        if let Some((stop, handle)) = self.watcher.take() {
            stop.store(true, Ordering::Relaxed);
            let _ = handle.join();
        }
        for h in self.workers.drain(..) {
            h.join().expect("fleet worker panicked")?;
        }
        if let Some((stop, handle, path)) = self.metrics_writer.take() {
            stop.store(true, Ordering::Relaxed);
            let _ = handle.join();
            // final snapshot: every request drained, counters settled
            if let Err(e) = self.write_metrics(&path) {
                obs_log::warn(format_args!(
                    "fleet: final metrics snapshot to {} failed: {e}",
                    path.display()
                ));
            }
        }
        let max_depths = self.admission.max_depths();
        let reports = (0..self.registry.len())
            .map(|m| {
                let metrics = self.metrics[m].lock().unwrap().clone();
                let state = self.registry.current(m);
                ModelReport {
                    model: state.name.clone(),
                    completed: metrics.count(),
                    shed: metrics.shed,
                    arena_bytes: state.plan.peak(),
                    pool_hits: state.pool.hits(),
                    pool_allocs: state.pool.allocs(),
                    pool_hit_rate: state.pool.hit_rate(),
                    pool_capacity: state.pool.capacity(),
                    pool_idle: state.pool.idle(),
                    max_queue_depth: max_depths[m],
                    queue_capacity: self.admission.capacity(),
                    generation: state.generation,
                    reloads: self.registry.reloads(m),
                    metrics,
                }
            })
            .collect();
        Ok(reports)
    }
}

/// Atomic file replace: write to `<path>.tmp`, then rename over `path`,
/// so a concurrent reader (a Prometheus scraper tailing the file) never
/// observes a half-written snapshot.
fn write_atomic(path: &Path, text: &str) -> std::io::Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text)?;
    std::fs::rename(&tmp, path)
}

/// Render the fleet's live state in Prometheus text-exposition format.
fn render_prometheus<T>(
    registry: &Registry,
    admission: &Admission<T>,
    metrics: &[Mutex<Metrics>],
) -> String {
    let mut p = PromText::new();
    let max_depths = admission.max_depths();
    p.family(
        "dmo_requests_completed_total",
        "Requests completed per model.",
        "counter",
    );
    p.family(
        "dmo_requests_shed_total",
        "Requests shed at admission per model.",
        "counter",
    );
    p.family("dmo_queue_depth", "Current admission queue depth.", "gauge");
    p.family(
        "dmo_queue_depth_max",
        "High-water mark of the admission queue.",
        "gauge",
    );
    p.family(
        "dmo_queue_capacity",
        "Configured admission queue bound.",
        "gauge",
    );
    p.family(
        "dmo_arena_bytes",
        "Planned arena bytes of the serving generation.",
        "gauge",
    );
    p.family(
        "dmo_arena_pool_hits_total",
        "Arena acquisitions served from the pool.",
        "counter",
    );
    p.family(
        "dmo_arena_pool_allocs_total",
        "Arena acquisitions that had to allocate.",
        "counter",
    );
    p.family("dmo_arena_pool_idle", "Arenas idle in the pool.", "gauge");
    p.family(
        "dmo_arena_pool_capacity",
        "Arenas held by the pool in total.",
        "gauge",
    );
    p.family(
        "dmo_model_generation",
        "Hot-reload generation currently serving.",
        "gauge",
    );
    p.family(
        "dmo_model_reloads_total",
        "Accepted hot reloads per model.",
        "counter",
    );
    for m in 0..registry.len() {
        let state = registry.current(m);
        let name = state.name.clone();
        let labels: &[(&str, &str)] = &[("model", &name)];
        let (completed, shed) = {
            let g = metrics[m].lock().unwrap();
            (g.count(), g.shed)
        };
        p.sample("dmo_requests_completed_total", labels, completed as f64);
        p.sample("dmo_requests_shed_total", labels, shed as f64);
        p.sample("dmo_queue_depth", labels, admission.depth(m) as f64);
        p.sample("dmo_queue_depth_max", labels, max_depths[m] as f64);
        p.sample("dmo_queue_capacity", labels, admission.capacity() as f64);
        p.sample("dmo_arena_bytes", labels, state.plan.peak() as f64);
        p.sample("dmo_arena_pool_hits_total", labels, state.pool.hits() as f64);
        p.sample(
            "dmo_arena_pool_allocs_total",
            labels,
            state.pool.allocs() as f64,
        );
        p.sample("dmo_arena_pool_idle", labels, state.pool.idle() as f64);
        p.sample(
            "dmo_arena_pool_capacity",
            labels,
            state.pool.capacity() as f64,
        );
        p.sample("dmo_model_generation", labels, state.generation as f64);
        p.sample(
            "dmo_model_reloads_total",
            labels,
            registry.reloads(m) as f64,
        );
    }
    p.family(
        "dmo_request_latency_seconds",
        "End-to-end request latency (enqueue to reply).",
        "histogram",
    );
    for m in 0..registry.len() {
        let state = registry.current(m);
        let name = state.name.clone();
        let hist = metrics[m].lock().unwrap().histogram().clone();
        p.latency_histogram("dmo_request_latency_seconds", &[("model", &name)], &hist);
    }
    p.finish()
}

/// Per-model serving summary. `shed` and `completed` both come out of
/// the model's [`Metrics`] — there is exactly one source of truth.
#[derive(Debug, Clone)]
pub struct ModelReport {
    pub model: String,
    pub completed: usize,
    pub shed: usize,
    pub metrics: Metrics,
    /// Arena bytes of the *current* generation (post-reload size).
    pub arena_bytes: usize,
    pub pool_hits: usize,
    pub pool_allocs: usize,
    pub pool_hit_rate: f64,
    /// Arenas the pool holds in total / idle at shutdown (gauges).
    pub pool_capacity: usize,
    pub pool_idle: usize,
    /// High-water mark of the model's admission queue over the run.
    pub max_queue_depth: usize,
    /// Configured per-model admission queue bound (clamped to ≥ 1).
    pub queue_capacity: usize,
    pub generation: u64,
    pub reloads: usize,
}

/// Fleet load-generation configuration (`dmo serve --models …`).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub models: Vec<ModelSpec>,
    /// Pooled arenas per model (K).
    pub arenas: usize,
    /// Worker threads (0 = all cores).
    pub workers: usize,
    /// Per-model admission queue capacity.
    pub queue_capacity: usize,
    pub requests: u64,
    /// Open-loop Poisson arrival rate in req/s with shedding admission;
    /// `<= 0` runs closed-loop (as fast as backpressure admits).
    pub rate: f64,
    /// Per-model traffic weights (empty = uniform).
    pub mix: Vec<f64>,
    pub seed: u64,
    /// Planner worker threads for models registered without an artifact.
    pub jobs: usize,
    /// Directory to watch for `<model>.plan.json` hot-reload drops.
    pub reload_watch: Option<PathBuf>,
    /// File to (re)write Prometheus text-format metric snapshots to,
    /// periodically while serving and once more at shutdown.
    pub metrics_out: Option<PathBuf>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            models: vec![ModelSpec::planned("tiny")],
            arenas: 4,
            workers: 0,
            queue_capacity: 64,
            requests: 1024,
            rate: 0.0,
            mix: Vec::new(),
            seed: 42,
            jobs: 0,
            reload_watch: None,
            metrics_out: None,
        }
    }
}

/// Whole-run summary returned by [`fleet_serve`].
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub wall: Duration,
    pub completed: usize,
    pub shed: usize,
    pub throughput_rps: f64,
    pub per_model: Vec<ModelReport>,
}

/// Run the fleet under a deterministic mixed-model workload: start a
/// registry + worker pool, emit `cfg.requests` requests across the
/// models (weighted by `cfg.mix`), collect every reply, shut down.
/// Closed-loop runs (`rate <= 0`) use blocking admission, so
/// `completed == requests`; open-loop runs shed on full queues and the
/// report proves `completed == requests - shed` either way.
pub fn fleet_serve(cfg: &FleetConfig) -> Result<FleetReport> {
    let registry = Registry::load(&cfg.models, cfg.arenas, cfg.jobs, cfg.seed)?;
    let elems: Vec<usize> = (0..registry.len())
        .map(|m| registry.current(m).input_elements())
        .collect();
    let mut fleet = Fleet::start(registry, cfg.workers, cfg.queue_capacity);
    if let Some(dir) = &cfg.reload_watch {
        fleet.watch(dir.clone(), Duration::from_millis(100));
    }
    if let Some(path) = &cfg.metrics_out {
        fleet.metrics_writer(path.clone(), Duration::from_millis(500));
    }

    let n_models = elems.len();
    anyhow::ensure!(
        cfg.mix.is_empty() || cfg.mix.len() == n_models,
        "--mix needs one weight per model ({} given, {} models)",
        cfg.mix.len(),
        n_models
    );
    let weights: Vec<f64> = if cfg.mix.is_empty() {
        vec![1.0; n_models]
    } else {
        cfg.mix.clone()
    };
    let total_w: f64 = weights.iter().sum();
    anyhow::ensure!(total_w > 0.0, "--mix weights must sum to a positive value");

    let policy = if cfg.rate > 0.0 {
        AdmissionPolicy::Shed
    } else {
        AdmissionPolicy::Block
    };
    let (reply_tx, reply_rx) = mpsc::channel::<FleetReply>();
    let mut rng = crate::util::rng::Rng::new(cfg.seed ^ 0xF1EE_7000);
    let t0 = Instant::now();
    for id in 0..cfg.requests {
        if cfg.rate > 0.0 {
            thread::sleep(Duration::from_secs_f64(rng.exp(cfg.rate)));
        }
        // weighted model pick, then a deterministic per-(model,id) payload
        let mut pick = rng.next_f64() * total_w;
        let mut m = n_models - 1;
        for (i, w) in weights.iter().enumerate() {
            if pick < *w {
                m = i;
                break;
            }
            pick -= w;
        }
        let mut pr = crate::util::rng::Rng::new(cfg.seed ^ (id << 8) ^ m as u64);
        let data: Vec<f32> = (0..elems[m]).map(|_| pr.uniform(-1.0, 1.0)).collect();
        let req = FleetRequest {
            id,
            data,
            enqueued: Instant::now(),
            reply: reply_tx.clone(),
        };
        fleet.submit(m, req, policy);
    }
    drop(reply_tx);

    let completed = reply_rx.iter().count();
    let wall = t0.elapsed();
    let per_model = fleet.shutdown()?;

    let shed: usize = per_model.iter().map(|r| r.shed).sum();
    let by_metrics: usize = per_model.iter().map(|r| r.completed).sum();
    anyhow::ensure!(
        completed == by_metrics && completed as u64 + shed as u64 == cfg.requests,
        "reply accounting broke: {completed} replies, {by_metrics} recorded, \
         {shed} shed, {} requested",
        cfg.requests
    );
    Ok(FleetReport {
        wall,
        completed,
        shed,
        throughput_rps: completed as f64 / wall.as_secs_f64().max(1e-9),
        per_model,
    })
}
