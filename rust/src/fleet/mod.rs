//! L3.5 fleet serving: many DMO-planned models in one process.
//!
//! The paper makes planning a pre-inference step (§II-D): the arena size
//! and layout are fixed before the first request arrives. This module is
//! the serving layer that cashes that property in at scale:
//!
//! - [`Registry`] — N models, each loaded from (or planned into) a
//!   revalidated [`crate::planner::PlanArtifact`] and proven bit-exact
//!   before serving; hot-reload swaps generations behind an `Arc`
//!   without dropping in-flight requests.
//! - [`ArenaPool`] — K pre-sized arenas per model generation; steady
//!   state performs **zero** per-request arena allocation, and the pool
//!   counts hits/allocs so benches assert it rather than trust it.
//! - [`Admission`] — per-model bounded queues drained round-robin by a
//!   shared worker pool: backpressure for closed-loop producers,
//!   shedding for open-loop ones, fairness across models either way.
//! - [`Fleet`] / [`fleet_serve`] — the running server and the
//!   deterministic mixed-model load generator behind
//!   `dmo serve --models a,b,c` and `benches/serve_scale.rs`.
//! - [`Breaker`] — per-model circuit breaker: K consecutive failures
//!   quarantine a model (shed with a distinct reason) without touching
//!   its healthy peers; recovery probes on cooldown or reload.
//!
//! Fault tolerance is layered on, not bolted in: every request executes
//! under `catch_unwind` (a panic settles as a per-request failure, the
//! worker survives), and a watermark violation degrades the slot to its
//! last-known-good generation or a freshly proven safe plan
//! ([`Registry::degrade`]).

pub mod admission;
pub mod breaker;
pub mod pool;
pub mod registry;
pub mod server;

pub use admission::Admission;
pub use breaker::{Admit, Breaker, BreakerConfig};
pub use pool::{ArenaPool, PooledArena};
pub use registry::{DegradeInfo, DegradeMode, ModelSpec, ModelState, Registry, ReloadInfo};
pub use server::{
    fleet_serve, AdmissionPolicy, Fleet, FleetConfig, FleetOptions, FleetReply, FleetReport,
    FleetRequest, FleetShutdown, ModelReport,
};
