//! Minimal vendored re-implementation of the `anyhow` API surface this
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build environment is fully offline, so crates.io dependencies
//! cannot be resolved; this crate keeps the familiar idioms compiling
//! without network access. Error values are stored as a chain of
//! messages (outermost context first). `{e}` prints the outermost
//! message, `{e:#}` prints the whole chain separated by `: `, matching
//! the real crate's Display behaviour closely enough for logs and tests.

use std::fmt;

/// A dynamic error: a chain of human-readable messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, c) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {c}")?;
                    }
                }
                Ok(())
            }
            None => f.write_str("unknown error"),
        }
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Preserve the source chain as context layers.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Opaque std-error wrapper so `Error` converts into `Box<dyn Error>`.
struct BoxedMessage(String);

impl fmt::Display for BoxedMessage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for BoxedMessage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for BoxedMessage {}

impl From<Error> for Box<dyn std::error::Error + Send + Sync + 'static> {
    fn from(e: Error) -> Self {
        Box::new(BoxedMessage(format!("{e:#}")))
    }
}

impl From<Error> for Box<dyn std::error::Error + 'static> {
    fn from(e: Error) -> Self {
        Box::new(BoxedMessage(format!("{e:#}")))
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Attach a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("root {}", 42))
    }

    #[test]
    fn context_chains_and_formats() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
    }

    #[test]
    fn std_errors_convert() {
        let r: Result<i32> = "nope".parse::<i32>().context("parsing");
        let e = r.unwrap_err();
        assert!(format!("{e:#}").starts_with("parsing: "));
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 1, "x too small: {x}");
            if x > 10 {
                bail!("x too big");
            }
            Ok(x)
        }
        assert!(f(0).is_err());
        assert!(f(11).is_err());
        assert_eq!(f(5).unwrap(), 5);
    }

    #[test]
    fn option_context() {
        let v: Option<usize> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }
}
