//! DenseNet 121 (Huang et al.) — Table III row 10. Every dense layer
//! concatenates its input with its output, so tensors are extremely
//! multi-use; the paper's 4.55 % saving is an allocation-ordering side
//! effect, not direct overlapping (Fig 9).

use crate::ir::graph::{Graph, TensorId};
use crate::ir::op::{Activation, Padding};
use crate::ir::{DType, GraphBuilder, Shape};

const GROWTH: usize = 32;

/// One dense layer: 1×1 bottleneck to 4·growth, 3×3 conv to growth,
/// concat with the running feature map (BN folded, relu fused).
fn dense_layer(b: &mut GraphBuilder, x: TensorId) -> TensorId {
    let h = b.conv2d(x, 4 * GROWTH, (1, 1), (1, 1), Padding::Same, Activation::Relu);
    let h = b.conv2d(h, GROWTH, (3, 3), (1, 1), Padding::Same, Activation::Relu);
    b.concat(&[x, h])
}

/// Transition: 1×1 conv to half the channels + 2×2 average pool.
fn transition(b: &mut GraphBuilder, x: TensorId, channels: usize) -> TensorId {
    let h = b.conv2d(x, channels / 2, (1, 1), (1, 1), Padding::Same, Activation::Relu);
    b.avgpool(h, (2, 2), (2, 2), Padding::Valid)
}

/// Build DenseNet 121 at 224×224 (blocks 6/12/24/16, growth 32).
pub fn build(dtype: DType) -> Graph {
    let mut b = GraphBuilder::new("densenet_121", dtype);
    let x = b.input(Shape::hwc(224, 224, 3));
    let h = b.conv2d(x, 64, (7, 7), (2, 2), Padding::Same, Activation::Relu);
    let mut h = b.maxpool(h, (3, 3), (2, 2), Padding::Same);
    let mut c = 64usize;
    for (bi, n) in [6usize, 12, 24, 16].iter().enumerate() {
        for _ in 0..*n {
            h = dense_layer(&mut b, h);
            c += GROWTH;
        }
        if bi < 3 {
            h = transition(&mut b, h, c);
            c /= 2;
        }
    }
    let h = b.global_avg_pool(h);
    let h = b.reshape(h, Shape::new(&[1, c]));
    let h = b.fully_connected(h, 1000, Activation::None);
    let out = b.softmax(h);
    b.finish(&[out])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_progression() {
        let g = build(DType::F32);
        // after block1 (6 layers): 64 + 6*32 = 256 at 56x56
        let concats: Vec<_> = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, crate::ir::op::OpKind::Concat))
            .collect();
        assert_eq!(concats.len(), 6 + 12 + 24 + 16);
        assert_eq!(g.tensor(concats[5].output).shape, Shape::hwc(56, 56, 256));
        // final features: 1024 at 7x7
        assert_eq!(
            g.tensor(concats.last().unwrap().output).shape,
            Shape::hwc(7, 7, 1024)
        );
    }

    #[test]
    fn inputs_are_multi_use() {
        let g = build(DType::F32);
        // a dense-block tensor feeds both the bottleneck conv and the concat
        let first_concat = g.ops.iter().position(|o| matches!(o.kind, crate::ir::op::OpKind::Concat)).unwrap();
        let x_in = g.ops[first_concat].inputs[0];
        assert!(g.consumers(x_in).len() >= 2);
    }
}
