//! Chaos suite: deterministic fault injection against the serving fleet.
//!
//! Every test here drives the fleet through a seeded [`FaultPlan`] (or a
//! hand-built corrupt artifact) and proves the same contract from
//! different angles: **no request is ever lost** — each one settles as
//! exactly one of completed, shed, or failed
//! (`completed + shed + failed == requests`), panics stay inside the
//! request that caused them, a quarantined model never starves its
//! healthy peers, a watermark violation degrades the slot without
//! producing a single wrong bit, and two runs with the same seed settle
//! to identical counters.

use dmo::fault::{FaultPlan, FaultSpec, GarbleMode};
use dmo::fleet::{
    fleet_serve, AdmissionPolicy, BreakerConfig, Fleet, FleetConfig, FleetOptions, FleetReply,
    FleetRequest, ModelSpec, Registry,
};
use dmo::interp;
use dmo::planner::{PlanArtifact, PlanError, Planner};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

const SEED: u64 = 42;

fn deterministic_input(elems: usize, salt: u64) -> Vec<f32> {
    let mut rng = dmo::util::rng::Rng::new(SEED ^ salt);
    (0..elems).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

fn assert_bit_identical(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} differs ({x} vs {y})"
        );
    }
}

fn submit_blocking(
    fleet: &Fleet,
    id: u64,
    data: Vec<f32>,
    attempts_left: u32,
    tx: &mpsc::Sender<FleetReply>,
) {
    let ok = fleet.submit(
        0,
        FleetRequest {
            id,
            data,
            enqueued: Instant::now(),
            attempts_left,
            reply: tx.clone(),
        },
        AdmissionPolicy::Block,
    );
    assert!(ok, "blocking submit on an open, unquarantined fleet cannot fail");
}

/// Injected panics settle as per-request failures — the workers survive,
/// accounting balances exactly, and a second run with the same seed
/// lands on identical counters (the CI chaos smoke relies on this).
#[test]
fn panic_faults_settle_and_same_seed_runs_match() {
    let cfg = FleetConfig {
        models: vec![ModelSpec::planned("tiny"), ModelSpec::planned("tiny_int8")],
        arenas: 2,
        workers: 2,
        queue_capacity: 8,
        requests: 300,
        seed: 7,
        jobs: 1,
        faults: Some(FaultSpec::parse("panic:2,corrupt-reload:1").unwrap()),
        ..FleetConfig::default()
    };
    let a = fleet_serve(&cfg).unwrap();
    let b = fleet_serve(&cfg).unwrap();
    for r in [&a, &b] {
        assert_eq!(
            r.completed + r.shed + r.failed,
            300,
            "three-way accounting identity"
        );
        assert_eq!(r.failed, 2, "exactly the two injected panics fail");
        assert_eq!(r.shed, 0, "a closed loop under the breaker threshold never sheds");
        assert!(
            r.worker_errors.is_empty(),
            "panics are isolated per request, workers survive: {:?}",
            r.worker_errors
        );
        assert_eq!(r.faults_injected, 3, "2 panics + 1 corrupt reload");
        let rejections: usize = r.per_model.iter().map(|m| m.reload_rejections).sum();
        assert_eq!(rejections, 1, "the garbled hot-reload was rejected");
        for m in &r.per_model {
            assert_eq!(m.generation, 0, "no corrupt artifact was ever installed");
            assert!(!m.quarantined, "2 failures stay under the default K=3");
            assert!(!m.degraded);
        }
    }
    // same seed ⇒ same triggers ⇒ identical settled counters
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.faults_injected, b.faults_injected);
    for (x, y) in a.per_model.iter().zip(&b.per_model) {
        assert_eq!(x.completed, y.completed, "per-model completed ({})", x.model);
        assert_eq!(x.failed, y.failed, "per-model failed ({})", x.model);
        assert_eq!(x.shed, y.shed, "per-model shed ({})", x.model);
        assert_eq!(x.reload_rejections, y.reload_rejections);
    }
}

/// K consecutive failures quarantine exactly the faulty model: its sheds
/// carry the distinct quarantine reason, the healthy peer keeps full
/// throughput, and once the fault window passes a half-open probe closes
/// the breaker again.
#[test]
fn quarantined_model_sheds_distinctly_and_never_starves_its_peer() {
    let report = fleet_serve(&FleetConfig {
        models: vec![ModelSpec::planned("tiny"), ModelSpec::planned("tiny_int8")],
        arenas: 2,
        workers: 2,
        queue_capacity: 4,
        requests: 400,
        seed: 21,
        jobs: 1,
        faults: Some(FaultSpec::parse("panic:4@0").unwrap()),
        breaker: BreakerConfig {
            threshold: 2,
            cooldown: 4,
        },
        ..FleetConfig::default()
    })
    .unwrap();
    assert_eq!(report.completed + report.shed + report.failed, 400);
    assert!(report.worker_errors.is_empty());
    let m0 = &report.per_model[0];
    let m1 = &report.per_model[1];
    // the faulty model: every window dispatch fails, the breaker opens,
    // and quarantine sheds are counted under their own reason
    assert_eq!(m0.failed, 4, "every injected panic settles as a failure");
    assert!(
        report.quarantine_shed > 0,
        "an open breaker must shed at admission with the quarantine reason"
    );
    assert_eq!(
        m0.metrics.shed_quarantined, report.quarantine_shed,
        "only the faulty model is quarantined"
    );
    // the healthy peer never pays for its neighbour's faults
    assert_eq!(m1.failed, 0, "healthy peer has zero failures");
    assert_eq!(m1.shed, 0, "healthy peer sheds nothing");
    assert_eq!(m1.metrics.shed_quarantined, 0);
    assert!(
        m1.completed > 100,
        "healthy peer keeps its full throughput (completed {})",
        m1.completed
    );
    // recovery: the fault window is finite, so a probe eventually lands
    // outside it and closes the breaker
    assert!(!m0.quarantined, "breaker closes once the fault clears");
    assert!(
        m0.completed > 100,
        "the model serves again after recovery (completed {})",
        m0.completed
    );
}

/// An injected arena corruption trips the per-request watermark check;
/// the generation is abandoned for a freshly proven safe plan (no
/// overlaps, no rewrites) — and every *successful* reply, before and
/// after the degrade, stays bit-identical to the disjoint reference.
#[test]
fn watermark_violation_degrades_to_a_safe_plan_and_stays_bit_identical() {
    let spec = FaultSpec::parse("corrupt-arena:1@0").unwrap();
    let fault_plan = Arc::new(FaultPlan::new(&spec, 5, 30, 1));
    let reg = Registry::load(&[ModelSpec::planned("tiny")], 1, 1, SEED).unwrap();
    let fleet = Fleet::start_with(
        reg,
        1, // one worker: replies settle in dispatch order
        64,
        FleetOptions {
            breaker: BreakerConfig {
                threshold: 100, // keep the breaker out of this test
                cooldown: 8,
            },
            faults: Some(fault_plan),
            deadline: None,
            watermark_checks: true,
        },
    );
    let elems = fleet.registry.current(0).input_elements();
    let (tx, rx) = mpsc::channel::<FleetReply>();
    for id in 0..30u64 {
        submit_blocking(&fleet, id, deterministic_input(elems, id), 0, &tx);
    }
    drop(tx);
    let replies: Vec<FleetReply> = rx.iter().collect();
    assert_eq!(replies.len(), 30, "zero lost replies under corruption");

    let failures: Vec<&FleetReply> = replies.iter().filter(|r| r.error.is_some()).collect();
    assert_eq!(failures.len(), 1, "exactly the corrupted request fails");
    let msg = failures[0].error.as_deref().unwrap();
    assert!(msg.contains("watermark"), "failure names the watermark: {msg}");

    // the corrupted generation was abandoned — no previous generation
    // exists, so a freshly planned + proven safe plan takes the slot
    assert!(fleet.registry.is_degraded(0), "slot flagged degraded");
    assert_eq!(fleet.registry.degrades(0), 1, "one degrade transition");
    let cur = fleet.registry.current(0);
    assert_eq!(cur.generation, 1, "safe plan serves as the next generation");
    assert!(
        cur.plan.alloc.applied.is_empty(),
        "safe plan relaxes nothing: every buffer disjoint"
    );

    // correctness under degradation: every successful reply — generation
    // 0 before the fault, the safe plan after — is bit-identical to the
    // disjoint reference interpreter
    let graph = dmo::models::build("tiny").unwrap();
    for r in replies.iter().filter(|r| r.error.is_none()) {
        let reference = interp::run_reference(&graph, &[deterministic_input(elems, r.id)], SEED)
            .unwrap()
            .remove(0);
        assert_bit_identical(&r.output, &reference, &format!("request {}", r.id));
    }
    let served_degraded = replies
        .iter()
        .filter(|r| r.error.is_none() && r.generation == 1)
        .count();
    assert!(
        served_degraded > 0,
        "requests behind the fault are served by the safe plan"
    );

    // observability: state gauge 1 (degraded), fault + degrade counters
    let snap = fleet.prometheus_snapshot();
    assert!(
        snap.contains("dmo_model_state{model=\"tiny\"} 1"),
        "degraded state gauge missing:\n{snap}"
    );
    assert!(snap.contains("dmo_faults_injected_total{kind=\"corrupt-arena\"} 1"));
    assert!(snap.contains("dmo_model_degraded_total{model=\"tiny\"} 1"));

    // a fresh validated reload recovers the slot
    let replan = Planner::for_graph(&graph).dmo(true).plan().unwrap();
    fleet
        .reload(0, PlanArtifact::from_plan(&graph, &replan))
        .unwrap();
    assert!(
        !fleet.registry.is_degraded(0),
        "a successful reload clears the degraded flag"
    );

    let down = fleet.shutdown().unwrap();
    assert!(down.worker_errors.is_empty());
    let m = &down.per_model[0];
    assert_eq!(m.completed, 29);
    assert_eq!(m.failed, 1);
    assert_eq!(m.degrades, 1);
    assert!(m.metrics.degraded > 0, "degraded-served counter advanced");
}

/// A stalled admission queue backs traffic up but loses nothing: the
/// stall expires, the queue drains, and every request completes.
#[test]
fn queue_stall_delays_but_never_drops_requests() {
    let report = fleet_serve(&FleetConfig {
        models: vec![ModelSpec::planned("tiny")],
        arenas: 2,
        workers: 2,
        queue_capacity: 4,
        requests: 120,
        seed: 13,
        jobs: 1,
        faults: Some(FaultSpec::parse("stall:1@0").unwrap()),
        ..FleetConfig::default()
    })
    .unwrap();
    assert_eq!(report.completed, 120, "a stalled queue drains; nothing is lost");
    assert_eq!(report.shed, 0);
    assert_eq!(report.failed, 0);
    assert_eq!(report.faults_injected, 1);
    assert!(report.per_model[0].max_queue_depth >= 1);
}

/// Without a deadline an injected exec delay is just latency: every
/// request still completes.
#[test]
fn delay_faults_slow_but_do_not_fail_without_a_deadline() {
    let report = fleet_serve(&FleetConfig {
        models: vec![ModelSpec::planned("tiny")],
        arenas: 2,
        workers: 2,
        queue_capacity: 8,
        requests: 60,
        seed: 5,
        jobs: 1,
        faults: Some(FaultSpec::parse("delay:2@0").unwrap()),
        ..FleetConfig::default()
    })
    .unwrap();
    assert_eq!(report.completed, 60);
    assert_eq!(report.failed, 0);
    assert_eq!(report.faults_injected, 2);
}

/// The closed-loop client's retry path: an injected panic is a
/// *retryable* failure, the resubmitted attempt regenerates the exact
/// same payload, and with enough budget every request eventually
/// completes — the failure count stays zero while the retry counter
/// records exactly the injected faults.
#[test]
fn client_retries_with_backoff_recover_every_injected_panic() {
    let report = fleet_serve(&FleetConfig {
        models: vec![ModelSpec::planned("tiny")],
        arenas: 2,
        workers: 2,
        queue_capacity: 8,
        requests: 100,
        seed: 3,
        jobs: 1,
        faults: Some(FaultSpec::parse("panic:2@0").unwrap()),
        retries: 3,
        breaker: BreakerConfig {
            threshold: 10,
            cooldown: 8,
        },
        ..FleetConfig::default()
    })
    .unwrap();
    // each of the 2 window sequence numbers is dispatched exactly once
    // over the whole run, so exactly 2 attempts fail — and each had
    // retry budget, so nothing settles as failed
    assert_eq!(report.completed, 100, "every request settles successfully");
    assert_eq!(report.failed, 0);
    assert_eq!(report.retried, 2, "each injected panic burned one retry");
    assert_eq!(report.faults_injected, 2);
    assert_eq!(report.shed, 0);
}

/// Deadlines end to end: an attempt that is already past its deadline
/// settles as a retryable failure before burning execution time, and an
/// injected 300 ms exec delay blows a 150 ms deadline even though the
/// result was computed — the answer arrived too late to be an answer.
#[test]
fn injected_delay_blows_the_deadline_and_retries_recover() {
    let spec = FaultSpec::parse("delay:2@0").unwrap();
    let mut fp = FaultPlan::new(&spec, 9, 40, 1);
    fp.delay = Duration::from_millis(300); // dwarfs any honest execution
    let fleet = Fleet::start_with(
        Registry::load(&[ModelSpec::planned("tiny")], 1, 1, SEED).unwrap(),
        1,
        8,
        FleetOptions {
            breaker: BreakerConfig {
                threshold: 100,
                cooldown: 8,
            },
            faults: Some(Arc::new(fp)),
            deadline: Some(Duration::from_millis(150)),
            watermark_checks: false,
        },
    );
    let elems = fleet.registry.current(0).input_elements();
    let (tx, rx) = mpsc::channel::<FleetReply>();
    // depth-1 closed loop: queue wait stays ~0, so only the injected
    // delays can blow the deadline
    let mut deadline_failures = 0usize;
    for id in 0..40u64 {
        submit_blocking(&fleet, id, deterministic_input(elems, id), 2, &tx);
        loop {
            let rep = rx.recv().unwrap();
            match rep.error {
                None => break,
                Some(msg) => {
                    assert!(
                        msg.contains("deadline"),
                        "only deadline failures expected: {msg}"
                    );
                    assert!(rep.output.is_empty(), "a late answer is not an answer");
                    deadline_failures += 1;
                    assert!(
                        rep.attempts_left > 0,
                        "the 2-deep retry budget covers the 2-long fault window"
                    );
                    submit_blocking(
                        &fleet,
                        rep.id,
                        deterministic_input(elems, rep.id),
                        rep.attempts_left - 1,
                        &tx,
                    );
                }
            }
        }
    }
    drop(tx);
    // the fault window is 2 consecutive sequence numbers, each consumed
    // exactly once (the retry of the first delayed attempt eats the
    // second window slot), so exactly 2 attempts expire
    assert_eq!(deadline_failures, 2);
    let down = fleet.shutdown().unwrap();
    assert!(down.worker_errors.is_empty());
    let m = &down.per_model[0];
    assert_eq!(m.completed, 40, "every request eventually completed");
    assert_eq!(m.failed, 0, "both expiries had retry budget left");
    assert_eq!(m.metrics.retries, 2);
    assert_eq!(m.metrics.deadline_expired, 2);
}

/// An attempt born long before its deadline is rejected *before*
/// execution — the deadline gate runs first and costs no worker time.
#[test]
fn pre_expired_deadline_fails_before_execution_and_a_retry_lands() {
    let fleet = Fleet::start_with(
        Registry::load(&[ModelSpec::planned("tiny")], 1, 1, SEED).unwrap(),
        1,
        8,
        FleetOptions {
            breaker: BreakerConfig {
                threshold: 100,
                cooldown: 8,
            },
            faults: None,
            deadline: Some(Duration::from_secs(5)),
            watermark_checks: false,
        },
    );
    let elems = fleet.registry.current(0).input_elements();
    let (tx, rx) = mpsc::channel::<FleetReply>();
    // an attempt enqueued a minute ago: already past its 5 s deadline
    let long_ago = Instant::now()
        .checked_sub(Duration::from_secs(60))
        .or_else(|| Instant::now().checked_sub(Duration::from_secs(6)))
        .expect("the process has been alive for seconds already");
    let ok = fleet.submit(
        0,
        FleetRequest {
            id: 0,
            data: deterministic_input(elems, 0),
            enqueued: long_ago,
            attempts_left: 1,
            reply: tx.clone(),
        },
        AdmissionPolicy::Block,
    );
    assert!(ok);
    let first = rx.recv().unwrap();
    let msg = first.error.as_deref().expect("expired attempt must fail");
    assert!(msg.contains("deadline expired before execution"), "{msg}");
    assert_eq!(first.attempts_left, 1, "the reply echoes the remaining budget");
    // the client retries with a fresh clock — and succeeds
    submit_blocking(&fleet, 0, deterministic_input(elems, 0), 0, &tx);
    drop(tx);
    assert!(rx.recv().unwrap().error.is_none(), "the retry lands");
    let down = fleet.shutdown().unwrap();
    let m = &down.per_model[0];
    assert_eq!(m.metrics.deadline_expired, 1);
    assert_eq!(m.metrics.retries, 1, "budgeted failure settles as a retry");
    assert_eq!(m.failed, 0);
    assert_eq!(m.completed, 1);
}

/// Satellite corpus: truncated, bit-flipped, future-versioned and
/// wrong-fingerprint artifacts all come back as *typed* [`PlanError`]s —
/// never a panic — at both `PlanArtifact::load` and fleet reload, and a
/// rejected reload leaves the serving generation untouched.
#[test]
fn corrupt_artifact_corpus_yields_typed_errors_and_never_panics() {
    let dir = std::env::temp_dir().join(format!("dmo_chaos_corpus_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let g = dmo::models::build("tiny").unwrap();
    let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
    let art = PlanArtifact::from_plan(&g, &plan);
    let good = dir.join("good.plan.json");
    art.save(&good).unwrap();
    // positive control: the untouched round trip is clean
    PlanArtifact::load(&good).unwrap().to_plan(&g).unwrap();
    let text = std::fs::read_to_string(&good).unwrap();

    let mut corpus: Vec<(String, String)> = Vec::new();
    for pct in [5usize, 25, 50, 75, 90, 99] {
        // artifact JSON is ASCII, so byte truncation is char-safe
        corpus.push((
            format!("truncated-{pct}"),
            text[..text.len() * pct / 100].to_string(),
        ));
    }
    corpus.push(("empty".into(), String::new()));
    corpus.push(("bitflip-quotes".into(), text.replace('"', "\u{7}")));
    corpus.push(("bitflip-braces".into(), text.replace('{', "[")));
    corpus.push(("not-json".into(), "\u{0}\u{1}\u{2}garbage\u{fe}\u{ff}".into()));
    for (name, body) in &corpus {
        let p = dir.join(format!("{name}.plan.json"));
        std::fs::write(&p, body).unwrap();
        let err = PlanArtifact::load(&p)
            .expect_err(&format!("corpus entry `{name}` must not load"));
        assert!(
            matches!(err, PlanError::Malformed(_)),
            "`{name}`: wrong error class: {err}"
        );
    }
    // a missing file is a typed I/O error, not a panic
    let err = PlanArtifact::load(&dir.join("never-written.plan.json"))
        .expect_err("missing file must not load");
    assert!(matches!(err, PlanError::Io(_)), "{err}");
    // a future version is refused at parse, before any field is trusted
    let mut future = art.clone();
    future.version = 99;
    let p = dir.join("future.plan.json");
    future.save(&p).unwrap();
    let err = PlanArtifact::load(&p).expect_err("future version must be refused");
    assert!(
        matches!(err, PlanError::UnsupportedVersion { found: 99, .. }),
        "{err}"
    );
    // wrong fingerprint / O_s hash: parse fine, refused at revalidation
    let err = FaultPlan::garble(&art, GarbleMode::FingerprintFlip)
        .to_plan(&g)
        .expect_err("flipped fingerprint must be refused");
    assert!(matches!(err, PlanError::GraphMismatch { .. }), "{err}");
    let err = FaultPlan::garble(&art, GarbleMode::OsHashFlip)
        .to_plan(&g)
        .expect_err("flipped O_s hash must be refused");
    assert!(matches!(err, PlanError::Malformed(_)), "{err}");

    // and through the fleet: a rejected reload leaves the serving
    // generation untouched and the server answering
    let reg = Registry::load(&[ModelSpec::planned("tiny")], 1, 1, SEED).unwrap();
    let fleet = Fleet::start(reg, 1, 8);
    assert!(fleet
        .reload(0, FaultPlan::garble(&art, GarbleMode::FingerprintFlip))
        .is_err());
    assert!(fleet
        .reload(0, FaultPlan::garble(&art, GarbleMode::OsHashFlip))
        .is_err());
    assert_eq!(
        fleet.registry.current(0).generation,
        0,
        "serving generation untouched by rejected reloads"
    );
    assert_eq!(fleet.registry.reload_rejections(0), 2);
    let elems = fleet.registry.current(0).input_elements();
    let (tx, rx) = mpsc::channel::<FleetReply>();
    submit_blocking(&fleet, 0, deterministic_input(elems, 0), 0, &tx);
    drop(tx);
    let rep = rx.recv().unwrap();
    assert!(rep.error.is_none(), "still serving after rejected reloads");
    assert_eq!(rep.generation, 0);
    fleet.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
