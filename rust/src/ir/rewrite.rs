//! Graph rewrites — §II-A operation splitting as a composable,
//! executable pass surface.
//!
//! The paper splits a chained window-op pair into `k` vertical bands by
//! hand (MobileNet v1: 96 KB → 66 KB peak) and calls automatic
//! application future work. This module *is* that application,
//! generalised past the paper: a rewrite is described by a
//! [`RewriteSpec`] — a pair split, or a whole chain of depth ≥ 2 banded
//! end-to-end (Pex-style partial execution, arXiv 2211.17246) — and a
//! plan may carry *several* independent specs. The single entry point
//! [`apply`] materialises a spec sequence as real graph ops:
//! [`OpKind::Band`] slices whose halo recomputation is explicit in
//! their shapes, plus an [`OpKind::ConcatRows`] reassembly — so the
//! rewritten graph plans, interprets, emits as C and fit-checks through
//! every downstream layer unchanged.
//!
//! Structure of the rewrite for a chain `o_1 → … → o_d` split `parts`
//! ways (`in → o_1 → t_1 → … → o_d → out` becomes):
//!
//! ```text
//! in ─┬─ band(o_1) ─ t_1_band_p ─ … ─ band(o_d) ─ out_band_p ─┐
//!     └─ … one banded chain per part p …                      ├─ concat-rows → out
//!                                                             ┘
//! ```
//!
//! Only one band per level is live at a time, so the peak drops to
//! roughly `in + Σ level bands + out` — at the price of recomputing the
//! receptive-field halo rows adjacent bands share at *every*
//! intermediate level. For depth 2 this is exactly the paper's §II-A
//! pair split; for depth ≥ 3 the halo recompute is amortised across the
//! chain (no intermediate level is ever fully materialised), which is
//! where chains beat pairs on hourglass-shaped regions (small input,
//! fat intermediates, small output). The memory ↔ compute trade is
//! quantified by [`crate::planner::split::analyse_chain`].
//!
//! Every rewritten op records where it came from ([`Provenance`]) and
//! points its synthetic weight stream at the original op
//! ([`crate::ir::graph::OpNode::weight_seed`]), which is what makes
//! banded execution bit-identical to the unsplit reference — the
//! correctness anchor `interp::validate_plan` enforces.

use super::graph::{Graph, OpId, OpNode, TensorId, TensorInfo, TensorKind};
use super::op::{BandParams, OpKind};
use super::shape::Shape;
use anyhow::{ensure, Result};

/// One recorded pair split: ops `first → second` of the graph it is
/// applied to, banded into (up to) `parts` row bands. The pair-shaped
/// special case of [`RewriteSpec`], kept as a named struct because the
/// pair is the paper's §II-A unit and artifact v3 serialised exactly
/// this shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SplitSpec {
    /// Producer op index in the graph the spec applies to.
    pub first: usize,
    /// Consumer op index (must be the sole consumer of `first`'s output).
    pub second: usize,
    /// Number of row bands.
    pub parts: usize,
}

/// One composable graph rewrite, applied by [`apply`]. Op indices refer
/// to the graph the spec is applied to (for a sequence, the graph
/// produced by the previous application). Serialised in
/// [`crate::planner::PlanArtifact`] v4 so a rewritten plan can be
/// re-derived from the base graph in another process; v3 artifacts'
/// single pair splits load as [`RewriteSpec::PairSplit`].
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum RewriteSpec {
    /// The §II-A pair split: `first → second` banded `parts` ways.
    PairSplit(SplitSpec),
    /// A chain of `ops.len() ≥ 2` ops banded end-to-end into `parts`
    /// row bands (Pex-style). `ops` must be a producer→consumer chain
    /// in increasing index order; depth 2 is exactly `PairSplit`.
    ChainSplit { ops: Vec<OpId>, parts: usize },
}

impl RewriteSpec {
    /// The op indices this spec bands, producer first.
    pub fn op_indices(&self) -> Vec<usize> {
        match self {
            RewriteSpec::PairSplit(s) => vec![s.first, s.second],
            RewriteSpec::ChainSplit { ops, .. } => ops.iter().map(|o| o.0).collect(),
        }
    }

    /// Number of row bands.
    pub fn parts(&self) -> usize {
        match self {
            RewriteSpec::PairSplit(s) => s.parts,
            RewriteSpec::ChainSplit { parts, .. } => *parts,
        }
    }

    /// Chain depth (2 for a pair).
    pub fn depth(&self) -> usize {
        match self {
            RewriteSpec::PairSplit(_) => 2,
            RewriteSpec::ChainSplit { ops, .. } => ops.len(),
        }
    }

    /// Human-readable one-liner for reports and the CLI.
    pub fn describe(&self) -> String {
        let ops = self
            .op_indices()
            .iter()
            .map(|i| i.to_string())
            .collect::<Vec<_>>()
            .join("→");
        match self {
            RewriteSpec::PairSplit(_) => format!("ops {ops} banded ×{}", self.parts()),
            RewriteSpec::ChainSplit { .. } => format!("chain {ops} banded ×{}", self.parts()),
        }
    }
}

/// Where a rewritten op came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpOrigin {
    /// Copied unchanged; the id is the op's index in the source graph.
    Kept(OpId),
    /// Band `part` (of `parts`) of source op `of`.
    Band { of: OpId, part: usize, parts: usize },
    /// The concat-rows op reassembling source op `of`'s output.
    Assemble { of: OpId },
}

/// Per-op provenance of a rewritten graph, indexed by the new op id.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Provenance {
    pub per_op: Vec<OpOrigin>,
}

impl Provenance {
    /// Origin of rewritten op `op`.
    pub fn origin(&self, op: OpId) -> OpOrigin {
        self.per_op[op.0]
    }

    /// Identity provenance for an unrewritten graph.
    pub fn identity(n_ops: usize) -> Provenance {
        Provenance {
            per_op: (0..n_ops).map(|i| OpOrigin::Kept(OpId(i))).collect(),
        }
    }
}

/// A rewritten graph plus the map back to its source.
#[derive(Debug, Clone)]
pub struct SplitResult {
    pub graph: Graph,
    pub provenance: Provenance,
}

/// Per-part banded geometry of a *pair* split: output rows
/// `[out0, out1)` of the pair's final output, and the intermediate rows
/// `[mid0, mid1)` the part must compute (adjacent parts' mid ranges
/// overlap by the halo). The pair view of [`ChainBandPlan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BandPlan {
    pub out0: usize,
    pub out1: usize,
    pub mid0: usize,
    pub mid1: usize,
}

/// Per-part banded geometry of a chain split: `rows[j]` is the row
/// range `[r0, r1)` of chain op `j`'s output this part computes. The
/// last entry is the part's slice of the final output (exact
/// partition); every earlier level overlaps its neighbours by the
/// receptive-field halo.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChainBandPlan {
    pub rows: Vec<(usize, usize)>,
}

/// Check whether the op sequence `ops` forms a bandable chain that can
/// be split `parts` ways. Errors describe the first violated
/// precondition.
pub fn chain_eligible(graph: &Graph, ops: &[OpId], parts: usize) -> Result<()> {
    ensure!(parts >= 2, "parts must be >= 2");
    ensure!(ops.len() >= 2, "a chain needs at least 2 ops");
    for w in ops.windows(2) {
        ensure!(
            w[0].0 < w[1].0,
            "producer must precede consumer in op order"
        );
    }
    for &o in ops {
        ensure!(o.0 < graph.ops.len(), "op id out of range");
        let op = graph.op(o);
        ensure!(op.kind.bandable(), "op `{}` is not bandable", op.name);
        ensure!(
            op.inputs.len() == 1,
            "op `{}` must have exactly one activation input",
            op.name
        );
    }
    for w in ops.windows(2) {
        let f = graph.op(w[0]);
        let s = graph.op(w[1]);
        ensure!(
            s.inputs[0] == f.output,
            "op `{}` must consume exactly `{}`'s output",
            s.name,
            f.name
        );
        ensure!(
            graph.consumers(f.output) == vec![w[1]],
            "intermediate `{}` must have exactly one consumer",
            graph.tensor(f.output).name
        );
        ensure!(
            graph.tensor(f.output).kind == TensorKind::Intermediate,
            "cannot band through a graph input/output tensor"
        );
    }
    let inp = graph.tensor(graph.op(ops[0]).inputs[0]);
    ensure!(inp.shape.rank() == 4, "need an NHWC chain");
    for &o in ops {
        ensure!(
            graph.tensor(graph.op(o).output).shape.rank() == 4,
            "need an NHWC chain"
        );
    }
    let out = graph.tensor(graph.op(*ops.last().unwrap()).output);
    ensure!(
        out.shape.h() >= parts,
        "output has {} rows, cannot split into {} bands",
        out.shape.h(),
        parts
    );
    Ok(())
}

/// Check whether the pair `first → second` can be split. Thin shim over
/// [`chain_eligible`] at depth 2, kept for the §II-A pair surface.
pub fn split_eligible(graph: &Graph, first: OpId, second: OpId, parts: usize) -> Result<()> {
    chain_eligible(graph, &[first, second], parts)
}

/// The balanced row partition a `parts`-way split of the chain uses:
/// part `p` produces output rows `[p·O_h/parts, (p+1)·O_h/parts)` of
/// the final output, and the row range of every earlier level is
/// derived backwards through each op's receptive field
/// ([`BandParams::in_rows_needed`]). Shared by the rewrite itself and
/// the analysis ([`crate::planner::split::analyse_chain`]), so
/// predicted and materialised geometry can never diverge.
pub fn chain_band_plan(graph: &Graph, ops: &[OpId], parts: usize) -> Result<Vec<ChainBandPlan>> {
    chain_eligible(graph, ops, parts)?;
    let d = ops.len();
    // full frame height of each level's output (and the chain input)
    let level_h: Vec<usize> = ops
        .iter()
        .map(|&o| graph.tensor(graph.op(o).output).shape.h())
        .collect();
    let oh = level_h[d - 1];
    let mut plans = Vec::with_capacity(parts);
    for p in 0..parts {
        let mut rows = vec![(0usize, 0usize); d];
        rows[d - 1] = (p * oh / parts, (p + 1) * oh / parts);
        for j in (0..d - 1).rev() {
            // rows of op j's output that op j+1's band reads
            let s = graph.op(ops[j + 1]);
            let probe = BandParams {
                inner: Box::new(s.kind.clone()),
                full_in_h: level_h[j],
                in_row0: 0,
                full_out_h: level_h[j + 1],
                out_row0: rows[j + 1].0,
                out_rows: rows[j + 1].1 - rows[j + 1].0,
            };
            let (r0, r1) = probe.in_rows_needed();
            ensure!(
                r1 > r0,
                "band {p} of `{}` reads no input rows (degenerate geometry)",
                s.name
            );
            rows[j] = (r0, r1);
        }
        plans.push(ChainBandPlan { rows });
    }
    Ok(plans)
}

/// Pair view of [`chain_band_plan`], kept for the §II-A surface and the
/// pair-shaped analysis/report code.
pub fn band_plan(graph: &Graph, first: OpId, second: OpId, parts: usize) -> Result<Vec<BandPlan>> {
    let plans = chain_band_plan(graph, &[first, second], parts)?;
    Ok(plans
        .into_iter()
        .map(|p| BandPlan {
            out0: p.rows[1].0,
            out1: p.rows[1].1,
            mid0: p.rows[0].0,
            mid1: p.rows[0].1,
        })
        .collect())
}

/// Materialise the end-to-end banding of a bandable chain into `parts`
/// bands — the executable form of [`RewriteSpec::ChainSplit`] (and, at
/// depth 2, of the §II-A pair split).
///
/// The returned graph keeps every original tensor id (the bypassed
/// intermediates become orphans the planner skips) and appends the band
/// tensors; downstream consumers of the chain's output are untouched
/// because the reassembled tensor keeps its id. All ops carry explicit
/// [`OpNode::weight_seed`] provenance so weight streams — and therefore
/// numerics — match the unsplit graph exactly.
pub fn split_chain(graph: &Graph, ops: &[OpId], parts: usize) -> Result<SplitResult> {
    let plans = chain_band_plan(graph, ops, parts)?;
    let d = ops.len();
    let chain_ops: Vec<OpNode> = ops.iter().map(|&o| graph.op(o).clone()).collect();
    let cin = chain_ops[0].inputs[0];
    let in_h = graph.tensor(cin).shape.h();
    let infos: Vec<TensorInfo> = chain_ops
        .iter()
        .map(|o| graph.tensor(o.output).clone())
        .collect();
    let last = *ops.last().unwrap();

    let mut g = Graph {
        name: graph.name.clone(),
        tensors: graph.tensors.clone(),
        ops: Vec::with_capacity(graph.ops.len() + d * parts + 1 - d),
        inputs: graph.inputs.clone(),
        outputs: graph.outputs.clone(),
    };
    let mut per_op: Vec<OpOrigin> = Vec::with_capacity(g.ops.capacity());

    // band tensors, appended past the existing ids: per part, one band
    // of every level's output (the last level's band is the part's
    // slice of the final output, reassembled below)
    let mut bands: Vec<Vec<TensorId>> = Vec::with_capacity(parts);
    for (p, cp) in plans.iter().enumerate() {
        let mut level = Vec::with_capacity(d);
        for j in 0..d {
            let (r0, r1) = cp.rows[j];
            let t = TensorId(g.tensors.len());
            g.tensors.push(TensorInfo {
                name: format!("{}_band{p}", infos[j].name),
                shape: Shape::hwc(r1 - r0, infos[j].shape.w(), infos[j].shape.c()),
                dtype: infos[j].dtype,
                kind: TensorKind::Intermediate,
            });
            level.push(t);
        }
        bands.push(level);
    }

    for (i, op) in graph.ops.iter().enumerate() {
        if ops.iter().any(|o| o.0 == i) && i != last.0 {
            continue; // re-emitted as bands at the chain tail's slot
        }
        if i == last.0 {
            for (p, cp) in plans.iter().enumerate() {
                for j in 0..d {
                    let (r0, r1) = cp.rows[j];
                    let (src, in_row0, full_in_h) = if j == 0 {
                        (cin, 0, in_h)
                    } else {
                        (bands[p][j - 1], cp.rows[j - 1].0, infos[j - 1].shape.h())
                    };
                    g.ops.push(OpNode {
                        name: format!("{}_band{p}", chain_ops[j].name),
                        kind: OpKind::Band(BandParams {
                            inner: Box::new(chain_ops[j].kind.clone()),
                            full_in_h,
                            in_row0,
                            full_out_h: infos[j].shape.h(),
                            out_row0: r0,
                            out_rows: r1 - r0,
                        }),
                        inputs: vec![src],
                        output: bands[p][j],
                        weights: chain_ops[j].weights.clone(),
                        weight_seed: Some(chain_ops[j].weight_key(ops[j].0)),
                    });
                    per_op.push(OpOrigin::Band {
                        of: ops[j],
                        part: p,
                        parts,
                    });
                }
            }
            g.ops.push(OpNode {
                name: format!("{}_assemble", chain_ops[d - 1].name),
                kind: OpKind::ConcatRows,
                inputs: bands.iter().map(|level| level[d - 1]).collect(),
                output: chain_ops[d - 1].output,
                weights: Vec::new(),
                weight_seed: Some(chain_ops[d - 1].weight_key(last.0)),
            });
            per_op.push(OpOrigin::Assemble { of: last });
            continue;
        }
        let mut kept = op.clone();
        kept.weight_seed = Some(op.weight_key(i));
        g.ops.push(kept);
        per_op.push(OpOrigin::Kept(OpId(i)));
    }

    g.validate()?;
    Ok(SplitResult {
        graph: g,
        provenance: Provenance { per_op },
    })
}

/// Materialise the §II-A split of `first → second` into `parts` bands.
/// Thin shim over [`split_chain`] at depth 2 — there is one code path
/// that executes rewrites.
pub fn split_pair(graph: &Graph, first: OpId, second: OpId, parts: usize) -> Result<SplitResult> {
    split_chain(graph, &[first, second], parts)
}

/// Apply a recorded sequence of rewrites (each spec indexes into the
/// graph produced by the previous application) and return the final
/// graph with provenance composed back to the base graph where
/// possible. This is the single entry point every rewrite consumer —
/// the planner, artifact revalidation, the CLI — goes through.
pub fn apply(graph: &Graph, specs: &[RewriteSpec]) -> Result<(Graph, Provenance)> {
    let mut g = graph.clone();
    let mut prov = Provenance::identity(graph.ops.len());
    for spec in specs {
        let r = match spec {
            RewriteSpec::PairSplit(s) => {
                split_chain(&g, &[OpId(s.first), OpId(s.second)], s.parts)?
            }
            RewriteSpec::ChainSplit { ops, parts } => split_chain(&g, ops, *parts)?,
        };
        let per_op = r
            .provenance
            .per_op
            .iter()
            .map(|o| match *o {
                OpOrigin::Kept(prev) => prov.per_op[prev.0],
                OpOrigin::Band { of, part, parts } => match prov.per_op[of.0] {
                    OpOrigin::Kept(orig) => OpOrigin::Band {
                        of: orig,
                        part,
                        parts,
                    },
                    // rewriting an already-rewritten op: keep the nearest
                    // ancestor id (weight provenance still composes via
                    // `weight_seed`, which chains through `weight_key`)
                    _ => OpOrigin::Band { of, part, parts },
                },
                OpOrigin::Assemble { of } => match prov.per_op[of.0] {
                    OpOrigin::Kept(orig) => OpOrigin::Assemble { of: orig },
                    _ => OpOrigin::Assemble { of },
                },
            })
            .collect();
        prov = Provenance { per_op };
        g = r.graph;
    }
    Ok((g, prov))
}

/// Apply a recorded sequence of pair splits. Thin shim over [`apply`]
/// with every spec mapped to [`RewriteSpec::PairSplit`] — kept for the
/// §II-A surface and artifact-v3 revalidation.
pub fn apply_splits(graph: &Graph, splits: &[SplitSpec]) -> Result<(Graph, Provenance)> {
    let specs: Vec<RewriteSpec> = splits.iter().map(|&s| RewriteSpec::PairSplit(s)).collect();
    apply(graph, &specs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::{gen_input, run_reference};
    use crate::ir::op::{Activation, Padding};
    use crate::ir::{DType, GraphBuilder};

    /// The §II-A MobileNet shape: 1x1 conv doubling bytes, then a
    /// stride-2 depthwise conv.
    fn pair_graph(dtype: DType) -> Graph {
        let mut b = GraphBuilder::new("pair", dtype);
        let x = b.input(Shape::hwc(16, 16, 4));
        let c = b.conv2d(x, 8, (1, 1), (1, 1), Padding::Same, Activation::Relu6);
        let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None);
        b.finish(&[d])
    }

    /// A depth-3 bandable chain: conv → dwconv → pool.
    fn chain_graph(dtype: DType) -> Graph {
        let mut b = GraphBuilder::new("chain", dtype);
        let x = b.input(Shape::hwc(16, 16, 2));
        let c = b.conv2d(x, 8, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let d = b.dwconv2d(c, (3, 3), (1, 1), Padding::Same, Activation::Relu6);
        let p = b.maxpool(d, (2, 2), (2, 2), Padding::Valid);
        b.finish(&[p])
    }

    #[test]
    fn split_pair_materialises_bands_and_validates() {
        let g = pair_graph(DType::F32);
        let r = split_pair(&g, OpId(0), OpId(1), 4).unwrap();
        // 4 × (A, B) + concat
        assert_eq!(r.graph.ops.len(), 9);
        assert_eq!(r.provenance.per_op.len(), 9);
        assert!(matches!(
            r.provenance.origin(OpId(0)),
            OpOrigin::Band { of: OpId(0), part: 0, parts: 4 }
        ));
        assert!(matches!(r.provenance.origin(OpId(8)), OpOrigin::Assemble { of: OpId(1) }));
        // the reassembled output keeps its tensor id
        assert_eq!(r.graph.ops[8].output, g.ops[1].output);
        // weight provenance points every band at the original op
        assert_eq!(r.graph.ops[0].weight_seed, Some(0));
        assert_eq!(r.graph.ops[2].weight_seed, Some(0));
        assert_eq!(r.graph.ops[1].weight_seed, Some(1));
        // … and flash stores each original weight tensor once
        assert_eq!(r.graph.weight_bytes(), g.weight_bytes());
    }

    #[test]
    fn banded_execution_is_bit_identical_to_unsplit() {
        for dtype in [DType::F32, DType::I8] {
            let g = pair_graph(dtype);
            let inputs: Vec<Vec<f32>> =
                g.inputs.iter().map(|&t| gen_input(&g, t, 7)).collect();
            let want = run_reference(&g, &inputs, 7).unwrap();
            for parts in [2usize, 3, 4, 7] {
                let r = split_pair(&g, OpId(0), OpId(1), parts).unwrap();
                let got = run_reference(&r.graph, &inputs, 7).unwrap();
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().flatten().zip(want.iter().flatten()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?} parts={parts}");
                }
            }
        }
    }

    #[test]
    fn chain_split_is_the_one_code_path_for_pairs() {
        // split_pair is a shim: the depth-2 chain must produce the
        // byte-identical graph (this is also what keeps v3 artifacts'
        // split fingerprints loading unchanged)
        let g = pair_graph(DType::F32);
        let via_pair = split_pair(&g, OpId(0), OpId(1), 3).unwrap();
        let via_chain = split_chain(&g, &[OpId(0), OpId(1)], 3).unwrap();
        assert_eq!(
            crate::planner::graph_fingerprint(&via_pair.graph),
            crate::planner::graph_fingerprint(&via_chain.graph)
        );
        assert_eq!(via_pair.provenance, via_chain.provenance);
    }

    #[test]
    fn chain_banded_execution_is_bit_identical_to_unsplit() {
        for dtype in [DType::F32, DType::I8] {
            let g = chain_graph(dtype);
            let ops = [OpId(0), OpId(1), OpId(2)];
            let inputs: Vec<Vec<f32>> =
                g.inputs.iter().map(|&t| gen_input(&g, t, 13)).collect();
            let want = run_reference(&g, &inputs, 13).unwrap();
            for parts in [2usize, 3, 4] {
                let r = split_chain(&g, &ops, parts).unwrap();
                // d bands per part + concat, original chain ops gone
                assert_eq!(r.graph.ops.len(), g.ops.len() - 3 + 3 * parts + 1);
                let got = run_reference(&r.graph, &inputs, 13).unwrap();
                for (a, b) in got.iter().flatten().zip(want.iter().flatten()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?} parts={parts}");
                }
            }
        }
    }

    #[test]
    fn chain_band_plan_halos_overlap_at_every_level() {
        let g = chain_graph(DType::F32);
        let plans = chain_band_plan(&g, &[OpId(0), OpId(1), OpId(2)], 4).unwrap();
        assert_eq!(plans.len(), 4);
        // final level is an exact partition
        assert_eq!(plans[0].rows[2].0, 0);
        assert_eq!(plans[3].rows[2].1, 8);
        let covered: usize = plans.iter().map(|p| p.rows[2].1 - p.rows[2].0).sum();
        assert_eq!(covered, 8);
        // intermediate levels overlap between adjacent parts (halo)
        for level in 0..2 {
            assert!(
                plans[1].rows[level].0 < plans[0].rows[level].1,
                "level {level} has no halo"
            );
        }
    }

    #[test]
    fn uneven_row_counts_partition_exactly() {
        // 15 output rows into 4 bands: 3 + 4 + 4 + 4
        let mut b = GraphBuilder::new("odd", DType::F32);
        let x = b.input(Shape::hwc(15, 8, 2));
        let c = b.conv2d(x, 4, (3, 3), (1, 1), Padding::Same, Activation::None);
        let d = b.maxpool(c, (3, 3), (1, 1), Padding::Same);
        let g = b.finish(&[d]);
        let plans = band_plan(&g, OpId(0), OpId(1), 4).unwrap();
        assert_eq!(plans.len(), 4);
        assert_eq!(plans[0].out0, 0);
        assert_eq!(plans.last().unwrap().out1, 15);
        let covered: usize = plans.iter().map(|p| p.out1 - p.out0).sum();
        assert_eq!(covered, 15);
        // halo: adjacent mid ranges overlap
        assert!(plans[1].mid0 < plans[0].mid1);
        let r = split_pair(&g, OpId(0), OpId(1), 4).unwrap();
        let inputs: Vec<Vec<f32>> = g.inputs.iter().map(|&t| gen_input(&g, t, 3)).collect();
        assert_eq!(
            run_reference(&g, &inputs, 3).unwrap(),
            run_reference(&r.graph, &inputs, 3).unwrap()
        );
    }

    #[test]
    fn ineligible_pairs_are_rejected() {
        // multi-consumer intermediate
        let mut b = GraphBuilder::new("fanout", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 2));
        let c = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let p = b.conv2d(c, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let q = b.add(c, p);
        let g = b.finish(&[q]);
        assert!(split_eligible(&g, OpId(0), OpId(1), 2).is_err());
        // non-chain (siblings)
        let mut b = GraphBuilder::new("sib", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 2));
        let a = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let c = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let s = b.add(a, c);
        let g = b.finish(&[s]);
        assert!(split_eligible(&g, OpId(0), OpId(1), 2).is_err());
        // more parts than output rows
        let g = pair_graph(DType::F32);
        assert!(split_eligible(&g, OpId(0), OpId(1), 64).is_err());
    }

    #[test]
    fn ineligible_chains_are_rejected() {
        let g = chain_graph(DType::F32);
        // non-consecutive ops are not a chain
        assert!(chain_eligible(&g, &[OpId(0), OpId(2)], 2).is_err());
        // depth 1 is not a chain
        assert!(chain_eligible(&g, &[OpId(0)], 2).is_err());
        // chain through a non-bandable op
        let mut b = GraphBuilder::new("nb", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 2));
        let c = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let r = b.reshape(c, Shape::new(&[1, 8 * 8 * 2]));
        let f = b.fully_connected(r, 4, Activation::None);
        let g2 = b.finish(&[f]);
        assert!(chain_eligible(&g2, &[OpId(0), OpId(1), OpId(2)], 2).is_err());
    }

    #[test]
    fn apply_composes_mixed_specs_deterministically() {
        let g = chain_graph(DType::F32);
        let specs = [RewriteSpec::ChainSplit {
            ops: vec![OpId(0), OpId(1), OpId(2)],
            parts: 2,
        }];
        let (a, prov_a) = apply(&g, &specs).unwrap();
        let (b, prov_b) = apply(&g, &specs).unwrap();
        assert_eq!(
            crate::planner::graph_fingerprint(&a),
            crate::planner::graph_fingerprint(&b)
        );
        assert_eq!(prov_a, prov_b);
        // every band op maps back to a base chain op
        for o in &prov_a.per_op {
            match *o {
                OpOrigin::Band { of, .. } => assert!(of.0 <= 2),
                OpOrigin::Assemble { of } => assert_eq!(of, OpId(2)),
                OpOrigin::Kept(_) => {}
            }
        }
    }

    #[test]
    fn apply_splits_round_trips_deterministically() {
        let g = pair_graph(DType::F32);
        let spec = SplitSpec {
            first: 0,
            second: 1,
            parts: 3,
        };
        let (a, prov_a) = apply_splits(&g, &[spec]).unwrap();
        let (b, prov_b) = apply_splits(&g, &[spec]).unwrap();
        assert_eq!(
            crate::planner::graph_fingerprint(&a),
            crate::planner::graph_fingerprint(&b)
        );
        assert_eq!(prov_a, prov_b);
        assert_eq!(a.ops.len(), g.ops.len() + 2 * 3 + 1 - 2);
        // … and the shim agrees with the generic entry point
        let (c, prov_c) = apply(&g, &[RewriteSpec::PairSplit(spec)]).unwrap();
        assert_eq!(
            crate::planner::graph_fingerprint(&a),
            crate::planner::graph_fingerprint(&c)
        );
        assert_eq!(prov_a, prov_c);
    }

    #[test]
    fn describe_names_pairs_and_chains() {
        let p = RewriteSpec::PairSplit(SplitSpec { first: 3, second: 4, parts: 4 });
        assert_eq!(p.describe(), "ops 3→4 banded ×4");
        assert_eq!(p.depth(), 2);
        let c = RewriteSpec::ChainSplit {
            ops: vec![OpId(1), OpId(2), OpId(3)],
            parts: 2,
        };
        assert_eq!(c.describe(), "chain 1→2→3 banded ×2");
        assert_eq!(c.depth(), 3);
        assert_eq!(c.op_indices(), vec![1, 2, 3]);
    }
}
