//! §IV deployment study: which models fit which micro-controllers, with
//! and without diagonal memory optimisation.
//!
//! Reproduces the paper's headline deployment claim — "it becomes
//! possible to execute the smallest MobileNet (v1 0.25 128 8bit) on
//! [the STM32F103xF]" only when DMO shrinks the arena below 96 KB SRAM —
//! and extends the check across a catalog of common MCUs.
//!
//! ```sh
//! cargo run --release --example mcu_fit
//! ```

use dmo::mcu::{catalog, fit};
use dmo::models;
use dmo::planner::PlannedModel;
use dmo::report::fmt_bytes;

/// SRAM the application keeps for stack/runtime besides the tensor arena.
const RUNTIME_HEADROOM: usize = 4 * 1024;

fn main() -> anyhow::Result<()> {
    let models_under_test = [
        "mobilenet_v1_0.25_128_int8",
        "mobilenet_v1_0.25_224",
        "mobilenet_v1_1.0_224_int8",
        "tiny_int8",
    ];

    println!(
        "{:28} {:>10} {:>10} {:>9}   {}",
        "model", "arena", "arena+DMO", "weights", "deployability per MCU"
    );
    println!("{}", "-".repeat(110));

    for name in models_under_test {
        let pm = PlannedModel::new(models::build(name)?)?;
        let row = pm.row();
        print!(
            "{:28} {:>10} {:>10} {:>9}   ",
            name,
            fmt_bytes(row.original),
            fmt_bytes(row.optimised),
            fmt_bytes(pm.graph.weight_bytes())
        );
        for m in catalog() {
            let f0 = fit(&pm.graph, &m, row.original + RUNTIME_HEADROOM);
            let f1 = fit(&pm.graph, &m, row.optimised + RUNTIME_HEADROOM);
            let mark = match (f0.deployable(), f1.deployable()) {
                (true, true) => "✓",       // fits regardless
                (false, true) => "D",      // deployable ONLY with DMO
                (false, false) => "·",     // doesn't fit
                (true, false) => "?",      // cannot happen (DMO ≤ original)
            };
            print!("{mark} ");
        }
        println!();
    }

    println!("\nlegend: ✓ fits without DMO   D fits ONLY with DMO   · does not fit");
    println!("columns:");
    for m in catalog() {
        println!(
            "  {:20} {:>9} flash / {:>8} SRAM ({})",
            m.name,
            fmt_bytes(m.flash_bytes),
            fmt_bytes(m.sram_bytes),
            m.core
        );
    }

    // the paper's specific claim, asserted
    let pm = PlannedModel::new(models::build("mobilenet_v1_0.25_128_int8")?)?;
    let g = &pm.graph;
    let row = pm.row();
    let stm = &catalog()[0];
    let without = fit(g, stm, row.original + RUNTIME_HEADROOM).deployable();
    let with = fit(g, stm, row.optimised + RUNTIME_HEADROOM).deployable();
    println!(
        "\nSTM32F103xF + MobileNet v1 0.25 128 (8-bit): without DMO {} | with DMO {}",
        if without { "deploys" } else { "DOES NOT deploy" },
        if with { "deploys ✓" } else { "does not deploy" },
    );
    println!(
        "weights occupy {:.1}% of its flash (paper: 60.8%)",
        100.0 * g.weight_bytes() as f64 / stm.flash_bytes as f64
    );
    assert!(!without && with, "the paper's deployment flip must reproduce");
    Ok(())
}
