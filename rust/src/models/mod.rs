//! Model zoo: the eleven networks of Table III plus the small serving
//! model used by the end-to-end stack.
//!
//! DMO depends only on op types, shapes, dtypes and topology, so the
//! builders construct the published architectures with their exact layer
//! shapes (weights are irrelevant to planning and generated
//! deterministically when execution is needed). Activations are fused
//! into their producing ops, as TFLite does — standalone activations
//! would introduce intermediate tensors the deployed models don't have.

pub mod densenet;
pub mod inception_resnet_v2;
pub mod inception_v4;
pub mod mobilenet_v1;
pub mod mobilenet_v2;
pub mod nasnet;
pub mod resnet;
pub mod tiny;

use crate::ir::graph::Graph;
use crate::ir::DType;

/// Keras/TF channel rounding: round to the nearest multiple of `divisor`
/// (≥ `divisor`), never dropping below 90 % of the requested value.
pub fn make_divisible(v: f64, divisor: usize) -> usize {
    let d = divisor as f64;
    let mut new_v = ((v + d / 2.0) / d).floor() * d;
    if new_v < d {
        new_v = d;
    }
    if new_v < 0.9 * v {
        new_v += d;
    }
    new_v as usize
}

/// The Table III catalog, in the paper's row order.
pub fn table3_names() -> Vec<&'static str> {
    vec![
        "mobilenet_v1_1.0_224",
        "mobilenet_v1_1.0_224_int8",
        "mobilenet_v1_0.25_224",
        "mobilenet_v1_0.25_128_int8",
        "mobilenet_v2_0.35_224",
        "mobilenet_v2_1.0_224",
        "inception_v4",
        "inception_resnet_v2",
        "nasnet_mobile",
        "densenet_121",
        "resnet_50_v2",
    ]
}

/// Build a catalog model by name.
pub fn build(name: &str) -> anyhow::Result<Graph> {
    Ok(match name {
        "mobilenet_v1_1.0_224" => mobilenet_v1::build(1.0, 224, DType::F32),
        "mobilenet_v1_1.0_224_int8" => mobilenet_v1::build(1.0, 224, DType::I8),
        "mobilenet_v1_0.25_224" => mobilenet_v1::build(0.25, 224, DType::F32),
        "mobilenet_v1_0.25_128" => mobilenet_v1::build(0.25, 128, DType::F32),
        "mobilenet_v1_0.25_128_int8" => mobilenet_v1::build(0.25, 128, DType::I8),
        "mobilenet_v2_0.35_224" => mobilenet_v2::build(0.35, 224, DType::F32),
        "mobilenet_v2_1.0_224" => mobilenet_v2::build(1.0, 224, DType::F32),
        "inception_v4" => inception_v4::build(DType::F32),
        "inception_resnet_v2" => inception_resnet_v2::build(DType::F32),
        "nasnet_mobile" => nasnet::build(DType::F32),
        "densenet_121" => densenet::build(DType::F32),
        "resnet_50_v2" => resnet::build_50_v2(DType::F32),
        "tiny" => tiny::build(DType::F32),
        "tiny_int8" => tiny::build(DType::I8),
        "tiny_wide" => tiny::build_wide(DType::F32),
        "hourglass" => tiny::build_hourglass(DType::I8),
        other => anyhow::bail!("unknown model `{other}` (see `dmo models`)"),
    })
}

/// All buildable names (catalog + extras).
pub fn all_names() -> Vec<&'static str> {
    let mut v = table3_names();
    v.extend(["mobilenet_v1_0.25_128", "tiny", "tiny_int8", "tiny_wide", "hourglass"]);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn make_divisible_matches_keras() {
        // reference values from keras_applications.mobilenet_v2
        assert_eq!(make_divisible(32.0 * 0.35, 8), 16); // 11.2 -> 16 (0.9 rule)
        assert_eq!(make_divisible(16.0 * 0.35, 8), 8); // 5.6 -> 8
        assert_eq!(make_divisible(24.0 * 0.35, 8), 8); // 8.4 -> 8
        assert_eq!(make_divisible(32.0 * 0.25, 8), 8);
        assert_eq!(make_divisible(64.0 * 0.25, 8), 16);
        assert_eq!(make_divisible(1024.0 * 0.25, 8), 256);
        assert_eq!(make_divisible(96.0, 8), 96);
    }

    #[test]
    fn every_catalog_model_builds_and_validates() {
        for name in all_names() {
            let g = build(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!g.ops.is_empty(), "{name} empty");
        }
    }
}
