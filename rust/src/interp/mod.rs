//! Arena interpreter: execute a planned graph *in its planned layout*,
//! overlapped buffers and all.
//!
//! This is the proof-of-safety layer: a DMO plan claims that clobbering
//! an op's input while writing its output never destroys a value that is
//! still needed. [`validate_plan`] executes the model twice — once with
//! every buffer disjoint (reference) and once inside the planned arena —
//! and demands bit-identical outputs. TFMin performed the same check by
//! generating C with fixed pre-allocated offsets (§I); here it is a
//! library call used by the test suite on every model.

use crate::ir::graph::{Graph, TensorId};
use crate::obs::trace as otrace;
use crate::obs::watermark::{ExecProfile, OpProfile, WatermarkSink};
pub use crate::obs::watermark::WatermarkViolation;
use crate::ops::exec::{execute_op, gen_weights, Arena, OpIo, Region};
use crate::planner::{Plan, PlanArtifact};
use crate::util::json;
use anyhow::{ensure, Context, Result};

/// Deterministic synthetic input for a tensor.
pub fn gen_input(graph: &Graph, t: TensorId, seed: u64) -> Vec<f32> {
    let info = graph.tensor(t);
    let mut rng = crate::util::rng::Rng::new(seed ^ ((t.0 as u64) << 32) ^ 0x1A9F_0007);
    (0..info.shape.num_elements())
        .map(|_| (rng.range(0, 8) as f32) - 4.0)
        .collect()
}

/// Execute `graph` in `plan`'s layout on `plan.order`. Returns the model
/// outputs (as f32, whatever the dtype).
///
/// `graph` is the graph the caller planned — when the plan carries a
/// §II-A split rewrite, the banded graph the order/offsets actually
/// refer to is resolved via [`Plan::graph_for`]. The rewrite preserves
/// input/output tensor ids, so callers feed and read the same tensors
/// either way.
pub fn run_plan(graph: &Graph, plan: &Plan, inputs: &[Vec<f32>], seed: u64) -> Result<Vec<Vec<f32>>> {
    let graph = plan.graph_for(graph);
    let regions: Vec<Option<Region>> = (0..graph.tensors.len())
        .map(|t| {
            plan.alloc.offsets[t]
                .map(|off| Region::new(off, graph.tensor(TensorId(t)).size_bytes()))
        })
        .collect();
    run_with_regions(graph, &plan.order.0, &regions, plan.peak(), inputs, seed)
}

/// Execute `graph` in `plan`'s layout like [`run_plan`], but with the
/// arena's event sink feeding an [`crate::obs::watermark::WatermarkSink`]:
/// every traced load/store/update updates the observed high-water mark and
/// touched-byte bitmap, per op and run-wide. Per-op wall time and byte
/// traffic are recorded as tracing spans (when [`crate::obs::trace`] is
/// enabled) and returned in the [`ExecProfile`] — the in-process analogue
/// of the paper's Valgrind observation, letting callers *assert*
/// `observed_peak ≤ plan.peak()` instead of trusting it.
pub fn run_plan_profiled(
    model: &str,
    graph: &Graph,
    plan: &Plan,
    inputs: &[Vec<f32>],
    seed: u64,
) -> Result<(Vec<Vec<f32>>, ExecProfile)> {
    let graph = plan.graph_for(graph);
    let regions: Vec<Option<Region>> = (0..graph.tensors.len())
        .map(|t| {
            plan.alloc.offsets[t]
                .map(|off| Region::new(off, graph.tensor(TensorId(t)).size_bytes()))
        })
        .collect();
    let arena_size = plan.peak();
    ensure!(inputs.len() == graph.inputs.len(), "wrong input count");
    let mut arena = Arena::new(arena_size);
    for (&t, data) in graph.inputs.iter().zip(inputs) {
        let info = graph.tensor(t);
        ensure!(
            data.len() == info.shape.num_elements(),
            "input {} wrong length",
            info.name
        );
        let r = regions[t.0].context("input tensor unplaced")?;
        arena.write_tensor(info.dtype, r, data);
    }
    let sink = WatermarkSink::new(arena_size);
    arena.set_sink(Some(Box::new(sink.clone())));
    let mut run_span = otrace::span(&format!("run:{model}"), "interp");
    if run_span.is_active() {
        run_span.arg("planned_peak", json::num(arena_size));
        run_span.arg("ops", json::num(plan.order.0.len()));
    }
    let mut op_profiles = Vec::with_capacity(plan.order.0.len());
    for (step, &opid) in plan.order.0.iter().enumerate() {
        let op = graph.op(opid);
        let in_shapes: Vec<&crate::ir::Shape> =
            op.inputs.iter().map(|&t| &graph.tensor(t).shape).collect();
        let in_regions: Vec<Region> = op
            .inputs
            .iter()
            .map(|&t| regions[t.0].context("op input unplaced"))
            .collect::<Result<_>>()?;
        let out_region = regions[op.output.0].context("op output unplaced")?;
        let weights = gen_weights(op, seed ^ op.weight_key(opid.0) as u64);
        let io = OpIo {
            in_shapes: &in_shapes,
            in_regions: &in_regions,
            out_shape: &graph.tensor(op.output).shape,
            out_region,
            dtype: graph.tensor(op.output).dtype,
            weights: &weights,
        };
        crate::util::sync::lock(&sink.0).begin_op();
        let mut sp = otrace::span(&format!("exec:{}", op.name), "interp");
        let t0 = std::time::Instant::now();
        execute_op(&op.kind, &io, &mut arena)
            .with_context(|| format!("executing {}", op.name))?;
        let wall_us = t0.elapsed().as_micros() as u64;
        let (bytes_read, bytes_written, high_water) = {
            let st = crate::util::sync::lock(&sink.0);
            (st.op_bytes_read, st.op_bytes_written, st.op_high_water)
        };
        if sp.is_active() {
            sp.arg("op", json::num(opid.0));
            sp.arg("bytes_read", json::num(bytes_read as usize));
            sp.arg("bytes_written", json::num(bytes_written as usize));
            sp.arg("high_water", json::num(high_water));
            sp.arg("planned_extent", json::num(out_region.end()));
        }
        drop(sp);
        op_profiles.push(OpProfile {
            step,
            op: opid.0,
            name: op.name.clone(),
            wall_us,
            bytes_read,
            bytes_written,
            high_water,
            planned_extent: out_region.end(),
        });
    }
    drop(run_span);
    arena.set_sink(None);
    let outputs: Vec<Vec<f32>> = graph
        .outputs
        .iter()
        .map(|&t| {
            let info = graph.tensor(t);
            arena.read_tensor(info.dtype, regions[t.0].unwrap(), info.shape.num_elements())
        })
        .collect();
    let st = crate::util::sync::lock(&sink.0);
    let profile = ExecProfile {
        model: model.to_string(),
        planned_peak: plan.peak(),
        observed_peak: st.high_water,
        touched_bytes: st.touched_bytes(),
        arena_bytes: arena_size,
        ops: op_profiles,
    };
    Ok((outputs, profile))
}

/// Execute with every live tensor in its own disjoint buffer (reference).
pub fn run_reference(graph: &Graph, inputs: &[Vec<f32>], seed: u64) -> Result<Vec<Vec<f32>>> {
    let order: Vec<crate::ir::graph::OpId> =
        crate::planner::serialise(graph, crate::planner::Strategy::Eager).0;
    let mut base = 0usize;
    let regions: Vec<Option<Region>> = (0..graph.tensors.len())
        .map(|t| {
            let r = Region::new(base, graph.tensor(TensorId(t)).size_bytes());
            base += r.len;
            Some(r)
        })
        .collect();
    run_with_regions(graph, &order, &regions, base, inputs, seed)
}

fn run_with_regions(
    graph: &Graph,
    order: &[crate::ir::graph::OpId],
    regions: &[Option<Region>],
    arena_size: usize,
    inputs: &[Vec<f32>],
    seed: u64,
) -> Result<Vec<Vec<f32>>> {
    ensure!(inputs.len() == graph.inputs.len(), "wrong input count");
    let mut arena = Arena::new(arena_size);
    for (&t, data) in graph.inputs.iter().zip(inputs) {
        let info = graph.tensor(t);
        ensure!(
            data.len() == info.shape.num_elements(),
            "input {} wrong length",
            info.name
        );
        let r = regions[t.0].context("input tensor unplaced")?;
        arena.write_tensor(info.dtype, r, data);
    }
    for &opid in order {
        let op = graph.op(opid);
        let in_shapes: Vec<&crate::ir::Shape> =
            op.inputs.iter().map(|&t| &graph.tensor(t).shape).collect();
        let in_regions: Vec<Region> = op
            .inputs
            .iter()
            .map(|&t| regions[t.0].context("op input unplaced"))
            .collect::<Result<_>>()?;
        let out_region = regions[op.output.0].context("op output unplaced")?;
        // seed by weight provenance: the bands of a split op draw the
        // same stream the original (unsplit) op would
        let weights = gen_weights(op, seed ^ op.weight_key(opid.0) as u64);
        let io = OpIo {
            in_shapes: &in_shapes,
            in_regions: &in_regions,
            out_shape: &graph.tensor(op.output).shape,
            out_region,
            dtype: graph.tensor(op.output).dtype,
            weights: &weights,
        };
        execute_op(&op.kind, &io, &mut arena)
            .with_context(|| format!("executing {}", op.name))?;
    }
    Ok(graph
        .outputs
        .iter()
        .map(|&t| {
            let info = graph.tensor(t);
            arena.read_tensor(info.dtype, regions[t.0].unwrap(), info.shape.num_elements())
        })
        .collect())
}

/// Reference outputs on the deterministic synthetic inputs for `seed` —
/// what [`gen_input`] would feed [`run_reference`]. The ground truth the
/// C-codegen differential harness compares emitted binaries against.
pub fn reference_outputs(graph: &Graph, seed: u64) -> Result<Vec<Vec<f32>>> {
    let inputs: Vec<Vec<f32>> = graph
        .inputs
        .iter()
        .map(|&t| gen_input(graph, t, seed))
        .collect();
    run_reference(graph, &inputs, seed)
}

/// Execute `graph` under `plan` and under the disjoint reference layout
/// with identical inputs/weights; fail unless outputs are bit-identical.
/// Returns the (verified) planned-layout outputs.
///
/// For §II-A split plans this is the correctness anchor across the
/// rewrite boundary: the planned run executes the *banded* graph in its
/// overlapping arena, while the reference executes the *unsplit* graph
/// in disjoint buffers — halo recomputation, weight provenance and
/// reassembly all have to line up exactly for the bits to match.
fn execute_and_prove(graph: &Graph, plan: &Plan, seed: u64) -> Result<Vec<Vec<f32>>> {
    let inputs: Vec<Vec<f32>> = graph
        .inputs
        .iter()
        .map(|&t| gen_input(graph, t, seed))
        .collect();
    let got = run_plan(graph, plan, &inputs, seed)?;
    let want = run_reference(graph, &inputs, seed)?;
    ensure!(got.len() == want.len());
    for (o, (g, w)) in got.iter().zip(&want).enumerate() {
        ensure!(g.len() == w.len(), "output {o} length mismatch");
        for (i, (a, b)) in g.iter().zip(w).enumerate() {
            ensure!(
                a.to_bits() == b.to_bits(),
                "output {o}[{i}]: planned {a} != reference {b} — overlap clobbered a live value"
            );
        }
    }
    Ok(got)
}

/// Execute `graph` under `plan` and under the disjoint reference layout
/// with identical inputs/weights; fail unless outputs are bit-identical.
pub fn validate_plan(graph: &Graph, plan: &Plan, seed: u64) -> Result<()> {
    execute_and_prove(graph, plan, seed).map(|_| ())
}

/// Reconstruct a loaded [`PlanArtifact`] against `graph`, *prove* the
/// layout safe by executing it bit-exactly against disjoint reference
/// buffers, and return the model outputs — the deploy-time entry point
/// for plans computed in another process.
pub fn run_planned_artifact(
    graph: &Graph,
    artifact: &PlanArtifact,
    seed: u64,
) -> Result<Vec<Vec<f32>>> {
    let plan = artifact
        .to_plan(graph)
        .context("revalidating plan artifact")?;
    execute_and_prove(graph, &plan, seed).context("executing loaded plan artifact")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;
    use crate::models;
    use crate::planner::Planner;

    #[test]
    fn tiny_model_dmo_plan_is_safe_f32() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        assert!(!plan.alloc.applied.is_empty(), "expect overlaps on tiny");
        validate_plan(&g, &plan, 42).unwrap();
    }

    #[test]
    fn tiny_model_dmo_plan_is_safe_i8() {
        let g = models::tiny::build(DType::I8);
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        validate_plan(&g, &plan, 7).unwrap();
    }

    #[test]
    fn baseline_plan_is_safe() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).plan().unwrap();
        validate_plan(&g, &plan, 3).unwrap();
    }

    #[test]
    fn corrupted_plan_is_caught() {
        // force an illegal overlap: shift a mid-graph tensor onto a live one
        let g = models::build("tiny").unwrap();
        let mut plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        // tensor 1 = conv1 out; slam it onto tensor 2's offset
        let o2 = plan.alloc.offsets[2];
        plan.alloc.offsets[1] = o2;
        let r = validate_plan(&g, &plan, 42);
        assert!(r.is_err(), "clobbering layout must be detected");
    }

    #[test]
    fn profiled_run_matches_and_stays_within_plan() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let inputs: Vec<Vec<f32>> = g.inputs.iter().map(|&t| gen_input(&g, t, 42)).collect();
        let want = run_plan(&g, &plan, &inputs, 42).unwrap();
        let (got, prof) = run_plan_profiled("tiny", &g, &plan, &inputs, 42).unwrap();
        assert_eq!(got, want, "profiling must not change results");
        assert!(
            prof.within_plan(),
            "observed {} exceeds planned {}",
            prof.observed_peak,
            prof.planned_peak
        );
        assert!(prof.observed_peak > 0, "the run must touch the arena");
        assert_eq!(prof.ops.len(), plan.order.0.len());
        assert!(prof.touched_bytes <= prof.arena_bytes);
    }

    #[test]
    fn artifact_executes_and_proves_safe() {
        let g = models::build("tiny").unwrap();
        let plan = Planner::for_graph(&g).dmo(true).plan().unwrap();
        let art = PlanArtifact::from_plan(&g, &plan);
        let out = run_planned_artifact(&g, &art, 42).unwrap();
        let want = run_reference(
            &g,
            &g.inputs
                .iter()
                .map(|&t| gen_input(&g, t, 42))
                .collect::<Vec<_>>(),
            42,
        )
        .unwrap();
        assert_eq!(out, want);
    }
}
