//! MobileNet v2 (Sandler et al. 2018) — inverted residual bottlenecks.
//! Two Table III rows (0.35/224 and 1.0/224, both 20 % savings: the peak
//! op is the Table-I depthwise conv whose `O_s` equals its output size).

use super::make_divisible;
use crate::ir::graph::{Graph, TensorId};
use crate::ir::op::{Activation, Padding};
use crate::ir::{DType, GraphBuilder, Shape};

/// (expansion t, channels c, repeats n, first stride s) per stage.
const STAGES: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

fn bottleneck(
    b: &mut GraphBuilder,
    x: TensorId,
    in_c: usize,
    out_c: usize,
    t: usize,
    stride: usize,
    g: &mut usize,
) -> TensorId {
    *g += 1;
    let mut h = x;
    // expansion 1x1 (skipped when t == 1, as in the published model)
    if t != 1 {
        h = b.conv2d(h, in_c * t, (1, 1), (1, 1), Padding::Same, Activation::Relu6);
    }
    // depthwise 3x3
    h = b.dwconv2d(h, (3, 3), (stride, stride), Padding::Same, Activation::Relu6);
    // linear projection
    h = b.conv2d(h, out_c, (1, 1), (1, 1), Padding::Same, Activation::None);
    // residual only when shapes match
    if stride == 1 && in_c == out_c {
        h = b.add(x, h);
    }
    h
}

/// Build MobileNet v2 with width multiplier `alpha` at `res`×`res`.
pub fn build(alpha: f64, res: usize, dtype: DType) -> Graph {
    let name = format!("mobilenet_v2_{alpha:.2}_{res}");
    let mut b = GraphBuilder::new(&name, dtype);
    let x = b.input(Shape::hwc(res, res, 3));
    let c0 = make_divisible(32.0 * alpha, 8);
    let mut h = b.conv2d(x, c0, (3, 3), (2, 2), Padding::Same, Activation::Relu6);
    let mut in_c = c0;
    let mut gidx = 0usize;
    for (t, c, n, s) in STAGES {
        let out_c = make_divisible(c as f64 * alpha, 8);
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            h = bottleneck(&mut b, h, in_c, out_c, t, stride, &mut gidx);
            in_c = out_c;
        }
    }
    // final 1x1 conv: 1280 channels, scaled only when alpha > 1
    let last = if alpha > 1.0 {
        make_divisible(1280.0 * alpha, 8)
    } else {
        1280
    };
    h = b.conv2d(h, last, (1, 1), (1, 1), Padding::Same, Activation::Relu6);
    h = b.global_avg_pool(h);
    let h = b.reshape(h, Shape::new(&[1, last]));
    let h = b.fully_connected(h, 1000, Activation::None);
    let out = b.softmax(h);
    b.finish(&[out])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_alpha_224_peak_pair_is_table1_op() {
        let g = build(1.0, 224, DType::F32);
        // find the dw conv with input 112x112x96 (Table I)
        let found = g.ops.iter().any(|op| {
            matches!(op.kind, crate::ir::op::OpKind::DepthwiseConv2D(ref p) if p.stride == (2,2))
                && g.tensor(op.inputs[0]).shape == Shape::hwc(112, 112, 96)
                && g.tensor(op.output).shape == Shape::hwc(56, 56, 96)
        });
        assert!(found, "Table I op (112,112,96)->(56,56,96) s2 must exist");
    }

    #[test]
    fn alpha_035_channels() {
        let g = build(0.35, 224, DType::F32);
        // conv1 -> 16 channels (0.9 rule), stage1 -> 8, stage2 -> 8
        assert_eq!(g.tensor(g.ops[0].output).shape.c(), 16);
        // first bottleneck (t=1): dw on 16, project to 8
        assert_eq!(g.tensor(g.ops[1].output).shape.c(), 16);
        assert_eq!(g.tensor(g.ops[2].output).shape.c(), 8);
        // stage-2 first expand: 8 * 6 = 48 channels at 112x112
        assert_eq!(g.tensor(g.ops[3].output).shape, Shape::hwc(112, 112, 48));
    }

    #[test]
    fn residuals_present() {
        let g = build(1.0, 224, DType::F32);
        let adds = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, crate::ir::op::OpKind::Binary(_)))
            .count();
        // stages with n>1 contribute n-1 residuals: 1+2+3+2+2 = 10
        assert_eq!(adds, 10);
    }
}
