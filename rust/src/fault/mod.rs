//! Deterministic fault injection for the serving fleet.
//!
//! DMO deliberately aliases input and output buffers in one arena, so a
//! single out-of-spec store, corrupted artifact, or buggy rewrite silently
//! clobbers live data. This module makes every such failure *injectable on
//! purpose*, seeded and reproducible, so the chaos suite
//! (`rust/tests/chaos.rs`) can prove the fleet sheds, quarantines,
//! degrades, or recovers without ever losing accounting:
//! `completed + shed + failed == requests`.
//!
//! A [`FaultSpec`] is the user-facing grammar (`panic:2@0,corrupt-reload:1`)
//! parsed from `dmo serve --faults=SPEC`; a [`FaultPlan`] resolves it
//! against a seed into concrete trigger points — contiguous windows over a
//! model's per-model *dispatch sequence*, which is assigned under the
//! admission lock and therefore identical across runs with the same seed
//! and workload. Contiguity is deliberate: K consecutive injected failures
//! are exactly what a K-threshold circuit breaker must observe to open.

mod plan;
mod spec;

pub use plan::{ArenaCorrupt, ExecFaults, FaultPlan, GarbleMode, ReloadFault, StallWindow};
pub use spec::{FaultClause, FaultKind, FaultSpec};
