//! Operation splitting analysis (§II-A).
//!
//! A pair of chained window ops whose intermediate tensor dominates peak
//! memory can be split into `k` vertical slices executed sequentially:
//! each slice computes a horizontal band of the final output through a
//! band of the intermediate tensor, so only `≈ 1/k` of the intermediate
//! values are live at once — at the price of recomputing the band-overlap
//! rows of the intermediate tensor (receptive-field halo).
//!
//! The paper demonstrates this manually on MobileNet v1 (§II-A: 96 KB →
//! 66 KB with 6144 elements computed twice) and calls for automatic
//! analysis as future work; [`analyse_pair`] is that analysis, and the
//! planner exposes it as a report (it cannot be combined with DMO — the
//! longer scopes of the split tensors defeat overlapping, as §II-A notes).

use crate::ir::graph::{Graph, OpId};
use crate::ir::op::OpKind;

/// Result of splitting a two-op chain into `parts` slices.
#[derive(Debug, Clone, PartialEq)]
pub struct SplitReport {
    pub first: OpId,
    pub second: OpId,
    pub parts: usize,
    /// Peak bytes for the fused pair without splitting
    /// (input + intermediate, intermediate + output, whichever is larger).
    pub peak_before: usize,
    /// Peak bytes with splitting: input + largest intermediate band +
    /// output (all live together, §II-A).
    pub peak_after: usize,
    /// Intermediate elements computed more than once (halo rows × parts-1).
    pub recomputed_elems: usize,
}

impl SplitReport {
    pub fn saving_pct(&self) -> f64 {
        if self.peak_before == 0 {
            return 0.0;
        }
        100.0 * (self.peak_before.saturating_sub(self.peak_after)) as f64 / self.peak_before as f64
    }
}

/// Kernel/stride extents of a window op along H, or `None` if the op is
/// not splittable this way.
fn window_h(kind: &OpKind) -> Option<(usize, usize, usize)> {
    // (kernel_h, stride_h, dilation_h)
    match kind {
        OpKind::Conv2D(p) => Some((p.kernel.0, p.stride.0, p.dilation.0)),
        OpKind::DepthwiseConv2D(p) => Some((p.kernel.0, p.stride.0, p.dilation.0)),
        OpKind::Pool(p) => Some((p.kernel.0, p.stride.0, 1)),
        OpKind::Unary(_) | OpKind::Reshape { .. } => Some((1, 1, 1)),
        _ => None,
    }
}

/// Analyse splitting the chain `first → second` (second consumes first's
/// output) into `parts` horizontal bands.
pub fn analyse_pair(graph: &Graph, first: OpId, second: OpId, parts: usize) -> anyhow::Result<SplitReport> {
    let f = graph.op(first);
    let s = graph.op(second);
    anyhow::ensure!(parts >= 2, "parts must be >= 2");
    anyhow::ensure!(
        s.inputs.contains(&f.output),
        "second op must consume first op's output"
    );
    let (k2, s2, d2) = window_h(&s.kind)
        .ok_or_else(|| anyhow::anyhow!("second op `{}` not splittable", s.name))?;
    window_h(&f.kind).ok_or_else(|| anyhow::anyhow!("first op `{}` not splittable", f.name))?;

    let input = graph.tensor(f.inputs[0]);
    let mid = graph.tensor(f.output);
    let out = graph.tensor(s.output);
    anyhow::ensure!(mid.shape.rank() == 4 && out.shape.rank() == 4, "need NHWC chain");

    let peak_before = (input.size_bytes() + mid.size_bytes()).max(mid.size_bytes() + out.size_bytes());

    // band of output rows per slice
    let oh = out.shape.h();
    let band_out = oh.div_ceil(parts);
    // intermediate rows needed for band_out output rows of the second op:
    // (band_out − 1)·stride + effective kernel
    let eff_k2 = (k2 - 1) * d2 + 1;
    let band_mid = ((band_out - 1) * s2 + eff_k2).min(mid.shape.h());
    let mid_row_bytes = mid.shape.w() * mid.shape.c() * mid.dtype.size_bytes();
    let band_mid_bytes = band_mid * mid_row_bytes;

    // §II-A: with splitting, input + current intermediate band + output
    // are all live at once (input and output now span all slices).
    let peak_after = input.size_bytes() + band_mid_bytes + out.size_bytes();

    // halo rows recomputed: each interior band boundary recomputes
    // (band_mid − stride·band_out) rows of the intermediate tensor
    let step_mid = s2 * band_out;
    let halo_rows = band_mid.saturating_sub(step_mid);
    let recomputed_elems = halo_rows * mid.shape.w() * mid.shape.c() * (parts - 1);

    Ok(SplitReport {
        first,
        second,
        parts,
        peak_before,
        peak_after,
        recomputed_elems,
    })
}

/// Scan a graph for its most profitable 2-op split (exhaustive over
/// adjacent window-op pairs and 2..=max_parts).
pub fn best_split(graph: &Graph, max_parts: usize) -> Option<SplitReport> {
    let mut best: Option<SplitReport> = None;
    for (i, f) in graph.ops.iter().enumerate() {
        for c in graph.consumers(f.output) {
            for parts in 2..=max_parts {
                if let Ok(r) = analyse_pair(graph, OpId(i), c, parts) {
                    if r.peak_after < r.peak_before
                        && best.as_ref().map_or(true, |b| r.peak_after < b.peak_after)
                    {
                        best = Some(r);
                    }
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Padding};
    use crate::ir::{DType, GraphBuilder, Shape};

    /// §II-A's MobileNet v1 0.25 128 (8-bit) case: conv2d (32 KB out…
    /// wait — the *pair* is the 2nd conv (1x1 → 64 KB mid) feeding the
    /// next dwconv (→16 KB out); splitting 4 ways shrinks 96 KB to ~66 KB
    /// with 6144 recomputed elements.
    #[test]
    fn paper_mobilenet_split_case() {
        let mut b = GraphBuilder::new("split", DType::I8);
        let x = b.input(Shape::hwc(64, 64, 8)); // 32 KB
        let c = b.conv2d(x, 16, (1, 1), (1, 1), Padding::Same, Activation::None); // 64 KB mid
        let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None); // 16 KB out
        let g = b.finish(&[d]);
        let r = analyse_pair(&g, OpId(0), OpId(1), 4).unwrap();
        assert_eq!(r.peak_before, 96 * 1024);
        // band: 8 output rows -> (8-1)*2+3 = 17 mid rows = 17 KB band
        // peak_after = 32 + 17 + 16 = 65 KB ≈ paper's 66 KB
        assert_eq!(r.peak_after, (32 + 17 + 16) * 1024);
        assert!(r.saving_pct() > 30.0);
        // halo: 17 − 16 = 1 row × 64·16 elems × 3 boundaries = 3072;
        // the paper's 6144 counts a 2-row halo (VALID alignment differs)
        assert!(r.recomputed_elems > 0);
    }

    #[test]
    fn best_split_finds_something() {
        let mut b = GraphBuilder::new("bs", DType::F32);
        let x = b.input(Shape::hwc(32, 32, 4));
        let c = b.conv2d(x, 16, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let d = b.maxpool(c, (2, 2), (2, 2), Padding::Valid);
        let g = b.finish(&[d]);
        let r = best_split(&g, 8).unwrap();
        assert!(r.peak_after < r.peak_before);
    }

    #[test]
    fn rejects_non_chain() {
        let mut b = GraphBuilder::new("nc", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 2));
        let c = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let d = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let s = b.add(c, d);
        let g = b.finish(&[s]);
        // ops 0 and 1 are siblings, not a chain
        assert!(analyse_pair(&g, OpId(0), OpId(1), 2).is_err());
    }
}
