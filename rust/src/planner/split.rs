//! Operation-splitting analysis (§II-A, generalised) — the planning
//! side of [`crate::ir::rewrite::apply`].
//!
//! A chain of window ops whose intermediate tensors dominate peak
//! memory can be split into `k` horizontal bands executed sequentially:
//! each band computes a slice of the final output through slices of
//! every intermediate level, so only `≈ 1/k` of each intermediate is
//! live at once — at the price of recomputing the receptive-field halo
//! rows adjacent bands share at every level, plus one copy of the
//! output during reassembly.
//!
//! The paper demonstrates the depth-2 case manually on MobileNet v1
//! (§II-A: 96 KB → 66 KB with 6144 elements computed twice) and calls
//! for automatic application as future work; Pex (arXiv 2211.17246)
//! bands whole subgraphs end-to-end, amortising the halo across the
//! chain. Here the analysis and the transform share one geometry
//! ([`crate::ir::rewrite::chain_band_plan`]): [`analyse_chain`]
//! predicts the banded schedule's live-set watermark — exact for pairs,
//! where it is what the allocator measures on the materialised rewrite
//! (asserted zoo-wide by `rust/tests/split_rewrite.rs`) — and
//! [`proposals`] turns a [`super::RewriteBudget`] into the ranked spec
//! sequences [`super::Planner::rewrites`] sweeps as variants: single
//! pair splits, multiple *independent* pair splits composed in one
//! plan, and depth-≥3 chains banded end-to-end.
//!
//! Note the §II-A caveat is *modelled*, not assumed away: the split
//! tensors' longer scopes (the chain's input spans every band) suppress
//! DMO overlap on the banded region, which the planner sees through the
//! ordinary scope analysis of the rewritten graph. The same effect is
//! why chains do **not** always beat pairs: the chain input stays live
//! across all `k·d` band steps, so a fat chain input (mnv1's 32 KB
//! head) can cost more than the pair's shorter scopes save — the
//! planner decides per graph on allocator-scored terms.

use super::RewriteBudget;
use crate::ir::graph::{Graph, OpId};
use crate::ir::rewrite::{self, RewriteSpec, SplitSpec};
use crate::ir::GraphBuilder;

/// Result of splitting a two-op chain into `parts` bands — the pair
/// view of [`ChainReport`], kept as a named struct because the pair is
/// the paper's §II-A unit and the report/CLI tables are built on it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SplitReport {
    pub first: OpId,
    pub second: OpId,
    pub parts: usize,
    /// Peak bytes for the fused pair without splitting
    /// (input + intermediate, intermediate + output, whichever is larger).
    pub peak_before: usize,
    /// Exact live-set watermark of the banded schedule (§II-A): the max
    /// over every band step of input + current intermediate band +
    /// already-materialised output bands, and the reassembly step's
    /// 2×output. This is what the baseline allocator measures on the
    /// rewritten pair.
    pub peak_after: usize,
    /// Intermediate elements computed more than once (halo rows shared
    /// by adjacent bands).
    pub recomputed_elems: usize,
    /// Output elements copied once by the concat-rows reassembly.
    pub assembled_elems: usize,
}

impl SplitReport {
    pub fn saving_pct(&self) -> f64 {
        if self.peak_before == 0 {
            return 0.0;
        }
        100.0 * (self.peak_before.saturating_sub(self.peak_after)) as f64 / self.peak_before as f64
    }

    /// The spec that materialises this report via
    /// [`crate::ir::rewrite::apply`].
    pub fn spec(&self) -> SplitSpec {
        SplitSpec {
            first: self.first.0,
            second: self.second.0,
            parts: self.parts,
        }
    }
}

/// Result of banding a whole chain of depth ≥ 2 into `parts` bands.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChainReport {
    /// The chain's ops, producer first.
    pub ops: Vec<OpId>,
    pub parts: usize,
    /// Peak bytes of the fused chain without banding: the largest
    /// adjacent-tensor sum along input → levels.
    pub peak_before: usize,
    /// Live-set watermark of the banded schedule: per band step, the
    /// chain input (live until the last part's first level) + the level
    /// being read + the level being written + already-materialised
    /// output bands; plus the reassembly step's output bands + full
    /// output. Reduces to the §II-A pair watermark at depth 2.
    pub peak_after: usize,
    /// Intermediate elements computed more than once, summed over every
    /// intermediate level (halo rows shared by adjacent bands).
    pub recomputed_elems: usize,
    /// Output elements copied once by the concat-rows reassembly.
    pub assembled_elems: usize,
}

impl ChainReport {
    pub fn saving_pct(&self) -> f64 {
        if self.peak_before == 0 {
            return 0.0;
        }
        100.0 * (self.peak_before.saturating_sub(self.peak_after)) as f64 / self.peak_before as f64
    }

    /// Chain depth (2 = a §II-A pair).
    pub fn depth(&self) -> usize {
        self.ops.len()
    }

    /// The spec that materialises this report via
    /// [`crate::ir::rewrite::apply`]. Depth-2 chains map onto
    /// [`RewriteSpec::PairSplit`] so they serialise in the legacy
    /// artifact shape.
    pub fn spec(&self) -> RewriteSpec {
        if self.ops.len() == 2 {
            RewriteSpec::PairSplit(SplitSpec {
                first: self.ops[0].0,
                second: self.ops[1].0,
                parts: self.parts,
            })
        } else {
            RewriteSpec::ChainSplit {
                ops: self.ops.clone(),
                parts: self.parts,
            }
        }
    }
}

/// Analyse banding the chain `ops` (each op consuming its predecessor's
/// output) end-to-end into `parts` horizontal bands. Errors when the
/// chain is not bandable (see [`crate::ir::rewrite::chain_eligible`]).
///
/// The model walks the banded schedule's emission order (part 0's
/// levels, part 1's levels, …, reassembly) and tracks the live set at
/// every step: the chain input is consumed by every part's first level,
/// so it dies at the last part's; within a part only two adjacent
/// levels are live at once (band `j−1` dies as band `j` completes);
/// final-level bands accumulate until the concat copies them out. At
/// depth 2 this reduces term-for-term to [`analyse_pair`]'s §II-A
/// watermark.
pub fn analyse_chain(graph: &Graph, ops: &[OpId], parts: usize) -> anyhow::Result<ChainReport> {
    let plans = rewrite::chain_band_plan(graph, ops, parts)?;
    let d = ops.len();
    let input = graph.tensor(graph.op(ops[0]).inputs[0]);
    let levels: Vec<_> = ops
        .iter()
        .map(|&o| graph.tensor(graph.op(o).output))
        .collect();

    let mut sizes = vec![input.size_bytes()];
    sizes.extend(levels.iter().map(|t| t.size_bytes()));
    let peak_before = sizes.windows(2).map(|w| w[0] + w[1]).max().unwrap();

    let row_bytes: Vec<usize> = levels
        .iter()
        .map(|t| t.shape.w() * t.shape.c() * t.dtype.size_bytes())
        .collect();
    let in_bytes = input.size_bytes();
    let out_bytes = levels[d - 1].size_bytes();

    let last = parts - 1;
    let mut peak_after = 0usize;
    let mut out_prefix = 0usize; // bytes of final-level bands already live
    let mut rows_total = vec![0usize; d];
    for (p, cp) in plans.iter().enumerate() {
        let mut prev_band = 0usize;
        for j in 0..d {
            let rows = cp.rows[j].1 - cp.rows[j].0;
            rows_total[j] += rows;
            let band = rows * row_bytes[j];
            // the chain input is live while any future part still needs
            // it (p < last), and during the step that reads it (j == 0)
            let in_live = if j == 0 || p < last { in_bytes } else { 0 };
            peak_after = peak_after.max(in_live + prev_band + band + out_prefix);
            prev_band = band;
        }
        out_prefix += prev_band;
    }
    // reassembly: every final-level band + the full output
    peak_after = peak_after.max(out_prefix + out_bytes);

    let recomputed_elems = (0..d - 1)
        .map(|j| {
            rows_total[j].saturating_sub(levels[j].shape.h())
                * levels[j].shape.w()
                * levels[j].shape.c()
        })
        .sum();
    Ok(ChainReport {
        ops: ops.to_vec(),
        parts,
        peak_before,
        peak_after,
        recomputed_elems,
        assembled_elems: levels[d - 1].shape.num_elements(),
    })
}

/// Analyse splitting the pair `first → second` into `parts` bands. Thin
/// shim over [`analyse_chain`] at depth 2 — one watermark model covers
/// every depth.
pub fn analyse_pair(
    graph: &Graph,
    first: OpId,
    second: OpId,
    parts: usize,
) -> anyhow::Result<SplitReport> {
    let r = analyse_chain(graph, &[first, second], parts)?;
    Ok(SplitReport {
        first,
        second,
        parts,
        peak_before: r.peak_before,
        peak_after: r.peak_after,
        recomputed_elems: r.recomputed_elems,
        assembled_elems: r.assembled_elems,
    })
}

/// Extract the pair `first → second` into a standalone three-tensor
/// chain (`Input → first → second → Output`) with the same kinds,
/// shapes, dtype and weights — the subgraph [`analyse_pair`]'s schedule
/// model describes, used by the property tests to compare prediction
/// against the allocator on the materialised rewrite.
pub fn isolate_pair(graph: &Graph, first: OpId, second: OpId) -> anyhow::Result<Graph> {
    rewrite::split_eligible(graph, first, second, 2)?;
    let f = graph.op(first);
    let s = graph.op(second);
    let dtype = graph.tensor(f.inputs[0]).dtype;
    let mut b = GraphBuilder::new(&format!("{}_pair", graph.name), dtype);
    let x = b.input(graph.tensor(f.inputs[0]).shape.clone());
    let m = b.add_op(f.kind.clone(), &[x], f.weights.clone());
    let o = b.add_op(s.kind.clone(), &[m], s.weights.clone());
    anyhow::ensure!(
        b.graph_ref().tensor(m).shape == graph.tensor(f.output).shape
            && b.graph_ref().tensor(o).shape == graph.tensor(s.output).shape,
        "isolated pair re-inferred different shapes"
    );
    Ok(b.finish(&[o]))
}

/// The graph's most promising pair-split candidates: every eligible
/// pair whose banded schedule beats its fused peak, each at its best
/// `parts` in `2..=max_parts`, ranked by the pair's memory pressure
/// (`peak_before`, descending) and truncated to `limit`. The
/// peak-defining pair of the graph — §II-A's target — ranks first.
pub fn candidates(graph: &Graph, max_parts: usize, limit: usize) -> Vec<SplitReport> {
    let mut per_pair: Vec<SplitReport> = Vec::new();
    for (i, f) in graph.ops.iter().enumerate() {
        let consumers = graph.consumers(f.output);
        if consumers.len() != 1 {
            continue;
        }
        let c = consumers[0];
        if rewrite::split_eligible(graph, OpId(i), c, 2).is_err() {
            continue;
        }
        let oh = graph.tensor(graph.op(c).output).shape.h();
        let mut best: Option<SplitReport> = None;
        for parts in 2..=max_parts.min(oh) {
            if let Ok(r) = analyse_pair(graph, OpId(i), c, parts) {
                if r.peak_after < r.peak_before
                    && best.as_ref().map_or(true, |b| r.peak_after < b.peak_after)
                {
                    best = Some(r);
                }
            }
        }
        if let Some(b) = best {
            per_pair.push(b);
        }
    }
    per_pair.sort_by_key(|r| (usize::MAX - r.peak_before, r.first.0));
    per_pair.truncate(limit);
    per_pair
}

/// The graph's most promising chain candidates of depth 3..=`max_depth`:
/// every bandable chain whose end-to-end banded watermark beats its
/// fused peak, each at its best `parts` in `2..=max_parts`, ranked by
/// the chain's memory pressure (`peak_before`, descending) and
/// truncated to `limit`. Depth-2 chains are [`candidates`]' job.
pub fn chain_candidates(
    graph: &Graph,
    max_parts: usize,
    max_depth: usize,
    limit: usize,
) -> Vec<ChainReport> {
    if max_depth < 3 {
        return Vec::new();
    }
    let mut out: Vec<ChainReport> = Vec::new();
    for start in 0..graph.ops.len() {
        // grow the chain link by link; every prefix of depth ≥ 3 is a
        // candidate of its own (the watermark is not monotone in depth)
        let mut ops = vec![OpId(start)];
        while ops.len() < max_depth {
            let tail = *ops.last().unwrap();
            let consumers = graph.consumers(graph.op(tail).output);
            if consumers.len() != 1 {
                break;
            }
            let next = consumers[0];
            if rewrite::chain_eligible(graph, &[tail, next], 2).is_err() {
                break;
            }
            ops.push(next);
            if ops.len() < 3 {
                continue;
            }
            let oh = graph.tensor(graph.op(next).output).shape.h();
            let mut best: Option<ChainReport> = None;
            for parts in 2..=max_parts.min(oh) {
                if let Ok(r) = analyse_chain(graph, &ops, parts) {
                    if r.peak_after < r.peak_before
                        && best.as_ref().map_or(true, |b| r.peak_after < b.peak_after)
                    {
                        best = Some(r);
                    }
                }
            }
            if let Some(b) = best {
                out.push(b);
            }
        }
    }
    out.sort_by_key(|r| (usize::MAX - r.peak_before, r.ops[0].0));
    out.truncate(limit);
    out
}

/// Turn a [`RewriteBudget`] into the spec sequences the planner sweeps
/// as variants, in deterministic order: single pair splits (ranked by
/// pressure), then one multi-split composition of the top *disjoint*
/// pairs (up to `max_splits`, recorded in descending op order so each
/// spec's indices stay valid in the graph the previous one produced),
/// then depth-≥3 chains. Every returned sequence is directly applicable
/// via [`crate::ir::rewrite::apply`].
pub fn proposals(graph: &Graph, budget: &RewriteBudget, limit: usize) -> Vec<Vec<RewriteSpec>> {
    if !budget.enabled() {
        return Vec::new();
    }
    let mut out: Vec<Vec<RewriteSpec>> = Vec::new();
    let pairs = candidates(graph, budget.max_parts, limit);
    for r in &pairs {
        out.push(vec![RewriteSpec::PairSplit(r.spec())]);
    }
    if budget.max_splits >= 2 && pairs.len() >= 2 {
        // greedy by rank, keeping only pairs whose op ranges don't
        // interleave an already-chosen pair (disjoint ranges are what
        // makes sequential application index-stable)
        let mut chosen: Vec<&SplitReport> = Vec::new();
        for r in &pairs {
            if chosen.len() >= budget.max_splits {
                break;
            }
            let disjoint = chosen
                .iter()
                .all(|c| r.second.0 < c.first.0 || c.second.0 < r.first.0);
            if disjoint {
                chosen.push(r);
            }
        }
        if chosen.len() >= 2 {
            // apply from the highest op indices down: a split only
            // renumbers ops after its first index, so every later spec
            // (strictly lower indices) stays valid
            chosen.sort_by_key(|r| usize::MAX - r.first.0);
            out.push(
                chosen
                    .iter()
                    .map(|r| RewriteSpec::PairSplit(r.spec()))
                    .collect(),
            );
        }
    }
    for c in chain_candidates(graph, budget.max_parts, budget.max_chain_depth, limit) {
        out.push(vec![c.spec()]);
    }
    out
}

/// Scan a graph for its most profitable 2-op split (exhaustive over
/// eligible pairs and `2..=max_parts`) — the pair row of the `dmo
/// split` report. Thin shim over [`candidates`], which itself rides the
/// [`analyse_chain`] model; prefer [`proposals`] +
/// [`crate::ir::rewrite::apply`] for anything that executes rewrites.
pub fn best_split(graph: &Graph, max_parts: usize) -> Option<SplitReport> {
    candidates(graph, max_parts, usize::MAX)
        .into_iter()
        .min_by_key(|r| (r.peak_after, r.first.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Activation, Padding};
    use crate::ir::{DType, GraphBuilder, Shape};
    use crate::overlap::Method;
    use crate::planner::alloc::{allocate, OsTable, HEURISTICS};
    use crate::planner::order::{serialise, Strategy};
    use crate::planner::scope::analyse;

    /// §II-A's MobileNet v1 0.25 128 (8-bit) shape: the 1x1 conv
    /// (64 KB intermediate) feeding the next dwconv (16 KB out), with a
    /// 32 KB input. The paper reports 96 KB → 66 KB; the banded
    /// schedule's exact watermark is lower still (61 KB) because output
    /// bands materialise progressively and the input dies before the
    /// last one exists.
    #[test]
    fn paper_mobilenet_split_case() {
        let mut b = GraphBuilder::new("split", DType::I8);
        let x = b.input(Shape::hwc(64, 64, 8)); // 32 KB
        let c = b.conv2d(x, 16, (1, 1), (1, 1), Padding::Same, Activation::None); // 64 KB mid
        let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None); // 16 KB out
        let g = b.finish(&[d]);
        let r = analyse_pair(&g, OpId(0), OpId(1), 4).unwrap();
        assert_eq!(r.peak_before, 96 * 1024);
        // bands of 8 output rows need (8-1)*2+3 = 17 intermediate rows
        // (16 for the last, clipped); watermark peaks during B_2:
        // 32 KB input + 17 KB band + 12 KB of output bands = 61 KB
        assert_eq!(r.peak_after, 61 * 1024);
        assert!(r.saving_pct() > 30.0);
        // halo: 1 recomputed row × 64·16 elems × 3 boundaries
        assert_eq!(r.recomputed_elems, 3 * 64 * 16);
        assert_eq!(r.assembled_elems, 32 * 32 * 16);
    }

    /// Extending the §II-A pair by the next pointwise conv into a
    /// depth-3 chain does NOT pay on the mnv1 head shape: the 32 KB
    /// chain input stays live across every part's sub-chain while the
    /// final level's bands accumulate, so the watermark lands at 72 KB —
    /// above the pair's 61 KB. (The chain wins on hourglass shapes
    /// instead — small input, fat intermediates; see the hourglass zoo
    /// model.) Pinned by hand: part 3's first level reads 16 rows of the
    /// 64 KB intermediate with 24 KB of output bands already live:
    /// 32 + 16 + 24 = 72 KB.
    #[test]
    fn mnv1_depth3_chain_is_correctly_beaten_by_the_pair() {
        let mut b = GraphBuilder::new("chain3", DType::I8);
        let x = b.input(Shape::hwc(64, 64, 8)); // 32 KB
        let c = b.conv2d(x, 16, (1, 1), (1, 1), Padding::Same, Activation::None); // 64 KB
        let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None); // 16 KB
        let e = b.conv2d(d, 32, (1, 1), (1, 1), Padding::Same, Activation::None); // 32 KB
        let g = b.finish(&[e]);
        let chain = analyse_chain(&g, &[OpId(0), OpId(1), OpId(2)], 4).unwrap();
        assert_eq!(chain.peak_before, 96 * 1024);
        assert_eq!(chain.peak_after, 72 * 1024);
        // same halo as the pair: only level 0 recomputes (level 1's
        // stride-2 bands partition its input exactly here)
        assert_eq!(chain.recomputed_elems, 3 * 64 * 16);
        let pair = analyse_pair(&g, OpId(0), OpId(1), 4).unwrap();
        assert!(pair.peak_after < chain.peak_after);
    }

    /// One watermark model: the depth-2 chain analysis must equal the
    /// pair analysis field for field.
    #[test]
    fn analyse_chain_reduces_to_analyse_pair_at_depth_2() {
        let mut b = GraphBuilder::new("red", DType::F32);
        let x = b.input(Shape::hwc(24, 20, 3));
        let c = b.conv2d(x, 12, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let d = b.maxpool(c, (2, 2), (2, 2), Padding::Valid);
        let g = b.finish(&[d]);
        for parts in [2usize, 3, 4, 5] {
            let pair = analyse_pair(&g, OpId(0), OpId(1), parts).unwrap();
            let chain = analyse_chain(&g, &[OpId(0), OpId(1)], parts).unwrap();
            assert_eq!(chain.peak_before, pair.peak_before, "parts={parts}");
            assert_eq!(chain.peak_after, pair.peak_after, "parts={parts}");
            assert_eq!(chain.recomputed_elems, pair.recomputed_elems);
            assert_eq!(chain.assembled_elems, pair.assembled_elems);
            assert!(matches!(chain.spec(), RewriteSpec::PairSplit(_)));
        }
    }

    /// The analysis must predict exactly what the baseline allocator
    /// measures on the materialised rewrite.
    #[test]
    fn predicted_peak_matches_allocator_on_rewrite() {
        let mut b = GraphBuilder::new("pm", DType::F32);
        let x = b.input(Shape::hwc(24, 20, 3));
        let c = b.conv2d(x, 12, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let d = b.maxpool(c, (2, 2), (2, 2), Padding::Valid);
        let g = b.finish(&[d]);
        for parts in [2usize, 3, 4] {
            let r = analyse_pair(&g, OpId(0), OpId(1), parts).unwrap();
            let rw = crate::ir::rewrite::split_pair(&g, OpId(0), OpId(1), parts).unwrap();
            let order = serialise(&rw.graph, Strategy::Eager);
            let scopes = analyse(&rw.graph, &order);
            let os = OsTable::disabled(&rw.graph);
            let measured = HEURISTICS
                .iter()
                .map(|&h| allocate(&rw.graph, &scopes, &os, h).peak)
                .min()
                .unwrap();
            assert_eq!(measured, r.peak_after, "parts={parts}");
        }
    }

    #[test]
    fn best_split_finds_something() {
        let mut b = GraphBuilder::new("bs", DType::F32);
        let x = b.input(Shape::hwc(32, 32, 4));
        let c = b.conv2d(x, 16, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let d = b.maxpool(c, (2, 2), (2, 2), Padding::Valid);
        let g = b.finish(&[d]);
        let r = best_split(&g, 8).unwrap();
        assert!(r.peak_after < r.peak_before);
        assert_eq!(r.spec().first, r.first.0);
    }

    #[test]
    fn rejects_non_chain() {
        let mut b = GraphBuilder::new("nc", DType::F32);
        let x = b.input(Shape::hwc(8, 8, 2));
        let c = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let d = b.conv2d(x, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
        let s = b.add(c, d);
        let g = b.finish(&[s]);
        // ops 0 and 1 are siblings, not a chain
        assert!(analyse_pair(&g, OpId(0), OpId(1), 2).is_err());
        assert!(analyse_chain(&g, &[OpId(0), OpId(1)], 2).is_err());
    }

    #[test]
    fn candidates_rank_by_pressure_and_keep_the_peak_pair_first() {
        // two eligible pairs with very different pressure
        let mut b = GraphBuilder::new("rank", DType::F32);
        let x = b.input(Shape::hwc(32, 32, 4));
        let big = b.conv2d(x, 16, (3, 3), (1, 1), Padding::Same, Activation::None); // big mid
        let shr = b.maxpool(big, (2, 2), (2, 2), Padding::Valid);
        let small = b.conv2d(shr, 8, (3, 3), (1, 1), Padding::Same, Activation::None);
        let tail = b.maxpool(small, (2, 2), (2, 2), Padding::Valid);
        let g = b.finish(&[tail]);
        let cands = candidates(&g, 4, 8);
        assert!(!cands.is_empty());
        // first candidate must be the highest-pressure pair
        let max_pressure = cands.iter().map(|r| r.peak_before).max().unwrap();
        assert_eq!(cands[0].peak_before, max_pressure);
        // limit is respected
        assert_eq!(candidates(&g, 4, 1).len(), 1);
    }

    #[test]
    fn chain_candidates_walk_every_bandable_prefix() {
        // conv → dw → pool is bandable end-to-end; the hourglass shape
        // (tiny input, fat intermediates, tiny output) is where chains
        // shine: no un-banded schedule can avoid materialising a fat
        // intermediate in full
        let mut b = GraphBuilder::new("cc", DType::I8);
        let x = b.input(Shape::hwc(32, 32, 2));
        let c = b.conv2d(x, 16, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        let d = b.dwconv2d(c, (3, 3), (1, 1), Padding::Same, Activation::None);
        let p = b.maxpool(d, (4, 4), (4, 4), Padding::Valid);
        let g = b.finish(&[p]);
        let chains = chain_candidates(&g, 4, 3, 8);
        assert!(!chains.is_empty());
        let best = &chains[0];
        assert_eq!(best.depth(), 3);
        assert!(best.peak_after < best.peak_before);
        // the chain's watermark must undercut every single-pair option:
        // a pair split still materialises one fat intermediate in full
        let pair_best = best_split(&g, 4).map_or(usize::MAX, |r| r.peak_after);
        assert!(best.peak_after < pair_best);
        // depth guard: max_depth < 3 yields nothing
        assert!(chain_candidates(&g, 4, 2, 8).is_empty());
    }

    #[test]
    fn proposals_cover_pairs_multi_splits_and_chains() {
        // two disjoint eligible pairs and bandable depth-3 chains
        let mut b = GraphBuilder::new("props", DType::F32);
        let x = b.input(Shape::hwc(32, 32, 4));
        let big = b.conv2d(x, 16, (3, 3), (1, 1), Padding::Same, Activation::None);
        let shr = b.maxpool(big, (2, 2), (2, 2), Padding::Valid);
        let small = b.conv2d(shr, 8, (3, 3), (1, 1), Padding::Same, Activation::None);
        let tail = b.maxpool(small, (2, 2), (2, 2), Padding::Valid);
        let g = b.finish(&[tail]);
        let budget = RewriteBudget {
            max_parts: 4,
            max_splits: 2,
            max_chain_depth: 3,
        };
        let props = proposals(&g, &budget, 8);
        let multi = props.iter().find(|p| p.len() == 2).expect("multi-split");
        // recorded in descending op order so sequential application is
        // index-stable …
        assert!(multi[0].op_indices()[0] > multi[1].op_indices()[0]);
        let chain = props
            .iter()
            .find(|p| matches!(p[0], RewriteSpec::ChainSplit { .. }))
            .expect("chain proposal");
        assert!(chain[0].depth() >= 3);
        // … and every proposal must actually apply and validate
        for p in &props {
            let (rg, _) = rewrite::apply(&g, p).unwrap();
            assert!(rg.ops.len() > g.ops.len());
        }
        // a pairs-only budget proposes no chains and no multis
        let pairs_only = proposals(&g, &RewriteBudget::pairs(4), 8);
        assert!(pairs_only
            .iter()
            .all(|p| p.len() == 1 && matches!(p[0], RewriteSpec::PairSplit(_))));
        // a disabled budget proposes nothing
        assert!(proposals(&g, &RewriteBudget::disabled(), 8).is_empty());
    }

    #[test]
    fn isolated_pair_matches_in_situ_analysis() {
        let mut b = GraphBuilder::new("iso", DType::F32);
        let x = b.input(Shape::hwc(16, 16, 4));
        let pre = b.relu(x);
        let c = b.conv2d(pre, 8, (3, 3), (1, 1), Padding::Same, Activation::None);
        let d = b.dwconv2d(c, (3, 3), (2, 2), Padding::Same, Activation::None);
        let post = b.relu(d);
        let g = b.finish(&[post]);
        let iso = isolate_pair(&g, OpId(1), OpId(2)).unwrap();
        assert_eq!(iso.ops.len(), 2);
        let in_situ = analyse_pair(&g, OpId(1), OpId(2), 3).unwrap();
        let isolated = analyse_pair(&iso, OpId(0), OpId(1), 3).unwrap();
        assert_eq!(in_situ.peak_after, isolated.peak_after);
        assert_eq!(in_situ.recomputed_elems, isolated.recomputed_elems);
    }

    #[test]
    fn split_suppresses_dmo_overlap_on_the_banded_region() {
        // the §II-A caveat, modelled: the pair input feeds every band,
        // so it cannot die at the first band — its O_s credit is unusable
        let mut b = GraphBuilder::new("caveat", DType::F32);
        let x = b.input(Shape::hwc(16, 16, 4));
        let c = b.conv2d(x, 8, (1, 1), (1, 1), Padding::Same, Activation::None);
        let d = b.dwconv2d(c, (3, 3), (1, 1), Padding::Same, Activation::None);
        let g = b.finish(&[d]);
        let rw = crate::ir::rewrite::split_pair(&g, OpId(0), OpId(1), 2).unwrap();
        let order = serialise(&rw.graph, Strategy::Eager);
        let scopes = analyse(&rw.graph, &order);
        // input is read by both A bands: it dies only at the last one
        let a0 = OpId(0);
        assert!(!scopes.dies_at(g.inputs[0], a0), "input must outlive band 0");
        let os = OsTable::build(&rw.graph, Method::Algorithmic);
        let alloc = allocate(
            &rw.graph,
            &scopes,
            &os,
            crate::planner::alloc::Heuristic::PairFrontier,
        );
        crate::planner::alloc::check(&rw.graph, &scopes, &os, &alloc).unwrap();
    }
}
