//! Fleet serving loop: mixed-model traffic → per-model admission →
//! shared worker pool → pooled-arena planned execution → replies.
//!
//! [`Fleet`] is the long-lived handle: start it on a [`Registry`],
//! submit requests (blocking or shedding), hot-reload artifacts while
//! requests are in flight, and shut down to collect per-model reports.
//! [`fleet_serve`] wraps it in a deterministic load generator — the
//! `dmo serve --models …` entry point and the `serve_scale` bench both
//! drive that function.
//!
//! Fault tolerance: every request executes inside `catch_unwind`, so a
//! panicking kernel (or an injected [`crate::fault::FaultPlan`] fault)
//! settles as a per-request failure — the worker thread survives, the
//! pooled arena returns sink-free, and the reply channel always gets an
//! answer (success, or an error the client may retry). A per-model
//! [`Breaker`] quarantines a model after K consecutive failures without
//! touching its healthy peers, and a watermark violation degrades the
//! slot to its last-known-good generation or a freshly proven safe plan
//! ([`Registry::degrade`]).

use super::admission::Admission;
use super::breaker::{Admit, Breaker, BreakerConfig};
use super::registry::{ModelSpec, ModelState, Registry, ReloadInfo};
use crate::coordinator::Metrics;
use crate::fault::{ExecFaults, FaultKind, FaultPlan, FaultSpec};
use crate::ir::DType;
use crate::obs::log as obs_log;
use crate::obs::prom::PromText;
use crate::obs::trace as otrace;
use crate::obs::watermark::{WatermarkSink, WatermarkViolation};
use crate::ops::exec::{Arena, EventKind};
use crate::planner::PlanArtifact;
use crate::util::json;
use crate::util::rng::Rng;
use crate::util::sync::lock;
use anyhow::{Context, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant, SystemTime};

/// One in-flight fleet request.
pub struct FleetRequest {
    pub id: u64,
    pub data: Vec<f32>,
    pub enqueued: Instant,
    /// Remaining client retries if this attempt fails (0 = final).
    pub attempts_left: u32,
    pub reply: mpsc::Sender<FleetReply>,
}

/// One settled fleet attempt: a successful inference, or a failure the
/// client may retry while `attempts_left > 0`.
pub struct FleetReply {
    pub id: u64,
    pub model: usize,
    /// Generation of the [`super::ModelState`] that served the request —
    /// hot-reload tests read this to see the swap happen mid-stream.
    pub generation: u64,
    pub output: Vec<f32>,
    pub latency: Duration,
    /// `Some(reason)` when the attempt failed (panic, exec error,
    /// watermark violation, blown deadline). `output` is empty then.
    pub error: Option<String>,
    /// Echo of the request's retry budget, so the client can decide
    /// whether to resubmit without tracking state per id.
    pub attempts_left: u32,
}

/// Overload behaviour at the admission edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Block the producer while the model's queue is full (closed loop).
    Block,
    /// Reject immediately and count a shed (open loop keeps its clock).
    Shed,
}

/// Fault-tolerance knobs for a running fleet. The default is the
/// pre-fault behaviour: no injection, no deadline, no watermark
/// re-checking per request — only the panic isolation and the breaker
/// (which never opens unless something actually fails) are always on.
#[derive(Clone, Default)]
pub struct FleetOptions {
    /// Per-model circuit-breaker tuning.
    pub breaker: BreakerConfig,
    /// Deterministic fault schedule to inject (tests / `--faults`).
    pub faults: Option<Arc<FaultPlan>>,
    /// Per-request deadline measured from enqueue; expiry settles the
    /// attempt as a failure (retryable like any other).
    pub deadline: Option<Duration>,
    /// Install a [`WatermarkSink`] per request and fail the attempt when
    /// the observed high water exceeds the plan's peak — the trigger for
    /// safe-plan degradation. Costs event tracing per op, so it is
    /// opt-in (on whenever faults are injected).
    pub watermark_checks: bool,
}

/// A running fleet: registry + admission + breakers + worker pool.
pub struct Fleet {
    pub registry: Arc<Registry>,
    admission: Arc<Admission<FleetRequest>>,
    metrics: Arc<Vec<Mutex<Metrics>>>,
    breakers: Arc<Vec<Breaker>>,
    options: FleetOptions,
    workers: Vec<thread::JoinHandle<()>>,
    watcher: Option<(Arc<AtomicBool>, thread::JoinHandle<()>)>,
    metrics_writer: Option<(Arc<AtomicBool>, thread::JoinHandle<()>, PathBuf)>,
}

/// How one attempt went wrong, with enough typing for the settle path.
struct AttemptError {
    msg: String,
    deadline: bool,
    watermark: bool,
}

impl Fleet {
    /// Spawn `workers` threads draining the fair admission queues with
    /// default [`FleetOptions`]. `queue_capacity` bounds each model's
    /// queue.
    pub fn start(registry: Registry, workers: usize, queue_capacity: usize) -> Fleet {
        Fleet::start_with(registry, workers, queue_capacity, FleetOptions::default())
    }

    /// [`Fleet::start`] with explicit fault-tolerance options.
    pub fn start_with(
        registry: Registry,
        workers: usize,
        queue_capacity: usize,
        options: FleetOptions,
    ) -> Fleet {
        let registry = Arc::new(registry);
        let admission = Arc::new(Admission::new(registry.len(), queue_capacity));
        let metrics: Arc<Vec<Mutex<Metrics>>> =
            Arc::new((0..registry.len()).map(|_| Mutex::new(Metrics::default())).collect());
        let breakers: Arc<Vec<Breaker>> = Arc::new(
            (0..registry.len())
                .map(|_| Breaker::new(options.breaker))
                .collect(),
        );
        let n = if workers == 0 {
            thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
        } else {
            workers
        };
        let handles = (0..n)
            .map(|w| {
                let reg = registry.clone();
                let adm = admission.clone();
                let met = metrics.clone();
                let brk = breakers.clone();
                let opts = options.clone();
                thread::Builder::new()
                    .name(format!("fleet-worker-{w}"))
                    .spawn(move || {
                        while let Some((m, seq, req)) = adm.take_seq() {
                            handle_one(m, seq, req, &reg, &met, &brk, &opts);
                        }
                    })
                    .expect("spawning fleet worker")
            })
            .collect();
        Fleet {
            registry,
            admission,
            metrics,
            breakers,
            options,
            workers: handles,
            watcher: None,
            metrics_writer: None,
        }
    }

    /// Admit a request for model `m` under `policy`. Returns `false`
    /// when the request was shed (recorded in that model's [`Metrics`] —
    /// the single source of truth the reports read) or the fleet is
    /// closed. A quarantined model sheds here, at the breaker, before
    /// the request ever costs a queue slot or a worker.
    pub fn submit(&self, m: usize, req: FleetRequest, policy: AdmissionPolicy) -> bool {
        let gate = self.breakers[m].admit();
        if gate == Admit::Shed {
            lock(&self.metrics[m]).record_shed_quarantined();
            return false;
        }
        let outcome = match policy {
            AdmissionPolicy::Block => self.admission.submit(m, req),
            AdmissionPolicy::Shed => self.admission.try_submit(m, req),
        };
        match outcome {
            Ok(()) => true,
            Err(_rejected) => {
                if gate == Admit::Probe {
                    // the half-open probe never made it into a queue —
                    // free the slot for the next submission
                    self.breakers[m].probe_aborted();
                }
                lock(&self.metrics[m]).record_shed();
                false
            }
        }
    }

    /// Hot-reload slot `m` from a re-planned artifact (see
    /// [`Registry::reload`] for the validation and drain semantics). A
    /// successful reload moves an open breaker to half-open: the fresh
    /// validated generation deserves an immediate probe.
    pub fn reload(&self, m: usize, artifact: PlanArtifact) -> Result<ReloadInfo> {
        let info = self.registry.reload(m, artifact)?;
        self.breakers[m].on_reload();
        Ok(info)
    }

    /// Stall model `m`'s admission queue for `hold` (fault injection —
    /// see [`Admission::stall_for`]).
    pub fn stall(&self, m: usize, hold: Duration) {
        self.admission.stall_for(m, hold);
    }

    /// Model `m`'s circuit breaker (tests inspect quarantine state).
    pub fn breaker(&self, m: usize) -> &Breaker {
        &self.breakers[m]
    }

    /// Watch `dir` for `<model>.plan.json` artifact drops and hot-reload
    /// the matching slot on every change. Files already present when the
    /// watch starts are treated as seen (the registry loaded them — or
    /// chose not to — at startup). A bad artifact is logged and skipped;
    /// the old generation keeps serving.
    pub fn watch(&mut self, dir: PathBuf, poll: Duration) {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let registry = self.registry.clone();
        let breakers = self.breakers.clone();
        let handle = thread::Builder::new()
            .name("fleet-reload-watch".into())
            .spawn(move || {
                let paths: Vec<PathBuf> = registry
                    .names()
                    .iter()
                    .map(|n| dir.join(format!("{n}.plan.json")))
                    .collect();
                let mtime = |p: &PathBuf| -> Option<SystemTime> {
                    std::fs::metadata(p).and_then(|m| m.modified()).ok()
                };
                let mut seen: Vec<Option<SystemTime>> = paths.iter().map(&mtime).collect();
                while !flag.load(Ordering::Relaxed) {
                    for (m, path) in paths.iter().enumerate() {
                        let now = mtime(path);
                        if now.is_some() && now != seen[m] {
                            seen[m] = now; // one attempt per change, even if it fails
                            match PlanArtifact::load(path).map_err(anyhow::Error::from)
                                .and_then(|a| registry.reload(m, a))
                            {
                                Ok(info) => {
                                    breakers[m].on_reload();
                                    obs_log::info(format_args!(
                                        "fleet: hot-reloaded `{}` → generation {} (arena {} → {})",
                                        registry.names()[m],
                                        info.generation,
                                        info.old_peak,
                                        info.new_peak
                                    ))
                                }
                                Err(e) => obs_log::warn(format_args!(
                                    "fleet: reload of `{}` from {} rejected ({e:#}); old \
                                     generation keeps serving",
                                    registry.names()[m],
                                    path.display()
                                )),
                            }
                        }
                    }
                    thread::sleep(poll);
                }
            })
            .expect("spawning reload watcher");
        self.watcher = Some((stop, handle));
    }

    /// Current queue depth for model `m` (live admission telemetry).
    pub fn queue_depth(&self, m: usize) -> usize {
        self.admission.depth(m)
    }

    /// Render a Prometheus text-exposition snapshot of the fleet's
    /// current state: per-model request counters (completed / shed /
    /// failed / retried / quarantine-shed / deadline / degraded),
    /// latency histograms, queue-depth and arena-pool gauges,
    /// generation / reload / degrade counters, the per-model state gauge
    /// and — when injecting — the fault counters.
    pub fn prometheus_snapshot(&self) -> String {
        render_prometheus(
            &self.registry,
            &self.admission,
            &self.metrics,
            &self.breakers,
            self.options.faults.as_deref(),
        )
    }

    /// Write the current snapshot to `path` atomically (tmp + rename, so
    /// a concurrent scraper never reads a torn file).
    pub fn write_metrics(&self, path: &Path) -> std::io::Result<()> {
        write_atomic(path, &self.prometheus_snapshot())
    }

    /// Rewrite `path` with a fresh snapshot every `period` until
    /// shutdown, which writes one final snapshot after the last request
    /// drains (`dmo serve --metrics-out=FILE`).
    pub fn metrics_writer(&mut self, path: PathBuf, period: Duration) {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let registry = self.registry.clone();
        let admission = self.admission.clone();
        let metrics = self.metrics.clone();
        let breakers = self.breakers.clone();
        let faults = self.options.faults.clone();
        let out = path.clone();
        let handle = thread::Builder::new()
            .name("fleet-metrics-writer".into())
            .spawn(move || {
                while !flag.load(Ordering::Relaxed) {
                    let text = render_prometheus(
                        &registry,
                        &admission,
                        &metrics,
                        &breakers,
                        faults.as_deref(),
                    );
                    if let Err(e) = write_atomic(&out, &text) {
                        obs_log::warn(format_args!(
                            "fleet: writing metrics snapshot to {} failed: {e}",
                            out.display()
                        ));
                    }
                    thread::sleep(period);
                }
            })
            .expect("spawning metrics writer");
        self.metrics_writer = Some((stop, handle, path));
    }

    /// Stop admitting, drain the queues, join every worker and the
    /// watcher, and assemble the per-model reports. A worker thread that
    /// died (it should never: request panics are caught per attempt)
    /// becomes an entry in [`FleetShutdown::worker_errors`] instead of
    /// tearing down the whole report.
    pub fn shutdown(mut self) -> Result<FleetShutdown> {
        self.admission.close();
        if let Some((stop, handle)) = self.watcher.take() {
            stop.store(true, Ordering::Relaxed);
            let _ = handle.join();
        }
        let mut worker_errors = Vec::new();
        for (w, h) in self.workers.drain(..).enumerate() {
            if let Err(payload) = h.join() {
                worker_errors.push(format!(
                    "fleet-worker-{w} died outside request isolation: {}",
                    panic_message(payload.as_ref())
                ));
            }
        }
        if let Some((stop, handle, path)) = self.metrics_writer.take() {
            stop.store(true, Ordering::Relaxed);
            let _ = handle.join();
            // final snapshot: every request drained, counters settled
            if let Err(e) = self.write_metrics(&path) {
                obs_log::warn(format_args!(
                    "fleet: final metrics snapshot to {} failed: {e}",
                    path.display()
                ));
            }
        }
        let max_depths = self.admission.max_depths();
        let per_model = (0..self.registry.len())
            .map(|m| {
                let metrics = lock(&self.metrics[m]).clone();
                let state = self.registry.current(m);
                ModelReport {
                    model: state.name.clone(),
                    completed: metrics.count(),
                    shed: metrics.shed,
                    failed: metrics.failed,
                    arena_bytes: state.plan.peak(),
                    pool_hits: state.pool.hits(),
                    pool_allocs: state.pool.allocs(),
                    pool_hit_rate: state.pool.hit_rate(),
                    pool_capacity: state.pool.capacity(),
                    pool_idle: state.pool.idle(),
                    max_queue_depth: max_depths[m],
                    queue_capacity: self.admission.capacity(),
                    generation: state.generation,
                    reloads: self.registry.reloads(m),
                    reload_rejections: self.registry.reload_rejections(m),
                    degraded: self.registry.is_degraded(m),
                    degrades: self.registry.degrades(m),
                    quarantined: self.breakers[m].is_open(),
                    metrics,
                }
            })
            .collect();
        Ok(FleetShutdown {
            per_model,
            worker_errors,
        })
    }
}

/// Everything [`Fleet::shutdown`] hands back.
#[derive(Debug, Clone)]
pub struct FleetShutdown {
    pub per_model: Vec<ModelReport>,
    /// Worker threads that died outside per-request isolation (expected
    /// empty; populated instead of panicking the shutdown path).
    pub worker_errors: Vec<String>,
}

/// Serve one dispatched request end to end: deadline gates, guarded
/// execution, breaker/metrics bookkeeping, and **exactly one** reply —
/// success or failure, the client is never left hanging.
fn handle_one(
    m: usize,
    seq: u64,
    req: FleetRequest,
    reg: &Registry,
    met: &[Mutex<Metrics>],
    breakers: &[Breaker],
    opts: &FleetOptions,
) {
    // time spent queued before a worker picked it up
    let queue_us = req.enqueued.elapsed().as_micros() as u64;
    let mut sp = otrace::span("request", "fleet");
    // the Arc pins this request to one generation; a concurrent reload
    // (or degrade) drains behind it
    let state = reg.current(m);
    let expired = |stage: &str| AttemptError {
        msg: format!(
            "deadline expired {stage} ({:?} elapsed)",
            req.enqueued.elapsed()
        ),
        deadline: true,
        watermark: false,
    };
    let outcome = if matches!(opts.deadline, Some(dl) if req.enqueued.elapsed() >= dl) {
        Err(expired("before execution"))
    } else {
        match execute_guarded(&state, &req.data, m, seq, opts) {
            Ok(out) if matches!(opts.deadline, Some(dl) if req.enqueued.elapsed() >= dl) => {
                // the answer arrived too late to be an answer
                drop(out);
                Err(expired("during execution"))
            }
            other => other,
        }
    };
    let latency = req.enqueued.elapsed();
    if sp.is_active() {
        sp.arg("model", json::s(&state.name));
        sp.arg("id", json::num(req.id as usize));
        sp.arg("generation", json::num(state.generation as usize));
        sp.arg("queue_us", json::num(queue_us as usize));
        sp.arg("seq", json::num(seq as usize));
        if let Err(e) = &outcome {
            sp.arg("error", json::s(&e.msg));
        }
    }
    drop(sp); // the settle path is outside the span
    match outcome {
        Ok(output) => {
            breakers[m].on_success();
            let degraded = reg.is_degraded(m);
            {
                let mut g = lock(&met[m]);
                g.record(latency);
                if degraded {
                    g.record_degraded_served();
                }
            }
            let _ = req.reply.send(FleetReply {
                id: req.id,
                model: m,
                generation: state.generation,
                output,
                latency,
                error: None,
                attempts_left: req.attempts_left,
            });
        }
        Err(err) => {
            if err.watermark {
                // the generation's results can no longer be trusted —
                // pin last-known-good or fall back to a safe plan
                match reg.degrade(m) {
                    Ok(info) => obs_log::warn(format_args!(
                        "fleet: watermark violation on `{}` — degraded to generation {} \
                         ({:?}, arena {} B)",
                        state.name, info.generation, info.mode, info.peak
                    )),
                    Err(e) => obs_log::warn(format_args!(
                        "fleet: watermark violation on `{}` but degrade failed: {e:#}",
                        state.name
                    )),
                }
            }
            breakers[m].on_failure();
            let retryable = req.attempts_left > 0;
            {
                let mut g = lock(&met[m]);
                if err.deadline {
                    g.record_deadline_expired();
                }
                if retryable {
                    g.record_retry();
                } else {
                    g.record_failed();
                }
            }
            obs_log::warn(format_args!(
                "fleet: request {} on `{}` failed ({}retryable): {}",
                req.id,
                state.name,
                if retryable { "" } else { "not " },
                err.msg
            ));
            let _ = req.reply.send(FleetReply {
                id: req.id,
                model: m,
                generation: state.generation,
                output: Vec::new(),
                latency,
                error: Some(err.msg),
                attempts_left: req.attempts_left,
            });
        }
    }
}

/// Execute one attempt inside `catch_unwind`: a panic (organic or
/// injected) unwinds through the pooled-arena guard — which returns the
/// buffer sink-free — and settles as an [`AttemptError`] instead of
/// killing the worker.
fn execute_guarded(
    state: &ModelState,
    data: &[f32],
    m: usize,
    seq: u64,
    opts: &FleetOptions,
) -> std::result::Result<Vec<f32>, AttemptError> {
    let fault = opts
        .faults
        .as_ref()
        .map(|f| f.exec_faults(m, seq))
        .unwrap_or_default();
    let caught = catch_unwind(AssertUnwindSafe(|| -> Result<Vec<f32>> {
        let mut arena = {
            let _acquire = otrace::span("arena_acquire", "fleet");
            state.acquire_arena()
        };
        let wm = if opts.watermark_checks {
            let sink = WatermarkSink::new(arena.len());
            arena.set_sink(Some(Box::new(sink.clone())));
            Some(sink)
        } else {
            None
        };
        // inject at the midpoint op: early enough that every fault class
        // fires even on short orders, late enough that real stores have
        // happened and corruption is observable
        let mid = state.plan.order.0.len() / 2;
        let out = {
            let _exec = otrace::span("exec", "fleet");
            state.execute_with(&mut arena, data, |step, arena| {
                if step == mid && fault.any() {
                    inject_exec_faults(&fault, arena, opts.faults.as_deref(), &state.name);
                }
                Ok(())
            })?
        };
        arena.set_sink(None);
        drop(arena); // back to the pool before the watermark verdict
        if let Some(sink) = wm {
            let observed = sink.high_water();
            if observed > state.plan.peak() {
                return Err(WatermarkViolation {
                    model: state.name.clone(),
                    observed_peak: observed,
                    planned_peak: state.plan.peak(),
                }
                .into());
            }
        }
        Ok(out)
    }));
    match caught {
        Ok(Ok(out)) => Ok(out),
        Ok(Err(e)) => Err(AttemptError {
            watermark: e.downcast_ref::<WatermarkViolation>().is_some(),
            msg: format!("serving `{}` failed: {e:#}", state.name),
            deadline: false,
        }),
        Err(payload) => Err(AttemptError {
            msg: format!(
                "panic while serving `{}`: {}",
                state.name,
                panic_message(payload.as_ref())
            ),
            deadline: false,
            watermark: false,
        }),
    }
}

/// Apply one dispatched request's scheduled exec faults (corruption
/// first, then delay, panic last — a panicking request still corrupted
/// and stalled, the worst realistic ordering).
fn inject_exec_faults(
    fault: &ExecFaults,
    arena: &mut Arena,
    plan: Option<&FaultPlan>,
    model: &str,
) {
    if let Some(c) = fault.corrupt {
        if let Some(p) = plan {
            p.note(FaultKind::ArenaCorrupt);
        }
        otrace::instant("fault:corrupt-arena", "fault", Vec::new());
        let len = arena.len();
        if len > 0 {
            let mut rng = Rng::new(c.salt);
            for _ in 0..c.len {
                let off = rng.below(len);
                let garbage = (rng.next_u64() % 256) as i64 - 128;
                arena.poke(DType::I8, off, garbage as f32);
            }
        }
        // a rogue writer does not respect the planned peak: surface the
        // out-of-bounds store this corruption models, so the watermark
        // check can convict the run instead of trusting its output
        if let Some(sink) = arena.sink.as_mut() {
            sink.event(EventKind::Store, len, c.len.max(1));
        }
        obs_log::warn(format_args!(
            "fault: corrupted {} arena bytes in `{model}`",
            c.len
        ));
    }
    if let Some(d) = fault.delay {
        if let Some(p) = plan {
            p.note(FaultKind::ExecDelay);
        }
        otrace::instant("fault:delay", "fault", Vec::new());
        thread::sleep(d);
    }
    if fault.panic {
        if let Some(p) = plan {
            p.note(FaultKind::WorkerPanic);
        }
        otrace::instant("fault:panic", "fault", Vec::new());
        panic!("injected fault: worker panic while serving `{model}`");
    }
}

/// Human-readable panic payload (panics carry `&str` or `String` in
/// practice; anything else gets a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Atomic file replace: write to `<path>.tmp`, then rename over `path`,
/// so a concurrent reader (a Prometheus scraper tailing the file) never
/// observes a half-written snapshot.
fn write_atomic(path: &Path, text: &str) -> std::io::Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text)?;
    std::fs::rename(&tmp, path)
}

/// Render the fleet's live state in Prometheus text-exposition format.
fn render_prometheus<T>(
    registry: &Registry,
    admission: &Admission<T>,
    metrics: &[Mutex<Metrics>],
    breakers: &[Breaker],
    faults: Option<&FaultPlan>,
) -> String {
    let mut p = PromText::new();
    let max_depths = admission.max_depths();
    p.family(
        "dmo_requests_completed_total",
        "Requests completed per model.",
        "counter",
    );
    p.family(
        "dmo_requests_shed_total",
        "Requests shed at admission per model.",
        "counter",
    );
    p.family(
        "dmo_requests_quarantine_shed_total",
        "Requests shed by the circuit breaker (subset of shed).",
        "counter",
    );
    p.family(
        "dmo_requests_failed_total",
        "Requests settled as failures with no retry budget left.",
        "counter",
    );
    p.family(
        "dmo_requests_retried_total",
        "Failed attempts handed back for a client retry.",
        "counter",
    );
    p.family(
        "dmo_requests_deadline_expired_total",
        "Attempts that blew their deadline.",
        "counter",
    );
    p.family(
        "dmo_requests_degraded_total",
        "Completed requests served by a degraded generation.",
        "counter",
    );
    p.family("dmo_queue_depth", "Current admission queue depth.", "gauge");
    p.family(
        "dmo_queue_depth_max",
        "High-water mark of the admission queue.",
        "gauge",
    );
    p.family(
        "dmo_queue_capacity",
        "Configured admission queue bound.",
        "gauge",
    );
    p.family(
        "dmo_arena_bytes",
        "Planned arena bytes of the serving generation.",
        "gauge",
    );
    p.family(
        "dmo_arena_pool_hits_total",
        "Arena acquisitions served from the pool.",
        "counter",
    );
    p.family(
        "dmo_arena_pool_allocs_total",
        "Arena acquisitions that had to allocate.",
        "counter",
    );
    p.family("dmo_arena_pool_idle", "Arenas idle in the pool.", "gauge");
    p.family(
        "dmo_arena_pool_capacity",
        "Arenas held by the pool in total.",
        "gauge",
    );
    p.family(
        "dmo_model_generation",
        "Hot-reload generation currently serving.",
        "gauge",
    );
    p.family(
        "dmo_model_reloads_total",
        "Accepted hot reloads per model.",
        "counter",
    );
    p.family(
        "dmo_model_reload_rejections_total",
        "Hot reloads rejected at validation, serving state untouched.",
        "counter",
    );
    p.family(
        "dmo_model_degraded_total",
        "Degrade transitions (pin previous / install safe plan).",
        "counter",
    );
    p.family(
        "dmo_model_state",
        "Serving state: 0 serving, 1 degraded, 2 quarantined, 3 half-open probe.",
        "gauge",
    );
    for m in 0..registry.len() {
        let state = registry.current(m);
        let name = state.name.clone();
        let labels: &[(&str, &str)] = &[("model", &name)];
        let snap = lock(&metrics[m]).clone();
        p.sample("dmo_requests_completed_total", labels, snap.count() as f64);
        p.sample("dmo_requests_shed_total", labels, snap.shed as f64);
        p.sample(
            "dmo_requests_quarantine_shed_total",
            labels,
            snap.shed_quarantined as f64,
        );
        p.sample("dmo_requests_failed_total", labels, snap.failed as f64);
        p.sample("dmo_requests_retried_total", labels, snap.retries as f64);
        p.sample(
            "dmo_requests_deadline_expired_total",
            labels,
            snap.deadline_expired as f64,
        );
        p.sample("dmo_requests_degraded_total", labels, snap.degraded as f64);
        p.sample("dmo_queue_depth", labels, admission.depth(m) as f64);
        p.sample("dmo_queue_depth_max", labels, max_depths[m] as f64);
        p.sample("dmo_queue_capacity", labels, admission.capacity() as f64);
        p.sample("dmo_arena_bytes", labels, state.plan.peak() as f64);
        p.sample("dmo_arena_pool_hits_total", labels, state.pool.hits() as f64);
        p.sample(
            "dmo_arena_pool_allocs_total",
            labels,
            state.pool.allocs() as f64,
        );
        p.sample("dmo_arena_pool_idle", labels, state.pool.idle() as f64);
        p.sample(
            "dmo_arena_pool_capacity",
            labels,
            state.pool.capacity() as f64,
        );
        p.sample("dmo_model_generation", labels, state.generation as f64);
        p.sample(
            "dmo_model_reloads_total",
            labels,
            registry.reloads(m) as f64,
        );
        p.sample(
            "dmo_model_reload_rejections_total",
            labels,
            registry.reload_rejections(m) as f64,
        );
        p.sample(
            "dmo_model_degraded_total",
            labels,
            registry.degrades(m) as f64,
        );
        // the breaker owns the louder states; degraded shows through
        // only while the breaker is closed
        let bcode = breakers[m].state_code();
        let code = if bcode >= 2 {
            bcode
        } else if registry.is_degraded(m) {
            1
        } else {
            0
        };
        p.sample("dmo_model_state", labels, code as f64);
    }
    if let Some(fp) = faults {
        p.family(
            "dmo_faults_injected_total",
            "Deterministically injected faults by kind.",
            "counter",
        );
        for kind in FaultKind::ALL {
            p.sample(
                "dmo_faults_injected_total",
                &[("kind", kind.name())],
                fp.injected(kind) as f64,
            );
        }
    }
    p.family(
        "dmo_request_latency_seconds",
        "End-to-end request latency (enqueue to reply).",
        "histogram",
    );
    for m in 0..registry.len() {
        let state = registry.current(m);
        let name = state.name.clone();
        let hist = lock(&metrics[m]).histogram().clone();
        p.latency_histogram("dmo_request_latency_seconds", &[("model", &name)], &hist);
    }
    p.finish()
}

/// Per-model serving summary. `shed` and `completed` both come out of
/// the model's [`Metrics`] — there is exactly one source of truth.
#[derive(Debug, Clone)]
pub struct ModelReport {
    pub model: String,
    pub completed: usize,
    pub shed: usize,
    /// Requests that settled as failures (retry budget exhausted).
    pub failed: usize,
    pub metrics: Metrics,
    /// Arena bytes of the *current* generation (post-reload size).
    pub arena_bytes: usize,
    pub pool_hits: usize,
    pub pool_allocs: usize,
    pub pool_hit_rate: f64,
    /// Arenas the pool holds in total / idle at shutdown (gauges).
    pub pool_capacity: usize,
    pub pool_idle: usize,
    /// High-water mark of the model's admission queue over the run.
    pub max_queue_depth: usize,
    /// Configured per-model admission queue bound (clamped to ≥ 1).
    pub queue_capacity: usize,
    pub generation: u64,
    pub reloads: usize,
    /// Reloads rejected at validation (serving state untouched).
    pub reload_rejections: usize,
    /// Slot is serving a degraded generation at shutdown.
    pub degraded: bool,
    /// Degrade transitions over the run.
    pub degrades: usize,
    /// Breaker is open (model quarantined) at shutdown.
    pub quarantined: bool,
}

/// Fleet load-generation configuration (`dmo serve --models …`).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub models: Vec<ModelSpec>,
    /// Pooled arenas per model (K).
    pub arenas: usize,
    /// Worker threads (0 = all cores).
    pub workers: usize,
    /// Per-model admission queue capacity.
    pub queue_capacity: usize,
    pub requests: u64,
    /// Open-loop Poisson arrival rate in req/s with shedding admission;
    /// `<= 0` runs closed-loop (as fast as backpressure admits).
    pub rate: f64,
    /// Per-model traffic weights (empty = uniform).
    pub mix: Vec<f64>,
    pub seed: u64,
    /// Planner worker threads for models registered without an artifact.
    pub jobs: usize,
    /// Directory to watch for `<model>.plan.json` hot-reload drops.
    pub reload_watch: Option<PathBuf>,
    /// File to (re)write Prometheus text-format metric snapshots to,
    /// periodically while serving and once more at shutdown.
    pub metrics_out: Option<PathBuf>,
    /// Deterministic fault schedule (`--faults=panic:1,stall:1@0`);
    /// implies per-request watermark checks.
    pub faults: Option<FaultSpec>,
    /// Per-request deadline from enqueue to reply.
    pub deadline: Option<Duration>,
    /// Client retries per failed request (exponential backoff).
    pub retries: u32,
    /// Base client backoff, doubled per prior attempt.
    pub backoff: Duration,
    /// Per-model circuit-breaker tuning.
    pub breaker: BreakerConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            models: vec![ModelSpec::planned("tiny")],
            arenas: 4,
            workers: 0,
            queue_capacity: 64,
            requests: 1024,
            rate: 0.0,
            mix: Vec::new(),
            seed: 42,
            jobs: 0,
            reload_watch: None,
            metrics_out: None,
            faults: None,
            deadline: None,
            retries: 0,
            backoff: Duration::from_micros(200),
            breaker: BreakerConfig::default(),
        }
    }
}

/// Whole-run summary returned by [`fleet_serve`].
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub wall: Duration,
    pub completed: usize,
    pub shed: usize,
    /// Requests settled as failures (never completed, never shed).
    pub failed: usize,
    /// Failed attempts the client retried (each later settled).
    pub retried: usize,
    /// Breaker sheds (subset of `shed`).
    pub quarantine_shed: usize,
    /// Completed requests served by degraded generations.
    pub degraded_served: usize,
    /// Faults the injector actually fired over the run.
    pub faults_injected: u64,
    pub throughput_rps: f64,
    /// Worker threads that died outside request isolation (expected
    /// empty).
    pub worker_errors: Vec<String>,
    pub per_model: Vec<ModelReport>,
}

/// Drive the scheduled generator-side faults due at request `id`:
/// garbled hot-reloads (which the registry must reject) and admission
/// queue stalls.
fn inject_generator_faults(fp: &FaultPlan, id: u64, fleet: &Fleet) {
    for rf in fp.reloads_at(id) {
        fp.note(FaultKind::CorruptReload);
        let bad = FaultPlan::garble(&fleet.registry.current(rf.model).artifact, rf.mode);
        match fleet.reload(rf.model, bad) {
            Ok(info) => obs_log::warn(format_args!(
                "fault: injected corrupt reload (model {}, {:?}) was ACCEPTED as generation \
                 {} — validation gap!",
                rf.model, rf.mode, info.generation
            )),
            Err(e) => obs_log::info(format_args!(
                "fault: injected corrupt reload (model {}, {:?}) rejected as designed: {e:#}",
                rf.model, rf.mode
            )),
        }
    }
    for st in fp.stalls_at(id) {
        fp.note(FaultKind::QueueStall);
        obs_log::info(format_args!(
            "fault: stalling model {} admission queue for {:?}",
            st.model, st.hold
        ));
        fleet.stall(st.model, st.hold);
    }
}

/// Run the fleet under a deterministic mixed-model workload: start a
/// registry + worker pool, emit `cfg.requests` requests across the
/// models (weighted by `cfg.mix`), settle every reply — retrying failed
/// attempts while budget remains — then shut down. The report proves
/// the three-way accounting identity
/// `completed + shed + failed == requests` under every fault class:
/// no request is ever lost, only completed, rejected, or failed.
pub fn fleet_serve(cfg: &FleetConfig) -> Result<FleetReport> {
    let registry = Registry::load(&cfg.models, cfg.arenas, cfg.jobs, cfg.seed)?;
    let n_models = registry.len();
    let elems: Vec<usize> = (0..n_models)
        .map(|m| registry.current(m).input_elements())
        .collect();
    let fault_plan = cfg
        .faults
        .as_ref()
        .map(|spec| Arc::new(FaultPlan::new(spec, cfg.seed, cfg.requests, n_models)));
    let options = FleetOptions {
        breaker: cfg.breaker,
        faults: fault_plan.clone(),
        deadline: cfg.deadline,
        watermark_checks: fault_plan.is_some(),
    };
    let mut fleet = Fleet::start_with(registry, cfg.workers, cfg.queue_capacity, options);
    if let Some(dir) = &cfg.reload_watch {
        fleet.watch(dir.clone(), Duration::from_millis(100));
    }
    if let Some(path) = &cfg.metrics_out {
        fleet.metrics_writer(path.clone(), Duration::from_millis(500));
    }

    anyhow::ensure!(
        cfg.mix.is_empty() || cfg.mix.len() == n_models,
        "--mix needs one weight per model ({} given, {} models)",
        cfg.mix.len(),
        n_models
    );
    let weights: Vec<f64> = if cfg.mix.is_empty() {
        vec![1.0; n_models]
    } else {
        cfg.mix.clone()
    };
    let total_w: f64 = weights.iter().sum();
    anyhow::ensure!(total_w > 0.0, "--mix weights must sum to a positive value");

    let policy = if cfg.rate > 0.0 {
        AdmissionPolicy::Shed
    } else {
        AdmissionPolicy::Block
    };
    let (reply_tx, reply_rx) = mpsc::channel::<FleetReply>();
    let mut rng = Rng::new(cfg.seed ^ 0xF1EE_7000);
    // deterministic per-(model,id) payload — a retry regenerates the
    // exact bytes its first attempt carried
    let payload = |id: u64, m: usize| -> Vec<f32> {
        let mut pr = Rng::new(cfg.seed ^ (id << 8) ^ m as u64);
        (0..elems[m]).map(|_| pr.uniform(-1.0, 1.0)).collect()
    };
    let t0 = Instant::now();
    let mut outstanding: u64 = 0;
    for id in 0..cfg.requests {
        if let Some(fp) = &fault_plan {
            inject_generator_faults(fp, id, &fleet);
        }
        if cfg.rate > 0.0 {
            thread::sleep(Duration::from_secs_f64(rng.exp(cfg.rate)));
        }
        // weighted model pick
        let mut pick = rng.next_f64() * total_w;
        let mut m = n_models - 1;
        for (i, w) in weights.iter().enumerate() {
            if pick < *w {
                m = i;
                break;
            }
            pick -= w;
        }
        let req = FleetRequest {
            id,
            data: payload(id, m),
            enqueued: Instant::now(),
            attempts_left: cfg.retries,
            reply: reply_tx.clone(),
        };
        if fleet.submit(m, req, policy) {
            outstanding += 1;
        }
        // a shed settled the request immediately — nothing outstanding
    }

    // Settle every admitted request: exactly one terminal outcome each.
    // A failed attempt with retry budget left is resubmitted after an
    // exponential backoff; a shed at resubmission settles it there.
    let mut completed: usize = 0;
    while outstanding > 0 {
        let rep = match reply_rx.recv_timeout(Duration::from_secs(60)) {
            Ok(r) => r,
            // a lost reply would hang the loop forever; break and let
            // the accounting identity below name the discrepancy
            Err(_) => break,
        };
        match rep.error {
            None => {
                completed += 1;
                outstanding -= 1;
            }
            Some(msg) => {
                if rep.attempts_left > 0 {
                    let prior = cfg.retries.saturating_sub(rep.attempts_left);
                    let backoff = cfg.backoff * 2u32.saturating_pow(prior.min(10));
                    thread::sleep(backoff);
                    obs_log::info(format_args!(
                        "fleet: retrying request {} on model {} after {:?} backoff \
                         ({} attempts left): {msg}",
                        rep.id, rep.model, backoff, rep.attempts_left
                    ));
                    let retry = FleetRequest {
                        id: rep.id,
                        data: payload(rep.id, rep.model),
                        enqueued: Instant::now(),
                        attempts_left: rep.attempts_left - 1,
                        reply: reply_tx.clone(),
                    };
                    if !fleet.submit(rep.model, retry, policy) {
                        outstanding -= 1; // settled as a shed at resubmission
                    }
                } else {
                    outstanding -= 1; // settled as failed (worker recorded it)
                }
            }
        }
    }
    drop(reply_tx);

    let wall = t0.elapsed();
    let shutdown = fleet.shutdown()?;
    let per_model = shutdown.per_model;

    let shed: usize = per_model.iter().map(|r| r.shed).sum();
    let failed: usize = per_model.iter().map(|r| r.failed).sum();
    let by_metrics: usize = per_model.iter().map(|r| r.completed).sum();
    anyhow::ensure!(
        completed == by_metrics && (completed + shed + failed) as u64 == cfg.requests,
        "reply accounting broke: {completed} replies, {by_metrics} recorded, \
         {shed} shed, {failed} failed, {} requested",
        cfg.requests
    );
    Ok(FleetReport {
        wall,
        completed,
        shed,
        failed,
        retried: per_model.iter().map(|r| r.metrics.retries).sum(),
        quarantine_shed: per_model.iter().map(|r| r.metrics.shed_quarantined).sum(),
        degraded_served: per_model.iter().map(|r| r.metrics.degraded).sum(),
        faults_injected: fault_plan.as_ref().map(|f| f.total_injected()).unwrap_or(0),
        throughput_rps: completed as f64 / wall.as_secs_f64().max(1e-9),
        worker_errors: shutdown.worker_errors,
        per_model,
    })
}
